#!/usr/bin/env bash
# Refresh the committed hot-path bench baselines with native cargo-bench
# numbers.
#
# The authoring environment has no Rust toolchain, so the committed
# BENCH_*.json files start life as C-proxy bootstraps
# (provenance=c-proxy-estimate) that the CI regression guards deliberately
# skip.  Run this script on a real machine (CI does, uploading the result
# as the bench-hotpath-numbers artifact) to rewrite them with
# provenance=cargo-bench; committing the rewritten files arms the guards
# with like-for-like numbers.
#
# Status of the carried-over "commit the native numbers" residual (checked
# again in PR 10): still blocked in the authoring environment — there is no
# cargo in the container, so the provenance check below refuses the local
# tree by design.  The committable numbers come from CI's perf-smoke job:
# download the `bench-hotpath-numbers` artifact from a green main run,
# verify `"provenance": "cargo-bench"` in both JSONs, and commit them.
# (PR 10 also added the `pallas-lint-census` artifact on the
# lint-invariants job — rule-drift numbers per PR — but that one is
# informational and never committed.)
#
# Usage: scripts/refresh_bench_baselines.sh
#   (from the repo root; needs cargo + python3)
set -euo pipefail

cd "$(dirname "$0")/.."

BENCHES=(sampling_hotpath window_hotpath)

for bench in "${BENCHES[@]}"; do
    echo "== cargo bench --bench ${bench} (full run) =="
    cargo bench --bench "${bench}"
done

# The full runs overwrite the working-tree JSONs in place; refuse to hand
# back anything that is not a native measurement.
for bench in "${BENCHES[@]}"; do
    json="BENCH_${bench}.json"
    prov=$(python3 -c "import json,sys; print(json.load(open('${json}')).get('provenance','none'))")
    if [ "${prov}" != "cargo-bench" ]; then
        echo "ERROR: ${json} has provenance '${prov}', expected 'cargo-bench'" >&2
        echo "       (full bench run should have rewritten it — check the bench output)" >&2
        exit 1
    fi
    echo "ok: ${json} provenance=cargo-bench"
done

# Per-metric diff against the committed baselines (HEAD), so the refresh
# is a review-and-commit instead of archaeology.
python3 - <<'EOF'
import json
import subprocess

SKIP = {"slide_ms", "items_per_pane", "intervals", "n_items", "workers"}


def flatten(prefix, node, out):
    if isinstance(node, dict):
        for k, v in node.items():
            flatten(f"{prefix}.{k}" if prefix else str(k), v, out)
    elif isinstance(node, (int, float)) and not isinstance(node, bool):
        out[prefix] = float(node)


for name in ("BENCH_sampling_hotpath.json", "BENCH_window_hotpath.json"):
    try:
        committed = json.loads(
            subprocess.check_output(["git", "show", f"HEAD:{name}"], text=True)
        )
    except Exception as e:  # noqa: BLE001 - diagnostic path
        print(f"\n{name}: no committed baseline ({e})")
        committed = {}
    with open(name) as f:
        fresh = json.load(f)
    print(f"\n=== {name} ===")
    print(f"provenance: {committed.get('provenance', 'none')} -> "
          f"{fresh.get('provenance', 'none')}")
    b, fz = {}, {}
    flatten("", committed, b)
    flatten("", fresh, fz)
    for key in sorted(set(b) | set(fz)):
        if key in SKIP:
            continue
        bv, fv = b.get(key), fz.get(key)
        if bv is None:
            print(f"  {key:<40} {'-':>9} -> {fv:9.4g}  (new)")
        elif fv is None:
            print(f"  {key:<40} {bv:9.4g} -> {'-':>9}  (gone)")
        else:
            delta = "n/a" if bv == 0 else f"{(fv - bv) / bv * 100.0:+.1f}%"
            print(f"  {key:<40} {bv:9.4g} -> {fv:9.4g}  ({delta})")
EOF

echo
echo "Baselines refreshed in place.  Review the diff above, then commit"
echo "BENCH_sampling_hotpath.json and BENCH_window_hotpath.json."
