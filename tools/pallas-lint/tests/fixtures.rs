//! Fixture-snippet suite for pallas-lint: one passing and one violating
//! fixture per rule (D1–P1), allowlist round-trip, and justification-
//! comment parsing edge cases.
//!
//! Fixtures are inline snippets linted under a synthetic path label —
//! `lint_source` scopes rules by path suffix/module, so a label like
//! `rust/src/sampling/fix.rs` places a snippet "in" `sampling/` without
//! touching the real tree.  Each violating fixture also asserts the rule
//! id and 1-based line number, which is the contract CI output relies on
//! (`RULE path:line message`).

use pallas_lint::{lint_source, Allowlist, Config};

fn lint(path: &str, src: &str) -> Vec<pallas_lint::Violation> {
    lint_source(path, src, &Config::default())
}

fn rules_hit(path: &str, src: &str) -> Vec<&'static str> {
    lint(path, src).into_iter().map(|v| v.rule).collect()
}

// ---------------------------------------------------------------------- D1

#[test]
fn d1_flags_hashmap_and_hashset() {
    let src = "use std::collections::HashMap;\nfn f() { let s = std::collections::HashSet::<u64>::new(); }\n";
    let vs = lint("rust/src/engine/fix.rs", src);
    assert_eq!(vs.len(), 2);
    assert_eq!(vs[0].rule, "D1");
    assert_eq!(vs[0].line, 1);
    assert_eq!(vs[1].line, 2);
}

#[test]
fn d1_passes_btreemap_and_justified_hashmap() {
    let src = "use std::collections::BTreeMap;\n\
               // lint: sorted-before-use — keys collected and sorted before fold\n\
               use std::collections::HashMap;\n\
               fn f(m: &HashMap<u64, u64>) {} // lint: sorted-before-use\n";
    assert!(rules_hit("rust/src/engine/fix.rs", src).is_empty());
}

#[test]
fn d1_ignores_test_code_and_strings() {
    let src = "#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n}\n\
               fn g() { let s = \"HashMap\"; }\n";
    assert!(rules_hit("rust/src/engine/fix.rs", src).is_empty());
}

// ---------------------------------------------------------------------- D2

#[test]
fn d2_flags_wall_clock_outside_obs() {
    let src = "fn f() { let t = std::time::Instant::now(); }\n\
               fn g() { let t = std::time::SystemTime::now(); }\n";
    let vs = lint("rust/src/engine/fix.rs", src);
    assert_eq!(vs.iter().filter(|v| v.rule == "D2").count(), 2);
}

#[test]
fn d2_passes_in_obs_harness_or_justified() {
    let obs = "fn f() { let t = std::time::Instant::now(); }\n";
    assert!(rules_hit("rust/src/obs/fix.rs", obs).is_empty());
    assert!(rules_hit("rust/src/harness/fix.rs", obs).is_empty());
    let justified = "// lint: wall-clock — latency metric only, never feeds results\n\
                     fn f() { let t = std::time::Instant::now(); }\n";
    assert!(rules_hit("rust/src/engine/fix.rs", justified).is_empty());
}

// ---------------------------------------------------------------------- D3

#[test]
fn d3_flags_fresh_seed_literal_in_sampling() {
    let src = "fn f() { let rng = Rng::seed_from_u64(42); }\n";
    let vs = lint("rust/src/sampling/fix.rs", src);
    assert_eq!(vs.len(), 1);
    assert_eq!(vs[0].rule, "D3");
    assert_eq!(vs[0].line, 1);
}

#[test]
fn d3_passes_derived_seeds_and_outside_sampling() {
    let derived = "fn f(seed: u64) { let rng = Rng::seed_from_u64(seed ^ 0x4D41_534B); }\n";
    assert!(rules_hit("rust/src/sampling/fix.rs", derived).is_empty());
    // same literal outside sampling/ is out of scope
    let literal = "fn f() { let rng = Rng::seed_from_u64(42); }\n";
    assert!(rules_hit("rust/src/engine/fix.rs", literal).is_empty());
    // a justified stream-label salt passes
    let salted = "// lint: rng-stream — literal is the mask-stream label salt\n\
                  fn f() { let rng = Rng::seed_from_u64(7); }\n";
    assert!(rules_hit("rust/src/sampling/fix.rs", salted).is_empty());
}

// ---------------------------------------------------------------------- U1

#[test]
fn u1_flags_bare_unsafe() {
    let src = "fn f(p: *const u8) -> u8 { unsafe { *p } }\n";
    let vs = lint("rust/src/util/fix.rs", src);
    assert_eq!(vs.len(), 1);
    assert_eq!(vs[0].rule, "U1");
}

#[test]
fn u1_passes_with_safety_comment() {
    let src = "// SAFETY: caller guarantees p is valid for reads\n\
               fn f(p: *const u8) -> u8 { unsafe { *p } }\n\
               unsafe impl Send for X {} // SAFETY: X owns its allocation\n";
    assert!(rules_hit("rust/src/util/fix.rs", src).is_empty());
}

#[test]
fn u1_does_not_match_unsafe_op_in_unsafe_fn() {
    let src = "#![deny(unsafe_op_in_unsafe_fn)]\nfn f() {}\n";
    assert!(rules_hit("rust/src/lib.rs", src).is_empty());
}

// ---------------------------------------------------------------------- A1

#[test]
fn a1_flags_unjustified_orderings() {
    let src = "fn f(a: &AtomicU64) { a.fetch_add(1, Ordering::Relaxed); }\n\
               fn g(a: &AtomicU64) { a.load(Ordering::SeqCst); }\n";
    let vs = lint("rust/src/util/fix.rs", src);
    assert_eq!(vs.iter().filter(|v| v.rule == "A1").count(), 2);
}

#[test]
fn a1_passes_with_ordering_comment_or_allowlist() {
    let src = "fn f(a: &AtomicU64) {\n\
               \x20   // ordering: monotonic counter, no reader depends on it\n\
               \x20   a.fetch_add(1, Ordering::Relaxed);\n}\n";
    assert!(rules_hit("rust/src/util/fix.rs", src).is_empty());

    let bare = "fn f(a: &AtomicU64) { a.fetch_add(1, Ordering::Relaxed); }\n";
    let mut cfg = Config::default();
    cfg.allow = Allowlist::parse("[A1]\nfiles = [\"rust/src/obs/fix.rs\"]\n").unwrap();
    assert!(lint_source("rust/src/obs/fix.rs", bare, &cfg).is_empty());
    // the allowlist is per-file: a different file still trips
    assert_eq!(lint_source("rust/src/util/fix.rs", bare, &cfg).len(), 1);
}

// ---------------------------------------------------------------------- H1

#[test]
fn h1_flags_allocation_in_hot_path() {
    let src = "// lint: hot-path\n\
               fn offer(&mut self, xs: &[f64]) {\n\
               \x20   let v = Vec::new();\n\
               \x20   let s = format!(\"{}\", xs.len());\n\
               \x20   let c = xs.to_vec();\n}\n";
    let vs = lint("rust/src/sampling/fix.rs", src);
    let h1: Vec<_> = vs.iter().filter(|v| v.rule == "H1").collect();
    assert_eq!(h1.len(), 3);
    assert_eq!(h1[0].line, 3);
}

#[test]
fn h1_only_applies_inside_marked_functions() {
    let src = "fn cold() { let v = Vec::new(); let c = v.clone(); }\n\
               // lint: hot-path\n\
               fn hot(&mut self) { self.cursor += 1; }\n\
               fn cold2() { let s = format!(\"x\"); }\n";
    assert!(rules_hit("rust/src/sampling/fix.rs", src).is_empty());
}

// ---------------------------------------------------------------------- P1

#[test]
fn p1_flags_panics_in_scoped_files() {
    let src = "fn f(x: Option<u64>) -> u64 { x.unwrap() }\n\
               fn g(x: Option<u64>) -> u64 { x.expect(\"present\") }\n\
               fn h() { panic!(\"boom\"); }\n";
    let vs = lint("rust/src/util/spsc.rs", src);
    assert_eq!(vs.iter().filter(|v| v.rule == "P1").count(), 3);
    // identical code outside the scoped files is clean
    assert!(rules_hit("rust/src/util/channel.rs", src).is_empty());
}

#[test]
fn p1_passes_in_tests_and_when_justified() {
    let src = "#[cfg(test)]\nmod tests {\n\
               \x20   #[test]\n    fn t() { Some(1u64).unwrap(); }\n}\n\
               // lint: allow(P1) construction-time, before any worker runs\n\
               fn spawn_it() { do_spawn().expect(\"spawn\"); }\n";
    assert!(rules_hit("rust/src/engine/worker.rs", src).is_empty());
}

// ----------------------------------------------------------- allowlist IO

#[test]
fn allowlist_round_trips() {
    let src = "# audited obs counters\n\
               [A1]\nfiles = [\n  \"rust/src/obs/mod.rs\",  # counters\n  \"rust/src/obs/hist.rs\",\n]\n\
               [D2]\nfiles = [\"rust/src/replay.rs\"]\n";
    let a = Allowlist::parse(src).unwrap();
    assert!(a.allows("A1", "rust/src/obs/mod.rs"));
    assert!(a.allows("A1", "/abs/prefix/rust/src/obs/hist.rs"));
    assert!(!a.allows("A1", "rust/src/obs/trace.rs"));
    assert!(a.allows("D2", "rust/src/replay.rs"));
    assert!(!a.allows("H1", "rust/src/obs/mod.rs"));

    let b = Allowlist::parse(&a.to_toml()).unwrap();
    assert_eq!(a.to_toml(), b.to_toml());
}

#[test]
fn repo_allowlist_parses() {
    // The committed allowlist must always parse — a broken allowlist would
    // make the CI gate exit 2 rather than silently widening.
    let src = include_str!("../../../.lint-allow.toml");
    let a = Allowlist::parse(src).unwrap();
    assert!(a.allows("A1", "rust/src/obs/mod.rs"));
}

// ------------------------------------------- justification edge cases

#[test]
fn justification_survives_intervening_attributes() {
    // #[inline] between the comment and the code must not break the link.
    let src = "// SAFETY: index is masked to capacity\n\
               #[inline]\n\
               fn f(p: *const u8) -> u8 { unsafe { *p } }\n";
    assert!(rules_hit("rust/src/util/fix.rs", src).is_empty());
}

#[test]
fn justification_does_not_leak_past_code() {
    // A SAFETY comment on an earlier, unrelated item must not cover a
    // later unsafe block once a code line intervenes.
    let src = "// SAFETY: covers only the next item\n\
               fn a(p: *const u8) -> u8 { unsafe { *p } }\n\
               fn b(p: *const u8) -> u8 { unsafe { *p } }\n";
    let vs = lint("rust/src/util/fix.rs", src);
    assert_eq!(vs.len(), 1);
    assert_eq!(vs[0].line, 3);
}

#[test]
fn trailing_same_line_justification_counts() {
    let src = "fn f(a: &AtomicU64) { a.load(Ordering::Relaxed) } // ordering: stats-only read\n";
    assert!(rules_hit("rust/src/util/fix.rs", src).is_empty());
}

#[test]
fn tokens_inside_comments_and_strings_never_fire() {
    let src = "// this mentions HashMap, unsafe, Ordering::Relaxed, panic! and .unwrap()\n\
               fn f() { let s = \"Instant::now() .unwrap() unsafe\"; }\n\
               /* block comment: SystemTime::now Vec::new */\n\
               fn g() {}\n";
    assert!(rules_hit("rust/src/util/spsc.rs", src).is_empty());
}

#[test]
fn violation_display_format_is_rule_file_line() {
    let vs = lint("rust/src/util/fix.rs", "fn f(p: *const u8) -> u8 { unsafe { *p } }\n");
    let rendered = vs[0].to_string();
    assert!(
        rendered.starts_with("U1 rust/src/util/fix.rs:1 "),
        "unexpected format: {rendered}"
    );
}
