//! pallas-lint CLI.
//!
//! ```text
//! cargo run -p pallas-lint -- rust/src                 # lint, exit 1 on violations
//! cargo run -p pallas-lint -- rust/src --census out.json
//! cargo run -p pallas-lint -- rust/src --allow-file .lint-allow.toml --quiet
//! ```
//!
//! Exit codes: 0 = clean, 1 = violations found, 2 = usage/IO/allowlist error.
//! Violations print as `RULE path:line message` — the format CI greps and
//! the fixture suite asserts on.

use std::path::PathBuf;
use std::process::ExitCode;

use pallas_lint::{lint_paths, Allowlist, Config};

const USAGE: &str = "usage: pallas-lint <path>... [--allow-file FILE] [--census FILE] [--quiet]

Lints .rs files under each <path> for project invariants (D1 D2 D3 U1 A1 H1 P1).
  --allow-file FILE   per-rule file allowlist (default: .lint-allow.toml if present)
  --census FILE       also write a JSON violation census (counts per rule + sites)
  --quiet             suppress the per-file summary line, print violations only";

fn main() -> ExitCode {
    match run() {
        Ok(clean) => {
            if clean {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
        Err(msg) => {
            eprintln!("pallas-lint: {msg}");
            eprintln!("{USAGE}");
            ExitCode::from(2)
        }
    }
}

fn run() -> Result<bool, String> {
    let mut roots: Vec<PathBuf> = Vec::new();
    let mut allow_file: Option<PathBuf> = None;
    let mut census_file: Option<PathBuf> = None;
    let mut quiet = false;

    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--allow-file" => {
                allow_file =
                    Some(PathBuf::from(args.next().ok_or("--allow-file needs a FILE")?));
            }
            "--census" => {
                census_file = Some(PathBuf::from(args.next().ok_or("--census needs a FILE")?));
            }
            "--quiet" => quiet = true,
            "--help" | "-h" => {
                println!("{USAGE}");
                return Ok(true);
            }
            s if s.starts_with('-') => return Err(format!("unknown flag {s}")),
            _ => roots.push(PathBuf::from(arg)),
        }
    }
    if roots.is_empty() {
        return Err("no paths given".to_string());
    }

    let mut cfg = Config::default();
    let default_allow = PathBuf::from(".lint-allow.toml");
    let allow_path = match allow_file {
        Some(p) => Some(p),
        None if default_allow.exists() => Some(default_allow),
        None => None,
    };
    if let Some(p) = allow_path {
        let src = std::fs::read_to_string(&p)
            .map_err(|e| format!("cannot read allowlist {}: {e}", p.display()))?;
        cfg.allow = Allowlist::parse(&src)?;
    }

    let report = lint_paths(&roots, &cfg).map_err(|e| format!("scan failed: {e}"))?;

    for v in &report.violations {
        println!("{v}");
    }
    if let Some(p) = census_file {
        std::fs::write(&p, report.census_json())
            .map_err(|e| format!("cannot write census {}: {e}", p.display()))?;
    }
    if !quiet {
        let census = report.census();
        let per_rule: Vec<String> =
            census.iter().map(|(r, n)| format!("{r}={n}")).collect();
        eprintln!(
            "pallas-lint: {} file(s), {} violation(s) [{}]",
            report.files_scanned,
            report.violations.len(),
            per_rule.join(" ")
        );
    }
    Ok(report.violations.is_empty())
}
