//! pallas-lint — first-party invariant linter for the streamapprox tree.
//!
//! Every accuracy claim this reproduction makes rests on contracts the
//! compiler cannot see: byte-identical sampler determinism across chunk
//! sizes/workers/recovery, per-named-stream RNG discipline, zero
//! steady-state allocation in the ingest kernels, and a hand-rolled unsafe
//! SPSC ring whose memory-ordering choices are load-bearing.  The tests
//! exercise specific schedules; this linter makes the *invariants
//! themselves* un-mergeable to violate.
//!
//! The scanner is deliberately token-level (no `syn`, no regex — the build
//! is offline and zero-dep): each file is split into parallel per-line
//! `code` / `comment` streams by a small string/char/comment state machine,
//! `#[cfg(test)]` / `#[test]` regions are tracked by brace depth, and each
//! rule is a token query over non-test code plus a justification-comment
//! lookup.  False positives are handled by *justifying*, not by making the
//! scanner clever — a justification is a reviewable artifact, a cleverer
//! scanner is not.
//!
//! Rules (see `tools/pallas-lint/README.md` for the full reference):
//!
//! | id | invariant |
//! |----|-----------|
//! | D1 | no `HashMap`/`HashSet` (iteration-order nondeterminism) — use `BTreeMap`/`BTreeSet` or justify `// lint: sorted-before-use` |
//! | D2 | no `SystemTime::now`/`Instant::now`/`RandomState` outside `obs/`+`harness/` — justify `// lint: wall-clock` |
//! | D3 | no fresh seed literals in `sampling/` — derive from the named stream; justify `// lint: rng-stream` |
//! | U1 | every `unsafe` needs a `// SAFETY:` comment |
//! | A1 | every `Ordering::Relaxed`/`SeqCst` needs an `// ordering:` comment or an `.lint-allow.toml` entry |
//! | H1 | no `Vec::new`/`format!`/`.clone()`/`.to_vec(` inside `// lint: hot-path` functions |
//! | P1 | no `unwrap`/`expect`/`panic!` in `engine/worker.rs` + `util/spsc.rs` |
//!
//! Any rule can also be suppressed site-by-site with
//! `// lint: allow(<ID>) <reason>` on the offending line or the comment
//! block above it.

use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};

/// One rule violation, printed as `ID path:line message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} {}:{} {}", self.rule, self.file, self.line, self.message)
    }
}

/// Parsed `.lint-allow.toml`: per-rule lists of path suffixes whose files
/// are exempt from that rule (the audited-and-allowlisted escape hatch,
/// e.g. the obs counters' `Relaxed` orderings).
#[derive(Debug, Default, Clone)]
pub struct Allowlist {
    /// rule id -> path suffixes (forward-slash form).
    entries: BTreeMap<String, Vec<String>>,
}

impl Allowlist {
    /// Parse the TOML subset the allowlist uses:
    ///
    /// ```toml
    /// [A1]
    /// files = [
    ///   "rust/src/obs/mod.rs",  # reason
    /// ]
    /// ```
    ///
    /// Unknown keys and malformed lines are errors — a typo in the
    /// allowlist must not silently widen it.
    pub fn parse(src: &str) -> Result<Self, String> {
        let mut entries: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut section: Option<String> = None;
        let mut in_array = false;
        for (i, raw) in src.lines().enumerate() {
            let line = strip_toml_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            let lineno = i + 1;
            if in_array {
                // inside `files = [` ... `]`
                if line == "]" {
                    in_array = false;
                    continue;
                }
                let item = line.trim_end_matches(',').trim();
                if item == "]" {
                    in_array = false;
                    continue;
                }
                let path = parse_toml_string(item)
                    .ok_or_else(|| format!("allowlist line {lineno}: expected quoted path, got {item:?}"))?;
                let rule = section
                    .clone()
                    .ok_or_else(|| format!("allowlist line {lineno}: array outside a [RULE] section"))?;
                entries.entry(rule).or_default().push(path);
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                let name = name.trim();
                if name.is_empty() {
                    return Err(format!("allowlist line {lineno}: empty section name"));
                }
                section = Some(name.to_string());
                entries.entry(name.to_string()).or_default();
                continue;
            }
            if let Some(rest) = line.strip_prefix("files") {
                let rest = rest.trim_start();
                let rest = rest
                    .strip_prefix('=')
                    .ok_or_else(|| format!("allowlist line {lineno}: expected `files = [`"))?
                    .trim_start();
                if section.is_none() {
                    return Err(format!("allowlist line {lineno}: `files` outside a [RULE] section"));
                }
                if rest == "[" {
                    in_array = true;
                    continue;
                }
                // single-line array: files = ["a", "b"]
                let inner = rest
                    .strip_prefix('[')
                    .and_then(|s| s.strip_suffix(']'))
                    .ok_or_else(|| format!("allowlist line {lineno}: expected `[` after `files =`"))?;
                let rule = section.clone().unwrap_or_default();
                for item in inner.split(',') {
                    let item = item.trim();
                    if item.is_empty() {
                        continue;
                    }
                    let path = parse_toml_string(item)
                        .ok_or_else(|| format!("allowlist line {lineno}: expected quoted path, got {item:?}"))?;
                    entries.entry(rule.clone()).or_default().push(path);
                }
                continue;
            }
            return Err(format!("allowlist line {lineno}: unrecognized line {line:?}"));
        }
        if in_array {
            return Err("allowlist: unterminated files = [ array".to_string());
        }
        Ok(Self { entries })
    }

    /// Serialize back to the same TOML subset (round-trip tested).
    pub fn to_toml(&self) -> String {
        let mut out = String::new();
        for (rule, paths) in &self.entries {
            out.push_str(&format!("[{rule}]\nfiles = [\n"));
            for p in paths {
                out.push_str(&format!("  \"{p}\",\n"));
            }
            out.push_str("]\n");
        }
        out
    }

    /// Is `file` (forward-slash path) exempt from `rule`?
    pub fn allows(&self, rule: &str, file: &str) -> bool {
        self.entries
            .get(rule)
            .map(|paths| paths.iter().any(|p| file == p || file.ends_with(&format!("/{p}"))))
            .unwrap_or(false)
    }
}

fn strip_toml_comment(line: &str) -> &str {
    // The allowlist never quotes a '#', so a bare scan is enough.
    match line.find('#') {
        Some(i) => &line[..i],
        None => line,
    }
}

fn parse_toml_string(item: &str) -> Option<String> {
    let inner = item.strip_prefix('"')?.strip_suffix('"')?;
    Some(inner.to_string())
}

/// Linter configuration: the allowlist plus the scan roots.
#[derive(Debug, Default, Clone)]
pub struct Config {
    pub allow: Allowlist,
}

// ---------------------------------------------------------------------------
// Source model: parallel per-line code/comment streams.
// ---------------------------------------------------------------------------

/// One source line split into its code part (string/char literal contents
/// blanked) and its comment text (line + block comments, `//` markers
/// stripped).
#[derive(Debug, Clone, Default)]
pub struct SplitLine {
    pub code: String,
    pub comment: String,
}

/// Split `src` into per-line code/comment streams with a small state
/// machine: line comments, nested block comments, string/char/byte/raw
/// literals (contents blanked so tokens inside strings never trigger
/// rules), and the `'a`-lifetime-vs-`'a'`-char-literal distinction.
pub fn split_lines(src: &str) -> Vec<SplitLine> {
    #[derive(PartialEq, Clone, Copy)]
    enum St {
        Code,
        LineComment,
        Block(u32),
        Str,
        RawStr(u32),
        Char,
    }
    let bytes = src.as_bytes();
    let mut st = St::Code;
    let mut lines = Vec::new();
    let mut cur = SplitLine::default();
    let mut i = 0;
    while i < bytes.len() {
        let c = bytes[i] as char;
        if c == '\n' {
            if st == St::LineComment {
                st = St::Code;
            }
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                let next = bytes.get(i + 1).map(|&b| b as char);
                match c {
                    '/' if next == Some('/') => {
                        st = St::LineComment;
                        i += 2;
                    }
                    '/' if next == Some('*') => {
                        st = St::Block(1);
                        i += 2;
                    }
                    '"' => {
                        // keep the quotes as token separators
                        cur.code.push('"');
                        st = St::Str;
                        i += 1;
                    }
                    'r' | 'b' if !prev_is_ident(&cur.code) => {
                        // raw / byte / byte-raw string prefixes
                        let (hashes, quote_at) = raw_string_open(&bytes[i..]);
                        if let Some(off) = quote_at {
                            cur.code.push('"');
                            st = St::RawStr(hashes);
                            i += off + 1;
                        } else if c == 'b' && next == Some('\'') {
                            cur.code.push('\'');
                            st = St::Char;
                            i += 2;
                        } else {
                            cur.code.push(c);
                            i += 1;
                        }
                    }
                    '\'' => {
                        // char literal iff `'\...'` or `'X'`; else lifetime
                        let nn = bytes.get(i + 2).map(|&b| b as char);
                        if next == Some('\\') || nn == Some('\'') {
                            cur.code.push('\'');
                            st = St::Char;
                            i += 1;
                        } else {
                            cur.code.push('\'');
                            i += 1;
                        }
                    }
                    _ => {
                        cur.code.push(c);
                        i += 1;
                    }
                }
            }
            St::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            St::Block(depth) => {
                let next = bytes.get(i + 1).map(|&b| b as char);
                if c == '*' && next == Some('/') {
                    st = if depth == 1 { St::Code } else { St::Block(depth - 1) };
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::Block(depth + 1);
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    i += 2;
                } else if c == '"' {
                    cur.code.push('"');
                    st = St::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            St::RawStr(hashes) => {
                if c == '"' && has_hashes(&bytes[i + 1..], hashes) {
                    cur.code.push('"');
                    st = St::Code;
                    i += 1 + hashes as usize;
                } else {
                    i += 1;
                }
            }
            St::Char => {
                if c == '\\' {
                    i += 2;
                } else if c == '\'' {
                    cur.code.push('\'');
                    st = St::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
        }
    }
    if !cur.code.is_empty() || !cur.comment.is_empty() {
        lines.push(cur);
    }
    lines
}

fn prev_is_ident(code: &str) -> bool {
    code.chars().last().map(|c| c.is_alphanumeric() || c == '_').unwrap_or(false)
}

/// For a byte slice starting at `r`/`b`: if it opens a raw string
/// (`r"`, `r#"`, `br##"`, ...), return (hash count, offset of the quote).
fn raw_string_open(bytes: &[u8]) -> (u32, Option<usize>) {
    let mut j = 1;
    if bytes.first() == Some(&b'b') && bytes.get(1) == Some(&b'r') {
        j = 2;
    } else if bytes.first() == Some(&b'b') {
        // plain byte string b"..."
        if bytes.get(1) == Some(&b'"') {
            return (0, Some(1));
        }
        return (0, None);
    }
    let mut hashes = 0u32;
    while bytes.get(j) == Some(&b'#') {
        hashes += 1;
        j += 1;
    }
    if bytes.get(j) == Some(&b'"') {
        (hashes, Some(j))
    } else {
        (0, None)
    }
}

fn has_hashes(bytes: &[u8], n: u32) -> bool {
    (0..n as usize).all(|k| bytes.get(k) == Some(&b'#'))
}

/// Per-line facts the rules consume.
#[derive(Debug)]
pub struct ScannedFile {
    pub lines: Vec<SplitLine>,
    /// True where the line sits inside `#[cfg(test)]` / `#[test]` code.
    pub in_test: Vec<bool>,
    /// True where the line sits inside a `// lint: hot-path` function body.
    pub in_hot: Vec<bool>,
}

/// Scan a file: split code/comments, then walk brace depth to mark test
/// regions and `// lint: hot-path` function bodies.
pub fn scan(src: &str) -> ScannedFile {
    let lines = split_lines(src);
    let n = lines.len();
    let mut in_test = vec![false; n];
    let mut in_hot = vec![false; n];

    let mut depth: i64 = 0;
    // open test/hot regions, recorded as the depth *at* their opening brace
    let mut test_stack: Vec<i64> = Vec::new();
    let mut hot_stack: Vec<i64> = Vec::new();
    let mut pending_test = false;
    let mut pending_hot = false;

    for (idx, line) in lines.iter().enumerate() {
        let code = line.code.as_str();
        let comment = line.comment.as_str();
        if code.contains("#[cfg(test)]") || code.contains("#[test]") {
            pending_test = true;
        }
        if comment.contains("lint: hot-path") {
            pending_hot = true;
        }
        let started_in = (!test_stack.is_empty(), !hot_stack.is_empty());
        for ch in code.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    if pending_test {
                        test_stack.push(depth);
                        pending_test = false;
                    }
                    if pending_hot {
                        hot_stack.push(depth);
                        pending_hot = false;
                    }
                }
                '}' => {
                    if test_stack.last() == Some(&depth) {
                        test_stack.pop();
                    }
                    if hot_stack.last() == Some(&depth) {
                        hot_stack.pop();
                    }
                    depth -= 1;
                }
                // an attribute that applies to a brace-less item
                // (`#[cfg(test)] use x;`) expires at the `;`
                ';' if pending_test && !code.contains('{') => {
                    pending_test = false;
                }
                _ => {}
            }
        }
        // A line counts as test/hot if it started inside the region or the
        // region is still open at end of line — the opening line itself is
        // covered either way.
        in_test[idx] = started_in.0 || !test_stack.is_empty();
        in_hot[idx] = started_in.1 || !hot_stack.is_empty();
    }

    ScannedFile { lines, in_test, in_hot }
}

/// Does the violation at `line` carry the given justification marker —
/// trailing on the same line, or in the contiguous comment block above
/// (attribute-only lines like `#[inline]` may sit between the comment and
/// the code)?
pub fn justified(file: &ScannedFile, line: usize, markers: &[&str]) -> bool {
    let has = |s: &str| markers.iter().any(|m| s.contains(m));
    if has(&file.lines[line].comment) {
        return true;
    }
    let mut j = line;
    while j > 0 {
        j -= 1;
        let l = &file.lines[j];
        let code = l.code.trim();
        let is_attr = code.starts_with("#[") && code.ends_with(']');
        if code.is_empty() || is_attr {
            if has(&l.comment) {
                return true;
            }
            continue;
        }
        return false;
    }
    false
}

/// Word-boundary token search on a code line (identifier chars delimit).
pub fn contains_token(code: &str, token: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find(token) {
        let at = start + pos;
        let before_ok = at == 0
            || !code[..at]
                .chars()
                .last()
                .map(|c| c.is_alphanumeric() || c == '_')
                .unwrap_or(false);
        let after = code[at + token.len()..].chars().next();
        let after_ok = !after.map(|c| c.is_alphanumeric() || c == '_').unwrap_or(false);
        if before_ok && after_ok {
            return true;
        }
        start = at + token.len();
    }
    false
}

/// D3 helper: does this code line seed an RNG from a bare literal
/// (`seed_from_u64(42)` / `seed_from_u64(0xABCD)`)?  Derivations from a
/// named stream (`seed_from_u64(self.seed ^ ...)`) pass.
pub fn seeds_from_literal(code: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find("seed_from_u64") {
        let at = start + pos;
        let rest = &code[at + "seed_from_u64".len()..];
        let mut chars = rest.chars().skip_while(|c| c.is_whitespace());
        if chars.next() == Some('(') {
            if let Some(first) = chars.find(|c| !c.is_whitespace()) {
                if first.is_ascii_digit() {
                    return true;
                }
            }
        }
        start = at + "seed_from_u64".len();
    }
    false
}

// ---------------------------------------------------------------------------
// Rules
// ---------------------------------------------------------------------------

const GENERIC_ALLOW: [&str; 7] = [
    "lint: allow(D1", "lint: allow(D2", "lint: allow(D3", "lint: allow(U1",
    "lint: allow(A1", "lint: allow(H1", "lint: allow(P1",
];

fn allow_marker(rule: &'static str) -> &'static str {
    match rule {
        "D1" => GENERIC_ALLOW[0],
        "D2" => GENERIC_ALLOW[1],
        "D3" => GENERIC_ALLOW[2],
        "U1" => GENERIC_ALLOW[3],
        "A1" => GENERIC_ALLOW[4],
        "H1" => GENERIC_ALLOW[5],
        _ => GENERIC_ALLOW[6],
    }
}

/// Normalize to forward slashes for scope matching.
fn norm(path: &str) -> String {
    path.replace('\\', "/")
}

fn in_module(path: &str, module: &str) -> bool {
    let needle = format!("/{module}/");
    path.contains(&needle) || path.starts_with(&format!("{module}/"))
}

/// All rule ids, in report order.
pub const RULES: [&str; 7] = ["D1", "D2", "D3", "U1", "A1", "H1", "P1"];

/// Lint one file's source under `path` (used verbatim in reports; scope
/// rules match on it, so fixture tests can place a snippet "in" any
/// module).
pub fn lint_source(path: &str, src: &str, cfg: &Config) -> Vec<Violation> {
    let path = norm(path);
    let scanned = scan(src);
    let mut out = Vec::new();
    let mut push = |rule: &'static str, line: usize, message: String| {
        out.push(Violation { rule, file: path.clone(), line: line + 1, message });
    };

    let p1_scoped = path.ends_with("engine/worker.rs") || path.ends_with("util/spsc.rs");
    let d2_exempt = in_module(&path, "obs") || in_module(&path, "harness");
    let d3_scoped = in_module(&path, "sampling");

    for (i, line) in scanned.lines.iter().enumerate() {
        if scanned.in_test[i] {
            continue;
        }
        let code = line.code.as_str();

        // D1 — iteration-order nondeterminism
        if !cfg.allow.allows("D1", &path)
            && (contains_token(code, "HashMap") || contains_token(code, "HashSet"))
            && !justified(&scanned, i, &["lint: sorted-before-use", allow_marker("D1")])
        {
            push(
                "D1",
                i,
                "HashMap/HashSet iteration order is nondeterministic and breaks byte-identity; \
                 use BTreeMap/BTreeSet or justify with `// lint: sorted-before-use`"
                    .to_string(),
            );
        }

        // D2 — wall-clock / random hash state outside obs/ + harness/
        if !d2_exempt && !cfg.allow.allows("D2", &path) {
            for tok in ["SystemTime::now", "Instant::now", "RandomState"] {
                if code.contains(tok)
                    && !justified(&scanned, i, &["lint: wall-clock", allow_marker("D2")])
                {
                    push(
                        "D2",
                        i,
                        format!(
                            "{tok} outside obs/ and harness/ can leak nondeterminism into results; \
                             justify with `// lint: wall-clock` if it only feeds metrics/latency"
                        ),
                    );
                }
            }
        }

        // D3 — fresh seed literals in sampling/
        if d3_scoped
            && !cfg.allow.allows("D3", &path)
            && seeds_from_literal(code)
            && !justified(&scanned, i, &["lint: rng-stream", allow_marker("D3")])
        {
            push(
                "D3",
                i,
                "RNG seeded from a bare literal in sampling/ — every draw must derive from the \
                 sampler's named seed stream so runs stay reproducible; justify with \
                 `// lint: rng-stream` if the literal is a stream-label salt"
                    .to_string(),
            );
        }

        // U1 — unsafe needs SAFETY
        if !cfg.allow.allows("U1", &path)
            && contains_token(code, "unsafe")
            && !justified(&scanned, i, &["SAFETY:", allow_marker("U1")])
        {
            push(
                "U1",
                i,
                "`unsafe` without a `// SAFETY:` comment stating the invariant that makes it sound"
                    .to_string(),
            );
        }

        // A1 — atomic ordering justification
        if !cfg.allow.allows("A1", &path) {
            for tok in ["Ordering::Relaxed", "Ordering::SeqCst"] {
                if code.contains(tok)
                    && !justified(&scanned, i, &["ordering:", allow_marker("A1")])
                {
                    push(
                        "A1",
                        i,
                        format!(
                            "{tok} without an `// ordering:` justification (why this ordering is \
                             sufficient/necessary); audited files can be listed in .lint-allow.toml"
                        ),
                    );
                }
            }
        }

        // H1 — hot-path allocation discipline
        if scanned.in_hot[i] && !cfg.allow.allows("H1", &path) {
            for tok in ["Vec::new", "format!", ".clone()", ".to_vec("] {
                if code.contains(tok) && !justified(&scanned, i, &[allow_marker("H1")]) {
                    push(
                        "H1",
                        i,
                        format!(
                            "`{tok}` inside a `// lint: hot-path` function — ingest kernels, SPSC \
                             push/pop and pane merges must not allocate or copy in steady state"
                        ),
                    );
                }
            }
        }

        // P1 — panic discipline in the worker/transport layer
        if p1_scoped && !cfg.allow.allows("P1", &path) {
            for tok in [".unwrap()", ".expect(", "panic!"] {
                if code.contains(tok) && !justified(&scanned, i, &[allow_marker("P1")]) {
                    push(
                        "P1",
                        i,
                        format!(
                            "`{tok}` in worker/transport non-test code — a panic here poisons the \
                             ring and deadlocks the coordinator; return an Error or justify"
                        ),
                    );
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

/// Lint run summary: violations plus per-rule counts (the census).
#[derive(Debug, Default)]
pub struct Report {
    pub violations: Vec<Violation>,
    pub files_scanned: usize,
}

impl Report {
    /// Per-rule violation counts, all rules present (zero-filled).
    pub fn census(&self) -> BTreeMap<&'static str, usize> {
        let mut c: BTreeMap<&'static str, usize> = RULES.iter().map(|r| (*r, 0)).collect();
        for v in &self.violations {
            *c.entry(v.rule).or_insert(0) += 1;
        }
        c
    }

    /// Census as a small JSON object (hand-written — zero-dep).
    pub fn census_json(&self) -> String {
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"files_scanned\": {},\n", self.files_scanned));
        s.push_str(&format!("  \"total\": {},\n", self.violations.len()));
        s.push_str("  \"by_rule\": {");
        let census = self.census();
        let mut first = true;
        for (rule, n) in &census {
            if !first {
                s.push(',');
            }
            first = false;
            s.push_str(&format!("\n    \"{rule}\": {n}"));
        }
        s.push_str("\n  },\n  \"violations\": [");
        let mut first = true;
        for v in &self.violations {
            if !first {
                s.push(',');
            }
            first = false;
            s.push_str(&format!(
                "\n    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}}}",
                v.rule, v.file, v.line
            ));
        }
        s.push_str("\n  ]\n}\n");
        s
    }
}

/// Recursively collect `.rs` files under `root` in sorted order (stable
/// output across filesystems).
pub fn collect_rs_files(root: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    if root.is_file() {
        out.push(root.to_path_buf());
        return Ok(out);
    }
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(root)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            out.extend(collect_rs_files(&p)?);
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
    Ok(out)
}

/// Lint every `.rs` file under the given roots.
pub fn lint_paths(roots: &[PathBuf], cfg: &Config) -> std::io::Result<Report> {
    let mut report = Report::default();
    for root in roots {
        for file in collect_rs_files(root)? {
            let src = std::fs::read_to_string(&file)?;
            let label = norm(&file.to_string_lossy());
            report.violations.extend(lint_source(&label, &src, cfg));
            report.files_scanned += 1;
        }
    }
    report.violations.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitter_blanks_strings_and_comments() {
        let src = "let x = \"Ordering::Relaxed\"; // HashMap in comment\nlet y = 1;\n";
        let lines = split_lines(src);
        assert!(!lines[0].code.contains("Relaxed"));
        assert!(lines[0].comment.contains("HashMap"));
        assert_eq!(lines[1].code.trim(), "let y = 1;");
    }

    #[test]
    fn lifetime_is_not_a_char_literal() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x }\nlet c = 'x';\nlet n = 1;\n";
        let lines = split_lines(src);
        assert!(lines[0].code.contains("fn f"));
        assert!(lines[0].code.contains("{ x }"));
        assert_eq!(lines[2].code.trim(), "let n = 1;");
    }

    #[test]
    fn raw_string_with_hashes() {
        let src = "let s = r#\"unsafe { HashMap }\"#;\nlet t = 2;\n";
        let lines = split_lines(src);
        assert!(!lines[0].code.contains("unsafe"));
        assert_eq!(lines[1].code.trim(), "let t = 2;");
    }

    #[test]
    fn token_word_boundaries() {
        assert!(contains_token("use std::collections::HashMap;", "HashMap"));
        assert!(!contains_token("deny(unsafe_op_in_unsafe_fn)", "unsafe"));
        assert!(contains_token("unsafe impl Send for X {}", "unsafe"));
    }

    #[test]
    fn seed_literal_detection() {
        assert!(seeds_from_literal("let r = Rng::seed_from_u64(42);"));
        assert!(seeds_from_literal("Rng::seed_from_u64( 0xABCD )"));
        assert!(!seeds_from_literal("Rng::seed_from_u64(seed ^ 0x4D)"));
        assert!(!seeds_from_literal("Rng::seed_from_u64(self.seed)"));
    }

    #[test]
    fn allowlist_rejects_malformed_lines() {
        assert!(Allowlist::parse("files = [\"x\"]").is_err()); // outside section
        assert!(Allowlist::parse("[A1]\nfiles = [\n\"unterminated\",\n").is_err());
        assert!(Allowlist::parse("[A1]\nnot_files = 3\n").is_err());
    }
}
