//! Integration tests for the sketch-backed queries (Quantile / Distinct /
//! TopK) end-to-end through both engines — the acceptance gates of the
//! sketch subsystem:
//!
//! * quantile rank error stays within the sketch's configured ε against the
//!   exact per-window distribution;
//! * top-k over a skewed CAIDA-style source trace recovers the true top-3
//!   sources at every sampling fraction in {0.8, 0.4, 0.1};
//! * same seed ⇒ identical top-k output (seeded-RNG discipline).

use streamapprox::budget::QueryBudget;
use streamapprox::datasets::CaidaSourcesConfig;
use streamapprox::engine::{EngineKind, WindowReport};
use streamapprox::pipeline::PipelineBuilder;
use streamapprox::prelude::*;
use streamapprox::sketch::SketchParams;

fn sources_trace(duration_ms: u64) -> Vec<streamapprox::core::Item> {
    CaidaSourcesConfig { flows_per_sec: 8_000.0, ..Default::default() }.generate(duration_ms)
}

/// Exact values of items whose event time falls inside the window span.
fn window_values(items: &[streamapprox::core::Item], w: &WindowReport) -> Vec<f64> {
    items
        .iter()
        .filter(|i| i.ts >= w.start_ms && i.ts < w.end_ms)
        .map(|i| i.value)
        .collect()
}

#[test]
fn quantile_rank_error_within_configured_eps() {
    let items = sources_trace(12_000);
    // ε = 2/50 = 4% — well above the residual sampling noise at these
    // fractions, so the sketch guarantee is the binding constraint.
    let params = SketchParams { quantile_clusters: 50, ..Default::default() };
    let eps = 2.0 / params.quantile_clusters as f64;

    for (sampler, fraction) in [(SamplerKind::None, 1.0), (SamplerKind::Oasrs, 0.4)] {
        for q in [0.5, 0.9] {
            let p = PipelineBuilder::new()
                .engine(EngineKind::Pipelined)
                .sampler(sampler)
                .budget(QueryBudget::SamplingFraction(fraction))
                .query(Query::Quantile(q))
                .window(WindowConfig::tumbling(2_000))
                .sketch_params(params)
                .seed(7)
                .build_native();
            let r = p.run_items(&items).unwrap();
            assert!(r.windows.len() >= 4, "windows {}", r.windows.len());
            for w in r.windows.iter().filter(|w| w.start_ms > 0) {
                let vals = window_values(&items, w);
                assert!(!vals.is_empty());
                let approx = w.result.value();
                assert!(approx.is_finite());
                let rank =
                    vals.iter().filter(|&&v| v <= approx).count() as f64 / vals.len() as f64;
                assert!(
                    (rank - q).abs() <= eps,
                    "{sampler:?}@{fraction} q={q}: rank {rank} off by more than ε={eps} \
                     (window {}..{})",
                    w.start_ms,
                    w.end_ms,
                );
            }
        }
    }
}

#[test]
fn quantile_bound_brackets_exact_value_unsampled() {
    let items = sources_trace(8_000);
    let p = PipelineBuilder::new()
        .sampler(SamplerKind::None)
        .query(Query::Quantile(0.5))
        .window(WindowConfig::tumbling(2_000))
        .seed(8)
        .build_native();
    let r = p.run_items(&items).unwrap();
    for w in r.windows.iter().filter(|w| w.start_ms > 0) {
        let mut vals = window_values(&items, w);
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let exact = vals[(vals.len() - 1) / 2];
        let ci = w.result.scalar.unwrap();
        // the rank-ε value band must cover the exact median (with a little
        // slack for the discrete↔interpolated rank convention)
        let slack = 0.05 * exact.abs();
        assert!(
            ci.lo() - slack <= exact && exact <= ci.hi() + slack,
            "window {}..{}: exact {exact} outside [{}, {}]",
            w.start_ms,
            w.end_ms,
            ci.lo(),
            ci.hi(),
        );
    }
}

#[test]
fn top_k_recovers_true_top3_at_all_fractions() {
    let items = sources_trace(12_000);
    for engine in [EngineKind::Pipelined, EngineKind::Batched] {
        for fraction in [0.8, 0.4, 0.1] {
            let p = PipelineBuilder::new()
                .engine(engine)
                .sampler(SamplerKind::Oasrs)
                .budget(QueryBudget::SamplingFraction(fraction))
                .query(Query::TopK(10))
                .window(WindowConfig::tumbling(2_000))
                .seed(9)
                .build_native();
            let r = p.run_items(&items).unwrap();
            assert!(!r.windows.is_empty());
            for w in &r.windows {
                let exact = w.exact_per_stratum.as_ref().expect("exact counts");
                let true_top3 = streamapprox::query::top_k_strata(exact, 3);
                let top = w.result.top_k.as_ref().expect("top-k list");
                let keys: Vec<u64> = top.iter().map(|&(k, _)| k).collect();
                for &s in &true_top3 {
                    assert!(
                        keys.contains(&(s as u64)),
                        "{engine:?}@{fraction}: true top-3 stratum {s} missing from {keys:?} \
                         (window {}..{})",
                        w.start_ms,
                        w.end_ms,
                    );
                }
            }
        }
    }
}

#[test]
fn top_k_counts_track_exact_counts() {
    let items = sources_trace(10_000);
    let p = PipelineBuilder::new()
        .sampler(SamplerKind::Oasrs)
        .budget(QueryBudget::SamplingFraction(0.6))
        .query(Query::TopK(5))
        .window(WindowConfig::tumbling(2_000))
        .seed(10)
        .build_native();
    let r = p.run_items(&items).unwrap();
    let loss = r.mean_accuracy_loss();
    assert!(loss < 0.05, "top-5 mass accuracy loss {loss}");
}

#[test]
fn distinct_estimate_within_hll_bound_unsampled() {
    let items = sources_trace(8_000);
    let p = PipelineBuilder::new()
        .sampler(SamplerKind::None)
        .query(Query::Distinct)
        .window(WindowConfig::tumbling(2_000))
        .seed(11)
        .build_native();
    let r = p.run_items(&items).unwrap();
    for w in r.windows.iter().filter(|w| w.start_ms > 0) {
        let vals = window_values(&items, w);
        let exact = {
            let mut seen = std::collections::HashSet::new();
            for v in &vals {
                seen.insert(v.to_bits());
            }
            seen.len() as f64
        };
        let est = w.result.value();
        let rel = (est - exact).abs() / exact;
        // default HLL p=12 -> RSE ~1.6%; allow 4σ
        assert!(rel < 4.0 * 0.0163, "distinct {est} vs exact {exact} (rel {rel})");
    }
}

#[test]
fn same_seed_same_top_k_output() {
    let items = sources_trace(8_000);
    let run = |seed: u64| {
        let p = PipelineBuilder::new()
            .engine(EngineKind::Pipelined)
            .sampler(SamplerKind::Oasrs)
            .budget(QueryBudget::SamplingFraction(0.3))
            .query(Query::TopK(10))
            .window(WindowConfig::tumbling(2_000))
            .seed(seed)
            .build_native();
        let r = p.run_items(&items).unwrap();
        r.windows
            .iter()
            .map(|w| w.result.top_k.clone().unwrap())
            .collect::<Vec<_>>()
    };
    let a = run(42);
    let b = run(42);
    assert_eq!(a, b, "same seed must reproduce the identical top-k lists");
}

#[test]
fn weighted_res_sampler_feeds_top_k() {
    // A-ExpJ value-weighted sampling over-represents heavy flows (no 1/π
    // correction — see sampling/weighted.rs docs), so it pairs with TopK
    // heavy-hitter recovery, NOT with calibrated quantiles.  Plumbing check:
    // the head sources must still surface through the pipelined engine.
    let items = sources_trace(8_000);
    let p = PipelineBuilder::new()
        .sampler(SamplerKind::WeightedRes)
        .budget(QueryBudget::SamplingFraction(0.2))
        .query(Query::TopK(10))
        .window(WindowConfig::tumbling(2_000))
        .seed(12)
        .build_native();
    let r = p.run_items(&items).unwrap();
    assert!(!r.windows.is_empty());
    for w in &r.windows {
        let top = w.result.top_k.as_ref().expect("top-k list");
        assert!(!top.is_empty());
        let keys: Vec<u64> = top.iter().map(|&(k, _)| k).collect();
        // the most popular source must be present in every window's top-10
        assert!(keys.contains(&0), "head source missing from {keys:?}");
        assert!(w.result.value().is_finite());
    }
}
