//! Seeded disorder-equivalence suite for event-time windowing.
//!
//! The headline contract: with event-time mode on, a bounded shuffle of the
//! trace (every arrival delayed at most `watermark_skew + allowed_lateness`
//! virtual ms) changes *nothing* — same seed, same trace, the in-order run
//! and the shuffled run emit byte-identical window estimates, for every
//! sampler kind, on both engines, with zero drops.  The router buffers each
//! open pane and releases it in canonical `(ts, stratum, value bits)` order
//! at close, so the order-sensitive reservoir samplers see identical
//! per-pane sequences either way.
//!
//! Around it: property tests that a closed pane is never mutated (items
//! routed at a sealed pane drop, exactly once, and never surface), that
//! `late_dropped` counts exactly the beyond-lateness items, and that those
//! drops widen the affected window's confidence interval by exactly the
//! missing mass.

use streamapprox::engine::WindowReport;
use streamapprox::prelude::*;
use streamapprox::runtime::{CheckpointSpec, DurabilityOptions};
use streamapprox::stream::{DisorderConfig, StreamGenerator};
use streamapprox::util::rng::Rng;
use streamapprox::window::{EventTimeConfig, EventTimeRouter};

/// Event-time-sorted base trace (the "in-order" arrival sequence).
fn sorted_trace(rate: f64, seed: u64, dur_ms: u64) -> Vec<Item> {
    let mut items = StreamGenerator::new(&StreamConfig::gaussian_micro(rate, seed))
        .take_until(dur_ms);
    items.sort_by_key(|i| i.ts);
    items
}

fn build(
    svc: &ComputeService,
    engine: EngineKind,
    sampler: SamplerKind,
    query: Query,
    workers: usize,
    skew_ms: u64,
    lateness_ms: u64,
) -> Pipeline {
    PipelineBuilder::new()
        .engine(engine)
        .sampler(sampler)
        // Fixed fraction: the pipelined engine applies budget feedback at a
        // racy point in the loop, so only a constant fraction is
        // replay-deterministic.
        .budget(QueryBudget::SamplingFraction(0.4))
        .query(query)
        .window(WindowConfig::new(2_000, 1_000))
        .workers(workers)
        .seed(4242)
        .event_time(skew_ms, lateness_ms)
        .build_with_handle(svc.handle())
}

fn assert_windows_byte_identical(a: &RunReport, b: &RunReport, tag: &str) {
    assert_eq!(a.windows.len(), b.windows.len(), "{tag}: window count");
    for (x, y) in a.windows.iter().zip(&b.windows) {
        let w = format!("{tag} window {}-{}", x.start_ms, x.end_ms);
        assert_eq!(x.start_ms, y.start_ms, "{w}: start");
        assert_eq!(x.end_ms, y.end_ms, "{w}: end");
        assert_eq!(x.sampled, y.sampled, "{w}: sample size");
        assert_eq!(x.arrived.to_bits(), y.arrived.to_bits(), "{w}: arrived");
        assert_eq!(x.late_dropped, y.late_dropped, "{w}: late_dropped");
        assert_eq!(
            x.result.value().to_bits(),
            y.result.value().to_bits(),
            "{w}: estimate {} vs {}",
            x.result.value(),
            y.result.value()
        );
        match (x.result.scalar, y.result.scalar) {
            (Some(ca), Some(cb)) => {
                assert_eq!(ca.bound.to_bits(), cb.bound.to_bits(), "{w}: bound")
            }
            (None, None) => {}
            _ => panic!("{w}: scalar presence diverged"),
        }
        match (x.exact_scalar, y.exact_scalar) {
            (Some(ea), Some(eb)) => assert_eq!(ea.to_bits(), eb.to_bits(), "{w}: exact"),
            (None, None) => {}
            _ => panic!("{w}: exact presence diverged"),
        }
    }
}

/// The headline: in-order vs bounded-shuffle, byte-identical, every sampler
/// kind, both engines, zero drops.
#[test]
fn seeded_disorder_equivalence_all_samplers_both_engines() {
    const SKEW: u64 = 300;
    const LATENESS: u64 = 200;
    let et = EventTimeConfig::new(SKEW, LATENESS);
    // Worst-case injected delay exactly matches the lossless budget.
    let disorder = DisorderConfig::bounded_skew(400, 99).with_stragglers(0.05, 100);
    assert_eq!(disorder.max_delay_ms(), et.max_lossless_delay_ms());

    let svc = ComputeService::native();
    let in_order = sorted_trace(200.0, 31, 10_000);
    let shuffled = disorder.apply(&in_order);
    assert_ne!(shuffled, in_order, "disorder must actually reorder the trace");

    for engine in [EngineKind::Batched, EngineKind::Pipelined] {
        for sampler in [
            SamplerKind::Oasrs,
            SamplerKind::Srs,
            SamplerKind::Sts,
            SamplerKind::WeightedRes,
            SamplerKind::None,
        ] {
            let tag = format!("{engine:?}/{sampler:?}");
            let run = |items: &[Item]| {
                build(&svc, engine, sampler, Query::Sum, 1, SKEW, LATENESS)
                    .run_items(items)
                    .unwrap()
            };
            let a = run(&in_order);
            let b = run(&shuffled);
            assert!(a.windows.len() >= 8, "{tag}: only {} windows", a.windows.len());
            assert_eq!(
                a.windows.iter().map(|w| w.late_dropped).sum::<u64>(),
                0,
                "{tag}: in-order run dropped items"
            );
            assert_eq!(
                b.windows.iter().map(|w| w.late_dropped).sum::<u64>(),
                0,
                "{tag}: within-lateness shuffle must drop nothing"
            );
            assert_windows_byte_identical(&a, &b, &tag);
        }
    }
}

/// Multi-worker pools keep the equivalence: chunk round-robin assignment is
/// a function of the canonical pane sequences, not of arrival order.
#[test]
fn disorder_equivalence_survives_threaded_ingest() {
    let svc = ComputeService::native();
    let in_order = sorted_trace(300.0, 47, 8_000);
    let shuffled = DisorderConfig::bounded_skew(500, 5).apply(&in_order);
    for engine in [EngineKind::Batched, EngineKind::Pipelined] {
        let tag = format!("{engine:?}/Oasrs/3-workers");
        let run = |items: &[Item]| {
            build(&svc, engine, SamplerKind::Oasrs, Query::Sum, 3, 300, 200)
                .run_items(items)
                .unwrap()
        };
        let a = run(&in_order);
        let b = run(&shuffled);
        assert_windows_byte_identical(&a, &b, &tag);
    }
}

/// Ground-truth check: with the exact (native) sampler and a COUNT query,
/// event-time windows over a *disordered* trace equal the legacy engine's
/// windows over the sorted trace — the router reconstructs exactly the
/// spans the sorted range scan reads off directly.
#[test]
fn event_time_count_matches_legacy_sorted_scan() {
    let svc = ComputeService::native();
    let in_order = sorted_trace(250.0, 53, 10_000);
    let shuffled = DisorderConfig::bounded_skew(450, 13).apply(&in_order);
    for engine in [EngineKind::Batched, EngineKind::Pipelined] {
        let legacy = PipelineBuilder::new()
            .engine(engine)
            .sampler(SamplerKind::None)
            .budget(QueryBudget::SamplingFraction(1.0))
            .query(Query::Count)
            .window(WindowConfig::new(2_000, 1_000))
            .build_with_handle(svc.handle())
            .run_items(&in_order)
            .unwrap();
        let et = build(&svc, engine, SamplerKind::None, Query::Count, 1, 300, 200)
            .run_items(&shuffled)
            .unwrap();
        assert_eq!(legacy.windows.len(), et.windows.len(), "{engine:?}: window count");
        for (l, e) in legacy.windows.iter().zip(&et.windows) {
            assert_eq!(l.end_ms, e.end_ms);
            assert_eq!(
                l.result.value(),
                e.result.value(),
                "{engine:?} window {}-{}: legacy {} vs event-time {}",
                l.start_ms,
                l.end_ms,
                l.result.value(),
                e.result.value()
            );
            let span = in_order
                .iter()
                .filter(|i| i.ts >= e.start_ms && i.ts < e.end_ms)
                .count() as f64;
            assert_eq!(e.result.value(), span, "window {}-{}", e.start_ms, e.end_ms);
        }
    }
}

/// Property: a closed pane is never mutated.  Every item a seeded
/// adversarial arrival order routes at or below the close boundary drops —
/// exactly once — and never surfaces in any released pane; everything else
/// surfaces exactly once, in its own pane, and pane ids only advance.
#[test]
fn closed_panes_are_immutable_under_adversarial_arrivals() {
    const INTERVAL: u64 = 100;
    for seed in 0..6u64 {
        let mut rng = Rng::seed_from_u64(0xE7 + seed);
        // Unbounded disorder: ~half the items arrive far beyond any
        // lateness budget, forcing sealed-pane hits.
        let mut arrivals: Vec<Item> = (0..2_000u64)
            .map(|i| Item::new((i % 5) as u16, i as f64, rng.range_usize(0, 1_500) as u64))
            .collect();
        let order: Vec<usize> =
            (0..arrivals.len()).map(|_| rng.range_usize(0, arrivals.len())).collect();
        // seeded shuffle by random keys (stable; same multiset)
        let mut keyed: Vec<(usize, Item)> =
            order.into_iter().zip(arrivals.drain(..)).collect();
        keyed.sort_by_key(|&(k, _)| k);

        let mut router = EventTimeRouter::new(INTERVAL, EventTimeConfig::new(40, 60));
        let mut surfaced: Vec<Item> = Vec::new();
        let mut pane_id = 0u64;
        let drain = |router: &mut EventTimeRouter, surfaced: &mut Vec<Item>,
                     pane_id: &mut u64| {
            while let Some(pane) = router.next_ready() {
                for item in &pane {
                    assert_eq!(
                        item.ts / INTERVAL,
                        *pane_id,
                        "seed {seed}: item ts {} leaked into pane {pane_id}",
                        item.ts
                    );
                }
                surfaced.extend(pane);
                *pane_id += 1;
            }
        };
        let total = keyed.len();
        for (_, item) in &keyed {
            let sealed_below = router.next_close_id();
            let is_late = item.ts / INTERVAL < sealed_below;
            let before = router.dropped_items();
            router.push(item);
            assert_eq!(
                router.dropped_items(),
                before + u64::from(is_late),
                "seed {seed}: sealed-pane routing must drop exactly once"
            );
            drain(&mut router, &mut surfaced, &mut pane_id);
        }
        router.flush();
        drain(&mut router, &mut surfaced, &mut pane_id);
        assert_eq!(
            surfaced.len() as u64 + router.dropped_items(),
            total as u64,
            "seed {seed}: conservation"
        );
        assert!(router.dropped_items() > 0, "seed {seed}: adversarial order must drop");
        assert!(router.next_ready().is_none());
    }
}

/// Crafted trace with exactly three beyond-lateness items: the engines must
/// drop them exactly once, report them in `late_dropped` on the affected
/// window, and widen that window's bound by exactly the missing mass.
#[test]
fn beyond_lateness_drops_count_exactly_and_widen_the_bound() {
    // Panes of 1000 ms, zero skew, zero lateness: pane p seals the moment
    // an event at ts >= (p+1)*1000 arrives.
    let mut clean: Vec<Item> = Vec::new();
    for pane in 0..4u64 {
        for k in 0..10u64 {
            clean.push(Item::new((k % 3) as u16, 10.0, pane * 1_000 + k * 100));
        }
    }
    // Arrival order: pane 1 seals when ts=2000 arrives; three ts∈pane-1
    // stragglers arrive mid-pane-2, far beyond the zero lateness budget.
    let mut disordered = clean.clone();
    let at = disordered.iter().position(|i| i.ts == 2_500).unwrap();
    for (j, ts) in [1_500u64, 1_600, 1_700].iter().enumerate() {
        disordered.insert(at + 1 + j, Item::new(0, 10.0, *ts));
    }

    let svc = ComputeService::native();
    // (query, expected widening of the affected window's bound):
    // SUM charges |dropped mass| = 30; COUNT charges the 3 dropped items;
    // MEAN drops at the window mean shift nothing — inclusion-shift 0.
    for (query, extra) in [(Query::Sum, 30.0), (Query::Count, 3.0), (Query::Mean, 0.0)] {
        for engine in [EngineKind::Batched, EngineKind::Pipelined] {
            let tag = format!("{engine:?}/{query:?}");
            let run = |items: &[Item]| {
                PipelineBuilder::new()
                    .engine(engine)
                    .sampler(SamplerKind::None)
                    .budget(QueryBudget::SamplingFraction(1.0))
                    .query(query.clone())
                    .window(WindowConfig::new(2_000, 1_000))
                    .batch_interval_ms(1_000)
                    .event_time(0, 0)
                    .build_with_handle(svc.handle())
                    .run_items(items)
                    .unwrap()
            };
            let base = run(&clean);
            let late = run(&disordered);
            assert_eq!(
                base.windows.iter().map(|w| w.late_dropped).sum::<u64>(),
                0,
                "{tag}: clean trace must not drop"
            );
            assert_eq!(
                late.windows.iter().map(|w| w.late_dropped).sum::<u64>(),
                3,
                "{tag}: exactly the three beyond-lateness items drop"
            );
            assert_eq!(base.windows.len(), late.windows.len(), "{tag}");
            for (b, l) in base.windows.iter().zip(&late.windows) {
                assert_eq!(b.end_ms, l.end_ms, "{tag}");
                let (cb, cl) = (b.result.scalar.unwrap(), l.result.scalar.unwrap());
                // The dropped items never reach the sampler, so the
                // estimate itself matches the clean run bit for bit.
                assert_eq!(cb.value.to_bits(), cl.value.to_bits(), "{tag} {}", b.end_ms);
                if l.late_dropped > 0 {
                    assert_eq!(l.late_dropped, 3, "{tag}: charged once, to one window");
                    assert!(
                        (cl.bound - cb.bound - extra).abs() < 1e-9,
                        "{tag} window {}-{}: bound {} vs clean {} (want +{extra})",
                        l.start_ms,
                        l.end_ms,
                        cl.bound,
                        cb.bound
                    );
                } else {
                    assert_eq!(
                        cb.bound.to_bits(),
                        cl.bound.to_bits(),
                        "{tag} {}: unaffected window must keep its bound",
                        b.end_ms
                    );
                }
            }
            // The charge lands on the window whose span still holds pane 1
            // when the drops become known: the one ending at 3000.
            let charged: Vec<u64> = late
                .windows
                .iter()
                .filter(|w| w.late_dropped > 0)
                .map(|w| w.end_ms)
                .collect();
            assert_eq!(charged, vec![3_000], "{tag}: charge attribution");
        }
    }
}

/// Recovery preserves the `DropLedger`: with a 500 ms batch interval and a
/// 1000 ms slide, beyond-lateness drops detected at an *odd* interval
/// boundary are charged to the ledger one boundary before the affected
/// window emits.  Crashing in that gap — charge checkpointed, emission
/// still pending — must not lose or double the charge: the recovered run
/// still widens exactly one window by the same missing mass, and every
/// crash point stitches bit-identically to the clean run.
#[test]
fn recovery_between_drop_charge_and_window_emission_keeps_the_ledger() {
    // Same crafted trace as above: 1000 ms event-time panes of ten items,
    // value 10.0 each; three ts∈[1500,1700] stragglers arrive right after
    // the first ts=2000 arrival (which seals the 500 ms pane [1500,2000)),
    // so they are consumed — and dropped — while the engine reads the
    // [2000,2500) pane at boundary 5, between the window emissions at
    // boundary 4 (end 2000) and boundary 6 (end 3000).
    let mut clean_trace: Vec<Item> = Vec::new();
    for pane in 0..4u64 {
        for k in 0..10u64 {
            clean_trace.push(Item::new((k % 3) as u16, 10.0, pane * 1_000 + k * 100));
        }
    }
    let mut disordered = clean_trace.clone();
    let at = disordered.iter().position(|i| i.ts == 2_000).unwrap();
    for (j, ts) in [1_500u64, 1_600, 1_700].iter().enumerate() {
        disordered.insert(at + 1 + j, Item::new(0, 10.0, *ts));
    }

    let svc = ComputeService::native();
    let run = |durability: DurabilityOptions| {
        PipelineBuilder::new()
            .engine(EngineKind::Batched)
            .sampler(SamplerKind::None)
            .budget(QueryBudget::SamplingFraction(1.0))
            .query(Query::Sum)
            .window(WindowConfig::new(2_000, 1_000))
            .batch_interval_ms(500)
            .event_time(0, 0)
            .durability(durability)
            .build_with_handle(svc.handle())
            .run_items(&disordered)
            .unwrap()
    };
    let clean = run(DurabilityOptions::default());
    assert_eq!(
        clean.windows.iter().map(|w| w.late_dropped).sum::<u64>(),
        3,
        "the crafted stragglers must drop"
    );

    let dir_tag = std::process::id();
    for crash_after in 1..=7u64 {
        let dir = std::env::temp_dir().join(format!("sax_et_ledger_{dir_tag}_{crash_after}"));
        let _ = std::fs::remove_dir_all(&dir);
        let crashed = run(DurabilityOptions {
            checkpoint: Some(CheckpointSpec::new(&dir, 1).with_crash_after(crash_after)),
            restore_on_start: false,
        });
        let recovered =
            run(DurabilityOptions::default().checkpoint_to(&dir, 1).restore_on_start(true));
        let tag = format!("ledger crash@{crash_after}");
        let mut stitched = RunReport::default();
        stitched.windows.extend(crashed.windows.iter().cloned());
        stitched.windows.extend(recovered.windows.iter().cloned());
        assert_windows_byte_identical(&clean, &stitched, &tag);
        if crash_after == 5 {
            // The gap this test exists for: the charge predates the crash,
            // the emission follows it.
            assert!(
                crashed.windows.iter().all(|w| w.end_ms < 3_000),
                "{tag}: the charged window must not have been emitted yet"
            );
            let widened: Vec<&WindowReport> =
                recovered.windows.iter().filter(|w| w.late_dropped > 0).collect();
            assert_eq!(widened.len(), 1, "{tag}: exactly one window carries the charge");
            assert_eq!(widened[0].end_ms, 3_000, "{tag}: charge attribution");
            assert_eq!(widened[0].late_dropped, 3, "{tag}: full missing count");
            let clean_w = clean.windows.iter().find(|w| w.end_ms == 3_000).unwrap();
            assert_eq!(
                widened[0].result.scalar.unwrap().bound.to_bits(),
                clean_w.result.scalar.unwrap().bound.to_bits(),
                "{tag}: widened bound must match the clean run's"
            );
        }
    }
}
