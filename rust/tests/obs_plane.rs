//! End-to-end tests for the observability plane: histogram bucket/quantile
//! properties, snapshot-delta exactness under concurrent recorders, both
//! engines' per-run metric deltas, and Chrome-trace export validity on a
//! threaded multi-worker run.
//!
//! This binary OWNS the process-global TRACING flag: the trace test enables
//! it, and every other test here tolerates spans being recorded while it
//! runs.  The METRICS_ENABLED flag is never touched (its default, enabled,
//! is what the metric assertions rely on — toggling it would race the other
//! tests in this process).

use streamapprox::obs::hist::{bucket_bounds, bucket_index, BUCKETS};
use streamapprox::obs::{HistCore, Registry};
use streamapprox::prelude::*;
use streamapprox::stream::StreamGenerator;
use streamapprox::util::json::{parse, Value};
use streamapprox::util::rng::Rng;

// ---------------------------------------------------------------------------
// histogram properties
// ---------------------------------------------------------------------------

/// Every recorded value must land in a bucket whose bounds contain it —
/// checked on the boundary-adjacent values of every octave plus a broad
/// random sweep.
#[test]
fn bucket_bounds_contain_their_values() {
    let mut probes: Vec<u64> = vec![0, 1, 2, 15, 16, 17, u64::MAX];
    for shift in 4..63 {
        let v = 1u64 << shift;
        probes.extend([v - 1, v, v + 1]);
    }
    let mut rng = Rng::seed_from_u64(42);
    for _ in 0..10_000 {
        // Exponentially distributed magnitudes so every octave gets hits.
        let shift = rng.range_usize(0, 63) as u32;
        probes.push(rng.next_u64() >> shift);
    }
    for &v in &probes {
        let i = bucket_index(v);
        assert!(i < BUCKETS, "index {i} out of range for {v}");
        let (lo, hi) = bucket_bounds(i);
        // Half-open [lo, hi); the final bucket saturates at u64::MAX, which
        // therefore lands on its (exclusive) bound.
        assert!(
            lo <= v && (v < hi || (v == u64::MAX && i == BUCKETS - 1)),
            "bucket {i} [{lo}, {hi}) does not contain {v}"
        );
    }
}

/// Bucket bounds tile the u64 range in order: each bucket starts where the
/// previous one ends, with no gaps or overlaps, saturating at `u64::MAX`.
#[test]
fn bucket_bounds_tile_without_gaps() {
    let mut expected_lo = 0u64;
    for i in 0..BUCKETS {
        let (lo, hi) = bucket_bounds(i);
        assert_eq!(lo, expected_lo, "bucket {i} leaves a gap");
        assert!(hi > lo, "bucket {i} is empty or inverted");
        expected_lo = hi;
    }
    assert_eq!(expected_lo, u64::MAX, "buckets must saturate the u64 domain");
}

/// Quantiles are monotone in q, never exceed the observed max, and q=1
/// answers from the bucket holding the max.
#[test]
fn quantiles_are_monotone_and_bounded() {
    let h = HistCore::new();
    let mut rng = Rng::seed_from_u64(7);
    let mut max_v = 0u64;
    for _ in 0..50_000 {
        // Log-uniform-ish spread across six orders of magnitude.
        let v = rng.next_u64() >> rng.range_usize(20, 60);
        max_v = max_v.max(v);
        h.record(v);
    }
    let s = h.snapshot();
    assert_eq!(s.count, 50_000);
    let qs = [0.0, 0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 0.999, 1.0];
    let mut prev = 0u64;
    for &q in &qs {
        let v = s.quantile(q);
        assert!(v >= prev, "quantile({q}) = {v} < quantile at previous q = {prev}");
        assert!(v <= max_v, "quantile({q}) = {v} exceeds recorded max {max_v}");
        prev = v;
    }
    assert_eq!(s.max, max_v);
    // q=1 answers from the bucket holding the max (midpoint, clamped to
    // max): never above it, never below its bucket's lower bound.
    let (max_lo, _) = bucket_bounds(bucket_index(max_v));
    assert!(
        s.quantile(1.0) >= max_lo,
        "quantile(1) = {} below the max bucket [{}..]",
        s.quantile(1.0),
        max_lo
    );
}

/// The log-linear layout guarantees a bounded relative quantile error: a
/// reported quantile of a constant stream is within one sub-bucket (6.25%)
/// of the true value.
#[test]
fn quantile_relative_error_is_bounded() {
    for &v in &[100u64, 1_000, 50_000, 1_000_000, 123_456_789] {
        let h = HistCore::new();
        for _ in 0..1_000 {
            h.record(v);
        }
        let s = h.snapshot();
        for q in [0.5, 0.95, 0.99] {
            let got = s.quantile(q) as f64;
            let rel = (got - v as f64).abs() / v as f64;
            assert!(rel <= 0.0625 + 1e-9, "quantile({q}) of constant {v} off by {rel}");
        }
    }
}

// ---------------------------------------------------------------------------
// snapshot deltas under concurrency
// ---------------------------------------------------------------------------

/// Counters and histogram counts in a snapshot delta are exact even with
/// many threads recording concurrently — an isolated registry instance so
/// parallel tests in this process cannot perturb the counts.
#[test]
fn snapshot_delta_exact_under_concurrent_recorders() {
    static REG: Registry = Registry::new();
    const THREADS: usize = 8;
    const PER_THREAD: u64 = 10_000;

    let start = REG.snapshot();
    std::thread::scope(|s| {
        for t in 0..THREADS {
            let c = REG.counter("events_total", "test counter");
            let h = REG.histogram("work_ns", "test histogram");
            s.spawn(move || {
                for i in 0..PER_THREAD {
                    c.inc();
                    h.record((t as u64 + 1) * 100 + i % 7);
                }
            });
        }
    });
    let delta = REG.snapshot().delta(&start);
    assert_eq!(delta.counter("events_total"), THREADS as u64 * PER_THREAD);
    let h = delta.hist("work_ns").expect("histogram registered");
    assert_eq!(h.count, THREADS as u64 * PER_THREAD);
    assert!(h.sum > 0 && h.max >= 800);
}

// ---------------------------------------------------------------------------
// per-run metric deltas from both engines
// ---------------------------------------------------------------------------

fn run_engine(engine: EngineKind, query: Query) -> RunReport {
    let items =
        StreamGenerator::new(&StreamConfig::gaussian_micro(400.0, 31)).take_until(12_000);
    PipelineBuilder::new()
        .engine(engine)
        .sampler(SamplerKind::Oasrs)
        .budget(QueryBudget::SamplingFraction(0.5))
        .query(query)
        .window(WindowConfig::new(4_000, 2_000))
        .workers(2)
        .build_native()
        .run_items(&items)
        .expect("pipeline run")
}

/// Acceptance criterion: both engines embed a `MetricsSnapshot` delta in
/// their `RunReport` with nonzero ingest, window-merge, and query-stage
/// series.
#[test]
fn both_engines_report_nonzero_stage_metrics() {
    for engine in [EngineKind::Batched, EngineKind::Pipelined] {
        let r = run_engine(engine, Query::Sum);
        let m = r.metrics.as_ref().unwrap_or_else(|| panic!("{engine:?}: no metrics delta"));
        assert!(
            m.counter("ingest_items_total") > 0,
            "{engine:?}: ingest_items_total is zero"
        );
        let merges = m.hist("window_merge_ns").map_or(0, |h| h.count);
        assert!(merges > 0, "{engine:?}: window_merge_ns recorded nothing");
        let queries = m.hist("query_execute_ns").map_or(0, |h| h.count);
        assert!(queries > 0, "{engine:?}: query_execute_ns recorded nothing");
        let closes = m.hist("interval_close_ns").map_or(0, |h| h.count);
        assert!(closes > 0, "{engine:?}: interval_close_ns recorded nothing");
        // The delta attributes THIS run: the in-process batched engine
        // ingests every offered item, so its delta must cover them all
        // (parallel tests may add counts, never remove them).  The threaded
        // pipelined transport may legitimately shed load, so only > 0 is
        // asserted there.
        if engine == EngineKind::Batched {
            assert!(
                m.counter("ingest_items_total") >= r.items_processed,
                "{engine:?}: delta {} < items processed {}",
                m.counter("ingest_items_total"),
                r.items_processed
            );
        }
    }
}

/// Sketch queries and the build-count series: the per-window rebuild path
/// ticks `query_sketch_builds_total`, while the default streaming-ingest
/// path performs zero query-time builds — the counter is the witness for
/// both directions.
#[test]
fn sketch_query_build_counter_tracks_the_rebuild_path() {
    let items =
        StreamGenerator::new(&StreamConfig::gaussian_micro(400.0, 31)).take_until(12_000);
    let run = |panes: bool| {
        PipelineBuilder::new()
            .engine(EngineKind::Batched)
            .sampler(SamplerKind::Oasrs)
            .budget(QueryBudget::SamplingFraction(0.5))
            .query(Query::Distinct)
            .window(WindowConfig::new(4_000, 2_000))
            .workers(2)
            .sketch_pane_windows(panes)
            .build_native()
            .run_items(&items)
            .expect("pipeline run")
    };
    let rebuilt = run(false);
    let m = rebuilt.metrics.as_ref().expect("metrics delta");
    assert!(
        m.counter("query_sketch_builds_total") > 0,
        "rebuild path produced no query-time sketch builds"
    );
    assert!(m.hist("query_execute_ns").map_or(0, |h| h.count) > 0);
    // Streaming ingest: panes arrive pre-built, so this run's delta adds
    // nothing to the build counter (no other test in this binary runs the
    // rebuild path concurrently).
    let streamed = run(true);
    let m = streamed.metrics.as_ref().expect("metrics delta");
    assert_eq!(
        m.counter("query_sketch_builds_total"),
        0,
        "streaming-ingest sketch query built sketches at query time"
    );
}

/// Event-time series under disorder: a pipelined run whose injected delays
/// exceed the lateness budget must tick `late_items_dropped_total` exactly
/// once per beyond-lateness item, tick `window_pane_reopens_total` for the
/// within-budget reorders, and leave the watermark-lag gauge at a level.
/// (No other test in this binary runs the event-time path, so the run's
/// delta attributes these counters exactly.)
#[test]
fn disordered_run_ticks_event_time_metrics() {
    use streamapprox::stream::DisorderConfig;
    let items =
        StreamGenerator::new(&StreamConfig::gaussian_micro(400.0, 37)).take_until(12_000);
    // Lossless budget 150 ms; uniform skew 200 ms plus 2 s stragglers, so
    // most items reorder within open panes and a seeded 2% land far past
    // the lateness horizon — guaranteed reopens AND guaranteed drops.
    let items =
        DisorderConfig::bounded_skew(200, 3).with_stragglers(0.02, 2_000).apply(&items);
    let r = PipelineBuilder::new()
        .engine(EngineKind::Pipelined)
        .sampler(SamplerKind::Oasrs)
        .budget(QueryBudget::SamplingFraction(0.5))
        .query(Query::Sum)
        .window(WindowConfig::new(4_000, 2_000))
        .workers(2)
        .event_time(100, 50)
        .build_native()
        .run_items(&items)
        .expect("pipeline run");
    let m = r.metrics.as_ref().expect("metrics delta");
    // The engine only ingests pane-surfaced items, so the router's drop
    // count is the feed/processed difference — the counter must match it.
    let dropped = items.len() as u64 - r.items_processed;
    assert!(dropped > 0, "2s stragglers past a 150ms budget must drop items");
    assert_eq!(
        m.counter("late_items_dropped_total"),
        dropped,
        "drop counter must tick exactly once per beyond-lateness item"
    );
    assert!(
        m.counter("late_items_dropped_total")
            >= r.windows.iter().map(|w| w.late_dropped).sum::<u64>(),
        "window reports cannot charge more drops than were counted"
    );
    assert!(
        m.counter("window_pane_reopens_total") > 0,
        "bounded skew must route some arrivals back into open lower panes"
    );
    let lag = m.gauge("event_time_watermark_lag_ms").expect("lag gauge never set");
    assert!(lag >= 0.0, "watermark lag {lag} negative");
}

/// The Prometheus rendering of a real run's delta carries the headline
/// families — the same surface CI's golden name-set check scrapes.
#[test]
fn run_delta_renders_prometheus_families() {
    let r = run_engine(EngineKind::Pipelined, Query::Sum);
    let text = r.metrics.as_ref().expect("metrics delta").to_prometheus();
    for family in [
        "# TYPE ingest_items_total counter",
        "# TYPE window_merge_ns summary",
        "# TYPE query_execute_ns summary",
        "# TYPE interval_close_ns summary",
    ] {
        assert!(text.contains(family), "missing {family:?} in:\n{text}");
    }
}

// ---------------------------------------------------------------------------
// span tracing
// ---------------------------------------------------------------------------

/// Chrome-trace export from a threaded 2-worker run: the document must
/// parse as JSON, contain complete (`ph:"X"`) events from the pipeline
/// stages, and every thread's spans must be well-nested (RAII drop order
/// guarantees any two same-thread spans are nested or disjoint).
#[test]
fn chrome_trace_is_valid_json_with_well_nested_spans() {
    streamapprox::obs::trace::set_tracing_enabled(true);
    let r = run_engine(EngineKind::Pipelined, Query::Sum);
    assert!(!r.windows.is_empty());

    let doc = streamapprox::obs::trace::chrome_trace().to_string();
    let parsed = parse(&doc).expect("chrome trace must be valid JSON");
    let events = parsed
        .get("traceEvents")
        .and_then(Value::as_arr)
        .expect("traceEvents array");

    // (tid, start_us, end_us, name) for complete events.
    let mut spans: Vec<(i64, f64, f64, String)> = Vec::new();
    let mut metadata = 0;
    for e in events {
        match e.get("ph").and_then(Value::as_str) {
            Some("M") => {
                assert_eq!(e.get("name").and_then(Value::as_str), Some("thread_name"));
                metadata += 1;
            }
            Some("X") => {
                let tid = e.get("tid").and_then(Value::as_i64).expect("tid");
                let ts = e.get("ts").and_then(Value::as_f64).expect("ts");
                let dur = e.get("dur").and_then(Value::as_f64).expect("dur");
                assert!(dur >= 0.0, "negative span duration {dur}");
                let name = e.get("name").and_then(Value::as_str).expect("name").to_string();
                spans.push((tid, ts, ts + dur, name));
            }
            ph => panic!("unexpected event phase {ph:?}"),
        }
    }
    assert!(metadata >= 2, "expected thread_name metadata for >= 2 threads");
    assert!(!spans.is_empty(), "no spans recorded from a traced run");
    let names: Vec<&str> = spans.iter().map(|s| s.3.as_str()).collect();
    assert!(names.contains(&"interval_close"), "missing interval_close spans: {names:?}");
    assert!(names.contains(&"window_emit"), "missing window_emit spans: {names:?}");

    // Well-nesting: any two spans on one thread are nested or disjoint.
    // Sweep each thread's spans by (start asc, end desc) with an open-span
    // stack — a span that starts inside an open ancestor must also end
    // inside it.  EPS absorbs the sub-ns float slack of the µs conversion.
    const EPS: f64 = 0.002;
    spans.sort_by(|a, b| {
        (a.0, a.1, -a.2).partial_cmp(&(b.0, b.1, -b.2)).unwrap()
    });
    let mut open: Vec<(i64, f64, f64, String)> = Vec::new(); // per-tid stack
    for s in &spans {
        // Entering a new thread's run resets the stack; otherwise close
        // every open span that ended before this one starts.
        while open.last().is_some_and(|t| t.0 != s.0 || t.2 <= s.1 + EPS) {
            open.pop();
        }
        if let Some(t) = open.last() {
            assert!(
                s.2 <= t.2 + EPS,
                "tid {}: span {:?} [{};{}] partially overlaps {:?} [{};{}]",
                s.0, s.3, s.1, s.2, t.3, t.1, t.2
            );
        }
        open.push(s.clone());
    }
}
