//! Integration tests over the whole coordinator: broker → engines →
//! samplers → windows → query → error bounds, on both compute backends.
//! These encode the paper's qualitative claims as assertions.

use streamapprox::datasets::{CaidaConfig, TaxiConfig};
use streamapprox::prelude::*;
use streamapprox::runtime::default_artifacts_dir;
use streamapprox::stream::{Broker, ReplayTool, StreamGenerator, TopicConfig};

fn xla_available() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

fn shared_service() -> ComputeService {
    if xla_available() {
        ComputeService::start(Backend::Xla, None).expect("xla")
    } else {
        ComputeService::native()
    }
}

fn build(
    svc: &ComputeService,
    engine: EngineKind,
    sampler: SamplerKind,
    fraction: f64,
) -> Pipeline {
    PipelineBuilder::new()
        .engine(engine)
        .sampler(sampler)
        .budget(QueryBudget::SamplingFraction(fraction))
        .query(Query::Sum)
        .window(WindowConfig::new(4_000, 2_000))
        .workers(2)
        .build_with_handle(svc.handle())
}

#[test]
fn all_system_combinations_run_and_bound_truth() {
    let svc = shared_service();
    let items = StreamGenerator::new(&StreamConfig::gaussian_micro(500.0, 21)).take_until(16_000);
    for engine in [EngineKind::Batched, EngineKind::Pipelined] {
        for sampler in
            [SamplerKind::Oasrs, SamplerKind::Srs, SamplerKind::Sts, SamplerKind::None]
        {
            let p = build(&svc, engine, sampler, 0.5);
            let r = p.run_items(&items).unwrap();
            assert!(
                r.windows.len() >= 6,
                "{engine:?}/{sampler:?}: only {} windows",
                r.windows.len()
            );
            assert_eq!(r.items_processed as usize, items.len());
            // 95% CI should usually contain the exact value — except for
            // SRS, whose global uniform weighting leaves the per-stratum
            // allocation randomness unmodelled: its bounds are unreliable
            // by construction (the paper's core argument for stratified
            // sampling). We assert that *as a property* instead.
            if sampler == SamplerKind::Srs {
                continue;
            }
            let mut covered = 0;
            let mut total = 0;
            for w in &r.windows {
                if let (Some(ci), Some(exact)) = (w.result.scalar, w.exact_scalar) {
                    total += 1;
                    // widen to 3 sigma for the small-sample strata
                    let wide = 1.5 * ci.bound;
                    if (ci.value - exact).abs() <= wide.max(exact.abs() * 1e-6) {
                        covered += 1;
                    }
                }
            }
            assert!(
                covered as f64 >= 0.7 * total as f64,
                "{engine:?}/{sampler:?}: CI covered {covered}/{total}"
            );
        }
    }
}

#[test]
fn oasrs_beats_srs_on_skewed_accuracy() {
    // The paper's central accuracy claim (Figs. 5b, 7c, 8): with a rare,
    // high-valued sub-stream, OASRS (stratified) beats SRS (uniform).
    let svc = shared_service();
    let items =
        StreamGenerator::new(&StreamConfig::gaussian_skew(8_000.0, 22)).take_until(24_000);
    let loss = |sampler| {
        let p = build(&svc, EngineKind::Batched, sampler, 0.1);
        p.run_items(&items).unwrap().mean_accuracy_loss()
    };
    let oasrs = loss(SamplerKind::Oasrs);
    let srs = loss(SamplerKind::Srs);
    assert!(
        oasrs < srs,
        "OASRS loss {oasrs} should beat SRS loss {srs} at 10% on skew"
    );
}

#[test]
fn sampled_systems_outrun_native() {
    // The paper's central throughput claim (Fig. 5a): sampling beats native
    // execution at moderate fractions.
    let svc = shared_service();
    let items = CaidaConfig { flows_per_sec: 30_000.0, ..Default::default() }.generate(20_000);
    let thr = |sampler, fraction| {
        let p = PipelineBuilder::new()
            .engine(EngineKind::Pipelined)
            .sampler(sampler)
            .budget(QueryBudget::SamplingFraction(fraction))
            .query(Query::PerStratumSum)
            .window(WindowConfig::new(4_000, 2_000))
            .workers(2)
            .track_exact(false)
            .build_with_handle(svc.handle());
        // best of 2 runs to damp scheduler noise
        (0..2)
            .map(|_| p.run_items(&items).unwrap().throughput())
            .fold(0.0f64, f64::max)
    };
    let native = thr(SamplerKind::None, 1.0);
    let approx10 = thr(SamplerKind::Oasrs, 0.1);
    assert!(
        approx10 > native,
        "10% OASRS ({approx10:.0}/s) must outrun native ({native:.0}/s)"
    );
}

#[test]
fn broker_to_pipeline_composition() {
    let svc = shared_service();
    let trace = TaxiConfig { rides_per_sec: 5_000.0, ..Default::default() }.generate(12_000);
    let broker = Broker::new();
    broker
        .create_topic("rides", TopicConfig { partitions: 2, capacity: 8192 })
        .unwrap();
    let replay = ReplayTool::new(trace.clone());
    let mut consumer = broker.consumer("rides").unwrap();
    let mut received = Vec::new();
    std::thread::scope(|s| {
        s.spawn(|| replay.replay_all(&broker, "rides").unwrap());
        while let Some(it) = consumer.poll() {
            received.push(it);
        }
    });
    assert_eq!(received.len(), trace.len());
    received.sort_by_key(|i| i.ts);
    let p = PipelineBuilder::new()
        .sampler(SamplerKind::Oasrs)
        .query(Query::PerStratumMean)
        .window(WindowConfig::new(4_000, 2_000))
        .build_with_handle(svc.handle());
    let r = p.run_items(&received).unwrap();
    assert!(!r.windows.is_empty());
    assert!(r.mean_accuracy_loss() < 0.1);
}

#[test]
fn adaptive_budget_tightens_error() {
    let svc = shared_service();
    let items = StreamGenerator::new(&StreamConfig::gaussian_micro(500.0, 23)).take_until(30_000);
    let run = |budget| {
        let p = PipelineBuilder::new()
            .engine(EngineKind::Batched)
            .sampler(SamplerKind::Oasrs)
            .budget(budget)
            .query(Query::Sum)
            .window(WindowConfig::new(2_000, 1_000))
            .build_with_handle(svc.handle());
        p.run_items(&items).unwrap()
    };
    let loose = run(QueryBudget::SamplingFraction(0.02));
    let adaptive = run(QueryBudget::TargetRelativeError { target: 0.0005, initial_fraction: 0.02 });
    // The adaptive run must end up sampling more than the loose fixed run.
    let loose_last = &loose.windows[loose.windows.len() - 1];
    let adaptive_last = &adaptive.windows[adaptive.windows.len() - 1];
    assert!(
        adaptive_last.sampled > loose_last.sampled,
        "adaptive {} should exceed fixed {}",
        adaptive_last.sampled,
        loose_last.sampled
    );
}

#[test]
fn per_stratum_queries_track_truth() {
    let svc = shared_service();
    let items = CaidaConfig::default().generate(16_000);
    let p = PipelineBuilder::new()
        .engine(EngineKind::Pipelined)
        .sampler(SamplerKind::Oasrs)
        .budget(QueryBudget::SamplingFraction(0.6))
        .query(Query::PerStratumSum)
        .window(WindowConfig::new(4_000, 2_000))
        .workers(2)
        .build_with_handle(svc.handle());
    let r = p.run_items(&items).unwrap();
    // skip the first (warm-up) window; strata estimates within 10%
    for w in r.windows.iter().skip(2) {
        let approx = w.result.per_stratum.as_ref().unwrap();
        let exact = w.exact_per_stratum.as_ref().unwrap();
        for s in 0..3 {
            if exact[s] > 0.0 {
                let rel = (approx[s] - exact[s]).abs() / exact[s];
                assert!(rel < 0.1, "window {} stratum {s}: rel {rel}", w.end_ms);
            }
        }
    }
}

#[test]
fn window_arithmetic_spans_slides() {
    let svc = shared_service();
    let items = StreamGenerator::new(&StreamConfig::gaussian_micro(100.0, 24)).take_until(20_000);
    let p = PipelineBuilder::new()
        .engine(EngineKind::Pipelined)
        .sampler(SamplerKind::None)
        .budget(QueryBudget::SamplingFraction(1.0))
        .query(Query::Count)
        .window(WindowConfig::new(10_000, 5_000))
        .build_with_handle(svc.handle());
    let r = p.run_items(&items).unwrap();
    // Window t in [10s..] covers two slides; counts must equal the exact
    // item count of that span.
    for w in &r.windows {
        let span_count = items
            .iter()
            .filter(|i| i.ts >= w.start_ms && i.ts < w.end_ms)
            .count() as f64;
        assert_eq!(w.result.value(), span_count, "window {}-{}", w.start_ms, w.end_ms);
    }
}

#[test]
fn deterministic_runs_same_seed() {
    let svc = shared_service();
    let items = StreamGenerator::new(&StreamConfig::gaussian_micro(200.0, 25)).take_until(8_000);
    let run = || {
        let p = PipelineBuilder::new()
            .engine(EngineKind::Batched)
            .sampler(SamplerKind::Oasrs)
            .budget(QueryBudget::SamplingFraction(0.3))
            .window(WindowConfig::new(2_000, 1_000))
            .seed(77)
            .build_with_handle(svc.handle());
        p.run_items(&items).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.windows.len(), b.windows.len());
    for (x, y) in a.windows.iter().zip(&b.windows) {
        assert_eq!(x.sampled, y.sampled);
        assert!((x.result.value() - y.result.value()).abs() < 1e-9);
    }
}
