//! SnapshotCodec property suite: serialize → restore → continue offering
//! is bit-identical to never having snapshotted, for every Mergeable
//! payload and sampler kind, across seeds and chunk sizes — including
//! mid-dense-phase and mid-skip Algorithm-L reservoir states and
//! mid-interval buffered batch state.  Plus the negative paths: trailing
//! bytes, truncation, bad magic, version mismatch, and checksum damage
//! all reject with descriptive `Error::Io`/`Error::Config`.

use streamapprox::core::Error;
use streamapprox::engine::IngestPool;
use streamapprox::error::estimator::LateDrops;
use streamapprox::prelude::*;
use streamapprox::runtime::checkpoint::{decode_frame, encode_frame};
use streamapprox::runtime::Snapshot;
use streamapprox::sampling::{Reservoir, SampleResult, WeightedReservoir};
use streamapprox::stream::StreamGenerator;
use streamapprox::util::rng::Rng;
use streamapprox::window::DropLedger;

/// Round-trip through the codec and pin the canonical form: decoding and
/// re-encoding must reproduce the exact bytes.
fn roundtrip<T: Snapshot>(x: &T, tag: &str) -> T {
    let bytes = x.to_snapshot_bytes();
    let decoded = T::from_snapshot_bytes(&bytes).unwrap_or_else(|e| panic!("{tag}: {e}"));
    assert_eq!(decoded.to_snapshot_bytes(), bytes, "{tag}: re-encode differs");
    decoded
}

fn trace(rate: f64, seed: u64, dur_ms: u64) -> Vec<Item> {
    let mut items =
        StreamGenerator::new(&StreamConfig::gaussian_micro(rate, seed)).take_until(dur_ms);
    items.sort_by_key(|i| i.ts);
    items
}

// ---------------------------------------------------------------------------
// RNG and reservoir states
// ---------------------------------------------------------------------------

/// The RNG stream continues bit-identically through a snapshot.
#[test]
fn rng_stream_continues_through_snapshot() {
    for seed in [0u64, 1, 0xDEAD_BEEF, u64::MAX] {
        let mut a = Rng::seed_from_u64(seed);
        for _ in 0..100 {
            a.next_u64();
        }
        let mut b = roundtrip(&a, &format!("rng seed {seed}"));
        for i in 0..1_000 {
            assert_eq!(a.next_u64(), b.next_u64(), "seed {seed}: draw {i} diverged");
        }
    }
}

/// Algorithm-L reservoirs snapshot mid-dense-phase (still filling) and
/// mid-skip (geometric skip counter engaged) and continue bit-identically:
/// same surviving items, same seen count, same skip state, same future
/// acceptances.
#[test]
fn reservoir_roundtrip_continues_from_dense_and_skip_phases() {
    let mut dense_covered = false;
    let mut skip_covered = false;
    for cap in [8usize, 64] {
        for seed in 0..4u64 {
            for prefix in [0usize, 3, cap - 1, cap, cap + 1, 20 * cap] {
                let tag = format!("cap {cap} seed {seed} prefix {prefix}");
                let mut a = Reservoir::<(u16, f64)>::new(cap, seed);
                for i in 0..prefix {
                    a.offer(((i % 5) as u16, i as f64 * 0.618 + 1.0));
                }
                dense_covered |= !a.skip_engaged() && a.len() < cap;
                skip_covered |= a.skip_engaged();
                let mut b = roundtrip(&a, &tag);
                assert_eq!(a.seen(), b.seen(), "{tag}: seen");
                assert_eq!(a.skip_engaged(), b.skip_engaged(), "{tag}: skip phase");
                for i in prefix..prefix + 500 {
                    let item = ((i % 5) as u16, i as f64 * 0.618 + 1.0);
                    a.offer(item);
                    b.offer(item);
                }
                let bits = |r: &Reservoir<(u16, f64)>| -> Vec<(u16, u64)> {
                    r.items().iter().map(|&(s, v)| (s, v.to_bits())).collect()
                };
                assert_eq!(bits(&a), bits(&b), "{tag}: reservoirs diverged after restore");
                assert_eq!(
                    a.to_snapshot_bytes(),
                    b.to_snapshot_bytes(),
                    "{tag}: full state diverged after restore"
                );
            }
        }
    }
    assert!(dense_covered, "matrix never hit a mid-dense-phase state");
    assert!(skip_covered, "matrix never hit a mid-skip state");
}

/// A-ExpJ weighted reservoirs keep their key heap and jump state across a
/// snapshot: the restored sampler makes the same future selections.
#[test]
fn weighted_reservoir_roundtrip_continues_bit_identical() {
    for seed in 0..4u64 {
        for prefix in [0usize, 5, 16, 400] {
            let tag = format!("weighted seed {seed} prefix {prefix}");
            let mut a = WeightedReservoir::<(u16, f64)>::new(16, seed);
            for i in 0..prefix {
                a.offer(((i % 3) as u16, i as f64), (i % 9 + 1) as f64);
            }
            let mut b = roundtrip(&a, &tag);
            for i in prefix..prefix + 300 {
                let item = ((i % 3) as u16, i as f64);
                let w = (i % 9 + 1) as f64;
                a.offer(item, w);
                b.offer(item, w);
            }
            assert_eq!(
                a.to_snapshot_bytes(),
                b.to_snapshot_bytes(),
                "{tag}: diverged after restore"
            );
            assert_eq!(a.seen(), b.seen(), "{tag}: seen");
            assert_eq!(
                a.weight_seen().to_bits(),
                b.weight_seen().to_bits(),
                "{tag}: weight seen"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// sketches and window payloads
// ---------------------------------------------------------------------------

/// Sketch partials (quantile clusters, HLL registers, Count-Min counters +
/// heavy-hitter entries) round-trip and keep answering identically while
/// more data streams in.
#[test]
fn sketch_partials_roundtrip_and_continue() {
    for seed in [3u64, 11] {
        let mut rng = Rng::seed_from_u64(seed);
        let feed: Vec<f64> = (0..2_000).map(|_| rng.range_usize(0, 5_000) as f64).collect();
        let (head, tail) = feed.split_at(700);

        let mut q = QuantileSketch::new(64);
        let mut h = HyperLogLog::new(12);
        let mut hh = HeavyHitters::new(8, 128, 4, seed);
        for &v in head {
            q.offer(v, 1.0);
            h.offer(v);
            hh.offer(v as u64 % 37, v);
        }
        let mut q2 = roundtrip(&q, "quantile");
        let mut h2 = roundtrip(&h, "hll");
        let mut hh2 = roundtrip(&hh, "heavy-hitters");
        for &v in tail {
            q.offer(v, 1.0);
            q2.offer(v, 1.0);
            h.offer(v);
            h2.offer(v);
            hh.offer(v as u64 % 37, v);
            hh2.offer(v as u64 % 37, v);
        }
        for p in [0.1, 0.5, 0.99] {
            assert_eq!(
                q.quantile(p).to_bits(),
                q2.quantile(p).to_bits(),
                "seed {seed}: q{p} diverged"
            );
        }
        assert_eq!(
            h.estimate().to_bits(),
            h2.estimate().to_bits(),
            "seed {seed}: distinct estimate diverged"
        );
        assert_eq!(h.registers(), h2.registers(), "seed {seed}: HLL registers diverged");
        let top = |s: &HeavyHitters| -> Vec<(u64, u64)> {
            s.top_k(4).into_iter().map(|(k, w)| (k, w.to_bits())).collect()
        };
        assert_eq!(top(&hh), top(&hh2), "seed {seed}: top-k diverged");
        assert_eq!(q.to_snapshot_bytes(), q2.to_snapshot_bytes(), "seed {seed}: quantile");
        assert_eq!(hh.to_snapshot_bytes(), hh2.to_snapshot_bytes(), "seed {seed}: hh");
    }
}

/// `PaneStore` contents (ring of Mergeable pane partials) and the
/// `DropLedger` round-trip exactly, including aggregate answers.
#[test]
fn pane_store_and_drop_ledger_roundtrip() {
    // Panes of real sampler output: one finished interval each.
    let items = trace(300.0, 17, 2_000);
    let mut store = PaneStore::<SampleResult>::new(4);
    let mut pool = IngestPool::new(SamplerKind::Oasrs, 1, 0.5, 23);
    for chunk in items.chunks(200) {
        pool.offer_slice(chunk);
        store.push(pool.finish_interval());
    }
    let restored = roundtrip(&store, "pane store");
    assert_eq!(store.len(), restored.len(), "pane count");
    assert_eq!(store.merge_ops(), restored.merge_ops(), "merge telemetry");
    match (store.aggregate(), restored.aggregate()) {
        (Some(a), Some(b)) => {
            assert_eq!(a.to_snapshot_bytes(), b.to_snapshot_bytes(), "window aggregate")
        }
        (None, None) => {}
        _ => panic!("aggregate presence diverged"),
    }

    let mut ledger = DropLedger::new(500);
    ledger.absorb(vec![
        (2, LateDrops { count: 3.0, mass: 30.5 }),
        (5, LateDrops { count: 1.0, mass: 7.25 }),
    ]);
    let restored = roundtrip(&ledger, "drop ledger");
    for (lo, hi) in [(0u64, 2_000u64), (1_000, 3_000), (2_500, 3_000)] {
        let a = ledger.span(lo, hi);
        let b = restored.span(lo, hi);
        assert_eq!(a.count.to_bits(), b.count.to_bits(), "span {lo}-{hi}: count");
        assert_eq!(a.mass.to_bits(), b.mass.to_bits(), "span {lo}-{hi}: mass");
    }
}

// ---------------------------------------------------------------------------
// the full pool, every sampler kind
// ---------------------------------------------------------------------------

/// The pool-level contract the engines rely on: snapshot the workers
/// (mid-interval or at a boundary), restore a second pool from the blobs,
/// feed both the identical suffix, and the merged interval results are
/// bit-identical — every sampler kind, single- and multi-worker, across
/// offer chunk sizes.
#[test]
fn ingest_pool_restores_bit_identically_for_every_sampler_kind() {
    let items = trace(400.0, 29, 2_000);
    let (head, tail) = items.split_at(items.len() / 2);
    for kind in [
        SamplerKind::Oasrs,
        SamplerKind::Srs,
        SamplerKind::Sts,
        SamplerKind::WeightedRes,
        SamplerKind::None,
    ] {
        for workers in [1usize, 3] {
            for chunk in [7usize, 64] {
                for boundary_snapshot in [false, true] {
                    let tag = format!(
                        "{kind:?}/{workers}w/chunk{chunk}/{}",
                        if boundary_snapshot { "boundary" } else { "mid-interval" }
                    );
                    let mut a = IngestPool::new(kind, workers, 0.4, 31);
                    for c in head.chunks(chunk) {
                        a.offer_slice(c);
                    }
                    if boundary_snapshot {
                        // Engine discipline: snapshot after the interval
                        // close, with empty batch buffers.
                        let _ = a.finish_interval();
                    }
                    let blobs = a.snapshot_workers();
                    assert_eq!(blobs.len(), workers, "{tag}: one blob per worker");
                    let cursor = a.transport_cursor();
                    let mut b = IngestPool::restore(kind, workers, 0.4, &blobs, cursor)
                        .unwrap_or_else(|e| panic!("{tag}: restore failed: {e}"));
                    for c in tail.chunks(chunk) {
                        a.offer_slice(c);
                        b.offer_slice(c);
                    }
                    let ra = a.finish_interval();
                    let rb = b.finish_interval();
                    assert_eq!(
                        ra.to_snapshot_bytes(),
                        rb.to_snapshot_bytes(),
                        "{tag}: merged interval results diverged after restore"
                    );
                }
            }
        }
    }
}

/// Restore validates its inputs: a blob count that does not match the
/// worker count and a blob from a different sampler kind both reject.
#[test]
fn pool_restore_rejects_mismatched_blobs() {
    let items = trace(200.0, 37, 1_000);
    let mut pool = IngestPool::new(SamplerKind::Srs, 2, 0.4, 41);
    pool.offer_slice(&items);
    let _ = pool.finish_interval();
    let blobs = pool.snapshot_workers();
    let cursor = pool.transport_cursor();

    let err = IngestPool::restore(SamplerKind::Srs, 3, 0.4, &blobs, cursor).unwrap_err();
    assert!(
        err.to_string().contains("worker blobs"),
        "worker-count mismatch must say how many blobs, got: {err}"
    );
    let err = IngestPool::restore(SamplerKind::Oasrs, 2, 0.4, &blobs, cursor).unwrap_err();
    assert!(
        err.to_string().contains("sampler"),
        "kind mismatch must name the sampler, got: {err}"
    );
}

// ---------------------------------------------------------------------------
// negative paths: trailing bytes, truncation, frame damage
// ---------------------------------------------------------------------------

/// A payload with trailing garbage or missing bytes is rejected with a
/// descriptive `Error::Io` — never silently accepted.
#[test]
fn truncated_and_padded_payloads_are_rejected() {
    let mut rng = Rng::seed_from_u64(47);
    rng.next_u64();
    let bytes = rng.to_snapshot_bytes();

    let mut padded = bytes.clone();
    padded.push(0);
    let err = Rng::from_snapshot_bytes(&padded).unwrap_err();
    assert!(matches!(err, Error::Io(_)), "want Io, got: {err}");
    assert!(err.to_string().contains("trailing"), "got: {err}");

    let err = Rng::from_snapshot_bytes(&bytes[..bytes.len() - 1]).unwrap_err();
    assert!(matches!(err, Error::Io(_)), "want Io, got: {err}");
    assert!(err.to_string().contains("truncated"), "got: {err}");
}

/// Frame-level damage taxonomy: short frames and checksum damage are
/// `Error::Io` (torn writes); foreign magic and future versions are
/// `Error::Config` (wrong file / wrong build) — each with a message that
/// says what happened.
#[test]
fn frame_damage_is_rejected_with_descriptive_errors() {
    let frame = encode_frame(b"mergeable payload");
    assert_eq!(decode_frame(&frame).unwrap(), b"mergeable payload");

    let err = decode_frame(&frame[..5]).unwrap_err();
    assert!(matches!(err, Error::Io(_)), "short frame: want Io, got {err}");
    assert!(err.to_string().contains("truncated"), "got: {err}");

    let mut torn = frame.clone();
    let last = torn.len() - 1;
    torn[last] ^= 0x01;
    let err = decode_frame(&torn).unwrap_err();
    assert!(matches!(err, Error::Io(_)), "checksum: want Io, got {err}");
    assert!(err.to_string().contains("checksum mismatch"), "got: {err}");

    let mut flipped = frame.clone();
    flipped[10] ^= 0x80; // payload bit-flip → checksum catches it
    let err = decode_frame(&flipped).unwrap_err();
    assert!(err.to_string().contains("checksum mismatch"), "got: {err}");

    let mut foreign = frame.clone();
    foreign[0] = b'Z';
    let err = decode_frame(&foreign).unwrap_err();
    assert!(matches!(err, Error::Config(_)), "magic: want Config, got {err}");
    assert!(err.to_string().contains("magic"), "got: {err}");

    let mut future = frame;
    future[4] = 0xFF;
    future[5] = 0x7F;
    let err = decode_frame(&future).unwrap_err();
    assert!(matches!(err, Error::Config(_)), "version: want Config, got {err}");
    assert!(err.to_string().contains("version mismatch"), "got: {err}");
}
