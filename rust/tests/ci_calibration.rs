//! Statistical acceptance suite for the paper's "rigorous error bounds"
//! claim (§3.3), finally tested end to end: across hundreds of seeded runs
//! through the real OASRS sampler, the pane-store window assembler, and the
//! estimator (Eq. 1–9), the 95% `ConfidenceInterval` for SUM and MEAN must
//! contain the `ExactAgg` ground truth at a rate statistically compatible
//! with 0.95 — at every sampling fraction in {0.8, 0.4, 0.1}.
//!
//! **Acceptance bands.**  Each configuration runs `TRIALS = 200`
//! independent seeds.  The paper's P95 level is the 2σ rule, whose nominal
//! normal coverage is 95.45%; estimating per-stratum variance from the
//! sample costs a few tenths of a point (t-vs-normal, ~100 d.o.f. at the
//! smallest fraction).  A binomial proportion over n = 200 trials at
//! p ≈ 0.95 has σ ≈ 1.5%, so the per-configuration acceptance band is
//! p ± 3.2σ ≈ [0.90, 0.995] and the pooled band (n = 600 per query) is
//! [0.925, 0.985].  A cross-validation of this exact trial design
//! (reservoir WOR sampling + Eq. 1–9 + 2σ) measured empirical coverage
//! 0.935–0.96 per configuration — comfortably inside both bands, far
//! outside them if the variance arithmetic (Eq. 6/7/9), the weight law
//! (Eq. 1), or the window merge ever regress.
//!
//! Everything is seeded; the suite is deterministic in CI.
//!
//! A second axis runs the same bands over *disordered* arrivals: a seeded
//! bounded-skew shuffle routed through the event-time watermark path, at
//! every fraction — pinning that pane reassembly preserves the sampling
//! distribution the bounds are calibrated against.

use streamapprox::core::Item;
use streamapprox::error::bounds::{ConfidenceInterval, ConfidenceLevel};
use streamapprox::error::estimator::{estimate, StrataPartials};
use streamapprox::sampling::{OasrsSampler, Sampler};
use streamapprox::stream::DisorderConfig;
use streamapprox::util::rng::Rng;
use streamapprox::window::{
    EventTimeConfig, EventTimeSlicer, ExactAgg, WindowAssembler, WindowConfig,
};

const TRIALS: u64 = 200;
const FRACTIONS: [f64; 3] = [0.8, 0.4, 0.1];

/// Per-stratum trial population: (stratum, items/interval, mean, sd).
/// Three scales so mis-weighting any stratum moves the SUM far outside its
/// interval.
const SPEC: [(u16, usize, f64, f64); 3] =
    [(0, 1800, 50.0, 10.0), (1, 900, 200.0, 40.0), (2, 300, 1000.0, 100.0)];

/// One seeded run: a warm-up interval (locks the OASRS per-stratum
/// capacities to fraction × arrivals), then a measured interval assembled
/// into a tumbling window.  Returns whether the P95 SUM and MEAN intervals
/// contain the exact ground truth.
fn trial(seed: u64, fraction: f64) -> (bool, bool) {
    let mut rng = Rng::seed_from_u64(seed);
    let mut sampler = OasrsSampler::new(fraction, seed ^ 0xA5A5_5A5A_0F0F_F0F0);
    let mut assembler = WindowAssembler::new(WindowConfig::tumbling(1_000));

    let mut window = None;
    for interval in 0..2u64 {
        let mut exact = ExactAgg::default();
        let ts = interval * 1_000;
        for &(s, n, mu, sd) in &SPEC {
            for _ in 0..n {
                let v = rng.normal(mu, sd);
                sampler.offer(&Item::new(s, v, ts));
                exact.add(s, v);
            }
        }
        let result = sampler.finish_interval();
        window = assembler.push_interval(result, exact);
    }
    let ws = window.expect("tumbling window emits every interval");

    let partials = StrataPartials::from_sample(&ws.result.sample);
    let est = estimate(&partials, &ws.result.state);
    let sum_ci = ConfidenceInterval::for_sum(&est, ConfidenceLevel::P95);
    let mean_ci = ConfidenceInterval::for_mean(&est, ConfidenceLevel::P95);

    let truth_sum = ws.exact.total_sum();
    let truth_mean = truth_sum / ws.exact.total_count();
    (sum_ci.contains(truth_sum), mean_ci.contains(truth_mean))
}

/// Same populations as [`trial`], but arriving out of order: items carry
/// per-item timestamps inside each interval, a seeded bounded-skew shuffle
/// reorders the trace, and the event-time router reassembles the panes
/// before the sampler sees them.  The disorder budget (skew 300) exactly
/// matches the watermark config's lossless bound (150 + 150), so nothing
/// drops and the coverage statistics face the identical estimator math —
/// the axis pins that the event-time path neither biases the sample nor
/// corrupts the weights that the CIs are built from.
fn disordered_trial(seed: u64, fraction: f64) -> (bool, bool) {
    let mut rng = Rng::seed_from_u64(seed);
    let mut sampler = OasrsSampler::new(fraction, seed ^ 0xA5A5_5A5A_0F0F_F0F0);
    let mut assembler = WindowAssembler::new(WindowConfig::tumbling(1_000));

    let mut items = Vec::new();
    for interval in 0..2u64 {
        for &(s, n, mu, sd) in &SPEC {
            for k in 0..n {
                let ts = interval * 1_000 + (k as u64 * 1_000) / n as u64;
                items.push(Item::new(s, rng.normal(mu, sd), ts));
            }
        }
    }
    items.sort_by_key(|i| i.ts);
    let mut exact_panes = [ExactAgg::default(), ExactAgg::default()];
    for it in &items {
        exact_panes[(it.ts / 1_000) as usize].add(it.stratum, it.value);
    }
    let arrivals = DisorderConfig::bounded_skew(300, seed ^ 0xD15C).apply(&items);

    let mut slicer = EventTimeSlicer::new(&arrivals, 1_000, EventTimeConfig::new(150, 150));
    let mut window = None;
    let mut pane = 0usize;
    while let Some(batch) = slicer.next_pane() {
        for it in &batch {
            sampler.offer(it);
        }
        window = assembler.push_interval(
            sampler.finish_interval(),
            std::mem::take(&mut exact_panes[pane]),
        );
        pane += 1;
    }
    assert_eq!(pane, 2, "two event-time panes per trial");
    assert_eq!(slicer.dropped_items(), 0, "skew 300 fits the 150+150 lossless budget");
    let ws = window.expect("tumbling window emits every interval");

    let partials = StrataPartials::from_sample(&ws.result.sample);
    let est = estimate(&partials, &ws.result.state);
    let sum_ci = ConfidenceInterval::for_sum(&est, ConfidenceLevel::P95);
    let mean_ci = ConfidenceInterval::for_mean(&est, ConfidenceLevel::P95);

    let truth_sum = ws.exact.total_sum();
    let truth_mean = truth_sum / ws.exact.total_count();
    (sum_ci.contains(truth_sum), mean_ci.contains(truth_mean))
}

fn coverage(trial_fn: fn(u64, f64) -> (bool, bool), fraction: f64, seed_bank: u64) -> (f64, f64) {
    let mut sum_hits = 0u64;
    let mut mean_hits = 0u64;
    for i in 0..TRIALS {
        let seed = seed_bank.wrapping_add(i).wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
        let (s, m) = trial_fn(seed, fraction);
        sum_hits += s as u64;
        mean_hits += m as u64;
    }
    (sum_hits as f64 / TRIALS as f64, mean_hits as f64 / TRIALS as f64)
}

#[test]
fn p95_coverage_within_binomial_tolerance_at_all_fractions() {
    let mut pooled_sum = 0.0;
    let mut pooled_mean = 0.0;
    for (bank, &fraction) in FRACTIONS.iter().enumerate() {
        let (cov_sum, cov_mean) = coverage(trial, fraction, 1 + bank as u64);
        pooled_sum += cov_sum;
        pooled_mean += cov_mean;
        for (what, cov) in [("SUM", cov_sum), ("MEAN", cov_mean)] {
            assert!(
                (0.90..=0.995).contains(&cov),
                "{what}@f={fraction}: empirical P95 coverage {cov} outside \
                 the n={TRIALS} binomial band [0.90, 0.995]"
            );
        }
        eprintln!("f={fraction}: SUM coverage {cov_sum:.3}, MEAN coverage {cov_mean:.3}");
    }
    // Pooled over all fractions (n = 600 per query): a tighter band that a
    // systematic bias at any single fraction cannot hide inside.
    for (what, pooled) in [("SUM", pooled_sum), ("MEAN", pooled_mean)] {
        let cov = pooled / FRACTIONS.len() as f64;
        assert!(
            (0.925..=0.985).contains(&cov),
            "{what} pooled coverage {cov} outside [0.925, 0.985]"
        );
    }
}

#[test]
fn p95_coverage_holds_under_bounded_skew_disorder() {
    // The disorder axis: the same binomial acceptance bands, but the
    // sampler is fed by the event-time router over a bounded-skew shuffled
    // arrival sequence.  If pane reassembly double-offered, lost, or
    // re-weighted items, coverage would collapse out of these bands.
    let mut pooled_sum = 0.0;
    let mut pooled_mean = 0.0;
    for (bank, &fraction) in FRACTIONS.iter().enumerate() {
        let (cov_sum, cov_mean) = coverage(disordered_trial, fraction, 11 + bank as u64);
        pooled_sum += cov_sum;
        pooled_mean += cov_mean;
        for (what, cov) in [("SUM", cov_sum), ("MEAN", cov_mean)] {
            assert!(
                (0.90..=0.995).contains(&cov),
                "{what}@f={fraction} (disordered): empirical P95 coverage {cov} outside \
                 the n={TRIALS} binomial band [0.90, 0.995]"
            );
        }
        eprintln!(
            "disordered f={fraction}: SUM coverage {cov_sum:.3}, MEAN coverage {cov_mean:.3}"
        );
    }
    for (what, pooled) in [("SUM", pooled_sum), ("MEAN", pooled_mean)] {
        let cov = pooled / FRACTIONS.len() as f64;
        assert!(
            (0.925..=0.985).contains(&cov),
            "{what} pooled disordered coverage {cov} outside [0.925, 0.985]"
        );
    }
}

#[test]
fn intervals_are_informative_not_degenerate() {
    // The coverage test would be vacuous if the intervals were huge (always
    // contain) or the estimator exact (zero-width always at truth).  Pin
    // that at f = 0.4 the P95 SUM interval is strictly positive-width and
    // usefully tight: relative half-width under 5%, and the estimate is
    // genuinely approximate (non-zero miss distance on most seeds).
    let mut widths = Vec::new();
    let mut misses = 0;
    for i in 0..50u64 {
        let seed = 77 + i * 13;
        let mut rng = Rng::seed_from_u64(seed);
        let mut sampler = OasrsSampler::new(0.4, seed);
        let mut assembler = WindowAssembler::new(WindowConfig::tumbling(1_000));
        let mut window = None;
        for interval in 0..2u64 {
            let mut exact = ExactAgg::default();
            for &(s, n, mu, sd) in &SPEC {
                for _ in 0..n {
                    let v = rng.normal(mu, sd);
                    sampler.offer(&Item::new(s, v, interval * 1_000));
                    exact.add(s, v);
                }
            }
            window = assembler.push_interval(sampler.finish_interval(), exact);
        }
        let ws = window.unwrap();
        let est = estimate(&StrataPartials::from_sample(&ws.result.sample), &ws.result.state);
        let ci = ConfidenceInterval::for_sum(&est, ConfidenceLevel::P95);
        assert!(ci.bound > 0.0, "seed {seed}: degenerate zero-width interval");
        widths.push(ci.relative());
        if (ci.value - ws.exact.total_sum()).abs() > 1e-9 {
            misses += 1;
        }
    }
    let mean_rel: f64 = widths.iter().sum::<f64>() / widths.len() as f64;
    assert!(mean_rel < 0.05, "P95 SUM interval too loose: mean relative {mean_rel}");
    assert!(misses >= 45, "estimates suspiciously exact ({misses}/50 non-exact)");
}
