//! Integration tests for the zero-allocation batch ingest path:
//! Algorithm-L vs draw-per-item reservoir uniformity (chi-square),
//! chunk-size independence of seeded results, `offer_slice` ≡ `offer` ≡
//! `offer_columnar` equivalence across every sampler kind, AoS↔SoA
//! round-trip losslessness, batched-Bernoulli mask uniformity, the
//! threaded transport's buffer-recycling guarantee (scalar and columnar
//! feeds alike), and bit-identical event-time transport (worker-side ts
//! bounds vs inline ground truth).

use streamapprox::core::{ColumnarChunk, Item};
use streamapprox::engine::IngestPool;
use streamapprox::sampling::{
    make_sampler, ColumnarMode, OasrsSampler, Reservoir, ReservoirMode, SampleResult, SamplerKind,
};
use streamapprox::util::rng::Rng;

// ---------------------------------------------------------------------------
// Algorithm-L vs draw-per-item: same inclusion distribution
// ---------------------------------------------------------------------------

/// Per-item inclusion chi-square statistic for one reservoir mode: `trials`
/// independent reservoirs over the same `n`-item stream, counting how often
/// each item survives.
fn inclusion_chi2(mode: ReservoirMode, n: usize, cap: usize, trials: u64) -> f64 {
    let mut counts = vec![0u64; n];
    for t in 0..trials {
        let mut r = Reservoir::with_mode(cap, t.wrapping_mul(0x9E3779B9).wrapping_add(5), mode);
        for i in 0..n {
            r.offer(i);
        }
        for &x in r.items() {
            counts[x] += 1;
        }
    }
    let expect = trials as f64 * cap as f64 / n as f64;
    counts
        .iter()
        .map(|&c| {
            let d = c as f64 - expect;
            d * d / expect
        })
        .sum()
}

#[test]
fn chi_square_uniformity_skip_vs_draw_per_item() {
    // Both acceptance algorithms must produce per-item inclusion counts
    // consistent with uniform p = cap/n.  Same seed budget for both modes:
    // 4000 trials of a 300-item stream into a cap-6 reservoir — n/cap = 50
    // clears the skip-engagement horizon, so the dense phase, the Beta
    // re-seeded switch, and the geometric-skip chain are all inside the
    // tested region.  The statistic is ~chi2 with df = 299 (mean 299,
    // sd ~24.5); [180, 420] is a ±~5 sigma acceptance band — failures
    // indicate real non-uniformity, not noise.
    let (n, cap, trials) = (300, 6, 4000);
    for mode in [ReservoirMode::SkipAheadL, ReservoirMode::DrawPerItem] {
        let chi2 = inclusion_chi2(mode, n, cap, trials);
        assert!(
            (180.0..420.0).contains(&chi2),
            "{mode:?}: chi-square {chi2:.1} outside uniformity band"
        );
    }
}

#[test]
fn skip_reservoir_subset_and_size_invariants_hold() {
    // Large-stream smoke for the skip path: correct size, items from the
    // input, no duplicates.
    let mut r = Reservoir::new(32, 77);
    for i in 0..1_000_000u32 {
        r.offer(i);
    }
    assert_eq!(r.len(), 32);
    assert_eq!(r.seen(), 1_000_000);
    let mut v: Vec<u32> = r.items().to_vec();
    v.sort_unstable();
    v.dedup();
    assert_eq!(v.len(), 32);
    assert!(v.iter().all(|&x| x < 1_000_000));
}

// ---------------------------------------------------------------------------
// Chunk-size independence + offer_slice ≡ offer
// ---------------------------------------------------------------------------

fn trace(n: usize, strata: usize, seed: u64) -> Vec<Item> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..n)
        .map(|i| {
            Item::new(
                rng.range_usize(0, strata) as u16,
                rng.normal(100.0, 25.0),
                i as u64,
            )
        })
        .collect()
}

fn assert_results_identical(a: &SampleResult, b: &SampleResult, tag: &str) {
    assert_eq!(a.sample, b.sample, "{tag}: samples differ");
    assert_eq!(a.state.c, b.state.c, "{tag}: arrival counters differ");
    assert_eq!(a.state.n_cap, b.state.n_cap, "{tag}: capacities differ");
}

#[test]
fn inline_pool_deterministic_across_chunk_sizes() {
    // Same seed, same items: offering one-at-a-time, in 512-item chunks,
    // or as one full slice must produce bit-identical SampleResults (two
    // intervals each, so adaptive capacities are exercised too).
    let kinds = [
        SamplerKind::Oasrs,
        SamplerKind::Srs,
        SamplerKind::Sts,
        SamplerKind::WeightedRes,
        SamplerKind::None,
    ];
    let items = trace(10_000, 5, 42);
    for kind in kinds {
        let run = |chunk: usize| -> Vec<SampleResult> {
            let mut pool = IngestPool::new(kind, 1, 0.3, 7);
            let mut out = Vec::new();
            for _ in 0..2 {
                match chunk {
                    0 => {
                        for &it in &items {
                            pool.offer(it);
                        }
                    }
                    c => {
                        for piece in items.chunks(c) {
                            pool.offer_slice(piece);
                        }
                    }
                }
                out.push(pool.finish_interval());
            }
            out
        };
        let per_item = run(0);
        let chunked = run(512);
        let whole = run(items.len());
        for i in 0..2 {
            assert_results_identical(&per_item[i], &chunked[i], &format!("{kind:?}[512]"));
            assert_results_identical(&per_item[i], &whole[i], &format!("{kind:?}[full]"));
        }
    }
}

// ---------------------------------------------------------------------------
// Columnar (SoA) path: round-trip, equivalence, mask uniformity
// ---------------------------------------------------------------------------

#[test]
fn aos_soa_round_trip_is_lossless() {
    // Transposing an item slice into a ColumnarChunk and back must
    // reproduce every field bit-for-bit, for arbitrary shapes (including
    // out-of-range strata — transport does not validate, samplers do).
    for case in 0..8u64 {
        let mut meta = Rng::seed_from_u64(400 + case);
        let n = meta.range_usize(0, 3000);
        let mut items = trace(n, meta.range_usize(1, 9), 500 + case);
        if !items.is_empty() {
            items[0].stratum = 999;
        }
        let chunk = ColumnarChunk::from_items(&items);
        assert_eq!(chunk.len(), items.len());
        assert_eq!(chunk.to_items(), items, "case {case}");
        // Incremental builds agree with the bulk transpose.
        let mut push_built = ColumnarChunk::new();
        for it in &items {
            push_built.push_item(it);
        }
        assert_eq!(push_built, chunk, "case {case}: push_item path");
    }
}

#[test]
fn inline_pool_columnar_matches_scalar_across_chunk_sizes() {
    // The tentpole equivalence gate at the pool level: a columnar feed in
    // 1-item, 512-item, or whole-interval chunks must reproduce the
    // per-item scalar feed bit-for-bit, for every sampler kind, across
    // two intervals (adaptive capacities included).
    let kinds = [
        SamplerKind::Oasrs,
        SamplerKind::Srs,
        SamplerKind::Sts,
        SamplerKind::WeightedRes,
        SamplerKind::None,
    ];
    let items = trace(10_000, 5, 42);
    for kind in kinds {
        let scalar = {
            let mut pool = IngestPool::new(kind, 1, 0.3, 7);
            let mut out = Vec::new();
            for _ in 0..2 {
                for &it in &items {
                    pool.offer(it);
                }
                out.push(pool.finish_interval());
            }
            out
        };
        for chunk_size in [1usize, 512, items.len()] {
            let mut pool = IngestPool::new(kind, 1, 0.3, 7);
            for interval in 0..2 {
                for piece in items.chunks(chunk_size) {
                    pool.offer_columnar(&ColumnarChunk::from_items(piece));
                }
                let r = pool.finish_interval();
                assert_results_identical(
                    &scalar[interval],
                    &r,
                    &format!("{kind:?} columnar[{chunk_size}] interval {interval}"),
                );
            }
        }
    }
}

#[test]
fn offer_columnar_equivalence_property_all_kinds() {
    // Property over random seeds/shapes/chunkings: a sampler fed SoA
    // chunks equals the same sampler fed item-at-a-time.
    let kinds = [
        SamplerKind::Oasrs,
        SamplerKind::Srs,
        SamplerKind::Sts,
        SamplerKind::WeightedRes,
        SamplerKind::None,
    ];
    for case in 0..10u64 {
        let mut meta = Rng::seed_from_u64(2000 + case);
        let n = meta.range_usize(1, 4000);
        let strata = meta.range_usize(1, 8);
        let fraction = meta.range_f64(0.05, 1.0);
        let seed = meta.next_u64();
        let items = trace(n, strata, 9_000 + case);
        for kind in kinds {
            let mut a = make_sampler(kind, fraction, seed);
            for it in &items {
                a.offer(it);
            }
            let mut b = make_sampler(kind, fraction, seed);
            let mut rest = &items[..];
            let mut chop = Rng::seed_from_u64(case);
            while !rest.is_empty() {
                let take = chop.range_usize(1, rest.len().min(700) + 1);
                b.offer_columnar(&ColumnarChunk::from_items(&rest[..take]));
                rest = &rest[take..];
            }
            let (ra, rb) = (a.finish_interval(), b.finish_interval());
            assert_results_identical(&ra, &rb, &format!("case {case} {kind:?}"));
        }
    }
}

#[test]
fn batched_bernoulli_mask_is_uniform_chi_square() {
    // Per-position acceptance counts of the batched Bernoulli mask over
    // independent seeds must be binomial(trials, p) in every lane of the
    // 8-wide fill.  Statistic ~ chi2 with df = 300 (mean 300, sd ~24.5);
    // [180, 420] is a ±~5 sigma band — a failure is real lane bias.
    let (n, trials, p) = (300usize, 2000u64, 0.3f64);
    let mut counts = vec![0u64; n];
    let mut mask = vec![false; n];
    for t in 0..trials {
        let mut rng = Rng::seed_from_u64(t.wrapping_mul(0x9E3779B9).wrapping_add(17));
        rng.fill_bernoulli(p, &mut mask);
        for (c, &hit) in counts.iter_mut().zip(&mask) {
            *c += hit as u64;
        }
    }
    let expect = trials as f64 * p;
    let var = trials as f64 * p * (1.0 - p);
    let chi2: f64 = counts
        .iter()
        .map(|&c| {
            let d = c as f64 - expect;
            d * d / var
        })
        .sum();
    assert!(
        (180.0..420.0).contains(&chi2),
        "mask chi-square {chi2:.1} outside uniformity band"
    );
}

#[test]
fn masked_columnar_inclusion_is_uniform_chi_square() {
    // The Masked kernel consumes a dedicated mask stream, so it cannot be
    // byte-compared to the scalar path — its pin is statistical: per-item
    // inclusion over independent seeds must be uniform at p = cap/n.
    // fraction 0.02 on a 300-item stratum -> cap 6 after the warm-up
    // interval locks the EWMA, matching the reservoir suite's band.
    let (n, trials) = (300usize, 4000u64);
    let mut counts = vec![0u64; n];
    let mut chunk = ColumnarChunk::new();
    for i in 0..n {
        chunk.push(0, i as f64, i as u64);
    }
    for t in 0..trials {
        let mut s = OasrsSampler::new(0.02, t.wrapping_mul(0x9E3779B9).wrapping_add(29))
            .with_columnar_mode(ColumnarMode::Masked);
        s.offer_columnar(&chunk);
        s.finish_interval(); // warm-up: EWMA = 300 -> cap = ceil(0.02*300) = 6
        s.offer_columnar(&chunk);
        let r = s.finish_interval();
        assert_eq!(r.state.n_cap[0], 6.0, "capacity drifted; band below assumes cap 6");
        for &(_, v) in &r.sample {
            counts[v as usize] += 1;
        }
    }
    let expect = trials as f64 * 6.0 / n as f64;
    let chi2: f64 = counts
        .iter()
        .map(|&c| {
            let d = c as f64 - expect;
            d * d / expect
        })
        .sum();
    assert!(
        (180.0..420.0).contains(&chi2),
        "masked-mode inclusion chi-square {chi2:.1} outside uniformity band"
    );
}

#[test]
fn offer_slice_equivalence_property_all_kinds() {
    // Property over random seeds/shapes: a sampler fed via offer_slice with
    // arbitrary chunking equals the same sampler fed item-at-a-time.
    let kinds = [
        SamplerKind::Oasrs,
        SamplerKind::Srs,
        SamplerKind::Sts,
        SamplerKind::WeightedRes,
        SamplerKind::None,
    ];
    for case in 0..10u64 {
        let mut meta = Rng::seed_from_u64(1000 + case);
        let n = meta.range_usize(1, 4000);
        let strata = meta.range_usize(1, 8);
        let fraction = meta.range_f64(0.05, 1.0);
        let seed = meta.next_u64();
        let items = trace(n, strata, 7_000 + case);
        for kind in kinds {
            let mut a = make_sampler(kind, fraction, seed);
            for it in &items {
                a.offer(it);
            }
            let mut b = make_sampler(kind, fraction, seed);
            let mut rest = &items[..];
            let mut chop = Rng::seed_from_u64(case);
            while !rest.is_empty() {
                let take = chop.range_usize(1, rest.len().min(700) + 1);
                b.offer_slice(&rest[..take]);
                rest = &rest[take..];
            }
            let (ra, rb) = (a.finish_interval(), b.finish_interval());
            assert_results_identical(&ra, &rb, &format!("case {case} {kind:?}"));
        }
    }
}

#[test]
fn seeded_inline_runs_are_reproducible() {
    // The acceptance determinism check: same seed + workers=1 -> identical
    // SampleResult, run-to-run.
    let items = trace(20_000, 4, 9);
    let run = || {
        let mut pool = IngestPool::new(SamplerKind::Oasrs, 1, 0.2, 123);
        pool.offer_slice(&items);
        let warm = pool.finish_interval();
        pool.offer_slice(&items);
        (warm, pool.finish_interval())
    };
    let (a1, a2) = run();
    let (b1, b2) = run();
    assert_results_identical(&a1, &b1, "warm-up interval");
    assert_results_identical(&a2, &b2, "steady interval");
}

#[test]
fn seeded_threaded_runs_are_reproducible() {
    // Chunk round-robin + per-worker seeds are deterministic, so even the
    // threaded pool reproduces exactly for a fixed worker count.
    let items = trace(30_000, 4, 17);
    let run = || {
        let mut pool = IngestPool::new(SamplerKind::Oasrs, 3, 0.2, 321);
        pool.offer_slice(&items);
        pool.finish_interval()
    };
    let (a, b) = (run(), run());
    assert_results_identical(&a, &b, "threaded");
}

#[test]
fn threaded_spsc_preserves_ts_bounds_bit_identically() {
    // Threaded pools compute interval ts bounds worker-side, off the `ts`
    // columns of the chunks that crossed the SPSC transport; inline pools
    // compute them offer-side, before any transport.  Agreement with each
    // other and with ground truth — including planted u64-domain extremes —
    // certifies event times survive the chunk ring bit-identically (the
    // event-time router's pane arithmetic depends on exact ts values).
    let mut items = trace(20_000, 4, 61);
    items[137].ts = u64::MAX;
    items[9_000].ts = u64::MAX - 3;
    items[18_111].ts = 0;
    let truth = items
        .iter()
        .fold(None, |acc: Option<(u64, u64)>, it| match acc {
            Some((lo, hi)) => Some((lo.min(it.ts), hi.max(it.ts))),
            None => Some((it.ts, it.ts)),
        })
        .unwrap();

    for workers in [1usize, 3] {
        for feed in ["offer", "slice", "columnar"] {
            let mut pool = IngestPool::new(SamplerKind::Oasrs, workers, 0.2, 91);
            for interval in 0..2 {
                match feed {
                    "offer" => {
                        for &it in &items {
                            pool.offer(it);
                        }
                    }
                    "slice" => {
                        for piece in items.chunks(700) {
                            pool.offer_slice(piece);
                        }
                    }
                    _ => pool.offer_columnar(&ColumnarChunk::from_items(&items)),
                }
                pool.finish_interval();
                assert_eq!(
                    pool.interval_ts_bounds(),
                    Some(truth),
                    "workers={workers} feed={feed} interval={interval}: ts bounds diverged"
                );
            }
            // An empty interval resets the bounds — stale values must not
            // leak across closes.
            pool.finish_interval();
            assert_eq!(
                pool.interval_ts_bounds(),
                None,
                "workers={workers} feed={feed}: empty interval must clear bounds"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Transport: zero allocations in steady state
// ---------------------------------------------------------------------------

#[test]
fn threaded_transport_zero_allocations_in_steady_state() {
    let items = trace(25_000, 4, 23);
    let mut pool = IngestPool::new(SamplerKind::Oasrs, 4, 0.3, 55);
    // The buffer pool is pre-sized at construction; every chunk of every
    // interval must be served by a recycled buffer.
    let constructed = pool.transport_stats().expect("threaded pool has stats");
    assert!(constructed.buffers_allocated > 0);
    assert_eq!(constructed.chunks_sent, 0);
    for _ in 0..5 {
        pool.offer_slice(&items);
        pool.finish_interval();
    }
    let steady = pool.transport_stats().unwrap();
    assert_eq!(
        steady.buffers_allocated, constructed.buffers_allocated,
        "ingest must never allocate chunk buffers after construction"
    );
    assert_eq!(
        steady.buffers_recycled, steady.chunks_sent,
        "every shipped chunk must ride a recycled buffer"
    );
    assert!(steady.chunks_sent >= 5 * 25_000 / 512);
    assert!(
        steady.recycle_hit_rate() > 0.7,
        "recycle hit rate {:.2} too low",
        steady.recycle_hit_rate()
    );
}

#[test]
fn threaded_columnar_feed_zero_allocations_in_steady_state() {
    // The columnar acceptance gate for the transport: whole-interval SoA
    // slices ride the same recycled ColumnarChunk ring buffers — after
    // construction the allocation counter never moves.
    let chunk = ColumnarChunk::from_items(&trace(25_000, 4, 31));
    let mut pool = IngestPool::new(SamplerKind::Oasrs, 4, 0.3, 56);
    let constructed = pool.transport_stats().expect("threaded pool has stats");
    for _ in 0..5 {
        pool.offer_columnar(&chunk);
        pool.finish_interval();
    }
    let steady = pool.transport_stats().unwrap();
    assert_eq!(
        steady.buffers_allocated, constructed.buffers_allocated,
        "columnar ingest must never allocate chunk buffers after construction"
    );
    assert_eq!(
        steady.buffers_recycled, steady.chunks_sent,
        "every shipped chunk must ride a recycled buffer"
    );
    assert!(steady.chunks_sent >= 5 * 25_000 / 512);
}
