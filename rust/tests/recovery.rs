//! Seeded crash-injection determinism suite for checkpoint/restore.
//!
//! The headline contract: a seeded run interrupted at *any* interval
//! boundary and restored from its newest snapshot is bit-identical to the
//! uninterrupted run — the crashed run's windows concatenated with the
//! recovered run's windows equal the clean run's windows field for field
//! (`to_bits` on every float), for every sampler kind, on both engines,
//! single- and multi-worker.  Checkpointing itself must not perturb the
//! run: a checkpointed run matches a plain run byte for byte.
//!
//! Around it: torn-write/corrupt-snapshot rejection with fallback to the
//! previous epoch (pinned, exact-once accounting), version/fingerprint/
//! budget mismatch rejection with descriptive errors, adaptive-budget
//! feedback state surviving the crash, and sketch-backed answers (top-k
//! lists, quantiles, distinct counts) surviving recovery unchanged.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// The two corruption tests below tick the global
/// `recovery_fallbacks_total` counter; this serializes them so the
/// exact-once delta assertion cannot be perturbed by a parallel test.
static FALLBACK_COUNTER_LOCK: Mutex<()> = Mutex::new(());

use streamapprox::engine::WindowReport;
use streamapprox::prelude::*;
use streamapprox::runtime::{CheckpointSpec, CheckpointStore, DurabilityOptions};
use streamapprox::stream::StreamGenerator;

const ALL_SAMPLERS: [SamplerKind; 5] = [
    SamplerKind::Oasrs,
    SamplerKind::Srs,
    SamplerKind::Sts,
    SamplerKind::WeightedRes,
    SamplerKind::None,
];

fn tmp_dir(tag: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let n = SEQ.fetch_add(1, Ordering::Relaxed);
    let dir =
        std::env::temp_dir().join(format!("sax_recovery_{tag}_{}_{n}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Event-time-sorted trace (both engines expect a sorted broker log).
fn sorted_trace(rate: f64, seed: u64, dur_ms: u64) -> Vec<Item> {
    let mut items =
        StreamGenerator::new(&StreamConfig::gaussian_micro(rate, seed)).take_until(dur_ms);
    items.sort_by_key(|i| i.ts);
    items
}

fn build(
    svc: &ComputeService,
    engine: EngineKind,
    sampler: SamplerKind,
    query: Query,
    workers: usize,
    budget: QueryBudget,
    durability: DurabilityOptions,
) -> Pipeline {
    PipelineBuilder::new()
        .engine(engine)
        .sampler(sampler)
        // Fixed fraction by default: the pipelined engine's window-feedback
        // channel is racy under adaptive budgets, so only a constant
        // fraction is replay-deterministic there (the batched engine's
        // adaptive path is covered by its own test below).
        .budget(budget)
        .query(query)
        .window(WindowConfig::new(2_000, 1_000))
        .batch_interval_ms(500)
        .workers(workers)
        .seed(7177)
        .durability(durability)
        .build_with_handle(svc.handle())
}

fn ckpt_every(dir: &PathBuf) -> DurabilityOptions {
    DurabilityOptions::default().checkpoint_to(dir, 1)
}

fn crash_at(dir: &PathBuf, n: u64) -> DurabilityOptions {
    DurabilityOptions {
        checkpoint: Some(CheckpointSpec::new(dir, 1).with_crash_after(n)),
        restore_on_start: false,
    }
}

fn restore_from(dir: &PathBuf) -> DurabilityOptions {
    DurabilityOptions::default().checkpoint_to(dir, 1).restore_on_start(true)
}

fn assert_window_bits(x: &WindowReport, y: &WindowReport, tag: &str) {
    let w = format!("{tag} window {}-{}", x.start_ms, x.end_ms);
    assert_eq!(x.start_ms, y.start_ms, "{w}: start");
    assert_eq!(x.end_ms, y.end_ms, "{w}: end");
    assert_eq!(x.sampled, y.sampled, "{w}: sample size");
    assert_eq!(x.arrived.to_bits(), y.arrived.to_bits(), "{w}: arrived");
    assert_eq!(x.late_dropped, y.late_dropped, "{w}: late_dropped");
    assert_eq!(
        x.result.value().to_bits(),
        y.result.value().to_bits(),
        "{w}: estimate {} vs {}",
        x.result.value(),
        y.result.value()
    );
    match (x.result.scalar, y.result.scalar) {
        (Some(a), Some(b)) => assert_eq!(a.bound.to_bits(), b.bound.to_bits(), "{w}: bound"),
        (None, None) => {}
        _ => panic!("{w}: scalar presence diverged"),
    }
    match (&x.result.per_stratum, &y.result.per_stratum) {
        (Some(a), Some(b)) => {
            let a: Vec<u64> = a.iter().map(|v| v.to_bits()).collect();
            let b: Vec<u64> = b.iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b, "{w}: per-stratum");
        }
        (None, None) => {}
        _ => panic!("{w}: per-stratum presence diverged"),
    }
    match (&x.result.top_k, &y.result.top_k) {
        (Some(a), Some(b)) => {
            let a: Vec<(u64, u64)> = a.iter().map(|&(k, v)| (k, v.to_bits())).collect();
            let b: Vec<(u64, u64)> = b.iter().map(|&(k, v)| (k, v.to_bits())).collect();
            assert_eq!(a, b, "{w}: top-k ranking");
        }
        (None, None) => {}
        _ => panic!("{w}: top-k presence diverged"),
    }
    match (x.exact_scalar, y.exact_scalar) {
        (Some(a), Some(b)) => assert_eq!(a.to_bits(), b.to_bits(), "{w}: exact"),
        (None, None) => {}
        _ => panic!("{w}: exact presence diverged"),
    }
}

fn assert_reports_identical(a: &RunReport, b: &RunReport, tag: &str) {
    assert_eq!(a.windows.len(), b.windows.len(), "{tag}: window count");
    for (x, y) in a.windows.iter().zip(&b.windows) {
        assert_window_bits(x, y, tag);
    }
}

/// crashed ++ recovered == clean, field for field.
fn assert_stitch_equals_clean(
    clean: &RunReport,
    crashed: &RunReport,
    recovered: &RunReport,
    tag: &str,
) {
    assert_eq!(
        crashed.windows.len() + recovered.windows.len(),
        clean.windows.len(),
        "{tag}: stitched window count ({} crashed + {} recovered)",
        crashed.windows.len(),
        recovered.windows.len()
    );
    let stitched = crashed.windows.iter().chain(&recovered.windows);
    for (c, s) in clean.windows.iter().zip(stitched) {
        assert_window_bits(c, s, tag);
    }
}

/// Epochs the clean checkpointed run wrote — one per interval boundary.
fn boundaries(dir: &PathBuf) -> Vec<u64> {
    CheckpointStore::open(dir).expect("store").epochs().expect("epochs")
}

// ---------------------------------------------------------------------------
// the headline: crash at every boundary × all samplers × both engines
// ---------------------------------------------------------------------------

/// Crash-injection matrix: every interval boundary, all five sampler
/// kinds, both engines, single-worker.  Also pins that snapshotting does
/// not perturb a run (checkpointed == plain, byte for byte).
#[test]
fn crash_at_every_boundary_all_samplers_both_engines() {
    let svc = ComputeService::native();
    let items = sorted_trace(200.0, 31, 4_000);
    let budget = QueryBudget::SamplingFraction(0.4);
    for engine in [EngineKind::Batched, EngineKind::Pipelined] {
        for sampler in ALL_SAMPLERS {
            let tag = format!("{engine:?}/{sampler:?}");
            let plain = build(
                &svc,
                engine,
                sampler,
                Query::Sum,
                1,
                budget,
                DurabilityOptions::default(),
            )
            .run_items(&items)
            .unwrap();
            let clean_dir = tmp_dir("clean");
            let clean =
                build(&svc, engine, sampler, Query::Sum, 1, budget, ckpt_every(&clean_dir))
                    .run_items(&items)
                    .unwrap();
            assert_reports_identical(&plain, &clean, &format!("{tag}: ckpt-on vs off"));

            let epochs = boundaries(&clean_dir);
            assert!(
                epochs.len() >= 4,
                "{tag}: only {} interval boundaries — trace too short",
                epochs.len()
            );
            for &n in &epochs {
                let dir = tmp_dir("crash");
                let crashed =
                    build(&svc, engine, sampler, Query::Sum, 1, budget, crash_at(&dir, n))
                        .run_items(&items)
                        .unwrap();
                let recovered =
                    build(&svc, engine, sampler, Query::Sum, 1, budget, restore_from(&dir))
                        .run_items(&items)
                        .unwrap();
                assert_stitch_equals_clean(
                    &clean,
                    &crashed,
                    &recovered,
                    &format!("{tag} crash@{n}"),
                );
            }
        }
    }
}

/// Multi-worker pools recover bit-identically: per-worker RNG streams,
/// the round-robin transport cursor, and STS's two-phase batch state all
/// restore to exactly where the crash left them.
#[test]
fn multi_worker_recovery_is_bit_identical() {
    let svc = ComputeService::native();
    let items = sorted_trace(300.0, 47, 4_000);
    let budget = QueryBudget::SamplingFraction(0.4);
    for engine in [EngineKind::Batched, EngineKind::Pipelined] {
        for sampler in [SamplerKind::Oasrs, SamplerKind::Sts, SamplerKind::WeightedRes] {
            let tag = format!("{engine:?}/{sampler:?}/3-workers");
            let clean_dir = tmp_dir("mw_clean");
            let clean =
                build(&svc, engine, sampler, Query::Sum, 3, budget, ckpt_every(&clean_dir))
                    .run_items(&items)
                    .unwrap();
            let epochs = boundaries(&clean_dir);
            let picks = [epochs[epochs.len() / 2], *epochs.last().unwrap()];
            for n in picks {
                let dir = tmp_dir("mw_crash");
                let crashed =
                    build(&svc, engine, sampler, Query::Sum, 3, budget, crash_at(&dir, n))
                        .run_items(&items)
                        .unwrap();
                let recovered =
                    build(&svc, engine, sampler, Query::Sum, 3, budget, restore_from(&dir))
                        .run_items(&items)
                        .unwrap();
                assert_stitch_equals_clean(
                    &clean,
                    &crashed,
                    &recovered,
                    &format!("{tag} crash@{n}"),
                );
            }
        }
    }
}

/// The feedback-EWMA controller's state is part of the snapshot: under an
/// adaptive accuracy budget the recovered run continues the *same*
/// fraction trajectory the clean run followed (batched engine — the only
/// one whose feedback point is replay-deterministic).
#[test]
fn adaptive_budget_feedback_state_survives_crash() {
    let svc = ComputeService::native();
    let items = sorted_trace(250.0, 53, 4_000);
    let budget = QueryBudget::TargetRelativeError { target: 0.02, initial_fraction: 0.5 };
    let clean_dir = tmp_dir("adapt_clean");
    let run = |durability: DurabilityOptions| {
        build(
            &svc,
            EngineKind::Batched,
            SamplerKind::Oasrs,
            Query::Sum,
            1,
            budget,
            durability,
        )
        .run_items(&items)
        .unwrap()
    };
    let clean = run(ckpt_every(&clean_dir));
    for &n in &boundaries(&clean_dir) {
        let dir = tmp_dir("adapt_crash");
        let crashed = run(crash_at(&dir, n));
        let recovered = run(restore_from(&dir));
        assert_stitch_equals_clean(&clean, &crashed, &recovered, &format!("adaptive crash@{n}"));
    }
}

/// Sketch-backed answers survive recovery unchanged: pane-sketch partials
/// (quantile clusters, HLL registers, Count-Min + heavy-hitter entries)
/// restore from the snapshot, and the recovered windows report the same
/// top-k rankings, quantiles, and distinct counts as the clean run.
#[test]
fn sketch_answers_survive_recovery() {
    let svc = ComputeService::native();
    let items = sorted_trace(300.0, 61, 4_000);
    let budget = QueryBudget::SamplingFraction(0.5);
    for engine in [EngineKind::Batched, EngineKind::Pipelined] {
        for query in [Query::TopK(4), Query::Quantile(0.9), Query::Distinct] {
            let tag = format!("{engine:?}/{query:?}");
            let clean_dir = tmp_dir("sk_clean");
            let clean = build(
                &svc,
                engine,
                SamplerKind::Oasrs,
                query.clone(),
                1,
                budget,
                ckpt_every(&clean_dir),
            )
            .run_items(&items)
            .unwrap();
            let epochs = boundaries(&clean_dir);
            let n = epochs[epochs.len() / 2];
            let dir = tmp_dir("sk_crash");
            let crashed = build(
                &svc,
                engine,
                SamplerKind::Oasrs,
                query.clone(),
                1,
                budget,
                crash_at(&dir, n),
            )
            .run_items(&items)
            .unwrap();
            let recovered = build(
                &svc,
                engine,
                SamplerKind::Oasrs,
                query.clone(),
                1,
                budget,
                restore_from(&dir),
            )
            .run_items(&items)
            .unwrap();
            if matches!(query, Query::TopK(_)) {
                assert!(
                    clean.windows.iter().any(|w| w.result.top_k.is_some()),
                    "{tag}: no top-k output to compare"
                );
            }
            assert_stitch_equals_clean(&clean, &crashed, &recovered, &format!("{tag} crash@{n}"));
        }
    }
}

// ---------------------------------------------------------------------------
// torn writes, corrupt snapshots, fallback accounting
// ---------------------------------------------------------------------------

/// Corrupting the newest epoch (bit-flip, truncation, or an empty torn
/// file) makes recovery fall back to the previous epoch — skipping exactly
/// one file, ticking `recovery_fallbacks_total` exactly once — and the
/// fallback recovery is bit-identical to a recovery that never saw the
/// corrupt epoch.
#[test]
fn corrupt_newest_epoch_falls_back_exactly_once() {
    let _serial =
        FALLBACK_COUNTER_LOCK.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
    let svc = ComputeService::native();
    let items = sorted_trace(200.0, 71, 4_000);
    let budget = QueryBudget::SamplingFraction(0.4);
    let run = |sampler, durability: DurabilityOptions| {
        build(&svc, EngineKind::Batched, sampler, Query::Sum, 1, budget, durability)
            .run_items(&items)
            .unwrap()
    };

    // Reference: crash at boundary n-1 and recover — the trajectory a
    // fallback from a corrupt epoch n must reproduce exactly.
    let probe_dir = tmp_dir("fb_probe");
    run(SamplerKind::Oasrs, ckpt_every(&probe_dir));
    let epochs = boundaries(&probe_dir);
    let n = epochs[epochs.len() / 2];
    assert!(n >= 2, "need at least two epochs before the crash point");
    let ref_dir = tmp_dir("fb_ref");
    run(SamplerKind::Oasrs, crash_at(&ref_dir, n - 1));
    let reference = run(SamplerKind::Oasrs, restore_from(&ref_dir));

    let corruptions: [(&str, fn(&PathBuf)); 3] = [
        ("bit-flip", |p| {
            let mut bytes = std::fs::read(p).unwrap();
            let mid = bytes.len() / 2;
            bytes[mid] ^= 0x40;
            std::fs::write(p, bytes).unwrap();
        }),
        ("truncate", |p| {
            let bytes = std::fs::read(p).unwrap();
            std::fs::write(p, &bytes[..bytes.len() / 3]).unwrap();
        }),
        ("torn-empty", |p| {
            std::fs::write(p, []).unwrap();
        }),
    ];
    for (mode, corrupt) in corruptions {
        let dir = tmp_dir("fb_crash");
        run(SamplerKind::Oasrs, crash_at(&dir, n));
        let store = CheckpointStore::open(&dir).unwrap();
        corrupt(&store.epoch_path(n));

        // Pin the accounting: the loader skips exactly the one corrupt
        // file, lands on epoch n-1, and ticks the fallback counter once.
        let before = streamapprox::obs::global().snapshot();
        let loaded = store.load_latest().unwrap().expect("a valid epoch remains");
        let delta = streamapprox::obs::global().snapshot().delta(&before);
        assert_eq!(loaded.epoch, n - 1, "{mode}: fallback epoch");
        assert_eq!(loaded.skipped, 1, "{mode}: exactly one file skipped");
        assert_eq!(
            delta.counter("recovery_fallbacks_total"),
            1,
            "{mode}: fallback counter must tick exactly once"
        );

        let recovered = run(SamplerKind::Oasrs, restore_from(&dir));
        assert_reports_identical(
            &reference,
            &recovered,
            &format!("{mode}: fallback recovery vs clean epoch-{} recovery", n - 1),
        );
    }
}

/// When every epoch is corrupt there is nothing to fall back to: recovery
/// reports the torn write instead of silently starting fresh.
#[test]
fn all_epochs_corrupt_is_an_error_not_a_fresh_start() {
    let _serial =
        FALLBACK_COUNTER_LOCK.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
    let svc = ComputeService::native();
    let items = sorted_trace(200.0, 73, 3_000);
    let budget = QueryBudget::SamplingFraction(0.4);
    let dir = tmp_dir("all_corrupt");
    build(
        &svc,
        EngineKind::Batched,
        SamplerKind::Srs,
        Query::Sum,
        1,
        budget,
        ckpt_every(&dir),
    )
    .run_items(&items)
    .unwrap();
    let store = CheckpointStore::open(&dir).unwrap();
    let epochs = store.epochs().unwrap();
    assert!(!epochs.is_empty());
    for &e in &epochs {
        let p = store.epoch_path(e);
        let mut bytes = std::fs::read(&p).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF; // breaks the FNV-1a checksum
        std::fs::write(&p, bytes).unwrap();
    }
    let err = build(
        &svc,
        EngineKind::Batched,
        SamplerKind::Srs,
        Query::Sum,
        1,
        budget,
        restore_from(&dir),
    )
    .run_items(&items)
    .unwrap_err();
    assert!(
        err.to_string().contains("checksum mismatch"),
        "want the torn-write diagnosis, got: {err}"
    );
}

// ---------------------------------------------------------------------------
// version / fingerprint / budget mismatch rejection
// ---------------------------------------------------------------------------

/// A snapshot from a different codec version, a different pipeline
/// configuration, or a different budget family is rejected with a
/// descriptive error — never silently reinterpreted.
#[test]
fn mismatched_snapshots_are_rejected_with_descriptive_errors() {
    let svc = ComputeService::native();
    let items = sorted_trace(200.0, 79, 3_000);
    let budget = QueryBudget::SamplingFraction(0.4);
    let dir = tmp_dir("mismatch");
    build(
        &svc,
        EngineKind::Batched,
        SamplerKind::Oasrs,
        Query::Sum,
        1,
        budget,
        ckpt_every(&dir),
    )
    .run_items(&items)
    .unwrap();

    // Different seed → fingerprint check names the diverging field.
    let err = PipelineBuilder::new()
        .engine(EngineKind::Batched)
        .sampler(SamplerKind::Oasrs)
        .budget(budget)
        .query(Query::Sum)
        .window(WindowConfig::new(2_000, 1_000))
        .batch_interval_ms(500)
        .seed(9999)
        .durability(restore_from(&dir))
        .build_with_handle(svc.handle())
        .run_items(&items)
        .unwrap_err();
    assert!(
        err.to_string().contains("different configuration"),
        "want fingerprint rejection, got: {err}"
    );

    // Different budget family → discriminant check.
    let err = build(
        &svc,
        EngineKind::Batched,
        SamplerKind::Oasrs,
        Query::Sum,
        1,
        QueryBudget::SampleSizePerInterval(64),
        restore_from(&dir),
    )
    .run_items(&items)
    .unwrap_err();
    assert!(err.to_string().contains("budget"), "want budget rejection, got: {err}");

    // Future codec version → version check (bytes 4..6 are the LE version
    // in the frame header).
    let store = CheckpointStore::open(&dir).unwrap();
    let newest = *store.epochs().unwrap().last().unwrap();
    let p = store.epoch_path(newest);
    let mut bytes = std::fs::read(&p).unwrap();
    bytes[4] = 0x63; // v99
    bytes[5] = 0x00;
    std::fs::write(&p, bytes).unwrap();
    let err = store.read_epoch(newest).unwrap_err();
    assert!(
        err.to_string().contains("version mismatch"),
        "want version rejection, got: {err}"
    );

    // An empty directory has nothing to restore.
    let empty = tmp_dir("mismatch_empty");
    std::fs::create_dir_all(&empty).unwrap();
    let err = build(
        &svc,
        EngineKind::Batched,
        SamplerKind::Oasrs,
        Query::Sum,
        1,
        budget,
        restore_from(&empty),
    )
    .run_items(&items)
    .unwrap_err();
    assert!(
        err.to_string().contains("no snapshot"),
        "want empty-store rejection, got: {err}"
    );
}

/// Restore-on-start without a checkpoint directory is a config error at
/// the facade, before any engine work happens.
#[test]
fn restore_without_checkpoint_dir_is_rejected() {
    let svc = ComputeService::native();
    let err = build(
        &svc,
        EngineKind::Batched,
        SamplerKind::Oasrs,
        Query::Sum,
        1,
        QueryBudget::SamplingFraction(0.4),
        DurabilityOptions::default().restore_on_start(true),
    )
    .run_items(&sorted_trace(100.0, 83, 1_000))
    .unwrap_err();
    assert!(err.to_string().contains("checkpoint directory"), "got: {err}");
}
