//! Integration tests for the XLA-backed runtime: loads the real AOT
//! artifacts, executes them on the PJRT CPU client, and cross-checks the
//! in-graph estimates against the Rust estimator (the L2 graph and
//! `error::estimator` implement the same Eq. 1-9 arithmetic).
//!
//! Skips (with a note) when `artifacts/` has not been built — run
//! `make artifacts` first.

use streamapprox::core::MAX_STRATA;
use streamapprox::error::estimator::{estimate, StrataState, K};
use streamapprox::runtime::{
    default_artifacts_dir, Backend, ComputeService, Manifest, RustExecutor, WindowInput,
    XlaEngine,
};

fn artifacts_available() -> bool {
    // needs both the compiled-in PJRT engine (`--features xla`) and the
    // AOT artifacts on disk (`make artifacts`)
    cfg!(feature = "xla") && default_artifacts_dir().join("manifest.json").exists()
}

fn test_input(n: usize, seed: u64) -> WindowInput {
    use streamapprox::util::rng::Rng;
    let mut rng = Rng::seed_from_u64(seed);
    let mut input = WindowInput::default();
    for _ in 0..n {
        input.ids.push(rng.range_usize(0, MAX_STRATA) as i32);
        input.values.push(rng.range_f64(-50.0, 150.0) as f32);
    }
    for i in 0..K {
        let selected = input.ids.iter().filter(|&&x| x == i as i32).count() as f64;
        input.c[i] = selected * 3.0 + 10.0;
        input.n_cap[i] = 64.0;
    }
    input
}

#[test]
fn xla_engine_loads_and_reports_platform() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let m = Manifest::load(default_artifacts_dir()).unwrap();
    let engine = XlaEngine::load(&m).unwrap();
    assert!(engine.platform().to_lowercase().contains("cpu"));
    assert!(engine.max_capacity() >= 16384);
}

#[test]
fn xla_matches_rust_executor_small() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let m = Manifest::load(default_artifacts_dir()).unwrap();
    let engine = XlaEngine::load(&m).unwrap();
    for seed in 0..5 {
        let input = test_input(800, seed);
        let xla_out = engine.aggregate(&input).unwrap();
        let rust_out = RustExecutor.aggregate(&input);
        assert_eq!(xla_out.executions, 1);
        for i in 0..K {
            assert!(
                (xla_out.partials.y[i] - rust_out.partials.y[i]).abs() < 1e-3,
                "y[{i}] {} vs {}",
                xla_out.partials.y[i],
                rust_out.partials.y[i]
            );
            let rel = (xla_out.partials.sum[i] - rust_out.partials.sum[i]).abs()
                / rust_out.partials.sum[i].abs().max(1.0);
            assert!(rel < 1e-4, "sum[{i}] rel err {rel}");
        }
        let rel_sum = (xla_out.estimate.sum - rust_out.estimate.sum).abs()
            / rust_out.estimate.sum.abs().max(1.0);
        assert!(rel_sum < 1e-4);
        let rel_var = (xla_out.estimate.var_sum - rust_out.estimate.var_sum).abs()
            / rust_out.estimate.var_sum.abs().max(1.0);
        assert!(rel_var < 1e-3, "var rel err {rel_var}");
    }
}

#[test]
fn xla_in_graph_estimate_matches_rust_estimator_arithmetic() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let m = Manifest::load(default_artifacts_dir()).unwrap();
    let engine = XlaEngine::load(&m).unwrap();
    let input = test_input(1000, 99);
    let out = engine.aggregate(&input).unwrap();
    // Finish the estimate Rust-side from the XLA partials; must agree with
    // the in-graph epilogue.
    let st = StrataState { c: input.c, n_cap: input.n_cap };
    let rust_est = estimate(&out.partials, &st);
    assert!((out.estimate.sum - rust_est.sum).abs() / rust_est.sum.abs().max(1.0) < 1e-4);
    assert!((out.estimate.mean - rust_est.mean).abs() / rust_est.mean.abs().max(1e-9) < 1e-4);
    for i in 0..K {
        assert!((out.estimate.weights[i] - rust_est.weights[i]).abs() < 1e-4);
    }
}

#[test]
fn chunked_window_combines_partials() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let m = Manifest::load(default_artifacts_dir()).unwrap();
    let engine = XlaEngine::load(&m).unwrap();
    let max = engine.max_capacity();
    let input = test_input(max + 1000, 5);
    let out = engine.aggregate(&input).unwrap();
    assert_eq!(out.executions, 2);
    let rust_out = RustExecutor.aggregate(&input);
    let rel = (out.estimate.sum - rust_out.estimate.sum).abs()
        / rust_out.estimate.sum.abs().max(1.0);
    assert!(rel < 1e-3, "chunked sum rel err {rel}");
    assert!((out.partials.total_y() - (max + 1000) as f64).abs() < 1e-3);
}

#[test]
fn variant_selection_pads_correctly() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let m = Manifest::load(default_artifacts_dir()).unwrap();
    let engine = XlaEngine::load(&m).unwrap();
    // Tiny input on the smallest variant: padding must not pollute results.
    let mut input = WindowInput::default();
    input.ids = vec![0, 1];
    input.values = vec![10.0, 20.0];
    input.c[0] = 1.0;
    input.c[1] = 1.0;
    input.n_cap = [8.0; K];
    let out = engine.aggregate(&input).unwrap();
    assert_eq!(out.partials.y[0], 1.0);
    assert_eq!(out.partials.y[1], 1.0);
    assert_eq!(out.partials.total_y(), 2.0);
    assert!((out.estimate.sum - 30.0).abs() < 1e-3);
}

#[test]
fn compute_service_xla_backend() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let svc = ComputeService::start(Backend::Xla, Some(default_artifacts_dir())).unwrap();
    let h = svc.handle();
    let out = h.aggregate(test_input(500, 3)).unwrap();
    assert!((out.partials.total_y() - 500.0).abs() < 1e-3);

    // handles usable from multiple threads
    let mut joins = Vec::new();
    for t in 0..4 {
        let h = svc.handle();
        joins.push(std::thread::spawn(move || {
            let out = h.aggregate(test_input(300, t)).unwrap();
            assert!(out.estimate.sum.is_finite());
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
}
