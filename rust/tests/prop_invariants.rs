//! Property-based tests over the coordinator's core invariants.  The
//! offline build has no proptest, so this uses a small in-tree harness:
//! each property runs over many seeded random cases and reports the first
//! failing seed (deterministically reproducible).

use streamapprox::core::{Item, MAX_STRATA};
use streamapprox::error::estimator::{estimate, StrataPartials, StrataState, K};
use streamapprox::sampling::oasrs::merge_worker_results;
use streamapprox::sampling::{
    make_sampler, OasrsSampler, Reservoir, SampleResult, Sampler, SamplerKind,
};
use streamapprox::sketch::{HeavyHitters, HyperLogLog, QuantileSketch};
use streamapprox::util::rng::Rng;
use streamapprox::window::{ExactAgg, Mergeable, PaneStore};

/// Mini property harness: run `prop` for `cases` seeds; panic with the seed
/// on the first failure.
fn check(cases: u64, prop: impl Fn(&mut Rng) -> Result<(), String>) {
    for seed in 0..cases {
        let mut rng = Rng::seed_from_u64(seed * 0x9E3779B9 + 1);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

fn random_items(rng: &mut Rng, n: usize, strata: usize) -> Vec<Item> {
    (0..n)
        .map(|i| {
            Item::new(
                rng.range_usize(0, strata) as u16,
                rng.normal(100.0, 30.0),
                i as u64,
            )
        })
        .collect()
}

#[test]
fn prop_reservoir_size_and_membership() {
    check(50, |rng| {
        let cap = rng.range_usize(1, 64);
        let n = rng.range_usize(0, 2000);
        let mut r = Reservoir::new(cap, rng.next_u64());
        for i in 0..n {
            r.offer(i as u32);
        }
        if r.len() != cap.min(n) {
            return Err(format!("len {} != min(cap {cap}, n {n})", r.len()));
        }
        // membership + uniqueness
        let mut seen = std::collections::HashSet::new();
        for &x in r.items() {
            if x as usize >= n {
                return Err(format!("item {x} not from input"));
            }
            if !seen.insert(x) {
                return Err(format!("duplicate item {x}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_oasrs_weight_law_eq1() {
    // For every stratum: W_i == max(C_i / N_i, 1) exactly (Eq. 1).
    check(40, |rng| {
        let mut s = OasrsSampler::new(rng.range_f64(0.05, 0.95), rng.next_u64());
        let strata = rng.range_usize(1, 6);
        let n = rng.range_usize(10, 3000);
        let items = random_items(rng, n, strata);
        // two intervals so capacities adapt
        for it in &items {
            s.offer(it);
        }
        s.finish_interval();
        for it in &items {
            s.offer(it);
        }
        let r = s.finish_interval();
        let est = estimate(&StrataPartials::from_sample(&r.sample), &r.state);
        for i in 0..K {
            let c = r.state.c[i];
            let n = r.state.n_cap[i];
            let expect = if c > n { c / n.max(1.0) } else { 1.0 };
            if (est.weights[i] - expect).abs() > 1e-9 {
                return Err(format!("stratum {i}: W {} != {expect}", est.weights[i]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_oasrs_sample_counts_bounded_by_cap_and_arrivals() {
    check(40, |rng| {
        let mut s = OasrsSampler::new(rng.range_f64(0.05, 0.95), rng.next_u64());
        let strata = rng.range_usize(1, 8);
        let n = rng.range_usize(1, 5000);
        let items = random_items(rng, n, strata);
        for it in &items {
            s.offer(it);
        }
        let r = s.finish_interval();
        for i in 0..K {
            let selected = r.sample.iter().filter(|(st, _)| *st as usize == i).count() as f64;
            if selected > r.state.n_cap[i] {
                return Err(format!("stratum {i}: selected {selected} > cap {}", r.state.n_cap[i]));
            }
            if selected > r.state.c[i] {
                return Err(format!("stratum {i}: selected {selected} > arrived {}", r.state.c[i]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_merge_is_associative_and_commutative() {
    check(30, |rng| {
        let mk = |rng: &mut Rng| {
            let mut r = SampleResult::default();
            for _ in 0..rng.range_usize(0, 50) {
                let s = rng.range_usize(0, MAX_STRATA);
                r.sample.push((s as u16, rng.normal(0.0, 1.0)));
            }
            for i in 0..MAX_STRATA {
                r.state.c[i] = rng.range_f64(0.0, 100.0);
                r.state.n_cap[i] = rng.range_f64(0.0, 50.0);
            }
            r
        };
        let (a, b, c) = (mk(rng), mk(rng), mk(rng));
        let left = merge_worker_results(vec![
            merge_worker_results(vec![a.clone(), b.clone()]),
            c.clone(),
        ]);
        let right = merge_worker_results(vec![
            a.clone(),
            merge_worker_results(vec![b.clone(), c.clone()]),
        ]);
        let both = merge_worker_results(vec![c, b, a]);
        for (x, tag) in [(&right, "assoc"), (&both, "comm")] {
            for i in 0..MAX_STRATA {
                if (left.state.c[i] - x.state.c[i]).abs() > 1e-9
                    || (left.state.n_cap[i] - x.state.n_cap[i]).abs() > 1e-9
                {
                    return Err(format!("{tag}: state mismatch at stratum {i}"));
                }
            }
            if left.sample.len() != x.sample.len() {
                return Err(format!("{tag}: sample count mismatch"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_estimator_exact_when_fully_sampled() {
    // If n_cap >= c for every stratum and the sample holds all items, the
    // estimate equals the exact sum and variance is 0.
    check(40, |rng| {
        let strata = rng.range_usize(1, 8);
        let n = rng.range_usize(1, 1000);
        let items = random_items(rng, n, strata);
        let mut partials = StrataPartials::default();
        let mut state = StrataState::default();
        let mut exact = 0.0;
        for it in &items {
            partials.push(it.stratum as usize, it.value);
            state.c[it.stratum as usize] += 1.0;
            exact += it.value;
        }
        state.n_cap = [1e18; K];
        let est = estimate(&partials, &state);
        if (est.sum - exact).abs() > 1e-6 * exact.abs().max(1.0) {
            return Err(format!("sum {} != exact {exact}", est.sum));
        }
        if est.var_sum != 0.0 {
            return Err(format!("variance {} != 0", est.var_sum));
        }
        Ok(())
    });
}

#[test]
fn prop_estimator_unbiased_under_srs_subsampling() {
    // Estimate averaged over many random subsamples approaches the exact
    // sum (unbiasedness of the Horvitz-Thompson estimator).
    let mut rng = Rng::seed_from_u64(7);
    let items = random_items(&mut rng, 2000, 3);
    let exact: f64 = items.iter().map(|i| i.value).sum();
    let trials = 300;
    let mut sum_est = 0.0;
    for t in 0..trials {
        let mut s = make_sampler(SamplerKind::Srs, 0.2, t as u64);
        for it in &items {
            s.offer(it);
        }
        let r = s.finish_interval();
        let est = estimate(&StrataPartials::from_sample(&r.sample), &r.state);
        sum_est += est.sum;
    }
    let mean_est = sum_est / trials as f64;
    let rel = (mean_est - exact).abs() / exact.abs();
    assert!(rel < 0.01, "bias {rel}");
}

#[test]
fn prop_all_samplers_conserve_arrival_counts() {
    check(24, |rng| {
        for kind in [
            SamplerKind::Oasrs,
            SamplerKind::Srs,
            SamplerKind::Sts,
            SamplerKind::WeightedRes,
            SamplerKind::None,
        ] {
            let mut s = make_sampler(kind, rng.range_f64(0.05, 1.0), rng.next_u64());
            let strata = rng.range_usize(1, 8);
            let n = rng.range_usize(0, 2000);
            let items = random_items(rng, n, strata);
            for it in &items {
                s.offer(it);
            }
            let r = s.finish_interval();
            if (r.arrived() - n as f64).abs() > 1e-9 {
                return Err(format!("{kind:?}: arrived {} != {n}", r.arrived()));
            }
            if r.sample.len() > n {
                return Err(format!("{kind:?}: sample larger than input"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sample_values_come_from_input() {
    check(24, |rng| {
        for kind in [
            SamplerKind::Oasrs,
            SamplerKind::Srs,
            SamplerKind::Sts,
            SamplerKind::WeightedRes,
        ] {
            let mut s = make_sampler(kind, 0.4, rng.next_u64());
            let items = random_items(rng, 500, 4);
            let mut allowed: std::collections::HashMap<u16, Vec<f64>> = Default::default();
            for it in &items {
                allowed.entry(it.stratum).or_default().push(it.value);
                s.offer(it);
            }
            let r = s.finish_interval();
            for &(st, v) in &r.sample {
                let vals = allowed.get(&st).ok_or(format!("{kind:?}: unknown stratum"))?;
                if !vals.iter().any(|&x| x == v) {
                    return Err(format!("{kind:?}: value {v} not from stratum {st}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_confidence_interval_scales_with_variance() {
    use streamapprox::error::{ConfidenceInterval, ConfidenceLevel};
    check(40, |rng| {
        let mut partials = StrataPartials::default();
        let mut state = StrataState::default();
        for _ in 0..rng.range_usize(2, 200) {
            partials.push(0, rng.normal(50.0, 10.0));
        }
        state.c[0] = partials.y[0] * rng.range_f64(1.0, 10.0);
        state.n_cap = [partials.y[0].max(1.0); K];
        let est = estimate(&partials, &state);
        let c68 = ConfidenceInterval::for_sum(&est, ConfidenceLevel::P68).bound;
        let c95 = ConfidenceInterval::for_sum(&est, ConfidenceLevel::P95).bound;
        let c997 = ConfidenceInterval::for_sum(&est, ConfidenceLevel::P997).bound;
        if !(c68 <= c95 && c95 <= c997) {
            return Err("bounds not monotone in level".into());
        }
        if (c95 - 2.0 * c68).abs() > 1e-9 || (c997 - 3.0 * c68).abs() > 1e-9 {
            return Err("bounds not sigma-multiples".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Sketch mergeability: merge(sketch(A), sketch(B)) ≡ sketch(A ∪ B) for all
// three sketches — exactly for HLL (register max) and Count-Min (counter
// addition, up to summation rounding), within the rank guarantee for the
// quantile sketch (re-clustering is the lossy step its ε already budgets).
// ---------------------------------------------------------------------------

#[test]
fn prop_hll_merge_equals_union_exactly() {
    check(20, |rng| {
        let mut whole = HyperLogLog::new(10);
        let mut a = HyperLogLog::new(10);
        let mut b = HyperLogLog::new(10);
        let n = rng.range_usize(0, 20_000);
        for _ in 0..n {
            let k = rng.range_u64(0, 5_000);
            whole.offer_key(k);
            if rng.bernoulli(0.5) {
                a.offer_key(k);
            } else {
                b.offer_key(k);
            }
        }
        a.merge(&b);
        if a != whole {
            return Err("merged HLL registers differ from union HLL".into());
        }
        Ok(())
    });
}

#[test]
fn prop_countmin_merge_equals_union() {
    check(20, |rng| {
        let seed = rng.next_u64();
        let mut whole = HeavyHitters::new(16, 256, 4, seed);
        let mut a = HeavyHitters::new(16, 256, 4, seed);
        let mut b = HeavyHitters::new(16, 256, 4, seed);
        // skewed keys so a stable top-k exists
        let weights: Vec<f64> = (0..100).map(|i| 1.0 / (1.0 + i as f64).powf(1.5)).collect();
        let n = rng.range_usize(100, 20_000);
        for _ in 0..n {
            let k = rng.categorical(&weights) as u64;
            let w = rng.range_f64(0.5, 2.0);
            whole.offer(k, w);
            if rng.bernoulli(0.5) {
                a.offer(k, w);
            } else {
                b.offer(k, w);
            }
        }
        a.merge(&b);
        if (a.total_weight() - whole.total_weight()).abs() > 1e-6 * whole.total_weight().max(1.0) {
            return Err(format!(
                "merged weight {} != union weight {}",
                a.total_weight(),
                whole.total_weight()
            ));
        }
        // point queries agree up to summation rounding (counters are sums)
        for k in 0..20u64 {
            let (qa, qw) = (a.query(k), whole.query(k));
            if (qa - qw).abs() > 1e-6 * qw.max(1.0) {
                return Err(format!("key {k}: merged {qa} != union {qw}"));
            }
        }
        // the head of the distribution survives the merge identically
        let ta: Vec<u64> = a.top_k(3).into_iter().map(|(k, _)| k).collect();
        let tw: Vec<u64> = whole.top_k(3).into_iter().map(|(k, _)| k).collect();
        if ta != tw {
            return Err(format!("merged top-3 {ta:?} != union top-3 {tw:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_quantile_merge_within_guarantee() {
    check(20, |rng| {
        let mut whole = QuantileSketch::new(100);
        let mut a = QuantileSketch::new(100);
        let mut b = QuantileSketch::new(100);
        let n = rng.range_usize(100, 20_000);
        let mut vals = Vec::with_capacity(n);
        for _ in 0..n {
            let v = rng.normal(0.0, 100.0);
            let w = rng.range_f64(0.5, 2.0);
            vals.push((v, w));
            whole.offer(v, w);
            if rng.bernoulli(0.5) {
                a.offer(v, w);
            } else {
                b.offer(v, w);
            }
        }
        a.merge(&b);
        if (a.total_weight() - whole.total_weight()).abs() > 1e-6 * whole.total_weight() {
            return Err("merged weight differs".into());
        }
        // merged answers must agree with the directly-built sketch in rank
        // space within the combined guarantee (each side contributes ≤ ε)
        vals.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
        let total_w: f64 = vals.iter().map(|&(_, w)| w).sum();
        // tolerance: ε from each side plus the discrete-rank granularity of
        // small inputs (one max-weight item of rank mass)
        let tol = 2.0 * a.eps() + 2.0 / total_w;
        for q in [0.1, 0.5, 0.9] {
            let approx = a.quantile(q);
            let rank: f64 = vals
                .iter()
                .filter(|&&(v, _)| v <= approx)
                .map(|&(_, w)| w)
                .sum::<f64>()
                / total_w;
            if (rank - q).abs() > tol {
                return Err(format!("q={q}: merged rank {rank} beyond tolerance {tol}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_quantile_sketch_rank_guarantee_holds() {
    // Direct (unmerged) sketches honor ε on every distribution shape the
    // generators produce.
    check(20, |rng| {
        let mut s = QuantileSketch::new(64);
        let n = rng.range_usize(10, 10_000);
        let heavy_tail = rng.bernoulli(0.5);
        let mut vals = Vec::with_capacity(n);
        for _ in 0..n {
            let v = if heavy_tail { rng.log_normal(3.0, 1.5) } else { rng.normal(0.0, 10.0) };
            vals.push(v);
            s.offer(v, 1.0);
        }
        vals.sort_by(|x, y| x.partial_cmp(y).unwrap());
        // ε plus the discrete-rank granularity of one item (dominates for
        // n below the cluster count, where the sketch is actually exact)
        let tol = s.eps() + 1.0 / n as f64;
        for q in [0.25, 0.5, 0.75, 0.95] {
            let approx = s.quantile(q);
            let rank = vals.iter().filter(|&&v| v <= approx).count() as f64 / n as f64;
            if (rank - q).abs() > tol {
                return Err(format!("n={n} q={q}: rank {rank} beyond tolerance {tol}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_fuzz() {
    use streamapprox::util::json::{parse, Value};
    fn random_value(rng: &mut Rng, depth: usize) -> Value {
        match if depth == 0 { rng.range_usize(0, 4) } else { rng.range_usize(0, 6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.bernoulli(0.5)),
            2 => Value::Num((rng.range_f64(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => {
                let n = rng.range_usize(0, 12);
                Value::Str(
                    (0..n)
                        .map(|_| {
                            let c = rng.range_u64(32, 127) as u8 as char;
                            c
                        })
                        .collect(),
                )
            }
            4 => Value::Arr((0..rng.range_usize(0, 5)).map(|_| random_value(rng, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.range_usize(0, 5) {
                    m.insert(format!("k{i}"), random_value(rng, depth - 1));
                }
                Value::Obj(m)
            }
        }
    }
    check(100, |rng| {
        let v = random_value(rng, 3);
        let s = v.to_string();
        let back = parse(&s).map_err(|e| format!("parse error on {s:?}: {e}"))?;
        if back != v {
            return Err(format!("roundtrip mismatch: {s}"));
        }
        Ok(())
    });
}

#[test]
fn prop_channel_conserves_items_under_contention() {
    use streamapprox::util::channel::bounded;
    check(10, |rng| {
        let cap = rng.range_usize(1, 64);
        let producers = rng.range_usize(1, 5);
        let per = rng.range_usize(1, 500);
        let (tx, rx) = bounded::<usize>(cap);
        let total = std::thread::scope(|scope| {
            for p in 0..producers {
                let tx = tx.clone();
                scope.spawn(move || {
                    for i in 0..per {
                        tx.send(p * per + i).unwrap();
                    }
                });
            }
            drop(tx);
            let mut seen = std::collections::HashSet::new();
            while let Some(v) = rx.recv() {
                assert!(seen.insert(v), "duplicate {v}");
            }
            seen.len()
        });
        if total != producers * per {
            return Err(format!("got {total} != {}", producers * per));
        }
        Ok(())
    });
}

// --- Mergeable trait laws (window/mergeable.rs) ------------------------
//
// Exactness is payload-specific and stated per test: sample concatenation
// and integral counters are bit-exactly associative; f64 *value* sums are
// associative only up to rounding (bit-exact on integral values);
// commutativity of per-component f64 addition is always bit-exact, but
// sample concatenation is order-sensitive by design.

/// Random interval result: integral arrival/capacity counters (the real
/// samplers produce integral counts; SRS's fractional capacities are
/// covered by the window-level equivalence tests, which fold in ring
/// order), float or integral sample values by choice.
fn random_sample_result(rng: &mut Rng, integral_values: bool) -> SampleResult {
    let mut r = SampleResult::default();
    for s in 0..4u16 {
        let arrived = rng.range_usize(0, 40);
        let selected = rng.range_usize(0, arrived + 1);
        r.state.c[s as usize] = arrived as f64;
        r.state.n_cap[s as usize] = selected as f64;
        for _ in 0..selected {
            let v = if integral_values {
                rng.range_usize(0, 1000) as f64
            } else {
                rng.normal(100.0, 30.0)
            };
            r.sample.push((s, v));
        }
    }
    r
}

#[test]
fn prop_mergeable_sample_result_associative_bitexact() {
    // (a·b)·c == a·(b·c) bit-for-bit: concatenation is exactly associative
    // and the counters are integral, so addition is exact.  Values are
    // arbitrary floats — they are only ever concatenated.
    check(50, |rng| {
        let a = random_sample_result(rng, false);
        let b = random_sample_result(rng, false);
        let c = random_sample_result(rng, false);
        let mut left = a.clone();
        left.merge_from(&b);
        left.merge_from(&c);
        let mut bc = b.clone();
        bc.merge_from(&c);
        let mut right = a.clone();
        right.merge_from(&bc);
        if left.sample != right.sample {
            return Err("sample association diverged".into());
        }
        if left.state != right.state {
            return Err("state association diverged".into());
        }
        // and the fold through merge_worker_results agrees
        let fold = merge_worker_results(vec![a, b, c]);
        if fold.sample != left.sample || fold.state != left.state {
            return Err("merge_worker_results fold diverged".into());
        }
        Ok(())
    });
}

#[test]
fn prop_mergeable_sample_result_commutes_up_to_permutation() {
    // a·b and b·a hold the same multiset of samples and bit-identical
    // counters (f64 addition commutes exactly); the *order* differs, which
    // is why commutativity is not part of the Mergeable contract.
    check(50, |rng| {
        let a = random_sample_result(rng, false);
        let b = random_sample_result(rng, false);
        let mut ab = a.clone();
        ab.merge_from(&b);
        let mut ba = b.clone();
        ba.merge_from(&a);
        if ab.state != ba.state {
            return Err("counter addition failed to commute bitwise".into());
        }
        let canon = |r: &SampleResult| {
            let mut v: Vec<(u16, u64)> =
                r.sample.iter().map(|&(s, x)| (s, x.to_bits())).collect();
            v.sort_unstable();
            v
        };
        if canon(&ab) != canon(&ba) {
            return Err("sample multisets diverged".into());
        }
        Ok(())
    });
}

#[test]
fn prop_mergeable_exact_agg_laws() {
    check(50, |rng| {
        let mk_float = |rng: &mut Rng| {
            let mut e = ExactAgg::default();
            for _ in 0..rng.range_usize(0, 60) {
                e.add(rng.range_usize(0, 5) as u16, rng.normal(50.0, 20.0));
            }
            e
        };
        // commutativity is bit-exact even for float sums
        let a = mk_float(rng);
        let b = mk_float(rng);
        let mut ab = a;
        ab.merge_from(&b);
        let mut ba = b;
        ba.merge_from(&a);
        if ab != ba {
            return Err("ExactAgg merge failed to commute bitwise".into());
        }
        // associativity is bit-exact on integral values…
        let mk_int = |rng: &mut Rng| {
            let mut e = ExactAgg::default();
            for _ in 0..rng.range_usize(0, 60) {
                e.add(rng.range_usize(0, 5) as u16, rng.range_usize(0, 1000) as f64);
            }
            e
        };
        let (x, y, z) = (mk_int(rng), mk_int(rng), mk_int(rng));
        let mut left = x;
        left.merge_from(&y);
        left.merge_from(&z);
        let mut yz = y;
        yz.merge_from(&z);
        let mut right = x;
        right.merge_from(&yz);
        if left != right {
            return Err("ExactAgg integral association diverged".into());
        }
        // …and up to rounding on floats
        let (x, y, z) = (mk_float(rng), mk_float(rng), mk_float(rng));
        let mut left = x;
        left.merge_from(&y);
        left.merge_from(&z);
        let mut yz = y;
        yz.merge_from(&z);
        let mut right = x;
        right.merge_from(&yz);
        for s in 0..MAX_STRATA {
            let (l, r) = (left.sum[s], right.sum[s]);
            if (l - r).abs() > 1e-9 * (1.0 + l.abs()) {
                return Err(format!("float association off beyond rounding: {l} vs {r}"));
            }
            if left.count[s] != right.count[s] {
                return Err("counts are integral and must associate exactly".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mergeable_hll_assoc_and_commut_bitexact() {
    // Register-wise max is exactly associative AND commutative.
    check(30, |rng| {
        let mk = |rng: &mut Rng| {
            let mut h = HyperLogLog::new(8);
            for _ in 0..rng.range_usize(0, 500) {
                h.offer_key(rng.range_u64(0, 10_000));
            }
            h
        };
        let (a, b, c) = (mk(rng), mk(rng), mk(rng));
        let mut left = a.clone();
        left.merge_from(&b);
        left.merge_from(&c);
        let mut bc = b.clone();
        bc.merge_from(&c);
        let mut right = a.clone();
        right.merge_from(&bc);
        if left != right {
            return Err("HLL association diverged".into());
        }
        let mut ab = a.clone();
        ab.merge_from(&b);
        let mut ba = b.clone();
        ba.merge_from(&a);
        if ab != ba {
            return Err("HLL merge failed to commute".into());
        }
        Ok(())
    });
}

#[test]
fn prop_mergeable_heavy_hitters_grouping_invariant() {
    // With integral weights and capacity above the key-domain size, the
    // Count-Min counters and the rescored candidate set are identical
    // under any merge grouping or order.
    check(30, |rng| {
        let mk = |rng: &mut Rng| {
            let mut h = HeavyHitters::new(64, 128, 3, 0xBEEF);
            for _ in 0..rng.range_usize(0, 300) {
                h.offer(rng.range_u64(0, 16), rng.range_usize(1, 5) as f64);
            }
            h
        };
        let (a, b, c) = (mk(rng), mk(rng), mk(rng));
        let mut left = a.clone();
        left.merge_from(&b);
        left.merge_from(&c);
        let mut bc = b.clone();
        bc.merge_from(&c);
        let mut right = a.clone();
        right.merge_from(&bc);
        if left.top_k(16) != right.top_k(16) {
            return Err("heavy-hitters association diverged".into());
        }
        let mut ab = a.clone();
        ab.merge_from(&b);
        let mut ba = b.clone();
        ba.merge_from(&a);
        if ab.top_k(16) != ba.top_k(16) {
            return Err("heavy-hitters merge failed to commute".into());
        }
        Ok(())
    });
}

#[test]
fn prop_mergeable_quantile_grouping_within_guarantee() {
    // Quantile sketches re-cluster on merge, so grouping changes answers
    // only within the rank-ε guarantee — the law is approximate by design.
    check(20, |rng| {
        let mut parts: Vec<QuantileSketch> = Vec::new();
        let mut all: Vec<f64> = Vec::new();
        for _ in 0..4 {
            let mut sk = QuantileSketch::new(100); // ε = 0.02
            for _ in 0..rng.range_usize(50, 400) {
                let v = rng.normal(100.0, 30.0);
                sk.offer(v, 1.0);
                all.push(v);
            }
            parts.push(sk);
        }
        let mut left = parts[0].clone();
        for p in &parts[1..] {
            left.merge_from(p);
        }
        let mut right = parts[3].clone();
        for p in parts[..3].iter().rev() {
            let mut q = p.clone();
            q.merge_from(&right);
            right = q;
        }
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for &q in &[0.1, 0.5, 0.9] {
            for sk in [&left, &right] {
                let v = sk.quantile(q);
                let rank = all.iter().filter(|&&x| x <= v).count() as f64 / all.len() as f64;
                if (rank - q).abs() > 2.0 * sk.eps() + 0.01 {
                    return Err(format!("q={q}: rank {rank} beyond guarantee"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pane_store_equals_merge_all_ring() {
    // The two-stacks pane store must agree byte-for-byte with the seed's
    // merge-every-pane-per-slide fold over the same sliding ring, at every
    // ring size and step (integral counters ⇒ every addition is exact;
    // samples only concatenate).
    check(25, |rng| {
        let cap = rng.range_usize(1, 12);
        let mut store: PaneStore<SampleResult> = PaneStore::new(cap);
        let mut ring: std::collections::VecDeque<SampleResult> = Default::default();
        let steps = rng.range_usize(cap.max(2), 40);
        for _ in 0..steps {
            let pane = random_sample_result(rng, false);
            ring.push_back(pane.clone());
            if ring.len() > cap {
                ring.pop_front();
            }
            store.push(pane);
            let want = merge_worker_results(ring.iter().cloned().collect());
            let got = store.aggregate().expect("non-empty store");
            if got.sample != want.sample {
                return Err(format!("sample diverged at ring size {}", ring.len()));
            }
            if got.state != want.state {
                return Err(format!("state diverged at ring size {}", ring.len()));
            }
        }
        // merge-op accounting: amortized ≤ 2 structural merges per push
        if store.merge_ops() > 2 * steps as u64 {
            return Err(format!("{} merges for {steps} pushes", store.merge_ops()));
        }
        Ok(())
    });
}
