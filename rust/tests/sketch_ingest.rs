//! Acceptance tests for the streaming sketch ingest path (ISSUE 5):
//!
//! * sketch queries over a ratio-64 sliding window perform **zero
//!   query-time sketch builds** — pane sketches arrive pre-built from the
//!   ingest workers (witnessed by `RunReport::sketch_ingest` /
//!   `QueryExecutor::query_time_sketch_builds`);
//! * for single-worker runs the worker-built pane sketch is
//!   **byte-identical** to the rebuild-per-query path's sketch;
//! * multi-worker partials merge to sketches whose per-stratum mass
//!   matches the merged arrival counters exactly;
//! * sample-deque spill past the configured ratio changes no sketch
//!   answer (the panes carry the query; the samples had no reader).

use streamapprox::budget::QueryBudget;
use streamapprox::core::Item;
use streamapprox::engine::{EngineKind, IngestPool};
use streamapprox::prelude::*;
use streamapprox::query::sketch_spec_for;
use streamapprox::util::rng::Rng;

/// Ratio-64 sliding window: 16 s window, 250 ms slide.
fn ratio_64() -> WindowConfig {
    WindowConfig::new(16_000, 250)
}

#[test]
fn ratio_64_sketch_queries_build_nothing_at_query_time() {
    let stream = StreamConfig::gaussian_micro(500.0, 31);
    for engine in [EngineKind::Pipelined, EngineKind::Batched] {
        for query in [Query::Quantile(0.9), Query::Distinct, Query::TopK(3)] {
            let p = PipelineBuilder::new()
                .engine(engine)
                .sampler(SamplerKind::Oasrs)
                .budget(QueryBudget::SamplingFraction(0.4))
                .query(query.clone())
                .window(ratio_64())
                .seed(5)
                .build_native();
            let r = p.run_stream(&stream, 24_000).unwrap();
            assert!(r.windows.len() >= 32, "{engine:?}/{query:?}: {} windows", r.windows.len());
            let stats = r.sketch_ingest.expect("sketch run must report provenance");
            assert!(
                stats.prebuilt_panes >= 64,
                "{engine:?}/{query:?}: only {} pre-built panes",
                stats.prebuilt_panes
            );
            assert_eq!(
                stats.rebuilt_panes, 0,
                "{engine:?}/{query:?}: panes were rebuilt at the window operator"
            );
            assert_eq!(
                stats.query_time_builds, 0,
                "{engine:?}/{query:?}: sketches were built at query time"
            );
        }
    }
}

#[test]
fn linear_queries_report_no_sketch_provenance() {
    let p = PipelineBuilder::new()
        .query(Query::Sum)
        .window(WindowConfig::new(2_000, 1_000))
        .build_native();
    let r = p.run_stream(&StreamConfig::gaussian_micro(200.0, 7), 4_000).unwrap();
    assert!(r.sketch_ingest.is_none());
}

#[test]
fn single_worker_prebuilt_equals_rebuild_byte_for_byte() {
    // The tentpole's byte-identity acceptance gate: one worker, same seed —
    // the pool's worker-built pane sketch must equal the rebuild from the
    // merged interval result bit-for-bit, for every sketch family, across
    // several intervals and a mid-stream fraction change.
    let specs = [
        sketch_spec_for(&Query::Quantile(0.5), SketchParams::default()).unwrap(),
        sketch_spec_for(&Query::Distinct, SketchParams::default()).unwrap(),
        sketch_spec_for(&Query::TopK(4), SketchParams::default()).unwrap(),
    ];
    for kind in [SamplerKind::Oasrs, SamplerKind::Srs, SamplerKind::Sts, SamplerKind::None] {
        let mut registered = IngestPool::new(kind, 1, 0.5, 77);
        let mut plain = IngestPool::new(kind, 1, 0.5, 77);
        registered.register_sketches(&specs);
        let mut rng = Rng::seed_from_u64(99);
        for interval in 0..4u64 {
            if interval == 2 {
                registered.set_fraction(0.2);
                plain.set_fraction(0.2);
            }
            for i in 0..4_000u64 {
                let it = Item::new(
                    (i % 5) as u16,
                    rng.normal(100.0, 25.0),
                    interval * 4_000 + i,
                );
                registered.offer(it);
                plain.offer(it);
            }
            let (ra, built) = registered.finish_interval_with_sketches();
            let rb = plain.finish_interval();
            assert_eq!(ra.sample, rb.sample, "{kind:?}: registration perturbed sampling");
            assert_eq!(ra.state, rb.state, "{kind:?}");
            assert_eq!(built.len(), specs.len(), "{kind:?}");
            for (spec, pane) in specs.iter().zip(&built) {
                assert_eq!(
                    *pane,
                    spec.build(&rb),
                    "{kind:?}: worker-built pane sketch != query-side rebuild"
                );
            }
        }
    }
}

#[test]
fn multi_worker_partials_carry_exact_stratum_mass() {
    // Worker partials weight by worker-local counters; for count-based
    // samplers Σ(HT weights of a stratum's sample) = C_i exactly, so the
    // merged sketch's per-stratum mass must match the merged counters to
    // rounding — the cross-worker consistency gate.
    let spec = sketch_spec_for(&Query::TopK(8), SketchParams::default()).unwrap();
    let mut pool = IngestPool::new(SamplerKind::Oasrs, 4, 0.25, 13);
    pool.register_sketches(&[spec]);
    let mut rng = Rng::seed_from_u64(14);
    // warm-up interval sizes the OASRS reservoirs
    for i in 0..40_000u64 {
        pool.offer(Item::new((i % 6) as u16, rng.f64(), i));
    }
    pool.finish_interval();
    for i in 0..40_000u64 {
        pool.offer(Item::new((i % 6) as u16, rng.f64(), 40_000 + i));
    }
    let (r, sketches) = pool.finish_interval_with_sketches();
    assert_eq!(sketches.len(), 1);
    match &sketches[0] {
        PaneSketch::TopK(hh) => {
            let arrived = r.arrived();
            assert!((hh.total_weight() - arrived).abs() <= 1e-6 * arrived);
            for (key, count) in hh.top_k(6) {
                let c = r.state.c[key as usize];
                assert!(
                    (count - c).abs() <= 1e-6 * c.max(1.0),
                    "stratum {key}: sketch mass {count} vs merged counter {c}"
                );
            }
        }
        other => panic!("wrong pane kind: {other:?}"),
    }
}

#[test]
fn spill_changes_no_sketch_answer() {
    // Always-spill vs never-spill over the same seeded stream: sketch
    // results, window spans, and sampled counts must be identical — the
    // spilled sample deque had no reader on the sketch path.
    let stream = StreamConfig::gaussian_micro(400.0, 23);
    let run = |spill_ratio: usize| {
        let p = PipelineBuilder::new()
            .engine(EngineKind::Pipelined)
            .sampler(SamplerKind::Oasrs)
            .budget(QueryBudget::SamplingFraction(0.5))
            .query(Query::Quantile(0.95))
            .window(WindowConfig::new(8_000, 500)) // ratio 16
            .sample_spill_ratio(spill_ratio)
            .seed(3)
            .build_native();
        p.run_stream(&stream, 16_000).unwrap()
    };
    let spilled = run(1); // ratio 16 >= 1 -> spills
    let kept = run(usize::MAX); // never spills
    assert_eq!(spilled.windows.len(), kept.windows.len());
    assert!(spilled.windows.len() >= 16);
    for (a, b) in spilled.windows.iter().zip(kept.windows.iter()) {
        assert_eq!(a.end_ms, b.end_ms);
        assert_eq!(a.sampled, b.sampled, "spill lost the sampled count");
        assert_eq!(a.arrived, b.arrived);
        assert_eq!(
            a.result.value().to_bits(),
            b.result.value().to_bits(),
            "window {}..{}: spill changed the sketch answer",
            a.start_ms,
            a.end_ms
        );
    }
    let stats = spilled.sketch_ingest.unwrap();
    assert_eq!(stats.rebuilt_panes, 0);
    assert_eq!(stats.query_time_builds, 0);
}
