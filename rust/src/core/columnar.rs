//! Struct-of-arrays chunk layout for the columnar ingest path.
//!
//! The scalar data plane moves `Vec<Item>` — arrays of 24-byte structs
//! whose stratum/value/ts fields interleave in memory, so the acceptance
//! kernels touch three fields per item and nothing vectorizes.  A
//! [`ColumnarChunk`] stores the same items as three parallel columns
//! (`values`, `strata`, `ts`), which is the layout the batched kernels in
//! `sampling/` consume: a stratum-bounds scan reads only the `strata`
//! column, the acceptance sweep reads only `values`, and bulk appends are
//! three `memcpy`s.  `python/compile/kernels/` and the cfg-gated
//! `xla_engine` stub assume this same chunk format, so the Rust hot path
//! and any future AOT/XLA backend share one data plane.
//!
//! Invariant: the three columns always have equal length (checked by
//! `debug_assert!` in every mutator; [`ColumnarChunk::len`] is defined as
//! the `values` length).

use crate::core::{EventTime, Item, StratumId};

/// A batch of stream items in struct-of-arrays layout.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ColumnarChunk {
    /// Numeric payloads (what linear queries aggregate).
    pub values: Vec<f64>,
    /// Stratum ids, parallel to `values`.
    pub strata: Vec<StratumId>,
    /// Virtual event times, parallel to `values`.
    pub ts: Vec<EventTime>,
}

impl ColumnarChunk {
    /// An empty chunk with no reserved capacity.
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty chunk with `cap` slots reserved in every column.
    pub fn with_capacity(cap: usize) -> Self {
        Self {
            values: Vec::with_capacity(cap),
            strata: Vec::with_capacity(cap),
            ts: Vec::with_capacity(cap),
        }
    }

    /// Number of items in the chunk.
    pub fn len(&self) -> usize {
        debug_assert_eq!(self.values.len(), self.strata.len());
        debug_assert_eq!(self.values.len(), self.ts.len());
        self.values.len()
    }

    /// Whether the chunk holds no items.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all items, keeping the columns' capacity (the transport's
    /// recycling discipline relies on this).
    pub fn clear(&mut self) {
        self.values.clear();
        self.strata.clear();
        self.ts.clear();
    }

    /// Append one item given as loose fields.
    #[inline]
    pub fn push(&mut self, stratum: StratumId, value: f64, ts: EventTime) {
        self.values.push(value);
        self.strata.push(stratum);
        self.ts.push(ts);
    }

    /// Append one AoS item.
    #[inline]
    pub fn push_item(&mut self, item: &Item) {
        self.push(item.stratum, item.value, item.ts);
    }

    /// Build a chunk from an AoS slice (one transposition pass).
    pub fn from_items(items: &[Item]) -> Self {
        let mut chunk = Self::with_capacity(items.len());
        chunk.extend_from_items(items);
        chunk
    }

    /// Append an AoS slice (transposing into the three columns).
    pub fn extend_from_items(&mut self, items: &[Item]) {
        self.values.reserve(items.len());
        self.strata.reserve(items.len());
        self.ts.reserve(items.len());
        for item in items {
            self.values.push(item.value);
            self.strata.push(item.stratum);
            self.ts.push(item.ts);
        }
    }

    /// Append `len` items of `other` starting at `offset` — three column
    /// `memcpy`s, the transport's bulk-move primitive.
    pub fn extend_from_chunk(&mut self, other: &Self, offset: usize, len: usize) {
        let end = offset + len;
        self.values.extend_from_slice(&other.values[offset..end]);
        self.strata.extend_from_slice(&other.strata[offset..end]);
        self.ts.extend_from_slice(&other.ts[offset..end]);
    }

    /// Transpose back to AoS (the inverse of [`ColumnarChunk::from_items`];
    /// used by tests and bridge paths, not the hot loop).
    pub fn to_items(&self) -> Vec<Item> {
        let n = self.len();
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            out.push(Item::new(self.strata[i], self.values[i], self.ts[i]));
        }
        out
    }

    /// The `i`-th item, reassembled.  Bridge/test helper, not a hot-loop
    /// accessor — kernels read the columns directly.
    #[inline]
    pub fn item(&self, i: usize) -> Item {
        Item::new(self.strata[i], self.values[i], self.ts[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_items() -> Vec<Item> {
        vec![
            Item::new(0, 1.5, 10),
            Item::new(3, -2.25, 11),
            Item::new(15, 0.0, 12),
            Item::new(7, f64::MAX, 13),
        ]
    }

    #[test]
    fn round_trip_is_lossless() {
        let items = sample_items();
        let chunk = ColumnarChunk::from_items(&items);
        assert_eq!(chunk.len(), items.len());
        assert_eq!(chunk.to_items(), items);
    }

    #[test]
    fn empty_round_trip() {
        let chunk = ColumnarChunk::from_items(&[]);
        assert!(chunk.is_empty());
        assert_eq!(chunk.to_items(), Vec::<Item>::new());
    }

    #[test]
    fn push_matches_from_items() {
        let items = sample_items();
        let mut chunk = ColumnarChunk::new();
        for it in &items {
            chunk.push_item(it);
        }
        assert_eq!(chunk, ColumnarChunk::from_items(&items));
    }

    #[test]
    fn ts_round_trip_preserves_extreme_event_times() {
        // Event times are raw u64 virtual-ms: the transpose must carry the
        // full domain bit-for-bit (the event-time router's pane arithmetic
        // and watermark saturation depend on exact ts values, so a lossy
        // cast anywhere in the chunk path would corrupt pane assignment).
        let extremes = [0u64, 1, 999, 1_000, u64::MAX / 2, u64::MAX - 1, u64::MAX];
        let items: Vec<Item> = extremes
            .iter()
            .enumerate()
            .map(|(i, &ts)| Item::new(i as StratumId, -0.1 * i as f64, ts))
            .collect();
        let chunk = ColumnarChunk::from_items(&items);
        assert_eq!(chunk.ts, extremes);
        for (i, (orig, rt)) in items.iter().zip(chunk.to_items()).enumerate() {
            assert_eq!(orig.ts, rt.ts, "slot {i}");
            assert_eq!(orig.value.to_bits(), rt.value.to_bits(), "slot {i}");
            assert_eq!(orig.stratum, rt.stratum, "slot {i}");
        }
        // Chunk-to-chunk bulk moves (the transport primitive) keep ts too.
        let mut relay = ColumnarChunk::new();
        relay.extend_from_chunk(&chunk, 0, chunk.len());
        assert_eq!(relay.ts, extremes);
    }

    #[test]
    fn clear_keeps_capacity() {
        let mut chunk = ColumnarChunk::from_items(&sample_items());
        let cap = chunk.values.capacity();
        chunk.clear();
        assert!(chunk.is_empty());
        assert_eq!(chunk.values.capacity(), cap);
    }

    #[test]
    fn extend_from_chunk_copies_subrange() {
        let items = sample_items();
        let src = ColumnarChunk::from_items(&items);
        let mut dst = ColumnarChunk::new();
        dst.extend_from_chunk(&src, 1, 2);
        assert_eq!(dst.to_items(), items[1..3].to_vec());
        dst.extend_from_chunk(&src, 0, 1);
        assert_eq!(dst.len(), 3);
        assert_eq!(dst.item(2), items[0]);
    }
}
