//! Core data types shared by every layer of the coordinator.
//!
//! A *data item* is one element of the input stream; a *stratum* identifies
//! the sub-stream it arrived from (paper §2.3: the stream is stratified by
//! source).  Timestamps are simulated event-time milliseconds — the whole
//! system runs on a virtual clock so experiments are deterministic and not
//! bound to wall-clock pacing.

pub mod columnar;

pub use columnar::ColumnarChunk;

/// Identifier of a stratum (sub-stream). The AOT artifacts are compiled for
/// `MAX_STRATA` strata; higher ids are rejected at ingest.
pub type StratumId = u16;

/// Number of strata the AOT compute artifacts support. Mirrors
/// `python/compile/aot.py::NUM_STRATA`.
pub const MAX_STRATA: usize = 16;

/// Virtual event time in milliseconds since the start of the experiment.
pub type EventTime = u64;

/// One element of the input data stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Item {
    /// Sub-stream (stratum) this item belongs to.
    pub stratum: StratumId,
    /// The item's numeric payload (what linear queries aggregate).
    pub value: f64,
    /// Virtual event time at which the item entered the system.
    pub ts: EventTime,
}

impl Item {
    /// Convenience constructor.
    pub fn new(stratum: StratumId, value: f64, ts: EventTime) -> Self {
        Self { stratum, value, ts }
    }
}

/// Library-wide error type.
#[derive(Debug)]
pub enum Error {
    Xla(String),
    Artifact(String),
    Config(String),
    Stream(String),
    Query(String),
    /// Snapshot/checkpoint I/O failures (truncated files, torn writes,
    /// checksum mismatches).  Distinct from [`Error::Artifact`] so recovery
    /// can tell "the checkpoint is damaged" from "the compute artifacts are
    /// missing".
    Io(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Artifact(e.to_string())
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Xla(s) => write!(f, "xla runtime error: {s}"),
            Error::Artifact(s) => write!(f, "artifact error: {s}"),
            Error::Config(s) => write!(f, "config error: {s}"),
            Error::Stream(s) => write!(f, "stream error: {s}"),
            Error::Query(s) => write!(f, "query error: {s}"),
            Error::Io(s) => write!(f, "snapshot io error: {s}"),
        }
    }
}

impl std::error::Error for Error {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn item_constructor() {
        let it = Item::new(3, 42.5, 1000);
        assert_eq!(it.stratum, 3);
        assert_eq!(it.value, 42.5);
        assert_eq!(it.ts, 1000);
    }

    #[test]
    fn error_display() {
        let e = Error::Config("bad".into());
        assert!(e.to_string().contains("bad"));
    }
}
