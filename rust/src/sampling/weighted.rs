//! Weighted reservoir sampling without replacement — **A-ExpJ**
//! (Efraimidis & Spirakis 2006, "Weighted random sampling with a
//! reservoir"), the exponential-jumps variant of Algorithm A-Res.
//!
//! Every item gets the key `u^(1/w)` (`u` uniform in (0,1), `w` its
//! weight); the reservoir keeps the `k` largest keys, which yields a
//! without-replacement sample where selection probability grows with
//! weight.  The exponential-jump optimization skips ahead by
//! `X = ln(r)/ln(T)` of *cumulative weight* (`T` = smallest resident key)
//! instead of drawing a key per item, cutting RNG work from O(n) to
//! O(k log(n/k)) — the trick the gtars/scatrs A-ExpJ sampler uses for
//! scATAC-seq simulation streams.
//!
//! [`WeightedResSampler`] wraps per-stratum A-ExpJ reservoirs behind the
//! [`Sampler`] trait (`SamplerKind::WeightedRes`) with OASRS-style adaptive
//! capacities, using `|value|` as the item weight, so *value-weighted*
//! sub-streams are sampled proportionally to the mass they carry — a
//! *mass-focused* design: the sample concentrates on the items that
//! dominate totals and heavy-hitter rankings instead of the lightweight
//! bulk.
//!
//! **Estimator caveat — read before pairing with queries.**  The emitted
//! [`SampleResult`] carries the same `(C_i, N_i)` bookkeeping as OASRS, so
//! the downstream Eq. (1) weights treat the sample as if inclusion were
//! uniform within a stratum.  It is not: inclusion probability grows with
//! `|value|` and no `1/π` correction is applied.  Consequently
//! * **linear estimates (SUM/MEAN) are biased upward**, and
//! * **distribution estimates (`Query::Quantile`, histograms) are biased
//!   toward heavy values** — the reported median of a 99%-light/1%-heavy
//!   stratum will sit near the heavy values, regardless of the sketch's
//!   rank-ε band (which bounds sketch error, not sampling bias).
//!
//! Use this sampler where over-representing mass is the point — `TopK`
//! heavy-hitter recovery at tiny fractions, extreme-value probes (max-like
//! statistics), or mass-weighted sub-sampling for offline analysis — and
//! use OASRS/SRS for calibrated quantiles and linear aggregates.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::core::{Error, Item, Result, MAX_STRATA};
use crate::error::estimator::StrataState;
use crate::runtime::checkpoint::{Snapshot, SnapshotReader, SnapshotWriter};
use crate::util::rng::Rng;

use super::{SampleResult, Sampler, SamplerKind};

/// Default capacity for a stratum never seen before (matches OASRS).
const DEFAULT_CAP: usize = 64;
/// EWMA smoothing for per-stratum arrival estimates (matches OASRS).
const EWMA_ALPHA: f64 = 0.5;

/// Resident ordered by key — reversed so the `BinaryHeap` (a max-heap)
/// keeps the *minimum* key at the top, which is the only resident A-ExpJ
/// ever evicts.  Keys are always finite in (0, 1), so `total_cmp` is a
/// plain numeric order here.
#[derive(Debug, Clone)]
struct Keyed<T> {
    key: f64,
    item: T,
}

impl<T> PartialEq for Keyed<T> {
    fn eq(&self, other: &Self) -> bool {
        self.key.total_cmp(&other.key) == Ordering::Equal
    }
}

impl<T> Eq for Keyed<T> {}

impl<T> PartialOrd for Keyed<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Keyed<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: smallest key = greatest element = heap top
        other.key.total_cmp(&self.key)
    }
}

/// Fixed-capacity A-ExpJ weighted reservoir over copyable items.
#[derive(Debug, Clone)]
pub struct WeightedReservoir<T: Copy> {
    cap: usize,
    /// Residents as a min-key-at-top heap, so eviction is O(log cap)
    /// instead of a linear rescan per replacement.
    buf: BinaryHeap<Keyed<T>>,
    /// Cumulative weight consumed since the last accepted item.
    acc: f64,
    /// Cumulative-weight target at which the next item is processed.
    jump: f64,
    seen: u64,
    weight_seen: f64,
    rng: Rng,
}

impl<T: Copy> WeightedReservoir<T> {
    pub fn new(cap: usize, seed: u64) -> Self {
        Self {
            cap,
            buf: BinaryHeap::with_capacity(cap.min(1024)),
            acc: 0.0,
            jump: 0.0,
            seen: 0,
            weight_seen: 0.0,
            rng: Rng::seed_from_u64(seed),
        }
    }

    #[inline]
    fn unit(&mut self) -> f64 {
        // keep u strictly inside (0, 1) so ln/pow never degenerate
        self.rng.f64().clamp(1e-12, 1.0 - 1e-12)
    }

    /// Key = u^(1/w), computed in log space for numerical stability with
    /// large weights, clamped inside (0, 1).
    #[inline]
    fn fresh_key(&mut self, w: f64) -> f64 {
        let u = self.unit();
        (u.ln() / w).exp().clamp(1e-300, 1.0 - 1e-12)
    }

    /// Smallest resident key T — the A-ExpJ threshold.
    fn threshold(&self) -> f64 {
        self.buf.peek().expect("non-empty reservoir").key
    }

    /// Exponential jump: how much cumulative weight to skip before the next
    /// candidate (X = ln(r)/ln(T); both logs negative, quotient positive).
    fn schedule_jump(&mut self) {
        let t = self.threshold();
        let r = self.unit();
        self.acc = 0.0;
        self.jump = r.ln() / t.ln();
    }

    /// Offer one item with weight `w > 0` (others ignored).
    pub fn offer(&mut self, item: T, w: f64) {
        if !(w > 0.0) || !w.is_finite() || self.cap == 0 {
            return;
        }
        self.seen += 1;
        self.weight_seen += w;

        if self.buf.len() < self.cap {
            let key = self.fresh_key(w);
            self.buf.push(Keyed { key, item });
            if self.buf.len() == self.cap {
                self.schedule_jump();
            }
            return;
        }

        self.acc += w;
        if self.acc < self.jump {
            return; // skipped without an RNG draw — the ExpJ fast path
        }

        // Replacement draw conditioned on beating the threshold: the new key
        // is uniform on (T^w, 1) raised to 1/w, i.e. guaranteed > T.
        let t = self.threshold();
        let tw = (w * t.ln()).exp(); // T^w in log space
        let u = tw + (1.0 - tw) * self.unit();
        let key = (u.ln() / w).exp().clamp(1e-300, 1.0 - 1e-12);
        self.buf.pop();
        self.buf.push(Keyed { key, item });
        self.schedule_jump();
    }

    /// Residents (unordered).
    pub fn items(&self) -> Vec<T> {
        self.buf.iter().map(|k| k.item).collect()
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Items observed (with positive weight) so far.
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Total weight observed so far.
    pub fn weight_seen(&self) -> f64 {
        self.weight_seen
    }
}

/// Per-stratum A-ExpJ sampler behind the [`Sampler`] trait
/// (`SamplerKind::WeightedRes`): OASRS-style adaptive per-stratum
/// capacities, item weight `|value|` (zero-valued items get a tiny floor so
/// they remain sampleable).
#[derive(Debug)]
pub struct WeightedResSampler {
    fraction: f64,
    reservoirs: Vec<Option<WeightedReservoir<f64>>>,
    counters: [f64; MAX_STRATA],
    ewma_arrivals: [f64; MAX_STRATA],
    caps: [usize; MAX_STRATA],
    seed: u64,
    interval: u64,
}

impl WeightedResSampler {
    pub fn new(fraction: f64, seed: u64) -> Self {
        let mut reservoirs = Vec::with_capacity(MAX_STRATA);
        reservoirs.resize_with(MAX_STRATA, || None);
        Self {
            fraction: fraction.clamp(1e-4, 1.0),
            reservoirs,
            counters: [0.0; MAX_STRATA],
            ewma_arrivals: [0.0; MAX_STRATA],
            caps: [0; MAX_STRATA],
            seed,
            interval: 0,
        }
    }

    /// Same equal-split capacity rule as OASRS (`OasrsSampler::capacity_for`).
    ///
    /// SYNC CONTRACT: this function, `DEFAULT_CAP`/`EWMA_ALPHA`, the
    /// per-stratum seed derivation in `offer`, and the EWMA update in
    /// `finish_interval` deliberately mirror `sampling/oasrs.rs` so the two
    /// samplers stay comparable under identical budgets.  If you change the
    /// OASRS adaptivity rule, change it here too (and vice versa).
    fn capacity_for(&self) -> usize {
        let total: f64 = self.ewma_arrivals.iter().sum();
        if total <= 0.0 {
            return DEFAULT_CAP;
        }
        let active = self.ewma_arrivals.iter().filter(|&&x| x > 0.0).count().max(1);
        ((self.fraction * total / active as f64).ceil() as usize).max(1)
    }

    pub fn fraction(&self) -> f64 {
        self.fraction
    }
}

impl Sampler for WeightedResSampler {
    #[inline]
    fn offer(&mut self, item: &Item) {
        let s = item.stratum as usize;
        if s >= MAX_STRATA {
            crate::metrics::record_dropped_item();
            return;
        }
        self.counters[s] += 1.0;
        let w = item.value.abs().max(1e-12);
        if let Some(res) = &mut self.reservoirs[s] {
            res.offer(item.value, w);
            return;
        }
        let cap = self.capacity_for();
        self.caps[s] = cap;
        let seed = self
            .seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((s as u64) << 32)
            .wrapping_add(self.interval);
        let mut res = WeightedReservoir::new(cap, seed);
        res.offer(item.value, w);
        self.reservoirs[s] = Some(res);
    }

    fn finish_interval(&mut self) -> SampleResult {
        let mut sample = Vec::new();
        let mut state = StrataState::default();
        for s in 0..MAX_STRATA {
            let c = self.counters[s];
            state.c[s] = c;
            if let Some(res) = self.reservoirs[s].as_ref() {
                state.n_cap[s] = self.caps[s] as f64;
                for v in res.items() {
                    sample.push((s as u16, v));
                }
            } else {
                state.n_cap[s] = 0.0;
            }
            self.ewma_arrivals[s] = if self.interval == 0 && self.ewma_arrivals[s] == 0.0 {
                c
            } else {
                EWMA_ALPHA * c + (1.0 - EWMA_ALPHA) * self.ewma_arrivals[s]
            };
        }
        self.counters = [0.0; MAX_STRATA];
        self.reservoirs.iter_mut().for_each(|r| *r = None);
        self.caps = [0; MAX_STRATA];
        self.interval += 1;
        SampleResult { sample, state }
    }

    fn set_fraction(&mut self, fraction: f64) {
        self.fraction = fraction.clamp(1e-4, 1.0);
    }

    fn kind(&self) -> SamplerKind {
        SamplerKind::WeightedRes
    }
}

/// Heap codec: residents are encoded in `buf.iter()` order, i.e. the
/// heap's underlying array.  That array already satisfies the heap
/// invariant, so rebuilding with `BinaryHeap::from` (Floyd heapify, which
/// never moves a node that already dominates its children) reproduces the
/// identical internal layout — and therefore the identical `items()`
/// emission order, which downstream f64 accumulation order depends on.
impl<T: Snapshot + Copy> Snapshot for WeightedReservoir<T> {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_usize(self.cap);
        w.put_usize(self.buf.len());
        for k in self.buf.iter() {
            w.put_f64(k.key);
            k.item.encode(w);
        }
        w.put_f64(self.acc);
        w.put_f64(self.jump);
        w.put_u64(self.seen);
        w.put_f64(self.weight_seen);
        self.rng.encode(w);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        let cap = r.get_usize()?;
        let n = r.get_usize()?;
        if n > cap || n > r.remaining() {
            return Err(Error::Io(format!(
                "weighted-reservoir snapshot resident count {n} exceeds capacity {cap} \
                 or remaining payload (corrupt payload)"
            )));
        }
        let mut residents = Vec::with_capacity(n);
        for _ in 0..n {
            let key = r.get_f64()?;
            let item = T::decode(r)?;
            residents.push(Keyed { key, item });
        }
        Ok(Self {
            cap,
            buf: BinaryHeap::from(residents),
            acc: r.get_f64()?,
            jump: r.get_f64()?,
            seen: r.get_u64()?,
            weight_seen: r.get_f64()?,
            rng: Rng::decode(r)?,
        })
    }
}

/// Same scaffolding as [`OasrsSampler`]'s snapshot (SYNC CONTRACT above):
/// per-stratum reservoirs, counters, EWMA arrivals, capacities, the base
/// seed, and the interval counter that salts per-stratum reservoir seeds.
impl Snapshot for WeightedResSampler {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_f64(self.fraction);
        self.reservoirs.encode(w);
        self.counters.encode(w);
        self.ewma_arrivals.encode(w);
        self.caps.encode(w);
        w.put_u64(self.seed);
        w.put_u64(self.interval);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        let fraction = r.get_f64()?;
        let reservoirs = Vec::<Option<WeightedReservoir<f64>>>::decode(r)?;
        if reservoirs.len() != MAX_STRATA {
            return Err(Error::Io(format!(
                "weighted sampler snapshot has {} strata slots, expected {MAX_STRATA}",
                reservoirs.len()
            )));
        }
        Ok(Self {
            fraction,
            reservoirs,
            counters: <[f64; MAX_STRATA]>::decode(r)?,
            ewma_arrivals: <[f64; MAX_STRATA]>::decode(r)?,
            caps: <[usize; MAX_STRATA]>::decode(r)?,
            seed: r.get_u64()?,
            interval: r.get_u64()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_then_holds_capacity() {
        let mut r = WeightedReservoir::new(10, 1);
        for i in 0..5 {
            r.offer(i as f64, 1.0);
        }
        assert_eq!(r.len(), 5);
        for i in 5..10_000 {
            r.offer(i as f64, 1.0);
        }
        assert_eq!(r.len(), 10);
        assert_eq!(r.seen(), 10_000);
    }

    #[test]
    fn zero_capacity_and_bad_weights() {
        let mut r = WeightedReservoir::new(0, 2);
        r.offer(1.0, 1.0);
        assert!(r.is_empty());
        let mut r = WeightedReservoir::new(4, 3);
        r.offer(1.0, 0.0);
        r.offer(1.0, -5.0);
        r.offer(1.0, f64::NAN);
        assert!(r.is_empty());
        assert_eq!(r.seen(), 0);
    }

    #[test]
    fn unit_weights_behave_uniformly() {
        // With all weights equal, A-ExpJ degenerates to uniform reservoir
        // sampling: per-item inclusion probability k/n.
        let n = 200u32;
        let cap = 20;
        let trials = 3000;
        let mut counts = vec![0u32; n as usize];
        for t in 0..trials {
            let mut r = WeightedReservoir::new(cap, 1000 + t as u64);
            for i in 0..n {
                r.offer(i as f64, 1.0);
            }
            for v in r.items() {
                counts[v as usize] += 1;
            }
        }
        let expect = trials as f64 * cap as f64 / n as f64; // 300
        for (i, &c) in counts.iter().enumerate() {
            let z = (c as f64 - expect) / (expect * (1.0 - cap as f64 / n as f64)).sqrt();
            assert!(z.abs() < 5.0, "item {i}: count {c} (z={z:.2})");
        }
    }

    #[test]
    fn heavy_items_sampled_proportionally_more() {
        // 1900 items of weight 1 + 100 of weight 10, cap 100: heavy items'
        // inclusion rate must be several times the light items'.
        let trials = 300;
        let mut heavy_in = 0u32;
        let mut light_in = 0u32;
        for t in 0..trials {
            let mut r = WeightedReservoir::new(100, 7 + t as u64);
            for i in 0..2000u32 {
                let heavy = i % 20 == 0; // 100 heavy
                let w = if heavy { 10.0 } else { 1.0 };
                r.offer(i as f64, w);
            }
            for v in r.items() {
                if (v as u32) % 20 == 0 {
                    heavy_in += 1;
                } else {
                    light_in += 1;
                }
            }
        }
        let heavy_rate = heavy_in as f64 / (trials as f64 * 100.0);
        let light_rate = light_in as f64 / (trials as f64 * 1900.0);
        assert!(
            heavy_rate > 3.0 * light_rate,
            "heavy {heavy_rate:.3} vs light {light_rate:.3}"
        );
    }

    #[test]
    fn deterministic_for_seed() {
        let collect = |seed| {
            let mut r = WeightedReservoir::new(8, seed);
            for i in 0..2000 {
                r.offer(i as f64, 1.0 + (i % 7) as f64);
            }
            let mut v = r.items();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v
        };
        assert_eq!(collect(42), collect(42));
        assert_ne!(collect(42), collect(43));
    }

    #[test]
    fn extreme_weights_stay_finite() {
        let mut r = WeightedReservoir::new(4, 9);
        r.offer(1.0, 1e-9);
        r.offer(2.0, 1e9);
        r.offer(3.0, 1.0);
        for i in 0..1000 {
            r.offer(i as f64, if i % 2 == 0 { 1e9 } else { 1e-9 });
        }
        assert_eq!(r.len(), 4);
        for resident in r.buf.iter() {
            assert!(resident.key > 0.0 && resident.key < 1.0 && resident.key.is_finite());
        }
    }

    #[test]
    fn sampler_trait_roundtrip() {
        let mut s = WeightedResSampler::new(0.5, 11);
        for i in 0..1000 {
            s.offer(&Item::new((i % 3) as u16, 1.0 + i as f64, i));
        }
        let r = s.finish_interval();
        assert_eq!(r.arrived(), 1000.0);
        assert!(!r.sample.is_empty());
        assert!(r.sample.len() <= 1000);
        // interval isolation
        let r2 = s.finish_interval();
        assert_eq!(r2.arrived(), 0.0);
        assert!(r2.sample.is_empty());
    }

    #[test]
    fn sampler_adapts_capacity_like_oasrs() {
        let mut s = WeightedResSampler::new(0.1, 12);
        for i in 0..1000 {
            s.offer(&Item::new(0, 1.0, i));
        }
        s.finish_interval(); // EWMA = 1000
        for i in 0..1000 {
            s.offer(&Item::new(0, 1.0, i));
        }
        let r = s.finish_interval();
        assert_eq!(r.state.n_cap[0], 100.0); // 0.1 × 1000
        let n0 = r.sample.len();
        assert_eq!(n0, 100);
    }

    #[test]
    fn sampler_prefers_heavy_values() {
        // One stratum mixing value 1 and value 1000 items; the sample's
        // share of heavy values must far exceed their population share.
        let mut s = WeightedResSampler::new(0.05, 13);
        let feed = |s: &mut WeightedResSampler| {
            for i in 0..10_000u64 {
                let v = if i % 100 == 0 { 1000.0 } else { 1.0 };
                s.offer(&Item::new(0, v, i));
            }
        };
        feed(&mut s);
        s.finish_interval(); // warm-up capacities
        feed(&mut s);
        let r = s.finish_interval();
        let heavy = r.sample.iter().filter(|&&(_, v)| v == 1000.0).count() as f64;
        let share = heavy / r.sample.len() as f64;
        // population share is 1%; with 100 heavy of 500 slots the ceiling is 20%
        assert!(share > 0.1, "heavy share {share}");
    }

    #[test]
    fn sampler_kind_and_fraction() {
        let mut s = WeightedResSampler::new(0.4, 14);
        assert_eq!(s.kind(), SamplerKind::WeightedRes);
        assert_eq!(s.fraction(), 0.4);
        s.set_fraction(2.0);
        assert_eq!(s.fraction(), 1.0);
    }
}
