//! OASRS — Online Adaptive Stratified Reservoir Sampling (paper §3.2,
//! Algorithm 3).  The paper's core contribution.
//!
//! Per interval, each stratum `S_i` gets its own fixed-capacity reservoir of
//! size `N_i` and an arrival counter `C_i`.  Items stream through with O(1)
//! amortized work and **no synchronization**; at the end of the interval the
//! per-stratum samples are emitted together with `(C_i, N_i)` so the
//! estimator can weight them by Eq. (1):  `W_i = C_i/N_i` if `C_i > N_i`
//! else `1`.
//!
//! **Adaptivity**: the per-stratum capacity is derived from the sampling
//! fraction and an EWMA of the stratum's arrivals over past intervals, so
//! the sampler tracks fluctuating sub-stream rates (the paper's "adaptive
//! cost function"); a stratum first seen mid-interval gets a default
//! capacity immediately — no sub-stream is overlooked regardless of
//! popularity.
//!
//! **Distributed execution** (paper §3.2): `w` workers each run an
//! independent OASRS with capacity `N_i/w`; [`merge_worker_results`]
//! combines their samples, counters, and capacities without coordination.

use std::time::Instant;

use crate::core::{ColumnarChunk, Error, Item, Result, MAX_STRATA};
use crate::error::estimator::StrataState;
use crate::runtime::checkpoint::{Snapshot, SnapshotReader, SnapshotWriter};
use crate::util::rng::Rng;

use super::reservoir::{BatchScratch, Reservoir};
use super::{ColumnarMode, SampleResult, Sampler, SamplerKind};

/// Default capacity for a stratum never seen before (items).
const DEFAULT_CAP: usize = 64;
/// EWMA smoothing for per-stratum arrival estimates.
const EWMA_ALPHA: f64 = 0.5;

/// The OASRS sampler.
#[derive(Debug)]
pub struct OasrsSampler {
    fraction: f64,
    /// Per-stratum reservoir for the current interval (lazily created).
    reservoirs: Vec<Option<Reservoir<f64>>>,
    /// Arrival counters C_i for the current interval.
    counters: [f64; MAX_STRATA],
    /// EWMA of per-interval arrivals per stratum (drives adaptivity).
    ewma_arrivals: [f64; MAX_STRATA],
    /// Capacities N_i chosen for the current interval.
    caps: [usize; MAX_STRATA],
    seed: u64,
    interval: u64,
    /// Which columnar kernel [`Sampler::offer_columnar`] runs.
    columnar_mode: ColumnarMode,
    /// Columnar-kernel scratch: per-stratum value runs (the 16-way stable
    /// partition of a chunk), reused across chunks and intervals.
    part_vals: Vec<Vec<f64>>,
    /// Batched-reservoir scratch (uniforms + survivor/victim compaction).
    scratch: BatchScratch,
    /// Dedicated uniform stream for the `Masked` kernel's chunk-level mask
    /// (deliberately separate from the reservoirs' streams).
    mask_rng: Rng,
    /// Mask-uniform buffer for the `Masked` kernel.
    mask_uniforms: Vec<f64>,
}

impl OasrsSampler {
    pub fn new(fraction: f64, seed: u64) -> Self {
        let mut reservoirs = Vec::with_capacity(MAX_STRATA);
        reservoirs.resize_with(MAX_STRATA, || None);
        let mut part_vals = Vec::with_capacity(MAX_STRATA);
        part_vals.resize_with(MAX_STRATA, Vec::new);
        Self {
            fraction: fraction.clamp(1e-4, 1.0),
            reservoirs,
            counters: [0.0; MAX_STRATA],
            ewma_arrivals: [0.0; MAX_STRATA],
            caps: [0; MAX_STRATA],
            seed,
            interval: 0,
            columnar_mode: ColumnarMode::Exact,
            part_vals,
            scratch: BatchScratch::default(),
            mask_rng: Rng::seed_from_u64(
                seed.wrapping_mul(0x9E3779B97F4A7C15) ^ 0x4D41_534B, // "MASK"
            ),
            mask_uniforms: Vec::new(),
        }
    }

    /// Select the columnar kernel (defaults to [`ColumnarMode::Exact`]).
    pub fn set_columnar_mode(&mut self, mode: ColumnarMode) {
        self.columnar_mode = mode;
    }

    /// Builder-style variant of [`OasrsSampler::set_columnar_mode`].
    pub fn with_columnar_mode(mut self, mode: ColumnarMode) -> Self {
        self.columnar_mode = mode;
        self
    }

    /// Capacity for stratum `s` given current knowledge (Algorithm 3's
    /// `getSampleSize` step).
    ///
    /// SYNC CONTRACT: `sampling/weighted.rs` mirrors this rule (and the
    /// EWMA/seed scaffolding) so OASRS and the weighted reservoir stay
    /// comparable under identical budgets — change both together.
    ///
    /// The total per-interval budget (`fraction ×` expected arrivals) is
    /// split **equally** across the known strata — the paper's design:
    /// StreamApprox "only maintains a sample of a fixed size for each
    /// sub-stream" (§5.2), which is what keeps rare-but-significant
    /// sub-streams fully represented and decouples the per-stratum cost
    /// from stratum popularity (unlike STS's proportional allocation).
    fn capacity_for(&self, _s: usize) -> usize {
        let total: f64 = self.ewma_arrivals.iter().sum();
        if total <= 0.0 {
            return DEFAULT_CAP;
        }
        let active = self.ewma_arrivals.iter().filter(|&&x| x > 0.0).count().max(1);
        ((self.fraction * total / active as f64).ceil() as usize).max(1)
    }

    /// Current sampling fraction.
    pub fn fraction(&self) -> f64 {
        self.fraction
    }

    /// Create stratum `s`'s reservoir for this interval if absent (same
    /// capacity rule and per-stratum seed as the scalar cold branch).
    fn ensure_reservoir(&mut self, s: usize) {
        if self.reservoirs[s].is_none() {
            let cap = self.capacity_for(s);
            self.caps[s] = cap;
            let seed = self
                .seed
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add((s as u64) << 32)
                .wrapping_add(self.interval);
            self.reservoirs[s] = Some(Reservoir::new(cap, seed));
        }
    }

    /// Exact columnar kernel: 16-way stable partition of the chunk's value
    /// column by stratum, then one batched reservoir offer per non-empty
    /// stratum.  Each reservoir owns its RNG and sees its items in arrival
    /// order, so this consumes every stream exactly as the scalar path does
    /// — byte-identical `SampleResult`s for a fixed seed, any chunking.
    // lint: hot-path — per-chunk acceptance sweep, zero steady-state allocation
    fn columnar_exact(&mut self, chunk: &ColumnarChunk) {
        let t0 = crate::obs::metrics_enabled().then(Instant::now); // lint: wall-clock latency metric only, never feeds results
        for vals in &mut self.part_vals {
            vals.clear();
        }
        let mut dropped = 0u64;
        for (&s, &v) in chunk.strata.iter().zip(&chunk.values) {
            let s = s as usize;
            if s < MAX_STRATA {
                self.part_vals[s].push(v);
            } else {
                dropped += 1;
            }
        }
        for _ in 0..dropped {
            crate::metrics::record_dropped_item();
        }
        let mut survivors = 0u64;
        for s in 0..MAX_STRATA {
            let n_s = self.part_vals[s].len();
            if n_s == 0 {
                continue;
            }
            self.counters[s] += n_s as f64;
            self.ensure_reservoir(s);
            let res = self.reservoirs[s].as_mut().expect("just ensured");
            survivors += res.offer_batch(&self.part_vals[s], &mut self.scratch);
        }
        crate::obs_counter!(
            "ingest_mask_survivors_total",
            "items accepted by the columnar acceptance pass"
        )
        .add(survivors);
        if let Some(t0) = t0 {
            crate::obs_histogram!(
                "columnar_compact_ns",
                "wall time of one columnar acceptance/compaction kernel call"
            )
            .record_elapsed(t0);
        }
    }

    /// Masked columnar kernel ([`ColumnarMode::Masked`]): one 8-wide
    /// uniform fill for the whole chunk from the dedicated mask stream,
    /// then an Algorithm-1 step per item driven by its mask lane.  Each
    /// item's inclusion is exactly uniform (same law as `DrawPerItem`), but
    /// the draw *order* differs from the scalar path — equivalence is
    /// pinned by the chi-square suite, not byte comparison, which is why
    /// this kernel is opt-in.
    // lint: hot-path — per-chunk Bernoulli-mask sweep
    fn columnar_masked(&mut self, chunk: &ColumnarChunk) {
        let t0 = crate::obs::metrics_enabled().then(Instant::now); // lint: wall-clock latency metric only, never feeds results
        let n = chunk.len();
        self.mask_uniforms.clear();
        self.mask_uniforms.resize(n, 0.0);
        self.mask_rng.fill_f64(&mut self.mask_uniforms);
        let mut survivors = 0u64;
        for i in 0..n {
            let s = chunk.strata[i] as usize;
            if s >= MAX_STRATA {
                crate::metrics::record_dropped_item();
                continue;
            }
            self.counters[s] += 1.0;
            self.ensure_reservoir(s);
            let res = self.reservoirs[s].as_mut().expect("just ensured");
            survivors += res.offer_with_uniform(chunk.values[i], self.mask_uniforms[i]) as u64;
        }
        crate::obs_counter!(
            "ingest_mask_survivors_total",
            "items accepted by the columnar acceptance pass"
        )
        .add(survivors);
        if let Some(t0) = t0 {
            crate::obs_histogram!(
                "columnar_compact_ns",
                "wall time of one columnar acceptance/compaction kernel call"
            )
            .record_elapsed(t0);
        }
    }
}

impl Sampler for OasrsSampler {
    #[inline]
    fn offer(&mut self, item: &Item) {
        let s = item.stratum as usize;
        if s >= MAX_STRATA {
            crate::metrics::record_dropped_item();
            return;
        }
        self.counters[s] += 1.0;
        // Single slot lookup on the hot path; reservoir creation (first item
        // of a new sub-stream this interval) is the cold branch.
        if let Some(res) = &mut self.reservoirs[s] {
            res.offer(item.value);
            return;
        }
        let cap = self.capacity_for(s);
        self.caps[s] = cap;
        let seed = self
            .seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((s as u64) << 32)
            .wrapping_add(self.interval);
        let mut res = Reservoir::new(cap, seed);
        res.offer(item.value);
        self.reservoirs[s] = Some(res);
    }

    fn offer_columnar(&mut self, chunk: &ColumnarChunk) {
        if chunk.is_empty() {
            return;
        }
        match self.columnar_mode {
            ColumnarMode::Exact => self.columnar_exact(chunk),
            ColumnarMode::Masked => self.columnar_masked(chunk),
        }
    }

    fn finish_interval(&mut self) -> SampleResult {
        let mut sample = Vec::new();
        let mut state = StrataState::default();
        for s in 0..MAX_STRATA {
            let c = self.counters[s];
            state.c[s] = c;
            if let Some(res) = self.reservoirs[s].as_mut() {
                state.n_cap[s] = self.caps[s] as f64;
                for &v in res.items() {
                    sample.push((s as u16, v));
                }
            } else {
                state.n_cap[s] = 0.0;
            }
            // EWMA update (0 arrivals also update, decaying dead strata).
            self.ewma_arrivals[s] = if self.interval == 0 && self.ewma_arrivals[s] == 0.0 {
                c
            } else {
                EWMA_ALPHA * c + (1.0 - EWMA_ALPHA) * self.ewma_arrivals[s]
            };
        }
        // Reset interval state.
        self.counters = [0.0; MAX_STRATA];
        self.reservoirs.iter_mut().for_each(|r| *r = None);
        self.caps = [0; MAX_STRATA];
        self.interval += 1;
        SampleResult { sample, state }
    }

    fn set_fraction(&mut self, fraction: f64) {
        self.fraction = fraction.clamp(1e-4, 1.0);
    }

    fn kind(&self) -> SamplerKind {
        SamplerKind::Oasrs
    }
}

impl Snapshot for OasrsSampler {
    /// Serializes every behavior-bearing field: fraction, per-stratum
    /// reservoirs (mid-interval states included), counters, EWMA history,
    /// capacities, seed, interval number, the columnar-kernel mode, and the
    /// dedicated mask RNG stream.  Scratch buffers (`part_vals`, `scratch`,
    /// `mask_uniforms`) are rebuilt empty — they are cleared or resized
    /// before every use and consume no RNG.
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_f64(self.fraction);
        self.reservoirs.encode(w);
        self.counters.encode(w);
        self.ewma_arrivals.encode(w);
        self.caps.encode(w);
        w.put_u64(self.seed);
        w.put_u64(self.interval);
        w.put_u8(match self.columnar_mode {
            ColumnarMode::Exact => 0,
            ColumnarMode::Masked => 1,
        });
        self.mask_rng.encode(w);
    }

    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        let fraction = r.get_f64()?;
        let reservoirs = Vec::<Option<Reservoir<f64>>>::decode(r)?;
        if reservoirs.len() != MAX_STRATA {
            return Err(Error::Io(format!(
                "oasrs snapshot has {} strata, expected {MAX_STRATA}",
                reservoirs.len()
            )));
        }
        let counters = <[f64; MAX_STRATA]>::decode(r)?;
        let ewma_arrivals = <[f64; MAX_STRATA]>::decode(r)?;
        let caps = <[usize; MAX_STRATA]>::decode(r)?;
        let seed = r.get_u64()?;
        let interval = r.get_u64()?;
        let columnar_mode = match r.get_u8()? {
            0 => ColumnarMode::Exact,
            1 => ColumnarMode::Masked,
            other => {
                return Err(Error::Io(format!("oasrs columnar-mode tag {other} (corrupt payload)")))
            }
        };
        let mask_rng = Rng::decode(r)?;
        let mut part_vals = Vec::with_capacity(MAX_STRATA);
        part_vals.resize_with(MAX_STRATA, Vec::new);
        Ok(Self {
            fraction,
            reservoirs,
            counters,
            ewma_arrivals,
            caps,
            seed,
            interval,
            columnar_mode,
            part_vals,
            scratch: BatchScratch::default(),
            mask_rng,
            mask_uniforms: Vec::new(),
        })
    }
}

/// Combine per-worker OASRS results for one interval (paper §3.2
/// "Distributed execution"): samples concatenate, arrival counters and
/// capacities add — no synchronization during the interval.  This is an
/// in-order fold over the [`crate::window::Mergeable`] impl of
/// [`SampleResult`]; the window pane store runs the same combine
/// incrementally.
pub fn merge_worker_results(parts: Vec<SampleResult>) -> SampleResult {
    use crate::window::Mergeable;
    let mut merged = SampleResult::default();
    for part in &parts {
        merged.merge_from(part);
    }
    merged
}

/// A distributed OASRS: `w` independent per-worker samplers, each sized
/// `fraction/w` of the stream it sees.  Used by the engines' parallel path
/// and by the scalability experiments (Fig. 7a).
#[derive(Debug)]
pub struct DistributedOasrs {
    workers: Vec<OasrsSampler>,
    next: usize,
}

impl DistributedOasrs {
    pub fn new(n_workers: usize, fraction: f64, seed: u64) -> Self {
        let workers = (0..n_workers.max(1))
            .map(|w| OasrsSampler::new(fraction, seed.wrapping_add(w as u64 * 7919)))
            .collect();
        Self { workers, next: 0 }
    }

    /// Round-robin an item to a worker (models the even split the paper
    /// assumes across workers of a sub-stream).
    pub fn offer(&mut self, item: &Item) {
        let w = self.next;
        self.next = (self.next + 1) % self.workers.len();
        self.workers[w].offer(item);
    }

    /// Finish the interval on every worker and merge.
    pub fn finish_interval(&mut self) -> SampleResult {
        let parts = self.workers.iter_mut().map(|w| w.finish_interval()).collect();
        merge_worker_results(parts)
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::estimator::{estimate, StrataPartials};

    fn feed(sampler: &mut OasrsSampler, per_stratum: &[(u16, usize, f64)]) {
        // (stratum, count, value_base)
        let mut ts = 0;
        for &(s, n, base) in per_stratum {
            for i in 0..n {
                sampler.offer(&Item::new(s, base + i as f64, ts));
                ts += 1;
            }
        }
    }

    #[test]
    fn respects_per_stratum_capacity() {
        let mut s = OasrsSampler::new(0.5, 1);
        feed(&mut s, &[(0, 1000, 0.0), (1, 10, 0.0)]);
        let r = s.finish_interval();
        // stratum 0: default cap 64 (no history) -> at most 64 selected
        let n0 = r.sample.iter().filter(|(st, _)| *st == 0).count();
        let n1 = r.sample.iter().filter(|(st, _)| *st == 1).count();
        assert_eq!(n0, 64);
        assert_eq!(n1, 10); // fewer than cap -> all kept
        assert_eq!(r.state.c[0], 1000.0);
        assert_eq!(r.state.c[1], 10.0);
    }

    #[test]
    fn weight_law_via_estimator() {
        let mut s = OasrsSampler::new(0.5, 2);
        feed(&mut s, &[(0, 1000, 5.0)]);
        let r = s.finish_interval();
        let est = estimate(&StrataPartials::from_sample(&r.sample), &r.state);
        // W_0 = C/N = 1000/64
        assert!((est.weights[0] - 1000.0 / 64.0).abs() < 1e-9);
    }

    #[test]
    fn adapts_capacity_to_arrival_rate() {
        let mut s = OasrsSampler::new(0.1, 3);
        // interval 1: 1000 items -> EWMA 1000
        feed(&mut s, &[(0, 1000, 0.0)]);
        s.finish_interval();
        // interval 2: capacity should now be ~0.1 * 1000 = 100
        feed(&mut s, &[(0, 1000, 0.0)]);
        let r = s.finish_interval();
        let n0 = r.sample.iter().filter(|(st, _)| *st == 0).count();
        assert_eq!(n0, 100);
        assert_eq!(r.state.n_cap[0], 100.0);
    }

    #[test]
    fn tracks_rate_increase() {
        let mut s = OasrsSampler::new(0.2, 4);
        feed(&mut s, &[(0, 100, 0.0)]);
        s.finish_interval(); // ewma 100
        feed(&mut s, &[(0, 10_000, 0.0)]);
        s.finish_interval(); // ewma -> 5050
        feed(&mut s, &[(0, 10_000, 0.0)]);
        let r = s.finish_interval();
        // cap = ceil(0.2 * 5050) = 1010
        assert_eq!(r.state.n_cap[0], 1010.0);
    }

    #[test]
    fn never_overlooks_rare_stratum() {
        // The SRS failure mode OASRS fixes: a tiny high-value sub-stream
        // must always contribute to the sample.
        let mut s = OasrsSampler::new(0.1, 5);
        feed(&mut s, &[(0, 100_000, 1.0), (2, 3, 1_000_000.0)]);
        let r = s.finish_interval();
        let n2 = r.sample.iter().filter(|(st, _)| *st == 2).count();
        assert_eq!(n2, 3, "rare stratum fully sampled");
    }

    #[test]
    fn estimate_accuracy_on_skewed_stream() {
        // 3 strata with very different scales; estimate vs exact sum.
        let mut s = OasrsSampler::new(0.3, 6);
        let mut exact = 0.0;
        let mut rng = Rng::seed_from_u64(99);
        for _ in 0..2 {
            // warm-up interval then measured interval
            exact = 0.0;
            for _ in 0..8000 {
                let v = rng.normal(10.0, 5.0);
                s.offer(&Item::new(0, v, 0));
                exact += v;
            }
            for _ in 0..2000 {
                let v = rng.normal(1000.0, 50.0);
                s.offer(&Item::new(1, v, 0));
                exact += v;
            }
            for _ in 0..100 {
                let v = rng.normal(10000.0, 500.0);
                s.offer(&Item::new(2, v, 0));
                exact += v;
            }
            if s.interval == 0 {
                s.finish_interval();
            }
        }
        let r = s.finish_interval();
        let est = estimate(&StrataPartials::from_sample(&r.sample), &r.state);
        let rel = (est.sum - exact).abs() / exact;
        assert!(rel < 0.05, "relative error {rel}");
    }

    #[test]
    fn interval_isolation() {
        let mut s = OasrsSampler::new(0.5, 7);
        feed(&mut s, &[(0, 50, 0.0)]);
        let r1 = s.finish_interval();
        let r2 = s.finish_interval();
        assert!(r1.sample.len() > 0);
        assert_eq!(r2.sample.len(), 0);
        assert_eq!(r2.state.c[0], 0.0);
    }

    #[test]
    fn distributed_merge_counts_add() {
        let mut d = DistributedOasrs::new(4, 0.5, 8);
        for i in 0..1000 {
            d.offer(&Item::new((i % 3) as u16, i as f64, i as u64));
        }
        let r = d.finish_interval();
        let total: f64 = r.state.c.iter().sum();
        assert_eq!(total, 1000.0);
        // per-stratum counters: 334/333/333
        assert!((r.state.c[0] - 334.0).abs() < 1.0);
    }

    #[test]
    fn distributed_estimate_matches_single_node_statistically() {
        // Same stream through 1-worker and 4-worker OASRS: estimates agree
        // within a few σ.
        let gen_stream = || {
            let mut rng = Rng::seed_from_u64(55);
            let mut items = Vec::new();
            for _ in 0..20_000 {
                items.push(Item::new(0, rng.normal(100.0, 20.0), 0));
            }
            for _ in 0..500 {
                items.push(Item::new(1, rng.normal(5000.0, 100.0), 0));
            }
            items
        };
        let exact: f64 = gen_stream().iter().map(|i| i.value).sum();

        let mut single = OasrsSampler::new(0.2, 9);
        // warm-up to lock in capacities, then measure
        for it in gen_stream() {
            single.offer(&it);
        }
        single.finish_interval();
        for it in gen_stream() {
            single.offer(&it);
        }
        let r1 = single.finish_interval();
        let e1 = estimate(&StrataPartials::from_sample(&r1.sample), &r1.state);

        let mut dist = DistributedOasrs::new(4, 0.2, 10);
        for it in gen_stream() {
            dist.offer(&it);
        }
        dist.finish_interval();
        for it in gen_stream() {
            dist.offer(&it);
        }
        let r4 = dist.finish_interval();
        let e4 = estimate(&StrataPartials::from_sample(&r4.sample), &r4.state);

        for (e, tag) in [(e1, "single"), (e4, "dist")] {
            let rel = (e.sum - exact).abs() / exact;
            assert!(rel < 0.05, "{tag} relative error {rel}");
        }
    }

    #[test]
    fn set_fraction_applies_next_interval() {
        let mut s = OasrsSampler::new(0.5, 11);
        feed(&mut s, &[(0, 1000, 0.0)]);
        s.finish_interval(); // ewma = 1000
        s.set_fraction(0.01);
        feed(&mut s, &[(0, 1000, 0.0)]);
        let r = s.finish_interval();
        assert_eq!(r.state.n_cap[0], 10.0); // 0.01 * 1000
    }

    #[test]
    fn out_of_range_stratum_dropped() {
        let mut s = OasrsSampler::new(0.5, 12);
        s.offer(&Item::new(999, 1.0, 0));
        let r = s.finish_interval();
        assert!(r.sample.is_empty());
        assert_eq!(r.arrived(), 0.0);
    }

    fn mixed_trace(n: usize, seed: u64) -> Vec<Item> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n)
            .map(|i| Item::new(rng.range_usize(0, 5) as u16, rng.normal(50.0, 10.0), i as u64))
            .collect()
    }

    #[test]
    fn columnar_exact_is_byte_identical_to_scalar() {
        // Two intervals (so EWMA-adapted capacities and per-interval seeds
        // are exercised), several chunkings, plus an out-of-range stratum.
        for chunk_size in [1usize, 17, 512, usize::MAX] {
            let mut items = mixed_trace(6000, 42);
            items.push(Item::new(999, 1.0, 6000));
            let mut scalar = OasrsSampler::new(0.1, 7);
            let mut columnar = OasrsSampler::new(0.1, 7);
            for _ in 0..2 {
                for it in &items {
                    scalar.offer(it);
                }
                for c in items.chunks(chunk_size.min(items.len())) {
                    columnar.offer_columnar(&ColumnarChunk::from_items(c));
                }
                let a = scalar.finish_interval();
                let b = columnar.finish_interval();
                assert_eq!(a.sample, b.sample, "chunk {chunk_size}");
                assert_eq!(a.state.c, b.state.c, "chunk {chunk_size}");
                assert_eq!(a.state.n_cap, b.state.n_cap, "chunk {chunk_size}");
            }
        }
    }

    #[test]
    fn masked_mode_respects_capacity_and_counts() {
        let mut s = OasrsSampler::new(0.5, 13).with_columnar_mode(ColumnarMode::Masked);
        let items = mixed_trace(3000, 8);
        s.offer_columnar(&ColumnarChunk::from_items(&items));
        let r = s.finish_interval();
        assert_eq!(r.arrived(), 3000.0);
        for st in 0..5usize {
            let n = r.sample.iter().filter(|(x, _)| *x as usize == st).count();
            assert!(n <= 64, "stratum {st}: {n} > default cap");
            assert!(n > 0, "stratum {st} empty");
        }
    }

    #[test]
    fn masked_mode_is_deterministic_per_seed() {
        let run = || {
            let mut s = OasrsSampler::new(0.2, 21).with_columnar_mode(ColumnarMode::Masked);
            let items = mixed_trace(4000, 3);
            for c in items.chunks(512) {
                s.offer_columnar(&ColumnarChunk::from_items(c));
            }
            s.finish_interval().sample
        };
        assert_eq!(run(), run());
    }

    use crate::util::rng::Rng;
}
