//! Spark-style Stratified Sampling — the `sampleByKey`/`sampleByKeyExact`
//! baseline (paper §4.1.1).
//!
//! Spark's STS first clusters the buffered batch by stratum
//! (`groupBy(strata)`), then runs the random-sort selection *within each
//! stratum* with a per-stratum target of `fraction · C_i` (proportional
//! allocation — the sample size of each stratum is proportional to the
//! stratum's size, which is why the paper calls STS resource-hungry: big
//! strata keep big samples).  `sampleByKeyExact` additionally needs an exact
//! per-key count, i.e. a *second pass* and a cross-worker synchronization to
//! assemble per-key totals before sampling can run; we reproduce both the
//! two-pass structure and the full per-stratum key sort it performs (not
//! just the (p,q) middle region — the "exact" variant sorts whole strata).
//!
//! **Estimation**: proportional allocation selects `k_i = fraction · C_i`
//! per stratum, so `n_cap_i = fraction · C_i` makes Eq. (1) produce the STS
//! weight `1 / fraction` uniformly.

use crate::core::{ColumnarChunk, Item, Result, MAX_STRATA};
use crate::error::estimator::StrataState;
use crate::runtime::checkpoint::{Snapshot, SnapshotReader, SnapshotWriter};
use crate::util::rng::Rng;

use super::{SampleResult, Sampler, SamplerKind};

/// Spark-`sampleByKeyExact`-style stratified sampler (batch fashion).
#[derive(Debug)]
pub struct StsSampler {
    fraction: f64,
    batch: Vec<(u16, f64)>,
    rng: Rng,
}

impl StsSampler {
    pub fn new(fraction: f64, seed: u64) -> Self {
        Self {
            fraction: fraction.clamp(1e-4, 1.0),
            batch: Vec::new(),
            rng: Rng::seed_from_u64(seed),
        }
    }
}

impl Sampler for StsSampler {
    #[inline]
    fn offer(&mut self, item: &Item) {
        if (item.stratum as usize) < MAX_STRATA {
            self.batch.push((item.stratum, item.value));
        } else {
            crate::metrics::record_dropped_item();
        }
    }

    fn offer_slice(&mut self, items: &[Item]) {
        // One buffer reservation per chunk, then a tight append loop.
        self.batch.reserve(items.len());
        for item in items {
            self.offer(item);
        }
    }

    fn offer_columnar(&mut self, chunk: &ColumnarChunk) {
        // Columnar buffering: read only the stratum/value columns (the ts
        // column is never touched — a third of the AoS traffic gone).  The
        // batch fashion and the full per-stratum sort at close are
        // deliberately preserved: they are the baseline's cost signature.
        self.batch.reserve(chunk.len());
        for (&s, &v) in chunk.strata.iter().zip(&chunk.values) {
            if (s as usize) < MAX_STRATA {
                self.batch.push((s, v));
            } else {
                crate::metrics::record_dropped_item();
            }
        }
    }

    fn finish_interval(&mut self) -> SampleResult {
        let batch = std::mem::take(&mut self.batch);

        // PASS 1 (the `sampleByKeyExact` count step): exact per-key counts.
        // In the distributed original this is the synchronization point — a
        // shuffle/join across workers; the engine layer adds that barrier.
        let mut counts = [0usize; MAX_STRATA];
        for &(s, _) in &batch {
            counts[s as usize] += 1;
        }

        // groupBy(strata): materialize per-stratum groups (the expensive
        // shuffle structure).
        let mut groups: Vec<Vec<f64>> = (0..MAX_STRATA)
            .map(|s| Vec::with_capacity(counts[s]))
            .collect();
        for &(s, v) in &batch {
            groups[s as usize].push(v);
        }

        // PASS 2: per-stratum random sort. The exact variant sorts the whole
        // stratum's keys to take precisely k_i items.
        let mut sample = Vec::new();
        let mut state = StrataState::default();
        for s in 0..MAX_STRATA {
            let c_i = counts[s];
            state.c[s] = c_i as f64;
            if c_i == 0 {
                continue;
            }
            let k_i = ((self.fraction * c_i as f64).round() as usize).clamp(1, c_i);
            // full key sort (sampleByKeyExact's cost signature)
            let mut keyed: Vec<(f64, usize)> =
                (0..c_i).map(|i| (self.rng.f64(), i)).collect();
            keyed.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for &(_, idx) in keyed.iter().take(k_i) {
                sample.push((s as u16, groups[s][idx]));
            }
            // Proportional allocation -> weight 1/fraction via Eq. (1).
            state.n_cap[s] = k_i as f64;
        }
        SampleResult { sample, state }
    }

    fn set_fraction(&mut self, fraction: f64) {
        self.fraction = fraction.clamp(1e-4, 1.0);
    }

    fn kind(&self) -> SamplerKind {
        SamplerKind::Sts
    }
}

/// STS checkpoint state: the buffered batch and the per-stratum sort RNG
/// stream (which, like SRS's, advances across intervals and must survive a
/// boundary snapshot bit-exactly).
impl Snapshot for StsSampler {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_f64(self.fraction);
        self.batch.encode(w);
        self.rng.encode(w);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        Ok(Self {
            fraction: r.get_f64()?,
            batch: Vec::<(u16, f64)>::decode(r)?,
            rng: Rng::decode(r)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::estimator::{estimate, StrataPartials};
    use crate::util::rng::Rng;

    #[test]
    fn proportional_allocation() {
        let mut s = StsSampler::new(0.5, 1);
        for i in 0..8000 {
            s.offer(&Item::new(0, i as f64, 0));
        }
        for i in 0..2000 {
            s.offer(&Item::new(1, i as f64, 0));
        }
        let r = s.finish_interval();
        let n0 = r.sample.iter().filter(|(st, _)| *st == 0).count();
        let n1 = r.sample.iter().filter(|(st, _)| *st == 1).count();
        assert_eq!(n0, 4000);
        assert_eq!(n1, 1000);
    }

    #[test]
    fn never_misses_a_stratum() {
        // STS always takes at least one item from a present stratum.
        for seed in 0..20 {
            let mut s = StsSampler::new(0.05, seed);
            for i in 0..10_000 {
                s.offer(&Item::new(0, 1.0, i));
            }
            for _ in 0..3 {
                s.offer(&Item::new(2, 1_000_000.0, 0));
            }
            let r = s.finish_interval();
            assert!(r.sample.iter().any(|(st, _)| *st == 2), "seed {seed} missed stratum 2");
        }
    }

    #[test]
    fn weights_are_inverse_fraction() {
        let mut s = StsSampler::new(0.25, 2);
        for i in 0..4000 {
            s.offer(&Item::new((i % 3) as u16, 1.0, 0));
        }
        let r = s.finish_interval();
        let est = estimate(&StrataPartials::from_sample(&r.sample), &r.state);
        for i in 0..3 {
            assert!(
                (est.weights[i] - 4.0).abs() < 0.05,
                "stratum {i} weight {}",
                est.weights[i]
            );
        }
    }

    #[test]
    fn estimate_accuracy_on_skewed_stream() {
        let mut s = StsSampler::new(0.3, 3);
        let mut rng = Rng::seed_from_u64(42);
        let mut exact = 0.0;
        for _ in 0..8000 {
            let v = rng.normal(10.0, 5.0);
            s.offer(&Item::new(0, v, 0));
            exact += v;
        }
        for _ in 0..100 {
            let v = rng.normal(10000.0, 500.0);
            s.offer(&Item::new(2, v, 0));
            exact += v;
        }
        let r = s.finish_interval();
        let est = estimate(&StrataPartials::from_sample(&r.sample), &r.state);
        let rel = (est.sum - exact).abs() / exact;
        assert!(rel < 0.03, "relative error {rel}");
    }

    #[test]
    fn per_stratum_selection_is_unbiased() {
        // Within a stratum every item equally likely.
        let trials = 2000;
        let mut counts = vec![0u32; 100];
        for t in 0..trials {
            let mut s = StsSampler::new(0.2, t);
            for i in 0..100 {
                s.offer(&Item::new(0, i as f64, 0));
            }
            let r = s.finish_interval();
            for &(_, v) in &r.sample {
                counts[v as usize] += 1;
            }
        }
        let expect = trials as f64 * 0.2;
        for (i, &c) in counts.iter().enumerate() {
            let z = (c as f64 - expect) / (expect * 0.8).sqrt();
            assert!(z.abs() < 5.0, "item {i}: {c} (z {z:.2})");
        }
    }

    #[test]
    fn full_fraction_exact() {
        let mut s = StsSampler::new(1.0, 5);
        let mut exact = 0.0;
        for i in 0..500 {
            s.offer(&Item::new((i % 4) as u16, i as f64, 0));
            exact += i as f64;
        }
        let r = s.finish_interval();
        assert_eq!(r.sample.len(), 500);
        let est = estimate(&StrataPartials::from_sample(&r.sample), &r.state);
        assert!((est.sum - exact).abs() < 1e-9);
    }

    #[test]
    fn empty_interval() {
        let mut s = StsSampler::new(0.5, 6);
        let r = s.finish_interval();
        assert!(r.sample.is_empty());
    }

    #[test]
    fn offer_columnar_is_byte_identical_to_offer() {
        for chunk_size in [1usize, 512, usize::MAX] {
            let mut items: Vec<Item> = (0..4000)
                .map(|i| Item::new((i % 3) as u16, i as f64, i as u64))
                .collect();
            items.push(Item::new(999, 1.0, 4000));
            let mut scalar = StsSampler::new(0.2, 9);
            let mut columnar = StsSampler::new(0.2, 9);
            for it in &items {
                scalar.offer(it);
            }
            for c in items.chunks(chunk_size.min(items.len())) {
                columnar.offer_columnar(&ColumnarChunk::from_items(c));
            }
            let a = scalar.finish_interval();
            let b = columnar.finish_interval();
            assert_eq!(a.sample, b.sample, "chunk {chunk_size}");
            assert_eq!(a.state.c, b.state.c, "chunk {chunk_size}");
        }
    }
}
