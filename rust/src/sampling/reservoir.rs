//! Classic reservoir sampling (paper Algorithm 1; Vitter, TOMS '85) with an
//! Algorithm-L skip-ahead fast path (Li, TOMS '94).
//!
//! Maintains a uniform random sample of fixed capacity over a stream of
//! unknown length: the first `cap` items fill the reservoir; afterwards the
//! i-th item is accepted with probability `cap / i` and replaces a uniformly
//! random resident.
//!
//! Two operating modes produce the same inclusion distribution:
//!
//! * [`ReservoirMode::SkipAheadL`] (default) — Li's Algorithm L draws a
//!   geometric *skip count* per acceptance instead of one uniform per item:
//!   O(cap·log(n/cap)) RNG draws total, and the full-reservoir hot path of
//!   `offer` is a single integer decrement.  Because one acceptance costs
//!   several transcendentals (`ln`/`exp` for the threshold chain), skips
//!   only pay for themselves once the mean gap between acceptances
//!   (`seen / cap`) clears an amortization horizon; below it the mode runs
//!   the cheap one-draw-per-item step and *engages* the skip chain at
//!   `seen > ENGAGE_HORIZON · cap`, re-seeding the threshold with its exact
//!   conditional distribution `W ~ Beta(cap, seen − cap + 1)` (the
//!   acceptance probability after `seen` items under the uniform-keys
//!   model).  The hybrid is exactly uniform in both phases — cross-checked
//!   against draw-per-item by the chi-square tests — and never slower than
//!   Algorithm 1, while long-stream regimes (`n ≫ cap`, e.g. heavy strata
//!   under skewed arrivals or small sampling fractions) collapse to the
//!   decrement-only path.
//! * [`ReservoirMode::DrawPerItem`] — the classic Algorithm-1 body, one f64
//!   draw per item, kept for cross-validation: the uniformity property
//!   tests run both modes on the same seed budget and compare.

use crate::core::{Error, Result};
use crate::runtime::checkpoint::{Snapshot, SnapshotReader, SnapshotWriter};
use crate::util::rng::Rng;

/// Reusable scratch for [`Reservoir::offer_batch`] — owned by the caller
/// (the sampler) so reservoirs recreated every interval share one
/// allocation and the steady-state columnar path allocates nothing.
#[derive(Debug, Default, Clone)]
pub struct BatchScratch {
    /// Batched uniforms (filled by `Rng::fill_f64`).
    uniforms: Vec<f64>,
    /// Cursor-compacted positions (within the batch) of accepted items.
    survivors: Vec<u32>,
    /// Victim slot for each accepted item, parallel to `survivors`.
    victims: Vec<u32>,
}

/// Sentinel skip meaning "never accept again" (degenerate `w`; practically
/// unreachable but keeps the arithmetic total).
const SKIP_FOREVER: u64 = u64::MAX;

/// Engage Algorithm-L skips once `seen > ENGAGE_HORIZON * cap`, i.e. once
/// the mean gap between acceptances exceeds ~16 items.  An acceptance costs
/// ~4 transcendentals (≈30–60 ns) against ~2–3 ns per saved draw, so the
/// break-even gap is ~12–20 items; 16 is conservative on both fast and slow
/// libms.
const ENGAGE_HORIZON: u64 = 16;

/// Which acceptance algorithm a [`Reservoir`] runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReservoirMode {
    /// Algorithm L skips with the dense-phase hybrid: o(1) RNG work per
    /// item past the engagement horizon.
    SkipAheadL,
    /// Algorithm 1 (Vitter): one uniform draw per item.
    DrawPerItem,
}

/// A fixed-capacity uniform reservoir over `T`.
#[derive(Debug, Clone)]
pub struct Reservoir<T> {
    cap: usize,
    buf: Vec<T>,
    seen: u64,
    rng: Rng,
    mode: ReservoirMode,
    /// True once the Algorithm-L skip chain is running (SkipAheadL only).
    engaged: bool,
    /// Items still to reject before the next acceptance (engaged only).
    skip: u64,
    /// Algorithm L's threshold `W` — the current acceptance probability,
    /// updated multiplicatively per acceptance.
    w: f64,
}

impl<T> Reservoir<T> {
    /// Create a reservoir with capacity `cap` (>= 1 unless you want an
    /// always-empty sampler, which is permitted for capacity 0).  Uses the
    /// Algorithm-L skip fast path.
    pub fn new(cap: usize, seed: u64) -> Self {
        Self::with_mode(cap, seed, ReservoirMode::SkipAheadL)
    }

    /// Create a reservoir with an explicit acceptance algorithm.
    pub fn with_mode(cap: usize, seed: u64, mode: ReservoirMode) -> Self {
        Self {
            cap,
            buf: Vec::with_capacity(cap.min(1024)),
            seen: 0,
            rng: Rng::seed_from_u64(seed),
            mode,
            engaged: false,
            skip: 0,
            w: 1.0,
        }
    }

    /// Uniform draw kept strictly inside (0, 1) so logarithms stay finite.
    #[inline]
    fn unit(&mut self) -> f64 {
        self.rng.f64().clamp(f64::MIN_POSITIVE, 1.0 - f64::EPSILON / 2.0)
    }

    /// Start the Algorithm-L chain at the current position: the threshold
    /// (= acceptance probability) after processing `i` items is exactly
    /// `Beta(cap, i - cap + 1)` — one minus the cap-th largest of `i`
    /// uniform keys.  `offer` has already counted the current,
    /// not-yet-decided item, so the processed count here is `seen - 1` and
    /// the second parameter is `(seen - 1) - cap + 1 = seen - cap`; the
    /// current item then becomes the chain's first candidate.  At
    /// `i == cap` this reduces to Li's `W = U^(1/cap)` initialization
    /// (`Beta(cap, 1)` is the max-of-cap-uniforms law).
    fn engage(&mut self) {
        debug_assert!(self.cap > 0 && self.seen > self.cap as u64);
        self.w = self.rng.beta(self.cap as f64, (self.seen - self.cap as u64) as f64);
        self.engaged = true;
        self.schedule_skip();
    }

    /// Geometric skip length `floor(ln U / ln(1 - w))` (Li's gap law).
    fn schedule_skip(&mut self) {
        let ln_1mw = (1.0 - self.w).max(0.0).ln();
        if ln_1mw >= 0.0 {
            // w underflowed to 0 (ln(1-w) == -0.0): acceptances have become
            // astronomically rare; stop accepting rather than divide by zero.
            self.skip = SKIP_FOREVER;
            return;
        }
        let s = (self.unit().ln() / ln_1mw).floor();
        // Non-negative by construction (both logs negative); saturate huge
        // gaps.
        self.skip = if s < SKIP_FOREVER as f64 { s as u64 } else { SKIP_FOREVER };
    }

    /// Offer one item.
    ///
    /// Hot path (full reservoir): the engaged SkipAheadL phase decrements
    /// the pending skip count — no RNG draw, no float work; an acceptance
    /// costs three draws (victim index, threshold update, next gap).  The
    /// dense phase and DrawPerItem run the classic single draw per item:
    /// `r` uniform on [0, seen); accept iff `r < cap`, and *conditioned on
    /// acceptance* `r` is uniform on [0, cap) — so `floor(r)` doubles as
    /// the victim index with no second draw (f64 has 53 bits; bias is
    /// ~2⁻⁵³ per item, far below measurement noise — cross-checked by the
    /// uniformity tests).
    #[inline]
    pub fn offer(&mut self, item: T) {
        self.seen += 1;
        if self.buf.len() < self.cap {
            self.buf.push(item);
            return;
        }
        if self.cap == 0 {
            return;
        }
        if self.mode == ReservoirMode::SkipAheadL {
            if !self.engaged {
                if self.seen > ENGAGE_HORIZON.saturating_mul(self.cap as u64) {
                    // Seed the chain with the exact threshold for this
                    // position; the current item becomes its first
                    // candidate (skip 0 accepts it).
                    self.engage();
                } else {
                    self.draw_per_item_step(item);
                    return;
                }
            }
            if self.skip > 0 {
                self.skip -= 1;
                return;
            }
            let victim = self.rng.range_usize(0, self.cap);
            self.buf[victim] = item;
            self.w *= (self.unit().ln() / self.cap as f64).exp();
            self.schedule_skip();
        } else {
            self.draw_per_item_step(item);
        }
    }

    /// Algorithm 1 body: one uniform over [0, seen), accept iff below cap.
    #[inline]
    fn draw_per_item_step(&mut self, item: T) {
        let r = self.rng.f64() * self.seen as f64;
        if r < self.cap as f64 {
            self.buf[r as usize] = item;
        }
    }

    /// Algorithm-1 step driven by a caller-supplied uniform (the batched
    /// Bernoulli-mask path, [`crate::sampling::ColumnarMode::Masked`]):
    /// identical inclusion law to [`Reservoir::offer`] in `DrawPerItem`
    /// mode, but the reservoir consumes none of its own RNG.  Returns true
    /// when the item entered the reservoir.
    #[inline]
    pub fn offer_with_uniform(&mut self, item: T, u: f64) -> bool {
        self.seen += 1;
        if self.buf.len() < self.cap {
            self.buf.push(item);
            return true;
        }
        if self.cap == 0 {
            return false;
        }
        let r = u * self.seen as f64;
        if r < self.cap as f64 {
            self.buf[r as usize] = item;
            return true;
        }
        false
    }

    /// Items observed so far.
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Current sample size (== min(cap, seen)).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Acceptance algorithm this reservoir runs.
    pub fn mode(&self) -> ReservoirMode {
        self.mode
    }

    /// True once the geometric-skip chain is active (diagnostics/tests).
    pub fn skip_engaged(&self) -> bool {
        self.engaged
    }

    /// Borrow the current sample.
    pub fn items(&self) -> &[T] {
        &self.buf
    }

    /// Take the sample and reset counters (new interval), keeping capacity.
    pub fn drain(&mut self) -> Vec<T> {
        self.seen = 0;
        self.engaged = false;
        self.skip = 0;
        self.w = 1.0;
        std::mem::take(&mut self.buf)
    }

    /// Change capacity for the next interval (adaptive budgets). Shrinking
    /// truncates uniformly (the resident set is already uniform, and a
    /// uniform subset of a uniform sample is uniform).
    pub fn set_capacity(&mut self, cap: usize) {
        self.cap = cap;
        if self.buf.len() > cap {
            // Shuffle then truncate to keep the subset unbiased.
            self.rng.shuffle(&mut self.buf);
            self.buf.truncate(cap);
        }
        // The skip chain's threshold law is capacity-specific: drop back to
        // the (exact-from-any-state) dense phase and let the horizon check
        // re-engage against the new capacity.
        self.engaged = false;
        self.skip = 0;
        self.w = 1.0;
    }
}

impl<T: Copy> Reservoir<T> {
    /// Batched [`Reservoir::offer`]: process a whole slice with batched RNG
    /// and a branchless acceptance sweep.  **Byte-identical** to offering
    /// the items one at a time with the same seed — both phases consume the
    /// reservoir's RNG stream in exactly the scalar order (the dense sweep
    /// via [`Rng::fill_f64`], which replays sequential `f64()` draws; the
    /// engaged skip phase draws only at acceptances, as scalar does) — so
    /// chunk-size determinism holds for any chunking.  Returns the number
    /// of items that entered the reservoir (fill-phase pushes + accepted
    /// replacements).
    ///
    /// Cost shape: the dense phase replaces one serial
    /// draw→compare→branch per item with an 8-wide uniform fill plus a
    /// mask/cursor compaction whose loop body has no data-dependent
    /// branches; the engaged skip phase collapses whole rejected runs to
    /// one subtraction (`O(accepts)` total instead of `O(items)`
    /// decrements).
    // lint: hot-path — geometric-skip batch offer (scratch reused by caller)
    pub fn offer_batch(&mut self, items: &[T], scratch: &mut BatchScratch) -> u64 {
        let mut rest = items;
        let mut accepted = 0u64;
        // Fill phase: the first `cap` items are kept unconditionally.
        if self.buf.len() < self.cap {
            let take = (self.cap - self.buf.len()).min(rest.len());
            self.buf.extend_from_slice(&rest[..take]);
            self.seen += take as u64;
            accepted += take as u64;
            rest = &rest[take..];
            if rest.is_empty() {
                return accepted;
            }
        }
        if self.cap == 0 {
            self.seen += rest.len() as u64;
            return accepted;
        }
        match self.mode {
            ReservoirMode::DrawPerItem => {
                accepted += self.dense_batch(rest, scratch);
            }
            ReservoirMode::SkipAheadL => {
                if !self.engaged {
                    // Items are dense while seen-after-increment stays at or
                    // below the horizon — exactly the scalar engage check.
                    let horizon = ENGAGE_HORIZON.saturating_mul(self.cap as u64);
                    let dense_n = horizon.saturating_sub(self.seen).min(rest.len() as u64) as usize;
                    accepted += self.dense_batch(&rest[..dense_n], scratch);
                    rest = &rest[dense_n..];
                    if rest.is_empty() {
                        return accepted;
                    }
                    // The next item crosses the horizon: mirror the scalar
                    // order exactly — count it, seed the chain, then let it
                    // be the chain's first candidate.
                    self.seen += 1;
                    self.engage();
                    if self.skip > 0 {
                        self.skip -= 1;
                    } else {
                        let victim = self.rng.range_usize(0, self.cap);
                        self.buf[victim] = rest[0];
                        self.w *= (self.unit().ln() / self.cap as f64).exp();
                        self.schedule_skip();
                        accepted += 1;
                    }
                    rest = &rest[1..];
                }
                accepted += self.skip_batch(rest);
            }
        }
        accepted
    }

    /// Batched Algorithm-1 body over a full reservoir: one `fill_f64`, then
    /// a branchless mask/cursor sweep that compacts survivor positions and
    /// their victim slots, and only then touches reservoir state.
    // lint: hot-path — dense-phase batch fill
    fn dense_batch(&mut self, items: &[T], scratch: &mut BatchScratch) -> u64 {
        let n = items.len();
        if n == 0 {
            return 0;
        }
        debug_assert!(self.cap < u32::MAX as usize);
        scratch.uniforms.clear();
        scratch.uniforms.resize(n, 0.0);
        self.rng.fill_f64(&mut scratch.uniforms);
        scratch.survivors.clear();
        scratch.survivors.resize(n, 0);
        scratch.victims.clear();
        scratch.victims.resize(n, 0);
        let cap = self.cap as f64;
        let mut seen = self.seen as f64;
        let mut cursor = 0usize;
        // Every lane writes at the cursor; the cursor only advances on
        // acceptance.  An accepted lane's write at position k is permanent
        // (later lanes write at cursor >= k+1), a rejected lane's write is
        // overwritten by the next lane or lies at the final cursor (never
        // read) — so positions 0..cursor end up holding exactly the
        // accepted lanes in stream order, with no data-dependent branch in
        // the loop body.  Conditioned on acceptance `r` is uniform on
        // [0, cap), so `r as u32` doubles as the victim index exactly as
        // the scalar step's `r as usize` does (rejected lanes' saturated
        // casts are never read).
        for (i, &u) in scratch.uniforms.iter().enumerate() {
            seen += 1.0;
            let r = u * seen;
            scratch.survivors[cursor] = i as u32;
            scratch.victims[cursor] = r as u32;
            cursor += (r < cap) as usize;
        }
        self.seen += n as u64;
        // Only now touch reservoir state, survivors only.
        for k in 0..cursor {
            self.buf[scratch.victims[k] as usize] = items[scratch.survivors[k] as usize];
        }
        cursor as u64
    }

    /// Engaged Algorithm-L phase over a slice: consume whole rejected runs
    /// with one subtraction, draw RNG only at acceptances (three draws
    /// each, identical to the scalar acceptance body).
    fn skip_batch(&mut self, mut rest: &[T]) -> u64 {
        let mut accepted = 0u64;
        loop {
            let n = rest.len() as u64;
            if n == 0 {
                return accepted;
            }
            if self.skip >= n {
                // The whole remaining run is rejected: O(1).
                self.skip -= n;
                self.seen += n;
                return accepted;
            }
            // `skip` rejected items, then one acceptance.
            let adv = self.skip as usize;
            self.seen += self.skip + 1;
            self.skip = 0;
            let item = rest[adv];
            rest = &rest[adv + 1..];
            let victim = self.rng.range_usize(0, self.cap);
            self.buf[victim] = item;
            self.w *= (self.unit().ln() / self.cap as f64).exp();
            self.schedule_skip();
            accepted += 1;
        }
    }
}

impl<T: Snapshot> Snapshot for Reservoir<T> {
    /// Full mid-stream state: capacity, residents, seen count, RNG stream,
    /// mode, and the Algorithm-L chain (engaged flag, pending skip,
    /// threshold `w`) — so a reservoir serialized mid-dense-phase or
    /// mid-skip continues offering bit-identically to one never paused.
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_usize(self.cap);
        self.buf.encode(w);
        w.put_u64(self.seen);
        self.rng.encode(w);
        w.put_u8(match self.mode {
            ReservoirMode::SkipAheadL => 0,
            ReservoirMode::DrawPerItem => 1,
        });
        w.put_bool(self.engaged);
        w.put_u64(self.skip);
        w.put_f64(self.w);
    }

    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        let cap = r.get_usize()?;
        let buf = Vec::<T>::decode(r)?;
        let seen = r.get_u64()?;
        let rng = Rng::decode(r)?;
        let mode = match r.get_u8()? {
            0 => ReservoirMode::SkipAheadL,
            1 => ReservoirMode::DrawPerItem,
            other => {
                return Err(Error::Io(format!("reservoir mode tag {other} (corrupt payload)")))
            }
        };
        Ok(Self {
            cap,
            buf,
            seen,
            rng,
            mode,
            engaged: r.get_bool()?,
            skip: r.get_u64()?,
            w: r.get_f64()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_up_to_capacity() {
        for mode in [ReservoirMode::SkipAheadL, ReservoirMode::DrawPerItem] {
            let mut r = Reservoir::with_mode(10, 1, mode);
            for i in 0..5 {
                r.offer(i);
            }
            assert_eq!(r.len(), 5);
            assert_eq!(r.items(), &[0, 1, 2, 3, 4]);
            for i in 5..100 {
                r.offer(i);
            }
            assert_eq!(r.len(), 10);
            assert_eq!(r.seen(), 100);
        }
    }

    #[test]
    fn sample_is_subset_of_input() {
        for mode in [ReservoirMode::SkipAheadL, ReservoirMode::DrawPerItem] {
            let mut r = Reservoir::with_mode(16, 2, mode);
            for i in 0..5000u32 {
                r.offer(i);
            }
            for &x in r.items() {
                assert!(x < 5000);
            }
            // no duplicates possible when input has no duplicates
            let mut v: Vec<u32> = r.items().to_vec();
            v.sort();
            v.dedup();
            assert_eq!(v.len(), 16);
        }
    }

    #[test]
    fn inclusion_probability_is_uniform() {
        // Each of 200 items should land in a cap-4 reservoir with p = 0.02;
        // run 5000 trials and check per-item frequencies — in both modes.
        // n/cap = 50 > ENGAGE_HORIZON, so the skip chain (including the
        // Beta re-seeded engagement) is exercised, not just the dense
        // phase.
        let n = 200u32;
        let cap = 4;
        let trials = 5000;
        for mode in [ReservoirMode::SkipAheadL, ReservoirMode::DrawPerItem] {
            let mut counts = vec![0u32; n as usize];
            for t in 0..trials {
                let mut r = Reservoir::with_mode(cap, t as u64, mode);
                for i in 0..n {
                    r.offer(i);
                }
                for &x in r.items() {
                    counts[x as usize] += 1;
                }
            }
            let p = cap as f64 / n as f64;
            let expect = trials as f64 * p; // 100
            for (i, &c) in counts.iter().enumerate() {
                let z = (c as f64 - expect) / (expect * (1.0 - p)).sqrt();
                assert!(z.abs() < 5.0, "{mode:?} item {i}: count {c} (z={z:.2})");
            }
        }
    }

    #[test]
    fn skip_chain_engages_past_horizon() {
        let mut r = Reservoir::new(4, 3);
        for i in 0..64 {
            r.offer(i);
        }
        assert!(!r.skip_engaged(), "dense phase up to 16*cap");
        for i in 64..80 {
            r.offer(i);
        }
        assert!(r.skip_engaged(), "engaged past the horizon");
        // draw-per-item never engages
        let mut d = Reservoir::with_mode(4, 3, ReservoirMode::DrawPerItem);
        for i in 0..1000 {
            d.offer(i);
        }
        assert!(!d.skip_engaged());
    }

    #[test]
    fn drain_resets() {
        let mut r = Reservoir::new(4, 3);
        for i in 0..200 {
            r.offer(i);
        }
        assert!(r.skip_engaged());
        let s = r.drain();
        assert_eq!(s.len(), 4);
        assert_eq!(r.len(), 0);
        assert_eq!(r.seen(), 0);
        assert!(!r.skip_engaged());
        for i in 0..2 {
            r.offer(i);
        }
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn zero_capacity_never_stores() {
        for mode in [ReservoirMode::SkipAheadL, ReservoirMode::DrawPerItem] {
            let mut r = Reservoir::with_mode(0, 4, mode);
            for i in 0..100 {
                r.offer(i);
            }
            assert_eq!(r.len(), 0);
            assert_eq!(r.seen(), 100);
        }
    }

    #[test]
    fn set_capacity_shrinks_and_grows() {
        let mut r = Reservoir::new(10, 5);
        for i in 0..10 {
            r.offer(i);
        }
        r.set_capacity(4);
        assert_eq!(r.len(), 4);
        r.set_capacity(20);
        assert_eq!(r.len(), 4); // existing items stay; room to grow
        for i in 10..26 {
            r.offer(i);
        }
        assert_eq!(r.len(), 20);
    }

    #[test]
    fn set_capacity_keeps_sampling_after_shrink() {
        // After shrinking onto a full buffer the skip state must restart
        // against the new capacity and acceptances must keep happening.
        let mut r = Reservoir::new(64, 6);
        for i in 0..64 {
            r.offer(i);
        }
        r.set_capacity(8);
        let before: Vec<i32> = r.items().to_vec();
        for i in 64..100_064 {
            r.offer(i);
        }
        assert_eq!(r.len(), 8);
        assert!(r.skip_engaged());
        assert_ne!(r.items(), &before[..], "no acceptance in 100k offers");
    }

    #[test]
    fn deterministic_for_seed() {
        for mode in [ReservoirMode::SkipAheadL, ReservoirMode::DrawPerItem] {
            let collect = |seed| {
                let mut r = Reservoir::with_mode(8, seed, mode);
                for i in 0..2000 {
                    r.offer(i);
                }
                r.items().to_vec()
            };
            assert_eq!(collect(42), collect(42));
            assert_ne!(collect(42), collect(43));
        }
    }

    #[test]
    fn offer_batch_is_byte_identical_to_offer() {
        // The batched kernel must replay the scalar RNG order exactly —
        // across both modes, all phases (fill, dense, engage boundary,
        // engaged skips), and any chunking of the stream.
        for mode in [ReservoirMode::SkipAheadL, ReservoirMode::DrawPerItem] {
            for cap in [0usize, 1, 4, 64] {
                for n in [0usize, 3, 40, 1_500, 12_000] {
                    for chunk in [1usize, 7, 512, usize::MAX] {
                        let items: Vec<f64> = (0..n).map(|i| i as f64).collect();
                        let mut scalar = Reservoir::with_mode(cap, 77, mode);
                        for &x in &items {
                            scalar.offer(x);
                        }
                        let mut batched = Reservoir::with_mode(cap, 77, mode);
                        let mut scratch = BatchScratch::default();
                        for c in items.chunks(chunk.min(n.max(1))) {
                            batched.offer_batch(c, &mut scratch);
                        }
                        let tag = format!("{mode:?} cap={cap} n={n} chunk={chunk}");
                        assert_eq!(batched.items(), scalar.items(), "{tag}");
                        assert_eq!(batched.seen(), scalar.seen(), "{tag}");
                        assert_eq!(batched.skip_engaged(), scalar.skip_engaged(), "{tag}");
                    }
                }
            }
        }
    }

    #[test]
    fn offer_batch_counts_reservoir_entries() {
        let mut r = Reservoir::with_mode(8, 5, ReservoirMode::DrawPerItem);
        let mut scratch = BatchScratch::default();
        let accepted = r.offer_batch(&(0..8).map(|i| i as f64).collect::<Vec<_>>(), &mut scratch);
        assert_eq!(accepted, 8, "fill phase accepts everything");
        let more = r.offer_batch(&(8..5000).map(|i| i as f64).collect::<Vec<_>>(), &mut scratch);
        // E[accepts] = sum_{i=9..5000} 8/i ~ 8 ln(5000/8) ~ 51; just check
        // it is in a sane band and that the buffer stayed full.
        assert!(more > 10 && more < 200, "accepted {more}");
        assert_eq!(r.len(), 8);
    }

    #[test]
    fn offer_with_uniform_matches_draw_per_item_law() {
        // Feeding externally drawn uniforms through offer_with_uniform must
        // reproduce DrawPerItem exactly when given the same uniform stream.
        let mut a = Reservoir::with_mode(4, 11, ReservoirMode::DrawPerItem);
        let mut b = Reservoir::with_mode(4, 11, ReservoirMode::DrawPerItem);
        let mut feed = Rng::seed_from_u64(11);
        for i in 0..500 {
            a.offer(i as f64);
            // b's own RNG is untouched; replay the same stream externally.
            if i < 4 {
                b.offer_with_uniform(i as f64, 0.0);
            } else {
                b.offer_with_uniform(i as f64, feed.f64());
            }
        }
        // a consumed its seeded stream starting after the fill phase; mirror
        // by burning none for the first cap items (offer's fill phase draws
        // nothing).
        assert_eq!(a.items(), b.items());
    }

    #[test]
    fn skip_mode_is_the_default() {
        let r: Reservoir<u8> = Reservoir::new(4, 1);
        assert_eq!(r.mode(), ReservoirMode::SkipAheadL);
        // Below the horizon the two modes consume RNG identically, so the
        // same seed produces the same residents.
        let collect = |mode| {
            let mut r = Reservoir::with_mode(8, 9, mode);
            for i in 0..100 {
                r.offer(i);
            }
            r.items().to_vec()
        };
        assert_eq!(
            collect(ReservoirMode::SkipAheadL),
            collect(ReservoirMode::DrawPerItem)
        );
    }
}
