//! Classic reservoir sampling (paper Algorithm 1; Vitter, TOMS '85).
//!
//! Maintains a uniform random sample of fixed capacity over a stream of
//! unknown length: the first `cap` items fill the reservoir; the i-th item
//! (i > cap) is accepted with probability `cap / i` and replaces a uniformly
//! random resident.

use crate::util::rng::Rng;

/// A fixed-capacity uniform reservoir over `T`.
#[derive(Debug, Clone)]
pub struct Reservoir<T> {
    cap: usize,
    buf: Vec<T>,
    seen: u64,
    rng: Rng,
}

impl<T> Reservoir<T> {
    /// Create a reservoir with capacity `cap` (>= 1 unless you want an
    /// always-empty sampler, which is permitted for capacity 0).
    pub fn new(cap: usize, seed: u64) -> Self {
        Self { cap, buf: Vec::with_capacity(cap.min(1024)), seen: 0, rng: Rng::seed_from_u64(seed) }
    }

    /// Offer one item (Algorithm 1 body).
    ///
    /// Hot path: a single RNG draw per item.  `r` is uniform on [0, seen);
    /// the item is accepted iff `r < cap`, and *conditioned on acceptance*
    /// `r` is uniform on [0, cap) — so `floor(r)` doubles as the victim
    /// index with no second draw (f64 has 53 bits; bias is ~2⁻⁵³ per item,
    /// far below measurement noise — cross-checked by the uniformity test).
    #[inline]
    pub fn offer(&mut self, item: T) {
        self.seen += 1;
        if self.buf.len() < self.cap {
            self.buf.push(item);
            return;
        }
        if self.cap == 0 {
            return;
        }
        let r = self.rng.f64() * self.seen as f64;
        if r < self.cap as f64 {
            self.buf[r as usize] = item;
        }
    }

    /// Items observed so far.
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Current sample size (== min(cap, seen)).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Borrow the current sample.
    pub fn items(&self) -> &[T] {
        &self.buf
    }

    /// Take the sample and reset counters (new interval), keeping capacity.
    pub fn drain(&mut self) -> Vec<T> {
        self.seen = 0;
        std::mem::take(&mut self.buf)
    }

    /// Change capacity for the next interval (adaptive budgets). Shrinking
    /// truncates uniformly (the resident set is already uniform, and a
    /// uniform subset of a uniform sample is uniform).
    pub fn set_capacity(&mut self, cap: usize) {
        self.cap = cap;
        if self.buf.len() > cap {
            // Shuffle then truncate to keep the subset unbiased.
            self.rng.shuffle(&mut self.buf);
            self.buf.truncate(cap);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_up_to_capacity() {
        let mut r = Reservoir::new(10, 1);
        for i in 0..5 {
            r.offer(i);
        }
        assert_eq!(r.len(), 5);
        assert_eq!(r.items(), &[0, 1, 2, 3, 4]);
        for i in 5..100 {
            r.offer(i);
        }
        assert_eq!(r.len(), 10);
        assert_eq!(r.seen(), 100);
    }

    #[test]
    fn sample_is_subset_of_input() {
        let mut r = Reservoir::new(16, 2);
        for i in 0..1000u32 {
            r.offer(i);
        }
        for &x in r.items() {
            assert!(x < 1000);
        }
        // no duplicates possible when input has no duplicates
        let mut v: Vec<u32> = r.items().to_vec();
        v.sort();
        v.dedup();
        assert_eq!(v.len(), 16);
    }

    #[test]
    fn inclusion_probability_is_uniform() {
        // Each of 100 items should land in a cap-10 reservoir with p = 0.1;
        // run 5000 trials and check per-item frequencies.
        let n = 100u32;
        let cap = 10;
        let trials = 5000;
        let mut counts = vec![0u32; n as usize];
        for t in 0..trials {
            let mut r = Reservoir::new(cap, t as u64);
            for i in 0..n {
                r.offer(i);
            }
            for &x in r.items() {
                counts[x as usize] += 1;
            }
        }
        let expect = trials as f64 * cap as f64 / n as f64; // 500
        for (i, &c) in counts.iter().enumerate() {
            let z = (c as f64 - expect) / (expect * (1.0 - 0.1)).sqrt();
            assert!(z.abs() < 5.0, "item {i}: count {c} (z={z:.2})");
        }
    }

    #[test]
    fn drain_resets() {
        let mut r = Reservoir::new(4, 3);
        for i in 0..20 {
            r.offer(i);
        }
        let s = r.drain();
        assert_eq!(s.len(), 4);
        assert_eq!(r.len(), 0);
        assert_eq!(r.seen(), 0);
        for i in 0..2 {
            r.offer(i);
        }
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn zero_capacity_never_stores() {
        let mut r = Reservoir::new(0, 4);
        for i in 0..100 {
            r.offer(i);
        }
        assert_eq!(r.len(), 0);
        assert_eq!(r.seen(), 100);
    }

    #[test]
    fn set_capacity_shrinks_and_grows() {
        let mut r = Reservoir::new(10, 5);
        for i in 0..10 {
            r.offer(i);
        }
        r.set_capacity(4);
        assert_eq!(r.len(), 4);
        r.set_capacity(20);
        assert_eq!(r.len(), 4); // existing items stay; room to grow
        for i in 10..26 {
            r.offer(i);
        }
        assert_eq!(r.len(), 20);
    }

    #[test]
    fn deterministic_for_seed() {
        let collect = |seed| {
            let mut r = Reservoir::new(8, seed);
            for i in 0..500 {
                r.offer(i);
            }
            r.items().to_vec()
        };
        assert_eq!(collect(42), collect(42));
        assert_ne!(collect(42), collect(43));
    }
}
