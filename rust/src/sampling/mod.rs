//! Sampling algorithms (paper §2.4, §3.2, §4.1).
//!
//! * [`reservoir`] — classic reservoir sampling (Algorithm 1, Vitter '85)
//!   with an Algorithm-L geometric-skip fast path (Li '94) that engages
//!   once skips are long enough to amortize their acceptance cost.
//! * [`oasrs`] — **O**nline **A**daptive **S**tratified **R**eservoir
//!   **S**ampling, the paper's contribution (Algorithm 3): per-stratum
//!   reservoirs + arrival counters, weights by Eq. (1), no synchronization.
//! * [`srs`] — Spark-style Simple Random Sampling (`sample`): random-sort
//!   with (p, q) thresholds [Meng, ICML'13], batch-fashion.
//! * [`sts`] — Spark-style Stratified Sampling (`sampleByKey`): groupBy on
//!   strata + per-stratum random-sort, batch-fashion, with the cross-worker
//!   synchronization the paper blames for its poor scaling.
//! * [`weighted`] — A-ExpJ weighted reservoir sampling (Efraimidis &
//!   Spirakis `key = u^(1/w)` with exponential jumps): value-weighted
//!   sub-streams sampled proportionally to the mass they carry.
//! * native (no sampling) is represented by [`NoopSampler`].
//!
//! All samplers emit a [`SampleResult`] per interval: the selected items and
//! the per-stratum bookkeeping ([`StrataState`]) the estimator needs.  The
//! SRS/STS baselines encode their uniform / proportional designs in the
//! `n_cap` field so the single weight law Eq. (1) reproduces their
//! Horvitz-Thompson weights (see each module's docs).

pub mod oasrs;
pub mod reservoir;
pub mod srs;
pub mod sts;
pub mod weighted;

use crate::core::{ColumnarChunk, Error, Item, Result};
use crate::error::estimator::StrataState;
use crate::runtime::checkpoint::{Snapshot, SnapshotReader, SnapshotWriter};

pub use oasrs::OasrsSampler;
pub use reservoir::{Reservoir, ReservoirMode};
pub use srs::SrsSampler;
pub use sts::StsSampler;
pub use weighted::{WeightedResSampler, WeightedReservoir};

/// Which sampling algorithm a pipeline uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SamplerKind {
    /// The paper's online adaptive stratified reservoir sampling.
    Oasrs,
    /// Spark-style simple random sampling (`sample`).
    Srs,
    /// Spark-style stratified sampling (`sampleByKey`/`sampleByKeyExact`).
    Sts,
    /// A-ExpJ weighted reservoir sampling (value-weighted inclusion).
    WeightedRes,
    /// No sampling — native execution.
    None,
}

impl SamplerKind {
    pub fn label(self) -> &'static str {
        match self {
            SamplerKind::Oasrs => "streamapprox",
            SamplerKind::Srs => "spark-srs",
            SamplerKind::Sts => "spark-sts",
            SamplerKind::WeightedRes => "weighted-res",
            SamplerKind::None => "native",
        }
    }

    /// True for batch-fashion samplers that must buffer the whole interval
    /// (the Spark baselines); OASRS and native stream item-at-a-time.
    pub fn is_batch_fashion(self) -> bool {
        matches!(self, SamplerKind::Srs | SamplerKind::Sts)
    }

    /// Stable numeric tag used in snapshot frames and config fingerprints.
    pub fn tag(self) -> u8 {
        match self {
            SamplerKind::Oasrs => 0,
            SamplerKind::Srs => 1,
            SamplerKind::Sts => 2,
            SamplerKind::WeightedRes => 3,
            SamplerKind::None => 4,
        }
    }

    /// Inverse of [`SamplerKind::tag`].
    pub fn from_tag(tag: u8) -> Result<Self> {
        Ok(match tag {
            0 => SamplerKind::Oasrs,
            1 => SamplerKind::Srs,
            2 => SamplerKind::Sts,
            3 => SamplerKind::WeightedRes,
            4 => SamplerKind::None,
            other => return Err(Error::Io(format!("unknown sampler tag {other} in snapshot"))),
        })
    }
}

/// How a sampler's columnar kernel consumes randomness (ISSUE 7).
///
/// The default is [`ColumnarMode::Exact`]: batched kernels replay each
/// reservoir's RNG stream in exactly the scalar order, so `offer_columnar`
/// is byte-identical to `offer`/`offer_slice` for a fixed seed regardless
/// of chunking.  [`ColumnarMode::Masked`] trades that replay for a single
/// chunk-level 8-wide uniform fill from a dedicated mask stream — the draw
/// *order* deliberately differs from the scalar path (it could not be
/// byte-identical), so equivalence is pinned statistically by the
/// chi-square inclusion suite instead.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ColumnarMode {
    /// Scalar-order RNG replay: byte-identical to `offer()` per seed.
    #[default]
    Exact,
    /// Chunk-level Bernoulli-mask kernel from a dedicated uniform stream:
    /// exactly uniform inclusion, different stream — statistically (not
    /// byte-) equivalent.
    Masked,
}

/// The per-interval output of a sampler.
#[derive(Debug, Clone, Default)]
pub struct SampleResult {
    /// Selected items as (stratum, value) pairs.
    pub sample: Vec<(u16, f64)>,
    /// Per-stratum arrival counters + effective capacities for Eq. (1).
    pub state: StrataState,
}

impl SampleResult {
    /// Total arrived items this interval.
    pub fn arrived(&self) -> f64 {
        self.state.total_c()
    }

    /// Achieved sampling fraction.
    ///
    /// **Empty intervals**: when nothing arrived (`arrived() == 0`) the
    /// fraction is defined as `0.0` rather than `NaN`/`inf`, so budget
    /// feedback and metrics aggregation stay finite across idle intervals.
    /// A non-empty sample with zero arrivals is impossible by construction
    /// (every sampler counts an arrival before it can select the item);
    /// this is asserted in debug builds.
    pub fn fraction(&self) -> f64 {
        let c = self.arrived();
        if c == 0.0 {
            debug_assert!(self.sample.is_empty(), "sample without arrivals");
            0.0
        } else {
            self.sample.len() as f64 / c
        }
    }

    /// True when nothing arrived in the interval.
    pub fn is_empty(&self) -> bool {
        self.arrived() == 0.0 && self.sample.is_empty()
    }
}

/// Common interface: offer items during the interval, then finish it.
pub trait Sampler: Send {
    /// Offer one arriving item.
    fn offer(&mut self, item: &Item);

    /// Offer a contiguous batch of items.
    ///
    /// Semantically identical to calling [`Sampler::offer`] per item (the
    /// chunk-size determinism tests assert exactly that), but lets
    /// implementations amortize per-item overhead — one virtual/enum
    /// dispatch per chunk instead of per item, buffer `reserve`s, and tight
    /// monomorphic loops.  Implementations must keep the per-item RNG
    /// consumption identical to the one-at-a-time path so seeded runs do
    /// not depend on how the stream was chunked.
    fn offer_slice(&mut self, items: &[Item]) {
        for item in items {
            self.offer(item);
        }
    }

    /// Offer a struct-of-arrays chunk (the columnar ingest path).
    ///
    /// The default reassembles each item on the stack and bridges to
    /// [`Sampler::offer`] — zero allocation and semantically identical to
    /// `offer_slice` of the transposed chunk, so samplers without a
    /// columnar kernel (`WeightedRes`, `Noop`) keep working unchanged.
    /// SRS/STS/OASRS override this with real columnar kernels (batched
    /// RNG, branchless acceptance); under [`ColumnarMode::Exact`] (the
    /// default everywhere) those overrides remain byte-identical to the
    /// scalar path for a fixed seed.
    fn offer_columnar(&mut self, chunk: &ColumnarChunk) {
        for i in 0..chunk.len() {
            self.offer(&Item::new(chunk.strata[i], chunk.values[i], chunk.ts[i]));
        }
    }

    /// Close the current interval: emit the sample + strata bookkeeping and
    /// reset for the next interval.
    fn finish_interval(&mut self) -> SampleResult;

    /// Re-target the sampler (adaptive budgets — fraction in (0, 1]).
    fn set_fraction(&mut self, fraction: f64);

    /// Algorithm tag.
    fn kind(&self) -> SamplerKind;
}

/// Native execution: keep every item, weight 1.
#[derive(Debug, Default)]
pub struct NoopSampler {
    buf: Vec<(u16, f64)>,
    state: StrataState,
}

impl NoopSampler {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Sampler for NoopSampler {
    fn offer(&mut self, item: &Item) {
        let s = item.stratum as usize;
        if s < crate::core::MAX_STRATA {
            self.buf.push((item.stratum, item.value));
            self.state.c[s] += 1.0;
            // capacity tracks arrivals so C_i <= N_i and Eq. (1) gives 1.
            self.state.n_cap[s] = self.state.c[s];
        } else {
            // Out-of-range strata used to vanish silently; surface them.
            crate::metrics::record_dropped_item();
        }
    }

    fn offer_slice(&mut self, items: &[Item]) {
        // Native execution keeps everything: one reservation per chunk,
        // then a tight push loop.
        self.buf.reserve(items.len());
        for item in items {
            self.offer(item);
        }
    }

    fn offer_columnar(&mut self, chunk: &ColumnarChunk) {
        // Same as the trait default (per-item bridge), plus the chunk-level
        // reservation offer_slice makes.
        self.buf.reserve(chunk.len());
        for i in 0..chunk.len() {
            self.offer(&Item::new(chunk.strata[i], chunk.values[i], chunk.ts[i]));
        }
    }

    fn finish_interval(&mut self) -> SampleResult {
        let sample = std::mem::take(&mut self.buf);
        let state = self.state;
        self.state = StrataState::default();
        SampleResult { sample, state }
    }

    fn set_fraction(&mut self, _fraction: f64) {}

    fn kind(&self) -> SamplerKind {
        SamplerKind::None
    }
}

impl Snapshot for SampleResult {
    fn encode(&self, w: &mut SnapshotWriter) {
        self.sample.encode(w);
        self.state.encode(w);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        Ok(Self { sample: Vec::<(u16, f64)>::decode(r)?, state: StrataState::decode(r)? })
    }
}

impl Snapshot for NoopSampler {
    fn encode(&self, w: &mut SnapshotWriter) {
        self.buf.encode(w);
        self.state.encode(w);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        Ok(Self { buf: Vec::<(u16, f64)>::decode(r)?, state: StrataState::decode(r)? })
    }
}

/// Construct a sampler of the given kind with an initial sampling fraction.
///
/// `seed` makes every sampler deterministic for a fixed workload.
pub fn make_sampler(kind: SamplerKind, fraction: f64, seed: u64) -> Box<dyn Sampler> {
    match kind {
        SamplerKind::Oasrs => Box::new(OasrsSampler::new(fraction, seed)),
        SamplerKind::Srs => Box::new(SrsSampler::new(fraction, seed)),
        SamplerKind::Sts => Box::new(StsSampler::new(fraction, seed)),
        SamplerKind::WeightedRes => Box::new(WeightedResSampler::new(fraction, seed)),
        SamplerKind::None => Box::new(NoopSampler::new()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_keeps_everything_with_weight_one() {
        let mut s = NoopSampler::new();
        for i in 0..100 {
            s.offer(&Item::new((i % 4) as u16, i as f64, i));
        }
        let r = s.finish_interval();
        assert_eq!(r.sample.len(), 100);
        assert_eq!(r.arrived(), 100.0);
        assert_eq!(r.fraction(), 1.0);
        let est = crate::error::estimator::estimate(
            &crate::error::estimator::StrataPartials::from_sample(&r.sample),
            &r.state,
        );
        // exact: sum of 0..99
        assert!((est.sum - 4950.0).abs() < 1e-9);
        assert_eq!(est.var_sum, 0.0);
    }

    #[test]
    fn noop_interval_reset() {
        let mut s = NoopSampler::new();
        s.offer(&Item::new(0, 1.0, 0));
        let r1 = s.finish_interval();
        assert_eq!(r1.sample.len(), 1);
        let r2 = s.finish_interval();
        assert_eq!(r2.sample.len(), 0);
        assert_eq!(r2.arrived(), 0.0);
    }

    #[test]
    fn factory_returns_right_kinds() {
        for kind in [
            SamplerKind::Oasrs,
            SamplerKind::Srs,
            SamplerKind::Sts,
            SamplerKind::WeightedRes,
            SamplerKind::None,
        ] {
            let s = make_sampler(kind, 0.5, 1);
            assert_eq!(s.kind(), kind);
        }
    }

    #[test]
    fn labels_match_paper_naming() {
        assert_eq!(SamplerKind::Oasrs.label(), "streamapprox");
        assert_eq!(SamplerKind::WeightedRes.label(), "weighted-res");
        assert!(SamplerKind::Srs.is_batch_fashion());
        assert!(SamplerKind::Sts.is_batch_fashion());
        assert!(!SamplerKind::Oasrs.is_batch_fashion());
        assert!(!SamplerKind::WeightedRes.is_batch_fashion());
    }

    #[test]
    fn noop_counts_out_of_range_drops() {
        let before = crate::metrics::dropped_items();
        let mut s = NoopSampler::new();
        s.offer(&Item::new(999, 1.0, 0));
        s.offer(&Item::new(0, 1.0, 0));
        // other tests may drop concurrently; the counter is monotone
        assert!(crate::metrics::dropped_items() >= before + 1);
        let r = s.finish_interval();
        assert_eq!(r.sample.len(), 1);
    }

    #[test]
    fn empty_interval_fraction_is_zero() {
        let mut s = NoopSampler::new();
        let r = s.finish_interval();
        assert!(r.is_empty());
        assert_eq!(r.fraction(), 0.0);
        assert_eq!(r.arrived(), 0.0);
    }
}
