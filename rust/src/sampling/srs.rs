//! Spark-style Simple Random Sampling — the `sample` operator baseline
//! (paper §4.1.1).
//!
//! Spark implements SRS by *random sort* [Meng, ICML '13]: assign each item
//! a uniform key in [0,1], then take the `k` items with the smallest keys.
//! Sorting the whole batch is the bottleneck, so Spark narrows it with two
//! thresholds `p < q`: items with key < `p` are accepted outright, items
//! with key > `q` are discarded outright, and only the (small) middle region
//! is sorted.  We reproduce that algorithm — including its batch fashion:
//! the whole interval is buffered (the "RDD") before sampling runs, which is
//! exactly the overhead StreamApprox's on-the-fly sampling avoids.
//!
//! **Estimation**: an SRS sample is uniform over the whole batch, so every
//! selected item represents `C_total / k` originals.  We encode that in the
//! per-stratum capacities as `n_cap_i = C_i · k / C_total`, which makes the
//! shared weight law Eq. (1) produce exactly the SRS Horvitz-Thompson weight
//! `C_total / k` for every stratum.

use crate::core::{ColumnarChunk, Error, Item, Result, MAX_STRATA};
use crate::error::estimator::StrataState;
use crate::runtime::checkpoint::{Snapshot, SnapshotReader, SnapshotWriter};
use crate::util::rng::Rng;

use super::{SampleResult, Sampler, SamplerKind};

// The columnar histogram pass masks stratum ids with `MAX_STRATA - 1`.
const _: () = assert!(MAX_STRATA.is_power_of_two());

/// Spark-`sample`-style simple random sampler (batch fashion).
///
/// The buffered batch (the "RDD") is stored struct-of-arrays — parallel
/// stratum/value columns — so the columnar ingest path appends a whole
/// [`ColumnarChunk`] with two column `memcpy`s plus a count pass, instead
/// of one tuple push per item.  The batch *buffering itself* stays: it is
/// the baseline cost signature the paper charges Spark's `sample` with.
#[derive(Debug)]
pub struct SrsSampler {
    fraction: f64,
    /// Stratum column of the buffered batch ("RDD").
    batch_strata: Vec<u16>,
    /// Value column, parallel to `batch_strata`.
    batch_values: Vec<f64>,
    counters: [f64; MAX_STRATA],
    rng: Rng,
    /// Random-sort key scratch, reused across intervals (the per-interval
    /// key `Vec` rebuild was a measurable allocation hot spot).
    keys: Vec<f64>,
}

impl SrsSampler {
    pub fn new(fraction: f64, seed: u64) -> Self {
        Self {
            fraction: fraction.clamp(1e-4, 1.0),
            batch_strata: Vec::new(),
            batch_values: Vec::new(),
            counters: [0.0; MAX_STRATA],
            rng: Rng::seed_from_u64(seed),
            keys: Vec::new(),
        }
    }

    /// Random-sort selection of `k` items from `n` using the (p, q)
    /// threshold optimization. Returns selected indices.  `keys` is a
    /// caller-owned scratch buffer (resized and overwritten here) filled by
    /// the batched `fill_f64` — same draw order as the former per-item
    /// `rng.f64()` loop, so selections are byte-identical.
    fn random_sort_select(rng: &mut Rng, keys: &mut Vec<f64>, n: usize, k: usize) -> Vec<usize> {
        if k >= n {
            return (0..n).collect();
        }
        // Keys for every item, 8-wide into the reused scratch.
        keys.clear();
        keys.resize(n, 0.0);
        rng.fill_f64(keys);
        // Thresholds around k/n; the slack keeps P(middle misses the true
        // k-th key) negligible (Chernoff), same construction as Spark's.
        let ratio = k as f64 / n as f64;
        let slack = 8.0 * (ratio * (1.0 - ratio) / n as f64).sqrt() + 16.0 / n as f64;
        let p = (ratio - slack).max(0.0);
        let q = (ratio + slack).min(1.0);

        let mut accepted: Vec<usize> = Vec::with_capacity(k + 16);
        let mut middle: Vec<usize> = Vec::new();
        for (i, &key) in keys.iter().enumerate() {
            if key < p {
                accepted.push(i);
            } else if key <= q {
                middle.push(i);
            }
        }
        if accepted.len() > k {
            // Rare slack failure: fall back to sorting the accepted region.
            accepted.sort_by(|&a, &b| keys[a].partial_cmp(&keys[b]).unwrap());
            accepted.truncate(k);
            return accepted;
        }
        // Sort only the middle region and top up.
        middle.sort_by(|&a, &b| keys[a].partial_cmp(&keys[b]).unwrap());
        let need = k - accepted.len();
        accepted.extend(middle.into_iter().take(need));
        accepted
    }
}

impl Sampler for SrsSampler {
    #[inline]
    fn offer(&mut self, item: &Item) {
        let s = item.stratum as usize;
        if s >= MAX_STRATA {
            crate::metrics::record_dropped_item();
            return;
        }
        // Batch fashion: buffer everything (this buffering is the cost
        // StreamApprox's pre-RDD sampling avoids).
        self.batch_strata.push(item.stratum);
        self.batch_values.push(item.value);
        self.counters[s] += 1.0;
    }

    fn offer_slice(&mut self, items: &[Item]) {
        // One buffer reservation per chunk, then a tight append loop.
        self.batch_strata.reserve(items.len());
        self.batch_values.reserve(items.len());
        for item in items {
            self.offer(item);
        }
    }

    // lint: hot-path — fused max/histogram + column memcpy kernel
    fn offer_columnar(&mut self, chunk: &ColumnarChunk) {
        // Columnar kernel: when every stratum is in range (the common case,
        // checked while counting), appending the chunk is two column memcpys
        // plus one fused max-scan/histogram pass — no per-item Item
        // reassembly, no per-item bounds branch.  The histogram accumulates
        // in u64 (`s & (MAX_STRATA-1)` is a no-op when max_s is in range,
        // and the pass is discarded otherwise), then folds into the f64
        // counters once per chunk: per-item `counters[s] += 1.0` forms
        // fp-add latency chains that alone cost more than the two memcpys.
        let mut hist = [0u64; MAX_STRATA];
        let mut max_s = 0u16;
        for &s in &chunk.strata {
            max_s = max_s.max(s);
            hist[(s as usize) & (MAX_STRATA - 1)] += 1;
        }
        if (max_s as usize) < MAX_STRATA {
            self.batch_strata.extend_from_slice(&chunk.strata);
            self.batch_values.extend_from_slice(&chunk.values);
            for (c, h) in self.counters.iter_mut().zip(hist) {
                *c += h as f64;
            }
        } else {
            // Rare: out-of-range strata present — per-item path with drops.
            for i in 0..chunk.len() {
                self.offer(&Item::new(chunk.strata[i], chunk.values[i], chunk.ts[i]));
            }
        }
    }

    fn finish_interval(&mut self) -> SampleResult {
        let n = self.batch_values.len();
        let k = ((self.fraction * n as f64).round() as usize).min(n);

        let selected = Self::random_sort_select(&mut self.rng, &mut self.keys, n, k);
        let k_actual = selected.len();
        let sample: Vec<(u16, f64)> = selected
            .into_iter()
            .map(|i| (self.batch_strata[i], self.batch_values[i]))
            .collect();
        // Keep the columns' capacity across intervals — batch *fashion* is
        // the baseline's signature, per-interval reallocation is not.
        self.batch_strata.clear();
        self.batch_values.clear();

        // Global uniform weight C_total / k — exactly what Spark's `sample`
        // gives you: a uniform sample with NO per-stratum bookkeeping, so
        // every selected item represents C_total/k originals regardless of
        // stratum.  Encoded via n_cap_i = C_i·k/C_total so Eq. (1)
        // reproduces that weight.  This is deliberately NOT post-stratified:
        // the randomness of the per-stratum allocation Y_i goes unmodelled,
        // which both inflates SRS's real error on skewed streams and makes
        // its error bounds unreliable — the paper's core argument for
        // stratified sampling (§2.4, §5.2), and a property the integration
        // tests assert.
        let mut state = StrataState::default();
        let c_total: f64 = self.counters.iter().sum();
        for s in 0..MAX_STRATA {
            state.c[s] = self.counters[s];
            state.n_cap[s] = if c_total > 0.0 && (k_actual as f64) < c_total {
                self.counters[s] * k_actual as f64 / c_total
            } else {
                self.counters[s]
            };
        }
        self.counters = [0.0; MAX_STRATA];
        SampleResult { sample, state }
    }

    fn set_fraction(&mut self, fraction: f64) {
        self.fraction = fraction.clamp(1e-4, 1.0);
    }

    fn kind(&self) -> SamplerKind {
        SamplerKind::Srs
    }
}

/// SRS checkpoint state: the buffered batch columns, the counters, and —
/// critically — the random-sort RNG stream.  SRS clears its batch at every
/// `finish_interval`, but the RNG advances monotonically across intervals,
/// so a boundary snapshot that dropped it would diverge on the very next
/// selection.  The `keys` scratch is derived (overwritten before each use)
/// and is rebuilt empty.
impl Snapshot for SrsSampler {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_f64(self.fraction);
        self.batch_strata.encode(w);
        self.batch_values.encode(w);
        self.counters.encode(w);
        self.rng.encode(w);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        let fraction = r.get_f64()?;
        let batch_strata = Vec::<u16>::decode(r)?;
        let batch_values = Vec::<f64>::decode(r)?;
        if batch_strata.len() != batch_values.len() {
            return Err(Error::Io(format!(
                "SRS snapshot column mismatch: {} strata vs {} values",
                batch_strata.len(),
                batch_values.len()
            )));
        }
        Ok(Self {
            fraction,
            batch_strata,
            batch_values,
            counters: <[f64; MAX_STRATA]>::decode(r)?,
            rng: Rng::decode(r)?,
            keys: Vec::new(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::estimator::{estimate, StrataPartials};

    fn feed_uniform(s: &mut SrsSampler, n: usize, strata: usize) {
        for i in 0..n {
            s.offer(&Item::new((i % strata) as u16, i as f64, i as u64));
        }
    }

    #[test]
    fn samples_requested_fraction() {
        let mut s = SrsSampler::new(0.3, 1);
        feed_uniform(&mut s, 10_000, 4);
        let r = s.finish_interval();
        let got = r.sample.len() as f64 / 10_000.0;
        assert!((got - 0.3).abs() < 0.001, "fraction {got}");
    }

    #[test]
    fn full_fraction_keeps_everything() {
        let mut s = SrsSampler::new(1.0, 2);
        feed_uniform(&mut s, 500, 3);
        let r = s.finish_interval();
        assert_eq!(r.sample.len(), 500);
        // weights should be 1 -> estimate exact
        let est = estimate(&StrataPartials::from_sample(&r.sample), &r.state);
        let exact: f64 = (0..500).map(|i| i as f64).sum();
        assert!((est.sum - exact).abs() < 1e-6);
    }

    #[test]
    fn weights_are_global_uniform_horvitz_thompson() {
        let mut s = SrsSampler::new(0.25, 3);
        feed_uniform(&mut s, 8000, 4);
        let r = s.finish_interval();
        let k = r.sample.len() as f64;
        let est = estimate(&StrataPartials::from_sample(&r.sample), &r.state);
        for i in 0..4 {
            let expected = 8000.0 / k;
            assert!(
                (est.weights[i] - expected).abs() / expected < 1e-9,
                "stratum {i} weight {} != {expected}",
                est.weights[i]
            );
        }
    }

    #[test]
    fn estimate_unbiased_on_uniform_stream() {
        let mut errs = Vec::new();
        for seed in 0..20 {
            let mut s = SrsSampler::new(0.2, seed);
            let mut rng = Rng::seed_from_u64(1000 + seed);
            let mut exact = 0.0;
            for _ in 0..5000 {
                let v = rng.normal(100.0, 10.0);
                s.offer(&Item::new(0, v, 0));
                exact += v;
            }
            let r = s.finish_interval();
            let est = estimate(&StrataPartials::from_sample(&r.sample), &r.state);
            errs.push((est.sum - exact) / exact);
        }
        let mean_err = errs.iter().sum::<f64>() / errs.len() as f64;
        assert!(mean_err.abs() < 0.01, "bias {mean_err}");
    }

    #[test]
    fn can_overlook_tiny_stratum() {
        // The documented SRS failure mode (paper §2.4): with a very small
        // sub-stream and small fraction, some runs miss the stratum.
        let mut missed = 0;
        for seed in 0..50 {
            let mut s = SrsSampler::new(0.05, seed);
            for i in 0..10_000 {
                s.offer(&Item::new(0, 1.0, i));
            }
            for _ in 0..3 {
                s.offer(&Item::new(2, 1_000_000.0, 0));
            }
            let r = s.finish_interval();
            if !r.sample.iter().any(|(st, _)| *st == 2) {
                missed += 1;
            }
        }
        assert!(missed > 5, "SRS should sometimes miss the rare stratum (missed {missed}/50)");
    }

    #[test]
    fn selection_is_unbiased_per_item() {
        // Every item equally likely under random-sort selection.
        let n = 200;
        let k = 20;
        let trials = 3000;
        let mut counts = vec![0u32; n];
        let mut keys = Vec::new();
        for t in 0..trials {
            let mut rng = Rng::seed_from_u64(t);
            for i in SrsSampler::random_sort_select(&mut rng, &mut keys, n, k) {
                counts[i] += 1;
            }
        }
        let expect = trials as f64 * k as f64 / n as f64;
        for (i, &c) in counts.iter().enumerate() {
            let z = (c as f64 - expect) / (expect * (1.0 - k as f64 / n as f64)).sqrt();
            assert!(z.abs() < 5.0, "item {i}: {c} vs {expect} (z {z:.2})");
        }
    }

    #[test]
    fn interval_reset() {
        let mut s = SrsSampler::new(0.5, 9);
        feed_uniform(&mut s, 100, 2);
        s.finish_interval();
        let r2 = s.finish_interval();
        assert!(r2.sample.is_empty());
        assert_eq!(r2.arrived(), 0.0);
    }

    #[test]
    fn offer_columnar_is_byte_identical_to_offer() {
        for chunk_size in [1usize, 17, 512, usize::MAX] {
            let mut items: Vec<Item> = (0..5000)
                .map(|i| Item::new((i % 4) as u16, i as f64, i as u64))
                .collect();
            items.push(Item::new(999, 1.0, 5000)); // forces the fallback path
            let mut scalar = SrsSampler::new(0.1, 5);
            let mut columnar = SrsSampler::new(0.1, 5);
            for _ in 0..2 {
                for it in &items {
                    scalar.offer(it);
                }
                for c in items.chunks(chunk_size.min(items.len())) {
                    columnar.offer_columnar(&ColumnarChunk::from_items(c));
                }
                let a = scalar.finish_interval();
                let b = columnar.finish_interval();
                assert_eq!(a.sample, b.sample, "chunk {chunk_size}");
                assert_eq!(a.state.c, b.state.c, "chunk {chunk_size}");
            }
        }
    }

    #[test]
    fn empty_interval_ok() {
        let mut s = SrsSampler::new(0.5, 10);
        let r = s.finish_interval();
        assert!(r.sample.is_empty());
        let est = estimate(&StrataPartials::from_sample(&r.sample), &r.state);
        assert_eq!(est.sum, 0.0);
    }

    use crate::util::rng::Rng;
}
