//! # StreamApprox
//!
//! A reproduction of *"Approximate Stream Analytics in Apache Flink and
//! Apache Spark Streaming"* (StreamApprox): approximate computing for stream
//! analytics via **Online Adaptive Stratified Reservoir Sampling (OASRS)**,
//! with rigorous error bounds, generic over batched (Spark-Streaming-like)
//! and pipelined (Flink-like) stream processing models.
//!
//! The library is a three-layer system:
//! * **L3 (this crate)** — the streaming coordinator: broker, samplers,
//!   engines, windows, queries, error estimation, budgets, metrics.
//! * **L2/L1 (build time)** — the per-window aggregation job as a JAX graph
//!   wrapping a Pallas kernel, AOT-lowered to HLO text in `artifacts/` and
//!   executed through [`runtime`] (PJRT CPU). Python never runs at runtime.
//!
//! Within L3, query execution itself splits in two:
//! * **linear queries** (sum/mean/count/per-stratum/histogram) run through
//!   the compute service and the Horvitz–Thompson estimator (Eq. 1–9) with
//!   CLT error bounds;
//! * **sketch-backed queries** ([`sketch`]) — `Query::Quantile`,
//!   `Query::Distinct`, `Query::TopK` — build mergeable, weight-aware
//!   summaries (equi-depth quantile clusters, HyperLogLog, Count-Min +
//!   space-saving) over the window sample.  Sketches merge associatively
//!   with no barrier, mirroring the OASRS worker-merge protocol, and each
//!   result carries the sketch's *native* guarantee (rank ε, HLL RSE,
//!   Count-Min over-bound) as its confidence interval.
//!
//! Sampling designs: OASRS (the paper's contribution), Spark-style SRS/STS
//! baselines, A-ExpJ weighted reservoirs ([`sampling::weighted`]) for
//! value-proportional designs, and native (no sampling).
//!
//! ## Quickstart
//!
//! ```no_run
//! use streamapprox::prelude::*;
//!
//! let pipeline = PipelineBuilder::new()
//!     .engine(EngineKind::Pipelined)
//!     .sampler(SamplerKind::Oasrs)
//!     .budget(QueryBudget::SamplingFraction(0.6))
//!     .query(Query::sum())
//!     .build_native();
//! let report = pipeline
//!     .run_stream(&StreamConfig::gaussian_micro(1000.0, 7), 60_000)
//!     .unwrap();
//! println!("{:.0} items/s", report.throughput());
//! ```

// Every `unsafe` operation inside an `unsafe fn` must sit in its own
// `unsafe {}` block with its own `// SAFETY:` comment (enforced together
// with pallas-lint rule U1).
#![deny(unsafe_op_in_unsafe_fn)]
// Public types must be inspectable — worker state, rings and handles all
// show up in test failure messages and operator logs.
#![warn(missing_debug_implementations)]

pub mod budget;
pub mod core;
pub mod datasets;
pub mod engine;
pub mod error;
pub mod harness;
pub mod metrics;
pub mod obs;
pub mod pipeline;
pub mod query;
pub mod runtime;
pub mod sampling;
pub mod sketch;
pub mod stream;
pub mod util;
pub mod window;

/// Commonly used types, one import away.
pub mod prelude {
    pub use crate::budget::QueryBudget;
    pub use crate::core::{Item, StratumId, MAX_STRATA};
    pub use crate::engine::{EngineKind, RunReport};
    pub use crate::error::{ConfidenceInterval, ConfidenceLevel, Estimate};
    pub use crate::obs::MetricsSnapshot;
    pub use crate::pipeline::{Pipeline, PipelineBuilder, PipelineReport};
    pub use crate::query::Query;
    pub use crate::runtime::{Backend, ComputeService};
    pub use crate::sampling::SamplerKind;
    pub use crate::sketch::{
        HeavyHitters, HyperLogLog, PaneSketch, QuantileSketch, SketchParams, SketchSpec,
    };
    pub use crate::stream::{StreamConfig, SubStreamSpec};
    pub use crate::window::{Mergeable, PaneStore, WindowConfig, WindowView};
}
