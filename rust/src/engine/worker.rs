//! Parallel sampling workers shared by both engines.
//!
//! Each worker thread owns an independent sampler instance and receives a
//! round-robin partition of the input (the even split the paper's
//! distributed-execution section assumes).  The per-interval protocol
//! depends on the algorithm:
//!
//! * **OASRS / SRS / native** — one `Finish` round: every worker emits its
//!   local `SampleResult`; results merge associatively with **no barrier
//!   between workers** (they never wait on each other's data).
//! * **STS (`sampleByKeyExact`)** — two rounds with a true synchronization
//!   barrier: a count pass (workers report exact per-stratum counts), a
//!   coordinator-side merge + proportional target allocation (the "join" the
//!   paper blames), then a sampling pass against the allocated targets.
//!
//! With `workers == 1` the pool runs inline (no threads, no channels) — the
//! single-core configuration and the pipelined engine's sampling operator
//! use this fast path.

use crate::core::{Item, MAX_STRATA};
use crate::error::estimator::StrataState;
use crate::sampling::oasrs::merge_worker_results;
use crate::sampling::{
    NoopSampler, OasrsSampler, SampleResult, Sampler, SamplerKind, SrsSampler,
    WeightedResSampler,
};
use crate::util::channel::{bounded, Receiver, Sender};
use crate::util::rng::Rng;

/// Per-worker sampler instance (concrete dispatch; the STS two-phase
/// protocol needs more than the `Sampler` trait exposes).
pub enum WorkerSampler {
    Oasrs(OasrsSampler),
    Srs(SrsSampler),
    Sts(StsBatch),
    WeightedRes(WeightedResSampler),
    Noop(NoopSampler),
}

impl WorkerSampler {
    fn new(kind: SamplerKind, fraction: f64, seed: u64) -> Self {
        match kind {
            SamplerKind::Oasrs => WorkerSampler::Oasrs(OasrsSampler::new(fraction, seed)),
            SamplerKind::Srs => WorkerSampler::Srs(SrsSampler::new(fraction, seed)),
            SamplerKind::Sts => WorkerSampler::Sts(StsBatch::new(seed)),
            SamplerKind::WeightedRes => {
                WorkerSampler::WeightedRes(WeightedResSampler::new(fraction, seed))
            }
            SamplerKind::None => WorkerSampler::Noop(NoopSampler::new()),
        }
    }

    #[inline]
    fn offer(&mut self, item: &Item) {
        match self {
            WorkerSampler::Oasrs(s) => s.offer(item),
            WorkerSampler::Srs(s) => s.offer(item),
            WorkerSampler::Sts(s) => s.offer(item),
            WorkerSampler::WeightedRes(s) => s.offer(item),
            WorkerSampler::Noop(s) => s.offer(item),
        }
    }

    fn finish_simple(&mut self) -> SampleResult {
        match self {
            WorkerSampler::Oasrs(s) => s.finish_interval(),
            WorkerSampler::Srs(s) => s.finish_interval(),
            WorkerSampler::WeightedRes(s) => s.finish_interval(),
            WorkerSampler::Noop(s) => s.finish_interval(),
            WorkerSampler::Sts(_) => panic!("STS requires the two-phase protocol"),
        }
    }

    fn set_fraction(&mut self, f: f64) {
        match self {
            WorkerSampler::Oasrs(s) => s.set_fraction(f),
            WorkerSampler::Srs(s) => s.set_fraction(f),
            WorkerSampler::WeightedRes(s) => s.set_fraction(f),
            WorkerSampler::Noop(s) => s.set_fraction(f),
            WorkerSampler::Sts(_) => {} // fraction applied via targets
        }
    }
}

/// STS worker state: buffers its partition of the batch; the coordinator
/// drives the two-phase count/sample protocol.
pub struct StsBatch {
    groups: Vec<Vec<f64>>,
    counts: [usize; MAX_STRATA],
    rng: Rng,
}

impl StsBatch {
    pub fn new(seed: u64) -> Self {
        Self {
            groups: (0..MAX_STRATA).map(|_| Vec::new()).collect(),
            counts: [0; MAX_STRATA],
            rng: Rng::seed_from_u64(seed),
        }
    }

    #[inline]
    pub fn offer(&mut self, item: &Item) {
        let s = item.stratum as usize;
        if s < MAX_STRATA {
            // groupBy(strata) happens at ingest into per-key buffers — the
            // shuffle-write half of Spark's groupBy.
            self.groups[s].push(item.value);
            self.counts[s] += 1;
        } else {
            crate::metrics::record_dropped_item();
        }
    }

    /// Phase 1: exact local per-stratum counts (`sampleByKeyExact`'s count
    /// job).
    pub fn local_counts(&self) -> [usize; MAX_STRATA] {
        self.counts
    }

    /// Phase 2: sample exactly `targets[s]` items per stratum from the local
    /// groups by full random sort, then reset for the next interval.
    pub fn finish_with_targets(&mut self, targets: &[usize; MAX_STRATA]) -> SampleResult {
        let mut sample = Vec::new();
        let mut state = StrataState::default();
        for s in 0..MAX_STRATA {
            let c_i = self.counts[s];
            state.c[s] = c_i as f64;
            if c_i == 0 {
                continue;
            }
            let k_i = targets[s].min(c_i);
            // Full key sort — the exact variant's cost signature.
            let mut keyed: Vec<(f64, usize)> = (0..c_i).map(|i| (self.rng.f64(), i)).collect();
            keyed.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for &(_, idx) in keyed.iter().take(k_i) {
                sample.push((s as u16, self.groups[s][idx]));
            }
            state.n_cap[s] = k_i as f64;
        }
        for g in &mut self.groups {
            g.clear();
        }
        self.counts = [0; MAX_STRATA];
        SampleResult { sample, state }
    }
}

/// Items are shipped to workers in chunks (shuffle buffers), not one by
/// one — a per-item channel rendezvous costs ~0.5 µs and would dominate
/// every sampler; real engines batch their network transfers the same way.
const CHUNK: usize = 512;

enum Msg {
    Chunk(Vec<Item>),
    /// Simple one-round finish (OASRS/SRS/native).
    Finish(Sender<SampleResult>),
    /// STS phase 1.
    Counts(Sender<[usize; MAX_STRATA]>),
    /// STS phase 2.
    FinishSts([usize; MAX_STRATA], Sender<SampleResult>),
    SetFraction(f64),
}

enum PoolImpl {
    /// Single worker, no threads.
    Inline(Box<WorkerSampler>),
    Threaded {
        txs: Vec<Sender<Msg>>,
        joins: Vec<std::thread::JoinHandle<()>>,
        /// Pending chunk being filled (flushed to workers round-robin).
        buf: Vec<Item>,
    },
}

/// Parallel ingest + sampling pool.
pub struct IngestPool {
    kind: SamplerKind,
    fraction: f64,
    imp: PoolImpl,
    next: usize,
    n_workers: usize,
}

impl IngestPool {
    pub fn new(kind: SamplerKind, n_workers: usize, fraction: f64, seed: u64) -> Self {
        let n = n_workers.max(1);
        let imp = if n == 1 {
            PoolImpl::Inline(Box::new(WorkerSampler::new(kind, fraction, seed)))
        } else {
            let mut txs = Vec::new();
            let mut joins = Vec::new();
            for w in 0..n {
                let (tx, rx): (Sender<Msg>, Receiver<Msg>) = bounded(8192);
                let mut sampler = WorkerSampler::new(kind, fraction, seed.wrapping_add(w as u64 * 7919));
                joins.push(
                    std::thread::Builder::new()
                        .name(format!("sa-worker-{w}"))
                        .spawn(move || {
                            while let Some(msg) = rx.recv() {
                                match msg {
                                    Msg::Chunk(items) => {
                                        for it in &items {
                                            sampler.offer(it);
                                        }
                                    }
                                    Msg::Finish(reply) => {
                                        let _ = reply.send(sampler.finish_simple());
                                    }
                                    Msg::Counts(reply) => {
                                        if let WorkerSampler::Sts(s) = &sampler {
                                            let _ = reply.send(s.local_counts());
                                        }
                                    }
                                    Msg::FinishSts(targets, reply) => {
                                        if let WorkerSampler::Sts(s) = &mut sampler {
                                            let _ = reply.send(s.finish_with_targets(&targets));
                                        }
                                    }
                                    Msg::SetFraction(f) => sampler.set_fraction(f),
                                }
                            }
                        })
                        .expect("spawn worker"),
                );
                txs.push(tx);
            }
            PoolImpl::Threaded { txs, joins, buf: Vec::with_capacity(CHUNK) }
        };
        Self { kind, fraction, imp, next: 0, n_workers: n }
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    pub fn kind(&self) -> SamplerKind {
        self.kind
    }

    /// Offer one item (chunk-round-robin partitioning across workers).
    #[inline]
    pub fn offer(&mut self, item: Item) {
        match &mut self.imp {
            PoolImpl::Inline(s) => s.offer(&item),
            PoolImpl::Threaded { txs, buf, .. } => {
                buf.push(item);
                if buf.len() >= CHUNK {
                    let chunk = std::mem::replace(buf, Vec::with_capacity(CHUNK));
                    let w = self.next;
                    self.next = (self.next + 1) % txs.len();
                    let _ = txs[w].send(Msg::Chunk(chunk));
                }
            }
        }
    }

    /// Flush the pending partial chunk (interval close).
    fn flush(&mut self) {
        if let PoolImpl::Threaded { txs, buf, .. } = &mut self.imp {
            if !buf.is_empty() {
                let chunk = std::mem::replace(buf, Vec::with_capacity(CHUNK));
                let w = self.next;
                self.next = (self.next + 1) % txs.len();
                let _ = txs[w].send(Msg::Chunk(chunk));
            }
        }
    }

    /// Close the interval on every worker and merge their results.
    pub fn finish_interval(&mut self) -> SampleResult {
        self.flush();
        match &mut self.imp {
            PoolImpl::Inline(s) => match s.as_mut() {
                WorkerSampler::Sts(sts) => {
                    // Single worker: counts -> proportional targets -> sample.
                    let counts = sts.local_counts();
                    let targets = proportional_targets(&counts, self.fraction);
                    sts.finish_with_targets(&targets)
                }
                other => other.finish_simple(),
            },
            PoolImpl::Threaded { txs, .. } => {
                if self.kind == SamplerKind::Sts {
                    // Phase 1: count pass (synchronization barrier — the
                    // coordinator must gather every worker's counts before
                    // any worker may sample).
                    let mut replies = Vec::new();
                    for tx in txs.iter() {
                        let (rtx, rrx) = bounded(1);
                        let _ = tx.send(Msg::Counts(rtx));
                        replies.push(rrx);
                    }
                    let per_worker: Vec<[usize; MAX_STRATA]> = replies
                        .into_iter()
                        .map(|r| r.recv().unwrap_or([0; MAX_STRATA]))
                        .collect();
                    let mut global = [0usize; MAX_STRATA];
                    for c in &per_worker {
                        for s in 0..MAX_STRATA {
                            global[s] += c[s];
                        }
                    }
                    let global_targets = proportional_targets(&global, self.fraction);
                    // Phase 2: allocate targets proportionally to each
                    // worker's local share, then sample.
                    let mut replies = Vec::new();
                    for (w, tx) in txs.iter().enumerate() {
                        let mut t = [0usize; MAX_STRATA];
                        for s in 0..MAX_STRATA {
                            if global[s] > 0 {
                                t[s] = (global_targets[s] * per_worker[w][s] + global[s] / 2)
                                    / global[s];
                            }
                        }
                        let (rtx, rrx) = bounded(1);
                        let _ = tx.send(Msg::FinishSts(t, rtx));
                        replies.push(rrx);
                    }
                    merge_worker_results(
                        replies.into_iter().filter_map(|r| r.recv()).collect(),
                    )
                } else {
                    let mut replies = Vec::new();
                    for tx in txs.iter() {
                        let (rtx, rrx) = bounded(1);
                        let _ = tx.send(Msg::Finish(rtx));
                        replies.push(rrx);
                    }
                    merge_worker_results(
                        replies.into_iter().filter_map(|r| r.recv()).collect(),
                    )
                }
            }
        }
    }

    /// Update the sampling fraction for subsequent intervals.
    pub fn set_fraction(&mut self, fraction: f64) {
        self.fraction = fraction;
        match &mut self.imp {
            PoolImpl::Inline(s) => s.set_fraction(fraction),
            PoolImpl::Threaded { txs, .. } => {
                for tx in txs {
                    let _ = tx.send(Msg::SetFraction(fraction));
                }
            }
        }
    }
}

impl Drop for IngestPool {
    fn drop(&mut self) {
        if let PoolImpl::Threaded { txs, joins, .. } = &mut self.imp {
            for tx in txs.iter() {
                tx.close();
            }
            for j in joins.drain(..) {
                let _ = j.join();
            }
        }
    }
}

/// Proportional STS allocation: `k_i = round(fraction * C_i)`, at least one
/// item from every non-empty stratum.
fn proportional_targets(counts: &[usize; MAX_STRATA], fraction: f64) -> [usize; MAX_STRATA] {
    let mut t = [0usize; MAX_STRATA];
    for s in 0..MAX_STRATA {
        if counts[s] > 0 {
            t[s] = ((fraction * counts[s] as f64).round() as usize).clamp(1, counts[s]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::estimator::{estimate, StrataPartials};

    fn feed(pool: &mut IngestPool, n: usize, strata: usize) {
        for i in 0..n {
            pool.offer(Item::new((i % strata) as u16, i as f64, i as u64));
        }
    }

    #[test]
    fn inline_oasrs_counts_everything() {
        let mut p = IngestPool::new(SamplerKind::Oasrs, 1, 0.5, 1);
        feed(&mut p, 1000, 4);
        let r = p.finish_interval();
        assert_eq!(r.arrived(), 1000.0);
    }

    #[test]
    fn threaded_oasrs_counts_everything() {
        let mut p = IngestPool::new(SamplerKind::Oasrs, 4, 0.5, 2);
        feed(&mut p, 10_000, 4);
        let r = p.finish_interval();
        assert_eq!(r.arrived(), 10_000.0);
        // second interval isolated
        let r2 = p.finish_interval();
        assert_eq!(r2.arrived(), 0.0);
    }

    #[test]
    fn threaded_sts_proportional() {
        let mut p = IngestPool::new(SamplerKind::Sts, 4, 0.5, 3);
        for i in 0..8000 {
            p.offer(Item::new(0, i as f64, 0));
        }
        for i in 0..2000 {
            p.offer(Item::new(1, i as f64, 0));
        }
        let r = p.finish_interval();
        let n0 = r.sample.iter().filter(|(s, _)| *s == 0).count() as f64;
        let n1 = r.sample.iter().filter(|(s, _)| *s == 1).count() as f64;
        assert!((n0 - 4000.0).abs() <= 4.0, "n0 {n0}");
        assert!((n1 - 1000.0).abs() <= 4.0, "n1 {n1}");
        assert_eq!(r.state.c[0], 8000.0);
    }

    #[test]
    fn sts_estimate_accuracy_multi_worker() {
        let mut p = IngestPool::new(SamplerKind::Sts, 3, 0.25, 4);
        let mut rng = Rng::seed_from_u64(5);
        let mut exact = 0.0;
        for _ in 0..10_000 {
            let v = rng.normal(100.0, 10.0);
            p.offer(Item::new(0, v, 0));
            exact += v;
        }
        for _ in 0..50 {
            let v = rng.normal(50_000.0, 100.0);
            p.offer(Item::new(2, v, 0));
            exact += v;
        }
        let r = p.finish_interval();
        let est = estimate(&StrataPartials::from_sample(&r.sample), &r.state);
        let rel = (est.sum - exact).abs() / exact;
        assert!(rel < 0.02, "rel err {rel}");
    }

    #[test]
    fn srs_multi_worker_fraction() {
        let mut p = IngestPool::new(SamplerKind::Srs, 2, 0.3, 6);
        feed(&mut p, 10_000, 3);
        let r = p.finish_interval();
        let f = r.fraction();
        assert!((f - 0.3).abs() < 0.01, "fraction {f}");
    }

    #[test]
    fn weighted_res_multi_worker_counts_everything() {
        let mut p = IngestPool::new(SamplerKind::WeightedRes, 3, 0.2, 21);
        for i in 0..9_000 {
            p.offer(Item::new((i % 3) as u16, 1.0 + (i % 10) as f64, i as u64));
        }
        let r = p.finish_interval();
        assert_eq!(r.arrived(), 9_000.0);
        assert!(!r.sample.is_empty());
        assert!(r.sample.len() < 9_000);
    }

    #[test]
    fn native_multi_worker_keeps_all() {
        let mut p = IngestPool::new(SamplerKind::None, 4, 1.0, 7);
        feed(&mut p, 5000, 5);
        let r = p.finish_interval();
        assert_eq!(r.sample.len(), 5000);
    }

    #[test]
    fn set_fraction_propagates() {
        let mut p = IngestPool::new(SamplerKind::Sts, 2, 0.5, 8);
        p.set_fraction(0.1);
        feed(&mut p, 10_000, 2);
        let r = p.finish_interval();
        let f = r.fraction();
        assert!((f - 0.1).abs() < 0.01, "fraction {f}");
    }

    #[test]
    fn oasrs_no_sync_rare_stratum_kept_across_workers() {
        let mut p = IngestPool::new(SamplerKind::Oasrs, 4, 0.1, 9);
        for i in 0..100_000 {
            p.offer(Item::new(0, 1.0, i));
        }
        for _ in 0..8 {
            p.offer(Item::new(2, 1e6, 0));
        }
        let r = p.finish_interval();
        let n2 = r.sample.iter().filter(|(s, _)| *s == 2).count();
        assert_eq!(n2, 8);
    }

    use crate::util::rng::Rng;
}
