//! Parallel sampling workers shared by both engines.
//!
//! Each worker thread owns an independent sampler instance and receives a
//! round-robin partition of the input (the even split the paper's
//! distributed-execution section assumes).  The per-interval protocol
//! depends on the algorithm:
//!
//! * **OASRS / SRS / native** — one `Finish` round: every worker emits its
//!   local `SampleResult`; results merge associatively with **no barrier
//!   between workers** (they never wait on each other's data).
//! * **STS (`sampleByKeyExact`)** — two rounds with a true synchronization
//!   barrier: a count pass (workers report exact per-stratum counts), a
//!   coordinator-side merge + largest-remainder target allocation (the
//!   "join" the paper blames), then a sampling pass against the allocated
//!   targets.
//!
//! **Transport (two planes).**  Item traffic rides a lock-free SPSC ring
//! per worker ([`crate::util::spsc`]): the coordinator pushes 512-item
//! **columnar (SoA) chunks** ([`ColumnarChunk`] — the workers' batched
//! kernels read whole columns), the worker drains them and hands the
//! emptied buffers back through a second (return) ring, so steady-state
//! ingest performs **zero heap allocations and takes zero locks** —
//! buffers just circulate.
//! Control messages (finish/counts/set-fraction/register-sketches) are
//! rare rendezvous events and stay on the blocking MPMC channel; a worker
//! always drains its data ring before acting on a control message, which
//! preserves the chunks-before-finish ordering the single-threaded
//! coordinator guarantees at send time.  [`TransportStats`] exposes the
//! recycle hit rate so tests can assert the zero-allocation property.
//!
//! **Streaming sketch ingest.**  A sketch-backed query registers its
//! [`SketchSpec`] on the pool ([`IngestPool::register_sketches`]) over the
//! same acked rendezvous as `set_fraction`, so registration orders before
//! any subsequent chunk.  From then on every interval close returns, next
//! to the merged sample, one **pre-built [`PaneSketch`] per spec**: each
//! worker folds its own finished interval sample into a sketch partial
//! (Horvitz–Thompson weights from its local counters — sample membership
//! and weights only finalize at close, so that is the earliest the fold
//! is sound for reservoir samplers) and the coordinator merges the
//! partials through the same barrier-free associative combine as the
//! sample results.  Pane sketches therefore arrive at the window operator
//! already built: the per-pane O(interval sample) sketch construction
//! moves off the query path and parallelizes across the ingest workers.
//!
//! With `workers == 1` the pool runs inline (no threads, no rings) — the
//! single-core configuration and the pipelined engine's sampling operator
//! use this fast path.

use crate::core::{ColumnarChunk, Error, EventTime, Item, Result, MAX_STRATA};
use crate::error::estimator::StrataState;
use crate::obs;
use crate::runtime::checkpoint::{Snapshot, SnapshotReader, SnapshotWriter};
use crate::sampling::oasrs::merge_worker_results;
use crate::sampling::{
    NoopSampler, OasrsSampler, SampleResult, Sampler, SamplerKind, SrsSampler,
    WeightedResSampler,
};
use crate::sketch::{PaneSketch, SketchSpec};
use crate::util::channel::{bounded, Receiver, Sender, TryRecvError};
use crate::util::rng::Rng;
use crate::util::spsc::{self, spsc, SpscReceiver, SpscSender};

/// Per-worker sampler instance (concrete dispatch; the STS two-phase
/// protocol needs more than the `Sampler` trait exposes).
#[derive(Debug)]
pub enum WorkerSampler {
    Oasrs(OasrsSampler),
    Srs(SrsSampler),
    Sts(StsBatch),
    WeightedRes(WeightedResSampler),
    Noop(NoopSampler),
}

impl WorkerSampler {
    fn new(kind: SamplerKind, fraction: f64, seed: u64) -> Self {
        match kind {
            SamplerKind::Oasrs => WorkerSampler::Oasrs(OasrsSampler::new(fraction, seed)),
            SamplerKind::Srs => WorkerSampler::Srs(SrsSampler::new(fraction, seed)),
            SamplerKind::Sts => WorkerSampler::Sts(StsBatch::new(seed)),
            SamplerKind::WeightedRes => {
                WorkerSampler::WeightedRes(WeightedResSampler::new(fraction, seed))
            }
            SamplerKind::None => WorkerSampler::Noop(NoopSampler::new()),
        }
    }

    #[inline]
    fn offer(&mut self, item: &Item) {
        match self {
            WorkerSampler::Oasrs(s) => s.offer(item),
            WorkerSampler::Srs(s) => s.offer(item),
            WorkerSampler::Sts(s) => s.offer(item),
            WorkerSampler::WeightedRes(s) => s.offer(item),
            WorkerSampler::Noop(s) => s.offer(item),
        }
    }

    /// Batch offer: one enum dispatch per chunk, then the sampler's own
    /// tight loop.  Behaviorally identical to per-item `offer` (same RNG
    /// consumption), which the chunk-size determinism tests assert.
    #[inline]
    fn offer_slice(&mut self, items: &[Item]) {
        match self {
            WorkerSampler::Oasrs(s) => s.offer_slice(items),
            WorkerSampler::Srs(s) => s.offer_slice(items),
            WorkerSampler::Sts(s) => s.offer_slice(items),
            WorkerSampler::WeightedRes(s) => s.offer_slice(items),
            WorkerSampler::Noop(s) => s.offer_slice(items),
        }
    }

    /// Columnar batch offer: the SoA fast path.  OASRS/SRS/STS run their
    /// batched kernels (column reads, batched RNG, branchless acceptance);
    /// WeightedRes/Noop bridge through the `Sampler` trait default, which
    /// reassembles items — behaviorally identical either way, which the
    /// columnar equivalence tests assert per kind.
    #[inline]
    fn offer_columnar(&mut self, chunk: &ColumnarChunk) {
        crate::obs_counter!(
            "ingest_columnar_chunks_total",
            "columnar chunks offered to the sampling kernels"
        )
        .inc();
        match self {
            WorkerSampler::Oasrs(s) => s.offer_columnar(chunk),
            WorkerSampler::Srs(s) => s.offer_columnar(chunk),
            WorkerSampler::Sts(s) => s.offer_columnar(chunk),
            WorkerSampler::WeightedRes(s) => s.offer_columnar(chunk),
            WorkerSampler::Noop(s) => s.offer_columnar(chunk),
        }
    }

    fn finish_simple(&mut self) -> SampleResult {
        match self {
            WorkerSampler::Oasrs(s) => s.finish_interval(),
            WorkerSampler::Srs(s) => s.finish_interval(),
            WorkerSampler::WeightedRes(s) => s.finish_interval(),
            WorkerSampler::Noop(s) => s.finish_interval(),
            // lint: allow(P1) internal protocol bug, not a data condition:
            // the coordinator statically routes STS through the two-phase
            // close (local_counts -> finish_with_targets) and never sends
            // an STS pool the simple-finish control message.
            WorkerSampler::Sts(_) => panic!("STS requires the two-phase protocol"),
        }
    }

    fn set_fraction(&mut self, f: f64) {
        match self {
            WorkerSampler::Oasrs(s) => s.set_fraction(f),
            WorkerSampler::Srs(s) => s.set_fraction(f),
            WorkerSampler::WeightedRes(s) => s.set_fraction(f),
            WorkerSampler::Noop(s) => s.set_fraction(f),
            WorkerSampler::Sts(_) => {} // fraction applied via targets
        }
    }

    fn kind(&self) -> SamplerKind {
        match self {
            WorkerSampler::Oasrs(_) => SamplerKind::Oasrs,
            WorkerSampler::Srs(_) => SamplerKind::Srs,
            WorkerSampler::Sts(_) => SamplerKind::Sts,
            WorkerSampler::WeightedRes(_) => SamplerKind::WeightedRes,
            WorkerSampler::Noop(_) => SamplerKind::None,
        }
    }
}

/// Tagged by [`SamplerKind::tag`] so a restore can verify the blob matches
/// the pool's configured algorithm before touching any payload bytes.
impl Snapshot for WorkerSampler {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_u8(self.kind().tag());
        match self {
            WorkerSampler::Oasrs(s) => s.encode(w),
            WorkerSampler::Srs(s) => s.encode(w),
            WorkerSampler::Sts(s) => s.encode(w),
            WorkerSampler::WeightedRes(s) => s.encode(w),
            WorkerSampler::Noop(s) => s.encode(w),
        }
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        Ok(match SamplerKind::from_tag(r.get_u8()?)? {
            SamplerKind::Oasrs => WorkerSampler::Oasrs(OasrsSampler::decode(r)?),
            SamplerKind::Srs => WorkerSampler::Srs(SrsSampler::decode(r)?),
            SamplerKind::Sts => WorkerSampler::Sts(StsBatch::decode(r)?),
            SamplerKind::WeightedRes => {
                WorkerSampler::WeightedRes(WeightedResSampler::decode(r)?)
            }
            SamplerKind::None => WorkerSampler::Noop(NoopSampler::decode(r)?),
        })
    }
}

/// STS worker state: buffers its partition of the batch; the coordinator
/// drives the two-phase count/sample protocol.
#[derive(Debug)]
pub struct StsBatch {
    groups: Vec<Vec<f64>>,
    counts: [usize; MAX_STRATA],
    rng: Rng,
}

impl StsBatch {
    pub fn new(seed: u64) -> Self {
        Self {
            groups: (0..MAX_STRATA).map(|_| Vec::new()).collect(),
            counts: [0; MAX_STRATA],
            rng: Rng::seed_from_u64(seed),
        }
    }

    #[inline]
    pub fn offer(&mut self, item: &Item) {
        let s = item.stratum as usize;
        if s < MAX_STRATA {
            // groupBy(strata) happens at ingest into per-key buffers — the
            // shuffle-write half of Spark's groupBy.
            self.groups[s].push(item.value);
            self.counts[s] += 1;
        } else {
            crate::metrics::record_dropped_item();
        }
    }

    /// Batch offer into the per-stratum groups (tight loop, one bounds
    /// check pattern per item instead of a channel/enum round-trip).
    #[inline]
    pub fn offer_slice(&mut self, items: &[Item]) {
        for item in items {
            self.offer(item);
        }
    }

    /// Columnar offer: partition the value column straight into the
    /// per-stratum groups.  The ts column is never read — the groupBy
    /// shuffle write touches two columns instead of three AoS fields.
    #[inline]
    // lint: hot-path — per-chunk dispatch into the sampler kernels
    pub fn offer_columnar(&mut self, chunk: &ColumnarChunk) {
        for (&s, &v) in chunk.strata.iter().zip(&chunk.values) {
            let s = s as usize;
            if s < MAX_STRATA {
                self.groups[s].push(v);
                self.counts[s] += 1;
            } else {
                crate::metrics::record_dropped_item();
            }
        }
    }

    /// Phase 1: exact local per-stratum counts (`sampleByKeyExact`'s count
    /// job).
    pub fn local_counts(&self) -> [usize; MAX_STRATA] {
        self.counts
    }

    /// Phase 2: sample exactly `targets[s]` items per stratum from the local
    /// groups by full random sort, then reset for the next interval.
    pub fn finish_with_targets(&mut self, targets: &[usize; MAX_STRATA]) -> SampleResult {
        let t0 = obs::metrics_enabled().then(std::time::Instant::now); // lint: wall-clock latency metric only, never feeds results
        let mut sample = Vec::new();
        let mut state = StrataState::default();
        for s in 0..MAX_STRATA {
            let c_i = self.counts[s];
            state.c[s] = c_i as f64;
            if c_i == 0 {
                continue;
            }
            let k_i = targets[s].min(c_i);
            // Full key sort — the exact variant's cost signature.
            let mut keyed: Vec<(f64, usize)> = (0..c_i).map(|i| (self.rng.f64(), i)).collect();
            // total_cmp, not partial_cmp().unwrap(): byte-identical for
            // these keys (rng.f64() yields [0,1) — never NaN or -0.0, where
            // the two orderings could differ) and panic-free by type.
            keyed.sort_by(|a, b| a.0.total_cmp(&b.0));
            for &(_, idx) in keyed.iter().take(k_i) {
                sample.push((s as u16, self.groups[s][idx]));
            }
            state.n_cap[s] = k_i as f64;
        }
        for g in &mut self.groups {
            g.clear();
        }
        self.counts = [0; MAX_STRATA];
        if let Some(t0) = t0 {
            crate::obs_histogram!(
                "close_sts_sort_ns",
                "STS full-random-sort sampling pass at interval close"
            )
            .record_elapsed(t0);
        }
        SampleResult { sample, state }
    }
}

/// Buffered batch + the partition RNG travel: a mid-stream STS worker that
/// crashes between offers resumes with the same groups, the same exact
/// counts, and the same key-sort randomness at the next close.
impl Snapshot for StsBatch {
    fn encode(&self, w: &mut SnapshotWriter) {
        self.groups.encode(w);
        self.counts.encode(w);
        self.rng.encode(w);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        let groups = Vec::<Vec<f64>>::decode(r)?;
        let counts = <[usize; MAX_STRATA]>::decode(r)?;
        if groups.len() != MAX_STRATA {
            return Err(Error::Io(format!(
                "STS snapshot has {} stratum groups, expected {MAX_STRATA}",
                groups.len()
            )));
        }
        for (s, g) in groups.iter().enumerate() {
            if counts[s] != g.len() {
                return Err(Error::Io(format!(
                    "STS snapshot stratum {s} count {} disagrees with its {} buffered items",
                    counts[s],
                    g.len()
                )));
            }
        }
        Ok(Self { groups, counts, rng: Rng::decode(r)? })
    }
}

/// Items are shipped to workers in chunks (shuffle buffers), not one by
/// one — a per-item hand-off costs ~0.5 µs and would dominate every
/// sampler; real engines batch their network transfers the same way.
const CHUNK: usize = 512;

/// Data-plane ring capacity per worker, in chunks (the backpressure bound:
/// a coordinator more than `RING_CAP` chunks ahead of a worker blocks).
const RING_CAP: usize = 16;

/// Return-ring capacity: a worker holds at most `RING_CAP` queued chunks
/// plus one being processed, so `RING_CAP + 2` guarantees every emptied
/// buffer fits and none is ever dropped (which would force a fresh
/// allocation later).
const RETURN_RING_CAP: usize = RING_CAP + 2;

/// One worker's interval close: the local sample plus one pre-built
/// sketch partial per registered spec (empty when nothing is registered).
#[derive(Debug)]
pub struct WorkerFinish {
    pub result: SampleResult,
    pub sketches: Vec<PaneSketch>,
    /// `(min_ts, max_ts)` over the items this worker ingested during the
    /// closing interval, observed worker-side off the SPSC chunk `ts`
    /// column (`None` for an empty interval).  The ts column's downstream
    /// consumer: the ingest-path tests assert these bounds survive the
    /// transport bit-identically.
    pub ts_bounds: Option<(EventTime, EventTime)>,
}

/// Fold a `[lo, hi]` event-time range into an accumulator.
fn merge_ts_bounds(acc: &mut Option<(EventTime, EventTime)>, lo: EventTime, hi: EventTime) {
    *acc = Some(match *acc {
        Some((a, b)) => (a.min(lo), b.max(hi)),
        None => (lo, hi),
    });
}

/// Min/max over a ts column (one pass; `None` when empty).
fn ts_column_bounds(ts: &[EventTime]) -> Option<(EventTime, EventTime)> {
    let mut it = ts.iter();
    let first = *it.next()?;
    Some(it.fold((first, first), |(lo, hi), &t| (lo.min(t), hi.max(t))))
}

/// Control-plane messages (rare rendezvous events — the chunk traffic rides
/// the SPSC rings instead).
enum Msg {
    /// Simple one-round finish (OASRS/SRS/native).
    Finish(Sender<WorkerFinish>),
    /// STS phase 1.
    Counts(Sender<[usize; MAX_STRATA]>),
    /// STS phase 2.
    FinishSts([usize; MAX_STRATA], Sender<WorkerFinish>),
    /// Fraction update with an ack rendezvous: the coordinator waits for
    /// every worker's ack before accepting more items, so no chunk shipped
    /// *after* `set_fraction` can be ingested under the old fraction (the
    /// old single-channel transport got that ordering for free; with a
    /// separate data plane it must be explicit).
    SetFraction(f64, Sender<()>),
    /// Sketch-registration update, same acked rendezvous discipline as
    /// `SetFraction`: no chunk shipped after `register_sketches` can close
    /// into an interval that lacks the registered partials.
    RegisterSketches(Vec<SketchSpec>, Sender<()>),
    /// Checkpoint rendezvous, same acked discipline as `SetFraction`: the
    /// coordinator sends it at an interval boundary (data rings drained at
    /// send time, and the worker drains once more before replying), so the
    /// returned blob serializes the worker's full post-close sampler state
    /// — RNG streams mid-sequence included.
    Snapshot(Sender<Vec<u8>>),
}

/// The worker-side sketch fold: one partial per registered spec, built
/// from the finished interval's sample with the interval's own HT weights.
fn build_partials(specs: &[SketchSpec], result: &SampleResult) -> Vec<PaneSketch> {
    if specs.is_empty() {
        return Vec::new();
    }
    let t0 = obs::metrics_enabled().then(std::time::Instant::now); // lint: wall-clock latency metric only, never feeds results
    let partials = specs.iter().map(|spec| spec.build(result)).collect();
    if let Some(t0) = t0 {
        crate::obs_histogram!(
            "close_sketch_build_ns",
            "sketch-partial build from one interval sample"
        )
        .record_elapsed(t0);
    }
    partials
}

/// Counters for the chunk transport (threaded pools only).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransportStats {
    /// Chunks shipped to workers (including partial flush chunks).
    pub chunks_sent: u64,
    /// Chunk buffers obtained by recycling a drained one.
    pub buffers_recycled: u64,
    /// Chunk buffers obtained from the allocator (pool warm-up; steady
    /// state must not grow this).
    pub buffers_allocated: u64,
}

impl TransportStats {
    /// Fraction of buffer acquisitions served by recycling.
    pub fn recycle_hit_rate(&self) -> f64 {
        let total = self.buffers_recycled + self.buffers_allocated;
        if total == 0 {
            0.0
        } else {
            self.buffers_recycled as f64 / total as f64
        }
    }
}

/// Coordinator side of the threaded transport: per-worker control channel +
/// chunk ring + buffer-return ring, and the free-list of recycled buffers.
struct ThreadedTransport {
    ctrl_txs: Vec<Sender<Msg>>,
    chunk_txs: Vec<SpscSender<ColumnarChunk>>,
    return_rxs: Vec<SpscReceiver<ColumnarChunk>>,
    joins: Vec<std::thread::JoinHandle<()>>,
    /// Pending chunk being filled (shipped to workers round-robin).
    buf: ColumnarChunk,
    /// Recycled chunk buffers ready for reuse.
    free: Vec<ColumnarChunk>,
    next: usize,
    stats: TransportStats,
}

impl ThreadedTransport {
    #[inline]
    fn offer(&mut self, item: Item) {
        self.buf.push_item(&item);
        if self.buf.len() >= CHUNK {
            self.ship_chunk();
        }
    }

    fn offer_slice(&mut self, items: &[Item]) {
        let mut rest = items;
        while !rest.is_empty() {
            // `buf` is always below CHUNK here (shipped eagerly), so at
            // least one item fits: transpose into the pending chunk.
            let take = (CHUNK - self.buf.len()).min(rest.len());
            self.buf.extend_from_items(&rest[..take]);
            rest = &rest[take..];
            if self.buf.len() >= CHUNK {
                self.ship_chunk();
            }
        }
    }

    /// Columnar offer: three column memcpys per take instead of an AoS
    /// transpose.  Same chunk boundaries and round-robin assignment as
    /// [`Self::offer_slice`], so seeded runs are ingest-path independent.
    fn offer_columnar(&mut self, chunk: &ColumnarChunk) {
        let mut off = 0;
        let n = chunk.len();
        while off < n {
            let take = (CHUNK - self.buf.len()).min(n - off);
            self.buf.extend_from_chunk(chunk, off, take);
            off += take;
            if self.buf.len() >= CHUNK {
                self.ship_chunk();
            }
        }
    }

    /// Ship the pending chunk to the next worker (round-robin) and swap in
    /// a recycled buffer.  Blocking when the worker's ring is full — that
    /// is the backpressure; `Err` only if the worker died, in which case
    /// the chunk is dropped (matching the old channel semantics).
    fn ship_chunk(&mut self) {
        let fresh = self.take_buffer();
        let chunk = std::mem::replace(&mut self.buf, fresh);
        let w = self.next;
        self.next = (self.next + 1) % self.chunk_txs.len();
        self.stats.chunks_sent += 1;
        crate::obs_counter!(
            "transport_chunks_sent_total",
            "512-item chunks shipped over the SPSC data rings"
        )
        .inc();
        let _ = self.chunk_txs[w].send(chunk);
        // Per-chunk occupancy probe of the ring just written (a relaxed
        // load pair) — "which worker's ring is backing up" on a live run.
        crate::obs_gauge!(
            "ingest_ring_occupancy",
            "chunks queued on the most recently shipped worker ring"
        )
        .set(self.chunk_txs[w].len() as f64);
    }

    /// Flush the pending partial chunk (interval close).
    fn flush(&mut self) {
        if !self.buf.is_empty() {
            self.ship_chunk();
        }
    }

    /// Acquire an empty chunk buffer: poll the return rings into the free
    /// list (a few relaxed atomic loads when they are empty — amortized
    /// over 512 items), then reuse.  The pool is pre-sized at construction
    /// to cover the worst-case number of in-flight buffers (see
    /// [`IngestPool::new`]), so the allocation branch is unreachable in
    /// practice and kept only as a safety net.
    fn take_buffer(&mut self) -> ColumnarChunk {
        for rx in &self.return_rxs {
            while let Some(b) = rx.try_recv() {
                self.free.push(b);
            }
        }
        if let Some(b) = self.free.pop() {
            self.stats.buffers_recycled += 1;
            crate::obs_counter!(
                "transport_buffers_recycled_total",
                "chunk buffers reused from the return rings"
            )
            .inc();
            return b;
        }
        self.stats.buffers_allocated += 1;
        crate::obs_counter!(
            "transport_buffers_allocated_total",
            "chunk buffers freshly allocated (pool misses)"
        )
        .inc();
        ColumnarChunk::with_capacity(CHUNK)
    }
}

enum PoolImpl {
    /// Single worker, no threads.
    Inline(Box<WorkerSampler>),
    Threaded(ThreadedTransport),
}

/// Parallel ingest + sampling pool.
pub struct IngestPool {
    kind: SamplerKind,
    fraction: f64,
    imp: PoolImpl,
    n_workers: usize,
    /// Registered per-query sketch specs (the inline pool builds partials
    /// from these at close; threaded workers hold their own copy).
    specs: Vec<SketchSpec>,
    /// Event-time bounds of the interval being fed (inline pools track at
    /// offer; threaded pools fold worker-side bounds in at close).
    cur_ts_bounds: Option<(EventTime, EventTime)>,
    /// Bounds of the most recently closed interval.
    last_ts_bounds: Option<(EventTime, EventTime)>,
}

// Manual Debug: `PoolImpl` holds join handles and ring endpoints; report
// the pool shape rather than demanding Debug of transport internals.
impl std::fmt::Debug for IngestPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("IngestPool")
            .field("kind", &self.kind)
            .field("fraction", &self.fraction)
            .field("n_workers", &self.n_workers)
            .field("specs", &self.specs.len())
            .finish_non_exhaustive()
    }
}

/// Worker thread body: drain the data ring eagerly (recycling each emptied
/// buffer), interleave control messages, and back off when idle.
fn worker_loop(
    mut sampler: WorkerSampler,
    ctrl_rx: Receiver<Msg>,
    chunk_rx: SpscReceiver<ColumnarChunk>,
    return_tx: SpscSender<ColumnarChunk>,
) {
    let drain =
        |sampler: &mut WorkerSampler, ts_bounds: &mut Option<(EventTime, EventTime)>| {
            let mut any = false;
            while let Some(mut chunk) = chunk_rx.try_recv() {
                sampler.offer_columnar(&chunk);
                // Worker-side event-time bounds, read off the transported
                // ts column (the ingest-path tests' SPSC-fidelity witness).
                if let Some((lo, hi)) = ts_column_bounds(&chunk.ts) {
                    merge_ts_bounds(ts_bounds, lo, hi);
                }
                chunk.clear();
                // A full return ring is impossible by capacity (see
                // RETURN_RING_CAP) but degrade to dropping, not blocking.
                let _ = return_tx.try_send(chunk);
                any = true;
            }
            any
        };
    let mut specs: Vec<SketchSpec> = Vec::new();
    let mut ts_bounds: Option<(EventTime, EventTime)> = None;
    let mut idle = 0u32;
    loop {
        let mut worked = drain(&mut sampler, &mut ts_bounds);
        match ctrl_rx.try_recv() {
            Ok(msg) => {
                // All chunks of the closing interval were pushed before the
                // control message was sent: drain once more so the finish
                // sees every item.
                drain(&mut sampler, &mut ts_bounds);
                match msg {
                    Msg::Finish(reply) => {
                        let _sp = obs::trace::span("worker_finish");
                        let result = sampler.finish_simple();
                        let sketches = build_partials(&specs, &result);
                        let _ = reply.send(WorkerFinish {
                            result,
                            sketches,
                            ts_bounds: ts_bounds.take(),
                        });
                    }
                    Msg::Counts(reply) => {
                        if let WorkerSampler::Sts(s) = &sampler {
                            let _ = reply.send(s.local_counts());
                        }
                    }
                    Msg::FinishSts(targets, reply) => {
                        if let WorkerSampler::Sts(s) = &mut sampler {
                            let _sp = obs::trace::span("worker_finish_sts");
                            let result = s.finish_with_targets(&targets);
                            let sketches = build_partials(&specs, &result);
                            let _ = reply.send(WorkerFinish {
                                result,
                                sketches,
                                ts_bounds: ts_bounds.take(),
                            });
                        }
                    }
                    Msg::SetFraction(f, reply) => {
                        sampler.set_fraction(f);
                        let _ = reply.send(());
                    }
                    Msg::RegisterSketches(new_specs, reply) => {
                        specs = new_specs;
                        let _ = reply.send(());
                    }
                    Msg::Snapshot(reply) => {
                        let _sp = obs::trace::span("worker_snapshot");
                        let _ = reply.send(sampler.to_snapshot_bytes());
                    }
                }
                worked = true;
            }
            Err(TryRecvError::Empty) => {}
            Err(TryRecvError::Closed) => break,
        }
        if worked {
            idle = 0;
        } else {
            if idle >= 256 {
                // Nap-tier backoff rounds (>= 50 µs apart, so the counter
                // tick is amortized into the nap itself).
                crate::obs_counter!(
                    "ingest_backoff_naps_total",
                    "worker idle-loop naps (sleep-tier backoff rounds)"
                )
                .inc();
            }
            spsc::backoff(idle);
            idle = idle.saturating_add(1);
        }
    }
}

impl IngestPool {
    pub fn new(kind: SamplerKind, n_workers: usize, fraction: f64, seed: u64) -> Self {
        let n = n_workers.max(1);
        let samplers: Vec<WorkerSampler> = (0..n)
            .map(|w| WorkerSampler::new(kind, fraction, seed.wrapping_add(w as u64 * 7919)))
            .collect();
        Self::assemble(kind, fraction, samplers, 0)
    }

    /// Rebuild a pool from checkpointed worker blobs (one per worker, in
    /// worker order — see [`Self::snapshot_workers`]): each worker starts
    /// from its restored sampler (RNG streams mid-sequence) and the chunk
    /// round-robin resumes at `cursor`.  Sketch registration is *not* in
    /// the blobs — the engine re-registers from its query config after
    /// restore, exactly as at first construction.
    pub fn restore(
        kind: SamplerKind,
        n_workers: usize,
        fraction: f64,
        blobs: &[Vec<u8>],
        cursor: u64,
    ) -> Result<Self> {
        let n = n_workers.max(1);
        if blobs.len() != n {
            return Err(Error::Io(format!(
                "checkpoint carries {} worker blobs but the pool needs {n}",
                blobs.len()
            )));
        }
        let mut samplers = Vec::with_capacity(n);
        for blob in blobs {
            let s = WorkerSampler::from_snapshot_bytes(blob)?;
            if s.kind() != kind {
                return Err(Error::Io(format!(
                    "checkpointed worker sampler is {:?} but the pool runs {kind:?}",
                    s.kind()
                )));
            }
            samplers.push(s);
        }
        Ok(Self::assemble(kind, fraction, samplers, cursor as usize))
    }

    /// Shared constructor body: wire one worker (inline) or one thread per
    /// sampler.  `cursor` seeds the round-robin chunk cursor so a restored
    /// pool resumes the checkpointed partitioning.
    fn assemble(
        kind: SamplerKind,
        fraction: f64,
        samplers: Vec<WorkerSampler>,
        cursor: usize,
    ) -> Self {
        let n = samplers.len();
        let imp = if n == 1 {
            // lint: allow(P1) `n == 1` was just read from this Vec's len.
            let s = samplers.into_iter().next().expect("one sampler");
            PoolImpl::Inline(Box::new(s))
        } else {
            let mut ctrl_txs = Vec::with_capacity(n);
            let mut chunk_txs = Vec::with_capacity(n);
            let mut return_rxs = Vec::with_capacity(n);
            let mut joins = Vec::with_capacity(n);
            for (w, sampler) in samplers.into_iter().enumerate() {
                let (ctrl_tx, ctrl_rx): (Sender<Msg>, Receiver<Msg>) = bounded(64);
                let (chunk_tx, chunk_rx) = spsc::<ColumnarChunk>(RING_CAP);
                let (return_tx, return_rx) = spsc::<ColumnarChunk>(RETURN_RING_CAP);
                joins.push(
                    std::thread::Builder::new()
                        .name(format!("sa-worker-{w}"))
                        .spawn(move || worker_loop(sampler, ctrl_rx, chunk_rx, return_tx))
                        // lint: allow(P1) construction-time, before any
                        // ring carries data: OS thread exhaustion here is
                        // unrecoverable for the pool and nothing is queued
                        // yet to poison.
                        .expect("spawn worker"),
                );
                ctrl_txs.push(ctrl_tx);
                chunk_txs.push(chunk_tx);
                return_rxs.push(return_rx);
            }
            // Pre-size the buffer pool so the data plane never allocates
            // after construction, under any thread interleaving: at the
            // moment a buffer is taken, at most RING_CAP queued + 1
            // in-processing buffers per worker plus the pending chunk are
            // unavailable, so RETURN_RING_CAP (= RING_CAP + 2) buffers per
            // worker plus the pending one always leave a spare.
            let pool_size = n * RETURN_RING_CAP;
            let free: Vec<ColumnarChunk> =
                (0..pool_size).map(|_| ColumnarChunk::with_capacity(CHUNK)).collect();
            let stats = TransportStats {
                buffers_allocated: (pool_size + 1) as u64,
                ..Default::default()
            };
            PoolImpl::Threaded(ThreadedTransport {
                ctrl_txs,
                chunk_txs,
                return_rxs,
                joins,
                buf: ColumnarChunk::with_capacity(CHUNK),
                free,
                next: cursor % n,
                stats,
            })
        };
        Self {
            kind,
            fraction,
            imp,
            n_workers: n,
            specs: Vec::new(),
            cur_ts_bounds: None,
            last_ts_bounds: None,
        }
    }

    /// Serialize every worker's sampler state (one opaque blob per worker,
    /// in worker order) — the pool's contribution to a pipeline checkpoint.
    /// Must be called at an interval boundary (right after a finish): the
    /// data rings are drained there, so each blob observes exactly the
    /// post-close state the uninterrupted run would carry forward.
    pub fn snapshot_workers(&self) -> Vec<Vec<u8>> {
        match &self.imp {
            PoolImpl::Inline(s) => vec![s.to_snapshot_bytes()],
            PoolImpl::Threaded(t) => {
                let t0 = obs::metrics_enabled().then(std::time::Instant::now); // lint: wall-clock latency metric only, never feeds results
                let mut replies = Vec::new();
                for tx in &t.ctrl_txs {
                    let (rtx, rrx) = bounded(1);
                    let _ = tx.send(Msg::Snapshot(rtx));
                    replies.push(rrx);
                }
                let blobs =
                    replies.into_iter().map(|r| r.recv().unwrap_or_default()).collect();
                if let Some(t0) = t0 {
                    control_ack_hist().record_elapsed(t0);
                }
                blobs
            }
        }
    }

    /// Round-robin chunk cursor (always 0 for inline pools): which worker
    /// the next shipped chunk goes to.  Part of the checkpoint — a restored
    /// pool must resume the same partitioning or every post-restore chunk
    /// lands on the wrong sampler's RNG stream.
    pub fn transport_cursor(&self) -> u64 {
        match &self.imp {
            PoolImpl::Inline(_) => 0,
            PoolImpl::Threaded(t) => t.next as u64,
        }
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    pub fn kind(&self) -> SamplerKind {
        self.kind
    }

    /// Chunk-transport counters (`None` for the inline pool, which has no
    /// transport).
    pub fn transport_stats(&self) -> Option<TransportStats> {
        match &self.imp {
            PoolImpl::Inline(_) => None,
            PoolImpl::Threaded(t) => Some(t.stats),
        }
    }

    /// Event-time bounds `(min_ts, max_ts)` over the items ingested in the
    /// most recently closed interval (`None` if it was empty).  Threaded
    /// pools observe these worker-side off the SPSC chunk `ts` column, so
    /// equality with an inline pool's bounds certifies the column survives
    /// the transport bit-identically.
    pub fn interval_ts_bounds(&self) -> Option<(EventTime, EventTime)> {
        self.last_ts_bounds
    }

    /// Offer one item (chunk-round-robin partitioning across workers).
    #[inline]
    pub fn offer(&mut self, item: Item) {
        match &mut self.imp {
            PoolImpl::Inline(s) => {
                merge_ts_bounds(&mut self.cur_ts_bounds, item.ts, item.ts);
                s.offer(&item)
            }
            PoolImpl::Threaded(t) => t.offer(item),
        }
    }

    /// Offer a contiguous batch (the engines' per-interval feed).  Same
    /// chunk boundaries and worker assignment as repeated [`Self::offer`]
    /// calls, so seeded runs are chunk-size independent.
    pub fn offer_slice(&mut self, items: &[Item]) {
        let t0 = obs::metrics_enabled().then(std::time::Instant::now); // lint: wall-clock latency metric only, never feeds results
        match &mut self.imp {
            PoolImpl::Inline(s) => {
                let mut it = items.iter().map(|i| i.ts);
                if let Some(first) = it.next() {
                    let (lo, hi) = it.fold((first, first), |(lo, hi), t| (lo.min(t), hi.max(t)));
                    merge_ts_bounds(&mut self.cur_ts_bounds, lo, hi);
                }
                s.offer_slice(items)
            }
            PoolImpl::Threaded(t) => t.offer_slice(items),
        }
        if let Some(t0) = t0 {
            crate::obs_histogram!(
                "ingest_offer_ns",
                "wall time of one offer_slice call (per slice, never per item)"
            )
            .record_elapsed(t0);
        }
    }

    /// Offer a columnar (SoA) batch — the engines' per-interval fast path.
    /// Same chunk boundaries and worker assignment as [`Self::offer_slice`]
    /// over the equivalent items, so seeded runs are ingest-path
    /// independent (asserted by the columnar equivalence tests).
    // lint: hot-path — per-chunk dispatch into the sampler kernels
    pub fn offer_columnar(&mut self, chunk: &ColumnarChunk) {
        let t0 = obs::metrics_enabled().then(std::time::Instant::now); // lint: wall-clock latency metric only, never feeds results
        match &mut self.imp {
            PoolImpl::Inline(s) => {
                if let Some((lo, hi)) = ts_column_bounds(&chunk.ts) {
                    merge_ts_bounds(&mut self.cur_ts_bounds, lo, hi);
                }
                s.offer_columnar(chunk)
            }
            PoolImpl::Threaded(t) => t.offer_columnar(chunk),
        }
        if let Some(t0) = t0 {
            crate::obs_histogram!(
                "ingest_offer_ns",
                "wall time of one offer_slice call (per slice, never per item)"
            )
            .record_elapsed(t0);
        }
    }

    /// Close the interval on every worker and merge their results
    /// (sketch-partial-free view of
    /// [`Self::finish_interval_with_sketches`]).
    pub fn finish_interval(&mut self) -> SampleResult {
        self.finish_interval_with_sketches().0
    }

    /// Close the interval on every worker and merge their results *and*
    /// their pre-built sketch partials — one merged [`PaneSketch`] per
    /// registered spec, in registration order (empty when nothing is
    /// registered).  Worker partials fold in worker order, the same
    /// barrier-free associative combine as the samples, so a single-worker
    /// pool returns a sketch byte-identical to rebuilding from the merged
    /// interval result.
    pub fn finish_interval_with_sketches(&mut self) -> (SampleResult, Vec<PaneSketch>) {
        let (result, sketches) = self.finish_impl();
        self.last_ts_bounds = self.cur_ts_bounds.take();
        // Interval-close accounting: one counter batch per interval, zero
        // per-item cost.  RNG draws equal items offered for the per-item
        // rate samplers (OASRS/SRS draw once per offer).
        let arrived = result.arrived() as u64;
        crate::obs_counter!("ingest_items_total", "items offered to the sampling plane").add(arrived);
        crate::obs_counter!("ingest_accepts_total", "sampled items surviving admission")
            .add(result.sample.len() as u64);
        crate::obs_counter!("ingest_rng_draws_total", "sampler RNG draws (one per offered item)")
            .add(arrived);
        if let PoolImpl::Threaded(t) = &self.imp {
            crate::obs_gauge!(
                "transport_recycle_hit_rate",
                "fraction of buffer acquisitions served by recycling (0.0 when idle)"
            )
            .set(t.stats.recycle_hit_rate());
        }
        (result, sketches)
    }

    fn finish_impl(&mut self) -> (SampleResult, Vec<PaneSketch>) {
        match &mut self.imp {
            PoolImpl::Inline(s) => {
                let result = match s.as_mut() {
                    WorkerSampler::Sts(sts) => {
                        // Single worker: counts -> proportional targets ->
                        // sample.
                        let counts = sts.local_counts();
                        let targets = proportional_targets(&counts, self.fraction);
                        sts.finish_with_targets(&targets)
                    }
                    other => other.finish_simple(),
                };
                let sketches = build_partials(&self.specs, &result);
                (result, sketches)
            }
            PoolImpl::Threaded(t) => {
                t.flush();
                let finishes: Vec<WorkerFinish> = if self.kind == SamplerKind::Sts {
                    // Phase 1: count pass (synchronization barrier — the
                    // coordinator must gather every worker's counts before
                    // any worker may sample).
                    let mut replies = Vec::new();
                    for tx in t.ctrl_txs.iter() {
                        let (rtx, rrx) = bounded(1);
                        let _ = tx.send(Msg::Counts(rtx));
                        replies.push(rrx);
                    }
                    let per_worker: Vec<[usize; MAX_STRATA]> = replies
                        .into_iter()
                        .map(|r| r.recv().unwrap_or([0; MAX_STRATA]))
                        .collect();
                    let mut global = [0usize; MAX_STRATA];
                    for c in &per_worker {
                        for s in 0..MAX_STRATA {
                            global[s] += c[s];
                        }
                    }
                    let global_targets = proportional_targets(&global, self.fraction);
                    // Phase 2: split each stratum's global target across the
                    // workers by largest remainder (sums exactly), then
                    // sample.
                    let worker_targets =
                        allocate_worker_targets(&global_targets, &per_worker, &global);
                    let mut replies = Vec::new();
                    for (w, tx) in t.ctrl_txs.iter().enumerate() {
                        let (rtx, rrx) = bounded(1);
                        let _ = tx.send(Msg::FinishSts(worker_targets[w], rtx));
                        replies.push(rrx);
                    }
                    replies.into_iter().filter_map(|r| r.recv()).collect()
                } else {
                    let mut replies = Vec::new();
                    for tx in t.ctrl_txs.iter() {
                        let (rtx, rrx) = bounded(1);
                        let _ = tx.send(Msg::Finish(rtx));
                        replies.push(rrx);
                    }
                    replies.into_iter().filter_map(|r| r.recv()).collect()
                };
                // Merge samples and sketch partials in worker order — the
                // same grouping, so weights and concatenation stay aligned.
                let mut sketches: Vec<PaneSketch> = Vec::new();
                let mut results = Vec::with_capacity(finishes.len());
                for f in finishes {
                    if let Some((lo, hi)) = f.ts_bounds {
                        merge_ts_bounds(&mut self.cur_ts_bounds, lo, hi);
                    }
                    if sketches.is_empty() {
                        sketches = f.sketches;
                    } else {
                        debug_assert_eq!(sketches.len(), f.sketches.len());
                        for (acc, part) in sketches.iter_mut().zip(&f.sketches) {
                            acc.merge_same(part);
                        }
                    }
                    results.push(f.result);
                }
                (merge_worker_results(results), sketches)
            }
        }
    }

    /// Register the sketch specs every interval close should pre-build
    /// partials for (one [`PaneSketch`] per spec per close).  Blocks until
    /// every worker has applied the registration — the same acked
    /// rendezvous as [`Self::set_fraction`], so registration orders before
    /// any chunk shipped afterwards.  Replaces any previous registration;
    /// an empty slice unregisters.
    ///
    /// Rejects `WeightedRes` (A-ExpJ) pools: value-biased designs give each
    /// item an inclusion probability the count-based Horvitz–Thompson
    /// weights in the sketch fold do not model, so the resulting sketch
    /// mass would be silently uncalibrated (the ROADMAP residual this
    /// rejection closes).  A future fix would thread per-item inclusion
    /// probabilities from the A-ExpJ keys into the fold; until then the
    /// combination fails loudly here, mirroring how accuracy-target
    /// budgets reject sketch queries in `validate_budget`.
    pub fn register_sketches(&mut self, specs: &[SketchSpec]) -> Result<()> {
        if self.kind == SamplerKind::WeightedRes && !specs.is_empty() {
            return Err(Error::Config(
                "sketch registration cannot run over the WeightedRes (A-ExpJ) \
                 sampler: its value-biased inclusion probabilities are not \
                 modeled by the count-based Horvitz-Thompson weights the \
                 sketch fold uses, so quantile/distinct/top-k mass would be \
                 uncalibrated - use Oasrs, Srs, or Sts for sketch-backed \
                 queries"
                    .to_string(),
            ));
        }
        self.specs = specs.to_vec();
        if let PoolImpl::Threaded(t) = &mut self.imp {
            let t0 = obs::metrics_enabled().then(std::time::Instant::now); // lint: wall-clock latency metric only, never feeds results
            let mut acks = Vec::new();
            for tx in &t.ctrl_txs {
                let (rtx, rrx) = bounded(1);
                let _ = tx.send(Msg::RegisterSketches(self.specs.clone(), rtx));
                acks.push(rrx);
            }
            for ack in acks {
                let _ = ack.recv();
            }
            if let Some(t0) = t0 {
                control_ack_hist().record_elapsed(t0);
            }
        }
        Ok(())
    }

    /// Update the sampling fraction for subsequent intervals.  Blocks
    /// until every worker has applied it (see [`Msg::SetFraction`]); the
    /// engines call this between intervals, where the data rings are
    /// already drained, so the rendezvous is a few idle-poll latencies.
    pub fn set_fraction(&mut self, fraction: f64) {
        self.fraction = fraction;
        match &mut self.imp {
            PoolImpl::Inline(s) => s.set_fraction(fraction),
            PoolImpl::Threaded(t) => {
                let t0 = obs::metrics_enabled().then(std::time::Instant::now); // lint: wall-clock latency metric only, never feeds results
                let mut acks = Vec::new();
                for tx in &t.ctrl_txs {
                    let (rtx, rrx) = bounded(1);
                    let _ = tx.send(Msg::SetFraction(fraction, rtx));
                    acks.push(rrx);
                }
                for ack in acks {
                    let _ = ack.recv();
                }
                if let Some(t0) = t0 {
                    control_ack_hist().record_elapsed(t0);
                }
            }
        }
    }
}

/// Shared histogram for the acked control-plane rendezvous
/// (`set_fraction` / `register_sketches`): time from first send to last
/// worker ack.
fn control_ack_hist() -> obs::Histogram {
    crate::obs_histogram!(
        "control_ack_ns",
        "rendezvous ack latency for set_fraction / register_sketches"
    )
}

impl Drop for IngestPool {
    fn drop(&mut self) {
        if let PoolImpl::Threaded(t) = &mut self.imp {
            for tx in t.ctrl_txs.iter() {
                tx.close();
            }
            for j in t.joins.drain(..) {
                let _ = j.join();
            }
        }
    }
}

/// Proportional STS allocation: `k_i = round(fraction * C_i)`, at least one
/// item from every non-empty stratum.
fn proportional_targets(counts: &[usize; MAX_STRATA], fraction: f64) -> [usize; MAX_STRATA] {
    let mut t = [0usize; MAX_STRATA];
    for s in 0..MAX_STRATA {
        if counts[s] > 0 {
            t[s] = ((fraction * counts[s] as f64).round() as usize).clamp(1, counts[s]);
        }
    }
    t
}

/// Split each stratum's global target across workers so the per-worker
/// targets **sum exactly** to `global_targets[s]`.
///
/// Largest-remainder (Hamilton) allocation: every worker gets the floor of
/// its proportional share `target · c_w / C`, then the leftover units go to
/// the workers with the largest remainders (ties broken toward the lower
/// worker index, so the allocation is deterministic).  Independent
/// per-worker rounding — the previous scheme — can miss the global target
/// by up to `n_workers / 2` items per stratum, which made
/// `sampleByKeyExact` not actually exact under multi-worker runs.
fn allocate_worker_targets(
    global_targets: &[usize; MAX_STRATA],
    per_worker: &[[usize; MAX_STRATA]],
    global: &[usize; MAX_STRATA],
) -> Vec<[usize; MAX_STRATA]> {
    let n = per_worker.len();
    let mut out = vec![[0usize; MAX_STRATA]; n];
    for s in 0..MAX_STRATA {
        let c_total = global[s] as u64;
        let target = global_targets[s] as u64;
        if c_total == 0 || target == 0 {
            continue;
        }
        let mut assigned = 0u64;
        let mut rems: Vec<(u64, usize)> = Vec::with_capacity(n);
        for (w, counts) in per_worker.iter().enumerate() {
            let num = target * counts[s] as u64;
            let q = num / c_total;
            out[w][s] = q as usize;
            assigned += q;
            rems.push((num % c_total, w));
        }
        let mut left = target.saturating_sub(assigned);
        rems.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        for (_, w) in rems {
            if left == 0 {
                break;
            }
            if out[w][s] < per_worker[w][s] {
                out[w][s] += 1;
                left -= 1;
            }
        }
        // Safety net: a worker can be capped by its local count; hand the
        // leftovers to anyone with items to spare (capacity always suffices
        // because target <= C).
        while left > 0 {
            let mut moved = false;
            for (o, c) in out.iter_mut().zip(per_worker.iter()) {
                if left > 0 && o[s] < c[s] {
                    o[s] += 1;
                    left -= 1;
                    moved = true;
                }
            }
            if !moved {
                break;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::estimator::{estimate, StrataPartials};

    fn feed(pool: &mut IngestPool, n: usize, strata: usize) {
        for i in 0..n {
            pool.offer(Item::new((i % strata) as u16, i as f64, i as u64));
        }
    }

    #[test]
    fn inline_oasrs_counts_everything() {
        let mut p = IngestPool::new(SamplerKind::Oasrs, 1, 0.5, 1);
        feed(&mut p, 1000, 4);
        let r = p.finish_interval();
        assert_eq!(r.arrived(), 1000.0);
    }

    #[test]
    fn threaded_oasrs_counts_everything() {
        let mut p = IngestPool::new(SamplerKind::Oasrs, 4, 0.5, 2);
        feed(&mut p, 10_000, 4);
        let r = p.finish_interval();
        assert_eq!(r.arrived(), 10_000.0);
        // second interval isolated
        let r2 = p.finish_interval();
        assert_eq!(r2.arrived(), 0.0);
    }

    #[test]
    fn threaded_sts_proportional() {
        let mut p = IngestPool::new(SamplerKind::Sts, 4, 0.5, 3);
        for i in 0..8000 {
            p.offer(Item::new(0, i as f64, 0));
        }
        for i in 0..2000 {
            p.offer(Item::new(1, i as f64, 0));
        }
        let r = p.finish_interval();
        let n0 = r.sample.iter().filter(|(s, _)| *s == 0).count() as f64;
        let n1 = r.sample.iter().filter(|(s, _)| *s == 1).count() as f64;
        // largest-remainder allocation hits the global target exactly
        assert_eq!(n0, 4000.0, "n0 {n0}");
        assert_eq!(n1, 1000.0, "n1 {n1}");
        assert_eq!(r.state.c[0], 8000.0);
    }

    #[test]
    fn sts_estimate_accuracy_multi_worker() {
        let mut p = IngestPool::new(SamplerKind::Sts, 3, 0.25, 4);
        let mut rng = Rng::seed_from_u64(5);
        let mut exact = 0.0;
        for _ in 0..10_000 {
            let v = rng.normal(100.0, 10.0);
            p.offer(Item::new(0, v, 0));
            exact += v;
        }
        for _ in 0..50 {
            let v = rng.normal(50_000.0, 100.0);
            p.offer(Item::new(2, v, 0));
            exact += v;
        }
        let r = p.finish_interval();
        let est = estimate(&StrataPartials::from_sample(&r.sample), &r.state);
        let rel = (est.sum - exact).abs() / exact;
        assert!(rel < 0.02, "rel err {rel}");
    }

    #[test]
    fn srs_multi_worker_fraction() {
        let mut p = IngestPool::new(SamplerKind::Srs, 2, 0.3, 6);
        feed(&mut p, 10_000, 3);
        let r = p.finish_interval();
        let f = r.fraction();
        assert!((f - 0.3).abs() < 0.01, "fraction {f}");
    }

    #[test]
    fn weighted_res_multi_worker_counts_everything() {
        let mut p = IngestPool::new(SamplerKind::WeightedRes, 3, 0.2, 21);
        for i in 0..9_000 {
            p.offer(Item::new((i % 3) as u16, 1.0 + (i % 10) as f64, i as u64));
        }
        let r = p.finish_interval();
        assert_eq!(r.arrived(), 9_000.0);
        assert!(!r.sample.is_empty());
        assert!(r.sample.len() < 9_000);
    }

    #[test]
    fn native_multi_worker_keeps_all() {
        let mut p = IngestPool::new(SamplerKind::None, 4, 1.0, 7);
        feed(&mut p, 5000, 5);
        let r = p.finish_interval();
        assert_eq!(r.sample.len(), 5000);
    }

    #[test]
    fn set_fraction_propagates() {
        let mut p = IngestPool::new(SamplerKind::Sts, 2, 0.5, 8);
        p.set_fraction(0.1);
        feed(&mut p, 10_000, 2);
        let r = p.finish_interval();
        let f = r.fraction();
        assert!((f - 0.1).abs() < 0.01, "fraction {f}");
    }

    #[test]
    fn set_fraction_ack_applies_before_next_interval_chunks() {
        // OASRS applies the fraction at ingest (capacities lock at the
        // first offer of an interval), so the set_fraction ack rendezvous
        // must land on every worker before the next interval's chunks do.
        let mut p = IngestPool::new(SamplerKind::Oasrs, 2, 0.5, 31);
        for i in 0..20_000 {
            p.offer(Item::new(0, 1.0, i));
        }
        p.finish_interval(); // per-worker EWMA = 10k
        p.set_fraction(0.01);
        for i in 0..20_000 {
            p.offer(Item::new(0, 1.0, i));
        }
        let r = p.finish_interval();
        // per worker: cap = 0.01 * 10k = 100 -> merged n_cap = 200; a
        // worker that ingested under the stale 0.5 would report ~5000.
        assert!(
            r.state.n_cap[0] <= 300.0,
            "stale fraction reached a worker: n_cap {}",
            r.state.n_cap[0]
        );
    }

    #[test]
    fn oasrs_no_sync_rare_stratum_kept_across_workers() {
        let mut p = IngestPool::new(SamplerKind::Oasrs, 4, 0.1, 9);
        for i in 0..100_000 {
            p.offer(Item::new(0, 1.0, i));
        }
        for _ in 0..8 {
            p.offer(Item::new(2, 1e6, 0));
        }
        let r = p.finish_interval();
        let n2 = r.sample.iter().filter(|(s, _)| *s == 2).count();
        assert_eq!(n2, 8);
    }

    #[test]
    fn offer_slice_matches_offer_threaded_counts() {
        let items: Vec<Item> =
            (0..7000).map(|i| Item::new((i % 5) as u16, i as f64, i as u64)).collect();
        let mut a = IngestPool::new(SamplerKind::None, 3, 1.0, 10);
        let mut b = IngestPool::new(SamplerKind::None, 3, 1.0, 10);
        for &it in &items {
            a.offer(it);
        }
        b.offer_slice(&items);
        let (ra, rb) = (a.finish_interval(), b.finish_interval());
        assert_eq!(ra.sample.len(), rb.sample.len());
        assert_eq!(ra.state.c, rb.state.c);
    }

    #[test]
    fn offer_columnar_matches_offer_slice_threaded_byte_identical() {
        // Same chunk boundaries, same worker round-robin, same per-worker
        // kernels: an SoA feed must reproduce the AoS feed bit-for-bit.
        let items: Vec<Item> =
            (0..7000).map(|i| Item::new((i % 5) as u16, i as f64, i as u64)).collect();
        let chunk = ColumnarChunk::from_items(&items);
        for kind in [
            SamplerKind::Oasrs,
            SamplerKind::Srs,
            SamplerKind::Sts,
            SamplerKind::WeightedRes,
            SamplerKind::None,
        ] {
            let mut a = IngestPool::new(kind, 3, 0.3, 10);
            let mut b = IngestPool::new(kind, 3, 0.3, 10);
            a.offer_slice(&items);
            b.offer_columnar(&chunk);
            let (ra, rb) = (a.finish_interval(), b.finish_interval());
            assert_eq!(ra.sample, rb.sample, "{kind:?}");
            assert_eq!(ra.state.c, rb.state.c, "{kind:?}");
        }
    }

    #[test]
    fn threaded_steady_state_reuses_buffers() {
        // The zero-allocation acceptance check: the pool is pre-sized at
        // construction, so every chunk ever shipped is served by a
        // recycled buffer and the allocation counter never moves — under
        // any worker/coordinator interleaving, not just lucky timing.
        let mut p = IngestPool::new(SamplerKind::Oasrs, 2, 0.5, 11);
        let constructed = (2 * RETURN_RING_CAP + 1) as u64;
        assert_eq!(p.transport_stats().unwrap().buffers_allocated, constructed);
        let feed_interval = |p: &mut IngestPool| {
            for i in 0..20 * CHUNK {
                p.offer(Item::new((i % 4) as u16, i as f64, i as u64));
            }
            p.finish_interval();
        };
        feed_interval(&mut p);
        let warm = p.transport_stats().unwrap();
        assert!(warm.chunks_sent >= 20);
        assert_eq!(warm.buffers_recycled, warm.chunks_sent);
        for _ in 0..3 {
            feed_interval(&mut p);
        }
        let now = p.transport_stats().unwrap();
        assert_eq!(
            now.buffers_allocated, constructed,
            "ingest must never allocate chunk buffers after construction"
        );
        assert_eq!(now.buffers_recycled, now.chunks_sent);
        assert!(now.recycle_hit_rate() > 0.5, "rate {}", now.recycle_hit_rate());
    }

    #[test]
    fn inline_pool_has_no_transport_stats() {
        let p = IngestPool::new(SamplerKind::Oasrs, 1, 0.5, 12);
        assert!(p.transport_stats().is_none());
    }

    #[test]
    fn idle_pool_recycle_hit_rate_is_zero_not_nan() {
        // Zero-denominator guard: a stats block that has never acquired a
        // buffer must report 0.0, not NaN (ratio gauges feed dashboards —
        // NaN poisons min/max/avg panels silently).
        let idle = TransportStats::default();
        assert_eq!(idle.recycle_hit_rate(), 0.0);
        assert!(idle.recycle_hit_rate().is_finite());
        // A freshly constructed threaded pool has recycled nothing yet:
        // still finite, still zero.
        let p = IngestPool::new(SamplerKind::Oasrs, 2, 0.5, 77);
        let rate = p.transport_stats().unwrap().recycle_hit_rate();
        assert_eq!(rate, 0.0);
    }

    #[test]
    fn weighted_res_pool_rejects_sketch_registration() {
        use crate::sketch::SketchSpec;
        // The ROADMAP calibration residual, closed the cheap way: A-ExpJ
        // inclusion probabilities are value-biased, so the count-based HT
        // weights in the sketch fold would produce uncalibrated mass —
        // reject loudly instead (cf. validate_budget for the analogous
        // budget/query rejection).
        for workers in [1usize, 2] {
            let mut p = IngestPool::new(SamplerKind::WeightedRes, workers, 0.3, 55);
            let err = p.register_sketches(&[SketchSpec::Quantile { clusters: 32 }]);
            let msg = err.err().expect("WeightedRes registration must fail").to_string();
            assert!(msg.contains("WeightedRes"), "unhelpful error: {msg}");
            assert!(msg.contains("uncalibrated"), "unhelpful error: {msg}");
            // the pool stays usable for plain sampling, with no partials
            feed(&mut p, 2_000, 3);
            let (r, sks) = p.finish_interval_with_sketches();
            assert_eq!(r.arrived(), 2_000.0);
            assert!(sks.is_empty());
            // unregistering (empty slice) is always allowed
            p.register_sketches(&[]).unwrap();
        }
    }

    #[test]
    fn largest_remainder_sums_exactly() {
        // 5 workers, 3 items each, target 7: independent rounding gives
        // round(7*3/15) = 1 per worker = 5 != 7; largest remainder hits 7.
        let mut per_worker = vec![[0usize; MAX_STRATA]; 5];
        let mut global = [0usize; MAX_STRATA];
        for t in per_worker.iter_mut() {
            t[0] = 3;
        }
        global[0] = 15;
        let mut targets = [0usize; MAX_STRATA];
        targets[0] = 7;
        let out = allocate_worker_targets(&targets, &per_worker, &global);
        let total: usize = out.iter().map(|t| t[0]).sum();
        assert_eq!(total, 7);
        for (w, t) in out.iter().enumerate() {
            assert!(t[0] <= per_worker[w][0], "worker {w} over-allocated");
        }
    }

    #[test]
    fn largest_remainder_respects_local_counts_and_sums() {
        // Randomized splits: the summed allocation always equals the global
        // target and never exceeds a worker's local count.
        let mut rng = Rng::seed_from_u64(99);
        for _ in 0..500 {
            let n = rng.range_usize(1, 9);
            let mut per_worker = vec![[0usize; MAX_STRATA]; n];
            let mut global = [0usize; MAX_STRATA];
            for s in 0..4 {
                for pw in per_worker.iter_mut() {
                    let c = rng.range_usize(0, 50);
                    pw[s] = c;
                    global[s] += c;
                }
            }
            let mut targets = [0usize; MAX_STRATA];
            for s in 0..4 {
                if global[s] > 0 {
                    targets[s] = rng.range_usize(0, global[s] + 1);
                }
            }
            let out = allocate_worker_targets(&targets, &per_worker, &global);
            for s in 0..4 {
                let total: usize = out.iter().map(|t| t[s]).sum();
                assert_eq!(total, targets[s], "stratum {s}");
                for (o, c) in out.iter().zip(per_worker.iter()) {
                    assert!(o[s] <= c[s]);
                }
            }
        }
    }

    #[test]
    fn unregistered_pool_returns_no_sketches() {
        let mut p = IngestPool::new(SamplerKind::Oasrs, 2, 0.5, 40);
        feed(&mut p, 2_000, 3);
        let (r, sks) = p.finish_interval_with_sketches();
        assert_eq!(r.arrived(), 2_000.0);
        assert!(sks.is_empty());
    }

    #[test]
    fn inline_prebuilt_sketch_is_byte_identical_to_rebuild() {
        use crate::sketch::SketchSpec;
        // Two identical single-worker pools: one registered, one not.  The
        // worker-built pane sketch must equal rebuilding from the merged
        // interval result bit-for-bit (the tentpole's single-worker
        // acceptance gate at the pool level).
        let specs = [
            SketchSpec::Quantile { clusters: 64 },
            SketchSpec::Distinct { precision: 10 },
            SketchSpec::TopK { capacity: 16, cm_width: 256, cm_depth: 4, seed: 0x70_4B },
        ];
        let mut registered = IngestPool::new(SamplerKind::Oasrs, 1, 0.4, 41);
        let mut plain = IngestPool::new(SamplerKind::Oasrs, 1, 0.4, 41);
        registered.register_sketches(&specs).unwrap();
        for interval in 0..3 {
            for i in 0..5_000u64 {
                let it = Item::new((i % 4) as u16, (i * 7 % 1000) as f64, interval * 5_000 + i);
                registered.offer(it);
                plain.offer(it);
            }
            let (ra, sks) = registered.finish_interval_with_sketches();
            let rb = plain.finish_interval();
            assert_eq!(ra.sample, rb.sample, "registration changed the sample");
            assert_eq!(ra.state, rb.state);
            assert_eq!(sks.len(), specs.len());
            for (spec, built) in specs.iter().zip(&sks) {
                assert!(built.matches(spec));
                assert_eq!(*built, spec.build(&rb), "worker-built != rebuild");
            }
        }
    }

    #[test]
    fn threaded_partials_merge_to_consistent_sketches() {
        use crate::sketch::{PaneSketch, SketchSpec};
        // 3 workers, registered quantile + top-k.  Partials merge through
        // the same associative combine as the samples; per-stratum sketch
        // mass must match the arrival counters exactly (Σ HT weights of a
        // stratum's sample = C_i for count-based samplers).
        let specs = [
            SketchSpec::Quantile { clusters: 100 },
            SketchSpec::TopK { capacity: 16, cm_width: 1024, cm_depth: 4, seed: 0x70_4B },
        ];
        let mut p = IngestPool::new(SamplerKind::Oasrs, 3, 0.3, 42);
        p.register_sketches(&specs).unwrap();
        // warm-up interval so OASRS capacities are sized
        feed(&mut p, 30_000, 4);
        p.finish_interval();
        feed(&mut p, 30_000, 4);
        let (r, sks) = p.finish_interval_with_sketches();
        assert_eq!(sks.len(), 2);
        let arrived = r.arrived();
        match &sks[0] {
            PaneSketch::Quantile(sk) => {
                assert!(
                    (sk.total_weight() - arrived).abs() <= 1e-6 * arrived,
                    "quantile mass {} vs arrivals {arrived}",
                    sk.total_weight()
                );
            }
            other => panic!("wrong kind: {other:?}"),
        }
        match &sks[1] {
            PaneSketch::TopK(hh) => {
                assert!((hh.total_weight() - arrived).abs() <= 1e-6 * arrived);
                for (key, count) in hh.top_k(4) {
                    let c = r.state.c[key as usize];
                    assert!(
                        (count - c).abs() <= 1e-6 * c.max(1.0),
                        "stratum {key}: sketch count {count} vs arrivals {c}"
                    );
                }
            }
            other => panic!("wrong kind: {other:?}"),
        }
        // next interval: partials reset
        let (_, sks2) = p.finish_interval_with_sketches();
        match &sks2[0] {
            PaneSketch::Quantile(sk) => assert!(sk.is_empty()),
            other => panic!("wrong kind: {other:?}"),
        }
    }

    #[test]
    fn registration_orders_before_subsequent_chunks() {
        use crate::sketch::{PaneSketch, SketchSpec};
        // Register mid-stream: every item offered after the (acked)
        // registration must be captured in the next close's partials.
        let mut p = IngestPool::new(SamplerKind::None, 2, 1.0, 43);
        feed(&mut p, 1_000, 2);
        p.finish_interval();
        p.register_sketches(&[SketchSpec::Quantile { clusters: 32 }]).unwrap();
        feed(&mut p, 4_000, 2);
        let (r, sks) = p.finish_interval_with_sketches();
        assert_eq!(r.sample.len(), 4_000);
        match &sks[0] {
            // native sampler: weight 1 per item — the partials saw all 4000
            PaneSketch::Quantile(sk) => assert_eq!(sk.total_weight(), 4_000.0),
            other => panic!("wrong kind: {other:?}"),
        }
    }

    #[test]
    fn threaded_sts_exact_total_sample_size() {
        // sampleByKeyExact must be *exact*: the merged sample hits the
        // global per-stratum target even when the count does not divide
        // evenly across workers.
        let mut p = IngestPool::new(SamplerKind::Sts, 4, 0.5, 13);
        for i in 0..8001 {
            p.offer(Item::new(0, i as f64, 0));
        }
        let r = p.finish_interval();
        // target = round(0.5 * 8001) = 4001 (previously ±workers/2 off)
        assert_eq!(r.sample.len(), 4001);
    }

    use crate::util::rng::Rng;
}
