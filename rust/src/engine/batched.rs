//! Batched stream processing (Spark-Streaming-like; paper §2.2, §4.2.1).
//!
//! The input is cut into micro-batches at a fixed batch interval (virtual
//! time).  Per batch:
//!
//! 1. **Ingest + sampling.**  OASRS/native sample *at ingest*, before the
//!    batch forms (the paper's key Spark modification — pre-RDD sampling).
//!    SRS/STS are batch-fashion: their workers buffer the batch (the "RDD")
//!    and sample only when it closes; STS additionally pays its two-phase
//!    count/sample synchronization at every batch boundary.
//! 2. **Interval close.**  The per-worker results merge into the batch's
//!    `SampleResult` (a scheduling rendezvous per batch — the cost that
//!    grows as the batch interval shrinks, Fig. 5c).
//! 3. **Windowing.**  Batch results accumulate in the window ring; when a
//!    batch ends on a slide boundary the merged window sample is shipped to
//!    the query executor (the XLA-backed data-parallel job) and the result
//!    is emitted with error bounds.

use std::time::Instant;

use crate::budget::CostFunction;
use crate::core::{ColumnarChunk, Error, EventTime, Item, Result};
use crate::query::{Query, QueryExecutor, SketchWindow};
use crate::runtime::checkpoint::{
    self, CheckpointSpec, CheckpointStore, PipelineSnapshot, Snapshot, SnapshotWriter,
};
use crate::sampling::SamplerKind;
use crate::window::{DropLedger, EventTimeSlicer, ExactAgg, WindowAssembler, WindowConfig};

use super::worker::IngestPool;
use super::{EngineConfig, RunReport, WindowReport};

/// Batched engine over a finite, event-time-sorted trace.
#[derive(Debug)]
pub struct BatchedEngine<'a> {
    config: &'a EngineConfig,
    window: WindowConfig,
    query: Query,
    executor: &'a QueryExecutor,
}

impl<'a> BatchedEngine<'a> {
    pub fn new(
        config: &'a EngineConfig,
        window: WindowConfig,
        query: Query,
        executor: &'a QueryExecutor,
    ) -> Self {
        Self { config, window, query, executor }
    }

    /// Run the engine over `items` with the given sampler and budget.
    pub fn run(
        &self,
        items: &[Item],
        sampler_kind: SamplerKind,
        cost: &mut CostFunction,
    ) -> Result<RunReport> {
        self.run_inner(items, sampler_kind, cost, None, None)
    }

    /// Run with periodic epoch-stamped snapshots per `spec` (and, for the
    /// crash-injection suite, an optional deterministic stop).
    pub fn run_checkpointed(
        &self,
        items: &[Item],
        sampler_kind: SamplerKind,
        cost: &mut CostFunction,
        spec: &CheckpointSpec,
    ) -> Result<RunReport> {
        self.run_inner(items, sampler_kind, cost, Some(spec), None)
    }

    /// Restore from the newest valid snapshot in `spec.dir` and resume the
    /// run from the recorded broker offset with restored sampler/window
    /// state.  The emitted windows are bit-identical to the suffix the
    /// uninterrupted run would have produced from the same boundary.
    pub fn recover(
        &self,
        items: &[Item],
        sampler_kind: SamplerKind,
        cost: &mut CostFunction,
        spec: &CheckpointSpec,
    ) -> Result<RunReport> {
        let store = CheckpointStore::open(spec.dir.clone())?;
        let loaded = store.load_latest()?.ok_or_else(|| {
            Error::Config(format!("no snapshot to restore in {}", spec.dir.display()))
        })?;
        let snap = PipelineSnapshot::from_snapshot_bytes(&loaded.payload)?;
        let current = super::fingerprint(
            self.config,
            &self.window,
            super::EngineKind::Batched,
            sampler_kind,
        );
        snap.fingerprint.check(&current)?;
        if std::mem::discriminant(snap.cost.budget()) != std::mem::discriminant(cost.budget()) {
            return Err(Error::Config(format!(
                "snapshot budget {:?} does not match the requested budget {:?}",
                snap.cost.budget(),
                cost.budget()
            )));
        }
        checkpoint::record_restore();
        self.run_inner(items, sampler_kind, cost, Some(spec), Some(snap))
    }

    fn run_inner(
        &self,
        items: &[Item],
        sampler_kind: SamplerKind,
        cost: &mut CostFunction,
        ckpt: Option<&CheckpointSpec>,
        resume: Option<PipelineSnapshot>,
    ) -> Result<RunReport> {
        super::validate_budget(&self.query, cost)?;
        let interval = self.config.batch_interval_ms.min(self.window.slide_ms);
        let interval = gcd_fit(interval, self.window.slide_ms);
        let mut assembler = WindowAssembler::with_interval(self.window, interval);
        // Pane-level sketches for sketch-backed queries: one sketch per
        // batch, built by the ingest workers (spec registered below) and
        // merged incrementally at the window boundary.
        let mut sketches = if self.config.sketch_panes {
            SketchWindow::for_query(
                &self.query,
                self.executor.sketch_params(),
                assembler.panes_per_window(),
            )
        } else {
            None
        };
        // Long-window spill: with pre-built pane sketches the window's
        // sample deque has no reader, so past the configured ratio the
        // assembler keeps only pane summaries.
        if sketches.is_some() && self.config.spills_at(assembler.panes_per_window()) {
            assembler.spill_samples();
        }
        let fingerprint = super::fingerprint(
            self.config,
            &self.window,
            super::EngineKind::Batched,
            sampler_kind,
        );
        let store = ckpt.map(|s| CheckpointStore::create(s.dir.clone())).transpose()?;
        let mut ledger = DropLedger::new(interval);
        let mut intervals_done = 0u64;
        let mut windows_base = 0u64;
        let mut idx = 0usize;
        let resumed = resume.is_some();
        let mut pool = match resume {
            Some(snap) => {
                // The query shape is not part of the fingerprint, so the
                // sketch state carries its own compatibility witness: the
                // restored pane store must belong to the same sketch spec
                // this run would register.
                match (&snap.sketches, &sketches) {
                    (None, None) => {}
                    (Some(s), Some(f)) if s.spec() == f.spec() => {}
                    _ => {
                        return Err(Error::Config(
                            "snapshot sketch state does not match this query's sketch \
                             configuration (was the snapshot taken under a different query?)"
                                .into(),
                        ))
                    }
                }
                intervals_done = snap.epoch;
                windows_base = snap.windows_emitted;
                idx = snap.item_offset as usize;
                assembler = snap.assembler;
                sketches = snap.sketches;
                ledger = snap.ledger;
                *cost = snap.cost;
                IngestPool::restore(
                    sampler_kind,
                    self.config.workers,
                    snap.fraction,
                    &snap.workers,
                    snap.transport_cursor,
                )?
            }
            None => IngestPool::new(
                sampler_kind,
                self.config.workers,
                cost.fraction(),
                self.config.seed,
            ),
        };
        // Sketch registration is a control-plane message on the pool: the
        // acked rendezvous orders it before every chunk of the run.
        if let Some(sw) = &sketches {
            pool.register_sketches(&[sw.spec()])?;
        }
        let query_builds_at_start = self.executor.query_time_sketch_builds();
        let obs_start = crate::obs::global().snapshot();
        // Event-time mode: panes come from the watermark-driven router
        // (arrival order in, canonical event-time panes out) instead of the
        // arrival-order range scan.  `None` keeps the legacy path
        // byte-identical.
        let mut slicer =
            self.config.event_time.map(|et| EventTimeSlicer::new(items, interval, et));
        if resumed && intervals_done > 0 {
            if let Some(sl) = slicer.as_mut() {
                // The watermark router's pane assignment depends only on
                // event times, so recovery replays the consumed prefix
                // through a fresh router and discards the already-emitted
                // panes (and the already-checkpointed drop charges); the
                // slicer consumes no RNG, so the surviving panes are
                // byte-identical to the uninterrupted run's.
                let mut replayed = 0u64;
                for _ in 0..intervals_done {
                    match sl.next_pane() {
                        Some(pane) => replayed += pane.len() as u64,
                        None => break,
                    }
                }
                let _ = sl.take_new_drops();
                checkpoint::record_replayed_items(replayed);
            }
            // Legacy mode seeks straight to the recorded offset — the
            // event-time-sorted trace is a seekable broker, so no replay.
        }

        let mut report = RunReport::default();
        let mut exact = ExactAgg::default();
        let start = Instant::now(); // lint: wall-clock latency metric only, never feeds results

        // A resumed legacy run whose snapshot was taken at end-of-trace has
        // nothing left to ingest; entering the loop would process a phantom
        // empty batch the uninterrupted run never saw.
        let exhausted = resumed && slicer.is_none() && idx >= items.len();

        // Reusable SoA staging chunk: one AoS->SoA transpose per batch,
        // then the whole slice rides the columnar fast path (capacity is
        // retained across intervals — zero steady-state allocation).
        let mut ingest_chunk = ColumnarChunk::new();
        loop {
            if exhausted {
                break;
            }
            let batch_end = assembler.current_interval_end();
            // Ingest this batch's contiguous slice (sampling at ingest for
            // stream-fashion samplers; buffering for batch-fashion ones).
            // Legacy mode range-scans the event-time-sorted trace; event-time
            // mode takes the next watermark-closed pane (canonical order, so
            // a bounded shuffle of the trace yields the same pane bytes).
            let pane_buf;
            let batch_items: &[Item] = if let Some(sl) = slicer.as_mut() {
                match sl.next_pane() {
                    Some(pane) => {
                        pane_buf = pane;
                        &pane_buf
                    }
                    None => break,
                }
            } else {
                let batch_start = idx;
                while idx < items.len() && items[idx].ts < batch_end {
                    idx += 1;
                }
                &items[batch_start..idx]
            };
            if self.config.track_exact {
                for it in batch_items {
                    exact.add(it.stratum, it.value);
                }
            }
            ingest_chunk.clear();
            ingest_chunk.extend_from_items(batch_items);
            pool.offer_columnar(&ingest_chunk);
            report.items_processed += batch_items.len() as u64;

            // Close the batch: per-worker finish + merge (the per-batch
            // scheduling rendezvous).  Registered pane sketches come back
            // pre-built from the workers.
            let t0 = Instant::now(); // lint: wall-clock latency metric only, never feeds results
            let (batch_result, mut pane_sketches) = {
                let _sp = crate::obs::trace::span("interval_close");
                pool.finish_interval_with_sketches()
            };
            crate::obs_histogram!("interval_close_ns", "whole interval close (drain+merge+partials)")
                .record_elapsed(t0);
            if let Some(sl) = slicer.as_mut() {
                ledger.absorb(sl.take_new_drops());
            }
            let batch_exact = std::mem::take(&mut exact);

            if let Some(sw) = sketches.as_mut() {
                // The engines register exactly one spec; pop() would
                // silently mispair if that ever changed.
                debug_assert!(pane_sketches.len() <= 1, "one registered spec per engine run");
                match pane_sketches.pop() {
                    Some(pane) => sw.push_prebuilt(pane),
                    None => sw.push_pane(&batch_result),
                }
            }
            if let Some(ws) = assembler.push_interval_view(batch_result, batch_exact) {
                let emit_t0 = crate::obs::metrics_enabled().then(Instant::now); // lint: wall-clock latency metric only, never feeds results
                let _sp = crate::obs::trace::span("window_emit");
                // The data-parallel job over the window: pane sketches for
                // sketch-backed queries, the zero-copy sample view for
                // linear ones.
                let mut qr = match &sketches {
                    Some(sw) => self.executor.execute_sketch(&self.query, sw, &ws.state)?,
                    None => self.executor.execute_view(&self.query, &ws)?,
                };
                let processing_ns = t0.elapsed().as_nanos() as u64;
                if let Some(emit_t0) = emit_t0 {
                    crate::obs_histogram!("window_emit_ns", "query execution + report emit at a slide boundary")
                        .record_elapsed(emit_t0);
                }

                let (exact_scalar, exact_ps) = if self.config.track_exact {
                    exact_values(&self.query, &ws.exact)
                } else {
                    (None, None)
                };

                // Window-level CI for the feedback loop.  Sketch-native
                // bounds (rank ε, HLL RSE, CM over-bound) do not shrink as
                // the sampling fraction grows, so feeding them to the
                // accuracy loop would saturate it at 1.0; None leaves the
                // controller untouched (cost/arrival EWMAs still update).
                let ci = if self.query.is_sketch_backed() { None } else { qr.scalar };
                let arrived = ws.arrived();
                let sampled = ws.sample_len();
                // Beyond-lateness drops charged to this window's span widen
                // the emitted bound; the feedback loop keeps the pre-widening
                // CI (a larger fraction cannot recover dropped items).
                let late = ledger.span(ws.start_ms, ws.end_ms);
                super::widen_for_late_drops(&self.query, &mut qr, arrived, &late);
                ledger.prune_below(ws.start_ms);
                report.windows.push(WindowReport {
                    start_ms: ws.start_ms,
                    end_ms: ws.end_ms,
                    result: qr,
                    exact_scalar,
                    exact_per_stratum: exact_ps,
                    arrived,
                    sampled,
                    processing_ns,
                    late_dropped: late.count as u64,
                });

                // Budget feedback -> next interval's fraction, driven by
                // the *window's* confidence interval.
                let f = cost.observe_window(arrived, sampled, processing_ns, ci);
                pool.set_fraction(f);
            }

            // Interval boundary fully processed (window emitted, feedback
            // applied): this is the one consistent cut where a snapshot can
            // be taken — pool fraction equals `cost.fraction()` here, and
            // every sampler is post-reset for the next interval.
            intervals_done += 1;
            if let (Some(spec), Some(store)) = (ckpt, store.as_ref()) {
                if spec.due(intervals_done) {
                    let mut w = SnapshotWriter::new();
                    fingerprint.encode(&mut w);
                    w.put_u64(intervals_done);
                    w.put_u64(if slicer.is_some() { 0 } else { idx as u64 });
                    w.put_u64(windows_base + report.windows.len() as u64);
                    w.put_f64(cost.fraction());
                    w.put_u64(pool.transport_cursor());
                    // Acked snapshot rendezvous: each worker drains its data
                    // ring, then serializes its sampler (RNG stream
                    // included) — same control-plane discipline as
                    // `set_fraction`/`register_sketches`.
                    pool.snapshot_workers().encode(&mut w);
                    assembler.encode(&mut w);
                    sketches.encode(&mut w);
                    ledger.encode(&mut w);
                    cost.encode(&mut w);
                    store.write_epoch(intervals_done, &w.into_bytes())?;
                }
                if spec.crashes_at(intervals_done) {
                    // Simulated crash: stop cold with whatever was emitted.
                    break;
                }
            }

            if idx >= items.len() {
                break;
            }
        }

        report.wall_ns = start.elapsed().as_nanos() as u64;
        report.sketch_ingest = sketches.as_ref().map(|sw| {
            super::SketchIngestStats::collect(
                sw,
                self.executor.query_time_sketch_builds().saturating_sub(query_builds_at_start),
            )
        });
        report.metrics = Some(crate::obs::global().snapshot().delta(&obs_start));
        Ok(report)
    }
}

/// Largest divisor of `slide` that is <= `interval` (keeps arbitrary batch
/// intervals usable with any slide).
fn gcd_fit(interval: EventTime, slide: EventTime) -> EventTime {
    let mut best = 1;
    let mut d = 1;
    while d * d <= slide {
        if slide % d == 0 {
            if d <= interval {
                best = best.max(d);
            }
            let q = slide / d;
            if q <= interval {
                best = best.max(q);
            }
        }
        d += 1;
    }
    best
}

/// Exact value(s) of a query from window ground truth.
pub(crate) fn exact_values(query: &Query, exact: &ExactAgg) -> (Option<f64>, Option<Vec<f64>>) {
    use crate::core::MAX_STRATA;
    match query {
        Query::Sum => (Some(exact.total_sum()), None),
        Query::Mean => {
            let c = exact.total_count();
            (Some(if c > 0.0 { exact.total_sum() / c } else { 0.0 }), None)
        }
        Query::Count => (Some(exact.total_count()), None),
        Query::PerStratumSum => (Some(exact.total_sum()), Some(exact.sum.to_vec())),
        Query::PerStratumMean => {
            let means: Vec<f64> = (0..MAX_STRATA)
                .map(|s| if exact.count[s] > 0.0 { exact.sum[s] / exact.count[s] } else { 0.0 })
                .collect();
            let c = exact.total_count();
            (Some(if c > 0.0 { exact.total_sum() / c } else { 0.0 }), Some(means))
        }
        // Histogram ground truth needs raw values; not tracked inline.
        Query::Histogram { .. } => (Some(exact.total_sum()), None),
        // Quantile/Distinct ground truth also needs raw values (ExactAgg only
        // keeps per-stratum count/sum); integration tests recompute it from
        // the trace instead.
        Query::Quantile(_) | Query::Distinct => (None, None),
        // TopK: per-stratum arrival counts are exact; the scalar mirrors the
        // approximate scalar (summed count of the true top-k strata).
        Query::TopK(k) => (
            Some(crate::query::top_k_mass(&exact.count, *k)),
            Some(exact.count.to_vec()),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::budget::QueryBudget;
    use crate::runtime::ComputeService;
    use crate::stream::{StreamConfig, StreamGenerator};

    fn run(
        sampler: SamplerKind,
        fraction: f64,
        workers: usize,
        batch_ms: EventTime,
        dur_ms: EventTime,
    ) -> RunReport {
        let cfg = EngineConfig {
            kind: super::super::EngineKind::Batched,
            batch_interval_ms: batch_ms,
            workers,
            ..Default::default()
        };
        let svc = ComputeService::native();
        let exec = QueryExecutor::new(svc.handle());
        let window = WindowConfig::new(2_000, 1_000);
        let engine = BatchedEngine::new(&cfg, window, Query::Sum, &exec);
        let mut items = StreamGenerator::new(&StreamConfig::gaussian_micro(100.0, 7))
            .take_until(dur_ms);
        items.sort_by_key(|i| i.ts);
        let mut cost = CostFunction::new(QueryBudget::SamplingFraction(fraction));
        engine.run(&items, sampler, &mut cost).unwrap()
    }

    #[test]
    fn emits_windows_at_slide_cadence() {
        let r = run(SamplerKind::Oasrs, 0.5, 1, 500, 8_000);
        // windows at 1s..8s
        assert!(r.windows.len() >= 7, "windows {}", r.windows.len());
        assert_eq!(r.windows[0].end_ms, 1_000);
        assert!(r.items_processed > 5_000);
    }

    #[test]
    fn native_is_exact() {
        let r = run(SamplerKind::None, 1.0, 1, 500, 6_000);
        for w in &r.windows {
            // The compute path is f32 (XLA artifact layout), so "exact"
            // carries float rounding ~1e-7 per item.
            let loss = w.accuracy_loss().unwrap();
            assert!(loss < 1e-5, "loss {loss}");
        }
    }

    #[test]
    fn oasrs_approximates_well() {
        let r = run(SamplerKind::Oasrs, 0.6, 1, 500, 10_000);
        let loss = r.mean_accuracy_loss();
        assert!(loss < 0.05, "mean accuracy loss {loss}");
        // sampled strictly less than arrived (after warm-up)
        let last = r.windows.last().unwrap();
        assert!((last.sampled as f64) < last.arrived);
    }

    #[test]
    fn sts_and_srs_run_multiworker() {
        for kind in [SamplerKind::Sts, SamplerKind::Srs] {
            let r = run(kind, 0.4, 3, 500, 6_000);
            assert!(!r.windows.is_empty());
            let loss = r.mean_accuracy_loss();
            assert!(loss < 0.2, "{kind:?} loss {loss}");
        }
    }

    #[test]
    fn small_batch_interval_many_rendezvous() {
        let r = run(SamplerKind::Oasrs, 0.5, 2, 250, 4_000);
        assert!(!r.windows.is_empty());
        assert!(r.windows[0].end_ms % 1_000 == 0);
    }

    #[test]
    fn batch_interval_larger_than_slide_clamped() {
        let r = run(SamplerKind::Oasrs, 0.5, 1, 5_000, 4_000);
        assert!(!r.windows.is_empty());
    }

    #[test]
    fn sketch_queries_run_through_batched_engine() {
        let cfg = EngineConfig {
            kind: super::super::EngineKind::Batched,
            batch_interval_ms: 500,
            workers: 2,
            ..Default::default()
        };
        let svc = ComputeService::native();
        let exec = QueryExecutor::new(svc.handle());
        let window = WindowConfig::new(2_000, 1_000);
        let items = {
            let mut v = StreamGenerator::new(&StreamConfig::gaussian_micro(100.0, 13))
                .take_until(6_000);
            v.sort_by_key(|i| i.ts);
            v
        };
        for query in [crate::query::Query::Quantile(0.9), crate::query::Query::Distinct] {
            let engine = BatchedEngine::new(&cfg, window, query, &exec);
            let mut cost = CostFunction::new(QueryBudget::SamplingFraction(0.6));
            let r = engine.run(&items, SamplerKind::Oasrs, &mut cost).unwrap();
            assert!(!r.windows.is_empty());
            for w in &r.windows {
                assert!(w.result.value().is_finite(), "non-finite sketch result");
            }
            // streaming ingest: every pane arrived pre-built, zero rebuilt
            let stats = r.sketch_ingest.expect("sketch run must report provenance");
            assert!(stats.prebuilt_panes > 0);
            assert_eq!(stats.rebuilt_panes, 0);
            assert_eq!(stats.query_time_builds, 0);
        }
        // TopK: exact per-stratum counts available -> accuracy loss finite
        let engine = BatchedEngine::new(&cfg, window, crate::query::Query::TopK(2), &exec);
        let mut cost = CostFunction::new(QueryBudget::SamplingFraction(0.6));
        let r = engine.run(&items, SamplerKind::Oasrs, &mut cost).unwrap();
        let loss = r.mean_accuracy_loss();
        assert!(loss < 0.1, "top-k mass loss {loss}");
        for w in &r.windows {
            assert!(w.result.top_k.is_some());
        }
    }

    #[test]
    fn gcd_fit_picks_largest_divisor() {
        assert_eq!(gcd_fit(500, 1000), 500);
        assert_eq!(gcd_fit(300, 1000), 250);
        assert_eq!(gcd_fit(1000, 1000), 1000);
        assert_eq!(gcd_fit(7, 1000), 5);
        assert_eq!(gcd_fit(1, 1000), 1);
    }

    #[test]
    fn adaptive_budget_changes_fraction() {
        let cfg = EngineConfig {
            kind: super::super::EngineKind::Batched,
            batch_interval_ms: 500,
            workers: 1,
            ..Default::default()
        };
        let svc = ComputeService::native();
        let exec = QueryExecutor::new(svc.handle());
        let window = WindowConfig::tumbling(1_000);
        let engine = BatchedEngine::new(&cfg, window, Query::Sum, &exec);
        let items = StreamGenerator::new(&StreamConfig::gaussian_micro(100.0, 9))
            .take_until(12_000);
        let mut cost = CostFunction::new(QueryBudget::TargetRelativeError {
            target: 0.001,
            initial_fraction: 0.05,
        });
        engine.run(&items, SamplerKind::Oasrs, &mut cost).unwrap();
        // tight target from a tiny fraction -> feedback must have grown it
        assert!(cost.fraction() > 0.05, "fraction {}", cost.fraction());
    }
}
