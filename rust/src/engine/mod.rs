//! Stream-processing engines (paper §2.2): the batched model (Spark
//! Streaming) and the pipelined model (Apache Flink), both running the same
//! samplers, window logic, and XLA-backed query execution.
//!
//! * [`worker`] — parallel per-worker samplers with the per-algorithm
//!   finish protocols (OASRS merge without barriers; STS two-phase
//!   count/sample with a real synchronization barrier).
//! * [`batched`] — micro-batches at a fixed batch interval; batch-fashion
//!   samplers (SRS/STS) buffer whole batches ("RDDs") before sampling,
//!   OASRS samples at ingest *before* the batch forms (§4.2.1).
//! * [`pipelined`] — item-at-a-time operators connected by bounded
//!   channels; the window query runs concurrently with ingest.

pub mod batched;
pub mod pipelined;
pub mod worker;

use crate::budget::{CostFunction, QueryBudget};
use crate::core::{Error, EventTime, Result};
use crate::error::estimator::{
    missing_mass_count, missing_mass_mean, missing_mass_sum, LateDrops,
};
use crate::query::{Query, QueryResult};

pub use crate::window::EventTimeConfig;
pub use worker::{IngestPool, TransportStats, WorkerFinish};

/// Provenance counters for the pane-sketch path of one run — the
/// acceptance witness of the streaming sketch ingest tentpole: on the
/// default path every pane arrives pre-built from the ingest workers and
/// both `rebuilt_panes` and `query_time_builds` stay at zero.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SketchIngestStats {
    /// Pane sketches that arrived pre-built from the ingest workers.
    pub prebuilt_panes: u64,
    /// Pane sketches rebuilt from interval samples at the window operator
    /// (the fallback when the pool had no registration).
    pub rebuilt_panes: u64,
    /// Sketches constructed at query time by the executor during this run
    /// (the per-window rebuild path; counts this engine's executor only —
    /// sharing one executor across concurrent runs mixes the deltas).
    pub query_time_builds: u64,
}

impl SketchIngestStats {
    /// Snapshot a run's pane provenance from its window (the executor's
    /// build delta is filled in by the engine, which owns the snapshot
    /// taken at run start) — the one place the stats shape is assembled.
    pub(crate) fn collect(sw: &crate::query::SketchWindow, query_time_builds: u64) -> Self {
        Self {
            prebuilt_panes: sw.prebuilt_panes(),
            rebuilt_panes: sw.rebuilt_panes(),
            query_time_builds,
        }
    }
}

/// Reject query/budget combinations the feedback loop cannot serve:
/// sketch-native bounds (rank ε, HLL RSE, Count-Min over-bound) are set by
/// the sketch configuration, not the sampling fraction, so an
/// accuracy-target budget would silently freeze at its initial fraction.
/// Called by both engines at the top of `run`.
pub(crate) fn validate_budget(query: &Query, cost: &CostFunction) -> Result<()> {
    if query.is_sketch_backed()
        && matches!(cost.budget(), QueryBudget::TargetRelativeError { .. })
    {
        return Err(Error::Config(format!(
            "TargetRelativeError budget cannot control the {} query: its \
             bound is fixed by the sketch parameters, not the sampling \
             fraction — use SamplingFraction or tune SketchParams instead",
            query.label()
        )));
    }
    Ok(())
}

/// The configuration fingerprint a snapshot is stamped with — and checked
/// against on restore.  Both engines call this with their own
/// [`EngineKind`] (not `config.kind`, which callers sometimes leave at the
/// default when driving an engine struct directly).
pub(crate) fn fingerprint(
    config: &EngineConfig,
    window: &crate::window::WindowConfig,
    engine: EngineKind,
    sampler: crate::sampling::SamplerKind,
) -> crate::runtime::checkpoint::ConfigFingerprint {
    crate::runtime::checkpoint::ConfigFingerprint {
        engine: match engine {
            EngineKind::Batched => 0,
            EngineKind::Pipelined => 1,
        },
        sampler: sampler.tag(),
        workers: config.workers.max(1) as u64,
        seed: config.seed,
        window_size_ms: window.size_ms,
        window_slide_ms: window.slide_ms,
        batch_interval_ms: config.batch_interval_ms,
        event_time: config.event_time.is_some(),
        watermark_skew_ms: config.event_time.map(|e| e.watermark_skew_ms).unwrap_or(0),
        allowed_lateness_ms: config.event_time.map(|e| e.allowed_lateness_ms).unwrap_or(0),
        sketch_panes: config.sketch_panes,
        spill_ratio: config.spill_ratio as u64,
    }
}

/// Which processing model to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Micro-batched (Spark-Streaming-like).
    Batched,
    /// Pipelined (Flink-like).
    Pipelined,
}

impl EngineKind {
    pub fn label(self) -> &'static str {
        match self {
            EngineKind::Batched => "batched(spark)",
            EngineKind::Pipelined => "pipelined(flink)",
        }
    }
}

/// Engine tuning knobs.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub kind: EngineKind,
    /// Batch interval (virtual ms) — batched engine only.
    pub batch_interval_ms: EventTime,
    /// Parallel sampling workers (scale-up knob; Fig. 7a).
    pub workers: usize,
    /// Simulated nodes: workers are grouped and each group's results are
    /// merged per node before the global merge (scale-out knob; Fig. 7a).
    pub nodes: usize,
    /// Track exact aggregates for accuracy-loss measurement (adds uniform
    /// per-item work; disable for pure throughput runs).
    pub track_exact: bool,
    /// Bounded queue capacity between pipelined operators.
    pub channel_capacity: usize,
    /// Sketch-backed queries run over pane-level sketches merged through
    /// the two-stacks store (O(panes evicted + 1) per slide) instead of a
    /// per-window rebuild from the merged sample (O(window) per slide).
    /// On by default; turn off to get the seed's per-window weighting.
    pub sketch_panes: bool,
    /// Window/slide (pane) ratio at or above which a sketch-backed query's
    /// window spills its sample deque to compressed pane summaries
    /// (counters + ground truth + lengths; the items are dropped — pane
    /// sketches arrive pre-built, so nothing reads them).  Long-window
    /// state then stays O(ratio × summary) instead of O(window sample).
    /// Linear queries never spill (they execute over the sample).
    pub spill_ratio: usize,
    /// Event-time mode: panes assigned from the `ts` column behind a
    /// bounded-skew low-watermark with allowed lateness, instead of the
    /// legacy arrival-order range scan (which requires a sorted trace).
    /// `None` (the default) keeps the legacy path byte-identical.
    pub event_time: Option<EventTimeConfig>,
    pub seed: u64,
}

impl EngineConfig {
    /// Whether a sketch-query window of `panes_per_window` panes spills its
    /// sample deque — the single home of the threshold semantics.
    pub(crate) fn spills_at(&self, panes_per_window: usize) -> bool {
        panes_per_window >= self.spill_ratio
    }
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            kind: EngineKind::Pipelined,
            batch_interval_ms: 500,
            workers: 1,
            nodes: 1,
            track_exact: true,
            channel_capacity: 16 * 1024,
            sketch_panes: true,
            spill_ratio: 128,
            event_time: None,
            seed: 42,
        }
    }
}

/// Widen a linear query's scalar interval by the missing-mass charge for
/// the window's beyond-lateness drops (see
/// [`crate::error::estimator::LateDrops`]): the dropped values were
/// observed, so the charge is exact per query shape — dropped mass for
/// SUM-like outputs, dropped count for COUNT, the inclusion shift for
/// MEAN-like outputs.  Sketch-backed queries keep their native guarantees
/// untouched (a rank-ε or RSE bound is not missing-mass arithmetic; their
/// drops are still visible via `WindowReport::late_dropped` and the
/// `late_items_dropped_total` counter).
pub(crate) fn widen_for_late_drops(
    query: &Query,
    result: &mut QueryResult,
    arrived: f64,
    drops: &LateDrops,
) {
    if drops.is_empty() || query.is_sketch_backed() {
        return;
    }
    if let Some(ci) = result.scalar.as_mut() {
        let extra = match query {
            Query::Count => missing_mass_count(drops),
            Query::Mean | Query::PerStratumMean => missing_mass_mean(drops, ci.value, arrived),
            _ => missing_mass_sum(drops),
        };
        *ci = ci.widened(extra);
    }
}

/// One emitted window result.
#[derive(Debug, Clone)]
pub struct WindowReport {
    pub start_ms: EventTime,
    pub end_ms: EventTime,
    /// Approximate query output ± bound.
    pub result: QueryResult,
    /// Exact scalar (when tracking is on).
    pub exact_scalar: Option<f64>,
    /// Exact per-stratum values (when tracking is on and the query is
    /// per-stratum).
    pub exact_per_stratum: Option<Vec<f64>>,
    /// Items that arrived in the window span.
    pub arrived: f64,
    /// Items in the window's sample.
    pub sampled: usize,
    /// Wall time spent closing the interval + running the query (ns).
    pub processing_ns: u64,
    /// Beyond-lateness items whose event time fell in this window's span
    /// (dropped by the event-time router; already folded into the scalar
    /// bound via [`widen_for_late_drops`]).  Always 0 on the legacy
    /// arrival-order path.
    pub late_dropped: u64,
}

impl WindowReport {
    /// |approx − exact| / exact for the scalar output.
    pub fn accuracy_loss(&self) -> Option<f64> {
        self.exact_scalar
            .map(|ex| crate::query::accuracy_loss(self.result.value(), ex))
    }
}

/// Outcome of one engine run over a finite trace.
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    pub windows: Vec<WindowReport>,
    pub items_processed: u64,
    pub wall_ns: u64,
    /// Pane-sketch provenance (None for linear queries or when
    /// `sketch_panes` is off).
    pub sketch_ingest: Option<SketchIngestStats>,
    /// Per-run observability delta (end-of-run registry snapshot minus the
    /// one taken at run start): ingest/transport/close/window/query series
    /// attributed to this run even though the registry is process-global.
    /// See [`crate::obs`] for the metrics reference.
    pub metrics: Option<crate::obs::MetricsSnapshot>,
}

impl RunReport {
    /// End-to-end processing throughput (items/s).
    pub fn throughput(&self) -> f64 {
        self.items_processed as f64 / (self.wall_ns as f64 / 1e9).max(1e-9)
    }

    /// Mean accuracy loss over steady-state windows.  Windows that span
    /// event time 0 are warm-up: the samplers' adaptive capacities have no
    /// arrival history there (OASRS sizes reservoirs from the previous
    /// intervals' EWMA), so they are excluded — the paper likewise reports
    /// steady-state accuracy.  Falls back to all windows if nothing else
    /// is available.
    pub fn mean_accuracy_loss(&self) -> f64 {
        let steady: Vec<f64> = self
            .windows
            .iter()
            .filter(|w| w.start_ms > 0)
            .filter_map(|w| w.accuracy_loss())
            .filter(|l| l.is_finite())
            .collect();
        let losses = if steady.is_empty() {
            self.windows
                .iter()
                .filter_map(|w| w.accuracy_loss())
                .filter(|l| l.is_finite())
                .collect()
        } else {
            steady
        };
        if losses.is_empty() {
            f64::NAN
        } else {
            losses.iter().sum::<f64>() / losses.len() as f64
        }
    }

    /// Mean per-window processing latency (ns).
    pub fn mean_window_latency_ns(&self) -> f64 {
        if self.windows.is_empty() {
            return f64::NAN;
        }
        self.windows.iter().map(|w| w.processing_ns as f64).sum::<f64>()
            / self.windows.len() as f64
    }

    /// p-th percentile window latency (ns), p in [0, 100].
    pub fn latency_percentile_ns(&self, p: f64) -> f64 {
        if self.windows.is_empty() {
            return f64::NAN;
        }
        let mut l: Vec<u64> = self.windows.iter().map(|w| w.processing_ns).collect();
        l.sort_unstable();
        let idx = ((p / 100.0) * (l.len() - 1) as f64).round() as usize;
        l[idx.min(l.len() - 1)] as f64
    }

    /// Total sampled items across windows.
    pub fn total_sampled(&self) -> usize {
        self.windows.iter().map(|w| w.sampled).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::bounds::{ConfidenceInterval, ConfidenceLevel};
    use crate::runtime::{RustExecutor, WindowInput};

    fn dummy_report(value: f64, exact: f64, ns: u64) -> WindowReport {
        let out = RustExecutor.aggregate(&WindowInput::default());
        WindowReport {
            start_ms: 0,
            end_ms: 1000,
            result: QueryResult {
                scalar: Some(ConfidenceInterval { value, bound: 0.0, level: ConfidenceLevel::P95 }),
                per_stratum: None,
                top_k: None,
                output: out,
            },
            exact_scalar: Some(exact),
            exact_per_stratum: None,
            arrived: 100.0,
            sampled: 50,
            processing_ns: ns,
            late_dropped: 0,
        }
    }

    #[test]
    fn run_report_metrics() {
        let r = RunReport {
            windows: vec![dummy_report(101.0, 100.0, 1000), dummy_report(99.0, 100.0, 3000)],
            items_processed: 1_000_000,
            wall_ns: 500_000_000, // 0.5 s
            sketch_ingest: None,
            metrics: None,
        };
        assert!((r.throughput() - 2_000_000.0).abs() < 1.0);
        assert!((r.mean_accuracy_loss() - 0.01).abs() < 1e-12);
        assert_eq!(r.mean_window_latency_ns(), 2000.0);
        assert_eq!(r.latency_percentile_ns(0.0), 1000.0);
        assert_eq!(r.latency_percentile_ns(100.0), 3000.0);
        assert_eq!(r.total_sampled(), 100);
    }

    #[test]
    fn empty_report_nan_metrics() {
        let r = RunReport::default();
        assert!(r.mean_accuracy_loss().is_nan());
        assert!(r.mean_window_latency_ns().is_nan());
    }

    #[test]
    fn engine_labels() {
        assert!(EngineKind::Batched.label().contains("spark"));
        assert!(EngineKind::Pipelined.label().contains("flink"));
    }
}
