//! Pipelined stream processing (Flink-like; paper §2.2, §4.2.2).
//!
//! Operators run as concurrent threads connected by bounded channels
//! (credit-based flow control, like Flink's network stack):
//!
//! ```text
//!   source ──items──▶ sampler-op ──interval results──▶ window/query-op
//! ```
//!
//! Items are forwarded the moment they arrive — no batch buffering.  The
//! sampling operator applies OASRS on the fly and closes an interval at
//! every slide boundary; the window/query operator merges intervals and runs
//! the XLA-backed aggregation *concurrently with ingest* — the pipelining
//! that gives the Flink variants their throughput edge in the paper.

use std::time::Instant;

use crate::budget::CostFunction;
use crate::core::{ColumnarChunk, Error, Item, Result};
use crate::error::bounds::ConfidenceInterval;
use crate::error::estimator::LateDrops;
use crate::query::{sketch_spec_for, Query, QueryExecutor, SketchWindow};
use crate::runtime::checkpoint::{
    self, CheckpointSpec, CheckpointStore, PipelineSnapshot, Snapshot, SnapshotWriter,
};
use crate::sampling::{SampleResult, SamplerKind};
use crate::sketch::PaneSketch;
use crate::util::channel::{bounded, Sender};
use crate::window::{DropLedger, EventTimeSlicer, ExactAgg, WindowAssembler, WindowConfig};

use super::batched::exact_values;
use super::worker::IngestPool;
use super::{EngineConfig, RunReport, SketchIngestStats, WindowReport};

/// Pipelined engine over a finite, event-time-sorted trace.
#[derive(Debug)]
pub struct PipelinedEngine<'a> {
    config: &'a EngineConfig,
    window: WindowConfig,
    query: Query,
    executor: &'a QueryExecutor,
}

/// Message from the sampling operator to the window/query operator.
struct IntervalMsg {
    result: SampleResult,
    exact: ExactAgg,
    /// The interval's pane sketch, pre-built by the ingest workers (None
    /// when no sketch query is registered on the pool).
    sketch: Option<PaneSketch>,
    /// ns spent closing the interval (sampling-side latency share).
    close_ns: u64,
    /// Per-pane beyond-lateness drops recorded while feeding this interval
    /// (always empty on the legacy arrival-order path).
    drops: Vec<(u64, LateDrops)>,
    /// Acked snapshot rendezvous riding the interval stream: when set, the
    /// window operator serializes its post-interval state and replies here.
    /// FIFO channel ordering guarantees the reply reflects exactly the
    /// state after this interval's windows were emitted — the same
    /// discipline `set_fraction`/`register_sketches` use on the pool.
    snapshot: Option<Sender<ConsumerCkpt>>,
}

/// The window operator's half of a whole-pipeline snapshot.
struct ConsumerCkpt {
    /// Windows emitted so far (including any restored base).
    windows_emitted: u64,
    /// `assembler · sketches · ledger`, encoded in [`PipelineSnapshot`]
    /// field order; the coordinator splices these bytes raw into the full
    /// payload between the worker blobs and the cost function.
    state: Vec<u8>,
}

/// Window-level observation flowing back from the query operator to the
/// budget loop: the window's CI (None for sketch-backed queries, whose
/// bounds are fraction-independent) plus the cost-model inputs the seed
/// path used to zero out.
struct WindowObs {
    arrived: f64,
    sampled: usize,
    processing_ns: u64,
    ci: Option<ConfidenceInterval>,
}

impl<'a> PipelinedEngine<'a> {
    pub fn new(
        config: &'a EngineConfig,
        window: WindowConfig,
        query: Query,
        executor: &'a QueryExecutor,
    ) -> Self {
        Self { config, window, query, executor }
    }

    /// Run the engine over `items` with the given sampler and budget.
    pub fn run(
        &self,
        items: &[Item],
        sampler_kind: SamplerKind,
        cost: &mut CostFunction,
    ) -> Result<RunReport> {
        self.run_inner(items, sampler_kind, cost, None, None)
    }

    /// Run with periodic epoch-stamped snapshots per `spec` (and, for the
    /// crash-injection suite, an optional deterministic stop).
    ///
    /// Determinism caveat: under an *adaptive* budget the window-feedback
    /// channel is racy by design (observations apply whenever they arrive),
    /// so only fixed-fraction budgets give bit-identical recovery on this
    /// engine; the batched engine's synchronous loop has no such race.
    pub fn run_checkpointed(
        &self,
        items: &[Item],
        sampler_kind: SamplerKind,
        cost: &mut CostFunction,
        spec: &CheckpointSpec,
    ) -> Result<RunReport> {
        self.run_inner(items, sampler_kind, cost, Some(spec), None)
    }

    /// Restore from the newest valid snapshot in `spec.dir` and resume the
    /// run from the recorded broker offset with restored sampler/window
    /// state (see [`Self::run_checkpointed`] for the adaptive-budget
    /// caveat).
    pub fn recover(
        &self,
        items: &[Item],
        sampler_kind: SamplerKind,
        cost: &mut CostFunction,
        spec: &CheckpointSpec,
    ) -> Result<RunReport> {
        let store = CheckpointStore::open(spec.dir.clone())?;
        let loaded = store.load_latest()?.ok_or_else(|| {
            Error::Config(format!("no snapshot to restore in {}", spec.dir.display()))
        })?;
        let snap = PipelineSnapshot::from_snapshot_bytes(&loaded.payload)?;
        let current = super::fingerprint(
            self.config,
            &self.window,
            super::EngineKind::Pipelined,
            sampler_kind,
        );
        snap.fingerprint.check(&current)?;
        if std::mem::discriminant(snap.cost.budget()) != std::mem::discriminant(cost.budget()) {
            return Err(Error::Config(format!(
                "snapshot budget {:?} does not match the requested budget {:?}",
                snap.cost.budget(),
                cost.budget()
            )));
        }
        checkpoint::record_restore();
        self.run_inner(items, sampler_kind, cost, Some(spec), Some(snap))
    }

    fn run_inner(
        &self,
        items: &[Item],
        sampler_kind: SamplerKind,
        cost: &mut CostFunction,
        ckpt: Option<&CheckpointSpec>,
        resume: Option<PipelineSnapshot>,
    ) -> Result<RunReport> {
        super::validate_budget(&self.query, cost)?;
        let fingerprint = super::fingerprint(
            self.config,
            &self.window,
            super::EngineKind::Pipelined,
            sampler_kind,
        );
        let store = ckpt.map(|s| CheckpointStore::create(s.dir.clone())).transpose()?;
        // Streaming sketch ingest: register the query's sketch spec on the
        // pool (acked control-plane rendezvous — orders before every chunk)
        // so interval closes return pre-built pane sketches.
        let sketch_spec = if self.config.sketch_panes {
            sketch_spec_for(&self.query, self.executor.sketch_params())
        } else {
            None
        };
        let mut epoch0 = 0u64;
        let mut windows_base = 0u64;
        let mut idx0 = 0usize;
        let mut consumer_resume: Option<(WindowAssembler, Option<SketchWindow>, DropLedger)> =
            None;
        let resumed = resume.is_some();
        let mut pool = match resume {
            Some(snap) => {
                // The query shape is not part of the fingerprint; the
                // restored sketch window must belong to the same spec this
                // run would register.
                match (&snap.sketches, &sketch_spec) {
                    (None, None) => {}
                    (Some(s), Some(spec)) if s.spec() == *spec => {}
                    _ => {
                        return Err(Error::Config(
                            "snapshot sketch state does not match this query's sketch \
                             configuration (was the snapshot taken under a different query?)"
                                .into(),
                        ))
                    }
                }
                epoch0 = snap.epoch;
                windows_base = snap.windows_emitted;
                idx0 = snap.item_offset as usize;
                *cost = snap.cost;
                consumer_resume = Some((snap.assembler, snap.sketches, snap.ledger));
                IngestPool::restore(
                    sampler_kind,
                    self.config.workers,
                    snap.fraction,
                    &snap.workers,
                    snap.transport_cursor,
                )?
            }
            None => IngestPool::new(
                sampler_kind,
                self.config.workers,
                cost.fraction(),
                self.config.seed,
            ),
        };
        if let Some(spec) = sketch_spec {
            pool.register_sketches(&[spec])?;
        }
        let query_builds_at_start = self.executor.query_time_sketch_builds();
        let obs_start = crate::obs::global().snapshot();
        // Window-level observations flow back from the query operator.
        // Sized to the interval channel: the consumer emits at most one
        // observation per interval message, so this can never fill and
        // silently drop a window the cost model now depends on.
        let (frac_tx, frac_rx) = bounded::<WindowObs>(self.config.channel_capacity.max(64));
        let (tx, rx) = bounded::<IntervalMsg>(self.config.channel_capacity.max(2));

        let start = Instant::now(); // lint: wall-clock latency metric only, never feeds results
        let mut items_processed = 0u64;

        type ConsumerOut = (Vec<WindowReport>, Option<SketchIngestStats>);
        let (windows, pane_stats) = std::thread::scope(|scope| -> Result<ConsumerOut> {
            // Window/query operator: runs concurrently with ingest.
            let query = self.query.clone();
            let executor = self.executor;
            let window_cfg = self.window;
            let config = self.config;
            let consumer = scope.spawn(move || -> Result<ConsumerOut> {
                // Recovery hands the operator its checkpointed state whole;
                // otherwise build it fresh.
                let (mut assembler, mut sketches, mut ledger) =
                    if let Some(state) = consumer_resume {
                        state
                    } else {
                        let mut assembler = WindowAssembler::new(window_cfg);
                        // Pane-level sketches: one per slide interval,
                        // arriving pre-built from the ingest workers and
                        // merged incrementally through the two-stacks store.
                        let sketches = if config.sketch_panes {
                            SketchWindow::for_query(
                                &query,
                                executor.sketch_params(),
                                assembler.panes_per_window(),
                            )
                        } else {
                            None
                        };
                        // Long-window spill: pane sketches make the sample
                        // deque readerless, so past the ratio threshold keep
                        // summaries only.
                        if sketches.is_some() && config.spills_at(assembler.panes_per_window())
                        {
                            assembler.spill_samples();
                        }
                        // Beyond-lateness drops, charged per event-time pane
                        // by the source operator and spanned per emitted
                        // window here.
                        (assembler, sketches, DropLedger::new(window_cfg.slide_ms))
                    };
                let mut out = Vec::new();
                while let Some(msg) = rx.recv() {
                    let t0 = Instant::now(); // lint: wall-clock latency metric only, never feeds results
                    ledger.absorb(msg.drops);
                    if let Some(sw) = sketches.as_mut() {
                        match msg.sketch {
                            Some(pane) => sw.push_prebuilt(pane),
                            None => sw.push_pane(&msg.result),
                        }
                    }
                    if let Some(ws) = assembler.push_interval_view(msg.result, msg.exact) {
                        let emit_t0 = crate::obs::metrics_enabled().then(Instant::now); // lint: wall-clock latency metric only, never feeds results
                        let _sp = crate::obs::trace::span("window_emit");
                        let mut qr = match &sketches {
                            Some(sw) => executor.execute_sketch(&query, sw, &ws.state)?,
                            None => executor.execute_view(&query, &ws)?,
                        };
                        let processing_ns = msg.close_ns + t0.elapsed().as_nanos() as u64;
                        if let Some(emit_t0) = emit_t0 {
                            crate::obs_histogram!("window_emit_ns", "query execution + report emit at a slide boundary")
                                .record_elapsed(emit_t0);
                        }
                        let (exact_scalar, exact_ps) = if config.track_exact {
                            exact_values(&query, &ws.exact)
                        } else {
                            (None, None)
                        };
                        let arrived = ws.arrived();
                        let sampled = ws.sample_len();
                        // Sketch-native bounds are fraction-independent:
                        // None keeps them out of the accuracy loop while the
                        // cost/arrival EWMAs still observe the window.
                        let ci = if query.is_sketch_backed() { None } else { qr.scalar };
                        // Drops widen the emitted bound only; the feedback
                        // loop keeps the pre-widening CI (a larger sampling
                        // fraction cannot recover dropped items).
                        let late = ledger.span(ws.start_ms, ws.end_ms);
                        super::widen_for_late_drops(&query, &mut qr, arrived, &late);
                        ledger.prune_below(ws.start_ms);
                        out.push(WindowReport {
                            start_ms: ws.start_ms,
                            end_ms: ws.end_ms,
                            result: qr,
                            exact_scalar,
                            exact_per_stratum: exact_ps,
                            arrived,
                            sampled,
                            processing_ns,
                            late_dropped: late.count as u64,
                        });
                        // Report the window-level observation upstream.
                        let _ = frac_tx.try_send(WindowObs {
                            arrived,
                            sampled,
                            processing_ns,
                            ci,
                        });
                    }
                    // Snapshot rendezvous: serialize the post-interval
                    // operator state and ack.  Runs after the window emit,
                    // so the blob reflects exactly what a restored operator
                    // must resume from.
                    if let Some(reply) = msg.snapshot {
                        let _sp = crate::obs::trace::span("consumer_snapshot");
                        let mut w = SnapshotWriter::new();
                        assembler.encode(&mut w);
                        sketches.encode(&mut w);
                        ledger.encode(&mut w);
                        let _ = reply.send(ConsumerCkpt {
                            windows_emitted: windows_base + out.len() as u64,
                            state: w.into_bytes(),
                        });
                    }
                }
                // Executor build-delta is filled in by the engine after the
                // join (it owns the run-start snapshot).
                let pane_stats =
                    sketches.map(|sw| SketchIngestStats::collect(&sw, 0));
                Ok((out, pane_stats))
            });

            // Source + sampling operator (this thread): forward items
            // immediately, close intervals at slide boundaries.  In
            // event-time mode the watermark-driven router re-panes the
            // arrival stream; `None` keeps the legacy path byte-identical.
            let mut slicer = self
                .config
                .event_time
                .map(|et| EventTimeSlicer::new(items, self.window.slide_ms, et));
            if resumed && epoch0 > 0 {
                if let Some(sl) = slicer.as_mut() {
                    // Replay the consumed prefix through a fresh watermark
                    // router, discarding already-emitted panes and their
                    // already-checkpointed drop charges (the slicer consumes
                    // no RNG, so the surviving panes are byte-identical).
                    let mut replayed = 0u64;
                    for _ in 0..epoch0 {
                        match sl.next_pane() {
                            Some(pane) => replayed += pane.len() as u64,
                            None => break,
                        }
                    }
                    let _ = sl.take_new_drops();
                    checkpoint::record_replayed_items(replayed);
                }
                // Legacy mode seeks straight to the recorded offset.
            }
            let mut exact = ExactAgg::default();
            let mut intervals_done = epoch0;
            let mut next_interval_end = (epoch0 + 1) * self.window.slide_ms;
            // Reusable SoA staging chunk (capacity retained across
            // intervals — zero steady-state allocation).
            let mut ingest_chunk = ColumnarChunk::new();
            let mut idx = idx0;
            // A resumed legacy run whose snapshot was taken at end-of-trace
            // has nothing left to ingest; entering the loop would feed a
            // phantom empty interval the uninterrupted run never saw.
            let exhausted = resumed && slicer.is_none() && idx >= items.len();
            loop {
                if exhausted {
                    break;
                }
                // Legacy mode range-scans the event-time-sorted trace (one
                // scan + one `offer_columnar`; per-item dispatch amortizes
                // across the whole interval feed).  Event-time mode takes
                // the next watermark-closed pane in canonical order.
                let pane_buf;
                let interval_items: &[Item] = if let Some(sl) = slicer.as_mut() {
                    match sl.next_pane() {
                        Some(pane) => {
                            pane_buf = pane;
                            &pane_buf
                        }
                        None => break,
                    }
                } else {
                    let interval_start = idx;
                    while idx < items.len() && items[idx].ts < next_interval_end {
                        idx += 1;
                    }
                    &items[interval_start..idx]
                };
                if self.config.track_exact {
                    for it in interval_items {
                        exact.add(it.stratum, it.value);
                    }
                }
                ingest_chunk.clear();
                ingest_chunk.extend_from_items(interval_items);
                pool.offer_columnar(&ingest_chunk);
                items_processed += interval_items.len() as u64;
                let t0 = Instant::now(); // lint: wall-clock latency metric only, never feeds results
                let (result, mut pane_sketches) = {
                    let _sp = crate::obs::trace::span("interval_close");
                    pool.finish_interval_with_sketches()
                };
                let close_ns = t0.elapsed().as_nanos() as u64;
                crate::obs_histogram!("interval_close_ns", "whole interval close (drain+merge+partials)")
                    .record(close_ns);
                // The engines register exactly one spec; pop() would
                // silently mispair if that ever changed.
                debug_assert!(pane_sketches.len() <= 1, "one registered spec per engine run");
                // Snapshot rendezvous request rides the interval message so
                // the window operator acks with its post-interval state.
                let snap_rx = if store.is_some()
                    && ckpt.is_some_and(|s| s.due(intervals_done + 1))
                {
                    Some(bounded::<ConsumerCkpt>(1))
                } else {
                    None
                };
                let (snap_tx, snap_rx) = match snap_rx {
                    Some((t, r)) => (Some(t), Some(r)),
                    None => (None, None),
                };
                let msg = IntervalMsg {
                    result,
                    exact: std::mem::take(&mut exact),
                    sketch: pane_sketches.pop(),
                    close_ns,
                    drops: slicer.as_mut().map(|sl| sl.take_new_drops()).unwrap_or_default(),
                    snapshot: snap_tx,
                };
                tx.send(msg)
                    .map_err(|_| crate::core::Error::Stream("query operator died".into()))?;
                next_interval_end += self.window.slide_ms;
                intervals_done += 1;

                // Apply any pending budget feedback (non-blocking): every
                // completed window's observation updates the cost model in
                // order; the resulting fraction is applied once.
                let mut latest = None;
                while let Ok(obs) = frac_rx.try_recv() {
                    latest = Some(cost.observe_window(
                        obs.arrived,
                        obs.sampled,
                        obs.processing_ns,
                        obs.ci,
                    ));
                }
                if let Some(f) = latest {
                    pool.set_fraction(f);
                }

                // Assemble and persist the epoch snapshot: the consumer's
                // blocking ack means every interval up to this one has been
                // fully processed downstream, and the feedback block above
                // keeps `cost.fraction()` in lockstep with the pool.
                if let Some(crx) = snap_rx {
                    let reply = crx.recv().ok_or_else(|| {
                        crate::core::Error::Stream(
                            "query operator died before snapshot ack".into(),
                        )
                    })?;
                    let store = store.as_ref().expect("store exists when a snapshot is due");
                    let mut w = SnapshotWriter::new();
                    fingerprint.encode(&mut w);
                    w.put_u64(intervals_done);
                    w.put_u64(if slicer.is_some() { 0 } else { idx as u64 });
                    w.put_u64(reply.windows_emitted);
                    w.put_f64(cost.fraction());
                    w.put_u64(pool.transport_cursor());
                    pool.snapshot_workers().encode(&mut w);
                    w.extend_raw(&reply.state);
                    cost.encode(&mut w);
                    store.write_epoch(intervals_done, &w.into_bytes())?;
                }
                if ckpt.is_some_and(|s| s.crashes_at(intervals_done)) {
                    // Simulated crash: stop feeding; the operator drains
                    // what was sent and the partial report is returned.
                    break;
                }

                if idx >= items.len() {
                    break;
                }
            }
            tx.close();
            consumer
                .join()
                .map_err(|_| crate::core::Error::Stream("query operator panicked".into()))?
        })?;

        Ok(RunReport {
            windows,
            items_processed,
            wall_ns: start.elapsed().as_nanos() as u64,
            sketch_ingest: pane_stats.map(|mut stats| {
                stats.query_time_builds = self
                    .executor
                    .query_time_sketch_builds()
                    .saturating_sub(query_builds_at_start);
                stats
            }),
            metrics: Some(crate::obs::global().snapshot().delta(&obs_start)),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::budget::QueryBudget;
    use crate::runtime::ComputeService;
    use crate::stream::{StreamConfig, StreamGenerator};

    fn run(sampler: SamplerKind, fraction: f64, workers: usize, dur_ms: u64) -> RunReport {
        let cfg = EngineConfig {
            kind: super::super::EngineKind::Pipelined,
            workers,
            ..Default::default()
        };
        let svc = ComputeService::native();
        let exec = QueryExecutor::new(svc.handle());
        let window = WindowConfig::new(2_000, 1_000);
        let engine = PipelinedEngine::new(&cfg, window, Query::Sum, &exec);
        let items =
            StreamGenerator::new(&StreamConfig::gaussian_micro(100.0, 11)).take_until(dur_ms);
        let mut cost = CostFunction::new(QueryBudget::SamplingFraction(fraction));
        engine.run(&items, sampler, &mut cost).unwrap()
    }

    #[test]
    fn emits_windows_and_processes_all_items() {
        let r = run(SamplerKind::Oasrs, 0.5, 1, 8_000);
        assert!(r.windows.len() >= 7, "windows {}", r.windows.len());
        assert!(r.items_processed > 5_000);
        assert_eq!(r.windows[0].end_ms, 1_000);
    }

    #[test]
    fn native_pipelined_exact() {
        let r = run(SamplerKind::None, 1.0, 1, 6_000);
        for w in &r.windows {
            // f32 compute path -> tiny rounding relative to f64 exact.
            assert!(w.accuracy_loss().unwrap() < 1e-5);
        }
    }

    #[test]
    fn oasrs_pipelined_accuracy() {
        let r = run(SamplerKind::Oasrs, 0.6, 2, 10_000);
        let loss = r.mean_accuracy_loss();
        assert!(loss < 0.05, "loss {loss}");
    }

    #[test]
    fn multiworker_conservation() {
        let r = run(SamplerKind::Oasrs, 0.4, 4, 6_000);
        let arrived_total: f64 = r
            .windows
            .iter()
            .filter(|w| w.end_ms % 2_000 == 0) // disjoint tumbling-ish picks
            .map(|w| w.arrived)
            .sum();
        assert!(arrived_total > 0.0);
        assert!(r.items_processed > 0);
    }

    #[test]
    fn sketch_queries_run_through_pipelined_engine() {
        let cfg = EngineConfig {
            kind: super::super::EngineKind::Pipelined,
            workers: 2,
            ..Default::default()
        };
        let svc = ComputeService::native();
        let exec = QueryExecutor::new(svc.handle());
        let window = WindowConfig::new(2_000, 1_000);
        let items =
            StreamGenerator::new(&StreamConfig::gaussian_micro(100.0, 17)).take_until(6_000);
        let engine = PipelinedEngine::new(&cfg, window, Query::TopK(3), &exec);
        let mut cost = CostFunction::new(QueryBudget::SamplingFraction(0.5));
        let r = engine.run(&items, SamplerKind::Oasrs, &mut cost).unwrap();
        assert!(!r.windows.is_empty());
        for w in &r.windows {
            let top = w.result.top_k.as_ref().expect("top-k list");
            assert!(!top.is_empty() && top.len() <= 3);
            assert!(top.windows(2).all(|p| p[0].1 >= p[1].1), "unsorted top-k");
        }
        // streaming ingest provenance: panes pre-built by the pool workers
        let stats = r.sketch_ingest.expect("sketch run must report provenance");
        assert!(stats.prebuilt_panes > 0);
        assert_eq!(stats.rebuilt_panes, 0);
        assert_eq!(stats.query_time_builds, 0);
        // weighted-reservoir + sketch query is now rejected up front: the
        // A-ExpJ value-biased inclusion probabilities are not modeled by the
        // count-based HT weights the sketch fold uses, so the registration
        // fails with a descriptive config error instead of silently serving
        // uncalibrated quantiles (closes the ROADMAP calibration residual).
        let engine = PipelinedEngine::new(&cfg, window, Query::Quantile(0.95), &exec);
        let mut cost = CostFunction::new(QueryBudget::SamplingFraction(0.3));
        let err = engine
            .run(&items, SamplerKind::WeightedRes, &mut cost)
            .expect_err("WeightedRes + sketch query must be rejected");
        let msg = err.to_string();
        assert!(msg.contains("WeightedRes"), "msg: {msg}");
        // WeightedRes still runs linear queries through the pipelined path.
        let engine = PipelinedEngine::new(&cfg, window, Query::Sum, &exec);
        let mut cost = CostFunction::new(QueryBudget::SamplingFraction(0.3));
        let r = engine.run(&items, SamplerKind::WeightedRes, &mut cost).unwrap();
        assert!(!r.windows.is_empty());
        for w in &r.windows {
            assert!(w.result.value().is_finite());
        }
    }

    #[test]
    fn query_runs_concurrently_with_ingest() {
        // Smoke: total wall time should be far below serial sum of window
        // processing times + ingest when windows are heavy. Just assert the
        // engine completes and reports plausible latencies.
        let r = run(SamplerKind::Oasrs, 0.8, 1, 12_000);
        assert!(r.mean_window_latency_ns() > 0.0);
        assert!(r.wall_ns > 0);
    }
}
