//! Query budgets and the virtual cost function (paper §2.3 assumption 1,
//! §7 discussion).
//!
//! The paper assumes "a virtual cost function which translates a given query
//! budget (expected latency or throughput guarantees, or the required
//! accuracy level) into the appropriate sample size".  This module
//! implements that translation:
//!
//! * **fraction / sample-size budgets** — direct.
//! * **accuracy budgets** — the [`FeedbackController`] closed loop (§4.2.1):
//!   widen the sample when the observed bound exceeds the target, shrink
//!   when comfortably under.
//! * **latency / throughput budgets** — a token-style resource model in the
//!   spirit of Pulsar [10]: the pipeline continuously estimates the
//!   processing cost per sampled item (EWMA over observed window-processing
//!   times) and sizes the next interval's sample so the window's predicted
//!   cost fits the budgeted time.

use crate::error::bounds::ConfidenceInterval;
use crate::error::feedback::FeedbackController;

/// User-facing budget for a streaming query.
#[derive(Debug, Clone, PartialEq)]
pub enum QueryBudget {
    /// Sample this fraction of the stream (the microbenchmarks' knob).
    SamplingFraction(f64),
    /// Absolute per-interval sample size.
    SampleSizePerInterval(usize),
    /// Keep the relative error bound of query results under `target`
    /// (e.g. 0.01 = 1%), adapting the fraction from `initial_fraction`.
    /// Only meaningful for the linear (CLT-bounded) queries; sketch-backed
    /// queries have fraction-independent bounds, and [`crate::pipeline`]
    /// rejects the combination.
    TargetRelativeError { target: f64, initial_fraction: f64 },
    /// Spend at most `ms_per_window` milliseconds of compute per window.
    LatencyPerWindowMs(f64),
}

impl QueryBudget {
    /// Initial sampling fraction implied by the budget (before any
    /// observations are available).
    pub fn initial_fraction(&self) -> f64 {
        match *self {
            QueryBudget::SamplingFraction(f) => f.clamp(1e-4, 1.0),
            QueryBudget::SampleSizePerInterval(_) => 1.0, // resolved per interval
            QueryBudget::TargetRelativeError { initial_fraction, .. } => {
                initial_fraction.clamp(1e-4, 1.0)
            }
            QueryBudget::LatencyPerWindowMs(_) => 1.0,
        }
    }
}

/// The virtual cost function: folds budget + runtime observations into the
/// sampling fraction for the next interval.
#[derive(Debug)]
pub struct CostFunction {
    budget: QueryBudget,
    feedback: Option<FeedbackController>,
    /// EWMA of per-sampled-item processing cost (ns).
    cost_per_item_ns: f64,
    /// EWMA of items arriving per interval.
    arrivals_per_interval: f64,
    /// The last completed window's confidence interval (None before the
    /// first window and for sketch-backed queries).
    last_window_ci: Option<ConfidenceInterval>,
    fraction: f64,
}

const EWMA: f64 = 0.4;

impl CostFunction {
    pub fn new(budget: QueryBudget) -> Self {
        let feedback = match &budget {
            QueryBudget::TargetRelativeError { target, initial_fraction } => {
                Some(FeedbackController::new(*target, *initial_fraction))
            }
            _ => None,
        };
        let fraction = budget.initial_fraction();
        Self {
            budget,
            feedback,
            cost_per_item_ns: 0.0,
            arrivals_per_interval: 0.0,
            last_window_ci: None,
            fraction,
        }
    }

    pub fn budget(&self) -> &QueryBudget {
        &self.budget
    }

    /// Current sampling fraction.
    pub fn fraction(&self) -> f64 {
        self.fraction
    }

    /// Feed one completed *window*'s observations, CI included — the
    /// engines' entry point.  The accuracy loop observes the window-level
    /// confidence interval (the user-facing `output ± bound` guarantee),
    /// not any per-interval proxy; `None` (sketch-backed queries, empty
    /// windows) leaves the accuracy controller untouched while the
    /// cost/arrival EWMAs still update.
    pub fn observe_window(
        &mut self,
        arrived: f64,
        sampled: usize,
        processing_ns: u64,
        ci: Option<ConfidenceInterval>,
    ) -> f64 {
        self.last_window_ci = ci;
        let rel = ci.map(|c| c.relative()).unwrap_or(f64::NAN);
        self.observe_inner(arrived, sampled, processing_ns, rel, ci)
    }

    /// The last completed window's CI, as observed by the budget loop.
    pub fn window_ci(&self) -> Option<ConfidenceInterval> {
        self.last_window_ci
    }

    /// Feed one window's observations: arrivals in the interval, sampled
    /// items, processing time, and the achieved relative error bound.
    /// Returns the fraction for the next interval.
    pub fn observe(
        &mut self,
        arrived: f64,
        sampled: usize,
        processing_ns: u64,
        rel_error: f64,
    ) -> f64 {
        self.observe_inner(arrived, sampled, processing_ns, rel_error, None)
    }

    fn observe_inner(
        &mut self,
        arrived: f64,
        sampled: usize,
        processing_ns: u64,
        rel_error: f64,
        ci: Option<ConfidenceInterval>,
    ) -> f64 {
        // Update cost model.
        if sampled > 0 {
            let per_item = processing_ns as f64 / sampled as f64;
            self.cost_per_item_ns = if self.cost_per_item_ns == 0.0 {
                per_item
            } else {
                EWMA * per_item + (1.0 - EWMA) * self.cost_per_item_ns
            };
        }
        if arrived > 0.0 {
            self.arrivals_per_interval = if self.arrivals_per_interval == 0.0 {
                arrived
            } else {
                EWMA * arrived + (1.0 - EWMA) * self.arrivals_per_interval
            };
        }

        self.fraction = match &self.budget {
            QueryBudget::SamplingFraction(f) => f.clamp(1e-4, 1.0),
            QueryBudget::SampleSizePerInterval(n) => {
                if self.arrivals_per_interval > 0.0 {
                    (*n as f64 / self.arrivals_per_interval).clamp(1e-4, 1.0)
                } else {
                    1.0
                }
            }
            QueryBudget::TargetRelativeError { .. } => {
                let fb = self.feedback.as_mut().expect("feedback exists");
                match &ci {
                    Some(ci) => fb.observe_ci(ci),
                    None => fb.observe(rel_error),
                }
            }
            QueryBudget::LatencyPerWindowMs(ms) => {
                // Pulsar-style token model: budget_ns / cost_per_item =
                // affordable sample size; fraction = affordable / arrivals.
                if self.cost_per_item_ns > 0.0 && self.arrivals_per_interval > 0.0 {
                    let affordable = ms * 1e6 / self.cost_per_item_ns;
                    (affordable / self.arrivals_per_interval).clamp(1e-4, 1.0)
                } else {
                    1.0
                }
            }
        };
        self.fraction
    }
}

use crate::core::Result;
use crate::runtime::checkpoint::{Snapshot, SnapshotReader, SnapshotWriter};

impl Snapshot for QueryBudget {
    fn encode(&self, w: &mut SnapshotWriter) {
        match self {
            QueryBudget::SamplingFraction(f) => {
                w.put_u8(0);
                w.put_f64(*f);
            }
            QueryBudget::SampleSizePerInterval(n) => {
                w.put_u8(1);
                w.put_usize(*n);
            }
            QueryBudget::TargetRelativeError { target, initial_fraction } => {
                w.put_u8(2);
                w.put_f64(*target);
                w.put_f64(*initial_fraction);
            }
            QueryBudget::LatencyPerWindowMs(ms) => {
                w.put_u8(3);
                w.put_f64(*ms);
            }
        }
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        match r.get_u8()? {
            0 => Ok(QueryBudget::SamplingFraction(r.get_f64()?)),
            1 => Ok(QueryBudget::SampleSizePerInterval(r.get_usize()?)),
            2 => Ok(QueryBudget::TargetRelativeError {
                target: r.get_f64()?,
                initial_fraction: r.get_f64()?,
            }),
            3 => Ok(QueryBudget::LatencyPerWindowMs(r.get_f64()?)),
            t => Err(crate::core::Error::Io(format!("unknown query budget tag {t}"))),
        }
    }
}

/// The whole adaptive loop travels: both EWMAs, the feedback controller
/// (itself carrying its CI-width EWMA), and the fraction in force.  A
/// restored pipeline therefore picks the *same* fraction for the next
/// interval as the uninterrupted run — the property that makes adaptive-
/// budget recovery bit-identical rather than merely eventually-convergent.
impl Snapshot for CostFunction {
    fn encode(&self, w: &mut SnapshotWriter) {
        self.budget.encode(w);
        self.feedback.encode(w);
        w.put_f64(self.cost_per_item_ns);
        w.put_f64(self.arrivals_per_interval);
        self.last_window_ci.encode(w);
        w.put_f64(self.fraction);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        let budget = QueryBudget::decode(r)?;
        let feedback = Option::<FeedbackController>::decode(r)?;
        if matches!(budget, QueryBudget::TargetRelativeError { .. }) != feedback.is_some() {
            return Err(crate::core::Error::Io(
                "cost function snapshot budget/feedback mismatch".into(),
            ));
        }
        Ok(Self {
            budget,
            feedback,
            cost_per_item_ns: r.get_f64()?,
            arrivals_per_interval: r.get_f64()?,
            last_window_ci: Option::<ConfidenceInterval>::decode(r)?,
            fraction: r.get_f64()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_fraction_is_stable() {
        let mut cf = CostFunction::new(QueryBudget::SamplingFraction(0.6));
        assert_eq!(cf.fraction(), 0.6);
        cf.observe(10_000.0, 6_000, 1_000_000, 0.05);
        assert_eq!(cf.fraction(), 0.6);
    }

    #[test]
    fn sample_size_budget_tracks_arrivals() {
        let mut cf = CostFunction::new(QueryBudget::SampleSizePerInterval(1_000));
        cf.observe(10_000.0, 10_000, 1_000_000, 0.0);
        assert!((cf.fraction() - 0.1).abs() < 1e-9);
        // arrivals double -> fraction roughly halves (EWMA-smoothed)
        cf.observe(20_000.0, 2_000, 1_000_000, 0.0);
        assert!(cf.fraction() < 0.1);
    }

    #[test]
    fn accuracy_budget_uses_feedback() {
        let mut cf = CostFunction::new(QueryBudget::TargetRelativeError {
            target: 0.01,
            initial_fraction: 0.2,
        });
        let f0 = cf.fraction();
        let f1 = cf.observe(1_000.0, 200, 1_000, 0.05); // error too big
        assert!(f1 > f0);
        let f2 = cf.observe(1_000.0, 500, 1_000, 0.001); // error tiny
        assert!(f2 < f1);
    }

    #[test]
    fn latency_budget_sizes_sample_to_cost() {
        let mut cf = CostFunction::new(QueryBudget::LatencyPerWindowMs(10.0));
        // 1000 ns per item, 100k arrivals: affordable = 10ms/1us = 10k -> 0.1
        cf.observe(100_000.0, 50_000, 50_000_000, 0.0);
        let f = cf.fraction();
        assert!((f - 0.1).abs() < 0.05, "fraction {f}");
    }

    #[test]
    fn latency_budget_adapts_to_costlier_items() {
        let mut cf = CostFunction::new(QueryBudget::LatencyPerWindowMs(10.0));
        cf.observe(100_000.0, 50_000, 50_000_000, 0.0); // 1 us/item
        let f_cheap = cf.fraction();
        for _ in 0..6 {
            cf.observe(100_000.0, 10_000, 100_000_000, 0.0); // 10 us/item
        }
        assert!(cf.fraction() < f_cheap);
    }

    #[test]
    fn observe_window_drives_feedback_from_the_ci() {
        use crate::error::bounds::ConfidenceLevel;
        let mut cf = CostFunction::new(QueryBudget::TargetRelativeError {
            target: 0.01,
            initial_fraction: 0.2,
        });
        assert!(cf.window_ci().is_none());
        // 5% relative width >> 1% target -> fraction grows
        let ci = ConfidenceInterval { value: 100.0, bound: 5.0, level: ConfidenceLevel::P95 };
        let f = cf.observe_window(1_000.0, 200, 1_000, Some(ci));
        assert!(f > 0.2);
        assert_eq!(cf.window_ci(), Some(ci));
        // sketch-backed windows observe None: fraction untouched, CI cleared
        let f2 = cf.observe_window(1_000.0, 200, 1_000, None);
        assert_eq!(f2, f);
        assert!(cf.window_ci().is_none());
    }

    #[test]
    fn observe_window_updates_cost_model_for_latency_budget() {
        let mut cf = CostFunction::new(QueryBudget::LatencyPerWindowMs(10.0));
        // 1000 ns per item, 100k arrivals -> affordable 10k -> fraction 0.1;
        // the window-level entry point must feed the same cost model
        // (the pipelined engine used to report zeros here).
        cf.observe_window(100_000.0, 50_000, 50_000_000, None);
        let f = cf.fraction();
        assert!((f - 0.1).abs() < 0.05, "fraction {f}");
    }

    #[test]
    fn initial_fractions() {
        assert_eq!(QueryBudget::SamplingFraction(0.4).initial_fraction(), 0.4);
        assert_eq!(QueryBudget::SampleSizePerInterval(5).initial_fraction(), 1.0);
        assert_eq!(
            QueryBudget::TargetRelativeError { target: 0.01, initial_fraction: 0.3 }
                .initial_fraction(),
            0.3
        );
    }
}
