//! Error estimation for approximate query results (paper §3.3).
//!
//! Implements the estimator arithmetic of Eq. (1)–(9) — shared between the
//! pure-Rust compute backend, the chunk-combining path of the XLA runtime,
//! and the adaptive feedback loop — plus confidence intervals from the
//! "68-95-99.7" rule and the feedback controller that re-tunes the sample
//! size when the error bound exceeds the user's target (§4.2.1).

pub mod bounds;
pub mod estimator;
pub mod feedback;

pub use bounds::{ConfidenceInterval, ConfidenceLevel};
pub use estimator::{Estimate, StrataPartials, StrataState};
pub use feedback::FeedbackController;
