//! Estimator arithmetic for approximate linear queries — Eq. (1)–(9).
//!
//! Per-stratum *partials* (selected count `Y_i`, `Σ I_ij`, `Σ I_ij²`) are
//! associative under addition, so partial aggregates computed over chunks of
//! a window (or on different worker nodes — paper §3.2 "Distributed
//! execution") combine losslessly before the estimate is finished.  The same
//! arithmetic is implemented in the L2 JAX graph (`python/compile/model.py`);
//! integration tests cross-check the two.

use crate::core::{Result, MAX_STRATA};
use crate::runtime::checkpoint::{Snapshot, SnapshotReader, SnapshotWriter};

/// Number of strata the fixed-shape compute kernels support.
pub const K: usize = MAX_STRATA;

/// Per-stratum partial aggregates of a sample: `Y_i`, `Σ I`, `Σ I²`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StrataPartials {
    /// Number of items actually selected per stratum (`Y_i`).
    pub y: [f64; K],
    /// Sum of selected item values per stratum.
    pub sum: [f64; K],
    /// Sum of squared selected item values per stratum.
    pub sumsq: [f64; K],
}

impl Default for StrataPartials {
    fn default() -> Self {
        Self { y: [0.0; K], sum: [0.0; K], sumsq: [0.0; K] }
    }
}

impl StrataPartials {
    /// Accumulate one selected item into stratum `i`.
    #[inline]
    pub fn push(&mut self, i: usize, value: f64) {
        self.y[i] += 1.0;
        self.sum[i] += value;
        self.sumsq[i] += value * value;
    }

    /// Combine partials from another chunk / worker (associative merge).
    pub fn merge(&mut self, other: &StrataPartials) {
        for i in 0..K {
            self.y[i] += other.y[i];
            self.sum[i] += other.sum[i];
            self.sumsq[i] += other.sumsq[i];
        }
    }

    /// Build partials from a flat sample of (stratum, value) pairs.
    pub fn from_sample<'a>(items: impl IntoIterator<Item = &'a (u16, f64)>) -> Self {
        let mut p = Self::default();
        for &(s, v) in items {
            if (s as usize) < K {
                p.push(s as usize, v);
            }
        }
        p
    }

    /// Total number of selected items across strata.
    pub fn total_y(&self) -> f64 {
        self.y.iter().sum()
    }
}

/// Per-stratum bookkeeping the sampler maintains per window: arrival counters
/// `C_i` and reservoir capacities `N_i`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StrataState {
    /// Items that *arrived* per stratum in the window (`C_i`).
    pub c: [f64; K],
    /// Reservoir capacity per stratum (`N_i`).
    pub n_cap: [f64; K],
}

impl Default for StrataState {
    fn default() -> Self {
        Self { c: [0.0; K], n_cap: [0.0; K] }
    }
}

impl StrataState {
    /// Merge counters from another worker (capacities must agree; arrival
    /// counters add — paper §3.2 distributed execution).
    pub fn merge_counters(&mut self, other: &StrataState) {
        for i in 0..K {
            self.c[i] += other.c[i];
        }
    }

    pub fn total_c(&self) -> f64 {
        self.c.iter().sum()
    }
}

impl Snapshot for StrataPartials {
    fn encode(&self, w: &mut SnapshotWriter) {
        self.y.encode(w);
        self.sum.encode(w);
        self.sumsq.encode(w);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        Ok(Self {
            y: <[f64; K]>::decode(r)?,
            sum: <[f64; K]>::decode(r)?,
            sumsq: <[f64; K]>::decode(r)?,
        })
    }
}

impl Snapshot for StrataState {
    fn encode(&self, w: &mut SnapshotWriter) {
        self.c.encode(w);
        self.n_cap.encode(w);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        Ok(Self { c: <[f64; K]>::decode(r)?, n_cap: <[f64; K]>::decode(r)? })
    }
}

/// A finished estimate for one window: Eq. (1)–(9).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Estimate {
    /// Approximate total SUM over all strata (Eq. 3).
    pub sum: f64,
    /// Approximate MEAN over all arrived items (Eq. 4).
    pub mean: f64,
    /// Estimated variance of the SUM estimate (Eq. 6).
    pub var_sum: f64,
    /// Estimated variance of the MEAN estimate (Eq. 9).
    pub var_mean: f64,
    /// Total arrived items Σ C_i.
    pub total_c: f64,
    /// Total selected items Σ Y_i.
    pub total_y: f64,
    /// Per-stratum weights W_i (Eq. 1).
    pub weights: [f64; K],
    /// Per-stratum estimated sums SUM_i (Eq. 2).
    pub strata_sums: [f64; K],
}

impl Estimate {
    /// Horvitz–Thompson weight of a sampled item from `stratum` (Eq. 1);
    /// `1.0` for out-of-range ids so callers never scale by garbage.  This
    /// is the weight the sketch subsystem attaches to each sampled item so
    /// mergeable summaries estimate full-stream frequencies/distributions.
    #[inline]
    pub fn weight_for(&self, stratum: u16) -> f64 {
        weight_from(&self.weights, stratum)
    }
}

/// Weight of one stratum out of a per-stratum weight array, with the same
/// out-of-range policy as [`Estimate::weight_for`]: `1.0` for ids past the
/// array so callers never scale by garbage.  The single source of truth
/// for that neutral-weight policy.
#[inline]
pub fn weight_from(weights: &[f64; K], stratum: u16) -> f64 {
    weights.get(stratum as usize).copied().unwrap_or(1.0)
}

/// Per-stratum Horvitz–Thompson weights W_i (Eq. 1), computable from the
/// counters alone: `W_i = C_i / N_i` when `C_i > N_i`, else 1 (and 1 for
/// empty strata, so callers never scale by garbage).  Shared by
/// [`estimate`] and the pane-level sketch builders, which weight each
/// interval's items by that interval's own counters.
///
/// **Arrived-but-unsampled strata** (`C_i > 0`, `N_i = 0`): there is no
/// selected item to carry the stratum's mass, so any non-zero weight would
/// either be scaled onto nothing or — worse — a non-finite `C_i / 0` that
/// [`crate::sketch::QuantileSketch::offer`] silently drops.  The weight is
/// pinned to an explicit `0.0` and the loss is surfaced through
/// [`crate::metrics::zero_weight_strata`], so an undercount from a
/// mis-sized sampler is observable instead of vanishing.
pub fn weights_for(state: &StrataState) -> [f64; K] {
    let mut weights = [1.0f64; K];
    for i in 0..K {
        if state.c[i] > state.n_cap[i] {
            if state.n_cap[i] > 0.0 {
                weights[i] = state.c[i] / state.n_cap[i];
            } else {
                weights[i] = 0.0;
                crate::metrics::record_zero_weight_stratum();
            }
        }
    }
    weights
}

/// Mass the watermark policy dropped from a window: count and value-sum of
/// the beyond-lateness items charged to its panes.  Unlike ordinary
/// non-response, the values *were observed* at drop time (the item arrived,
/// just too late to route), so the missing mass is exact, not estimated —
/// the widening terms below are deterministic worst-case bounds, not
/// variance inflations.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LateDrops {
    /// Number of beyond-lateness items dropped.
    pub count: f64,
    /// Sum of their observed values.
    pub mass: f64,
}

impl LateDrops {
    /// Record one dropped item's observed value.
    #[inline]
    pub fn add(&mut self, value: f64) {
        self.count += 1.0;
        self.mass += value;
    }

    /// Associative combine (drops charged to the same window span add).
    pub fn merge(&mut self, other: &LateDrops) {
        self.count += other.count;
        self.mass += other.mass;
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0.0
    }
}

impl Snapshot for LateDrops {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_f64(self.count);
        w.put_f64(self.mass);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        Ok(Self { count: r.get_f64()?, mass: r.get_f64()? })
    }
}

/// Missing-mass half-width for a SUM-type estimate: the estimate excludes
/// exactly `mass`, so the truth lies within `|mass|` of it (per-stratum
/// sums and histograms take the same bound — each bin's shift is at most
/// the total dropped mass).
#[inline]
pub fn missing_mass_sum(drops: &LateDrops) -> f64 {
    drops.mass.abs()
}

/// Missing-mass half-width for a COUNT estimate: each dropped item is one
/// uncounted arrival.
#[inline]
pub fn missing_mass_count(drops: &LateDrops) -> f64 {
    drops.count
}

/// Missing-mass half-width for a MEAN estimate.  With the estimate's mean
/// `m` over `arrived` items taken as exact, including the dropped mass
/// shifts it to `(arrived·m + mass) / (arrived + count)`; the half-width is
/// that shift, `|mass − count·m| / (arrived + count)`.
#[inline]
pub fn missing_mass_mean(drops: &LateDrops, est_mean: f64, arrived: f64) -> f64 {
    let n = arrived + drops.count;
    if n > 0.0 && est_mean.is_finite() {
        (drops.mass - drops.count * est_mean).abs() / n
    } else {
        0.0
    }
}

/// Finish an estimate from combined partials and strata state.
///
/// This is the exact arithmetic of the L2 graph (`model.py`), kept in sync by
/// the `runtime` integration tests.
pub fn estimate(partials: &StrataPartials, state: &StrataState) -> Estimate {
    let weights = weights_for(state);
    let mut strata_sums = [0.0f64; K];
    let mut total_sum = 0.0;
    let mut var_sum = 0.0;
    let total_c: f64 = state.total_c();
    let mut var_mean = 0.0;

    for i in 0..K {
        let c = state.c[i];
        let y = partials.y[i];
        let s1 = partials.sum[i];
        let s2 = partials.sumsq[i];

        // Eq. 2 — per-stratum estimated sum (weights are Eq. 1 above).
        strata_sums[i] = s1 * weights[i];
        total_sum += strata_sums[i];

        // Eq. 7 — sample variance (0 when fewer than 2 selected items).
        let s_sq = if y > 1.0 {
            let ybar = s1 / y;
            ((s2 - y * ybar * ybar) / (y - 1.0)).max(0.0)
        } else {
            0.0
        };

        // Eq. 6 / Eq. 9 terms.
        let fpc = (c - y).max(0.0);
        if y > 0.0 {
            var_sum += c * fpc * s_sq / y;
            if c > 0.0 && total_c > 0.0 {
                let omega = c / total_c;
                var_mean += omega * omega * (s_sq / y) * (fpc / c);
            }
        }
    }

    let mean = total_sum / total_c.max(1.0);
    Estimate {
        sum: total_sum,
        mean,
        var_sum,
        var_mean,
        total_c,
        total_y: partials.total_y(),
        weights,
        strata_sums,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn simple_case() -> (StrataPartials, StrataState) {
        let mut p = StrataPartials::default();
        // stratum 0: 4 items of value 2
        for _ in 0..4 {
            p.push(0, 2.0);
        }
        // stratum 1: 2 items, values 10 and 20
        p.push(1, 10.0);
        p.push(1, 20.0);
        let mut st = StrataState::default();
        st.c[0] = 8.0; // twice as many arrived as selected
        st.c[1] = 2.0; // fully sampled
        st.n_cap = [4.0; K];
        (p, st)
    }

    #[test]
    fn weight_law_eq1() {
        let (p, st) = simple_case();
        let e = estimate(&p, &st);
        assert_eq!(e.weights[0], 2.0); // C=8 > N=4 -> 8/4
        assert_eq!(e.weights[1], 1.0); // C=2 <= N=4 -> 1
    }

    #[test]
    fn sum_eq2_eq3() {
        let (p, st) = simple_case();
        let e = estimate(&p, &st);
        // stratum 0: sum 8 * w 2 = 16; stratum 1: 30 * 1 = 30
        assert_eq!(e.strata_sums[0], 16.0);
        assert_eq!(e.strata_sums[1], 30.0);
        assert_eq!(e.sum, 46.0);
    }

    #[test]
    fn mean_eq4() {
        let (p, st) = simple_case();
        let e = estimate(&p, &st);
        assert!((e.mean - 46.0 / 10.0).abs() < 1e-12);
    }

    #[test]
    fn fully_sampled_stratum_contributes_zero_variance() {
        let (p, st) = simple_case();
        let e = estimate(&p, &st);
        // stratum 1 fully sampled (C=Y=2) -> fpc = 0 -> no variance term;
        // stratum 0 items identical -> s^2 = 0. Total variance = 0.
        assert_eq!(e.var_sum, 0.0);
        assert_eq!(e.var_mean, 0.0);
    }

    #[test]
    fn variance_eq6_hand_computed() {
        let mut p = StrataPartials::default();
        // stratum 0: values 1, 3 selected out of C=10
        p.push(0, 1.0);
        p.push(0, 3.0);
        let mut st = StrataState::default();
        st.c[0] = 10.0;
        st.n_cap = [2.0; K];
        let e = estimate(&p, &st);
        // s^2 = ((1-2)^2 + (3-2)^2) / 1 = 2
        // Var(SUM) = C*(C-Y)*s^2/Y = 10*8*2/2 = 80
        assert!((e.var_sum - 80.0).abs() < 1e-9);
        // Var(MEAN) = w^2 * s^2/Y * (C-Y)/C with w = 1 -> 2/2 * 8/10 = 0.8
        assert!((e.var_mean - 0.8).abs() < 1e-9);
    }

    #[test]
    fn merge_is_associative_and_matches_whole() {
        let items: Vec<(u16, f64)> =
            (0..100).map(|i| ((i % 5) as u16, i as f64)).collect();
        let whole = StrataPartials::from_sample(&items);
        let mut a = StrataPartials::from_sample(&items[..37]);
        let b = StrataPartials::from_sample(&items[37..]);
        a.merge(&b);
        assert_eq!(a, whole);
    }

    #[test]
    fn empty_partials_estimate_is_zero() {
        let p = StrataPartials::default();
        let st = StrataState::default();
        let e = estimate(&p, &st);
        assert_eq!(e.sum, 0.0);
        assert_eq!(e.var_sum, 0.0);
        assert_eq!(e.total_y, 0.0);
    }

    #[test]
    fn out_of_range_strata_ignored_in_from_sample() {
        let items = vec![(0u16, 1.0), (99u16, 5.0)];
        let p = StrataPartials::from_sample(&items);
        assert_eq!(p.total_y(), 1.0);
    }

    #[test]
    fn zero_sample_stratum_gets_zero_weight_and_is_counted() {
        let before = crate::metrics::zero_weight_strata();
        let mut st = StrataState::default();
        st.c[0] = 50.0; // arrived, sampled nothing (n_cap stays 0)
        st.c[1] = 10.0;
        st.n_cap[1] = 10.0;
        let w = weights_for(&st);
        assert_eq!(w[0], 0.0, "unobservable stratum must weigh 0, not C/max(N,1)");
        assert_eq!(w[1], 1.0);
        // other tests may tick concurrently; the counter is monotone
        assert!(crate::metrics::zero_weight_strata() >= before + 1);
        // the estimate over such a state stays finite and simply lacks the
        // unobservable stratum's mass
        let e = estimate(&StrataPartials::default(), &st);
        assert!(e.sum.is_finite() && e.var_sum.is_finite());
        assert_eq!(e.weights[0], 0.0);
        // a sketch fed through these weights drops nothing silently: the
        // zero weight is rejected by offer() while the counter above has
        // already surfaced the loss
        let mut sk = crate::sketch::QuantileSketch::new(16);
        sk.offer(1.0, w[0]);
        assert!(sk.is_empty());
    }

    #[test]
    fn late_drops_accumulate_and_merge() {
        let mut a = LateDrops::default();
        assert!(a.is_empty());
        a.add(3.0);
        a.add(-1.0);
        let mut b = LateDrops::default();
        b.add(10.0);
        a.merge(&b);
        assert_eq!(a.count, 3.0);
        assert_eq!(a.mass, 12.0);
        assert!(!a.is_empty());
    }

    #[test]
    fn missing_mass_sum_is_exact_dropped_mass() {
        let d = LateDrops { count: 4.0, mass: -25.0 };
        assert_eq!(missing_mass_sum(&d), 25.0);
        assert_eq!(missing_mass_count(&d), 4.0);
    }

    #[test]
    fn missing_mass_mean_is_the_inclusion_shift() {
        // 9 arrived items with mean 10; one dropped item of value 30:
        // including it moves the mean to (90 + 30) / 10 = 12 -> shift 2.
        let d = LateDrops { count: 1.0, mass: 30.0 };
        assert!((missing_mass_mean(&d, 10.0, 9.0) - 2.0).abs() < 1e-12);
        // dropped items at exactly the mean shift nothing
        let at_mean = LateDrops { count: 2.0, mass: 20.0 };
        assert_eq!(missing_mass_mean(&at_mean, 10.0, 9.0), 0.0);
        // degenerate inputs stay finite
        assert_eq!(missing_mass_mean(&d, f64::NAN, 9.0), 0.0);
        assert_eq!(missing_mass_mean(&LateDrops::default(), 10.0, 0.0), 0.0);
    }

    #[test]
    fn weight_for_accessor() {
        let (p, st) = simple_case();
        let e = estimate(&p, &st);
        assert_eq!(e.weight_for(0), 2.0);
        assert_eq!(e.weight_for(1), 1.0);
        assert_eq!(e.weight_for(999), 1.0); // out of range -> neutral weight
    }
}
