//! Confidence intervals from the "68-95-99.7" rule (paper §3.3), plus the
//! *native* guarantees of the sketch subsystem surfaced in the same
//! `output ± bound` shape.
//!
//! The approximate result falls within 1, 2, 3 standard deviations of the
//! true result with probability 68% / 95% / 99.7%; the standard deviation is
//! the square root of the estimated variance (Eq. 6 / Eq. 9).
//!
//! Sketch-backed queries do not go through the CLT: each sketch carries its
//! own guarantee ([`crate::sketch`]), translated here into an interval —
//! * quantiles: a deterministic rank-error ε maps to the value band
//!   `[Q(q−ε), Q(q+ε)]` ([`ConfidenceInterval::for_quantile`]);
//! * distinct counts: HyperLogLog's relative standard error scales with the
//!   requested σ level ([`ConfidenceInterval::for_distinct`]);
//! * heavy-hitter counts: Count-Min's one-sided `ε·W` over-estimate bound
//!   ([`ConfidenceInterval::for_count_overestimate`]).

use super::estimator::Estimate;
use crate::core::{Error, Result};
use crate::runtime::checkpoint::{Snapshot, SnapshotReader, SnapshotWriter};

/// Confidence levels supported by the paper's error-bound rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConfidenceLevel {
    /// ±1σ ≈ 68%.
    P68,
    /// ±2σ ≈ 95%.
    P95,
    /// ±3σ ≈ 99.7%.
    P997,
}

impl ConfidenceLevel {
    /// Number of standard deviations for this level.
    pub fn sigmas(self) -> f64 {
        match self {
            ConfidenceLevel::P68 => 1.0,
            ConfidenceLevel::P95 => 2.0,
            ConfidenceLevel::P997 => 3.0,
        }
    }
}

/// An `output ± error bound` result (paper Algorithm 2's final step).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ConfidenceInterval {
    /// Point estimate.
    pub value: f64,
    /// Half-width of the interval (the "error bound").
    pub bound: f64,
    /// Level the bound was computed at.
    pub level: ConfidenceLevel,
}

impl ConfidenceInterval {
    /// Interval for the SUM estimate.
    pub fn for_sum(e: &Estimate, level: ConfidenceLevel) -> Self {
        Self { value: e.sum, bound: level.sigmas() * e.var_sum.max(0.0).sqrt(), level }
    }

    /// Interval for the MEAN estimate.
    pub fn for_mean(e: &Estimate, level: ConfidenceLevel) -> Self {
        Self { value: e.mean, bound: level.sigmas() * e.var_mean.max(0.0).sqrt(), level }
    }

    /// Interval for a quantile estimate from its rank-error band: `value` is
    /// `Q(q)`, `lo`/`hi` are the sketch's `Q(q−ε)`/`Q(q+ε)`.  The band is a
    /// deterministic guarantee of the sketch (not a CLT statement); the
    /// half-width is the wider side so the interval always covers the band.
    ///
    /// An empty-window sketch answers `NaN`: the interval then pins its
    /// bound to zero (a NaN-valued, zero-width interval that `contains`
    /// nothing and has NaN `relative`) instead of letting `NaN − NaN`
    /// arithmetic decide by IEEE accident.
    pub fn for_quantile(value: f64, lo: f64, hi: f64, level: ConfidenceLevel) -> Self {
        let bound = if value.is_finite() && lo.is_finite() && hi.is_finite() {
            (hi - value).max(value - lo).max(0.0)
        } else {
            0.0
        };
        Self { value, bound, level }
    }

    /// Interval for a HyperLogLog distinct-count estimate: the native
    /// relative standard error (≈1.04/√m) scaled by the level's σ-multiple.
    ///
    /// **Covers sketch error only.** Over a *sampled* stream the estimate
    /// counts distinct values among the selected items; values the sampler
    /// never selected are invisible, so relative to the full stream the
    /// value is a lower bound and the true distinct count can sit far above
    /// `hi()`.  Only over an unsampled window (native execution, or heavy
    /// keys certain to be selected) is this a calibrated two-sided interval.
    pub fn for_distinct(estimate: f64, relative_std_error: f64, level: ConfidenceLevel) -> Self {
        Self {
            value: estimate,
            bound: level.sigmas() * relative_std_error.max(0.0) * estimate.abs(),
            level,
        }
    }

    /// Interval for a Count-Min-backed count: the estimate never
    /// under-counts and over-counts by at most `over_bound = ε·W` (with
    /// probability ≥ 1 − e^−depth), so the bound is one-sided and
    /// independent of the σ level.
    pub fn for_count_overestimate(estimate: f64, over_bound: f64, level: ConfidenceLevel) -> Self {
        Self { value: estimate, bound: over_bound.max(0.0), level }
    }

    /// Widen the interval by an additive half-width term (the missing-mass
    /// charge for beyond-lateness drops — see
    /// [`crate::error::estimator::missing_mass_sum`] and friends).  The
    /// point estimate is untouched: the dropped mass is *known* to be
    /// excluded, so honesty lives in the bound, not the value.  Negative or
    /// non-finite extras are ignored (a NaN drop charge must not poison an
    /// otherwise-calibrated interval).
    pub fn widened(self, extra: f64) -> Self {
        if extra.is_finite() && extra > 0.0 {
            Self { bound: self.bound + extra, ..self }
        } else {
            self
        }
    }

    /// Relative error bound (`bound / |value|`).
    ///
    /// Edge cases, pinned by tests (the feedback loop ignores any
    /// non-finite observation, so every degenerate case must land on a
    /// non-finite value rather than a spurious 0):
    /// * zero-width interval at a non-zero value → `0.0` (a legitimately
    ///   exact result, e.g. COUNT or a fully-sampled window);
    /// * `0 ± 0` → `0.0`; `0 ± b` (b > 0) → `inf`;
    /// * NaN value or NaN/inf bound (empty window, empty stratum sketch)
    ///   → `NaN`.
    pub fn relative(&self) -> f64 {
        if !self.value.is_finite() || !self.bound.is_finite() {
            return f64::NAN;
        }
        if self.value == 0.0 {
            if self.bound == 0.0 {
                0.0
            } else {
                f64::INFINITY
            }
        } else {
            self.bound / self.value.abs()
        }
    }

    pub fn lo(&self) -> f64 {
        self.value - self.bound
    }

    pub fn hi(&self) -> f64 {
        self.value + self.bound
    }

    /// True when `truth` falls inside `[lo, hi]` (endpoints included, so a
    /// zero-width interval contains exactly its value).  Any NaN — a NaN
    /// truth, or a NaN value/bound from an empty window — can never attest
    /// coverage: the comparisons are IEEE-false, and the calibration suite
    /// pins that behavior.
    pub fn contains(&self, truth: f64) -> bool {
        truth >= self.lo() && truth <= self.hi()
    }
}

impl Snapshot for ConfidenceLevel {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_u8(match self {
            ConfidenceLevel::P68 => 0,
            ConfidenceLevel::P95 => 1,
            ConfidenceLevel::P997 => 2,
        });
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        Ok(match r.get_u8()? {
            0 => ConfidenceLevel::P68,
            1 => ConfidenceLevel::P95,
            2 => ConfidenceLevel::P997,
            other => {
                return Err(Error::Io(format!(
                    "unknown confidence level tag {other} in snapshot"
                )))
            }
        })
    }
}

impl Snapshot for ConfidenceInterval {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_f64(self.value);
        w.put_f64(self.bound);
        self.level.encode(w);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        Ok(Self {
            value: r.get_f64()?,
            bound: r.get_f64()?,
            level: ConfidenceLevel::decode(r)?,
        })
    }
}

impl std::fmt::Display for ConfidenceInterval {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.4} ± {:.4}", self.value, self.bound)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::estimator::{estimate, StrataPartials, StrataState, K};

    fn est_with_var(var_sum: f64) -> Estimate {
        Estimate {
            sum: 100.0,
            mean: 10.0,
            var_sum,
            var_mean: var_sum / 100.0,
            total_c: 10.0,
            total_y: 5.0,
            weights: [1.0; K],
            strata_sums: [0.0; K],
        }
    }

    #[test]
    fn sigma_scaling() {
        let e = est_with_var(4.0); // sd = 2
        assert_eq!(ConfidenceInterval::for_sum(&e, ConfidenceLevel::P68).bound, 2.0);
        assert_eq!(ConfidenceInterval::for_sum(&e, ConfidenceLevel::P95).bound, 4.0);
        assert_eq!(ConfidenceInterval::for_sum(&e, ConfidenceLevel::P997).bound, 6.0);
    }

    #[test]
    fn interval_endpoints_and_contains() {
        let e = est_with_var(4.0);
        let ci = ConfidenceInterval::for_sum(&e, ConfidenceLevel::P95);
        assert_eq!(ci.lo(), 96.0);
        assert_eq!(ci.hi(), 104.0);
        assert!(ci.contains(100.0));
        assert!(ci.contains(96.0));
        assert!(!ci.contains(95.9));
    }

    #[test]
    fn relative_bound() {
        let e = est_with_var(25.0); // sd 5
        let ci = ConfidenceInterval::for_sum(&e, ConfidenceLevel::P68);
        assert!((ci.relative() - 0.05).abs() < 1e-12);
    }

    #[test]
    fn zero_value_zero_bound_is_zero_relative() {
        let ci = ConfidenceInterval { value: 0.0, bound: 0.0, level: ConfidenceLevel::P95 };
        assert_eq!(ci.relative(), 0.0);
        let ci2 = ConfidenceInterval { value: 0.0, bound: 1.0, level: ConfidenceLevel::P95 };
        assert!(ci2.relative().is_infinite());
    }

    #[test]
    fn zero_width_interval_contains_exactly_its_value() {
        let ci = ConfidenceInterval { value: 42.0, bound: 0.0, level: ConfidenceLevel::P95 };
        assert!(ci.contains(42.0));
        assert!(!ci.contains(42.0 + 1e-12));
        assert!(!ci.contains(41.999999999999));
        assert_eq!(ci.relative(), 0.0);
    }

    #[test]
    fn nan_value_interval_is_inert() {
        // Empty-window quantile: the sketch answers NaN.
        let ci = ConfidenceInterval::for_quantile(
            f64::NAN,
            f64::NAN,
            f64::NAN,
            ConfidenceLevel::P95,
        );
        assert_eq!(ci.bound, 0.0, "NaN band must pin to zero width");
        assert!(!ci.contains(0.0));
        assert!(!ci.contains(f64::NAN));
        assert!(ci.relative().is_nan(), "feedback must see non-finite, not 0");
    }

    #[test]
    fn nan_or_inf_bound_never_attests_coverage() {
        let ci = ConfidenceInterval { value: 10.0, bound: f64::NAN, level: ConfidenceLevel::P95 };
        assert!(!ci.contains(10.0));
        assert!(ci.relative().is_nan());
        let ci = ConfidenceInterval {
            value: 10.0,
            bound: f64::INFINITY,
            level: ConfidenceLevel::P95,
        };
        // an infinite bound technically covers everything finite…
        assert!(ci.contains(1e300));
        // …but reads as a non-finite (ignored) observation, not rel = 0
        assert!(ci.relative().is_nan());
    }

    #[test]
    fn empty_stratum_estimates_stay_finite() {
        // An interval where a stratum arrived but nothing was selected
        // (c > 0, y = 0, n_cap = 0) and another that never arrived: the
        // estimate and both CIs must come out finite, not NaN.
        let mut st = StrataState::default();
        st.c[0] = 100.0; // arrived, never sampled
        st.c[1] = 50.0; // arrived, fully sampled
        st.n_cap[1] = 50.0;
        let mut p = StrataPartials::default();
        for i in 0..50 {
            p.push(1, i as f64);
        }
        let e = estimate(&p, &st);
        let sum_ci = ConfidenceInterval::for_sum(&e, ConfidenceLevel::P95);
        let mean_ci = ConfidenceInterval::for_mean(&e, ConfidenceLevel::P95);
        assert!(sum_ci.value.is_finite() && sum_ci.bound.is_finite());
        assert!(mean_ci.value.is_finite() && mean_ci.bound.is_finite());
        assert!(sum_ci.relative().is_finite());
    }

    #[test]
    fn empty_window_estimate_yields_workable_interval() {
        let e = estimate(&StrataPartials::default(), &StrataState::default());
        let ci = ConfidenceInterval::for_sum(&e, ConfidenceLevel::P95);
        assert_eq!(ci.value, 0.0);
        assert_eq!(ci.bound, 0.0);
        assert!(ci.contains(0.0));
        assert_eq!(ci.relative(), 0.0);
    }

    #[test]
    fn quantile_band_interval() {
        let ci = ConfidenceInterval::for_quantile(50.0, 48.0, 55.0, ConfidenceLevel::P95);
        assert_eq!(ci.value, 50.0);
        assert_eq!(ci.bound, 5.0); // wider side
        assert!(ci.contains(48.0) && ci.contains(55.0));
        // degenerate band (point mass) yields a zero-width interval
        let ci = ConfidenceInterval::for_quantile(1.0, 1.0, 1.0, ConfidenceLevel::P95);
        assert_eq!(ci.bound, 0.0);
    }

    #[test]
    fn distinct_interval_scales_with_level() {
        let c68 = ConfidenceInterval::for_distinct(1000.0, 0.016, ConfidenceLevel::P68);
        let c95 = ConfidenceInterval::for_distinct(1000.0, 0.016, ConfidenceLevel::P95);
        assert!((c68.bound - 16.0).abs() < 1e-9);
        assert!((c95.bound - 32.0).abs() < 1e-9);
    }

    #[test]
    fn count_overestimate_interval() {
        let ci = ConfidenceInterval::for_count_overestimate(500.0, 12.5, ConfidenceLevel::P95);
        assert_eq!(ci.value, 500.0);
        assert_eq!(ci.bound, 12.5);
        // negative bounds are clamped
        let ci = ConfidenceInterval::for_count_overestimate(1.0, -3.0, ConfidenceLevel::P95);
        assert_eq!(ci.bound, 0.0);
    }

    #[test]
    fn widened_adds_to_bound_and_ignores_garbage() {
        let ci = ConfidenceInterval { value: 100.0, bound: 4.0, level: ConfidenceLevel::P95 };
        let w = ci.widened(6.0);
        assert_eq!(w.value, 100.0, "widening must not move the point estimate");
        assert_eq!(w.bound, 10.0);
        assert!(w.contains(92.0) && !ci.contains(92.0));
        // zero / negative / non-finite extras are all no-ops
        assert_eq!(ci.widened(0.0), ci);
        assert_eq!(ci.widened(-5.0), ci);
        assert_eq!(ci.widened(f64::NAN), ci);
        assert_eq!(ci.widened(f64::INFINITY), ci);
    }

    #[test]
    fn statistical_coverage_p95() {
        // Sample repeatedly from a population; the 95% CI on SUM should
        // cover the true sum in roughly >= 90% of trials (Monte Carlo slack).
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from_u64(7);
        let population: Vec<f64> = (0..1000).map(|_| rng.range_f64(0.0, 100.0)).collect();
        let truth: f64 = population.iter().sum();
        let n_cap = 100usize;
        let trials = 200;
        let mut covered = 0;
        for _ in 0..trials {
            // SRS of n_cap items from the single stratum
            let mut partials = StrataPartials::default();
            let mut chosen = std::collections::HashSet::new();
            while chosen.len() < n_cap {
                chosen.insert(rng.range_usize(0, population.len()));
            }
            for &i in &chosen {
                partials.push(0, population[i]);
            }
            let mut st = StrataState::default();
            st.c[0] = population.len() as f64;
            st.n_cap = [n_cap as f64; K];
            let e = estimate(&partials, &st);
            let ci = ConfidenceInterval::for_sum(&e, ConfidenceLevel::P95);
            if ci.contains(truth) {
                covered += 1;
            }
        }
        let coverage = covered as f64 / trials as f64;
        assert!(coverage > 0.88, "coverage {coverage} too low");
    }
}
