//! Adaptive feedback: re-tune the sample size when the observed error bound
//! exceeds the user's accuracy target (paper §4.2.1: "For cases where the
//! error bound is larger than the specified target, an adaptive feedback
//! mechanism is activated to increase the sample size").
//!
//! The controller is a damped multiplicative-increase / gentle-decrease loop
//! over the *sampling fraction*: per window it compares the achieved relative
//! error bound against the target and scales the fraction by a bounded
//! factor.  Variance of a mean estimate shrinks ~1/Y, so to shrink the bound
//! by ratio r the sample must grow by ~r²; the controller applies that model
//! with damping to avoid oscillation under bursty arrivals.
//!
//! The engines feed the controller through [`FeedbackController::observe_ci`]
//! with the completed **window's** confidence interval — the user-facing
//! `output ± bound` over the full window span, as assembled by the pane
//! store — not any per-interval proxy.  (An interval-level bound
//! systematically over-states the window-level error by ~√(window/slide),
//! which would drive the fraction high; observing the window keeps the loop
//! honest for long-window/small-slide configurations.)

use crate::core::Result;
use crate::error::bounds::ConfidenceInterval;
use crate::runtime::checkpoint::{Snapshot, SnapshotReader, SnapshotWriter};

/// Smoothing for the observed window-CI-width EWMA.
const CI_WIDTH_EWMA: f64 = 0.4;

/// Adaptive sample-size controller.
#[derive(Debug, Clone)]
pub struct FeedbackController {
    /// Target relative error bound (e.g. 0.01 = 1%).
    target_rel_error: f64,
    /// Current sampling fraction in (0, 1].
    fraction: f64,
    /// Damping in (0, 1]: 1 = immediate jumps, smaller = smoother.
    damping: f64,
    /// Floor / ceiling for the fraction.
    min_fraction: f64,
    max_fraction: f64,
    /// Number of adjustments made (for introspection / tests).
    adjustments: u64,
    /// EWMA of observed window CI half-widths (introspection/metrics).
    ci_width_ewma: f64,
    /// Windows observed through [`Self::observe_ci`].
    windows_observed: u64,
}

impl FeedbackController {
    /// Create a controller starting at `initial_fraction`, aiming at
    /// `target_rel_error`.
    pub fn new(target_rel_error: f64, initial_fraction: f64) -> Self {
        Self {
            target_rel_error: target_rel_error.max(1e-9),
            fraction: initial_fraction.clamp(1e-4, 1.0),
            damping: 0.5,
            min_fraction: 0.01,
            max_fraction: 1.0,
            adjustments: 0,
            ci_width_ewma: 0.0,
            windows_observed: 0,
        }
    }

    /// Override the damping factor (tests / tuning).
    pub fn with_damping(mut self, damping: f64) -> Self {
        self.damping = damping.clamp(0.01, 1.0);
        self
    }

    /// Override fraction bounds.
    pub fn with_bounds(mut self, min: f64, max: f64) -> Self {
        self.min_fraction = min.clamp(1e-4, 1.0);
        self.max_fraction = max.clamp(self.min_fraction, 1.0);
        self.fraction = self.fraction.clamp(self.min_fraction, self.max_fraction);
        self
    }

    /// Current sampling fraction.
    pub fn fraction(&self) -> f64 {
        self.fraction
    }

    pub fn target(&self) -> f64 {
        self.target_rel_error
    }

    pub fn adjustments(&self) -> u64 {
        self.adjustments
    }

    /// EWMA of the window CI half-widths observed so far (0 before the
    /// first window).
    pub fn window_ci_width(&self) -> f64 {
        self.ci_width_ewma
    }

    /// Windows whose CI has been observed.
    pub fn windows_observed(&self) -> u64 {
        self.windows_observed
    }

    /// Feed one completed window's confidence interval: record its width
    /// and adjust the fraction from its relative half-width.
    ///
    /// A non-finite interval — NaN value (an all-empty-pane window's
    /// sketch answers NaN, and `for_quantile` then pins the band to a
    /// NaN-valued, zero-width interval) or a NaN/inf bound — is **not an
    /// observation**: it must touch neither the width EWMA (where a NaN
    /// would stick forever, and a spurious 0.0 would drag the EWMA down
    /// on every idle window) nor the fraction.  The fraction path was
    /// always guarded through `relative()`; the EWMA now skips too.
    pub fn observe_ci(&mut self, ci: &ConfidenceInterval) -> f64 {
        if ci.value.is_finite() && ci.bound.is_finite() {
            self.windows_observed += 1;
            self.ci_width_ewma = if self.windows_observed == 1 {
                ci.bound
            } else {
                CI_WIDTH_EWMA * ci.bound + (1.0 - CI_WIDTH_EWMA) * self.ci_width_ewma
            };
            crate::obs_gauge!(
                "feedback_ci_width_ewma",
                "EWMA of observed window CI half-widths (accuracy loop state)"
            )
            .set(self.ci_width_ewma);
        }
        self.observe(ci.relative())
    }

    /// Feed the relative error bound observed on the last window; returns the
    /// fraction to use for the next window.
    ///
    /// `observed` of `NaN`/`inf` (e.g. zero-valued window) leaves the
    /// fraction unchanged.
    pub fn observe(&mut self, observed_rel_error: f64) -> f64 {
        if !observed_rel_error.is_finite() {
            return self.fraction;
        }
        let ratio = observed_rel_error / self.target_rel_error;
        // Error ∝ 1/sqrt(sample) -> sample multiplier = ratio².  Damp in
        // log-space to avoid overshoot: multiplier^damping.
        let raw = (ratio * ratio).max(1e-6);
        let mult = raw.powf(self.damping);
        // Clamp a single step to [0.5x, 4x] so one noisy window cannot slam
        // the fraction across its whole range.
        let mult = mult.clamp(0.5, 4.0);
        let next = (self.fraction * mult).clamp(self.min_fraction, self.max_fraction);
        if (next - self.fraction).abs() > f64::EPSILON {
            self.adjustments += 1;
        }
        self.fraction = next;
        crate::obs_gauge!(
            "feedback_fraction",
            "sampling fraction currently commanded by the feedback loop"
        )
        .set(self.fraction);
        self.fraction
    }
}

/// The feedback EWMA is part of the checkpoint contract (ISSUE 9): an
/// interrupted adaptive run must resume with the same fraction trajectory
/// it would have followed uninterrupted.
impl Snapshot for FeedbackController {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_f64(self.target_rel_error);
        w.put_f64(self.fraction);
        w.put_f64(self.damping);
        w.put_f64(self.min_fraction);
        w.put_f64(self.max_fraction);
        w.put_u64(self.adjustments);
        w.put_f64(self.ci_width_ewma);
        w.put_u64(self.windows_observed);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        Ok(Self {
            target_rel_error: r.get_f64()?,
            fraction: r.get_f64()?,
            damping: r.get_f64()?,
            min_fraction: r.get_f64()?,
            max_fraction: r.get_f64()?,
            adjustments: r.get_u64()?,
            ci_width_ewma: r.get_f64()?,
            windows_observed: r.get_u64()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_when_error_above_target() {
        let mut c = FeedbackController::new(0.01, 0.2);
        let before = c.fraction();
        let after = c.observe(0.05); // 5x worse than target
        assert!(after > before);
    }

    #[test]
    fn shrinks_when_error_below_target() {
        let mut c = FeedbackController::new(0.01, 0.8);
        let after = c.observe(0.001); // 10x better than target
        assert!(after < 0.8);
    }

    #[test]
    fn clamped_to_bounds() {
        let mut c = FeedbackController::new(0.01, 0.5).with_bounds(0.1, 0.9);
        for _ in 0..20 {
            c.observe(10.0);
        }
        assert!(c.fraction() <= 0.9);
        for _ in 0..50 {
            c.observe(1e-9);
        }
        assert!(c.fraction() >= 0.1);
    }

    #[test]
    fn at_target_is_stable() {
        let mut c = FeedbackController::new(0.01, 0.4);
        let f0 = c.fraction();
        let f1 = c.observe(0.01);
        assert!((f1 - f0).abs() < 1e-9);
    }

    #[test]
    fn nan_and_inf_ignored() {
        let mut c = FeedbackController::new(0.01, 0.4);
        assert_eq!(c.observe(f64::NAN), 0.4);
        assert_eq!(c.observe(f64::INFINITY), 0.4);
        assert_eq!(c.adjustments(), 0);
    }

    #[test]
    fn converges_on_simulated_plant() {
        // Simulated system: rel error = base / sqrt(fraction).  With
        // base = 0.01 the fixed point for target 0.02 is fraction 0.25.
        let mut c = FeedbackController::new(0.02, 0.9);
        let mut f = c.fraction();
        for _ in 0..60 {
            let err = 0.01 / f.sqrt();
            f = c.observe(err);
        }
        assert!((f - 0.25).abs() < 0.05, "converged to {f}");
    }

    #[test]
    fn observe_ci_tracks_window_width_and_adjusts() {
        use crate::error::bounds::{ConfidenceInterval, ConfidenceLevel};
        let mut c = FeedbackController::new(0.01, 0.2);
        let ci = ConfidenceInterval { value: 100.0, bound: 5.0, level: ConfidenceLevel::P95 };
        let before = c.fraction();
        let after = c.observe_ci(&ci); // 5% >> 1% target
        assert!(after > before);
        assert_eq!(c.window_ci_width(), 5.0);
        assert_eq!(c.windows_observed(), 1);
        // second window narrows: EWMA moves toward the new width
        let ci2 = ConfidenceInterval { value: 100.0, bound: 1.0, level: ConfidenceLevel::P95 };
        c.observe_ci(&ci2);
        assert!(c.window_ci_width() < 5.0 && c.window_ci_width() > 1.0);
        // zero-valued window: relative() is inf -> fraction unchanged, but
        // the width is still recorded
        let f = c.fraction();
        let ci3 = ConfidenceInterval { value: 0.0, bound: 2.0, level: ConfidenceLevel::P95 };
        assert_eq!(c.observe_ci(&ci3), f);
        assert_eq!(c.windows_observed(), 3);
    }

    #[test]
    fn non_finite_window_ci_never_poisons_the_loop() {
        use crate::error::bounds::{ConfidenceInterval, ConfidenceLevel};
        // ISSUE 5 satellite: the empty-window path (sketch answers NaN →
        // NaN-valued zero-width CI) and any NaN/inf bound must be skipped
        // entirely — EWMA, window counter, and fraction all untouched.
        let mut c = FeedbackController::new(0.01, 0.3);
        let good = ConfidenceInterval { value: 10.0, bound: 1.0, level: ConfidenceLevel::P95 };
        c.observe_ci(&good);
        let (f, w, n) = (c.fraction(), c.window_ci_width(), c.windows_observed());
        assert!(w.is_finite() && n == 1);
        for bad in [
            ConfidenceInterval { value: f64::NAN, bound: 0.0, level: ConfidenceLevel::P95 },
            ConfidenceInterval { value: f64::NAN, bound: f64::NAN, level: ConfidenceLevel::P95 },
            ConfidenceInterval { value: 5.0, bound: f64::NAN, level: ConfidenceLevel::P95 },
            ConfidenceInterval { value: 5.0, bound: f64::INFINITY, level: ConfidenceLevel::P95 },
        ] {
            c.observe_ci(&bad);
            assert_eq!(c.fraction(), f, "fraction moved on {bad:?}");
            assert_eq!(c.window_ci_width(), w, "EWMA moved on {bad:?}");
            assert_eq!(c.windows_observed(), n, "counter moved on {bad:?}");
            assert!(c.window_ci_width().is_finite(), "EWMA poisoned by {bad:?}");
        }
        // a later finite window is observed normally
        c.observe_ci(&good);
        assert_eq!(c.windows_observed(), 2);
    }

    #[test]
    fn single_step_bounded() {
        let mut c = FeedbackController::new(0.01, 0.2).with_damping(1.0);
        let f = c.observe(1000.0); // absurd error
        assert!(f <= 0.2 * 4.0 + 1e-12);
    }
}
