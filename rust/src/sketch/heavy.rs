//! Heavy hitters: Count-Min sketch + space-saving candidate set.
//!
//! [`CountMin`] (Cormode & Muthukrishnan 2005) answers weighted point
//! queries with a one-sided guarantee: the estimate never under-counts,
//! and over-counts by more than `ε·W` (ε = e/width, `W` = total offered
//! weight) with probability at most `e^-depth`.  Counters are plain sums,
//! so merging two Count-Mins with the same shape/seed is **exact** —
//! element-wise addition equals the sketch of the concatenated stream.
//!
//! [`HeavyHitters`] pairs a Count-Min with a bounded space-saving candidate
//! set (Metwally et al. 2005) so the top-k keys can be *enumerated* (a bare
//! Count-Min can only be probed).  Candidates live in a `BTreeSet`, keeping
//! every operation deterministic — same inputs, same seed, same top-k list,
//! matching the repo's seeded-RNG discipline.
//!
//! **Count semantics are merge-history-independent.**  Every reported count
//! — `top_k`, `query` — is the Count-Min estimate *at query time*.  Count-Min
//! counters add exactly under merge, so the same stream yields the same
//! counts no matter whether or when partials were merged (an earlier design
//! seeded candidates with a Count-Min estimate and then accumulated exact
//! weights onto them, which made the counts depend on the merge schedule —
//! candidates now carry no counts at all).  Only the candidate *membership*
//! is history-dependent, as inherent to space-saving; heavy keys survive
//! every schedule.
//!
//! Weights are Horvitz–Thompson weights: a sampled item of stratum `i`
//! offered with weight `W_i` contributes its estimated share of the full
//! stream, so per-window top-k over a sample estimates the true per-window
//! top-k.

use std::collections::BTreeSet;

use super::hash64;

/// Weighted Count-Min sketch.
#[derive(Debug, Clone, PartialEq)]
pub struct CountMin {
    width: usize,
    depth: usize,
    /// depth × width counters, row-major.
    counters: Vec<f64>,
    /// Total offered weight W (the scale of the over-estimate bound).
    total: f64,
    /// Row-hash seed; merges require equal seeds.
    seed: u64,
}

impl CountMin {
    pub fn new(width: usize, depth: usize, seed: u64) -> Self {
        let width = width.max(8);
        let depth = depth.clamp(1, 16);
        Self { width, depth, counters: vec![0.0; width * depth], total: 0.0, seed }
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Native guarantee: over-estimate ≤ eps() · total_weight() with
    /// probability ≥ 1 − e^−depth.
    pub fn eps(&self) -> f64 {
        std::f64::consts::E / self.width as f64
    }

    /// Total offered weight W.
    pub fn total_weight(&self) -> f64 {
        self.total
    }

    /// Absolute over-estimate bound ε·W.
    pub fn over_estimate_bound(&self) -> f64 {
        self.eps() * self.total
    }

    #[inline]
    fn slot(&self, key: u64, row: usize) -> usize {
        let h = hash64(key, self.seed.wrapping_add(0x9E37 * (row as u64 + 1)));
        row * self.width + (h % self.width as u64) as usize
    }

    /// Add `weight` to `key` (non-positive / non-finite weights ignored).
    #[inline]
    pub fn add(&mut self, key: u64, weight: f64) {
        if !(weight > 0.0) || !weight.is_finite() {
            return;
        }
        for row in 0..self.depth {
            let s = self.slot(key, row);
            self.counters[s] += weight;
        }
        self.total += weight;
    }

    /// Point query: estimated total weight of `key` (never under-counts).
    pub fn query(&self, key: u64) -> f64 {
        let mut est = f64::INFINITY;
        for row in 0..self.depth {
            est = est.min(self.counters[self.slot(key, row)]);
        }
        if est.is_finite() {
            est
        } else {
            0.0
        }
    }

    /// Merge another Count-Min (same shape and seed): counters add, which is
    /// exactly the sketch of the concatenated streams.
    pub fn merge(&mut self, other: &CountMin) {
        assert_eq!(
            (self.width, self.depth, self.seed),
            (other.width, other.depth, other.seed),
            "CountMin shape/seed mismatch"
        );
        for (a, &b) in self.counters.iter_mut().zip(&other.counters) {
            *a += b;
        }
        self.total += other.total;
    }
}

/// Top-k tracker: Count-Min for counts, space-saving set for enumeration.
#[derive(Debug, Clone, PartialEq)]
pub struct HeavyHitters {
    cm: CountMin,
    /// Candidate keys only — every count (reporting *and* eviction) comes
    /// fresh from the Count-Min at use time, so nothing here can go stale
    /// or depend on merge history (see module docs).
    candidates: BTreeSet<u64>,
    capacity: usize,
    /// Lower bound on the smallest candidate count.  Candidate counts only
    /// ever grow, so a stale value stays a valid lower bound — newcomers
    /// whose estimate is below it are rejected without the O(capacity) min
    /// scan, which is the common case once the head stabilizes.
    min_floor: f64,
}

impl HeavyHitters {
    pub fn new(capacity: usize, cm_width: usize, cm_depth: usize, seed: u64) -> Self {
        Self {
            cm: CountMin::new(cm_width, cm_depth, seed),
            candidates: BTreeSet::new(),
            capacity: capacity.max(1),
            min_floor: 0.0,
        }
    }

    /// Offer one key occurrence with its Horvitz–Thompson weight.
    pub fn offer(&mut self, key: u64, weight: f64) {
        if !(weight > 0.0) || !weight.is_finite() {
            return;
        }
        self.cm.add(key, weight);
        if self.candidates.contains(&key) {
            return;
        }
        let est = self.cm.query(key);
        if self.candidates.len() < self.capacity {
            // keep the floor a true lower bound even for below-floor inserts
            // into a set that emptied below capacity (e.g. after a merge)
            self.min_floor = self.min_floor.min(est);
            self.candidates.insert(key);
            return;
        }
        // Fast reject: at or below the floor the newcomer cannot beat the
        // true minimum either.
        if est <= self.min_floor {
            return;
        }
        // Space-saving: displace the smallest candidate when the newcomer's
        // estimated count exceeds it.  Scored live against the Count-Min so
        // the eviction decision cannot depend on merge history; BTreeSet
        // iteration is key-ascending, so ties keep the lowest key —
        // deterministic.  The scan costs O(capacity · cm_depth) probes, so
        // it also harvests the *second*-lowest count: after a displacement
        // the new true minimum is min(second, newcomer), a tighter floor
        // than the evicted count, which fast-rejects more of the following
        // newcomers and keeps the scan off the common path.
        let mut min_key = 0u64;
        let mut min_count = f64::INFINITY;
        let mut second = f64::INFINITY;
        for &k in self.candidates.iter() {
            let c = self.cm.query(k);
            if c < min_count {
                second = min_count;
                min_count = c;
                min_key = k;
            } else if c < second {
                second = c;
            }
        }
        if est > min_count {
            self.candidates.remove(&min_key);
            self.candidates.insert(key);
            // every survivor scored >= second; the newcomer entered at est
            self.min_floor = second.min(est);
        } else {
            // the true minimum bounds every count from below
            self.min_floor = min_count;
        }
    }

    /// Merge another tracker: Count-Mins add exactly; the candidate set is
    /// re-scored against the merged Count-Min and truncated back to
    /// capacity, so merged top-k matches direct top-k up to the Count-Min
    /// over-estimate bound.
    pub fn merge(&mut self, other: &HeavyHitters) {
        self.cm.merge(&other.cm);
        let mut rescored: Vec<(u64, f64)> = self
            .candidates
            .union(&other.candidates)
            .map(|&k| (k, self.cm.query(k)))
            .collect();
        // keep the `capacity` largest (key asc as the deterministic tiebreak)
        rescored.sort_by(|a, b| {
            b.1.partial_cmp(&a.1).expect("finite counts").then(a.0.cmp(&b.0))
        });
        rescored.truncate(self.capacity);
        // The last kept entry is the new smallest count — an exact floor.
        self.min_floor = rescored.last().map(|&(_, c)| c).unwrap_or(0.0);
        self.candidates = rescored.into_iter().map(|(k, _)| k).collect();
    }

    /// The k heaviest keys, `(key, estimated weight)`, heaviest first
    /// (deterministic: ties break on key order).  Counts are the live
    /// Count-Min estimates, so merged and direct sketches report identical
    /// counts for any common candidate (Count-Min merge is exact).
    pub fn top_k(&self, k: usize) -> Vec<(u64, f64)> {
        let mut all: Vec<(u64, f64)> =
            self.candidates.iter().map(|&key| (key, self.cm.query(key))).collect();
        all.sort_by(|a, b| {
            b.1.partial_cmp(&a.1).expect("finite counts").then(a.0.cmp(&b.0))
        });
        all.truncate(k);
        all
    }

    /// Point query through the underlying Count-Min.
    pub fn query(&self, key: u64) -> f64 {
        self.cm.query(key)
    }

    /// Total offered weight W.
    pub fn total_weight(&self) -> f64 {
        self.cm.total_weight()
    }

    /// The Count-Min over-estimate bound ε·W each reported count carries.
    pub fn over_estimate_bound(&self) -> f64 {
        self.cm.over_estimate_bound()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

use crate::runtime::checkpoint::{Snapshot, SnapshotReader, SnapshotWriter};

impl Snapshot for CountMin {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_usize(self.width);
        w.put_usize(self.depth);
        self.counters.encode(w);
        w.put_f64(self.total);
        w.put_u64(self.seed);
    }
    fn decode(r: &mut SnapshotReader) -> crate::core::Result<Self> {
        let width = r.get_usize()?;
        let depth = r.get_usize()?;
        let counters = Vec::<f64>::decode(r)?;
        if counters.len() != width.saturating_mul(depth) {
            return Err(crate::core::Error::Io(format!(
                "CountMin snapshot has {} counters, expected {width}x{depth}",
                counters.len()
            )));
        }
        Ok(Self { width, depth, counters, total: r.get_f64()?, seed: r.get_u64()? })
    }
}

impl Snapshot for HeavyHitters {
    fn encode(&self, w: &mut SnapshotWriter) {
        self.cm.encode(w);
        // BTreeSet iterates key-ascending — a canonical, deterministic order.
        let keys: Vec<u64> = self.candidates.iter().copied().collect();
        keys.encode(w);
        w.put_usize(self.capacity);
        w.put_f64(self.min_floor);
    }
    fn decode(r: &mut SnapshotReader) -> crate::core::Result<Self> {
        let cm = CountMin::decode(r)?;
        let keys = Vec::<u64>::decode(r)?;
        Ok(Self {
            cm,
            candidates: keys.into_iter().collect(),
            capacity: r.get_usize()?,
            min_floor: r.get_f64()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn countmin_never_undercounts() {
        let mut cm = CountMin::new(256, 4, 1);
        let mut rng = Rng::seed_from_u64(2);
        let mut truth: BTreeMap<u64, f64> = BTreeMap::new();
        for _ in 0..20_000 {
            let k = rng.range_u64(0, 500);
            let w = rng.range_f64(0.5, 3.0);
            cm.add(k, w);
            *truth.entry(k).or_insert(0.0) += w;
        }
        for (&k, &t) in &truth {
            let est = cm.query(k);
            assert!(est + 1e-9 >= t, "undercount: key {k} est {est} true {t}");
            assert!(
                est <= t + 3.0 * cm.over_estimate_bound(),
                "gross overcount: key {k}"
            );
        }
    }

    #[test]
    fn countmin_merge_is_exact() {
        let mut rng = Rng::seed_from_u64(3);
        let mut whole = CountMin::new(128, 3, 9);
        let mut a = CountMin::new(128, 3, 9);
        let mut b = CountMin::new(128, 3, 9);
        for i in 0..5_000 {
            let k = rng.range_u64(0, 200);
            let w = rng.range_f64(0.1, 2.0);
            whole.add(k, w);
            if i % 2 == 0 {
                a.add(k, w);
            } else {
                b.add(k, w);
            }
        }
        a.merge(&b);
        // element-wise equal up to summation-order rounding
        for (x, y) in a.counters.iter().zip(&whole.counters) {
            assert!((x - y).abs() < 1e-6, "counter {x} vs {y}");
        }
        assert!((a.total - whole.total).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn countmin_merge_rejects_mismatch() {
        let mut a = CountMin::new(128, 3, 1);
        let b = CountMin::new(128, 3, 2);
        a.merge(&b);
    }

    #[test]
    fn top_k_recovers_zipf_heads() {
        // Zipf-ish stream over 1000 keys; the head keys must surface.
        let mut rng = Rng::seed_from_u64(4);
        let weights: Vec<f64> = (0..1000).map(|i| 1.0 / (1.0 + i as f64).powf(1.2)).collect();
        let mut hh = HeavyHitters::new(32, 1024, 4, 5);
        for _ in 0..100_000 {
            let k = rng.categorical(&weights) as u64;
            hh.offer(k, 1.0);
        }
        let top = hh.top_k(10);
        assert_eq!(top.len(), 10);
        let top_keys: Vec<u64> = top.iter().map(|&(k, _)| k).collect();
        for want in 0..3u64 {
            assert!(top_keys.contains(&want), "head key {want} missing from {top_keys:?}");
        }
        // counts sorted descending
        assert!(top.windows(2).all(|w| w[0].1 >= w[1].1));
    }

    #[test]
    fn merge_matches_direct_top_k() {
        let mut rng = Rng::seed_from_u64(6);
        let weights: Vec<f64> = (0..300).map(|i| 1.0 / (1.0 + i as f64).powf(1.5)).collect();
        let mut direct = HeavyHitters::new(32, 1024, 4, 7);
        let mut a = HeavyHitters::new(32, 1024, 4, 7);
        let mut b = HeavyHitters::new(32, 1024, 4, 7);
        for i in 0..60_000 {
            let k = rng.categorical(&weights) as u64;
            direct.offer(k, 1.0);
            if i % 2 == 0 {
                a.offer(k, 1.0);
            } else {
                b.offer(k, 1.0);
            }
        }
        a.merge(&b);
        assert_eq!(a.total_weight(), direct.total_weight());
        let top_direct: Vec<u64> = direct.top_k(5).into_iter().map(|(k, _)| k).collect();
        let top_merged: Vec<u64> = a.top_k(5).into_iter().map(|(k, _)| k).collect();
        assert_eq!(top_direct, top_merged);
        // merged counts agree with direct counts within the CM bound
        for &(k, c) in &a.top_k(5) {
            let d = direct.query(k);
            assert!((c - d).abs() <= a.over_estimate_bound() + 1e-9, "key {k}: {c} vs {d}");
        }
    }

    #[test]
    fn weighted_offers_scale_counts() {
        let mut hh = HeavyHitters::new(8, 512, 4, 8);
        for _ in 0..100 {
            hh.offer(1, 10.0); // heavy by weight
            hh.offer(2, 1.0);
        }
        let top = hh.top_k(2);
        assert_eq!(top[0].0, 1);
        assert!((top[0].1 - 1000.0).abs() < 1e-6);
        assert!((top[1].1 - 100.0).abs() < 1e-6);
    }

    #[test]
    fn capacity_bounds_candidates_and_keeps_heavies() {
        let mut hh = HeavyHitters::new(4, 512, 4, 9);
        // 100 distinct light keys then 3 heavy ones
        for k in 0..100u64 {
            hh.offer(k + 1000, 1.0);
        }
        for _ in 0..50 {
            hh.offer(1, 5.0);
            hh.offer(2, 5.0);
            hh.offer(3, 5.0);
        }
        assert!(hh.candidates.len() <= 4);
        let keys: Vec<u64> = hh.top_k(3).into_iter().map(|(k, _)| k).collect();
        assert_eq!(keys, vec![1, 2, 3]);
    }

    #[test]
    fn deterministic_for_seed() {
        let build = || {
            let mut rng = Rng::seed_from_u64(10);
            let mut hh = HeavyHitters::new(16, 512, 4, 11);
            for _ in 0..20_000 {
                hh.offer(rng.range_u64(0, 100), rng.range_f64(0.5, 2.0));
            }
            hh.top_k(10)
        };
        assert_eq!(build(), build());
    }

    #[test]
    fn merge_history_does_not_change_counts() {
        // ISSUE 5 satellite regression: the same stream, three merge
        // schedules — never merged, merged once at the end, merged every
        // quarter.  Reported top-k counts must agree within the Count-Min
        // over-bound regardless of schedule; since Count-Min counters add
        // exactly, they in fact agree to summation rounding.
        let mut rng = Rng::seed_from_u64(14);
        let weights: Vec<f64> = (0..400).map(|i| 1.0 / (1.0 + i as f64).powf(1.4)).collect();
        let stream: Vec<(u64, f64)> = (0..80_000)
            .map(|_| (rng.categorical(&weights) as u64, rng.range_f64(0.5, 2.0)))
            .collect();

        let mut direct = HeavyHitters::new(32, 1024, 4, 15);
        for &(k, w) in &stream {
            direct.offer(k, w);
        }

        // merged once: two halves
        let mut halves = HeavyHitters::new(32, 1024, 4, 15);
        {
            let mut tail = HeavyHitters::new(32, 1024, 4, 15);
            for (i, &(k, w)) in stream.iter().enumerate() {
                if i < stream.len() / 2 {
                    halves.offer(k, w);
                } else {
                    tail.offer(k, w);
                }
            }
            halves.merge(&tail);
        }

        // merged repeatedly: fold quarters into a running accumulator
        let mut running = HeavyHitters::new(32, 1024, 4, 15);
        for chunk in stream.chunks(stream.len() / 4) {
            let mut part = HeavyHitters::new(32, 1024, 4, 15);
            for &(k, w) in chunk {
                part.offer(k, w);
            }
            running.merge(&part);
        }

        for merged in [&halves, &running] {
            assert!(
                (merged.total_weight() - direct.total_weight()).abs()
                    <= 1e-6 * direct.total_weight(),
                "total weight drifted across merge schedules"
            );
            for &(k, c) in &merged.top_k(10) {
                let d = direct.query(k);
                // the hard guarantee of the issue…
                assert!(
                    (c - d).abs() <= direct.over_estimate_bound() + 1e-9,
                    "key {k}: merged count {c} vs direct {d} beyond over-bound"
                );
                // …and the sharper property the unified semantics buys:
                // counts are Count-Min estimates and Count-Min merge is
                // exact, so the schedules agree to rounding.
                assert!(
                    (c - d).abs() <= 1e-6 * d.max(1.0),
                    "key {k}: merged count {c} != direct {d}"
                );
            }
            // the head of the distribution is schedule-independent
            let tm: Vec<u64> = merged.top_k(5).into_iter().map(|(k, _)| k).collect();
            let td: Vec<u64> = direct.top_k(5).into_iter().map(|(k, _)| k).collect();
            assert_eq!(tm, td, "top-5 ranking depends on merge schedule");
        }
    }

    #[test]
    fn offer_path_counts_match_cm_estimates() {
        // The unified semantics: reported counts ARE the Count-Min
        // estimates, on the pure-offer path too.
        let mut hh = HeavyHitters::new(8, 512, 4, 16);
        for i in 0..1000u64 {
            hh.offer(i % 8, 1.0 + (i % 3) as f64);
        }
        for (k, c) in hh.top_k(8) {
            assert_eq!(c, hh.query(k), "stored count diverged from CM estimate");
        }
    }

    #[test]
    fn rejects_bad_weights() {
        let mut hh = HeavyHitters::new(4, 512, 4, 12);
        hh.offer(1, 0.0);
        hh.offer(1, -1.0);
        hh.offer(1, f64::NAN);
        assert!(hh.top_k(1).is_empty());
        assert_eq!(hh.total_weight(), 0.0);
    }
}
