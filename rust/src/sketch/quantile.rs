//! Weight-aware mergeable quantile sketch (GK/KLL-family).
//!
//! A bounded list of *equi-depth clusters* `(mean value, weight)` kept
//! sorted by value — the deterministic cousin of KLL's compactors and of
//! the merging t-digest: incoming `(value, weight)` pairs buffer until the
//! buffer fills, then buffer + clusters are sorted and re-clustered
//! greedily so no cluster (except unsplittable point masses) exceeds
//! `total_weight / c`.  Quantile and rank queries interpolate the cluster
//! midpoints, so any answer is off by at most one cluster of rank mass:
//!
//! * rank error ≤ 1/c per boundary; a direct (unmerged) sketch reports the
//!   conservative guarantee **ε = 2/c** ([`QuantileSketch::eps`]), which
//!   absorbs the re-clustering a long offer stream performs;
//! * space is O(c); offer is amortized O(log c) (buffered sort);
//! * fully deterministic — no RNG — so merge order changes answers only
//!   within ε and identical inputs give identical sketches.
//!
//! **Bounded-drift compaction.**  Merging re-clusters *summaries of
//! summaries*, and each such generation can displace cluster means by up
//! to one cluster of rank mass — a drift that a fixed ε = 2/c cannot
//! honestly cover along the deep merge chains the two-stacks pane store
//! produces at window/slide ratios in the hundreds.  Three mechanisms keep
//! the drift bounded and the reported bound honest:
//!
//! 1. merges are **lazy**: the other sketch's clusters land in the buffer
//!    and re-clustering is deferred until the buffered mass exceeds a
//!    *depth-aware budget* ([`QuantileSketch::compact_budget`] — deeper
//!    sketches buffer more before re-clustering), so a chain of `n`
//!    pairwise merges pays far fewer than `n` generations;
//! 2. the sketch tracks its **effective merge depth**
//!    ([`QuantileSketch::merge_depth`]): the number of re-cluster passes
//!    that folded previously-summarized (merged-in) mass;
//! 3. [`QuantileSketch::eps`] reports `(2 + √depth) / c` — the base
//!    guarantee plus an RMS (random-walk) model of per-generation drift,
//!    validated empirically against exact rank error at pane ratios
//!    {64, 256, 1024} by `benches/window_hotpath.rs` (BENCH_CHECK mode)
//!    and by the merge-chain property tests below.
//!
//! Weights are the Horvitz–Thompson weights of Eq. (1): an item selected
//! from stratum `i` is offered with weight `W_i`, which makes the sketch's
//! cumulative-weight axis an estimate of the *full* stream's rank axis.

/// Cap on the depth-aware buffer budget, in multiples of `clusters` (keeps
/// space O(c) no matter how deep the merge chain grows).
const MAX_BUDGET_CLUSTERS: usize = 12;

/// Mergeable equi-depth quantile summary.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantileSketch {
    /// Target number of clusters `c` (the accuracy knob).
    clusters: usize,
    /// Compressed clusters, sorted by mean value: `(mean, weight)`.
    centroids: Vec<(f64, f64)>,
    /// Uncompressed recent arrivals (raw offers and lazily-merged clusters).
    buffer: Vec<(f64, f64)>,
    /// Total offered weight (the estimated population size).
    total_weight: f64,
    /// Exact extremes (kept so q=0 / q=1 are never interpolated away).
    min: f64,
    max: f64,
    /// Re-cluster generations applied to merged-in (already summarized)
    /// mass — the drift odometer behind the honest `eps()`.
    depth: u32,
    /// True while the buffer holds clusters imported by a lazy merge (the
    /// next compress then counts as a drift generation).
    buffered_summaries: bool,
}

impl QuantileSketch {
    /// Sketch with `clusters` equi-depth clusters (≥ 8; rank error ε = 2/c).
    pub fn new(clusters: usize) -> Self {
        let clusters = clusters.max(8);
        Self {
            clusters,
            centroids: Vec::new(),
            buffer: Vec::with_capacity(4 * clusters),
            total_weight: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            depth: 0,
            buffered_summaries: false,
        }
    }

    /// Sketch configured for a target rank error `eps` (ε = 2/c ⇒ c = 2/ε).
    pub fn with_eps(eps: f64) -> Self {
        let eps = eps.clamp(1e-4, 0.25);
        Self::new((2.0 / eps).ceil() as usize)
    }

    /// The sketch's rank-error guarantee ε, honest about accumulated
    /// re-clustering: `(2 + √depth) / c`.  A direct (never-merged) sketch
    /// reports the classic 2/c; every drift generation a merge chain
    /// accumulates widens the bound by the RMS model above (see module
    /// docs — the bench validates the bound empirically at pane ratios up
    /// to 1024).
    pub fn eps(&self) -> f64 {
        (2.0 + (self.depth as f64).sqrt()) / self.clusters as f64
    }

    /// Effective merge depth: re-cluster generations applied to
    /// already-summarized mass (0 for a sketch only ever offered to).
    pub fn merge_depth(&self) -> u32 {
        self.depth
    }

    /// Buffered mass that triggers a re-cluster: `4c` for a shallow sketch
    /// (the classic offer-path threshold), growing by `c` per drift
    /// generation up to `(4 + 12)c` — deeper sketches amortize more merges
    /// per generation, so generations grow sub-linearly along a chain.
    fn compact_budget(&self) -> usize {
        self.clusters * (4 + (self.depth as usize).min(MAX_BUDGET_CLUSTERS))
    }

    /// Offer one item with its Horvitz–Thompson weight.  Non-finite values
    /// and non-positive weights are ignored.
    pub fn offer(&mut self, value: f64, weight: f64) {
        if !value.is_finite() || !(weight > 0.0) || !weight.is_finite() {
            return;
        }
        self.min = self.min.min(value);
        self.max = self.max.max(value);
        self.total_weight += weight;
        self.buffer.push((value, weight));
        if self.buffer.len() >= self.compact_budget() {
            self.compress();
        }
    }

    /// Merge another sketch into this one (A ∪ B semantics).  Lazy: the
    /// other sketch's clusters buffer here and re-clustering is deferred
    /// until the buffered mass exceeds the depth-aware budget, so merge
    /// chains pay O(chain mass / budget) drift generations, not one per
    /// merge.
    pub fn merge(&mut self, other: &QuantileSketch) {
        self.buffer.extend_from_slice(&other.centroids);
        self.buffer.extend_from_slice(&other.buffer);
        self.total_weight += other.total_weight;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.depth = self.depth.max(other.depth);
        self.buffered_summaries |= !other.centroids.is_empty() || other.buffered_summaries;
        if self.buffer.len() >= self.compact_budget() {
            self.compress();
        }
    }

    /// Total offered weight (≈ population size under HT weighting).
    pub fn total_weight(&self) -> f64 {
        self.total_weight
    }

    pub fn is_empty(&self) -> bool {
        self.total_weight <= 0.0
    }

    /// Exact minimum / maximum of all offered values.
    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Re-cluster `centroids + buffer` into ≤ ~c equi-depth clusters.
    /// Folding merged-in summaries counts as one drift generation; raw
    /// offers re-clustered against the sketch's own clusters do not (the
    /// base 2/c term of `eps()` absorbs that, as the direct-sketch rank
    /// tests pin down).
    fn compress(&mut self) {
        if self.buffer.is_empty() {
            return;
        }
        let folded_summaries = self.buffered_summaries;
        let mut all = std::mem::take(&mut self.centroids);
        all.append(&mut self.buffer);
        all.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite values"));

        let cap = self.total_weight / self.clusters as f64;
        let mut out: Vec<(f64, f64)> = Vec::with_capacity(self.clusters + 8);
        let mut acc_vw = 0.0; // Σ value·weight of the open cluster
        let mut acc_w = 0.0; // Σ weight of the open cluster
        for (v, w) in all {
            if acc_w > 0.0 && acc_w + w > cap {
                out.push((acc_vw / acc_w, acc_w));
                acc_vw = 0.0;
                acc_w = 0.0;
            }
            acc_vw += v * w;
            acc_w += w;
        }
        if acc_w > 0.0 {
            out.push((acc_vw / acc_w, acc_w));
        }
        self.centroids = out;
        if folded_summaries {
            self.depth = self.depth.saturating_add(1);
        }
        self.buffered_summaries = false;
    }

    /// Clusters + pending buffer, sorted by value (query-time view).
    /// `compress` leaves `centroids` sorted, so when the buffer is empty —
    /// the state every merged sketch is in — queries borrow it directly
    /// instead of cloning and re-sorting per call.
    fn sorted_view(&self) -> std::borrow::Cow<'_, [(f64, f64)]> {
        if self.buffer.is_empty() {
            return std::borrow::Cow::Borrowed(&self.centroids);
        }
        let mut all = self.centroids.clone();
        all.extend_from_slice(&self.buffer);
        all.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite values"));
        std::borrow::Cow::Owned(all)
    }

    /// Value at quantile `q ∈ [0, 1]` (midpoint interpolation between
    /// cluster means; exact min/max at the endpoints).  NaN when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.is_empty() {
            return f64::NAN;
        }
        let q = q.clamp(0.0, 1.0);
        let view = self.sorted_view();
        let target = q * self.total_weight;

        // Cumulative midpoints: cluster i's mean sits at rank
        // (Σ_{j<i} w_j) + w_i/2.
        let mut cum = 0.0;
        let mut prev_mid = 0.0;
        let mut prev_val = self.min;
        for &(v, w) in view.iter() {
            let mid = cum + w / 2.0;
            if target <= mid {
                let span = (mid - prev_mid).max(f64::MIN_POSITIVE);
                let t = ((target - prev_mid) / span).clamp(0.0, 1.0);
                return prev_val + t * (v - prev_val);
            }
            cum += w;
            prev_mid = mid;
            prev_val = v;
        }
        // Beyond the last midpoint: interpolate toward the exact max.
        let span = (self.total_weight - prev_mid).max(f64::MIN_POSITIVE);
        let t = ((target - prev_mid) / span).clamp(0.0, 1.0);
        prev_val + t * (self.max - prev_val)
    }

    /// Estimated rank (CDF) of `value` in [0, 1].  NaN when empty.
    pub fn rank(&self, value: f64) -> f64 {
        if self.is_empty() {
            return f64::NAN;
        }
        if value <= self.min {
            return 0.0;
        }
        if value >= self.max {
            return 1.0;
        }
        let view = self.sorted_view();
        let mut cum = 0.0;
        let mut prev_mid = 0.0;
        let mut prev_val = self.min;
        for &(v, w) in view.iter() {
            let mid = cum + w / 2.0;
            if value <= v {
                let span = (v - prev_val).max(f64::MIN_POSITIVE);
                let t = ((value - prev_val) / span).clamp(0.0, 1.0);
                return (prev_mid + t * (mid - prev_mid)) / self.total_weight;
            }
            cum += w;
            prev_mid = mid;
            prev_val = v;
        }
        1.0
    }

    /// Current number of stored clusters (space check; ≤ ~2c + buffer).
    pub fn n_clusters(&self) -> usize {
        self.centroids.len() + self.buffer.len()
    }
}

use crate::runtime::checkpoint::{Snapshot, SnapshotReader, SnapshotWriter};

/// Full-state codec: centroids *and* the uncompacted buffer travel, so a
/// restored sketch answers and compacts exactly like the original (a
/// compact-on-encode would instead advance the drift odometer).
impl Snapshot for QuantileSketch {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_usize(self.clusters);
        self.centroids.encode(w);
        self.buffer.encode(w);
        w.put_f64(self.total_weight);
        w.put_f64(self.min);
        w.put_f64(self.max);
        w.put_u32(self.depth);
        w.put_bool(self.buffered_summaries);
    }
    fn decode(r: &mut SnapshotReader) -> crate::core::Result<Self> {
        Ok(Self {
            clusters: r.get_usize()?,
            centroids: Vec::<(f64, f64)>::decode(r)?,
            buffer: Vec::<(f64, f64)>::decode(r)?,
            total_weight: r.get_f64()?,
            min: r.get_f64()?,
            max: r.get_f64()?,
            depth: r.get_u32()?,
            buffered_summaries: r.get_bool()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn exact_quantile(sorted: &[f64], q: f64) -> f64 {
        let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
        sorted[idx.min(sorted.len() - 1)]
    }

    #[test]
    fn empty_sketch_is_nan() {
        let s = QuantileSketch::new(64);
        assert!(s.quantile(0.5).is_nan());
        assert!(s.rank(1.0).is_nan());
        assert!(s.is_empty());
    }

    #[test]
    fn single_item() {
        let mut s = QuantileSketch::new(64);
        s.offer(42.0, 3.0);
        assert_eq!(s.quantile(0.0), 42.0);
        assert_eq!(s.quantile(0.5), 42.0);
        assert_eq!(s.quantile(1.0), 42.0);
        assert_eq!(s.total_weight(), 3.0);
    }

    #[test]
    fn ignores_bad_inputs() {
        let mut s = QuantileSketch::new(64);
        s.offer(f64::NAN, 1.0);
        s.offer(f64::INFINITY, 1.0);
        s.offer(1.0, 0.0);
        s.offer(1.0, -2.0);
        s.offer(1.0, f64::NAN);
        assert!(s.is_empty());
    }

    #[test]
    fn rank_error_within_eps_uniform() {
        let mut rng = Rng::seed_from_u64(7);
        let mut s = QuantileSketch::new(100); // eps = 0.02
        let mut vals: Vec<f64> = (0..50_000).map(|_| rng.range_f64(0.0, 1000.0)).collect();
        for &v in &vals {
            s.offer(v, 1.0);
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for &q in &[0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99] {
            let approx = s.quantile(q);
            // measure rank error against the exact data
            let rank = vals.iter().filter(|&&v| v <= approx).count() as f64 / vals.len() as f64;
            assert!(
                (rank - q).abs() <= s.eps(),
                "q={q}: rank {rank} vs eps {}",
                s.eps()
            );
        }
    }

    #[test]
    fn rank_error_within_eps_lognormal() {
        // Heavy-tailed input — the shape that breaks equi-width histograms.
        let mut rng = Rng::seed_from_u64(8);
        let mut s = QuantileSketch::new(100);
        let mut vals: Vec<f64> = (0..50_000).map(|_| rng.log_normal(6.9, 1.5)).collect();
        for &v in &vals {
            s.offer(v, 1.0);
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for &q in &[0.1, 0.5, 0.9, 0.99] {
            let approx = s.quantile(q);
            let rank = vals.iter().filter(|&&v| v <= approx).count() as f64 / vals.len() as f64;
            assert!((rank - q).abs() <= s.eps(), "q={q}: rank {rank}");
        }
    }

    #[test]
    fn weights_shift_the_distribution() {
        // 100 items at value 0 with weight 1, 100 at value 10 with weight 9:
        // the weighted median must be 10.
        let mut s = QuantileSketch::new(64);
        for _ in 0..100 {
            s.offer(0.0, 1.0);
            s.offer(10.0, 9.0);
        }
        assert!(s.quantile(0.5) > 5.0);
        assert!(s.quantile(0.05) < 1.0);
        // rank of the boundary reflects the 10/90 weight split
        let r = s.rank(5.0);
        assert!((r - 0.1).abs() < 0.05, "rank {r}");
    }

    #[test]
    fn merge_matches_direct_within_eps() {
        let mut rng = Rng::seed_from_u64(9);
        let vals: Vec<f64> = (0..40_000).map(|_| rng.normal(500.0, 100.0)).collect();
        let mut direct = QuantileSketch::new(100);
        let mut a = QuantileSketch::new(100);
        let mut b = QuantileSketch::new(100);
        for (i, &v) in vals.iter().enumerate() {
            direct.offer(v, 1.0);
            if i % 2 == 0 {
                a.offer(v, 1.0);
            } else {
                b.offer(v, 1.0);
            }
        }
        a.merge(&b);
        assert_eq!(a.total_weight(), direct.total_weight());
        for &q in &[0.1, 0.5, 0.9] {
            let dm = direct.quantile(q);
            let mm = a.quantile(q);
            // Compare through rank space: merged answer's rank in the direct
            // sketch must be within the combined guarantee.
            let r = direct.rank(mm);
            assert!((r - q).abs() <= 2.0 * a.eps(), "q={q}: direct {dm} merged {mm} rank {r}");
        }
    }

    #[test]
    fn space_stays_bounded() {
        let mut rng = Rng::seed_from_u64(10);
        let mut s = QuantileSketch::new(50);
        for _ in 0..100_000 {
            s.offer(rng.f64(), rng.range_f64(0.5, 2.0));
        }
        // ≤ ~2c clusters + one buffer's worth
        assert!(s.n_clusters() <= 2 * 50 + 4 * 50, "clusters {}", s.n_clusters());
    }

    #[test]
    fn deterministic_no_rng() {
        let build = || {
            let mut s = QuantileSketch::new(64);
            let mut rng = Rng::seed_from_u64(11);
            for _ in 0..10_000 {
                s.offer(rng.normal(0.0, 1.0), rng.range_f64(0.5, 4.0));
            }
            s
        };
        let (a, b) = (build(), build());
        for &q in &[0.05, 0.5, 0.95] {
            assert_eq!(a.quantile(q), b.quantile(q));
        }
    }

    #[test]
    fn endpoints_are_exact() {
        let mut s = QuantileSketch::new(32);
        let mut rng = Rng::seed_from_u64(12);
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for _ in 0..5_000 {
            let v = rng.normal(0.0, 50.0);
            lo = lo.min(v);
            hi = hi.max(v);
            s.offer(v, 1.0);
        }
        assert_eq!(s.quantile(0.0), lo);
        assert_eq!(s.quantile(1.0), hi);
        assert_eq!(s.min(), lo);
        assert_eq!(s.max(), hi);
    }

    #[test]
    fn exact_quantile_helper_sane() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(exact_quantile(&v, 0.5), 3.0);
        assert_eq!(exact_quantile(&v, 0.0), 1.0);
        assert_eq!(exact_quantile(&v, 1.0), 5.0);
    }

    #[test]
    fn direct_sketch_reports_base_eps_and_zero_depth() {
        let mut s = QuantileSketch::new(100);
        let mut rng = Rng::seed_from_u64(20);
        for _ in 0..50_000 {
            s.offer(rng.f64(), 1.0);
        }
        // A never-merged sketch keeps the classic guarantee: offer-path
        // re-clustering is absorbed by the base term, not the drift term.
        assert_eq!(s.merge_depth(), 0);
        assert_eq!(s.eps(), 2.0 / 100.0);
    }

    #[test]
    fn merge_depth_grows_and_eps_reflects_it() {
        let mut rng = Rng::seed_from_u64(21);
        let mut acc = QuantileSketch::new(64);
        for _ in 0..64 {
            let mut part = QuantileSketch::new(64);
            // enough mass per part that parts carry centroids (4c = 256)
            for _ in 0..400 {
                part.offer(rng.normal(0.0, 1.0), 1.0);
            }
            acc.merge(&part);
        }
        assert!(acc.merge_depth() > 0, "64-way chain never re-clustered summaries");
        assert!(
            acc.eps() > 2.0 / 64.0,
            "eps {} does not reflect depth {}",
            acc.eps(),
            acc.merge_depth()
        );
        // Lazy compaction bounds the generations: far fewer than one per
        // merge, and eps stays a usable bound.
        assert!(acc.merge_depth() < 64, "depth {} = one generation per merge", acc.merge_depth());
        assert!(acc.eps() < 0.25, "eps {} degenerate", acc.eps());
    }

    #[test]
    fn merge_chain_drift_within_reported_eps() {
        // ISSUE 5 satellite: a chain of n ∈ {16, 64, 256} pairwise merges
        // must stay within the *reported* eps() of the exact distribution
        // in rank space (the previous suite only covered one 2-way merge).
        for &n in &[16usize, 64, 256] {
            let mut rng = Rng::seed_from_u64(1000 + n as u64);
            let mut direct = QuantileSketch::new(100);
            let mut chain: Option<QuantileSketch> = None;
            let mut vals: Vec<f64> = Vec::with_capacity(n * 500);
            for _ in 0..n {
                let mut part = QuantileSketch::new(100);
                for _ in 0..500 {
                    // heavy-tailed: the shape where cluster smearing shows
                    let v = rng.log_normal(4.0, 1.2);
                    part.offer(v, 1.0);
                    direct.offer(v, 1.0);
                    vals.push(v);
                }
                match &mut chain {
                    None => chain = Some(part),
                    Some(c) => c.merge(&part),
                }
            }
            let chain = chain.unwrap();
            assert!(
                (chain.total_weight() - direct.total_weight()).abs()
                    <= 1e-9 * direct.total_weight(),
                "n={n}: chained weight drifted"
            );
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for &q in &[0.05, 0.25, 0.5, 0.75, 0.95, 0.99] {
                let approx = chain.quantile(q);
                let rank =
                    vals.iter().filter(|&&v| v <= approx).count() as f64 / vals.len() as f64;
                assert!(
                    (rank - q).abs() <= chain.eps(),
                    "n={n} q={q}: rank {rank} beyond reported eps {} (depth {})",
                    chain.eps(),
                    chain.merge_depth()
                );
                // …and the chain must also agree with the direct sketch in
                // rank space within the two sketches' combined guarantees.
                let dr = direct.rank(approx);
                assert!(
                    (dr - q).abs() <= chain.eps() + direct.eps(),
                    "n={n} q={q}: direct-rank {dr} disagrees beyond combined eps"
                );
            }
        }
    }

    #[test]
    fn lazy_merge_defers_compaction_under_budget() {
        // Two small raw-buffer sketches: the merge must concatenate
        // buffers without re-clustering (no centroids involved → no drift
        // generation), and queries over the unmerged buffer stay exact.
        let mut a = QuantileSketch::new(64);
        let mut b = QuantileSketch::new(64);
        for i in 0..50 {
            a.offer(i as f64, 1.0);
            b.offer(100.0 + i as f64, 1.0);
        }
        a.merge(&b);
        assert_eq!(a.merge_depth(), 0);
        assert_eq!(a.total_weight(), 100.0);
        assert_eq!(a.quantile(0.0), 0.0);
        assert_eq!(a.quantile(1.0), 149.0);
        // median sits at the boundary between the two halves
        let m = a.quantile(0.5);
        assert!((49.0..=100.0).contains(&m), "median {m}");
    }
}
