//! Weight-aware mergeable quantile sketch (GK/KLL-family).
//!
//! A bounded list of *equi-depth clusters* `(mean value, weight)` kept
//! sorted by value — the deterministic cousin of KLL's compactors and of
//! the merging t-digest: incoming `(value, weight)` pairs buffer until the
//! buffer fills, then buffer + clusters are sorted and re-clustered
//! greedily so no cluster (except unsplittable point masses) exceeds
//! `total_weight / c`.  Quantile and rank queries interpolate the cluster
//! midpoints, so any answer is off by at most one cluster of rank mass:
//!
//! * rank error ≤ 1/c per boundary; the sketch reports the conservative
//!   guarantee **ε = 2/c** ([`QuantileSketch::eps`]) to absorb repeated
//!   re-clustering during merges;
//! * space is O(c); offer is amortized O(log c) (buffered sort);
//! * fully deterministic — no RNG — so merge order changes answers only
//!   within ε and identical inputs give identical sketches.
//!
//! Weights are the Horvitz–Thompson weights of Eq. (1): an item selected
//! from stratum `i` is offered with weight `W_i`, which makes the sketch's
//! cumulative-weight axis an estimate of the *full* stream's rank axis.

/// Mergeable equi-depth quantile summary.
#[derive(Debug, Clone)]
pub struct QuantileSketch {
    /// Target number of clusters `c` (the accuracy knob).
    clusters: usize,
    /// Compressed clusters, sorted by mean value: `(mean, weight)`.
    centroids: Vec<(f64, f64)>,
    /// Uncompressed recent arrivals.
    buffer: Vec<(f64, f64)>,
    /// Total offered weight (the estimated population size).
    total_weight: f64,
    /// Exact extremes (kept so q=0 / q=1 are never interpolated away).
    min: f64,
    max: f64,
}

impl QuantileSketch {
    /// Sketch with `clusters` equi-depth clusters (≥ 8; rank error ε = 2/c).
    pub fn new(clusters: usize) -> Self {
        let clusters = clusters.max(8);
        Self {
            clusters,
            centroids: Vec::new(),
            buffer: Vec::with_capacity(4 * clusters),
            total_weight: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Sketch configured for a target rank error `eps` (ε = 2/c ⇒ c = 2/ε).
    pub fn with_eps(eps: f64) -> Self {
        let eps = eps.clamp(1e-4, 0.25);
        Self::new((2.0 / eps).ceil() as usize)
    }

    /// The sketch's rank-error guarantee ε.
    pub fn eps(&self) -> f64 {
        2.0 / self.clusters as f64
    }

    /// Offer one item with its Horvitz–Thompson weight.  Non-finite values
    /// and non-positive weights are ignored.
    pub fn offer(&mut self, value: f64, weight: f64) {
        if !value.is_finite() || !(weight > 0.0) || !weight.is_finite() {
            return;
        }
        self.min = self.min.min(value);
        self.max = self.max.max(value);
        self.total_weight += weight;
        self.buffer.push((value, weight));
        if self.buffer.len() >= 4 * self.clusters {
            self.compress();
        }
    }

    /// Merge another sketch into this one (A ∪ B semantics).
    pub fn merge(&mut self, other: &QuantileSketch) {
        self.buffer.extend_from_slice(&other.centroids);
        self.buffer.extend_from_slice(&other.buffer);
        self.total_weight += other.total_weight;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.compress();
    }

    /// Total offered weight (≈ population size under HT weighting).
    pub fn total_weight(&self) -> f64 {
        self.total_weight
    }

    pub fn is_empty(&self) -> bool {
        self.total_weight <= 0.0
    }

    /// Exact minimum / maximum of all offered values.
    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Re-cluster `centroids + buffer` into ≤ ~c equi-depth clusters.
    fn compress(&mut self) {
        if self.buffer.is_empty() {
            return;
        }
        let mut all = std::mem::take(&mut self.centroids);
        all.append(&mut self.buffer);
        all.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite values"));

        let cap = self.total_weight / self.clusters as f64;
        let mut out: Vec<(f64, f64)> = Vec::with_capacity(self.clusters + 8);
        let mut acc_vw = 0.0; // Σ value·weight of the open cluster
        let mut acc_w = 0.0; // Σ weight of the open cluster
        for (v, w) in all {
            if acc_w > 0.0 && acc_w + w > cap {
                out.push((acc_vw / acc_w, acc_w));
                acc_vw = 0.0;
                acc_w = 0.0;
            }
            acc_vw += v * w;
            acc_w += w;
        }
        if acc_w > 0.0 {
            out.push((acc_vw / acc_w, acc_w));
        }
        self.centroids = out;
    }

    /// Clusters + pending buffer, sorted by value (query-time view).
    /// `compress` leaves `centroids` sorted, so when the buffer is empty —
    /// the state every merged sketch is in — queries borrow it directly
    /// instead of cloning and re-sorting per call.
    fn sorted_view(&self) -> std::borrow::Cow<'_, [(f64, f64)]> {
        if self.buffer.is_empty() {
            return std::borrow::Cow::Borrowed(&self.centroids);
        }
        let mut all = self.centroids.clone();
        all.extend_from_slice(&self.buffer);
        all.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite values"));
        std::borrow::Cow::Owned(all)
    }

    /// Value at quantile `q ∈ [0, 1]` (midpoint interpolation between
    /// cluster means; exact min/max at the endpoints).  NaN when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.is_empty() {
            return f64::NAN;
        }
        let q = q.clamp(0.0, 1.0);
        let view = self.sorted_view();
        let target = q * self.total_weight;

        // Cumulative midpoints: cluster i's mean sits at rank
        // (Σ_{j<i} w_j) + w_i/2.
        let mut cum = 0.0;
        let mut prev_mid = 0.0;
        let mut prev_val = self.min;
        for &(v, w) in view.iter() {
            let mid = cum + w / 2.0;
            if target <= mid {
                let span = (mid - prev_mid).max(f64::MIN_POSITIVE);
                let t = ((target - prev_mid) / span).clamp(0.0, 1.0);
                return prev_val + t * (v - prev_val);
            }
            cum += w;
            prev_mid = mid;
            prev_val = v;
        }
        // Beyond the last midpoint: interpolate toward the exact max.
        let span = (self.total_weight - prev_mid).max(f64::MIN_POSITIVE);
        let t = ((target - prev_mid) / span).clamp(0.0, 1.0);
        prev_val + t * (self.max - prev_val)
    }

    /// Estimated rank (CDF) of `value` in [0, 1].  NaN when empty.
    pub fn rank(&self, value: f64) -> f64 {
        if self.is_empty() {
            return f64::NAN;
        }
        if value <= self.min {
            return 0.0;
        }
        if value >= self.max {
            return 1.0;
        }
        let view = self.sorted_view();
        let mut cum = 0.0;
        let mut prev_mid = 0.0;
        let mut prev_val = self.min;
        for &(v, w) in view.iter() {
            let mid = cum + w / 2.0;
            if value <= v {
                let span = (v - prev_val).max(f64::MIN_POSITIVE);
                let t = ((value - prev_val) / span).clamp(0.0, 1.0);
                return (prev_mid + t * (mid - prev_mid)) / self.total_weight;
            }
            cum += w;
            prev_mid = mid;
            prev_val = v;
        }
        1.0
    }

    /// Current number of stored clusters (space check; ≤ ~2c + buffer).
    pub fn n_clusters(&self) -> usize {
        self.centroids.len() + self.buffer.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn exact_quantile(sorted: &[f64], q: f64) -> f64 {
        let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
        sorted[idx.min(sorted.len() - 1)]
    }

    #[test]
    fn empty_sketch_is_nan() {
        let s = QuantileSketch::new(64);
        assert!(s.quantile(0.5).is_nan());
        assert!(s.rank(1.0).is_nan());
        assert!(s.is_empty());
    }

    #[test]
    fn single_item() {
        let mut s = QuantileSketch::new(64);
        s.offer(42.0, 3.0);
        assert_eq!(s.quantile(0.0), 42.0);
        assert_eq!(s.quantile(0.5), 42.0);
        assert_eq!(s.quantile(1.0), 42.0);
        assert_eq!(s.total_weight(), 3.0);
    }

    #[test]
    fn ignores_bad_inputs() {
        let mut s = QuantileSketch::new(64);
        s.offer(f64::NAN, 1.0);
        s.offer(f64::INFINITY, 1.0);
        s.offer(1.0, 0.0);
        s.offer(1.0, -2.0);
        s.offer(1.0, f64::NAN);
        assert!(s.is_empty());
    }

    #[test]
    fn rank_error_within_eps_uniform() {
        let mut rng = Rng::seed_from_u64(7);
        let mut s = QuantileSketch::new(100); // eps = 0.02
        let mut vals: Vec<f64> = (0..50_000).map(|_| rng.range_f64(0.0, 1000.0)).collect();
        for &v in &vals {
            s.offer(v, 1.0);
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for &q in &[0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99] {
            let approx = s.quantile(q);
            // measure rank error against the exact data
            let rank = vals.iter().filter(|&&v| v <= approx).count() as f64 / vals.len() as f64;
            assert!(
                (rank - q).abs() <= s.eps(),
                "q={q}: rank {rank} vs eps {}",
                s.eps()
            );
        }
    }

    #[test]
    fn rank_error_within_eps_lognormal() {
        // Heavy-tailed input — the shape that breaks equi-width histograms.
        let mut rng = Rng::seed_from_u64(8);
        let mut s = QuantileSketch::new(100);
        let mut vals: Vec<f64> = (0..50_000).map(|_| rng.log_normal(6.9, 1.5)).collect();
        for &v in &vals {
            s.offer(v, 1.0);
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for &q in &[0.1, 0.5, 0.9, 0.99] {
            let approx = s.quantile(q);
            let rank = vals.iter().filter(|&&v| v <= approx).count() as f64 / vals.len() as f64;
            assert!((rank - q).abs() <= s.eps(), "q={q}: rank {rank}");
        }
    }

    #[test]
    fn weights_shift_the_distribution() {
        // 100 items at value 0 with weight 1, 100 at value 10 with weight 9:
        // the weighted median must be 10.
        let mut s = QuantileSketch::new(64);
        for _ in 0..100 {
            s.offer(0.0, 1.0);
            s.offer(10.0, 9.0);
        }
        assert!(s.quantile(0.5) > 5.0);
        assert!(s.quantile(0.05) < 1.0);
        // rank of the boundary reflects the 10/90 weight split
        let r = s.rank(5.0);
        assert!((r - 0.1).abs() < 0.05, "rank {r}");
    }

    #[test]
    fn merge_matches_direct_within_eps() {
        let mut rng = Rng::seed_from_u64(9);
        let vals: Vec<f64> = (0..40_000).map(|_| rng.normal(500.0, 100.0)).collect();
        let mut direct = QuantileSketch::new(100);
        let mut a = QuantileSketch::new(100);
        let mut b = QuantileSketch::new(100);
        for (i, &v) in vals.iter().enumerate() {
            direct.offer(v, 1.0);
            if i % 2 == 0 {
                a.offer(v, 1.0);
            } else {
                b.offer(v, 1.0);
            }
        }
        a.merge(&b);
        assert_eq!(a.total_weight(), direct.total_weight());
        for &q in &[0.1, 0.5, 0.9] {
            let dm = direct.quantile(q);
            let mm = a.quantile(q);
            // Compare through rank space: merged answer's rank in the direct
            // sketch must be within the combined guarantee.
            let r = direct.rank(mm);
            assert!((r - q).abs() <= 2.0 * a.eps(), "q={q}: direct {dm} merged {mm} rank {r}");
        }
    }

    #[test]
    fn space_stays_bounded() {
        let mut rng = Rng::seed_from_u64(10);
        let mut s = QuantileSketch::new(50);
        for _ in 0..100_000 {
            s.offer(rng.f64(), rng.range_f64(0.5, 2.0));
        }
        // ≤ ~2c clusters + one buffer's worth
        assert!(s.n_clusters() <= 2 * 50 + 4 * 50, "clusters {}", s.n_clusters());
    }

    #[test]
    fn deterministic_no_rng() {
        let build = || {
            let mut s = QuantileSketch::new(64);
            let mut rng = Rng::seed_from_u64(11);
            for _ in 0..10_000 {
                s.offer(rng.normal(0.0, 1.0), rng.range_f64(0.5, 4.0));
            }
            s
        };
        let (a, b) = (build(), build());
        for &q in &[0.05, 0.5, 0.95] {
            assert_eq!(a.quantile(q), b.quantile(q));
        }
    }

    #[test]
    fn endpoints_are_exact() {
        let mut s = QuantileSketch::new(32);
        let mut rng = Rng::seed_from_u64(12);
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for _ in 0..5_000 {
            let v = rng.normal(0.0, 50.0);
            lo = lo.min(v);
            hi = hi.max(v);
            s.offer(v, 1.0);
        }
        assert_eq!(s.quantile(0.0), lo);
        assert_eq!(s.quantile(1.0), hi);
        assert_eq!(s.min(), lo);
        assert_eq!(s.max(), hi);
    }

    #[test]
    fn exact_quantile_helper_sane() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(exact_quantile(&v, 0.5), 3.0);
        assert_eq!(exact_quantile(&v, 0.0), 1.0);
        assert_eq!(exact_quantile(&v, 1.0), 5.0);
    }
}
