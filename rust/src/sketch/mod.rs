//! Mergeable, weight-aware stream summaries ("sketches").
//!
//! The estimator layer (`error::estimator`) answers *linear* queries —
//! sums, means, counts — from a Horvitz–Thompson-weighted sample.  The
//! paper's case studies also need *frequency* and *distribution* answers:
//! top-k flows, distinct sources, latency quantiles.  This module supplies
//! the three classic summaries for those workloads, all built to the same
//! contract as [`crate::error::estimator::StrataPartials`]:
//!
//! * **associatively mergeable** — `merge(sketch(A), sketch(B))` answers
//!   queries over `A ∪ B`, so per-worker / per-interval sketches combine at
//!   the window boundary with no barrier, exactly like the OASRS merge
//!   protocol in `engine::worker`;
//! * **weight-aware** — every `offer` takes the item's Horvitz–Thompson
//!   weight (Eq. 1, `W_i = C_i / N_i`), so sketches built over an
//!   OASRS/SRS/STS/weighted-reservoir *sample* estimate properties of the
//!   *full* stream;
//! * **self-bounding** — each sketch reports its native error guarantee
//!   (rank error ε, HLL relative standard error, Count-Min over-estimate
//!   bound), surfaced as a [`crate::error::ConfidenceInterval`] next to the
//!   CLT bounds of the linear queries.
//!
//! | sketch                | query                    | guarantee               |
//! |-----------------------|--------------------------|-------------------------|
//! | [`QuantileSketch`]    | `Query::Quantile(q)`     | rank error ≤ ε = 2/c    |
//! | [`HyperLogLog`]       | `Query::Distinct`        | RSE ≈ 1.04/√m           |
//! | [`HeavyHitters`]      | `Query::TopK(k)`         | over-count ≤ ε·W        |
//!
//! All three are deterministic for a fixed configuration/seed (the repo's
//! seeded-RNG discipline): the quantile sketch uses no randomness at all,
//! HLL is a pure hash fold, and heavy hitters keeps candidates in a
//! `BTreeMap` so iteration order never depends on hasher state.

pub mod heavy;
pub mod hll;
pub mod quantile;

use crate::core::{Error, Result};
use crate::error::estimator::{weight_from, weights_for};
use crate::runtime::checkpoint::{Snapshot, SnapshotReader, SnapshotWriter};
use crate::sampling::SampleResult;

pub use heavy::{CountMin, HeavyHitters};
pub use hll::HyperLogLog;
pub use quantile::QuantileSketch;

/// Tuning knobs for the per-window sketches built by
/// [`crate::query::QueryExecutor`] (defaults match the paper-scale windows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SketchParams {
    /// Equi-depth clusters kept by the quantile sketch; rank error
    /// ε = 2/clusters (default 200 → ε = 1%).
    pub quantile_clusters: usize,
    /// HyperLogLog precision p (2^p registers); RSE ≈ 1.04/2^(p/2)
    /// (default 12 → 4096 registers, ≈1.6%).
    pub hll_precision: u8,
    /// Count-Min width (over-estimate ≤ (e/width)·total-weight).
    pub cm_width: usize,
    /// Count-Min depth (failure probability e^-depth).
    pub cm_depth: usize,
    /// Space-saving candidate capacity of the heavy-hitters sketch.
    pub topk_capacity: usize,
    /// Shards a window sample is split into; one sketch per shard, merged
    /// at the end — the same no-barrier merge the per-worker samplers use,
    /// exercised on every window (the subsystem's per-worker merge
    /// contract, kept hot on the production path by design).  This costs
    /// `shards×` sketch state per window and a sequential merge; set `1`
    /// to build a single sketch directly when that overhead matters more
    /// than continuously exercising the merge path.
    pub shards: usize,
}

impl Default for SketchParams {
    fn default() -> Self {
        Self {
            quantile_clusters: 200,
            hll_precision: 12,
            cm_width: 1024,
            cm_depth: 4,
            topk_capacity: 64,
            shards: 4,
        }
    }
}

/// Full configuration of one pane/worker sketch — everything a remote
/// ingest worker needs to build a partial that merges bit-compatibly with
/// every other worker's (shape, precision, and the shared Count-Min
/// row-hash seed travel together).  This is the payload of the ingest
/// pool's sketch-registration control message: registering a sketch-backed
/// query sends the spec to every worker over the acked control plane, and
/// from then on interval closes return pre-built [`PaneSketch`] partials.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SketchSpec {
    /// Equi-depth quantile sketch with `clusters` clusters.
    Quantile { clusters: usize },
    /// HyperLogLog with precision `p` (2^p registers).
    Distinct { precision: u8 },
    /// Count-Min + space-saving top-k tracker.  `seed` is the Count-Min
    /// row-hash seed — identical across workers or the partials refuse to
    /// merge.
    TopK { capacity: usize, cm_width: usize, cm_depth: usize, seed: u64 },
}

impl SketchSpec {
    /// An empty sketch of this spec (the identity of the merge).
    pub fn empty(&self) -> PaneSketch {
        match *self {
            SketchSpec::Quantile { clusters } => {
                PaneSketch::Quantile(QuantileSketch::new(clusters))
            }
            SketchSpec::Distinct { precision } => {
                PaneSketch::Distinct(HyperLogLog::new(precision))
            }
            SketchSpec::TopK { capacity, cm_width, cm_depth, seed } => {
                PaneSketch::TopK(HeavyHitters::new(capacity, cm_width, cm_depth, seed))
            }
        }
    }

    /// Build a pane sketch from one finished interval result: every
    /// sampled item is offered with its Horvitz–Thompson weight from the
    /// interval's *own* counters (Eq. 1).  This is the fold the ingest
    /// workers run at interval close — and, run over a merged interval
    /// result, the query-side rebuild it replaces, so single-worker runs
    /// produce byte-identical sketches on either path.
    pub fn build(&self, interval: &SampleResult) -> PaneSketch {
        let mut pane = self.empty();
        pane.offer_interval(interval);
        pane
    }
}

/// One pane's (or one worker's partial) mergeable sketch, tagged by kind so
/// partials travel through channels and merge without the caller tracking
/// the query type.  Merging mismatched kinds is a logic error and panics.
#[derive(Debug, Clone, PartialEq)]
pub enum PaneSketch {
    Quantile(QuantileSketch),
    Distinct(HyperLogLog),
    TopK(HeavyHitters),
}

impl PaneSketch {
    /// Fold one interval's weighted sample into this sketch (see
    /// [`SketchSpec::build`]).  Distinct counting is
    /// multiplicity-insensitive, so its path skips the weight computation.
    pub fn offer_interval(&mut self, interval: &SampleResult) {
        match self {
            PaneSketch::Quantile(sk) => {
                let weights = weights_for(&interval.state);
                for &(s, v) in &interval.sample {
                    sk.offer(v, weight_from(&weights, s));
                }
            }
            PaneSketch::Distinct(sk) => {
                for &(_, v) in &interval.sample {
                    sk.offer(v);
                }
            }
            PaneSketch::TopK(sk) => {
                let weights = weights_for(&interval.state);
                for &(s, _) in &interval.sample {
                    sk.offer(s as u64, weight_from(&weights, s));
                }
            }
        }
    }

    /// Merge a same-kind sketch into this one (the barrier-free combine
    /// the coordinator runs over worker partials).  Panics on a kind
    /// mismatch — specs are registered process-wide, so mismatched
    /// partials indicate a protocol bug, not bad data.
    pub fn merge_same(&mut self, other: &PaneSketch) {
        match (self, other) {
            (PaneSketch::Quantile(a), PaneSketch::Quantile(b)) => a.merge(b),
            (PaneSketch::Distinct(a), PaneSketch::Distinct(b)) => a.merge(b),
            (PaneSketch::TopK(a), PaneSketch::TopK(b)) => a.merge(b),
            _ => panic!("pane sketch kind mismatch"),
        }
    }

    /// Does this sketch belong to `spec`'s family?  (Shape/seed equality
    /// is asserted by the underlying merge.)
    pub fn matches(&self, spec: &SketchSpec) -> bool {
        matches!(
            (self, spec),
            (PaneSketch::Quantile(_), SketchSpec::Quantile { .. })
                | (PaneSketch::Distinct(_), SketchSpec::Distinct { .. })
                | (PaneSketch::TopK(_), SketchSpec::TopK { .. })
        )
    }
}

impl Snapshot for SketchSpec {
    fn encode(&self, w: &mut SnapshotWriter) {
        match *self {
            SketchSpec::Quantile { clusters } => {
                w.put_u8(0);
                w.put_usize(clusters);
            }
            SketchSpec::Distinct { precision } => {
                w.put_u8(1);
                w.put_u8(precision);
            }
            SketchSpec::TopK { capacity, cm_width, cm_depth, seed } => {
                w.put_u8(2);
                w.put_usize(capacity);
                w.put_usize(cm_width);
                w.put_usize(cm_depth);
                w.put_u64(seed);
            }
        }
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        Ok(match r.get_u8()? {
            0 => SketchSpec::Quantile { clusters: r.get_usize()? },
            1 => SketchSpec::Distinct { precision: r.get_u8()? },
            2 => SketchSpec::TopK {
                capacity: r.get_usize()?,
                cm_width: r.get_usize()?,
                cm_depth: r.get_usize()?,
                seed: r.get_u64()?,
            },
            other => return Err(Error::Io(format!("unknown sketch spec tag {other}"))),
        })
    }
}

impl Snapshot for PaneSketch {
    fn encode(&self, w: &mut SnapshotWriter) {
        match self {
            PaneSketch::Quantile(sk) => {
                w.put_u8(0);
                sk.encode(w);
            }
            PaneSketch::Distinct(sk) => {
                w.put_u8(1);
                sk.encode(w);
            }
            PaneSketch::TopK(sk) => {
                w.put_u8(2);
                sk.encode(w);
            }
        }
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        Ok(match r.get_u8()? {
            0 => PaneSketch::Quantile(QuantileSketch::decode(r)?),
            1 => PaneSketch::Distinct(HyperLogLog::decode(r)?),
            2 => PaneSketch::TopK(HeavyHitters::decode(r)?),
            other => return Err(Error::Io(format!("unknown pane sketch tag {other}"))),
        })
    }
}

/// SplitMix64 finalizer — the shared 64-bit mixer behind every sketch hash
/// (same constants as `util::rng`'s seeder, salted per use).
#[inline]
pub(crate) fn hash64(x: u64, salt: u64) -> u64 {
    let mut z = x ^ salt.wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash64_mixes_and_is_deterministic() {
        assert_eq!(hash64(1, 2), hash64(1, 2));
        assert_ne!(hash64(1, 2), hash64(2, 2));
        assert_ne!(hash64(1, 2), hash64(1, 3));
        // avalanche smoke: flipping one input bit flips ~half the output bits
        let a = hash64(0x1234, 7);
        let b = hash64(0x1235, 7);
        let flipped = (a ^ b).count_ones();
        assert!((16..=48).contains(&flipped), "poor avalanche: {flipped}");
    }

    #[test]
    fn default_params_sane() {
        let p = SketchParams::default();
        assert!(p.quantile_clusters >= 8);
        assert!((4..=18).contains(&p.hll_precision));
        assert!(p.cm_width > 0 && p.cm_depth > 0);
        assert!(p.shards >= 1);
    }

    fn interval_result() -> SampleResult {
        // stratum 0 undersampled 2x (C=6, N=3), stratum 1 fully sampled
        let mut state = crate::error::estimator::StrataState::default();
        state.c[0] = 6.0;
        state.n_cap[0] = 3.0;
        state.c[1] = 1.0;
        state.n_cap[1] = 1.0;
        SampleResult { sample: vec![(0, 1.0), (0, 2.0), (0, 3.0), (1, 10.0)], state }
    }

    #[test]
    fn spec_build_applies_interval_ht_weights() {
        let r = interval_result();
        let quantile = SketchSpec::Quantile { clusters: 32 }.build(&r);
        match quantile {
            PaneSketch::Quantile(sk) => {
                // 3 items at weight 2 + 1 item at weight 1
                assert_eq!(sk.total_weight(), 7.0);
                assert_eq!(sk.min(), 1.0);
                assert_eq!(sk.max(), 10.0);
            }
            other => panic!("wrong kind: {other:?}"),
        }
        let topk = SketchSpec::TopK { capacity: 8, cm_width: 64, cm_depth: 3, seed: 9 }.build(&r);
        match topk {
            PaneSketch::TopK(hh) => {
                let top = hh.top_k(2);
                assert_eq!(top[0].0, 0);
                assert!((top[0].1 - 6.0).abs() < 1e-9, "stratum-0 mass {}", top[0].1);
                assert!((top[1].1 - 1.0).abs() < 1e-9);
            }
            other => panic!("wrong kind: {other:?}"),
        }
        let distinct = SketchSpec::Distinct { precision: 10 }.build(&r);
        match distinct {
            PaneSketch::Distinct(hll) => {
                assert!((hll.estimate() - 4.0).abs() < 0.5);
            }
            other => panic!("wrong kind: {other:?}"),
        }
    }

    #[test]
    fn spec_build_equals_empty_plus_offer_interval() {
        let r = interval_result();
        for spec in [
            SketchSpec::Quantile { clusters: 16 },
            SketchSpec::Distinct { precision: 8 },
            SketchSpec::TopK { capacity: 4, cm_width: 32, cm_depth: 2, seed: 1 },
        ] {
            let built = spec.build(&r);
            let mut manual = spec.empty();
            manual.offer_interval(&r);
            assert_eq!(built, manual);
            assert!(built.matches(&spec));
        }
    }

    #[test]
    fn pane_sketch_partials_merge_like_one_interval() {
        // Two worker partials of the same spec merge into the sketch of the
        // combined stream (HLL/CM exactly; quantile within guarantee).
        let spec = SketchSpec::Distinct { precision: 10 };
        let mut a = spec.empty();
        let mut b = spec.empty();
        let mut whole = spec.empty();
        for i in 0..1000 {
            let mut state = crate::error::estimator::StrataState::default();
            state.c[0] = 1.0;
            state.n_cap[0] = 1.0;
            let r = SampleResult { sample: vec![(0, i as f64)], state };
            whole.offer_interval(&r);
            if i % 2 == 0 {
                a.offer_interval(&r);
            } else {
                b.offer_interval(&r);
            }
        }
        a.merge_same(&b);
        assert_eq!(a, whole, "HLL partial merge must equal the union");
    }

    #[test]
    #[should_panic(expected = "kind mismatch")]
    fn pane_sketch_kind_mismatch_panics() {
        let mut q = SketchSpec::Quantile { clusters: 8 }.empty();
        let d = SketchSpec::Distinct { precision: 8 }.empty();
        q.merge_same(&d);
    }
}
