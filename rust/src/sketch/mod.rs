//! Mergeable, weight-aware stream summaries ("sketches").
//!
//! The estimator layer (`error::estimator`) answers *linear* queries —
//! sums, means, counts — from a Horvitz–Thompson-weighted sample.  The
//! paper's case studies also need *frequency* and *distribution* answers:
//! top-k flows, distinct sources, latency quantiles.  This module supplies
//! the three classic summaries for those workloads, all built to the same
//! contract as [`crate::error::estimator::StrataPartials`]:
//!
//! * **associatively mergeable** — `merge(sketch(A), sketch(B))` answers
//!   queries over `A ∪ B`, so per-worker / per-interval sketches combine at
//!   the window boundary with no barrier, exactly like the OASRS merge
//!   protocol in `engine::worker`;
//! * **weight-aware** — every `offer` takes the item's Horvitz–Thompson
//!   weight (Eq. 1, `W_i = C_i / N_i`), so sketches built over an
//!   OASRS/SRS/STS/weighted-reservoir *sample* estimate properties of the
//!   *full* stream;
//! * **self-bounding** — each sketch reports its native error guarantee
//!   (rank error ε, HLL relative standard error, Count-Min over-estimate
//!   bound), surfaced as a [`crate::error::ConfidenceInterval`] next to the
//!   CLT bounds of the linear queries.
//!
//! | sketch                | query                    | guarantee               |
//! |-----------------------|--------------------------|-------------------------|
//! | [`QuantileSketch`]    | `Query::Quantile(q)`     | rank error ≤ ε = 2/c    |
//! | [`HyperLogLog`]       | `Query::Distinct`        | RSE ≈ 1.04/√m           |
//! | [`HeavyHitters`]      | `Query::TopK(k)`         | over-count ≤ ε·W        |
//!
//! All three are deterministic for a fixed configuration/seed (the repo's
//! seeded-RNG discipline): the quantile sketch uses no randomness at all,
//! HLL is a pure hash fold, and heavy hitters keeps candidates in a
//! `BTreeMap` so iteration order never depends on hasher state.

pub mod heavy;
pub mod hll;
pub mod quantile;

pub use heavy::{CountMin, HeavyHitters};
pub use hll::HyperLogLog;
pub use quantile::QuantileSketch;

/// Tuning knobs for the per-window sketches built by
/// [`crate::query::QueryExecutor`] (defaults match the paper-scale windows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SketchParams {
    /// Equi-depth clusters kept by the quantile sketch; rank error
    /// ε = 2/clusters (default 200 → ε = 1%).
    pub quantile_clusters: usize,
    /// HyperLogLog precision p (2^p registers); RSE ≈ 1.04/2^(p/2)
    /// (default 12 → 4096 registers, ≈1.6%).
    pub hll_precision: u8,
    /// Count-Min width (over-estimate ≤ (e/width)·total-weight).
    pub cm_width: usize,
    /// Count-Min depth (failure probability e^-depth).
    pub cm_depth: usize,
    /// Space-saving candidate capacity of the heavy-hitters sketch.
    pub topk_capacity: usize,
    /// Shards a window sample is split into; one sketch per shard, merged
    /// at the end — the same no-barrier merge the per-worker samplers use,
    /// exercised on every window (the subsystem's per-worker merge
    /// contract, kept hot on the production path by design).  This costs
    /// `shards×` sketch state per window and a sequential merge; set `1`
    /// to build a single sketch directly when that overhead matters more
    /// than continuously exercising the merge path.
    pub shards: usize,
}

impl Default for SketchParams {
    fn default() -> Self {
        Self {
            quantile_clusters: 200,
            hll_precision: 12,
            cm_width: 1024,
            cm_depth: 4,
            topk_capacity: 64,
            shards: 4,
        }
    }
}

/// SplitMix64 finalizer — the shared 64-bit mixer behind every sketch hash
/// (same constants as `util::rng`'s seeder, salted per use).
#[inline]
pub(crate) fn hash64(x: u64, salt: u64) -> u64 {
    let mut z = x ^ salt.wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash64_mixes_and_is_deterministic() {
        assert_eq!(hash64(1, 2), hash64(1, 2));
        assert_ne!(hash64(1, 2), hash64(2, 2));
        assert_ne!(hash64(1, 2), hash64(1, 3));
        // avalanche smoke: flipping one input bit flips ~half the output bits
        let a = hash64(0x1234, 7);
        let b = hash64(0x1235, 7);
        let flipped = (a ^ b).count_ones();
        assert!((16..=48).contains(&flipped), "poor avalanche: {flipped}");
    }

    #[test]
    fn default_params_sane() {
        let p = SketchParams::default();
        assert!(p.quantile_clusters >= 8);
        assert!((4..=18).contains(&p.hll_precision));
        assert!(p.cm_width > 0 && p.cm_depth > 0);
        assert!(p.shards >= 1);
    }
}
