//! HyperLogLog distinct counter (Flajolet et al. 2007).
//!
//! `m = 2^p` one-byte registers; each offered key is hashed to 64 bits, the
//! top `p` bits pick a register, and the register keeps the maximum
//! "position of the first 1-bit" of the remaining bits.  The harmonic-mean
//! estimator has relative standard error ≈ `1.04/√m`; linear counting
//! covers the small-cardinality range.
//!
//! **Merge is exact**: register-wise max of two HLLs equals the HLL of the
//! union, so per-worker sketches combine at the window boundary with no
//! barrier and no approximation penalty — the strongest mergeability of the
//! three sketches.
//!
//! **Weights**: distinct counting is insensitive to multiplicity, so the
//! Horvitz–Thompson weight of a sampled item is a no-op here — an item seen
//! once counts once no matter how many originals it represents.  What
//! sampling *does* cost is items never selected at all: over a sampled
//! stream the estimate is therefore a lower bound on the true distinct
//! count (tight for heavy keys, loose for singletons), which the query
//! layer documents alongside the native RSE bound.

use super::hash64;

/// A 2^p-register HyperLogLog.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HyperLogLog {
    p: u8,
    regs: Vec<u8>,
}

impl HyperLogLog {
    /// Precision `p` in [4, 18] (m = 2^p registers, RSE ≈ 1.04/2^(p/2)).
    pub fn new(p: u8) -> Self {
        let p = p.clamp(4, 18);
        Self { p, regs: vec![0u8; 1usize << p] }
    }

    /// Number of registers m.
    pub fn m(&self) -> usize {
        self.regs.len()
    }

    pub fn precision(&self) -> u8 {
        self.p
    }

    /// Native guarantee: relative standard error ≈ 1.04/√m.
    pub fn relative_std_error(&self) -> f64 {
        1.04 / (self.m() as f64).sqrt()
    }

    /// Offer an arbitrary 64-bit key.
    #[inline]
    pub fn offer_key(&mut self, key: u64) {
        let h = hash64(key, 0x5EED_CAFE_F00D_D15C);
        let idx = (h >> (64 - self.p)) as usize;
        // rho = position of the leftmost 1 in the remaining 64-p bits.
        let w = h << self.p;
        let rho = (if w == 0 { (64 - self.p as u32) + 1 } else { w.leading_zeros() + 1 }) as u8;
        if rho > self.regs[idx] {
            self.regs[idx] = rho;
        }
    }

    /// Offer a float value (distinct by exact bit pattern; `-0.0 == +0.0`).
    #[inline]
    pub fn offer(&mut self, value: f64) {
        // normalize -0.0 so it does not count separately from 0.0
        let v = if value == 0.0 { 0.0 } else { value };
        self.offer_key(v.to_bits());
    }

    /// Merge another HLL (must share the precision). Exact union semantics.
    pub fn merge(&mut self, other: &HyperLogLog) {
        assert_eq!(self.p, other.p, "HLL precision mismatch");
        for (a, &b) in self.regs.iter_mut().zip(&other.regs) {
            if b > *a {
                *a = b;
            }
        }
    }

    /// Estimated distinct count.
    pub fn estimate(&self) -> f64 {
        let m = self.m() as f64;
        let alpha = match self.m() {
            16 => 0.673,
            32 => 0.697,
            64 => 0.709,
            _ => 0.7213 / (1.0 + 1.079 / m),
        };
        let mut inv_sum = 0.0;
        let mut zeros = 0usize;
        for &r in &self.regs {
            inv_sum += 1.0 / (1u64 << r) as f64;
            if r == 0 {
                zeros += 1;
            }
        }
        let raw = alpha * m * m / inv_sum;
        if raw <= 2.5 * m && zeros > 0 {
            // linear counting for the small range
            m * (m / zeros as f64).ln()
        } else {
            raw
        }
    }

    pub fn is_empty(&self) -> bool {
        self.regs.iter().all(|&r| r == 0)
    }

    /// Raw registers (tests / serialization).
    pub fn registers(&self) -> &[u8] {
        &self.regs
    }
}

use crate::runtime::checkpoint::{Snapshot, SnapshotReader, SnapshotWriter};

impl Snapshot for HyperLogLog {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_u8(self.p);
        w.put_bytes(&self.regs);
    }
    fn decode(r: &mut SnapshotReader) -> crate::core::Result<Self> {
        let p = r.get_u8()?;
        let regs = r.get_bytes()?;
        if !(4..=18).contains(&p) || regs.len() != 1usize << p {
            return Err(crate::core::Error::Io(format!(
                "HLL snapshot precision {p} with {} registers is inconsistent",
                regs.len()
            )));
        }
        Ok(Self { p, regs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn empty_estimates_zero() {
        let h = HyperLogLog::new(10);
        assert!(h.is_empty());
        assert_eq!(h.estimate(), 0.0);
    }

    #[test]
    fn small_range_exactish() {
        let mut h = HyperLogLog::new(12);
        for i in 0..100u64 {
            h.offer_key(i);
            h.offer_key(i); // duplicates must not count
        }
        let e = h.estimate();
        assert!((e - 100.0).abs() < 5.0, "estimate {e}");
    }

    #[test]
    fn large_range_within_rse() {
        let mut h = HyperLogLog::new(12);
        let n = 200_000u64;
        for i in 0..n {
            h.offer_key(i.wrapping_mul(0x2545F4914F6CDD1D));
        }
        let e = h.estimate();
        let rel = (e - n as f64).abs() / n as f64;
        // 4 sigma of the native RSE
        assert!(rel < 4.0 * h.relative_std_error(), "rel {rel}");
    }

    #[test]
    fn merge_is_exact_union() {
        let mut a = HyperLogLog::new(10);
        let mut b = HyperLogLog::new(10);
        let mut whole = HyperLogLog::new(10);
        let mut rng = Rng::seed_from_u64(3);
        for i in 0..20_000 {
            let k = rng.next_u64();
            whole.offer_key(k);
            if i % 2 == 0 {
                a.offer_key(k);
            } else {
                b.offer_key(k);
            }
        }
        a.merge(&b);
        // register-exact, not just close
        assert_eq!(a, whole);
    }

    #[test]
    #[should_panic]
    fn merge_rejects_precision_mismatch() {
        let mut a = HyperLogLog::new(10);
        let b = HyperLogLog::new(12);
        a.merge(&b);
    }

    #[test]
    fn float_offers_normalize_zero() {
        let mut a = HyperLogLog::new(10);
        a.offer(0.0);
        a.offer(-0.0);
        let e = a.estimate();
        assert!((e - 1.0).abs() < 0.5, "estimate {e}");
    }

    #[test]
    fn precision_clamped() {
        assert_eq!(HyperLogLog::new(1).precision(), 4);
        assert_eq!(HyperLogLog::new(30).precision(), 18);
        assert_eq!(HyperLogLog::new(12).m(), 4096);
    }

    #[test]
    fn rse_shrinks_with_precision() {
        assert!(HyperLogLog::new(14).relative_std_error() < HyperLogLog::new(10).relative_std_error());
    }
}
