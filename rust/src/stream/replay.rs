//! Trace replay tool (paper §6.1): feeds a dataset into the broker at a
//! controlled rate and searches for the saturation throughput.
//!
//! The paper's methodology: "first feed 2000 messages/second and continue to
//! increase the throughput until the system was saturated", 200 items per
//! message.  Our replay is virtual-time based: the replay offers items in
//! message-sized chunks and observes whether the consumer keeps up (queue
//! depth bounded) — saturation is the highest rate where the broker's
//! backlog stays bounded over the probe window.

use crate::core::Item;

use super::broker::{Broker, TopicConfig};

/// Items per replayed message (paper §6.1).
pub const ITEMS_PER_MESSAGE: usize = 200;

/// Rate-controlled replayer over an in-memory trace.
#[derive(Debug)]
pub struct ReplayTool {
    trace: Vec<Item>,
}

impl ReplayTool {
    pub fn new(trace: Vec<Item>) -> Self {
        Self { trace }
    }

    pub fn len(&self) -> usize {
        self.trace.len()
    }

    pub fn is_empty(&self) -> bool {
        self.trace.is_empty()
    }

    /// Replay the whole trace into `topic` as fast as the broker accepts it
    /// (used for peak-throughput runs, where the consumer side is the
    /// bottleneck being measured). Returns the number of items sent.
    pub fn replay_all(&self, broker: &Broker, topic: &str) -> crate::core::Result<usize> {
        let producer = broker.producer(topic)?;
        for chunk in self.trace.chunks(ITEMS_PER_MESSAGE) {
            for &it in chunk {
                producer.send(it)?;
            }
        }
        producer.close();
        Ok(self.trace.len())
    }

    /// Replay on a fresh topic and measure the consumer-side processing rate
    /// with `consume` (which drains the topic until termination and returns
    /// the number of items it processed).  Returns items/second achieved —
    /// the saturation throughput, since the producer is never the bottleneck
    /// on an in-process queue.
    pub fn measure_throughput<F>(
        &self,
        broker: &Broker,
        topic: &str,
        consume: F,
    ) -> crate::core::Result<f64>
    where
        F: FnOnce() -> usize + Send,
    {
        broker.create_topic(topic, TopicConfig::default())?;
        let start = std::time::Instant::now(); // lint: wall-clock latency metric only, never feeds results
        let processed = std::thread::scope(|scope| -> crate::core::Result<usize> {
            let feeder = scope.spawn(|| self.replay_all(broker, topic));
            let processed = consume();
            feeder.join().map_err(|_| crate::core::Error::Stream("feeder panicked".into()))??;
            Ok(processed)
        })?;
        let secs = start.elapsed().as_secs_f64();
        Ok(processed as f64 / secs.max(1e-9))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::broker::Broker;

    fn trace(n: usize) -> Vec<Item> {
        (0..n).map(|i| Item::new((i % 3) as u16, i as f64, i as u64)).collect()
    }

    #[test]
    fn replay_all_delivers_everything() {
        let b = Broker::new();
        b.create_topic("in", TopicConfig::default()).unwrap();
        let r = ReplayTool::new(trace(5_000));
        let mut consumer = b.consumer("in").unwrap();
        std::thread::scope(|s| {
            s.spawn(|| r.replay_all(&b, "in").unwrap());
            let mut n = 0;
            while let Some(_) = consumer.poll() {
                n += 1;
            }
            assert_eq!(n, 5_000);
        });
    }

    #[test]
    fn measure_throughput_counts_consumer_rate() {
        let b = Broker::new();
        let r = ReplayTool::new(trace(20_000));
        let mut consumer_holder: Option<crate::stream::broker::Consumer> = None;
        // create topic first so the consumer can attach inside the closure
        b.create_topic("m", TopicConfig::default()).unwrap();
        consumer_holder.replace(b.consumer("m").unwrap());
        let mut consumer = consumer_holder.take().unwrap();
        let thr = r
            .measure_throughput(&b, "m", move || {
                let mut n = 0;
                while let Some(_) = consumer.poll() {
                    n += 1;
                }
                n
            })
            .unwrap();
        assert!(thr > 10_000.0, "throughput {thr}");
    }

    #[test]
    fn empty_trace() {
        let r = ReplayTool::new(vec![]);
        assert!(r.is_empty());
        let b = Broker::new();
        b.create_topic("e", TopicConfig::default()).unwrap();
        assert_eq!(r.replay_all(&b, "e").unwrap(), 0);
    }
}
