//! Synthetic sub-stream generators (paper §5.1).
//!
//! The microbenchmarks use three sub-streams A/B/C with Gaussian or Poisson
//! value distributions and configurable arrival rates; the skew experiments
//! (§5.7) give one sub-stream 80%+ of the items.  Items carry virtual event
//! times, so experiments are deterministic and decoupled from wall-clock
//! pacing — throughput is measured as processing rate over generated items,
//! matching the paper's "increase the arrival rate until saturation"
//! methodology.

use crate::core::{EventTime, Item, StratumId};
use crate::util::rng::Rng;

/// Value distribution of one sub-stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Distribution {
    /// Gaussian(mu, sigma).
    Gaussian { mu: f64, sigma: f64 },
    /// Poisson(lambda).
    Poisson { lambda: f64 },
    /// Log-normal of the underlying normal (mu, sigma) — used by the case
    /// study datasets for heavy-tailed sizes.
    LogNormal { mu: f64, sigma: f64 },
    /// Constant value (degenerate; handy in tests).
    Constant { value: f64 },
}

impl Distribution {
    fn sample(&self, rng: &mut Rng) -> f64 {
        match *self {
            Distribution::Gaussian { mu, sigma } => rng.normal(mu, sigma),
            Distribution::Poisson { lambda } => rng.poisson(lambda) as f64,
            Distribution::LogNormal { mu, sigma } => rng.log_normal(mu, sigma),
            Distribution::Constant { value } => value,
        }
    }

    /// True mean of the distribution (for exact-value cross-checks).
    pub fn mean(&self) -> f64 {
        match *self {
            Distribution::Gaussian { mu, .. } => mu,
            Distribution::Poisson { lambda } => lambda,
            Distribution::LogNormal { mu, sigma } => (mu + sigma * sigma / 2.0).exp(),
            Distribution::Constant { value } => value,
        }
    }
}

/// Arrival-rate schedule of a sub-stream (items per second of virtual time).
#[derive(Debug, Clone)]
pub enum RateSchedule {
    /// Constant rate.
    Constant(f64),
    /// Piecewise-constant: (from_ms, rate) steps, sorted by time.
    Steps(Vec<(EventTime, f64)>),
}

impl RateSchedule {
    /// Rate at virtual time `t` (ms).
    pub fn rate_at(&self, t: EventTime) -> f64 {
        match self {
            RateSchedule::Constant(r) => *r,
            RateSchedule::Steps(steps) => {
                let mut rate = steps.first().map(|s| s.1).unwrap_or(0.0);
                for &(from, r) in steps {
                    if t >= from {
                        rate = r;
                    } else {
                        break;
                    }
                }
                rate
            }
        }
    }
}

/// One sub-stream (stratum source).
#[derive(Debug, Clone)]
pub struct SubStreamSpec {
    /// Stratum this sub-stream feeds.
    pub stratum: StratumId,
    /// Value distribution.
    pub dist: Distribution,
    /// Arrival rate schedule (items/s of virtual time).
    pub rate: RateSchedule,
}

impl SubStreamSpec {
    pub fn new(stratum: StratumId, dist: Distribution, rate_per_sec: f64) -> Self {
        Self { stratum, dist, rate: RateSchedule::Constant(rate_per_sec) }
    }
}

/// A full synthetic stream: several sub-streams merged by event time.
#[derive(Debug, Clone)]
pub struct StreamConfig {
    pub substreams: Vec<SubStreamSpec>,
    pub seed: u64,
}

impl StreamConfig {
    /// The paper's §5.1 Gaussian microbenchmark mix: A(10,5)@8000/s,
    /// B(1000,50)@2000/s, C(10000,500)@`rate_c`/s.
    pub fn gaussian_micro(rate_c: f64, seed: u64) -> Self {
        Self {
            substreams: vec![
                SubStreamSpec::new(0, Distribution::Gaussian { mu: 10.0, sigma: 5.0 }, 8000.0),
                SubStreamSpec::new(1, Distribution::Gaussian { mu: 1000.0, sigma: 50.0 }, 2000.0),
                SubStreamSpec::new(2, Distribution::Gaussian { mu: 10000.0, sigma: 500.0 }, rate_c),
            ],
            seed,
        }
    }

    /// §5.7 skewed Gaussian: A(100,10) 80%, B(1000,100) 19%, C(10000,1000) 1%
    /// of a `total_rate` stream.
    pub fn gaussian_skew(total_rate: f64, seed: u64) -> Self {
        Self {
            substreams: vec![
                SubStreamSpec::new(0, Distribution::Gaussian { mu: 100.0, sigma: 10.0 }, total_rate * 0.80),
                SubStreamSpec::new(1, Distribution::Gaussian { mu: 1000.0, sigma: 100.0 }, total_rate * 0.19),
                SubStreamSpec::new(2, Distribution::Gaussian { mu: 10000.0, sigma: 1000.0 }, total_rate * 0.01),
            ],
            seed,
        }
    }

    /// §5.7 skewed Poisson: A(λ=10) 80%, B(λ=1000) 19.99%, C(λ=1e8) 0.01%.
    pub fn poisson_skew(total_rate: f64, seed: u64) -> Self {
        Self {
            substreams: vec![
                SubStreamSpec::new(0, Distribution::Poisson { lambda: 10.0 }, total_rate * 0.80),
                SubStreamSpec::new(1, Distribution::Poisson { lambda: 1000.0 }, total_rate * 0.1999),
                SubStreamSpec::new(2, Distribution::Poisson { lambda: 1e8 }, total_rate * 0.0001),
            ],
            seed,
        }
    }
}

/// Deterministic event-time-ordered generator over a [`StreamConfig`].
#[derive(Debug)]
pub struct StreamGenerator {
    /// Per-substream state: (spec, next event time f64 ms, rng).
    subs: Vec<(SubStreamSpec, f64, Rng)>,
}

impl StreamGenerator {
    pub fn new(config: &StreamConfig) -> Self {
        let subs = config
            .substreams
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let rng = Rng::seed_from_u64(config.seed.wrapping_add(i as u64 * 0x9E37));
                (s.clone(), 0.0f64, rng)
            })
            .collect();
        Self { subs }
    }

    /// Generate all items with event time < `until_ms`, merged and sorted by
    /// event time.
    pub fn take_until(&mut self, until_ms: EventTime) -> Vec<Item> {
        let mut items = Vec::new();
        for (spec, next_t, rng) in &mut self.subs {
            loop {
                let t = *next_t;
                if t >= until_ms as f64 {
                    break;
                }
                let rate = spec.rate.rate_at(t as EventTime);
                if rate <= 0.0 {
                    // Skip forward to the next schedule step (or end).
                    *next_t = match &spec.rate {
                        RateSchedule::Steps(steps) => steps
                            .iter()
                            .map(|&(from, _)| from as f64)
                            .find(|&from| from > t)
                            .unwrap_or(until_ms as f64),
                        _ => until_ms as f64,
                    };
                    continue;
                }
                if t < until_ms as f64 {
                    items.push(Item::new(spec.stratum, spec.dist.sample(rng), t as EventTime));
                }
                // Deterministic inter-arrival: exponential spacing keeps the
                // Poisson-process character; mean 1000/rate ms.
                let gap_ms = rng.exponential(rate) * 1000.0;
                *next_t = t + gap_ms.max(1e-6);
            }
        }
        items.sort_by_key(|it| it.ts);
        items
    }

    /// Exact aggregates of a generated batch: per-stratum (count, sum).
    pub fn exact_aggregates(items: &[Item]) -> ([f64; crate::core::MAX_STRATA], [f64; crate::core::MAX_STRATA]) {
        let mut count = [0.0; crate::core::MAX_STRATA];
        let mut sum = [0.0; crate::core::MAX_STRATA];
        for it in items {
            let s = it.stratum as usize;
            if s < crate::core::MAX_STRATA {
                count[s] += 1.0;
                sum[s] += it.value;
            }
        }
        (count, sum)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_determines_item_count() {
        let cfg = StreamConfig {
            substreams: vec![SubStreamSpec::new(0, Distribution::Constant { value: 1.0 }, 1000.0)],
            seed: 1,
        };
        let mut g = StreamGenerator::new(&cfg);
        let items = g.take_until(10_000); // 10 s at 1000/s ~ 10k items
        let n = items.len() as f64;
        assert!((n - 10_000.0).abs() < 500.0, "n = {n}");
    }

    #[test]
    fn items_sorted_by_event_time() {
        let cfg = StreamConfig::gaussian_micro(100.0, 2);
        let mut g = StreamGenerator::new(&cfg);
        let items = g.take_until(2_000);
        assert!(items.windows(2).all(|w| w[0].ts <= w[1].ts));
        // all three strata present
        for s in 0..3u16 {
            assert!(items.iter().any(|i| i.stratum == s), "stratum {s} missing");
        }
    }

    #[test]
    fn take_until_is_contiguous() {
        let cfg = StreamConfig::gaussian_micro(500.0, 3);
        let mut g = StreamGenerator::new(&cfg);
        let a = g.take_until(1_000);
        let b = g.take_until(2_000);
        assert!(a.iter().all(|i| i.ts < 1_000));
        assert!(b.iter().all(|i| i.ts >= 1_000 && i.ts < 2_000) || b.is_empty());
    }

    #[test]
    fn step_schedule_changes_rate() {
        let spec = SubStreamSpec {
            stratum: 0,
            dist: Distribution::Constant { value: 1.0 },
            rate: RateSchedule::Steps(vec![(0, 100.0), (5_000, 2000.0)]),
        };
        let cfg = StreamConfig { substreams: vec![spec], seed: 4 };
        let mut g = StreamGenerator::new(&cfg);
        let first = g.take_until(5_000).len() as f64; // ~500
        let second = g.take_until(10_000).len() as f64; // ~10000
        assert!(first < 700.0, "first {first}");
        assert!(second > 8_000.0, "second {second}");
    }

    #[test]
    fn gaussian_values_have_right_mean() {
        let cfg = StreamConfig {
            substreams: vec![SubStreamSpec::new(
                0,
                Distribution::Gaussian { mu: 1000.0, sigma: 50.0 },
                5000.0,
            )],
            seed: 5,
        };
        let mut g = StreamGenerator::new(&cfg);
        let items = g.take_until(10_000);
        let mean = items.iter().map(|i| i.value).sum::<f64>() / items.len() as f64;
        assert!((mean - 1000.0).abs() < 5.0, "mean {mean}");
    }

    #[test]
    fn skew_mix_shares() {
        let cfg = StreamConfig::gaussian_skew(10_000.0, 6);
        let mut g = StreamGenerator::new(&cfg);
        let items = g.take_until(20_000);
        let (count, _) = StreamGenerator::exact_aggregates(&items);
        let total: f64 = count.iter().sum();
        let share0 = count[0] / total;
        let share2 = count[2] / total;
        assert!((share0 - 0.80).abs() < 0.02, "share0 {share0}");
        assert!((share2 - 0.01).abs() < 0.005, "share2 {share2}");
    }

    #[test]
    fn deterministic_given_seed() {
        let gen = |seed| {
            let cfg = StreamConfig::gaussian_micro(100.0, seed);
            StreamGenerator::new(&cfg).take_until(1_000)
        };
        let a = gen(7);
        let b = gen(7);
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| x == y));
        assert_ne!(gen(7).len(), 0);
    }

    #[test]
    fn poisson_large_lambda_values() {
        let cfg = StreamConfig::poisson_skew(10_000.0, 8);
        let mut g = StreamGenerator::new(&cfg);
        let items = g.take_until(5_000);
        // stratum 2 items around 1e8
        let big: Vec<&Item> = items.iter().filter(|i| i.stratum == 2).collect();
        if let Some(it) = big.first() {
            assert!((it.value - 1e8).abs() / 1e8 < 0.01);
        }
    }

    #[test]
    fn log_normal_mean() {
        let d = Distribution::LogNormal { mu: 0.0, sigma: 0.5 };
        let mut rng = Rng::seed_from_u64(9);
        let n = 100_000;
        let mean = (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64;
        assert!((mean - d.mean()).abs() / d.mean() < 0.02, "mean {mean} vs {}", d.mean());
    }
}
