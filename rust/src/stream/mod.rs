//! Input-stream layer: synthetic sub-stream generators (paper §5.1), the
//! Kafka-like in-process stream aggregator (§2.1), and the rate-controlled
//! replay tool used by the case studies (§6.1).

pub mod broker;
pub mod disorder;
pub mod generator;
pub mod replay;

pub use broker::{Broker, Consumer, Producer, TopicConfig};
pub use disorder::DisorderConfig;
pub use generator::{Distribution, RateSchedule, StreamConfig, StreamGenerator, SubStreamSpec};
pub use replay::ReplayTool;
