//! Kafka-like in-process stream aggregator (paper §2.1).
//!
//! The paper's deployment places Apache Kafka between the disjoint
//! sub-streams and the analytics system.  This module is the in-process
//! substitute: named topics with a fixed number of partitions, each
//! partition a bounded queue ([`util::channel`]) so producers experience
//! real backpressure when consumers lag; consumers attach to all partitions
//! of a topic and drain them fairly (round-robin with blocking fallback).
//!
//! Partitioning is by stratum id (`stratum % partitions`), which preserves
//! per-sub-stream FIFO order — the property OASRS's per-stratum counters
//! rely on.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::core::{Error, Item, Result};
use crate::util::channel::{bounded, Receiver, Sender, TryRecvError};

/// Configuration of one topic.
#[derive(Debug, Clone)]
pub struct TopicConfig {
    /// Number of partitions (parallelism of the topic).
    pub partitions: usize,
    /// Per-partition buffer capacity (items) — the backpressure bound.
    pub capacity: usize,
}

impl Default for TopicConfig {
    fn default() -> Self {
        Self { partitions: 4, capacity: 64 * 1024 }
    }
}

struct Topic {
    senders: Vec<Sender<Item>>,
    receivers: Vec<Receiver<Item>>,
    produced: Arc<AtomicU64>,
    consumed: Arc<AtomicU64>,
}

/// The in-process stream aggregator.
///
/// Topics live in a `BTreeMap` (not `HashMap`): any future "for each
/// topic" operation — shutdown sweeps, stats dumps, snapshot manifests —
/// iterates in name order regardless of creation order, so broker-fed
/// results can never pick up iteration-order nondeterminism (lint rule
/// D1; pinned by `topic_iteration_is_insertion_order_invariant` below).
#[derive(Default)]
pub struct Broker {
    topics: Mutex<BTreeMap<String, Arc<Topic>>>,
}

impl std::fmt::Debug for Broker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let names: Vec<String> = self.topic_names();
        f.debug_struct("Broker").field("topics", &names).finish()
    }
}

impl Broker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Create a topic (idempotent: re-creating with any config returns the
    /// existing topic).
    pub fn create_topic(&self, name: &str, config: TopicConfig) -> Result<()> {
        let mut topics = self.topics.lock().unwrap();
        if topics.contains_key(name) {
            return Ok(());
        }
        let mut senders = Vec::new();
        let mut receivers = Vec::new();
        for _ in 0..config.partitions.max(1) {
            let (tx, rx) = bounded(config.capacity.max(1));
            senders.push(tx);
            receivers.push(rx);
        }
        topics.insert(
            name.to_string(),
            Arc::new(Topic {
                senders,
                receivers,
                produced: Arc::new(AtomicU64::new(0)),
                consumed: Arc::new(AtomicU64::new(0)),
            }),
        );
        Ok(())
    }

    fn topic(&self, name: &str) -> Result<Arc<Topic>> {
        self.topics
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| Error::Stream(format!("unknown topic {name:?}")))
    }

    /// Producer handle for a topic.
    pub fn producer(&self, name: &str) -> Result<Producer> {
        let t = self.topic(name)?;
        Ok(Producer { topic: t })
    }

    /// Consumer handle attached to every partition of a topic.
    pub fn consumer(&self, name: &str) -> Result<Consumer> {
        let t = self.topic(name)?;
        Ok(Consumer { topic: t, next: 0 })
    }

    /// Close a topic (producers fail afterwards; consumers drain).
    pub fn close_topic(&self, name: &str) -> Result<()> {
        let t = self.topic(name)?;
        for s in &t.senders {
            s.close();
        }
        Ok(())
    }

    /// (produced, consumed) counters of a topic.
    ///
    /// Both counters are `Relaxed` atomics bumped on independent threads, so
    /// a reader racing an in-flight hand-off can observe `consumed >
    /// produced` for an instant; consumers of these stats must not subtract
    /// them directly — use [`Broker::lag`], which saturates at zero.
    pub fn stats(&self, name: &str) -> Result<(u64, u64)> {
        let t = self.topic(name)?;
        // ordering: statistical counters only (see doc comment above) — no
        // slot or queue access is derived from these reads.
        Ok((t.produced.load(Ordering::Relaxed), t.consumed.load(Ordering::Relaxed)))
    }

    /// Consumer lag of a topic: `produced - consumed`, saturating at zero so
    /// the momentary `consumed > produced` race (and the empty-topic case)
    /// reads as 0 instead of wrapping to ~2^64.
    pub fn lag(&self, name: &str) -> Result<u64> {
        let (produced, consumed) = self.stats(name)?;
        let lag = produced.saturating_sub(consumed);
        crate::obs_gauge!("broker_lag", "consumer lag of the most recently polled topic")
            .set(lag as f64);
        Ok(lag)
    }

    /// Total items currently buffered in a topic (queue depth).
    pub fn depth(&self, name: &str) -> Result<usize> {
        let t = self.topic(name)?;
        Ok(t.receivers.iter().map(|r| r.len()).sum())
    }

    /// Topic names in deterministic (lexicographic) order — the order every
    /// whole-broker sweep observes, independent of creation order.
    pub fn topic_names(&self) -> Vec<String> {
        self.topics.lock().unwrap().keys().cloned().collect()
    }

    /// Per-topic (produced, consumed) counters in deterministic name order.
    pub fn all_stats(&self) -> Vec<(String, u64, u64)> {
        let topics = self.topics.lock().unwrap();
        topics
            .iter()
            .map(|(name, t)| {
                // ordering: statistical counters (see `stats` docs); reads
                // race in-flight hand-offs by design.
                (name.clone(), t.produced.load(Ordering::Relaxed), t.consumed.load(Ordering::Relaxed))
            })
            .collect()
    }
}

/// Producer: publishes items, partitioned by stratum (per-stratum FIFO).
pub struct Producer {
    topic: Arc<Topic>,
}

impl std::fmt::Debug for Producer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Producer")
            .field("partitions", &self.topic.senders.len())
            .finish_non_exhaustive()
    }
}

impl Producer {
    /// Blocking publish (backpressure when the partition is full).
    pub fn send(&self, item: Item) -> Result<()> {
        let p = item.stratum as usize % self.topic.senders.len();
        self.topic.senders[p]
            .send(item)
            .map_err(|_| Error::Stream("topic closed".into()))?;
        // ordering: monotonic stats counter; the channel send above is the
        // synchronizing hand-off, the counter never gates data access.
        self.topic.produced.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Non-blocking publish; `false` when the partition is full.
    pub fn try_send(&self, item: Item) -> Result<bool> {
        let p = item.stratum as usize % self.topic.senders.len();
        match self.topic.senders[p].try_send(item) {
            Ok(()) => {
                // ordering: monotonic stats counter (see `send`).
                self.topic.produced.fetch_add(1, Ordering::Relaxed);
                Ok(true)
            }
            Err(_) => Ok(false),
        }
    }

    /// Close the topic from the producer side.
    pub fn close(&self) {
        for s in &self.topic.senders {
            s.close();
        }
    }
}

impl Clone for Producer {
    fn clone(&self) -> Self {
        Self { topic: self.topic.clone() }
    }
}

/// Consumer: drains all partitions of a topic fairly.
pub struct Consumer {
    topic: Arc<Topic>,
    next: usize,
}

impl std::fmt::Debug for Consumer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Consumer")
            .field("partitions", &self.topic.receivers.len())
            .field("next", &self.next)
            .finish_non_exhaustive()
    }
}

impl Consumer {
    /// Blocking poll across partitions; `None` when the topic is closed and
    /// fully drained.
    pub fn poll(&mut self) -> Option<Item> {
        let n = self.topic.receivers.len();
        loop {
            let mut all_closed = true;
            for i in 0..n {
                let idx = (self.next + i) % n;
                match self.topic.receivers[idx].try_recv() {
                    Ok(item) => {
                        self.next = (idx + 1) % n;
                        // ordering: monotonic stats counter; the channel
                        // recv is the synchronizing hand-off.
                        self.topic.consumed.fetch_add(1, Ordering::Relaxed);
                        return Some(item);
                    }
                    Err(TryRecvError::Empty) => {
                        all_closed = false;
                    }
                    Err(TryRecvError::Closed) => {}
                }
            }
            if all_closed {
                return None;
            }
            // Nothing ready: yield briefly rather than spin hot.
            std::thread::yield_now();
        }
    }

    /// Drain up to `max` currently-buffered items without blocking.
    pub fn poll_batch(&mut self, max: usize) -> Vec<Item> {
        let mut out = Vec::new();
        let n = self.topic.receivers.len();
        'outer: for _ in 0..n {
            let idx = self.next;
            self.next = (self.next + 1) % n;
            while let Ok(item) = self.topic.receivers[idx].try_recv() {
                // ordering: monotonic stats counter (see `poll`).
                self.topic.consumed.fetch_add(1, Ordering::Relaxed);
                out.push(item);
                if out.len() >= max {
                    break 'outer;
                }
            }
        }
        out
    }

    /// True once the topic is closed and all partitions are drained.
    pub fn is_terminated(&self) -> bool {
        self.topic.receivers.iter().all(|r| r.is_terminated())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item(s: u16, v: f64) -> Item {
        Item::new(s, v, 0)
    }

    #[test]
    fn produce_consume_roundtrip() {
        let b = Broker::new();
        b.create_topic("t", TopicConfig::default()).unwrap();
        let p = b.producer("t").unwrap();
        let mut c = b.consumer("t").unwrap();
        for i in 0..100 {
            p.send(item((i % 4) as u16, i as f64)).unwrap();
        }
        p.close();
        let mut got = Vec::new();
        while let Some(it) = c.poll() {
            got.push(it.value);
        }
        assert_eq!(got.len(), 100);
        let (prod, cons) = b.stats("t").unwrap();
        assert_eq!((prod, cons), (100, 100));
    }

    #[test]
    fn per_stratum_fifo_preserved() {
        let b = Broker::new();
        b.create_topic("t", TopicConfig { partitions: 3, capacity: 1024 }).unwrap();
        let p = b.producer("t").unwrap();
        let mut c = b.consumer("t").unwrap();
        for i in 0..300 {
            p.send(item((i % 5) as u16, i as f64)).unwrap();
        }
        p.close();
        let mut per_stratum: BTreeMap<u16, Vec<f64>> = BTreeMap::new();
        while let Some(it) = c.poll() {
            per_stratum.entry(it.stratum).or_default().push(it.value);
        }
        for (_, vals) in per_stratum {
            assert!(vals.windows(2).all(|w| w[0] < w[1]), "per-stratum order violated");
        }
    }

    #[test]
    fn unknown_topic_errors() {
        let b = Broker::new();
        assert!(b.producer("nope").is_err());
        assert!(b.consumer("nope").is_err());
        assert!(b.lag("nope").is_err());
    }

    #[test]
    fn lag_saturates_and_tracks_depth() {
        let b = Broker::new();
        b.create_topic("t", TopicConfig::default()).unwrap();
        // empty topic: zero lag, not underflow
        assert_eq!(b.lag("t").unwrap(), 0);
        let p = b.producer("t").unwrap();
        for i in 0..10 {
            p.send(item(0, i as f64)).unwrap();
        }
        assert_eq!(b.lag("t").unwrap(), 10);
        let mut c = b.consumer("t").unwrap();
        for _ in 0..10 {
            c.poll();
        }
        assert_eq!(b.lag("t").unwrap(), 0);
    }

    #[test]
    fn backpressure_try_send() {
        let b = Broker::new();
        b.create_topic("t", TopicConfig { partitions: 1, capacity: 2 }).unwrap();
        let p = b.producer("t").unwrap();
        assert!(p.try_send(item(0, 1.0)).unwrap());
        assert!(p.try_send(item(0, 2.0)).unwrap());
        assert!(!p.try_send(item(0, 3.0)).unwrap()); // full
        assert_eq!(b.depth("t").unwrap(), 2);
    }

    #[test]
    fn multi_producer_multi_consumer_conservation() {
        let b = Arc::new(Broker::new());
        b.create_topic("t", TopicConfig { partitions: 4, capacity: 256 }).unwrap();
        let n_producers = 4;
        let per = 5_000;
        let mut handles = Vec::new();
        for pid in 0..n_producers {
            let p = b.producer("t").unwrap();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    p.send(Item::new((i % 8) as u16, (pid * per + i) as f64, 0)).unwrap();
                }
            }));
        }
        let consumed = Arc::new(AtomicU64::new(0));
        let mut consumers = Vec::new();
        for _ in 0..2 {
            let mut c = b.consumer("t").unwrap();
            let consumed = consumed.clone();
            consumers.push(std::thread::spawn(move || {
                while let Some(_) = c.poll() {
                    consumed.fetch_add(1, Ordering::Relaxed);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        b.close_topic("t").unwrap();
        for c in consumers {
            c.join().unwrap();
        }
        assert_eq!(consumed.load(Ordering::Relaxed), (n_producers * per) as u64);
    }

    #[test]
    fn poll_batch_drains_quickly() {
        let b = Broker::new();
        b.create_topic("t", TopicConfig { partitions: 2, capacity: 1024 }).unwrap();
        let p = b.producer("t").unwrap();
        for i in 0..50 {
            p.send(item((i % 2) as u16, i as f64)).unwrap();
        }
        let mut c = b.consumer("t").unwrap();
        let batch = c.poll_batch(100);
        assert_eq!(batch.len(), 50);
        assert!(c.poll_batch(10).is_empty());
    }

    #[test]
    fn topic_iteration_is_insertion_order_invariant() {
        // Pinned determinism audit (lint rule D1): whole-broker sweeps must
        // observe the same topic order and the same per-topic results no
        // matter the order topics were created in.  With the old HashMap
        // this held only by accident of the per-process hash seed.
        let names = ["zeta", "alpha", "mid", "aa", "zz"];
        let mut reversed = names;
        reversed.reverse();

        let mut sweeps = Vec::new();
        for order in [names.as_slice(), reversed.as_slice()] {
            let b = Broker::new();
            for (i, name) in order.iter().enumerate() {
                b.create_topic(name, TopicConfig { partitions: 2, capacity: 64 }).unwrap();
                let p = b.producer(name).unwrap();
                // distinct per-topic item counts so produced counters differ
                for v in 0..=i {
                    p.send(item(0, v as f64)).unwrap();
                }
            }
            // The sweep result must depend only on the topic *set*, not on
            // creation order: name-sorted with matching produced counts.
            let stats = b.all_stats();
            let expect_names: Vec<&str> = {
                let mut s = order.to_vec();
                s.sort_unstable();
                s
            };
            let got_names: Vec<&str> = stats.iter().map(|(n, _, _)| n.as_str()).collect();
            assert_eq!(got_names, expect_names);
            assert_eq!(b.topic_names(), expect_names);
            sweeps.push(
                stats
                    .into_iter()
                    .map(|(n, prod, cons)| {
                        // produced count was keyed to creation index; map it
                        // back through the name so both orders agree
                        let idx = order.iter().position(|x| *x == n).unwrap() as u64;
                        (n, prod, cons, idx + 1)
                    })
                    .collect::<Vec<_>>(),
            );
        }
        // every topic produced exactly (creation index + 1) items
        for sweep in &sweeps {
            for (name, prod, cons, expect) in sweep {
                assert_eq!(prod, expect, "topic {name} produced count");
                assert_eq!(*cons, 0);
            }
        }
        // and the name-keyed view is identical across creation orders
        let a: Vec<(String, u64)> = sweeps[0].iter().map(|(n, p, _, _)| (n.clone(), *p)).collect();
        let b: Vec<(String, u64)> = sweeps[1].iter().map(|(n, p, _, _)| (n.clone(), *p)).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn create_topic_idempotent() {
        let b = Broker::new();
        b.create_topic("t", TopicConfig { partitions: 2, capacity: 8 }).unwrap();
        let p = b.producer("t").unwrap();
        p.send(item(0, 1.0)).unwrap();
        // re-create must not wipe buffered data
        b.create_topic("t", TopicConfig { partitions: 9, capacity: 9 }).unwrap();
        assert_eq!(b.depth("t").unwrap(), 1);
    }
}
