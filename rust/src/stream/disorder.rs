//! Seeded disorder injection: turn an event-time-sorted trace into the
//! out-of-order *arrival* sequence a real source would deliver.
//!
//! Every existing generator emits sorted by `ts`; real million-user streams
//! are neither sorted nor complete (NEXMark/YSB-style skew, ROADMAP
//! direction 2).  [`DisorderConfig`] models that as a per-item network
//! delay: each item's arrival key is `ts + delay`, where `delay` is a
//! seeded uniform draw in `[0, max_skew_ms]` plus, for a seeded
//! `straggler_fraction` of items, a fixed `straggler_delay_ms` burst.
//! Sorting (stably) by arrival key yields the shuffled sequence — event
//! times are untouched, only the order changes, so the disordered trace is
//! the *same multiset* as the input.
//!
//! The shuffle is bounded: an item can arrive at most
//! [`DisorderConfig::max_delay_ms`] behind the newest event time already
//! delivered.  Pair it with an [`crate::window::EventTimeConfig`] whose
//! `watermark_skew_ms + allowed_lateness_ms >= max_delay_ms()` and the
//! event-time router drops nothing — the seeded disorder-equivalence
//! contract `rust/tests/event_time.rs` pins.  Push `max_delay_ms` past
//! that budget and the overflow becomes deterministic beyond-lateness
//! drops, which is how the drop-accounting tests construct exact counts.

use crate::core::{EventTime, Item};
use crate::util::rng::Rng;

/// Seeded disorder wrapper over any in-order item trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DisorderConfig {
    /// Uniform per-item arrival delay bound (virtual ms): each item is
    /// delayed by a seeded draw in `[0, max_skew_ms]`.
    pub max_skew_ms: EventTime,
    /// Fraction of items additionally delayed by `straggler_delay_ms`
    /// (straggler bursts — the long tail of a retrying client).
    pub straggler_fraction: f64,
    /// Extra delay applied to straggler items (virtual ms).
    pub straggler_delay_ms: EventTime,
    /// Seed for the delay draws (independent of the trace's seed).
    pub seed: u64,
}

impl DisorderConfig {
    /// Bounded-skew shuffle only: uniform delays in `[0, max_skew_ms]`,
    /// no stragglers.
    pub fn bounded_skew(max_skew_ms: EventTime, seed: u64) -> Self {
        Self { max_skew_ms, straggler_fraction: 0.0, straggler_delay_ms: 0, seed }
    }

    /// Add a straggler burst: `fraction` of items take an extra
    /// `delay_ms` to arrive.
    pub fn with_stragglers(mut self, fraction: f64, delay_ms: EventTime) -> Self {
        self.straggler_fraction = fraction.clamp(0.0, 1.0);
        self.straggler_delay_ms = delay_ms;
        self
    }

    /// Worst-case arrival delay (virtual ms) this config can inject — the
    /// disorder bound the watermark heuristic must budget for.
    pub fn max_delay_ms(&self) -> EventTime {
        let straggler = if self.straggler_fraction > 0.0 { self.straggler_delay_ms } else { 0 };
        self.max_skew_ms.saturating_add(straggler)
    }

    /// Produce the arrival-order sequence: same items, same `ts` values,
    /// stably reordered by seeded per-item delay.  Deterministic for a
    /// given `(input, config)` pair.
    pub fn apply(&self, items: &[Item]) -> Vec<Item> {
        let mut rng = Rng::seed_from_u64(self.seed);
        let mut keyed: Vec<(EventTime, usize)> = items
            .iter()
            .enumerate()
            .map(|(i, item)| {
                let mut delay = if self.max_skew_ms > 0 {
                    rng.range_usize(0, self.max_skew_ms as usize + 1) as EventTime
                } else {
                    0
                };
                if self.straggler_fraction > 0.0 && rng.f64() < self.straggler_fraction {
                    delay = delay.saturating_add(self.straggler_delay_ms);
                }
                (item.ts.saturating_add(delay), i)
            })
            .collect();
        // Stable by construction: ties in arrival time keep input order.
        keyed.sort_by_key(|&(arrival, i)| (arrival, i));
        keyed.into_iter().map(|(_, i)| items[i]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sorted_trace(n: u64) -> Vec<Item> {
        (0..n).map(|t| Item::new((t % 3) as u16, t as f64, t * 7)).collect()
    }

    fn multiset_key(items: &[Item]) -> Vec<(u64, u16, u64)> {
        let mut k: Vec<(u64, u16, u64)> =
            items.iter().map(|i| (i.ts, i.stratum, i.value.to_bits())).collect();
        k.sort_unstable();
        k
    }

    #[test]
    fn shuffle_preserves_the_multiset() {
        let trace = sorted_trace(5_000);
        let shuffled = DisorderConfig::bounded_skew(400, 9).apply(&trace);
        assert_eq!(shuffled.len(), trace.len());
        assert_eq!(multiset_key(&shuffled), multiset_key(&trace));
        assert_ne!(shuffled, trace, "skew 400 over 7ms gaps must reorder something");
    }

    #[test]
    fn disorder_respects_the_skew_bound() {
        // Bounded-skew contract: no item arrives more than max_delay_ms
        // behind the newest event time already delivered.
        let trace = sorted_trace(5_000);
        for cfg in [
            DisorderConfig::bounded_skew(250, 3),
            DisorderConfig::bounded_skew(100, 4).with_stragglers(0.05, 900),
        ] {
            let shuffled = cfg.apply(&trace);
            let mut max_seen = 0u64;
            for item in &shuffled {
                assert!(
                    item.ts.saturating_add(cfg.max_delay_ms()) >= max_seen,
                    "item ts {} arrived {} behind the frontier (bound {})",
                    item.ts,
                    max_seen - item.ts,
                    cfg.max_delay_ms()
                );
                max_seen = max_seen.max(item.ts);
            }
        }
    }

    #[test]
    fn zero_skew_is_identity_on_sorted_input() {
        let trace = sorted_trace(1_000);
        assert_eq!(DisorderConfig::bounded_skew(0, 1).apply(&trace), trace);
    }

    #[test]
    fn apply_is_seed_deterministic() {
        let trace = sorted_trace(3_000);
        let cfg = DisorderConfig::bounded_skew(300, 11).with_stragglers(0.1, 500);
        assert_eq!(cfg.apply(&trace), cfg.apply(&trace));
        let other = DisorderConfig { seed: 12, ..cfg };
        assert_ne!(other.apply(&trace), cfg.apply(&trace));
    }

    #[test]
    fn stragglers_extend_the_delay_bound() {
        let plain = DisorderConfig::bounded_skew(100, 5);
        assert_eq!(plain.max_delay_ms(), 100);
        assert_eq!(plain.with_stragglers(0.2, 400).max_delay_ms(), 500);
        // zero-fraction stragglers do not budge the bound
        assert_eq!(plain.with_stragglers(0.0, 400).max_delay_ms(), 100);
    }
}
