//! Sliding-window computation (paper §2.2, §3.1).
//!
//! Both engines sample per *interval* — the batch interval on the batched
//! engine (Spark samples at every batch), the slide interval on the
//! pipelined engine (Flink samples at every slide) — and a window result
//! merges the intervals covering the window span.  The merge is the same
//! associative combine as distributed execution: arrival counters and
//! capacities add, samples concatenate.
//!
//! The assembler also carries exact per-interval aggregates (per-stratum
//! count/sum computed before sampling) so accuracy loss can be measured per
//! window without a second native run.

use std::collections::VecDeque;

use crate::core::{EventTime, MAX_STRATA};
use crate::sampling::oasrs::merge_worker_results;
use crate::sampling::SampleResult;

/// Exact per-interval aggregates (pre-sampling ground truth).
#[derive(Debug, Clone, Copy)]
pub struct ExactAgg {
    pub count: [f64; MAX_STRATA],
    pub sum: [f64; MAX_STRATA],
}

impl Default for ExactAgg {
    fn default() -> Self {
        Self { count: [0.0; MAX_STRATA], sum: [0.0; MAX_STRATA] }
    }
}

impl ExactAgg {
    #[inline]
    pub fn add(&mut self, stratum: u16, value: f64) {
        let s = stratum as usize;
        if s < MAX_STRATA {
            self.count[s] += 1.0;
            self.sum[s] += value;
        }
    }

    pub fn merge(&mut self, other: &ExactAgg) {
        for s in 0..MAX_STRATA {
            self.count[s] += other.count[s];
            self.sum[s] += other.sum[s];
        }
    }

    pub fn total_sum(&self) -> f64 {
        self.sum.iter().sum()
    }

    pub fn total_count(&self) -> f64 {
        self.count.iter().sum()
    }
}

/// Window parameters (time-based, per design assumption 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WindowConfig {
    /// Window length w in virtual ms.
    pub size_ms: EventTime,
    /// Slide δ in virtual ms (== size for tumbling windows).
    pub slide_ms: EventTime,
}

impl WindowConfig {
    pub fn new(size_ms: EventTime, slide_ms: EventTime) -> Self {
        assert!(size_ms > 0 && slide_ms > 0, "window sizes must be positive");
        assert!(
            size_ms % slide_ms == 0,
            "window size must be a multiple of the slide ({size_ms} % {slide_ms})"
        );
        Self { size_ms, slide_ms }
    }

    /// The paper's default: w = 10 s, δ = 5 s.
    pub fn paper_default() -> Self {
        Self::new(10_000, 5_000)
    }

    /// Tumbling window of the given size.
    pub fn tumbling(size_ms: EventTime) -> Self {
        Self::new(size_ms, size_ms)
    }

    /// Number of slide intervals per window.
    pub fn intervals_per_window(&self) -> usize {
        (self.size_ms / self.slide_ms) as usize
    }
}

/// A completed window's merged sample + ground truth.
#[derive(Debug, Clone)]
pub struct WindowSample {
    /// Window end (exclusive) in virtual ms.
    pub end_ms: EventTime,
    /// Window start (inclusive).
    pub start_ms: EventTime,
    /// Merged per-interval sample results.
    pub result: SampleResult,
    /// Merged exact aggregates over the same span.
    pub exact: ExactAgg,
    /// Number of intervals merged (fewer at stream start).
    pub intervals: usize,
}

/// Assembles per-interval [`SampleResult`]s into sliding windows.
///
/// `interval_ms` is the sampling cadence (batch interval or slide interval);
/// it must divide the slide.  A window is emitted whenever an interval ends
/// on a slide boundary.
#[derive(Debug)]
pub struct WindowAssembler {
    config: WindowConfig,
    interval_ms: EventTime,
    /// Ring of the most recent interval results (newest at back).
    ring: VecDeque<(SampleResult, ExactAgg)>,
    /// End time of the next interval to close.
    next_interval_end: EventTime,
}

impl WindowAssembler {
    /// Assembler sampling at the slide cadence (pipelined engine).
    pub fn new(config: WindowConfig) -> Self {
        Self::with_interval(config, config.slide_ms)
    }

    /// Assembler sampling every `interval_ms` (batched engine).
    pub fn with_interval(config: WindowConfig, interval_ms: EventTime) -> Self {
        assert!(interval_ms > 0, "interval must be positive");
        assert!(
            config.slide_ms % interval_ms == 0,
            "slide ({}) must be a multiple of the interval ({})",
            config.slide_ms,
            interval_ms
        );
        let ring_cap = (config.size_ms / interval_ms) as usize;
        Self {
            config,
            interval_ms,
            ring: VecDeque::with_capacity(ring_cap),
            next_interval_end: interval_ms,
        }
    }

    pub fn config(&self) -> WindowConfig {
        self.config
    }

    pub fn interval_ms(&self) -> EventTime {
        self.interval_ms
    }

    /// End time of the interval currently being filled.
    pub fn current_interval_end(&self) -> EventTime {
        self.next_interval_end
    }

    /// Push the result of the interval ending at `current_interval_end()`.
    /// Returns the completed window when that end lies on a slide boundary.
    pub fn push_interval(
        &mut self,
        result: SampleResult,
        exact: ExactAgg,
    ) -> Option<WindowSample> {
        let cap = (self.config.size_ms / self.interval_ms) as usize;
        if self.ring.len() == cap {
            self.ring.pop_front();
        }
        self.ring.push_back((result, exact));

        let end = self.next_interval_end;
        self.next_interval_end += self.interval_ms;

        if end % self.config.slide_ms != 0 {
            return None;
        }

        let merged = merge_worker_results(self.ring.iter().map(|(r, _)| r.clone()).collect());
        let mut exact_merged = ExactAgg::default();
        for (_, e) in &self.ring {
            exact_merged.merge(e);
        }
        let intervals = self.ring.len();
        Some(WindowSample {
            end_ms: end,
            start_ms: end.saturating_sub(intervals as EventTime * self.interval_ms),
            result: merged,
            exact: exact_merged,
            intervals,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result_with(c0: f64, sample_n: usize) -> SampleResult {
        let mut r = SampleResult::default();
        r.state.c[0] = c0;
        r.state.n_cap[0] = c0.min(10.0);
        for i in 0..sample_n {
            r.sample.push((0, i as f64));
        }
        r
    }

    fn exact_with(c0: f64) -> ExactAgg {
        let mut e = ExactAgg::default();
        e.count[0] = c0;
        e.sum[0] = c0 * 2.0;
        e
    }

    #[test]
    fn tumbling_window_single_interval() {
        let mut w = WindowAssembler::new(WindowConfig::tumbling(1000));
        let ws = w.push_interval(result_with(100.0, 10), exact_with(100.0)).unwrap();
        assert_eq!(ws.intervals, 1);
        assert_eq!(ws.start_ms, 0);
        assert_eq!(ws.end_ms, 1000);
        assert_eq!(ws.result.state.c[0], 100.0);
        assert_eq!(ws.exact.total_sum(), 200.0);
        let ws2 = w.push_interval(result_with(50.0, 5), exact_with(50.0)).unwrap();
        assert_eq!(ws2.result.state.c[0], 50.0); // previous interval evicted
        assert_eq!(ws2.start_ms, 1000);
    }

    #[test]
    fn sliding_window_merges_k_intervals() {
        // w = 10 s, δ = 5 s -> 2 intervals per window at slide cadence.
        let mut w = WindowAssembler::new(WindowConfig::paper_default());
        let w1 = w.push_interval(result_with(100.0, 10), exact_with(100.0)).unwrap();
        assert_eq!(w1.intervals, 1); // partial first window
        let w2 = w.push_interval(result_with(200.0, 20), exact_with(200.0)).unwrap();
        assert_eq!(w2.intervals, 2);
        assert_eq!(w2.result.state.c[0], 300.0);
        assert_eq!(w2.result.sample.len(), 30);
        assert_eq!(w2.exact.total_count(), 300.0);
        let w3 = w.push_interval(result_with(400.0, 40), exact_with(400.0)).unwrap();
        assert_eq!(w3.result.state.c[0], 600.0); // intervals 2+3
        assert_eq!(w3.start_ms, 5_000);
        assert_eq!(w3.end_ms, 15_000);
    }

    #[test]
    fn sub_slide_intervals_emit_on_slide_boundary_only() {
        // w = 2 s, δ = 1 s, batch interval 250 ms -> emit every 4th push.
        let cfg = WindowConfig::new(2_000, 1_000);
        let mut w = WindowAssembler::with_interval(cfg, 250);
        let mut emitted = Vec::new();
        for i in 0..16 {
            if let Some(ws) = w.push_interval(result_with(10.0, 1), exact_with(10.0)) {
                emitted.push((i, ws));
            }
        }
        assert_eq!(emitted.len(), 4);
        assert_eq!(emitted[0].0, 3); // 4th push = 1000 ms
        let full = &emitted[1].1; // window ending 2000 ms covers 8 intervals
        assert_eq!(full.intervals, 8);
        assert_eq!(full.result.state.c[0], 80.0);
    }

    #[test]
    fn capacities_add_across_intervals() {
        let mut w = WindowAssembler::new(WindowConfig::new(2000, 1000));
        w.push_interval(result_with(100.0, 10), ExactAgg::default());
        let ws = w.push_interval(result_with(100.0, 10), ExactAgg::default()).unwrap();
        assert_eq!(ws.result.state.n_cap[0], 20.0);
        for s in 1..MAX_STRATA {
            assert_eq!(ws.result.state.c[s], 0.0);
        }
    }

    #[test]
    #[should_panic]
    fn size_must_be_multiple_of_slide() {
        WindowConfig::new(1000, 300);
    }

    #[test]
    #[should_panic]
    fn interval_must_divide_slide() {
        WindowAssembler::with_interval(WindowConfig::new(1000, 1000), 300);
    }

    #[test]
    fn interval_clock_advances() {
        let mut w = WindowAssembler::new(WindowConfig::paper_default());
        assert_eq!(w.current_interval_end(), 5_000);
        w.push_interval(SampleResult::default(), ExactAgg::default());
        assert_eq!(w.current_interval_end(), 10_000);
    }

    #[test]
    fn exact_agg_arithmetic() {
        let mut e = ExactAgg::default();
        e.add(0, 5.0);
        e.add(0, 7.0);
        e.add(3, 1.0);
        e.add(99, 100.0); // out of range, dropped
        assert_eq!(e.total_count(), 3.0);
        assert_eq!(e.total_sum(), 13.0);
        let mut f = ExactAgg::default();
        f.add(3, 2.0);
        e.merge(&f);
        assert_eq!(e.sum[3], 3.0);
    }
}
