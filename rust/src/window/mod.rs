//! Sliding-window computation (paper §2.2, §3.1) — incremental pane store.
//!
//! Both engines sample per *interval* (pane): the batch interval on the
//! batched engine (Spark samples at every batch), the slide interval on the
//! pipelined engine (Flink samples at every slide).  A window result is the
//! associative combine of the panes covering the window span — the same
//! merge law as distributed execution (arrival counters and capacities add,
//! samples concatenate), now expressed once as the [`Mergeable`] trait.
//!
//! **The seed's assembler re-merged every pane on every slide** —
//! O(window/slide) combines and a full clone of every pane's sample per
//! emission, the hot spot ROADMAP flagged once ingest went zero-allocation.
//! This module replaces it with incremental structures sized to the payload:
//!
//! * **Window sample** (grows with the span): maintained *in place* in a
//!   pane-ordered deque — push appends the new pane's items, eviction
//!   drains the expired pane's prefix.  Per slide that is O(items of panes
//!   evicted + items of the pane pushed), independent of the window/slide
//!   ratio; emission borrows the deque ([`WindowView`]) instead of cloning
//!   the span.  Counter blocks (`C_i`, `N_i`) and the exact ground truth
//!   are *re-folded in ring order* at emission — a deliberate exception
//!   (2 cache lines per pane): addition of arbitrary `f64` sums is only
//!   associative up to rounding, so folding in the seed's exact order keeps
//!   window results **byte-identical** to the reference path for every
//!   sampler and trace (the equivalence tests below assert it), at a cost
//!   that is noise next to the sample churn.
//! * **Constant-size [`Mergeable`] payloads** (sketches, counter blocks):
//!   the two-stacks [`PaneStore`] gives O(panes evicted + 1) amortized
//!   merges per slide — the structure behind pane-level sketch windowing
//!   (`query::SketchWindow`) and the `window_hotpath` bench's flatness
//!   guarantee.
//!
//! The seed implementation is kept, verbatim, behind `cfg(test)` as
//! [`reference::ReferenceAssembler`]: the property tests drive both
//! assemblers with identical seeded pane streams and assert byte-identical
//! windows.

use std::collections::VecDeque;

use crate::core::{EventTime, MAX_STRATA};
use crate::error::estimator::StrataState;
use crate::sampling::SampleResult;

pub mod event_time;
pub mod mergeable;
pub mod pane;

pub use event_time::{DropLedger, EventTimeConfig, EventTimeRouter, EventTimeSlicer};
pub use mergeable::Mergeable;
pub use pane::PaneStore;

/// Exact per-interval aggregates (pre-sampling ground truth).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExactAgg {
    pub count: [f64; MAX_STRATA],
    pub sum: [f64; MAX_STRATA],
}

impl Default for ExactAgg {
    fn default() -> Self {
        Self { count: [0.0; MAX_STRATA], sum: [0.0; MAX_STRATA] }
    }
}

impl ExactAgg {
    #[inline]
    pub fn add(&mut self, stratum: u16, value: f64) {
        let s = stratum as usize;
        if s < MAX_STRATA {
            self.count[s] += 1.0;
            self.sum[s] += value;
        }
    }

    pub fn merge(&mut self, other: &ExactAgg) {
        for s in 0..MAX_STRATA {
            self.count[s] += other.count[s];
            self.sum[s] += other.sum[s];
        }
    }

    pub fn total_sum(&self) -> f64 {
        self.sum.iter().sum()
    }

    pub fn total_count(&self) -> f64 {
        self.count.iter().sum()
    }
}

/// Window parameters (time-based, per design assumption 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WindowConfig {
    /// Window length w in virtual ms.
    pub size_ms: EventTime,
    /// Slide δ in virtual ms (== size for tumbling windows).
    pub slide_ms: EventTime,
}

impl WindowConfig {
    pub fn new(size_ms: EventTime, slide_ms: EventTime) -> Self {
        assert!(size_ms > 0 && slide_ms > 0, "window sizes must be positive");
        assert!(
            size_ms % slide_ms == 0,
            "window size must be a multiple of the slide ({size_ms} % {slide_ms})"
        );
        Self { size_ms, slide_ms }
    }

    /// The paper's default: w = 10 s, δ = 5 s.
    pub fn paper_default() -> Self {
        Self::new(10_000, 5_000)
    }

    /// Tumbling window of the given size.
    pub fn tumbling(size_ms: EventTime) -> Self {
        Self::new(size_ms, size_ms)
    }

    /// Number of slide intervals per window.
    pub fn intervals_per_window(&self) -> usize {
        (self.size_ms / self.slide_ms) as usize
    }
}

/// A completed window's merged sample + ground truth (owned snapshot; the
/// engines use the zero-copy [`WindowView`] instead).
#[derive(Debug, Clone)]
pub struct WindowSample {
    /// Window end (exclusive) in virtual ms.
    pub end_ms: EventTime,
    /// Window start (inclusive).
    pub start_ms: EventTime,
    /// Merged per-interval sample results.
    pub result: SampleResult,
    /// Merged exact aggregates over the same span.
    pub exact: ExactAgg,
    /// Number of intervals merged (fewer at stream start).
    pub intervals: usize,
}

/// Zero-copy view of a completed window: the sample is borrowed from the
/// assembler's pane deque (as up to two contiguous slices, in pane order)
/// instead of cloned per slide.  Counter blocks and ground truth are small
/// `Copy` values.
#[derive(Debug, Clone, Copy)]
pub struct WindowView<'a> {
    /// Window end (exclusive) in virtual ms.
    pub end_ms: EventTime,
    /// Window start (inclusive).
    pub start_ms: EventTime,
    /// Number of intervals merged (fewer at stream start).
    pub intervals: usize,
    /// The window's sample in pane order, as the deque's two halves (both
    /// empty when the assembler spilled the sample — see
    /// [`WindowAssembler::spill_samples`]).
    parts: [&'a [(u16, f64)]; 2],
    /// Items the window's panes sampled — equal to the parts' total length
    /// except under spill, where the items are gone but the count (from
    /// the per-pane summaries) is still exact.
    sample_len: usize,
    /// Merged per-stratum counters over the span (ring-order fold).
    pub state: StrataState,
    /// Merged exact aggregates over the span (ring-order fold).
    pub exact: ExactAgg,
}

impl<'a> WindowView<'a> {
    /// View over a single already-merged result (adapter for callers that
    /// hold a [`SampleResult`], e.g. `QueryExecutor::execute`).
    pub fn from_result(result: &'a SampleResult) -> Self {
        Self {
            end_ms: 0,
            start_ms: 0,
            intervals: 1,
            parts: [result.sample.as_slice(), &[]],
            sample_len: result.sample.len(),
            state: result.state,
            exact: ExactAgg::default(),
        }
    }

    /// The sample's contiguous halves, in pane order.
    pub fn parts(&self) -> [&'a [(u16, f64)]; 2] {
        self.parts
    }

    /// Iterate the window sample in pane order.
    pub fn iter(
        &self,
    ) -> std::iter::Chain<std::slice::Iter<'a, (u16, f64)>, std::slice::Iter<'a, (u16, f64)>>
    {
        self.parts[0].iter().chain(self.parts[1].iter())
    }

    /// Items the window's panes sampled (see the field docs for spill).
    pub fn sample_len(&self) -> usize {
        self.sample_len
    }

    /// Items that arrived in the window span.
    pub fn arrived(&self) -> f64 {
        self.state.total_c()
    }

    /// Materialize an owned [`SampleResult`] (tests / compatibility; the
    /// production path never does this per slide).
    pub fn to_sample_result(&self) -> SampleResult {
        let mut sample = Vec::with_capacity(self.sample_len());
        sample.extend_from_slice(self.parts[0]);
        sample.extend_from_slice(self.parts[1]);
        SampleResult { sample, state: self.state }
    }
}

/// Shared handle for the assembler's per-push merge timing (both return
/// paths of `push_interval_view` record into it).
fn window_merge_hist() -> crate::obs::Histogram {
    crate::obs_histogram!(
        "window_merge_ns",
        "one assembler push: pane append/evict + emission fold when due"
    )
}

/// Per-pane bookkeeping the assembler keeps for eviction and emission.
#[derive(Debug, Clone, Copy)]
struct PaneMeta {
    sample_len: usize,
    state: StrataState,
    exact: ExactAgg,
}

/// Assembles per-interval [`SampleResult`]s into sliding windows,
/// incrementally (see module docs for the cost model).
///
/// `interval_ms` is the sampling cadence (batch interval or slide
/// interval); it must divide the slide.  A window is emitted whenever an
/// interval ends on a slide boundary.
#[derive(Debug)]
pub struct WindowAssembler {
    config: WindowConfig,
    interval_ms: EventTime,
    /// Ring of pane metadata (newest at back).
    panes: VecDeque<PaneMeta>,
    /// Concatenated window sample in pane order: extended on push, drained
    /// on eviction — never re-merged.
    sample: VecDeque<(u16, f64)>,
    /// Monotone mask of strata that have ever carried a non-zero counter or
    /// ground-truth entry: the emission fold skips the all-zero strata (a
    /// skipped stratum folds to exactly `+0.0`, which is also what adding
    /// its `+0.0` entries in order would produce, so byte-identity holds).
    active: [bool; MAX_STRATA],
    /// Spill mode: pane samples are dropped at push and the window carries
    /// only the constant-size pane summaries (counters, ground truth,
    /// sample length).  For sketch-backed queries over pre-built pane
    /// sketches the sample deque is dead weight — at window/slide ratios
    /// in the thousands it is the dominant state — so the engines switch
    /// it off past `EngineConfig::spill_ratio`.  Views then emit empty
    /// `parts` (never consumed on that path) while `sample_len`, counters,
    /// and ground truth stay exact.
    spill: bool,
    /// End time of the next interval to close.
    next_interval_end: EventTime,
}

impl WindowAssembler {
    /// Assembler sampling at the slide cadence (pipelined engine).
    pub fn new(config: WindowConfig) -> Self {
        Self::with_interval(config, config.slide_ms)
    }

    /// Assembler sampling every `interval_ms` (batched engine).
    pub fn with_interval(config: WindowConfig, interval_ms: EventTime) -> Self {
        assert!(interval_ms > 0, "interval must be positive");
        assert!(
            config.slide_ms % interval_ms == 0,
            "slide ({}) must be a multiple of the interval ({})",
            config.slide_ms,
            interval_ms
        );
        let ring_cap = (config.size_ms / interval_ms) as usize;
        Self {
            config,
            interval_ms,
            panes: VecDeque::with_capacity(ring_cap),
            sample: VecDeque::new(),
            active: [false; MAX_STRATA],
            spill: false,
            next_interval_end: interval_ms,
        }
    }

    /// Switch to spill mode (drop pane samples, keep pane summaries) —
    /// must be called before the first pane arrives.  See the field docs
    /// for when this is sound.
    pub fn spill_samples(&mut self) {
        assert!(self.panes.is_empty(), "spill mode must be set before the first pane");
        self.spill = true;
    }

    /// True when pane samples are being spilled to summaries.
    pub fn spills(&self) -> bool {
        self.spill
    }

    pub fn config(&self) -> WindowConfig {
        self.config
    }

    pub fn interval_ms(&self) -> EventTime {
        self.interval_ms
    }

    /// Panes a full window spans.
    pub fn panes_per_window(&self) -> usize {
        (self.config.size_ms / self.interval_ms) as usize
    }

    /// End time of the interval currently being filled.
    pub fn current_interval_end(&self) -> EventTime {
        self.next_interval_end
    }

    /// Push the result of the interval ending at `current_interval_end()`;
    /// returns a zero-copy view of the completed window when that end lies
    /// on a slide boundary.
    ///
    /// Cost per call: O(items evicted + items pushed) deque work plus, on
    /// emission, a fold of the active strata's counters per pane in the
    /// ring (the exactness anchor — see module docs); never a re-merge or
    /// clone of the span's sample.
    pub fn push_interval_view(
        &mut self,
        result: SampleResult,
        exact: ExactAgg,
    ) -> Option<WindowView<'_>> {
        let t0 = crate::obs::metrics_enabled().then(std::time::Instant::now); // lint: wall-clock latency metric only, never feeds results
        if self.spill {
            crate::obs_counter!(
                "window_spill_events_total",
                "panes whose sample was dropped to a summary (spill mode)"
            )
            .inc();
        }
        let cap = self.panes_per_window();
        if self.panes.len() == cap {
            let old = self.panes.pop_front().expect("ring non-empty at cap");
            if !self.spill {
                self.sample.drain(..old.sample_len);
            }
        }
        let meta = PaneMeta {
            sample_len: result.sample.len(),
            state: result.state,
            exact,
        };
        for s in 0..MAX_STRATA {
            if meta.state.c[s] != 0.0
                || meta.state.n_cap[s] != 0.0
                || meta.exact.count[s] != 0.0
                || meta.exact.sum[s] != 0.0
            {
                self.active[s] = true;
            }
        }
        if !self.spill {
            self.sample.extend(result.sample);
        }
        self.panes.push_back(meta);

        let end = self.next_interval_end;
        self.next_interval_end += self.interval_ms;

        if end % self.config.slide_ms != 0 {
            if let Some(t0) = t0 {
                window_merge_hist().record_elapsed(t0);
            }
            return None;
        }

        // Ring-order fold of the constant-size metas, restricted to the
        // active strata.  Each per-stratum accumulator sees its additions
        // in exactly the reference re-merge's pane order, so counters AND
        // ground-truth sums come out byte-identical (f64 addition is not
        // associative; order is the spec).  Skipped strata are `+0.0`
        // everywhere, which is also what folding them would produce.
        let mut state = StrataState::default();
        let mut exact_merged = ExactAgg::default();
        for s in 0..MAX_STRATA {
            if !self.active[s] {
                continue;
            }
            for meta in &self.panes {
                state.c[s] += meta.state.c[s];
                state.n_cap[s] += meta.state.n_cap[s];
                exact_merged.count[s] += meta.exact.count[s];
                exact_merged.sum[s] += meta.exact.sum[s];
            }
        }

        crate::obs_counter!(
            "window_pane_merges_total",
            "pane summaries folded into emitted windows (assembler + pane store)"
        )
        .add(self.panes.len() as u64);
        if let Some(t0) = t0 {
            window_merge_hist().record_elapsed(t0);
        }
        let intervals = self.panes.len();
        let sample_len = if self.spill {
            self.panes.iter().map(|m| m.sample_len).sum()
        } else {
            self.sample.len()
        };
        let (a, b) = self.sample.as_slices();
        Some(WindowView {
            end_ms: end,
            start_ms: end.saturating_sub(intervals as EventTime * self.interval_ms),
            intervals,
            parts: [a, b],
            sample_len,
            state,
            exact: exact_merged,
        })
    }

    /// Owned-snapshot variant of [`Self::push_interval_view`] (clones the
    /// window sample; kept for tests and simple callers).
    pub fn push_interval(
        &mut self,
        result: SampleResult,
        exact: ExactAgg,
    ) -> Option<WindowSample> {
        let view = self.push_interval_view(result, exact)?;
        Some(WindowSample {
            end_ms: view.end_ms,
            start_ms: view.start_ms,
            result: view.to_sample_result(),
            exact: view.exact,
            intervals: view.intervals,
        })
    }
}

use crate::core::Result;
use crate::runtime::checkpoint::{Snapshot, SnapshotReader, SnapshotWriter};

impl Snapshot for ExactAgg {
    fn encode(&self, w: &mut SnapshotWriter) {
        self.count.encode(w);
        self.sum.encode(w);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        Ok(Self {
            count: <[f64; MAX_STRATA]>::decode(r)?,
            sum: <[f64; MAX_STRATA]>::decode(r)?,
        })
    }
}

impl Snapshot for WindowConfig {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_u64(self.size_ms);
        w.put_u64(self.slide_ms);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        // Bypass `new`'s asserts: a corrupt frame must surface as an error,
        // and a frame that decodes got its invariants checked at write time.
        Ok(Self { size_ms: r.get_u64()?, slide_ms: r.get_u64()? })
    }
}

impl Snapshot for PaneMeta {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_usize(self.sample_len);
        self.state.encode(w);
        self.exact.encode(w);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        Ok(Self {
            sample_len: r.get_usize()?,
            state: StrataState::decode(r)?,
            exact: ExactAgg::decode(r)?,
        })
    }
}

/// Whole-assembler codec: pane ring, concatenated sample deque (in pane
/// order), active-strata mask, spill flag, and the interval clock — a
/// restored assembler emits the same windows at the same boundaries,
/// byte-for-byte, because the ring-order fold sees the identical metas.
impl Snapshot for WindowAssembler {
    fn encode(&self, w: &mut SnapshotWriter) {
        self.config.encode(w);
        w.put_u64(self.interval_ms);
        w.put_usize(self.panes.len());
        for meta in &self.panes {
            meta.encode(w);
        }
        w.put_usize(self.sample.len());
        for item in &self.sample {
            item.encode(w);
        }
        self.active.encode(w);
        w.put_bool(self.spill);
        w.put_u64(self.next_interval_end);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        let config = WindowConfig::decode(r)?;
        let interval_ms = r.get_u64()?;
        let n_panes = r.get_usize()?;
        if n_panes > r.remaining() {
            return Err(crate::core::Error::Io(format!(
                "assembler snapshot pane count {n_panes} exceeds remaining payload"
            )));
        }
        let mut panes = VecDeque::with_capacity(n_panes);
        for _ in 0..n_panes {
            panes.push_back(PaneMeta::decode(r)?);
        }
        let n_sample = r.get_usize()?;
        if n_sample > r.remaining() {
            return Err(crate::core::Error::Io(format!(
                "assembler snapshot sample length {n_sample} exceeds remaining payload"
            )));
        }
        let mut sample = VecDeque::with_capacity(n_sample);
        for _ in 0..n_sample {
            sample.push_back(<(u16, f64)>::decode(r)?);
        }
        Ok(Self {
            config,
            interval_ms,
            panes,
            sample,
            active: <[bool; MAX_STRATA]>::decode(r)?,
            spill: r.get_bool()?,
            next_interval_end: r.get_u64()?,
        })
    }
}

/// The seed's merge-all-intervals assembler, kept verbatim as the
/// equivalence oracle for the incremental pane path (tests only).
#[cfg(test)]
pub(crate) mod reference {
    use super::*;
    use crate::sampling::oasrs::merge_worker_results;

    #[derive(Debug)]
    pub struct ReferenceAssembler {
        config: WindowConfig,
        interval_ms: EventTime,
        ring: VecDeque<(SampleResult, ExactAgg)>,
        next_interval_end: EventTime,
    }

    impl ReferenceAssembler {
        pub fn with_interval(config: WindowConfig, interval_ms: EventTime) -> Self {
            let ring_cap = (config.size_ms / interval_ms) as usize;
            Self {
                config,
                interval_ms,
                ring: VecDeque::with_capacity(ring_cap),
                next_interval_end: interval_ms,
            }
        }

        pub fn push_interval(
            &mut self,
            result: SampleResult,
            exact: ExactAgg,
        ) -> Option<WindowSample> {
            let cap = (self.config.size_ms / self.interval_ms) as usize;
            if self.ring.len() == cap {
                self.ring.pop_front();
            }
            self.ring.push_back((result, exact));

            let end = self.next_interval_end;
            self.next_interval_end += self.interval_ms;

            if end % self.config.slide_ms != 0 {
                return None;
            }

            let merged =
                merge_worker_results(self.ring.iter().map(|(r, _)| r.clone()).collect());
            let mut exact_merged = ExactAgg::default();
            for (_, e) in &self.ring {
                exact_merged.merge(e);
            }
            let intervals = self.ring.len();
            Some(WindowSample {
                end_ms: end,
                start_ms: end.saturating_sub(intervals as EventTime * self.interval_ms),
                result: merged,
                exact: exact_merged,
                intervals,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result_with(c0: f64, sample_n: usize) -> SampleResult {
        let mut r = SampleResult::default();
        r.state.c[0] = c0;
        r.state.n_cap[0] = c0.min(10.0);
        for i in 0..sample_n {
            r.sample.push((0, i as f64));
        }
        r
    }

    fn exact_with(c0: f64) -> ExactAgg {
        let mut e = ExactAgg::default();
        e.count[0] = c0;
        e.sum[0] = c0 * 2.0;
        e
    }

    #[test]
    fn tumbling_window_single_interval() {
        let mut w = WindowAssembler::new(WindowConfig::tumbling(1000));
        let ws = w.push_interval(result_with(100.0, 10), exact_with(100.0)).unwrap();
        assert_eq!(ws.intervals, 1);
        assert_eq!(ws.start_ms, 0);
        assert_eq!(ws.end_ms, 1000);
        assert_eq!(ws.result.state.c[0], 100.0);
        assert_eq!(ws.exact.total_sum(), 200.0);
        let ws2 = w.push_interval(result_with(50.0, 5), exact_with(50.0)).unwrap();
        assert_eq!(ws2.result.state.c[0], 50.0); // previous interval evicted
        assert_eq!(ws2.start_ms, 1000);
    }

    #[test]
    fn sliding_window_merges_k_intervals() {
        // w = 10 s, δ = 5 s -> 2 intervals per window at slide cadence.
        let mut w = WindowAssembler::new(WindowConfig::paper_default());
        let w1 = w.push_interval(result_with(100.0, 10), exact_with(100.0)).unwrap();
        assert_eq!(w1.intervals, 1); // partial first window
        let w2 = w.push_interval(result_with(200.0, 20), exact_with(200.0)).unwrap();
        assert_eq!(w2.intervals, 2);
        assert_eq!(w2.result.state.c[0], 300.0);
        assert_eq!(w2.result.sample.len(), 30);
        assert_eq!(w2.exact.total_count(), 300.0);
        let w3 = w.push_interval(result_with(400.0, 40), exact_with(400.0)).unwrap();
        assert_eq!(w3.result.state.c[0], 600.0); // intervals 2+3
        assert_eq!(w3.start_ms, 5_000);
        assert_eq!(w3.end_ms, 15_000);
    }

    #[test]
    fn sub_slide_intervals_emit_on_slide_boundary_only() {
        // w = 2 s, δ = 1 s, batch interval 250 ms -> emit every 4th push.
        let cfg = WindowConfig::new(2_000, 1_000);
        let mut w = WindowAssembler::with_interval(cfg, 250);
        let mut emitted = Vec::new();
        for i in 0..16 {
            if let Some(ws) = w.push_interval(result_with(10.0, 1), exact_with(10.0)) {
                emitted.push((i, ws));
            }
        }
        assert_eq!(emitted.len(), 4);
        assert_eq!(emitted[0].0, 3); // 4th push = 1000 ms
        let full = &emitted[1].1; // window ending 2000 ms covers 8 intervals
        assert_eq!(full.intervals, 8);
        assert_eq!(full.result.state.c[0], 80.0);
    }

    #[test]
    fn capacities_add_across_intervals() {
        let mut w = WindowAssembler::new(WindowConfig::new(2000, 1000));
        w.push_interval(result_with(100.0, 10), ExactAgg::default());
        let ws = w.push_interval(result_with(100.0, 10), ExactAgg::default()).unwrap();
        assert_eq!(ws.result.state.n_cap[0], 20.0);
        for s in 1..MAX_STRATA {
            assert_eq!(ws.result.state.c[s], 0.0);
        }
    }

    #[test]
    #[should_panic]
    fn size_must_be_multiple_of_slide() {
        WindowConfig::new(1000, 300);
    }

    #[test]
    #[should_panic]
    fn interval_must_divide_slide() {
        WindowAssembler::with_interval(WindowConfig::new(1000, 1000), 300);
    }

    #[test]
    fn interval_clock_advances() {
        let mut w = WindowAssembler::new(WindowConfig::paper_default());
        assert_eq!(w.current_interval_end(), 5_000);
        w.push_interval(SampleResult::default(), ExactAgg::default());
        assert_eq!(w.current_interval_end(), 10_000);
    }

    #[test]
    fn exact_agg_arithmetic() {
        let mut e = ExactAgg::default();
        e.add(0, 5.0);
        e.add(0, 7.0);
        e.add(3, 1.0);
        e.add(99, 100.0); // out of range, dropped
        assert_eq!(e.total_count(), 3.0);
        assert_eq!(e.total_sum(), 13.0);
        let mut f = ExactAgg::default();
        f.add(3, 2.0);
        e.merge(&f);
        assert_eq!(e.sum[3], 3.0);
    }

    #[test]
    fn view_matches_owned_snapshot() {
        let mut a = WindowAssembler::new(WindowConfig::new(2_000, 1_000));
        let mut b = WindowAssembler::new(WindowConfig::new(2_000, 1_000));
        for i in 0..5 {
            let r = result_with(10.0 + i as f64, 4 + i);
            let e = exact_with(10.0 + i as f64);
            let owned = a.push_interval(r.clone(), e);
            let view = b.push_interval_view(r, e);
            match (owned, view) {
                (Some(ws), Some(v)) => {
                    assert_eq!(ws.start_ms, v.start_ms);
                    assert_eq!(ws.end_ms, v.end_ms);
                    assert_eq!(ws.intervals, v.intervals);
                    assert_eq!(ws.result.sample, v.to_sample_result().sample);
                    assert_eq!(ws.result.state, v.state);
                    assert_eq!(ws.exact, v.exact);
                    assert_eq!(ws.result.sample.len(), v.sample_len());
                    assert_eq!(ws.result.arrived(), v.arrived());
                }
                (None, None) => {}
                _ => panic!("owned/view emission cadence diverged"),
            }
        }
    }

    #[test]
    fn view_iter_and_parts_cover_sample_in_order() {
        let mut w = WindowAssembler::new(WindowConfig::new(3_000, 1_000));
        w.push_interval_view(result_with(2.0, 2), ExactAgg::default());
        w.push_interval_view(result_with(3.0, 3), ExactAgg::default());
        let v = w.push_interval_view(result_with(4.0, 4), ExactAgg::default()).unwrap();
        let via_iter: Vec<(u16, f64)> = v.iter().copied().collect();
        assert_eq!(via_iter.len(), 9);
        assert_eq!(via_iter, v.to_sample_result().sample);
        let [p0, p1] = v.parts();
        assert_eq!(p0.len() + p1.len(), 9);
    }

    #[test]
    fn from_result_adapter() {
        let r = result_with(7.0, 3);
        let v = WindowView::from_result(&r);
        assert_eq!(v.sample_len(), 3);
        assert_eq!(v.arrived(), 7.0);
        assert_eq!(v.to_sample_result().sample, r.sample);
        assert_eq!(v.state, r.state);
    }

    #[test]
    fn spilled_assembler_keeps_summaries_exact_and_drops_samples() {
        let cfg = WindowConfig::new(4_000, 1_000);
        let mut full = WindowAssembler::new(cfg);
        let mut spilled = WindowAssembler::new(cfg);
        spilled.spill_samples();
        assert!(spilled.spills() && !full.spills());
        for i in 0..12 {
            let r = result_with(20.0 + i as f64, 3 + i);
            let e = exact_with(20.0 + i as f64);
            let a = full.push_interval_view(r.clone(), e);
            let b = spilled.push_interval_view(r, e);
            match (a, b) {
                (Some(va), Some(vb)) => {
                    // summaries byte-identical; items gone but counted
                    assert_eq!(va.state, vb.state);
                    assert_eq!(va.exact, vb.exact);
                    assert_eq!(va.sample_len(), vb.sample_len());
                    assert_eq!(va.arrived(), vb.arrived());
                    assert_eq!(vb.parts()[0].len() + vb.parts()[1].len(), 0);
                    assert!(va.sample_len() > 0);
                }
                (None, None) => {}
                _ => panic!("emission cadence diverged under spill"),
            }
        }
    }

    #[test]
    #[should_panic(expected = "before the first pane")]
    fn spill_after_first_pane_rejected() {
        let mut w = WindowAssembler::new(WindowConfig::tumbling(1_000));
        w.push_interval_view(result_with(1.0, 1), ExactAgg::default());
        w.spill_samples();
    }

    // --- pane-store vs merge-all-intervals equivalence (the tentpole's
    //     byte-identity acceptance gate) -------------------------------

    use crate::core::Item;
    use crate::sampling::{make_sampler, SamplerKind};
    use crate::stream::{StreamConfig, StreamGenerator};

    /// Drive one sampler over a seeded trace at `interval_ms` cadence and
    /// feed the identical pane stream to both assemblers; every emitted
    /// window must match byte-for-byte (f64 bits, sample order, counters,
    /// ground truth).
    fn assert_equivalent(
        kind: SamplerKind,
        config: WindowConfig,
        interval_ms: EventTime,
        stream: &StreamConfig,
        duration_ms: EventTime,
        fraction: f64,
        seed: u64,
    ) {
        let items: Vec<Item> = StreamGenerator::new(stream).take_until(duration_ms);
        let mut sampler = make_sampler(kind, fraction, seed);
        let mut incremental = WindowAssembler::with_interval(config, interval_ms);
        let mut oracle = reference::ReferenceAssembler::with_interval(config, interval_ms);

        let mut idx = 0usize;
        let mut windows = 0usize;
        loop {
            let end = incremental.current_interval_end();
            let start = idx;
            while idx < items.len() && items[idx].ts < end {
                idx += 1;
            }
            let mut exact = ExactAgg::default();
            for it in &items[start..idx] {
                exact.add(it.stratum, it.value);
            }
            sampler.offer_slice(&items[start..idx]);
            let result = sampler.finish_interval();

            let want = oracle.push_interval(result.clone(), exact);
            let got = incremental.push_interval(result, exact);
            match (got, want) {
                (Some(g), Some(w)) => {
                    windows += 1;
                    assert_eq!(g.start_ms, w.start_ms, "{kind:?}");
                    assert_eq!(g.end_ms, w.end_ms, "{kind:?}");
                    assert_eq!(g.intervals, w.intervals, "{kind:?}");
                    // byte-identical: Vec<(u16, f64)> / [f64; K] PartialEq
                    // is bitwise for non-NaN values
                    assert_eq!(g.result.sample, w.result.sample, "{kind:?}");
                    assert_eq!(g.result.state, w.result.state, "{kind:?}");
                    assert_eq!(g.exact, w.exact, "{kind:?}");
                }
                (None, None) => {}
                _ => panic!("{kind:?}: emission cadence diverged"),
            }
            if idx >= items.len() {
                break;
            }
        }
        assert!(windows >= 2, "{kind:?}: too few windows ({windows}) to prove anything");
    }

    /// Light trace so ratio-64 spans stay fast in debug test runs.
    fn light_stream(seed: u64) -> StreamConfig {
        use crate::stream::{Distribution, SubStreamSpec};
        StreamConfig {
            substreams: vec![
                SubStreamSpec::new(0, Distribution::Gaussian { mu: 10.0, sigma: 5.0 }, 800.0),
                SubStreamSpec::new(1, Distribution::Gaussian { mu: 1000.0, sigma: 50.0 }, 200.0),
                SubStreamSpec::new(2, Distribution::Gaussian { mu: 10000.0, sigma: 500.0 }, 50.0),
            ],
            seed,
        }
    }

    #[test]
    fn equivalence_all_samplers_sliding() {
        // Gaussian (non-integral) values on purpose: the ring-order fold
        // makes even the f64 ground-truth sums bit-equal.
        for kind in [
            SamplerKind::Oasrs,
            SamplerKind::Srs,
            SamplerKind::Sts,
            SamplerKind::WeightedRes,
            SamplerKind::None,
        ] {
            assert_equivalent(
                kind,
                WindowConfig::new(2_000, 1_000),
                1_000,
                &light_stream(11),
                8_000,
                0.4,
                7,
            );
        }
    }

    #[test]
    fn equivalence_across_window_slide_ratios() {
        // The long-window/small-slide family the seed could not sustain:
        // ratios 4 / 16 / 64 at a fixed 250 ms slide.
        for (size, seeds) in [(1_000u64, 21u64), (4_000, 22), (16_000, 23)] {
            assert_equivalent(
                SamplerKind::Oasrs,
                WindowConfig::new(size, 250),
                250,
                &light_stream(seeds),
                20_000,
                0.3,
                seeds,
            );
        }
    }

    #[test]
    fn equivalence_sub_slide_batched_cadence() {
        // Batched-engine shape: panes at 250 ms feeding 1 s slides.
        assert_equivalent(
            SamplerKind::Oasrs,
            WindowConfig::new(4_000, 1_000),
            250,
            &light_stream(31),
            12_000,
            0.5,
            31,
        );
        assert_equivalent(
            SamplerKind::Srs,
            WindowConfig::new(4_000, 1_000),
            500,
            &light_stream(33),
            12_000,
            0.6,
            33,
        );
    }

    #[test]
    fn equivalence_fraction_changes_mid_stream() {
        // Adaptive-budget shape: the fraction moves between intervals.
        let items: Vec<Item> =
            StreamGenerator::new(&light_stream(41)).take_until(10_000);
        let config = WindowConfig::new(3_000, 1_000);
        let mut sampler = make_sampler(SamplerKind::Oasrs, 0.6, 5);
        let mut incremental = WindowAssembler::new(config);
        let mut oracle = reference::ReferenceAssembler::with_interval(config, 1_000);
        let mut idx = 0;
        for k in 0..10u64 {
            let end = incremental.current_interval_end();
            let start = idx;
            while idx < items.len() && items[idx].ts < end {
                idx += 1;
            }
            let mut exact = ExactAgg::default();
            for it in &items[start..idx] {
                exact.add(it.stratum, it.value);
            }
            sampler.offer_slice(&items[start..idx]);
            sampler.set_fraction(0.1 + 0.08 * (k % 7) as f64);
            let result = sampler.finish_interval();
            let want = oracle.push_interval(result.clone(), exact);
            let got = incremental.push_interval(result, exact);
            match (got, want) {
                (Some(g), Some(w)) => {
                    assert_eq!(g.result.sample, w.result.sample);
                    assert_eq!(g.result.state, w.result.state);
                    assert_eq!(g.exact, w.exact);
                }
                (None, None) => {}
                _ => panic!("cadence diverged"),
            }
        }
    }
}
