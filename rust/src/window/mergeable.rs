//! The [`Mergeable`] trait: one associative-combine contract for everything
//! a window pane can hold.
//!
//! The paper's distributed-execution argument (§3.2) and its windowing
//! (§2.2) rest on the same algebraic fact: per-worker and per-interval
//! summaries combine associatively, so results can be assembled in any
//! grouping without coordination.  Before this trait the repo encoded that
//! fact four separate times (OASRS worker merge, `ExactAgg::merge`, the
//! estimator partials, each sketch's `merge`); the pane store
//! ([`super::pane::PaneStore`]) and the window assembler now program
//! against the one trait instead.
//!
//! **Contract.**  `a.merge_from(&b)` must fold `b` into `a` where `a`
//! precedes `b` in stream order, and the fold must be *associative as an
//! operation on summaries*: merging panes in any grouping that preserves
//! their order answers queries over the concatenated stream.  Exactness of
//! that associativity differs by payload and is what the property tests in
//! `rust/tests/prop_invariants.rs` pin down:
//!
//! * sample concatenation and integral counters (`SampleResult`,
//!   [`ExactAgg`] counts, Count-Min/HLL registers) are **bit-exactly**
//!   associative;
//! * floating-point *value* sums ([`ExactAgg::sum`],
//!   [`StrataPartials`] sums) are associative up to rounding — bit-exact
//!   only when the summed values are exactly representable (integral), a
//!   distinction the window assembler honors by folding ground-truth metas
//!   in ring order (see `super` docs);
//! * the quantile sketch re-clusters on merge, so answers move within its
//!   rank-ε guarantee rather than bit-identically.
//!
//! Commutativity is NOT part of the contract (sample concatenation is
//! order-sensitive); payloads that happen to commute (HLL register max,
//! Count-Min counter sums) are tested as such where it matters.

use crate::error::estimator::StrataPartials;
use crate::sampling::SampleResult;
use crate::sketch::{CountMin, HeavyHitters, HyperLogLog, PaneSketch, QuantileSketch};

use super::ExactAgg;

/// Order-preserving associative combine of two summaries (see module docs).
pub trait Mergeable {
    /// Fold `other` into `self`; `self` precedes `other` in stream order.
    fn merge_from(&mut self, other: &Self);
}

impl Mergeable for ExactAgg {
    fn merge_from(&mut self, other: &Self) {
        self.merge(other);
    }
}

impl Mergeable for StrataPartials {
    fn merge_from(&mut self, other: &Self) {
        self.merge(other);
    }
}

/// Interval/worker sample results combine exactly as the OASRS distributed
/// merge (paper §3.2): samples concatenate in order, arrival counters and
/// capacities add.  [`crate::sampling::oasrs::merge_worker_results`] is a
/// fold over this impl.
impl Mergeable for SampleResult {
    fn merge_from(&mut self, other: &Self) {
        self.sample.extend_from_slice(&other.sample);
        for s in 0..crate::core::MAX_STRATA {
            self.state.c[s] += other.state.c[s];
            self.state.n_cap[s] += other.state.n_cap[s];
        }
    }
}

impl Mergeable for QuantileSketch {
    fn merge_from(&mut self, other: &Self) {
        self.merge(other);
    }
}

impl Mergeable for HyperLogLog {
    fn merge_from(&mut self, other: &Self) {
        self.merge(other);
    }
}

impl Mergeable for CountMin {
    fn merge_from(&mut self, other: &Self) {
        self.merge(other);
    }
}

impl Mergeable for HeavyHitters {
    fn merge_from(&mut self, other: &Self) {
        self.merge(other);
    }
}

/// Kind-tagged pane sketches merge through their inner sketch's combine;
/// a kind mismatch is a protocol bug and panics (see
/// [`PaneSketch::merge_same`]).  This is what lets `PaneStore<PaneSketch>`
/// hold whichever sketch the registered query needs.
impl Mergeable for PaneSketch {
    fn merge_from(&mut self, other: &Self) {
        self.merge_same(other);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_result_merge_matches_worker_merge() {
        let mk = |c0: f64, items: &[(u16, f64)]| {
            let mut r = SampleResult::default();
            r.state.c[0] = c0;
            r.state.n_cap[0] = c0;
            r.sample.extend_from_slice(items);
            r
        };
        let a = mk(2.0, &[(0, 1.0), (0, 2.0)]);
        let b = mk(3.0, &[(0, 5.0)]);
        let mut via_trait = a.clone();
        via_trait.merge_from(&b);
        let via_fn =
            crate::sampling::oasrs::merge_worker_results(vec![a.clone(), b.clone()]);
        assert_eq!(via_trait.sample, via_fn.sample);
        assert_eq!(via_trait.state, via_fn.state);
        // order preserved: a's items first
        assert_eq!(via_trait.sample[0], (0, 1.0));
        assert_eq!(via_trait.sample[2], (0, 5.0));
    }

    #[test]
    fn exact_agg_merge_from_adds() {
        let mut a = ExactAgg::default();
        a.add(0, 2.0);
        let mut b = ExactAgg::default();
        b.add(0, 3.0);
        b.add(1, 7.0);
        a.merge_from(&b);
        assert_eq!(a.count[0], 2.0);
        assert_eq!(a.sum[0], 5.0);
        assert_eq!(a.sum[1], 7.0);
    }

    #[test]
    fn hll_merge_from_is_union() {
        let mut a = HyperLogLog::new(10);
        let mut b = HyperLogLog::new(10);
        let mut u = HyperLogLog::new(10);
        for i in 0..500 {
            if i % 2 == 0 {
                a.offer(i as f64);
            } else {
                b.offer(i as f64);
            }
            u.offer(i as f64);
        }
        a.merge_from(&b);
        assert_eq!(a, u);
    }
}
