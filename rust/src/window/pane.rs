//! Two-stacks pane store: sliding-window aggregation over any
//! [`Mergeable`] payload in O(panes evicted + 1) amortized merges per
//! slide.
//!
//! The store keeps the last `capacity` panes (one pane per sampling
//! interval) and answers "merge of everything currently held" without
//! re-combining the whole span.  It is the classic two-stacks queue
//! aggregation (prefix/suffix scheme — cf. SABER/FlinkCEP-style sliding
//! aggregation and "the marriage of incremental and approximate
//! computing", Krishnan '16, PAPERS.md) adapted to the repo's pane ring:
//!
//! * a **back** stack receives new panes and maintains one running
//!   prefix aggregate (`back_agg`) — one merge per push;
//! * a **front** stack holds the older panes with precomputed *suffix*
//!   aggregates; evicting the oldest pane is a pop.  When the front
//!   empties, the back flips over: panes move across, each picking up the
//!   suffix aggregate of the panes behind it — `len(back)` merges paid
//!   once per `len(back)` evictions, so amortized one merge per evicted
//!   pane;
//! * the window aggregate is `front_suffix · back_prefix` — one merge,
//!   order-preserving, so any associative payload (samples, counters,
//!   sketches) gets the same answer as a left-to-right re-merge of the
//!   span, without the O(window/slide) combine the seed assembler paid.
//!
//! The amortized merge count per push is ≤ 3 **independent of the window/
//! slide ratio** — the property the `window_hotpath` bench pins (the seed
//! path re-merged all `ratio` panes per slide).  [`PaneStore::merge_ops`]
//! exposes the structural merge counter so tests and benches can assert
//! flatness deterministically instead of by timing.
//!
//! Payload sizing caveat: per-slide cost is O(merges × payload size).  For
//! constant-size payloads (sketches, counter blocks) that is O(1) per
//! slide; for growing payloads like a raw window sample the assembler uses
//! its in-place deque instead (see `super` docs for the split).

use super::mergeable::Mergeable;
use crate::core::Result;
use crate::runtime::checkpoint::{Snapshot, SnapshotReader, SnapshotWriter};

/// Sliding ring of the most recent `capacity` panes with two-stacks
/// incremental aggregation.
#[derive(Debug, Clone)]
pub struct PaneStore<T: Mergeable + Clone> {
    capacity: usize,
    /// Older panes: `(pane, suffix aggregate of this pane and everything
    /// newer up to the flip point)`, oldest at the top (= `Vec` end).
    front: Vec<(T, T)>,
    /// Newer panes in arrival order.
    back: Vec<T>,
    /// Running aggregate of `back` (None when `back` is empty).
    back_agg: Option<T>,
    /// Structural merges performed (push folds + flip folds).
    merges: u64,
}

impl<T: Mergeable + Clone> PaneStore<T> {
    /// Store holding the last `capacity` panes (capacity ≥ 1).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "pane store capacity must be positive");
        Self {
            capacity,
            front: Vec::with_capacity(capacity),
            back: Vec::with_capacity(capacity),
            back_agg: None,
            merges: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Panes currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.front.len() + self.back.len()
    }

    pub fn is_empty(&self) -> bool {
        self.front.is_empty() && self.back.is_empty()
    }

    /// Structural merges performed so far (push + flip; queries are counted
    /// by the caller — one merge per [`PaneStore::aggregate`] that touches
    /// both stacks).
    pub fn merge_ops(&self) -> u64 {
        self.merges
    }

    /// Push the newest pane, evicting the oldest when full.  One merge
    /// (plus amortized one per evicted pane).
    pub fn push(&mut self, pane: T) {
        if self.len() == self.capacity {
            self.evict_one();
        }
        match &mut self.back_agg {
            Some(agg) => {
                agg.merge_from(&pane);
                self.merges += 1;
            }
            None => self.back_agg = Some(pane.clone()),
        }
        self.back.push(pane);
    }

    /// Drop the oldest pane.  Amortized one merge: a flip moves each back
    /// pane across exactly once per residence.
    fn evict_one(&mut self) {
        if self.front.is_empty() {
            while let Some(pane) = self.back.pop() {
                let agg = match self.front.last() {
                    Some((_, newer_suffix)) => {
                        let mut a = pane.clone();
                        a.merge_from(newer_suffix);
                        self.merges += 1;
                        a
                    }
                    None => pane.clone(),
                };
                self.front.push((pane, agg));
            }
            self.back_agg = None;
        }
        self.front.pop();
    }

    /// Merge of every pane currently held, in arrival order (`None` when
    /// empty).  At most one merge (suffix · prefix), never a span re-merge.
    pub fn aggregate(&self) -> Option<T> {
        match (self.front.last(), &self.back_agg) {
            (Some((_, suffix)), Some(prefix)) => {
                let mut a = suffix.clone();
                a.merge_from(prefix);
                Some(a)
            }
            (Some((_, suffix)), None) => Some(suffix.clone()),
            (None, Some(prefix)) => Some(prefix.clone()),
            (None, None) => None,
        }
    }
}

/// Structural codec: both stacks (with the front's precomputed suffix
/// aggregates) and the running back prefix travel as-is, so a restored
/// store performs the *same* flips at the same pushes — `merge_ops` and
/// every aggregate stay bit-identical to the uninterrupted run.
impl<T: Mergeable + Clone + Snapshot> Snapshot for PaneStore<T> {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_usize(self.capacity);
        self.front.encode(w);
        self.back.encode(w);
        self.back_agg.encode(w);
        w.put_u64(self.merges);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        let capacity = r.get_usize()?;
        if capacity == 0 {
            return Err(crate::core::Error::Io(
                "pane store snapshot has zero capacity (corrupt payload)".into(),
            ));
        }
        Ok(Self {
            capacity,
            front: Vec::<(T, T)>::decode(r)?,
            back: Vec::<T>::decode(r)?,
            back_agg: Option::<T>::decode(r)?,
            merges: r.get_u64()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Order-sensitive test payload: a sequence of pane ids.  Concatenation
    /// is associative but not commutative, so any ordering or grouping bug
    /// in the store shows up as a wrong sequence, not a masked sum.
    #[derive(Debug, Clone, PartialEq)]
    struct Seq(Vec<u32>);

    impl Mergeable for Seq {
        fn merge_from(&mut self, other: &Self) {
            self.0.extend_from_slice(&other.0);
        }
    }

    #[test]
    fn aggregate_equals_ordered_remerge_for_every_capacity() {
        for cap in [1usize, 2, 3, 4, 7, 16, 64] {
            let mut store = PaneStore::new(cap);
            let mut ring: Vec<u32> = Vec::new();
            for i in 0..300u32 {
                store.push(Seq(vec![i]));
                ring.push(i);
                if ring.len() > cap {
                    ring.remove(0);
                }
                let got = store.aggregate().expect("non-empty");
                assert_eq!(got.0, ring, "cap {cap} at push {i}");
                assert_eq!(store.len(), ring.len());
            }
        }
    }

    #[test]
    fn empty_store() {
        let store: PaneStore<Seq> = PaneStore::new(4);
        assert!(store.is_empty());
        assert!(store.aggregate().is_none());
        assert_eq!(store.merge_ops(), 0);
    }

    #[test]
    #[should_panic]
    fn zero_capacity_rejected() {
        let _ = PaneStore::<Seq>::new(0);
    }

    #[test]
    fn amortized_merges_independent_of_capacity() {
        // The tentpole property: structural merges per push stay ≤ 2
        // amortized (1 push-fold + ≤ 1 flip-fold) at EVERY window/slide
        // ratio; with the query's single suffix·prefix merge that is ≤ 3
        // per slide, vs the seed's `capacity` merges per slide.
        let pushes = 10_000u64;
        let mut per_cap = Vec::new();
        for cap in [4usize, 16, 64] {
            let mut store = PaneStore::new(cap);
            for i in 0..pushes {
                store.push(Seq(vec![i as u32]));
                let _ = store.aggregate();
            }
            let ops = store.merge_ops();
            // exactly 2·(cap−1)/cap per push in steady state (measured
            // 1.50 / 1.87 / 1.97 for caps 4/16/64): bounded by 2, never a
            // factor of the ratio.
            assert!(
                ops <= 2 * pushes,
                "cap {cap}: {ops} structural merges for {pushes} pushes"
            );
            per_cap.push(ops);
        }
        // Flat across ratios: a 16x capacity spread moves the merge count
        // by < 1.5x (the seed path's count scales with the capacity itself).
        let max = *per_cap.iter().max().unwrap();
        let min = *per_cap.iter().min().unwrap();
        assert!(2 * max <= 3 * min, "merge counts scale with ratio: {per_cap:?}");
    }

    #[test]
    fn partial_window_aggregates_what_is_there() {
        let mut store = PaneStore::new(8);
        store.push(Seq(vec![1]));
        store.push(Seq(vec![2]));
        assert_eq!(store.aggregate().unwrap().0, vec![1, 2]);
        assert_eq!(store.len(), 2);
    }

    #[test]
    fn works_with_exact_agg_panes() {
        use crate::window::ExactAgg;
        let mut store = PaneStore::new(3);
        let mut direct: Vec<ExactAgg> = Vec::new();
        for i in 0..10 {
            let mut e = ExactAgg::default();
            e.add((i % 4) as u16, i as f64); // integral values: exact sums
            store.push(e);
            direct.push(e);
            if direct.len() > 3 {
                direct.remove(0);
            }
            let mut want = ExactAgg::default();
            for d in &direct {
                want.merge(d);
            }
            let got = store.aggregate().unwrap();
            assert_eq!(got.count, want.count);
            assert_eq!(got.sum, want.sum);
        }
    }
}
