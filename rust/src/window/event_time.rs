//! Event-time pane routing with a bounded-skew low-watermark.
//!
//! The legacy engines slice a pre-sorted trace by scanning `ts` ranges —
//! correct only because every source emits in order.  This module makes the
//! `ts` column authoritative: items route to the pane `ts / interval_ms`
//! regardless of arrival order, panes stay open until the low-watermark
//! minus the allowed lateness passes their end, and beyond-lateness items
//! are dropped *exactly once* into [`late-drop accounting`](LateDrops) that
//! widens the affected windows' confidence intervals.
//!
//! **Watermark heuristic (bounded skew).**  The watermark is
//! `max(ts seen) − watermark_skew_ms`: the source promises (via
//! [`crate::stream::DisorderConfig`] or its own semantics) that no item
//! arrives more than `skew` behind the newest event time already observed.
//! A pane `[start, end)` closes once `watermark ≥ end + allowed_lateness`.
//! An item delayed by at most `skew + lateness` virtual ms therefore always
//! finds its pane still open — the disorder-equivalence bound the
//! `event_time` test suite exercises.
//!
//! **Byte-identity under disorder.**  Reservoir samplers are order
//! sensitive (each offer consumes RNG), so routing alone cannot make a
//! shuffled run reproduce the in-order run.  The router instead *buffers*
//! each open pane's items and releases the pane as one canonically-ordered
//! sequence at close (sorted by `(ts, stratum, value bits)` — a total order
//! recoverable from item content alone).  Both an in-order and a
//! within-lateness shuffled arrival of the same trace then present the
//! sampler with identical per-pane sequences in identical pane order, so
//! samples, estimates, and bounds match bit for bit.
//!
//! A closed pane is *never* mutated: the close boundary (`next_close`)
//! only advances, and any item routed at or below it is dropped and
//! counted — the property tests in `rust/tests/event_time.rs` pin both.

use std::collections::{BTreeMap, VecDeque};

use crate::core::{EventTime, Item};
use crate::error::estimator::LateDrops;

/// Event-time knobs, off by default ([`crate::engine::EngineConfig`] holds
/// an `Option<EventTimeConfig>`; `None` keeps the legacy arrival-order
/// slicing byte-identical).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EventTimeConfig {
    /// Bounded-skew watermark allowance: the watermark trails the newest
    /// observed event time by this much (virtual ms).
    pub watermark_skew_ms: EventTime,
    /// How long past its end (watermark time) a pane stays open for late
    /// arrivals.
    pub allowed_lateness_ms: EventTime,
}

impl EventTimeConfig {
    pub fn new(watermark_skew_ms: EventTime, allowed_lateness_ms: EventTime) -> Self {
        Self { watermark_skew_ms, allowed_lateness_ms }
    }

    /// Largest per-item arrival delay (virtual ms) guaranteed to route
    /// without drops: a pane closes only after an event `skew + lateness`
    /// past its end has arrived, and an item delayed by at most that much
    /// arrives no later than such a closer (see the module doc).
    pub fn max_lossless_delay_ms(&self) -> EventTime {
        self.watermark_skew_ms.saturating_add(self.allowed_lateness_ms)
    }
}

/// Routes items into event-time panes and closes them in pane-id order as
/// the watermark advances.
#[derive(Debug)]
pub struct EventTimeRouter {
    interval_ms: EventTime,
    config: EventTimeConfig,
    /// Open pane buffers, keyed by pane id (`ts / interval_ms`).  Only
    /// non-empty panes hold an entry; gaps close as empty panes so the
    /// assembler's interval clock still ticks once per pane.
    open: BTreeMap<u64, Vec<Item>>,
    /// Panes closed but not yet taken, in pane-id order.
    ready: VecDeque<Vec<Item>>,
    /// Next pane id to close; every pane below it is sealed forever.
    next_close: u64,
    /// Highest pane id that has received an item (reopen detection).
    max_pane_seen: u64,
    /// Highest event time observed (the watermark input).
    max_ts: EventTime,
    watermark: EventTime,
    seen_any: bool,
    flushed: bool,
    /// Per-pane drops recorded since the last [`Self::take_new_drops`].
    new_drops: Vec<(u64, LateDrops)>,
    dropped_items: u64,
}

impl EventTimeRouter {
    pub fn new(interval_ms: EventTime, config: EventTimeConfig) -> Self {
        assert!(interval_ms > 0, "event-time pane interval must be positive");
        Self {
            interval_ms,
            config,
            open: BTreeMap::new(),
            ready: VecDeque::new(),
            next_close: 0,
            max_pane_seen: 0,
            max_ts: 0,
            watermark: 0,
            seen_any: false,
            flushed: false,
            new_drops: Vec::new(),
            dropped_items: 0,
        }
    }

    /// Route one arrival.  Beyond-lateness items are dropped (counted, and
    /// charged to their pane for CI widening); everything else lands in its
    /// still-open pane.
    pub fn push(&mut self, item: &Item) {
        let pane = item.ts / self.interval_ms;
        if pane < self.next_close {
            self.dropped_items += 1;
            crate::obs_counter!(
                "late_items_dropped_total",
                "beyond-lateness items dropped by the event-time router"
            )
            .inc();
            match self.new_drops.iter_mut().find(|(p, _)| *p == pane) {
                Some((_, d)) => d.add(item.value),
                None => {
                    let mut d = LateDrops::default();
                    d.add(item.value);
                    self.new_drops.push((pane, d));
                }
            }
            return;
        }
        if self.seen_any && pane < self.max_pane_seen {
            crate::obs_counter!(
                "window_pane_reopens_total",
                "late arrivals routed into an already-open older event-time pane"
            )
            .inc();
        }
        self.max_pane_seen = self.max_pane_seen.max(pane);
        self.seen_any = true;
        self.open.entry(pane).or_default().push(*item);
        if item.ts > self.max_ts {
            self.max_ts = item.ts;
            self.advance_watermark();
        }
    }

    /// Current low-watermark (`max ts seen − skew`, floored at 0).
    pub fn watermark(&self) -> EventTime {
        self.watermark
    }

    /// Beyond-lateness items dropped so far.
    pub fn dropped_items(&self) -> u64 {
        self.dropped_items
    }

    /// Pane id of the next pane to close (everything below is sealed).
    pub fn next_close_id(&self) -> u64 {
        self.next_close
    }

    /// Drain the drops recorded since the last call, as `(pane_id, drops)`
    /// pairs — the engines ship these alongside each closed pane so the
    /// window consumer can charge them to the right spans.
    pub fn take_new_drops(&mut self) -> Vec<(u64, LateDrops)> {
        std::mem::take(&mut self.new_drops)
    }

    /// End of stream: every remaining open pane (and gap) closes in order.
    pub fn flush(&mut self) {
        self.flushed = true;
        if !self.seen_any {
            return;
        }
        while self.next_close <= self.max_pane_seen {
            self.close_next();
        }
    }

    /// Next closed pane's items in canonical order (`(ts, stratum, value
    /// bits)` — see the module doc), empty for a gap pane; `None` when
    /// nothing is ready.  Panes come out strictly in pane-id order.
    pub fn next_ready(&mut self) -> Option<Vec<Item>> {
        self.ready.pop_front()
    }

    fn advance_watermark(&mut self) {
        self.watermark = self.max_ts.saturating_sub(self.config.watermark_skew_ms);
        crate::obs_gauge!(
            "event_time_watermark_lag_ms",
            "virtual ms the low-watermark trails the newest observed event time"
        )
        .set(self.max_ts.saturating_sub(self.watermark) as f64);
        loop {
            let end = (self.next_close + 1).saturating_mul(self.interval_ms);
            if end.saturating_add(self.config.allowed_lateness_ms) > self.watermark {
                break;
            }
            self.close_next();
        }
    }

    fn close_next(&mut self) {
        let mut items = self.open.remove(&self.next_close).unwrap_or_default();
        canonical_sort(&mut items);
        self.ready.push_back(items);
        self.next_close += 1;
    }
}

/// The canonical within-pane order: a total order recoverable from item
/// content alone, so every arrival permutation of the same pane multiset
/// releases the identical sequence.  This fold order *is* the byte-identity
/// spec for event-time mode.
fn canonical_sort(items: &mut [Item]) {
    items.sort_unstable_by(|a, b| {
        (a.ts, a.stratum, a.value.to_bits()).cmp(&(b.ts, b.stratum, b.value.to_bits()))
    });
}

/// Pulls an arrival-order trace through an [`EventTimeRouter`], yielding
/// one closed pane per call — the event-time replacement for the engines'
/// sorted range scan.
#[derive(Debug)]
pub struct EventTimeSlicer<'a> {
    items: &'a [Item],
    pos: usize,
    router: EventTimeRouter,
}

impl<'a> EventTimeSlicer<'a> {
    pub fn new(items: &'a [Item], interval_ms: EventTime, config: EventTimeConfig) -> Self {
        Self { items, pos: 0, router: EventTimeRouter::new(interval_ms, config) }
    }

    /// Items of the next pane (canonical order; empty `Vec` for a gap
    /// pane), or `None` once the input is exhausted and every pane has
    /// flushed.
    pub fn next_pane(&mut self) -> Option<Vec<Item>> {
        loop {
            if let Some(pane) = self.router.next_ready() {
                return Some(pane);
            }
            if self.pos < self.items.len() {
                self.router.push(&self.items[self.pos]);
                self.pos += 1;
            } else if !self.router.flushed {
                self.router.flush();
            } else {
                return None;
            }
        }
    }

    pub fn take_new_drops(&mut self) -> Vec<(u64, LateDrops)> {
        self.router.take_new_drops()
    }

    pub fn dropped_items(&self) -> u64 {
        self.router.dropped_items()
    }

    pub fn watermark(&self) -> EventTime {
        self.router.watermark()
    }
}

/// Window-side accounting of beyond-lateness drops: absorbs the routers'
/// `(pane_id, drops)` batches and answers "how much mass is missing from
/// the window `[start, end)`" at emission time.  Drops observed *after* a
/// window emits are charged only to later windows still spanning the pane —
/// an emitted result is immutable, so its bound reflects the drops known
/// when it closed.
#[derive(Debug, Default)]
pub struct DropLedger {
    interval_ms: EventTime,
    per_pane: BTreeMap<u64, LateDrops>,
}

impl DropLedger {
    pub fn new(interval_ms: EventTime) -> Self {
        assert!(interval_ms > 0, "drop ledger needs a positive pane interval");
        Self { interval_ms, per_pane: BTreeMap::new() }
    }

    pub fn absorb(&mut self, batch: Vec<(u64, LateDrops)>) {
        for (pane, d) in batch {
            self.per_pane.entry(pane).or_default().merge(&d);
        }
    }

    /// Total drops charged to panes inside `[start_ms, end_ms)`.
    pub fn span(&self, start_ms: EventTime, end_ms: EventTime) -> LateDrops {
        let lo = start_ms / self.interval_ms;
        let hi = end_ms / self.interval_ms; // exclusive
        let mut out = LateDrops::default();
        for (_, d) in self.per_pane.range(lo..hi) {
            out.merge(d);
        }
        out
    }

    /// Forget panes below `start_ms` — window starts are monotone, so the
    /// engines prune after each emission to bound ledger memory.
    pub fn prune_below(&mut self, start_ms: EventTime) {
        let lo = start_ms / self.interval_ms;
        self.per_pane = self.per_pane.split_off(&lo);
    }
}

use crate::core::Result;
use crate::runtime::checkpoint::{Snapshot, SnapshotReader, SnapshotWriter};

/// The ledger travels in every checkpoint: a crash between a late-drop
/// charge and the window emission that would consume it must not lose (or
/// double-count) the missing mass — recovery replays the emission against
/// the *restored* per-pane charges, widening exactly one window by exactly
/// the recorded amount (satellite 3 of the recovery suite pins this).
impl Snapshot for DropLedger {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_u64(self.interval_ms);
        w.put_usize(self.per_pane.len());
        for (pane, drops) in &self.per_pane {
            w.put_u64(*pane);
            drops.encode(w);
        }
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        let interval_ms = r.get_u64()?;
        if interval_ms == 0 {
            return Err(crate::core::Error::Io(
                "drop ledger snapshot has zero pane interval (corrupt payload)".into(),
            ));
        }
        let n = r.get_usize()?;
        if n > r.remaining() {
            return Err(crate::core::Error::Io(format!(
                "drop ledger snapshot claims {n} panes but only {} bytes remain",
                r.remaining()
            )));
        }
        let mut per_pane = BTreeMap::new();
        for _ in 0..n {
            let pane = r.get_u64()?;
            per_pane.insert(pane, LateDrops::decode(r)?);
        }
        Ok(Self { interval_ms, per_pane })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn it(stratum: u16, value: f64, ts: EventTime) -> Item {
        Item::new(stratum, value, ts)
    }

    fn cfg(skew: EventTime, lateness: EventTime) -> EventTimeConfig {
        EventTimeConfig::new(skew, lateness)
    }

    #[test]
    fn in_order_stream_panes_match_ts_ranges() {
        // 0..1000 -> pane 0, 1000..2000 -> pane 1, ...
        let items: Vec<Item> = (0..3000u64).map(|t| it(0, t as f64, t)).collect();
        let mut s = EventTimeSlicer::new(&items, 1000, cfg(0, 0));
        let mut pane_id = 0u64;
        while let Some(pane) = s.next_pane() {
            for item in &pane {
                assert_eq!(item.ts / 1000, pane_id, "item {} in pane {pane_id}", item.ts);
            }
            pane_id += 1;
        }
        assert_eq!(pane_id, 3);
        assert_eq!(s.dropped_items(), 0);
    }

    #[test]
    fn pane_close_waits_for_watermark_plus_lateness() {
        let mut r = EventTimeRouter::new(1000, cfg(200, 300));
        r.push(&it(0, 1.0, 500));
        assert!(r.next_ready().is_none(), "pane 0 must stay open");
        // watermark = 1499 - 200 = 1299 < 1000 + 300 -> still open
        r.push(&it(0, 2.0, 1499));
        assert!(r.next_ready().is_none());
        // watermark = 1500 - 200 = 1300 >= 1300 -> pane 0 closes
        r.push(&it(0, 3.0, 1500));
        let pane0 = r.next_ready().expect("pane 0 closed");
        assert_eq!(pane0.len(), 1);
        assert_eq!(pane0[0].ts, 500);
        assert_eq!(r.watermark(), 1300);
    }

    #[test]
    fn within_lateness_stragglers_route_into_open_pane() {
        let mut r = EventTimeRouter::new(1000, cfg(0, 500));
        r.push(&it(0, 1.0, 100));
        r.push(&it(0, 2.0, 1200)); // wm 1200 < 1500: pane 0 open
        r.push(&it(1, 3.0, 900)); // straggler for pane 0
        r.push(&it(0, 4.0, 1600)); // wm 1600 >= 1500: pane 0 closes
        let pane0 = r.next_ready().expect("pane 0");
        let ts: Vec<u64> = pane0.iter().map(|i| i.ts).collect();
        assert_eq!(ts, vec![100, 900], "straggler merged, canonical order");
        assert_eq!(r.dropped_items(), 0);
    }

    #[test]
    fn beyond_lateness_items_drop_exactly_once_and_are_charged() {
        let mut r = EventTimeRouter::new(1000, cfg(0, 0));
        r.push(&it(0, 1.0, 100));
        r.push(&it(0, 2.0, 2500)); // wm 2500: panes 0 and 1 close
        assert_eq!(r.next_close_id(), 2);
        r.push(&it(0, 7.5, 900)); // pane 0 is sealed -> drop
        r.push(&it(0, 2.5, 950)); // drop
        r.push(&it(0, 1.0, 1100)); // pane 1 sealed -> drop
        assert_eq!(r.dropped_items(), 3);
        let drops = r.take_new_drops();
        assert_eq!(drops.len(), 2, "charged per pane");
        let p0 = drops.iter().find(|(p, _)| *p == 0).unwrap().1;
        assert_eq!(p0.count, 2.0);
        assert_eq!(p0.mass, 10.0);
        let p1 = drops.iter().find(|(p, _)| *p == 1).unwrap().1;
        assert_eq!(p1.count, 1.0);
        // drained: a second take returns nothing
        assert!(r.take_new_drops().is_empty());
        // the dropped items never surface in any pane
        r.flush();
        let mut surfaced = 0;
        while let Some(pane) = r.next_ready() {
            surfaced += pane.len();
        }
        assert_eq!(surfaced, 2, "only the two routed items");
    }

    #[test]
    fn gap_panes_close_empty_in_order() {
        let items = [it(0, 1.0, 100), it(0, 2.0, 5100)];
        let mut s = EventTimeSlicer::new(&items, 1000, cfg(0, 0));
        let mut lens = Vec::new();
        while let Some(pane) = s.next_pane() {
            lens.push(pane.len());
        }
        assert_eq!(lens, vec![1, 0, 0, 0, 0, 1], "gaps tick the pane clock");
    }

    #[test]
    fn canonical_order_is_arrival_invariant() {
        let mut fwd = vec![it(2, 5.0, 10), it(0, 3.0, 10), it(0, 3.0, 7), it(1, -1.0, 10)];
        let mut rev: Vec<Item> = fwd.iter().rev().copied().collect();
        canonical_sort(&mut fwd);
        canonical_sort(&mut rev);
        assert_eq!(fwd, rev);
        assert_eq!(fwd[0].ts, 7);
    }

    #[test]
    fn max_lossless_delay_is_skew_plus_lateness() {
        assert_eq!(cfg(200, 300).max_lossless_delay_ms(), 500);
        assert_eq!(cfg(u64::MAX, 1).max_lossless_delay_ms(), u64::MAX);
    }

    #[test]
    fn drop_ledger_spans_and_prunes() {
        let mut l = DropLedger::new(1000);
        let mut d0 = LateDrops::default();
        d0.add(5.0);
        let mut d2 = LateDrops::default();
        d2.add(7.0);
        d2.add(1.0);
        l.absorb(vec![(0, d0), (2, d2)]);
        assert_eq!(l.span(0, 1000).count, 1.0);
        assert_eq!(l.span(0, 3000).count, 3.0);
        assert_eq!(l.span(0, 3000).mass, 13.0);
        assert_eq!(l.span(1000, 2000).count, 0.0);
        assert!(l.span(3000, 9000).is_empty());
        l.prune_below(2000);
        assert!(l.span(0, 2000).is_empty(), "pruned panes forgotten");
        assert_eq!(l.span(2000, 3000).count, 2.0);
    }

    #[test]
    fn flush_without_items_yields_nothing() {
        let mut r = EventTimeRouter::new(500, cfg(100, 100));
        r.flush();
        assert!(r.next_ready().is_none());
        assert_eq!(r.dropped_items(), 0);
    }
}
