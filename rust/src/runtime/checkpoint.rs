//! Checkpoint/restore subsystem: mergeable state as the recovery format.
//!
//! Everything a pane holds is associatively `Mergeable`, and every sampler
//! is a seeded deterministic machine — which together make the pipeline's
//! state a *checkpoint format*: serialize sampler state (including RNG
//! streams), pane-store contents, the `DropLedger`, and the feedback EWMA
//! at an interval boundary, record the broker offset, and a recovered run
//! replays bit-identically to one that never crashed.  (*The Marriage of
//! Incremental and Approximate Computing*, 1611.08573, frames memoized
//! partials as exactly this recovery substrate.)
//!
//! Three layers live here:
//!
//! * **[`SnapshotCodec`]** — [`SnapshotWriter`] / [`SnapshotReader`] and the
//!   [`Snapshot`] trait: a zero-dependency little-endian binary codec.
//!   Floats travel as `to_bits` so round-trips are bit-exact (NaN payloads
//!   and signed zeros included); every `Mergeable` payload and every
//!   sampler implements it in its own module (private fields stay private).
//! * **[`CheckpointStore`]** — epoch-stamped snapshot files
//!   (`epoch-NNNNNNNN.ckpt`, magic + version + payload + FNV-1a checksum,
//!   written tmp-then-rename so a torn write never replaces a good epoch)
//!   plus a `manifest.json`, with newest-valid-epoch fallback on load.
//! * **[`PipelineSnapshot`]** — the engines' whole-pipeline frame: config
//!   fingerprint, broker offset, per-worker sampler blobs, assembler,
//!   sketch window, drop ledger, and cost/feedback state.
//!
//! The control-plane half (how workers *produce* their blobs at interval
//! boundaries) rides the same acked rendezvous discipline as
//! `set_fraction`/`register_sketches` — see `engine::worker::Msg::Snapshot`.

use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::core::{Error, Result};
use crate::util::json::{self, Value};
use crate::util::rng::Rng;

/// Marker name for the codec half of this module (referenced by docs and
/// the property suite): the writer/reader pair plus the [`Snapshot`] trait.
pub type SnapshotCodec = SnapshotWriter;

/// File magic for snapshot frames ("StreamApprox Checkpoint").
pub const SNAPSHOT_MAGIC: [u8; 4] = *b"SAXC";

/// Frame format version; bump on any layout change so stale snapshots are
/// rejected loudly instead of mis-decoded.
pub const SNAPSHOT_VERSION: u16 = 1;

const FRAME_HEADER: usize = 4 + 2; // magic + version
const FRAME_TRAILER: usize = 8; // FNV-1a-64 checksum

/// FNV-1a 64-bit checksum (zero-dep, deterministic across platforms).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------------
// Codec
// ---------------------------------------------------------------------------

/// Append-only little-endian byte sink for snapshot payloads.
#[derive(Debug, Default)]
pub struct SnapshotWriter {
    buf: Vec<u8>,
}

impl SnapshotWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `usize` travels as u64 so snapshots are word-size independent.
    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    /// Floats travel as raw bits — bit-exact round-trip is the contract.
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    pub fn put_bool(&mut self, v: bool) {
        self.put_u8(v as u8);
    }

    /// Length-prefixed raw bytes (nested payloads, worker blobs).
    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_usize(v.len());
        self.buf.extend_from_slice(v);
    }

    /// Splice pre-encoded snapshot bytes in as-is (no length prefix): the
    /// pipelined consumer serializes its assembler/sketch/ledger state on
    /// its own thread and the coordinator stitches the blob into the full
    /// payload at the exact field positions the typed encode would use.
    pub fn extend_raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }
}

/// Cursor over a snapshot payload; every read is bounds-checked and an
/// underrun is a descriptive [`Error::Io`], never a panic.
#[derive(Debug)]
pub struct SnapshotReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> SnapshotReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_exhausted(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::Io(format!(
                "snapshot payload truncated: need {n} bytes at offset {}, {} remain",
                self.pos,
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_usize(&mut self) -> Result<usize> {
        Ok(self.get_u64()? as usize)
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.get_u64()?))
    }

    pub fn get_bool(&mut self) -> Result<bool> {
        match self.get_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(Error::Io(format!("snapshot bool byte {other} (corrupt payload)"))),
        }
    }

    pub fn get_bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.get_usize()?;
        if n > self.remaining() {
            return Err(Error::Io(format!(
                "snapshot byte-blob length {n} exceeds {} remaining bytes (corrupt payload)",
                self.remaining()
            )));
        }
        Ok(self.take(n)?.to_vec())
    }
}

/// Binary snapshot serialization — implemented by every `Mergeable`
/// payload, every sampler, and the window/budget state machines, each in
/// its own module so private fields stay private.  The contract is
/// bit-exact continuation: `decode(encode(x))` must behave identically to
/// `x` for every subsequent operation, RNG draws included.
pub trait Snapshot: Sized {
    fn encode(&self, w: &mut SnapshotWriter);
    fn decode(r: &mut SnapshotReader) -> Result<Self>;

    /// Convenience: encode into a fresh byte vector.
    fn to_snapshot_bytes(&self) -> Vec<u8> {
        let mut w = SnapshotWriter::new();
        self.encode(&mut w);
        w.into_bytes()
    }

    /// Convenience: decode from a byte slice, requiring full consumption
    /// (trailing garbage means a framing bug, not a compatible snapshot).
    fn from_snapshot_bytes(bytes: &[u8]) -> Result<Self> {
        let mut r = SnapshotReader::new(bytes);
        let v = Self::decode(&mut r)?;
        if !r.is_exhausted() {
            return Err(Error::Io(format!(
                "snapshot payload has {} trailing bytes after decode",
                r.remaining()
            )));
        }
        Ok(v)
    }
}

impl Snapshot for u8 {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_u8(*self);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        r.get_u8()
    }
}

impl Snapshot for u16 {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_u16(*self);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        r.get_u16()
    }
}

impl Snapshot for u32 {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_u32(*self);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        r.get_u32()
    }
}

impl Snapshot for u64 {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_u64(*self);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        r.get_u64()
    }
}

impl Snapshot for usize {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_usize(*self);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        r.get_usize()
    }
}

impl Snapshot for f64 {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_f64(*self);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        r.get_f64()
    }
}

impl Snapshot for bool {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_bool(*self);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        r.get_bool()
    }
}

impl<A: Snapshot, B: Snapshot> Snapshot for (A, B) {
    fn encode(&self, w: &mut SnapshotWriter) {
        self.0.encode(w);
        self.1.encode(w);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        Ok((A::decode(r)?, B::decode(r)?))
    }
}

impl<T: Snapshot> Snapshot for Option<T> {
    fn encode(&self, w: &mut SnapshotWriter) {
        match self {
            None => w.put_u8(0),
            Some(v) => {
                w.put_u8(1);
                v.encode(w);
            }
        }
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        match r.get_u8()? {
            0 => Ok(None),
            1 => Ok(Some(T::decode(r)?)),
            other => Err(Error::Io(format!("snapshot Option tag {other} (corrupt payload)"))),
        }
    }
}

impl<T: Snapshot> Snapshot for Vec<T> {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_usize(self.len());
        for v in self {
            v.encode(w);
        }
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        let n = r.get_usize()?;
        // Every element costs >= 1 byte, so a length beyond the remaining
        // payload is corruption — reject before allocating.
        if n > r.remaining() {
            return Err(Error::Io(format!(
                "snapshot vec length {n} exceeds {} remaining bytes (corrupt payload)",
                r.remaining()
            )));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(T::decode(r)?);
        }
        Ok(out)
    }
}

impl<T: Snapshot + Copy + Default, const N: usize> Snapshot for [T; N] {
    fn encode(&self, w: &mut SnapshotWriter) {
        for v in self {
            v.encode(w);
        }
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        let mut out = [T::default(); N];
        for slot in out.iter_mut() {
            *slot = T::decode(r)?;
        }
        Ok(out)
    }
}

impl Snapshot for Rng {
    fn encode(&self, w: &mut SnapshotWriter) {
        let (s, spare) = self.state();
        s.encode(w);
        spare.encode(w);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        let s = <[u64; 4]>::decode(r)?;
        let spare = Option::<f64>::decode(r)?;
        Ok(Rng::from_state(s, spare))
    }
}

// ---------------------------------------------------------------------------
// Epoch-stamped on-disk store
// ---------------------------------------------------------------------------

/// Wrap a payload in the on-disk frame: magic, version, payload, FNV-1a-64
/// checksum of everything preceding it.
pub fn encode_frame(payload: &[u8]) -> Vec<u8> {
    let mut frame = Vec::with_capacity(FRAME_HEADER + payload.len() + FRAME_TRAILER);
    frame.extend_from_slice(&SNAPSHOT_MAGIC);
    frame.extend_from_slice(&SNAPSHOT_VERSION.to_le_bytes());
    frame.extend_from_slice(payload);
    let sum = fnv1a64(&frame);
    frame.extend_from_slice(&sum.to_le_bytes());
    frame
}

/// Validate a frame and return its payload.  Rejection taxonomy:
/// too-short/checksum failures are [`Error::Io`] (torn or bit-flipped
/// writes), wrong magic or version are [`Error::Config`] (not a snapshot /
/// incompatible layout).
pub fn decode_frame(frame: &[u8]) -> Result<Vec<u8>> {
    if frame.len() < FRAME_HEADER + FRAME_TRAILER {
        return Err(Error::Io(format!(
            "truncated snapshot frame: {} bytes, minimum {}",
            frame.len(),
            FRAME_HEADER + FRAME_TRAILER
        )));
    }
    if frame[..4] != SNAPSHOT_MAGIC {
        return Err(Error::Config(format!(
            "bad snapshot magic {:02x?} (not a StreamApprox checkpoint)",
            &frame[..4]
        )));
    }
    let version = u16::from_le_bytes(frame[4..6].try_into().unwrap());
    if version != SNAPSHOT_VERSION {
        return Err(Error::Config(format!(
            "snapshot version mismatch: file is v{version}, this build reads v{SNAPSHOT_VERSION}"
        )));
    }
    let (body, trailer) = frame.split_at(frame.len() - FRAME_TRAILER);
    let want = u64::from_le_bytes(trailer.try_into().unwrap());
    let got = fnv1a64(body);
    if got != want {
        return Err(Error::Io(format!(
            "snapshot checksum mismatch: computed {got:#018x}, recorded {want:#018x} \
             (torn or bit-flipped write)"
        )));
    }
    Ok(body[FRAME_HEADER..].to_vec())
}

/// A snapshot successfully loaded from a [`CheckpointStore`], with the
/// exact-once fallback accounting the negative-path suite pins.
#[derive(Debug)]
pub struct LoadedSnapshot {
    /// Epoch (interval count) the snapshot was taken at.
    pub epoch: u64,
    /// Decoded frame payload.
    pub payload: Vec<u8>,
    /// Newer epochs that were present but invalid and skipped — one tick
    /// per skipped file, mirrored on `recovery_fallbacks_total`.
    pub skipped: u64,
}

/// Directory of epoch-stamped snapshot files plus a `manifest.json`.
///
/// Layout:
/// ```text
/// <dir>/epoch-00000003.ckpt   (frame: magic | version | payload | fnv64)
/// <dir>/manifest.json         ({"format": ..., "latest_epoch": 3, "epochs": [...]})
/// ```
///
/// Writes go through a `.tmp` file renamed into place, so a crash mid-write
/// leaves the previous epoch intact and the torn `.tmp` ignored.
#[derive(Debug, Clone)]
pub struct CheckpointStore {
    dir: PathBuf,
}

impl CheckpointStore {
    /// Open (creating the directory if needed).
    pub fn create(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .map_err(|e| Error::Io(format!("create checkpoint dir {}: {e}", dir.display())))?;
        Ok(Self { dir })
    }

    /// Open an existing checkpoint directory (restore path).
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        if !dir.is_dir() {
            return Err(Error::Config(format!(
                "checkpoint dir {} does not exist",
                dir.display()
            )));
        }
        Ok(Self { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Path of one epoch's snapshot file.
    pub fn epoch_path(&self, epoch: u64) -> PathBuf {
        self.dir.join(format!("epoch-{epoch:08}.ckpt"))
    }

    fn manifest_path(&self) -> PathBuf {
        self.dir.join("manifest.json")
    }

    /// Epochs with a snapshot file present, ascending.
    pub fn epochs(&self) -> Result<Vec<u64>> {
        let rd = std::fs::read_dir(&self.dir)
            .map_err(|e| Error::Io(format!("read checkpoint dir {}: {e}", self.dir.display())))?;
        let mut out = Vec::new();
        for entry in rd {
            let entry = entry.map_err(|e| Error::Io(format!("read checkpoint dir entry: {e}")))?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if let Some(num) = name.strip_prefix("epoch-").and_then(|s| s.strip_suffix(".ckpt")) {
                if let Ok(epoch) = num.parse::<u64>() {
                    out.push(epoch);
                }
            }
        }
        out.sort_unstable();
        Ok(out)
    }

    /// Persist one epoch's payload (framed, tmp-then-rename) and refresh
    /// the manifest.  Records snapshot size and write latency.
    pub fn write_epoch(&self, epoch: u64, payload: &[u8]) -> Result<u64> {
        let t0 = Instant::now(); // lint: wall-clock latency metric only, never feeds results
        let frame = encode_frame(payload);
        let final_path = self.epoch_path(epoch);
        let tmp = self.dir.join(format!("epoch-{epoch:08}.ckpt.tmp"));
        std::fs::write(&tmp, &frame)
            .map_err(|e| Error::Io(format!("write snapshot {}: {e}", tmp.display())))?;
        std::fs::rename(&tmp, &final_path).map_err(|e| {
            Error::Io(format!("publish snapshot {}: {e}", final_path.display()))
        })?;
        self.write_manifest(epoch)?;
        let bytes = frame.len() as u64;
        crate::obs_counter!("snapshots_written_total", "Checkpoint snapshots persisted").inc();
        crate::obs_histogram!("snapshot_bytes", "Size of one persisted snapshot frame (bytes)")
            .record(bytes);
        crate::obs_histogram!("snapshot_write_ns", "Wall time to frame + persist one snapshot")
            .record_elapsed(t0);
        crate::obs_gauge!("snapshot_epoch", "Most recently persisted checkpoint epoch")
            .set(epoch as f64);
        Ok(bytes)
    }

    fn write_manifest(&self, latest: u64) -> Result<()> {
        let epochs = self.epochs()?;
        let doc = json::obj(vec![
            ("format", Value::Str("streamapprox-checkpoint".into())),
            ("version", Value::Num(SNAPSHOT_VERSION as f64)),
            ("latest_epoch", Value::Num(latest as f64)),
            (
                "epochs",
                Value::Arr(epochs.into_iter().map(|e| Value::Num(e as f64)).collect()),
            ),
        ]);
        let tmp = self.dir.join("manifest.json.tmp");
        std::fs::write(&tmp, doc.to_string())
            .map_err(|e| Error::Io(format!("write manifest {}: {e}", tmp.display())))?;
        std::fs::rename(&tmp, self.manifest_path())
            .map_err(|e| Error::Io(format!("publish manifest: {e}")))?;
        Ok(())
    }

    /// Read and validate one epoch's payload.
    pub fn read_epoch(&self, epoch: u64) -> Result<Vec<u8>> {
        let path = self.epoch_path(epoch);
        let frame = std::fs::read(&path)
            .map_err(|e| Error::Io(format!("read snapshot {}: {e}", path.display())))?;
        decode_frame(&frame)
    }

    /// Load the newest *valid* epoch, skipping (and counting, exactly once
    /// per file) any newer snapshots that fail validation.  `Ok(None)` when
    /// the directory holds no snapshot files at all; `Err` when files exist
    /// but none validates (the last failure is returned).
    pub fn load_latest(&self) -> Result<Option<LoadedSnapshot>> {
        let epochs = self.epochs()?;
        if epochs.is_empty() {
            return Ok(None);
        }
        let mut skipped = 0u64;
        let mut last_err = None;
        for &epoch in epochs.iter().rev() {
            match self.read_epoch(epoch) {
                Ok(payload) => {
                    if skipped > 0 {
                        crate::obs_counter!(
                            "recovery_fallbacks_total",
                            "Invalid snapshot epochs skipped during recovery"
                        )
                        .add(skipped);
                    }
                    return Ok(Some(LoadedSnapshot { epoch, payload, skipped }));
                }
                Err(e) => {
                    skipped += 1;
                    last_err = Some(e);
                }
            }
        }
        crate::obs_counter!(
            "recovery_fallbacks_total",
            "Invalid snapshot epochs skipped during recovery"
        )
        .add(skipped);
        Err(last_err.unwrap_or_else(|| Error::Io("no valid snapshot epoch".into())))
    }
}

// ---------------------------------------------------------------------------
// Checkpoint policy
// ---------------------------------------------------------------------------

/// Engine-side checkpoint policy: where to persist, how often (in interval
/// boundaries), and — for the crash-injection suite — after how many
/// intervals to simulate a crash.
#[derive(Debug, Clone)]
pub struct CheckpointSpec {
    /// Snapshot directory.
    pub dir: PathBuf,
    /// Snapshot every `every` interval boundaries (clamped to >= 1).
    pub every: u64,
    /// Deterministic crash injection: stop the run right after completing
    /// (and, if due, snapshotting) this many intervals.  `None` in
    /// production.
    pub crash_after: Option<u64>,
}

impl CheckpointSpec {
    pub fn new(dir: impl Into<PathBuf>, every: u64) -> Self {
        Self { dir: dir.into(), every: every.max(1), crash_after: None }
    }

    pub fn with_crash_after(mut self, intervals: u64) -> Self {
        self.crash_after = Some(intervals);
        self
    }

    /// Is a snapshot due after `intervals_done` completed intervals?
    pub fn due(&self, intervals_done: u64) -> bool {
        intervals_done > 0 && intervals_done % self.every.max(1) == 0
    }

    /// Should the run stop (simulated crash) after `intervals_done`?
    pub fn crashes_at(&self, intervals_done: u64) -> bool {
        self.crash_after == Some(intervals_done)
    }
}

// ---------------------------------------------------------------------------
// Whole-pipeline snapshot frame
// ---------------------------------------------------------------------------

/// Everything that distinguishes one run configuration from another for
/// recovery purposes.  A snapshot taken under one fingerprint refuses to
/// restore under a different one — silently resuming a `seed=1` run into a
/// `seed=2` pipeline would void the determinism contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConfigFingerprint {
    pub engine: u8,
    pub sampler: u8,
    pub workers: u64,
    pub seed: u64,
    pub window_size_ms: u64,
    pub window_slide_ms: u64,
    pub batch_interval_ms: u64,
    pub event_time: bool,
    pub watermark_skew_ms: u64,
    pub allowed_lateness_ms: u64,
    pub sketch_panes: bool,
    pub spill_ratio: u64,
}

impl ConfigFingerprint {
    /// Reject restore into a different configuration with a field-level
    /// diagnostic.
    pub fn check(&self, current: &ConfigFingerprint) -> Result<()> {
        if self == current {
            return Ok(());
        }
        let mut diffs = Vec::new();
        macro_rules! diff {
            ($field:ident) => {
                if self.$field != current.$field {
                    diffs.push(format!(
                        concat!(stringify!($field), " {:?} != {:?}"),
                        self.$field, current.$field
                    ));
                }
            };
        }
        diff!(engine);
        diff!(sampler);
        diff!(workers);
        diff!(seed);
        diff!(window_size_ms);
        diff!(window_slide_ms);
        diff!(batch_interval_ms);
        diff!(event_time);
        diff!(watermark_skew_ms);
        diff!(allowed_lateness_ms);
        diff!(sketch_panes);
        diff!(spill_ratio);
        Err(Error::Config(format!(
            "snapshot was taken under a different configuration: {}",
            diffs.join(", ")
        )))
    }
}

impl Snapshot for ConfigFingerprint {
    fn encode(&self, w: &mut SnapshotWriter) {
        w.put_u8(self.engine);
        w.put_u8(self.sampler);
        w.put_u64(self.workers);
        w.put_u64(self.seed);
        w.put_u64(self.window_size_ms);
        w.put_u64(self.window_slide_ms);
        w.put_u64(self.batch_interval_ms);
        w.put_bool(self.event_time);
        w.put_u64(self.watermark_skew_ms);
        w.put_u64(self.allowed_lateness_ms);
        w.put_bool(self.sketch_panes);
        w.put_u64(self.spill_ratio);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        Ok(Self {
            engine: r.get_u8()?,
            sampler: r.get_u8()?,
            workers: r.get_u64()?,
            seed: r.get_u64()?,
            window_size_ms: r.get_u64()?,
            window_slide_ms: r.get_u64()?,
            batch_interval_ms: r.get_u64()?,
            event_time: r.get_bool()?,
            watermark_skew_ms: r.get_u64()?,
            allowed_lateness_ms: r.get_u64()?,
            sketch_panes: r.get_bool()?,
            spill_ratio: r.get_u64()?,
        })
    }
}

/// The engines' whole-pipeline snapshot, taken at an interval boundary.
///
/// Worker sampler state travels as opaque per-worker blobs (the
/// `WorkerSampler` machine is private to `engine::worker`; the blobs are
/// produced/consumed by the acked `Msg::Snapshot` rendezvous).  The rest is
/// typed: assembler panes, sketch-window pane store, drop ledger, and the
/// cost/feedback controller.
#[derive(Debug)]
pub struct PipelineSnapshot {
    pub fingerprint: ConfigFingerprint,
    /// Completed intervals (the epoch stamp).
    pub epoch: u64,
    /// Broker offset: items consumed from the replayable source.
    pub item_offset: u64,
    /// Windows already emitted before the snapshot.
    pub windows_emitted: u64,
    /// Current sampling fraction (feedback output at the boundary).
    pub fraction: f64,
    /// Threaded transport's round-robin dispatch cursor — multi-worker
    /// interleave must resume exactly where it stopped.
    pub transport_cursor: u64,
    /// Per-worker serialized `WorkerSampler` state (RNG streams included).
    pub workers: Vec<Vec<u8>>,
    pub assembler: crate::window::WindowAssembler,
    pub sketches: Option<crate::query::SketchWindow>,
    pub ledger: crate::window::event_time::DropLedger,
    pub cost: crate::budget::CostFunction,
}

impl Snapshot for PipelineSnapshot {
    fn encode(&self, w: &mut SnapshotWriter) {
        self.fingerprint.encode(w);
        w.put_u64(self.epoch);
        w.put_u64(self.item_offset);
        w.put_u64(self.windows_emitted);
        w.put_f64(self.fraction);
        w.put_u64(self.transport_cursor);
        self.workers.encode(w);
        self.assembler.encode(w);
        self.sketches.encode(w);
        self.ledger.encode(w);
        self.cost.encode(w);
    }
    fn decode(r: &mut SnapshotReader) -> Result<Self> {
        Ok(Self {
            fingerprint: ConfigFingerprint::decode(r)?,
            epoch: r.get_u64()?,
            item_offset: r.get_u64()?,
            windows_emitted: r.get_u64()?,
            fraction: r.get_f64()?,
            transport_cursor: r.get_u64()?,
            workers: Vec::<Vec<u8>>::decode(r)?,
            assembler: crate::window::WindowAssembler::decode(r)?,
            sketches: Option::<crate::query::SketchWindow>::decode(r)?,
            ledger: crate::window::event_time::DropLedger::decode(r)?,
            cost: crate::budget::CostFunction::decode(r)?,
        })
    }
}

/// Tick the replayed-items counter (recovery's replay cost witness).
pub fn record_replayed_items(n: u64) {
    crate::obs_counter!(
        "recovery_replayed_items_total",
        "Items re-read from the broker offset during recovery replay"
    )
    .add(n);
}

/// Tick the restore counter (one per successful `Engine::recover`).
pub fn record_restore() {
    crate::obs_counter!("recovery_restores_total", "Successful pipeline restores").inc();
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn tmp_dir(tag: &str) -> PathBuf {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let n = SEQ.fetch_add(1, Ordering::Relaxed);
        let dir = std::env::temp_dir().join(format!(
            "sax_ckpt_{tag}_{}_{n}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn primitive_roundtrip_bit_exact() {
        let mut w = SnapshotWriter::new();
        w.put_u8(7);
        w.put_u16(65535);
        w.put_u32(123456);
        w.put_u64(u64::MAX);
        w.put_f64(-0.0);
        w.put_f64(f64::NAN);
        w.put_bool(true);
        w.put_bytes(b"abc");
        let bytes = w.into_bytes();
        let mut r = SnapshotReader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u16().unwrap(), 65535);
        assert_eq!(r.get_u32().unwrap(), 123456);
        assert_eq!(r.get_u64().unwrap(), u64::MAX);
        assert_eq!(r.get_f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert_eq!(r.get_f64().unwrap().to_bits(), f64::NAN.to_bits());
        assert!(r.get_bool().unwrap());
        assert_eq!(r.get_bytes().unwrap(), b"abc");
        assert!(r.is_exhausted());
    }

    #[test]
    fn reader_underrun_is_io_error() {
        let mut r = SnapshotReader::new(&[1, 2]);
        match r.get_u64() {
            Err(Error::Io(msg)) => assert!(msg.contains("truncated"), "{msg}"),
            other => panic!("expected Io error, got {other:?}"),
        }
    }

    #[test]
    fn rng_snapshot_continues_stream() {
        let mut rng = Rng::seed_from_u64(99);
        for _ in 0..10 {
            rng.normal(0.0, 1.0); // leaves a gauss spare half the time
        }
        let mut restored = Rng::from_snapshot_bytes(&rng.to_snapshot_bytes()).unwrap();
        for _ in 0..100 {
            assert_eq!(rng.normal(2.0, 3.0).to_bits(), restored.normal(2.0, 3.0).to_bits());
            assert_eq!(rng.next_u64(), restored.next_u64());
        }
    }

    #[test]
    fn frame_roundtrip_and_rejections() {
        let payload = b"hello snapshot".to_vec();
        let frame = encode_frame(&payload);
        assert_eq!(decode_frame(&frame).unwrap(), payload);

        // truncated
        match decode_frame(&frame[..5]) {
            Err(Error::Io(msg)) => assert!(msg.contains("truncated"), "{msg}"),
            other => panic!("{other:?}"),
        }
        // bad magic
        let mut bad = frame.clone();
        bad[0] ^= 0xFF;
        match decode_frame(&bad) {
            Err(Error::Config(msg)) => assert!(msg.contains("magic"), "{msg}"),
            other => panic!("{other:?}"),
        }
        // version mismatch
        let mut bad = frame.clone();
        bad[4] = bad[4].wrapping_add(1);
        match decode_frame(&bad) {
            Err(Error::Config(msg)) => assert!(msg.contains("version"), "{msg}"),
            other => panic!("{other:?}"),
        }
        // payload bit-flip
        let mut bad = frame.clone();
        bad[FRAME_HEADER + 2] ^= 0x10;
        match decode_frame(&bad) {
            Err(Error::Io(msg)) => assert!(msg.contains("checksum"), "{msg}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn store_writes_epochs_and_manifest() {
        let dir = tmp_dir("store");
        let store = CheckpointStore::create(&dir).unwrap();
        store.write_epoch(1, b"one").unwrap();
        store.write_epoch(2, b"two").unwrap();
        assert_eq!(store.epochs().unwrap(), vec![1, 2]);
        let manifest = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        let doc = json::parse(&manifest).unwrap();
        assert_eq!(doc.get("latest_epoch").unwrap().as_i64(), Some(2));
        assert_eq!(doc.get("epochs").unwrap().as_arr().unwrap().len(), 2);
        let loaded = store.load_latest().unwrap().unwrap();
        assert_eq!(loaded.epoch, 2);
        assert_eq!(loaded.payload, b"two");
        assert_eq!(loaded.skipped, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_latest_falls_back_past_corrupt_epoch() {
        let dir = tmp_dir("fallback");
        let store = CheckpointStore::create(&dir).unwrap();
        store.write_epoch(1, b"good").unwrap();
        store.write_epoch(2, b"newer").unwrap();
        // Corrupt the newest epoch in place (payload bit-flip).
        let path = store.epoch_path(2);
        let mut frame = std::fs::read(&path).unwrap();
        frame[FRAME_HEADER] ^= 0x01;
        std::fs::write(&path, &frame).unwrap();
        let loaded = store.load_latest().unwrap().unwrap();
        assert_eq!(loaded.epoch, 1);
        assert_eq!(loaded.payload, b"good");
        assert_eq!(loaded.skipped, 1, "exact-once fallback accounting");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_latest_all_corrupt_is_err() {
        let dir = tmp_dir("allbad");
        let store = CheckpointStore::create(&dir).unwrap();
        store.write_epoch(1, b"x").unwrap();
        let path = store.epoch_path(1);
        std::fs::write(&path, b"SA").unwrap(); // truncated beyond repair
        assert!(store.load_latest().is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_store_loads_none() {
        let dir = tmp_dir("empty");
        let store = CheckpointStore::create(&dir).unwrap();
        assert!(store.load_latest().unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spec_cadence_and_crash() {
        let spec = CheckpointSpec::new("/tmp/x", 2).with_crash_after(4);
        assert!(!spec.due(0));
        assert!(!spec.due(1));
        assert!(spec.due(2));
        assert!(spec.due(4));
        assert!(spec.crashes_at(4));
        assert!(!spec.crashes_at(3));
    }

    #[test]
    fn fingerprint_check_reports_fields() {
        let a = ConfigFingerprint {
            engine: 0,
            sampler: 1,
            workers: 2,
            seed: 42,
            window_size_ms: 2000,
            window_slide_ms: 1000,
            batch_interval_ms: 500,
            event_time: false,
            watermark_skew_ms: 0,
            allowed_lateness_ms: 0,
            sketch_panes: true,
            spill_ratio: 128,
        };
        let mut b = a;
        assert!(a.check(&b).is_ok());
        b.seed = 43;
        let msg = a.check(&b).unwrap_err().to_string();
        assert!(msg.contains("seed"), "{msg}");
    }
}
