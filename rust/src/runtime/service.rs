//! Thread-hosted compute service around [`XlaEngine`].
//!
//! PJRT handles are not `Send`, so the engine lives on a dedicated thread;
//! coordinator workers talk to it through a cloneable [`ComputeHandle`]
//! (crossbeam rendezvous per request).  This mirrors a real deployment where
//! the aggregation job is shipped to an executor service rather than run
//! inline in the router thread.
//!
//! Backend selection:
//! * [`Backend::Xla`] — the AOT artifacts via PJRT (the production path).
//! * [`Backend::Native`] — pure-Rust executor with identical semantics
//!   (baseline, tests, and environments without artifacts).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::core::{Error, Result};
use crate::util::channel::{bounded, Sender};

use super::checkpoint::CheckpointSpec;
use super::manifest::{default_artifacts_dir, Manifest};
use super::xla_engine::{RustExecutor, WindowInput, WindowOutput, XlaEngine};

/// Service-level durability options: periodic snapshots while a pipeline
/// runs, and restore-on-start.  Consumed by
/// [`crate::pipeline::Pipeline::run_items`], which dispatches to the
/// engines' `run_checkpointed`/`recover` entry points; the CLI's
/// `--checkpoint-dir`/`--checkpoint-every`/`--restore` flags build one of
/// these.
#[derive(Debug, Clone, Default)]
pub struct DurabilityOptions {
    /// Periodic snapshot policy (`None` = no checkpointing).
    pub checkpoint: Option<CheckpointSpec>,
    /// Restore from the newest valid snapshot in the checkpoint directory
    /// before processing (requires `checkpoint` to be set).
    pub restore_on_start: bool,
}

impl DurabilityOptions {
    /// Snapshot to `dir` every `every` interval boundaries.
    pub fn checkpoint_to(mut self, dir: impl Into<std::path::PathBuf>, every: u64) -> Self {
        self.checkpoint = Some(CheckpointSpec::new(dir, every));
        self
    }

    /// Restore from the newest valid snapshot before processing.
    pub fn restore_on_start(mut self, yes: bool) -> Self {
        self.restore_on_start = yes;
        self
    }
}

/// Which executor the service hosts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// AOT HLO artifacts on the PJRT CPU client.
    Xla,
    /// Pure-Rust reference executor.
    Native,
}

struct Request {
    input: WindowInput,
    reply: Sender<Result<WindowOutput>>,
}

/// Cloneable handle for submitting window-aggregation jobs.
pub struct ComputeHandle {
    tx: Sender<Request>,
    jobs: Arc<AtomicU64>,
    backend: Backend,
}

impl std::fmt::Debug for ComputeHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ComputeHandle")
            .field("backend", &self.backend)
            // ordering: monotonic stats counter, diagnostics only.
            .field("jobs", &self.jobs.load(Ordering::Relaxed))
            .finish_non_exhaustive()
    }
}

impl Clone for ComputeHandle {
    fn clone(&self) -> Self {
        Self { tx: self.tx.clone(), jobs: self.jobs.clone(), backend: self.backend }
    }
}

impl ComputeHandle {
    /// Execute one window-aggregation job (blocking rendezvous).
    pub fn aggregate(&self, input: WindowInput) -> Result<WindowOutput> {
        let (rtx, rrx) = bounded(1);
        self.tx
            .send(Request { input, reply: rtx })
            .map_err(|_| Error::Xla("compute service stopped".into()))?;
        // ordering: monotonic stats counter; the channel rendezvous is
        // the synchronizing hand-off.
        self.jobs.fetch_add(1, Ordering::Relaxed);
        rrx.recv()
            .ok_or_else(|| Error::Xla("compute service dropped reply".into()))?
    }

    /// Total jobs submitted through all clones of this handle.
    pub fn jobs_submitted(&self) -> u64 {
        // ordering: monotonic stats counter read for reporting only.
        self.jobs.load(Ordering::Relaxed)
    }

    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// On-demand snapshot of the process-wide observability registry — the
    /// service-level "scrape me" entry point (same data the CLI's
    /// `--metrics` export and `RunReport::metrics` deltas are built from).
    pub fn metrics_snapshot(&self) -> crate::obs::MetricsSnapshot {
        crate::obs::global().snapshot()
    }
}

/// Owns the service thread; dropping it shuts the thread down.
pub struct ComputeService {
    handle: ComputeHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

impl std::fmt::Debug for ComputeService {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ComputeService")
            .field("handle", &self.handle)
            .field("running", &self.join.is_some())
            .finish()
    }
}

impl ComputeService {
    /// Start a service with the given backend. For [`Backend::Xla`] the
    /// artifacts are loaded from `artifacts_dir` (default: auto-discover).
    pub fn start(backend: Backend, artifacts_dir: Option<std::path::PathBuf>) -> Result<Self> {
        let (tx, rx) = bounded::<Request>(1024);
        let (ready_tx, ready_rx) = bounded::<Result<()>>(1);

        let join = std::thread::Builder::new()
            .name("streamapprox-compute".into())
            .spawn(move || {
                enum Exec {
                    Xla(XlaEngine),
                    Native(RustExecutor),
                }
                let exec = match backend {
                    Backend::Native => {
                        let _ = ready_tx.send(Ok(()));
                        Exec::Native(RustExecutor)
                    }
                    Backend::Xla => {
                        let dir = artifacts_dir.unwrap_or_else(default_artifacts_dir);
                        match Manifest::load(&dir).and_then(|m| XlaEngine::load(&m)) {
                            Ok(engine) => {
                                let _ = ready_tx.send(Ok(()));
                                Exec::Xla(engine)
                            }
                            Err(e) => {
                                let _ = ready_tx.send(Err(e));
                                return;
                            }
                        }
                    }
                };
                while let Some(req) = rx.recv() {
                    let out = match &exec {
                        Exec::Xla(engine) => engine.aggregate(&req.input),
                        Exec::Native(r) => Ok(r.aggregate(&req.input)),
                    };
                    // Receiver may have timed out / dropped; ignore.
                    let _ = req.reply.send(out);
                }
            })
            .map_err(|e| Error::Xla(format!("spawn compute thread: {e}")))?;

        ready_rx
            .recv()
            .ok_or_else(|| Error::Xla("compute thread died during init".into()))??;

        Ok(Self {
            handle: ComputeHandle {
                tx,
                jobs: Arc::new(AtomicU64::new(0)),
                backend,
            },
            join: Some(join),
        })
    }

    /// Convenience: native-backend service (never fails on missing artifacts).
    pub fn native() -> Self {
        Self::start(Backend::Native, None).expect("native backend cannot fail")
    }

    /// Handle for submitting jobs (cloneable, Send + Sync).
    pub fn handle(&self) -> ComputeHandle {
        self.handle.clone()
    }

    /// On-demand registry snapshot (see [`ComputeHandle::metrics_snapshot`]).
    pub fn metrics_snapshot(&self) -> crate::obs::MetricsSnapshot {
        self.handle.metrics_snapshot()
    }
}

impl Drop for ComputeService {
    fn drop(&mut self) {
        // Closing the channel stops the worker loop.
        self.handle.tx.close();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::MAX_STRATA;
    use crate::error::estimator::K;

    fn input() -> WindowInput {
        let mut wi = WindowInput::default();
        for i in 0..100 {
            wi.ids.push((i % MAX_STRATA) as i32);
            wi.values.push(i as f32);
        }
        for i in 0..K {
            wi.c[i] = 20.0;
            wi.n_cap[i] = 10.0;
        }
        wi
    }

    #[test]
    fn native_service_roundtrip() {
        let svc = ComputeService::native();
        let h = svc.handle();
        let out = h.aggregate(input()).unwrap();
        assert!((out.partials.total_y() - 100.0).abs() < 1e-9);
        assert_eq!(h.jobs_submitted(), 1);
        assert_eq!(h.backend(), Backend::Native);
    }

    #[test]
    fn concurrent_submissions() {
        let svc = ComputeService::native();
        let mut joins = Vec::new();
        for _ in 0..8 {
            let h = svc.handle();
            joins.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    let out = h.aggregate(input()).unwrap();
                    assert!(out.estimate.sum.is_finite());
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(svc.handle().jobs_submitted(), 8 * 50);
    }

    #[test]
    fn xla_backend_missing_artifacts_errors() {
        let res = ComputeService::start(
            Backend::Xla,
            Some(std::path::PathBuf::from("/nonexistent")),
        );
        assert!(res.is_err());
    }
}
