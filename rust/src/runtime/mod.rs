//! Runtime layer: loads the AOT-compiled HLO artifacts (produced by
//! `python/compile/aot.py`) and executes the per-window aggregation job via
//! the `xla` crate's PJRT CPU client.  Python never runs here — the HLO text
//! files in `artifacts/` are the only hand-off.
//!
//! * [`manifest`] — parses `artifacts/manifest.json`.
//! * [`xla_engine`] — compiles + executes the HLO variants; chunk-combining
//!   for oversized windows; plus a semantics-identical pure-Rust executor.
//! * [`service`] — hosts the engine on a dedicated thread (PJRT handles are
//!   not `Send`) behind a cloneable handle.

pub mod checkpoint;
pub mod manifest;
pub mod service;
pub mod xla_engine;

pub use checkpoint::{
    CheckpointSpec, CheckpointStore, ConfigFingerprint, PipelineSnapshot, Snapshot,
    SnapshotReader, SnapshotWriter,
};
pub use manifest::{default_artifacts_dir, Manifest};
pub use service::{Backend, ComputeHandle, ComputeService, DurabilityOptions};
pub use xla_engine::{RustExecutor, WindowInput, WindowOutput, XlaEngine};
