//! Artifact manifest: describes the AOT-compiled HLO variants produced by
//! `python/compile/aot.py` (shapes, output layout, file names).  Parsed with
//! the in-tree JSON parser (`util::json`).

use std::path::{Path, PathBuf};

use crate::core::{Error, Result};
use crate::util::json::{parse, Value};

/// One AOT variant: an HLO module compiled for a fixed item capacity.
#[derive(Debug, Clone)]
pub struct Variant {
    /// Static item capacity N of this module.
    pub n_items: usize,
    /// Number of strata K.
    pub num_strata: usize,
    /// File name (relative to the artifacts dir).
    pub file: String,
}

/// Output descriptor (name + shape) for sanity checks.
#[derive(Debug, Clone)]
pub struct OutputDesc {
    pub name: String,
    pub shape: Vec<usize>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub num_strata: usize,
    pub pad_id: i32,
    pub outputs: Vec<OutputDesc>,
    pub variants: Vec<Variant>,
    pub jax_version: String,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

fn field<'a>(v: &'a Value, key: &str) -> Result<&'a Value> {
    v.get(key)
        .ok_or_else(|| Error::Artifact(format!("manifest missing field {key:?}")))
}

impl Manifest {
    /// Load and validate `manifest.json` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!("cannot read {}: {e}", path.display()))
        })?;
        let v = parse(&text).map_err(Error::Artifact)?;

        let num_strata = field(&v, "num_strata")?
            .as_i64()
            .ok_or_else(|| Error::Artifact("num_strata not a number".into()))?
            as usize;
        let pad_id = field(&v, "pad_id")?
            .as_i64()
            .ok_or_else(|| Error::Artifact("pad_id not a number".into()))? as i32;

        let mut outputs = Vec::new();
        for o in field(&v, "outputs")?
            .as_arr()
            .ok_or_else(|| Error::Artifact("outputs not an array".into()))?
        {
            outputs.push(OutputDesc {
                name: field(o, "name")?
                    .as_str()
                    .ok_or_else(|| Error::Artifact("output name not a string".into()))?
                    .to_string(),
                shape: field(o, "shape")?
                    .as_arr()
                    .ok_or_else(|| Error::Artifact("shape not an array".into()))?
                    .iter()
                    .filter_map(|x| x.as_i64())
                    .map(|x| x as usize)
                    .collect(),
            });
        }

        let mut variants = Vec::new();
        for var in field(&v, "variants")?
            .as_arr()
            .ok_or_else(|| Error::Artifact("variants not an array".into()))?
        {
            variants.push(Variant {
                n_items: field(var, "n_items")?
                    .as_i64()
                    .ok_or_else(|| Error::Artifact("n_items not a number".into()))?
                    as usize,
                num_strata: field(var, "num_strata")?
                    .as_i64()
                    .ok_or_else(|| Error::Artifact("num_strata not a number".into()))?
                    as usize,
                file: field(var, "file")?
                    .as_str()
                    .ok_or_else(|| Error::Artifact("file not a string".into()))?
                    .to_string(),
            });
        }

        let jax_version = v
            .get("jax_version")
            .and_then(|x| x.as_str())
            .unwrap_or("")
            .to_string();

        let m = Manifest { num_strata, pad_id, outputs, variants, jax_version, dir: dir.to_path_buf() };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        if self.variants.is_empty() {
            return Err(Error::Artifact("manifest has no variants".into()));
        }
        let names: Vec<&str> = self.outputs.iter().map(|o| o.name.as_str()).collect();
        if names != ["partials", "weights", "strata_sums", "scalars"] {
            return Err(Error::Artifact(format!(
                "unexpected output layout: {names:?}"
            )));
        }
        for v in &self.variants {
            if v.num_strata != self.num_strata {
                return Err(Error::Artifact(format!(
                    "variant {} strata mismatch: {} != {}",
                    v.file, v.num_strata, self.num_strata
                )));
            }
            if !self.dir.join(&v.file).exists() {
                return Err(Error::Artifact(format!(
                    "missing artifact file {}",
                    self.dir.join(&v.file).display()
                )));
            }
        }
        Ok(())
    }

    /// Variants sorted ascending by capacity.
    pub fn sorted_variants(&self) -> Vec<&Variant> {
        let mut v: Vec<&Variant> = self.variants.iter().collect();
        v.sort_by_key(|v| v.n_items);
        v
    }

    /// Largest capacity available.
    pub fn max_capacity(&self) -> usize {
        self.variants.iter().map(|v| v.n_items).max().unwrap_or(0)
    }

    /// Path of a variant's HLO text file.
    pub fn variant_path(&self, v: &Variant) -> PathBuf {
        self.dir.join(&v.file)
    }
}

/// Resolve the default artifacts dir: `$STREAMAPPROX_ARTIFACTS` or the
/// nearest ancestor `artifacts/` containing a manifest.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("STREAMAPPROX_ARTIFACTS") {
        return PathBuf::from(d);
    }
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_manifest() {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.num_strata, crate::core::MAX_STRATA);
        assert_eq!(m.pad_id, -1);
        assert!(m.max_capacity() >= 1024);
        let sorted = m.sorted_variants();
        assert!(sorted.windows(2).all(|w| w[0].n_items < w[1].n_items));
    }

    #[test]
    fn missing_dir_errors() {
        assert!(Manifest::load("/nonexistent/nowhere").is_err());
    }

    #[test]
    fn bad_layout_rejected() {
        let dir = std::env::temp_dir().join(format!("sa-manifest-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"num_strata":16,"pad_id":-1,"outputs":[{"name":"x","shape":[1]}],"variants":[{"n_items":8,"num_strata":16,"file":"f.hlo.txt"}]}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_variant_file_rejected() {
        let dir = std::env::temp_dir().join(format!("sa-manifest-test2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"num_strata":16,"pad_id":-1,
                "outputs":[{"name":"partials","shape":[16,3]},{"name":"weights","shape":[16]},
                           {"name":"strata_sums","shape":[16]},{"name":"scalars","shape":[6]}],
                "variants":[{"n_items":8,"num_strata":16,"file":"missing.hlo.txt"}]}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
