//! PJRT-backed execution of the AOT window-aggregation artifacts.
//!
//! Loads each HLO-text variant once, compiles it on the PJRT CPU client, and
//! executes it with padded fixed-shape inputs.  Samples larger than the
//! biggest variant are chunked; per-stratum partials combine associatively
//! and the estimate is finished with `error::estimator` (the same arithmetic
//! as the in-graph epilogue — cross-checked in tests).
//!
//! `XlaEngine` holds raw PJRT pointers and is **not** `Send`; the
//! [`super::service::ComputeService`] wraps it in a dedicated thread for the
//! multi-worker coordinator.

use crate::core::{Error, Result};
#[cfg(any(feature = "xla", test))]
use crate::core::MAX_STRATA;
use crate::error::estimator::{estimate, Estimate, StrataPartials, StrataState, K};

use super::manifest::Manifest;

/// Input of one window-aggregation job (already sampled + weighted counters).
#[derive(Debug, Clone, Default)]
pub struct WindowInput {
    /// Stratum id per sampled item.
    pub ids: Vec<i32>,
    /// Value per sampled item.
    pub values: Vec<f32>,
    /// Per-stratum arrival counters C_i.
    pub c: [f64; K],
    /// Per-stratum reservoir capacities N_i.
    pub n_cap: [f64; K],
}

impl WindowInput {
    /// Build from (stratum, value) pairs + counters.
    pub fn from_sample(sample: &[(u16, f64)], state: &StrataState) -> Self {
        Self::from_parts(&[sample], state)
    }

    /// Build from a window sample held as several contiguous slices in pane
    /// order (the window assembler's zero-copy [`crate::window::WindowView`]
    /// hands its deque halves straight here — no per-slide re-merge).
    pub fn from_parts(parts: &[&[(u16, f64)]], state: &StrataState) -> Self {
        let len = parts.iter().map(|p| p.len()).sum();
        let mut ids = Vec::with_capacity(len);
        let mut values = Vec::with_capacity(len);
        for part in parts {
            for &(s, v) in *part {
                ids.push(s as i32);
                values.push(v as f32);
            }
        }
        Self { ids, values, c: state.c, n_cap: state.n_cap }
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    fn strata_state(&self) -> StrataState {
        StrataState { c: self.c, n_cap: self.n_cap }
    }
}

/// Output of one window-aggregation job.
#[derive(Debug, Clone)]
pub struct WindowOutput {
    /// Combined per-stratum partials.
    pub partials: StrataPartials,
    /// Finished estimate (Eq. 1-9).
    pub estimate: Estimate,
    /// Number of XLA executions this job needed (1 unless chunked).
    pub executions: u32,
}

#[cfg(feature = "xla")]
struct CompiledVariant {
    n_items: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// PJRT CPU engine holding compiled variants of the window-aggregation HLO.
///
/// Only compiled with the `xla` cfg-feature (the offline default build has
/// no `xla` crate); without it a stub with the same API reports the backend
/// as unavailable and callers fall back to [`RustExecutor`].
#[cfg(feature = "xla")]
pub struct XlaEngine {
    client: xla::PjRtClient,
    variants: Vec<CompiledVariant>,
    num_strata: usize,
}

#[cfg(feature = "xla")]
impl std::fmt::Debug for XlaEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaEngine")
            .field("variants", &self.variants.len())
            .field("num_strata", &self.num_strata)
            .finish_non_exhaustive()
    }
}

#[cfg(feature = "xla")]
impl XlaEngine {
    /// Compile every variant in the manifest on a fresh PJRT CPU client.
    pub fn load(manifest: &Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| Error::Xla(e.to_string()))?;
        let mut variants = Vec::new();
        for v in manifest.sorted_variants() {
            let path = manifest.variant_path(v);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| Error::Artifact("non-utf8 path".into()))?,
            )
            .map_err(|e| Error::Xla(format!("parse {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| Error::Xla(format!("compile {}: {e}", path.display())))?;
            variants.push(CompiledVariant { n_items: v.n_items, exe });
        }
        Ok(Self { client, variants, num_strata: manifest.num_strata })
    }

    /// Platform name of the underlying PJRT client (for logs).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Capacity of the largest compiled variant.
    pub fn max_capacity(&self) -> usize {
        self.variants.last().map(|v| v.n_items).unwrap_or(0)
    }

    fn pick_variant(&self, len: usize) -> &CompiledVariant {
        self.variants
            .iter()
            .find(|v| v.n_items >= len)
            .unwrap_or_else(|| self.variants.last().expect("no variants"))
    }

    /// Run the window-aggregation job, chunking if the sample exceeds the
    /// largest variant.
    pub fn aggregate(&self, input: &WindowInput) -> Result<WindowOutput> {
        debug_assert_eq!(self.num_strata, MAX_STRATA);
        let max = self.max_capacity();
        let state = input.strata_state();

        if input.len() <= max {
            let (partials, estimate) = self.execute_chunk(
                &input.ids,
                &input.values,
                &input.c,
                &input.n_cap,
                true,
            )?;
            return Ok(WindowOutput {
                partials,
                estimate: estimate.expect("estimate requested"),
                executions: 1,
            });
        }

        // Chunked path: combine partials, finish estimate Rust-side.
        let mut combined = StrataPartials::default();
        let mut execs = 0;
        for (ids, values) in input
            .ids
            .chunks(max)
            .zip(input.values.chunks(max))
        {
            let (p, _) = self.execute_chunk(ids, values, &input.c, &input.n_cap, false)?;
            combined.merge(&p);
            execs += 1;
        }
        let est = estimate(&combined, &state);
        Ok(WindowOutput { partials: combined, estimate: est, executions: execs })
    }

    /// Execute one padded chunk. Returns partials, and the in-graph estimate
    /// when `want_estimate` (only meaningful when the chunk is the whole
    /// sample — the graph's C_i are window-level counters).
    fn execute_chunk(
        &self,
        ids: &[i32],
        values: &[f32],
        c: &[f64; K],
        n_cap: &[f64; K],
        want_estimate: bool,
    ) -> Result<(StrataPartials, Option<Estimate>)> {
        let variant = self.pick_variant(ids.len());
        let n = variant.n_items;

        // Pad to the variant's static shape; id -1 = padding.
        let mut ids_p = vec![-1i32; n];
        ids_p[..ids.len()].copy_from_slice(ids);
        let mut vals_p = vec![0f32; n];
        vals_p[..values.len()].copy_from_slice(values);
        let c_f: Vec<f32> = c.iter().map(|&x| x as f32).collect();
        let n_f: Vec<f32> = n_cap.iter().map(|&x| x as f32).collect();

        let lit_ids = xla::Literal::vec1(&ids_p);
        let lit_vals = xla::Literal::vec1(&vals_p);
        let lit_c = xla::Literal::vec1(&c_f);
        let lit_n = xla::Literal::vec1(&n_f);

        let result = variant
            .exe
            .execute::<xla::Literal>(&[lit_ids, lit_vals, lit_c, lit_n])
            .map_err(|e| Error::Xla(e.to_string()))?[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Xla(e.to_string()))?;

        let outs = result.to_tuple().map_err(|e| Error::Xla(e.to_string()))?;
        if outs.len() != 4 {
            return Err(Error::Xla(format!("expected 4 outputs, got {}", outs.len())));
        }

        let partials_flat: Vec<f32> =
            outs[0].to_vec().map_err(|e| Error::Xla(e.to_string()))?;
        let scalars: Vec<f32> = outs[3].to_vec().map_err(|e| Error::Xla(e.to_string()))?;
        let weights_v: Vec<f32> = outs[1].to_vec().map_err(|e| Error::Xla(e.to_string()))?;
        let strata_sums_v: Vec<f32> =
            outs[2].to_vec().map_err(|e| Error::Xla(e.to_string()))?;

        let mut partials = StrataPartials::default();
        for i in 0..K {
            partials.y[i] = partials_flat[i * 3] as f64;
            partials.sum[i] = partials_flat[i * 3 + 1] as f64;
            partials.sumsq[i] = partials_flat[i * 3 + 2] as f64;
        }

        let est = if want_estimate {
            let mut weights = [0.0f64; K];
            let mut strata_sums = [0.0f64; K];
            for i in 0..K {
                weights[i] = weights_v[i] as f64;
                strata_sums[i] = strata_sums_v[i] as f64;
            }
            Some(Estimate {
                sum: scalars[0] as f64,
                mean: scalars[1] as f64,
                var_sum: scalars[2] as f64,
                var_mean: scalars[3] as f64,
                total_c: scalars[4] as f64,
                total_y: scalars[5] as f64,
                weights,
                strata_sums,
            })
        } else {
            None
        };
        Ok((partials, est))
    }
}

/// API-compatible stub for builds without the `xla` cfg-feature: loading
/// always fails with a descriptive error, so `Backend::Xla` degrades into
/// the documented "artifacts unavailable" path and every caller's fallback
/// to the native executor keeps working.
#[cfg(not(feature = "xla"))]
pub struct XlaEngine {
    _private: (),
}

#[cfg(not(feature = "xla"))]
impl std::fmt::Debug for XlaEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaEngine").field("available", &false).finish()
    }
}

#[cfg(not(feature = "xla"))]
impl XlaEngine {
    pub fn load(manifest: &Manifest) -> Result<Self> {
        let _ = manifest;
        Err(Error::Xla(
            "built without the `xla` feature (offline build); use Backend::Native".into(),
        ))
    }

    pub fn platform(&self) -> String {
        "unavailable".into()
    }

    pub fn max_capacity(&self) -> usize {
        0
    }

    pub fn aggregate(&self, input: &WindowInput) -> Result<WindowOutput> {
        let _ = input;
        Err(Error::Xla("xla backend not compiled in".into()))
    }
}

/// Pure-Rust executor with identical semantics — used as the baseline
/// "native aggregation" backend, in tests, and wherever spinning up PJRT is
/// unnecessary.
#[derive(Debug, Default, Clone, Copy)]
pub struct RustExecutor;

impl RustExecutor {
    pub fn aggregate(&self, input: &WindowInput) -> WindowOutput {
        let mut partials = StrataPartials::default();
        for (&id, &v) in input.ids.iter().zip(&input.values) {
            if id >= 0 && (id as usize) < K {
                partials.push(id as usize, v as f64);
            }
        }
        let est = estimate(&partials, &input.strata_state());
        WindowOutput { partials, estimate: est, executions: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_input(n: usize, seed: u64) -> WindowInput {
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from_u64(seed);
        let mut input = WindowInput::default();
        for _ in 0..n {
            let s = rng.range_usize(0, MAX_STRATA) as i32;
            input.ids.push(s);
            input.values.push(rng.range_f64(-50.0, 150.0) as f32);
        }
        for i in 0..K {
            input.c[i] = input.ids.iter().filter(|&&x| x == i as i32).count() as f64 * 2.0;
            input.n_cap[i] = 64.0;
        }
        input
    }

    #[test]
    fn rust_executor_matches_estimator_by_construction() {
        let input = test_input(500, 1);
        let out = RustExecutor.aggregate(&input);
        assert_eq!(out.executions, 0);
        assert!((out.partials.total_y() - 500.0).abs() < 1e-9);
        assert!(out.estimate.sum.is_finite());
    }

    #[test]
    fn window_input_from_sample() {
        let sample = vec![(0u16, 1.0), (3u16, 2.5)];
        let mut st = StrataState::default();
        st.c[0] = 5.0;
        st.n_cap = [10.0; K];
        let wi = WindowInput::from_sample(&sample, &st);
        assert_eq!(wi.ids, vec![0, 3]);
        assert_eq!(wi.values, vec![1.0f32, 2.5f32]);
        assert_eq!(wi.c[0], 5.0);
    }

    // XLA-backed tests live in rust/tests/runtime_xla.rs (integration) so a
    // unit-test run without artifacts still passes.
}
