//! The public face of StreamApprox: a builder that assembles source,
//! sampler, engine, window, query, budget, and compute backend into a
//! runnable pipeline (paper Fig. 1 / Algorithm 2).
//!
//! ```text
//! input stream -> [broker] -> engine{ sampler -> windows } -> XLA query
//!                                 -> output ± error bound, feedback loop
//! ```

use crate::budget::{CostFunction, QueryBudget};
use crate::core::{Item, Result};
use crate::engine::batched::BatchedEngine;
use crate::engine::pipelined::PipelinedEngine;
use crate::engine::{EngineConfig, EngineKind, RunReport};
use crate::core::Error;
use crate::query::{Query, QueryExecutor};
use crate::runtime::{Backend, ComputeHandle, ComputeService, DurabilityOptions};
use crate::sampling::SamplerKind;
use crate::sketch::SketchParams;
use crate::stream::{StreamConfig, StreamGenerator};
use crate::window::{EventTimeConfig, WindowConfig};

/// Builder for a [`Pipeline`].
#[derive(Debug, Clone)]
pub struct PipelineBuilder {
    engine: EngineKind,
    sampler: SamplerKind,
    budget: QueryBudget,
    query: Query,
    window: WindowConfig,
    batch_interval_ms: u64,
    workers: usize,
    nodes: usize,
    track_exact: bool,
    sketch_panes: bool,
    spill_ratio: usize,
    seed: u64,
    sketch: SketchParams,
    event_time: Option<EventTimeConfig>,
    durability: DurabilityOptions,
}

impl Default for PipelineBuilder {
    fn default() -> Self {
        Self {
            engine: EngineKind::Pipelined,
            sampler: SamplerKind::Oasrs,
            budget: QueryBudget::SamplingFraction(0.6),
            query: Query::Sum,
            window: WindowConfig::paper_default(),
            batch_interval_ms: 500,
            workers: 1,
            nodes: 1,
            track_exact: true,
            sketch_panes: true,
            spill_ratio: 128,
            seed: 42,
            sketch: SketchParams::default(),
            event_time: None,
            durability: DurabilityOptions::default(),
        }
    }
}

impl PipelineBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn engine(mut self, kind: EngineKind) -> Self {
        self.engine = kind;
        self
    }

    pub fn sampler(mut self, kind: SamplerKind) -> Self {
        self.sampler = kind;
        self
    }

    pub fn budget(mut self, budget: QueryBudget) -> Self {
        self.budget = budget;
        self
    }

    pub fn query(mut self, query: Query) -> Self {
        self.query = query;
        self
    }

    pub fn window(mut self, window: WindowConfig) -> Self {
        self.window = window;
        self
    }

    pub fn batch_interval_ms(mut self, ms: u64) -> Self {
        self.batch_interval_ms = ms;
        self
    }

    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    pub fn nodes(mut self, nodes: usize) -> Self {
        self.nodes = nodes;
        self
    }

    pub fn track_exact(mut self, yes: bool) -> Self {
        self.track_exact = yes;
        self
    }

    /// Sketch-backed queries over pane-level sketches (two-stacks pane
    /// store; the default) vs the seed's per-window sketch rebuild.  See
    /// [`crate::query::SketchWindow`] for the weighting difference.
    pub fn sketch_pane_windows(mut self, yes: bool) -> Self {
        self.sketch_panes = yes;
        self
    }

    /// Window/slide ratio at or above which sketch-backed queries spill
    /// the window's sample deque to compressed pane summaries (the pane
    /// sketches arrive pre-built from the ingest workers, so the sample
    /// has no reader on that path).  Default 128; set 1 to always spill,
    /// `usize::MAX` to never.
    pub fn sample_spill_ratio(mut self, ratio: usize) -> Self {
        self.spill_ratio = ratio.max(1);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Event-time windowing: assign panes from each item's `ts` (instead of
    /// arrival order) under a bounded-skew low-watermark, keeping each pane
    /// open for `allowed_lateness_ms` of watermark time past its end.
    /// Within-lateness stragglers merge into their true pane; items later
    /// than that are dropped, counted (`late_items_dropped_total`,
    /// [`crate::engine::WindowReport::late_dropped`]) and charged into the
    /// affected window's error bound.  Off by default — the legacy
    /// arrival-order slicing stays byte-identical.
    pub fn event_time(mut self, watermark_skew_ms: u64, allowed_lateness_ms: u64) -> Self {
        self.event_time = Some(EventTimeConfig::new(watermark_skew_ms, allowed_lateness_ms));
        self
    }

    /// Tune the mergeable sketches behind `Query::Quantile` /
    /// `Query::Distinct` / `Query::TopK` (accuracy ↔ space knobs).
    pub fn sketch_params(mut self, params: SketchParams) -> Self {
        self.sketch = params;
        self
    }

    /// Persist an epoch-stamped pipeline snapshot to `dir` every `every`
    /// interval boundaries (see [`crate::runtime::checkpoint`]).
    pub fn checkpoint_to(mut self, dir: impl Into<std::path::PathBuf>, every: u64) -> Self {
        self.durability = self.durability.checkpoint_to(dir, every);
        self
    }

    /// Restore from the newest valid snapshot in the checkpoint directory
    /// before processing, replaying from the recorded broker offset with
    /// restored sampler/window state.  Requires [`Self::checkpoint_to`].
    pub fn restore_on_start(mut self, yes: bool) -> Self {
        self.durability = self.durability.restore_on_start(yes);
        self
    }

    /// Set the full durability options in one call (service-level API; the
    /// two builder methods above are sugar over this).
    pub fn durability(mut self, options: DurabilityOptions) -> Self {
        self.durability = options;
        self
    }

    /// Build with the pure-Rust compute backend (no artifacts needed).
    pub fn build_native(self) -> Pipeline {
        let svc = ComputeService::native();
        let handle = svc.handle();
        self.finish(Some(svc), handle)
    }

    /// Build with the XLA/PJRT backend (loads `artifacts/`).
    pub fn build_xla(self) -> Result<Pipeline> {
        let svc = ComputeService::start(Backend::Xla, None)?;
        let handle = svc.handle();
        Ok(self.finish(Some(svc), handle))
    }

    /// Build on a shared compute handle (lets many pipelines reuse one
    /// compiled artifact set — the benchmark harness does this).
    pub fn build_with_handle(self, handle: ComputeHandle) -> Pipeline {
        self.finish(None, handle)
    }

    fn finish(self, service: Option<ComputeService>, handle: ComputeHandle) -> Pipeline {
        let config = EngineConfig {
            kind: self.engine,
            batch_interval_ms: self.batch_interval_ms,
            workers: self.workers * self.nodes.max(1),
            nodes: self.nodes,
            track_exact: self.track_exact,
            channel_capacity: 16 * 1024,
            sketch_panes: self.sketch_panes,
            spill_ratio: self.spill_ratio,
            seed: self.seed,
            event_time: self.event_time,
        };
        Pipeline {
            config,
            window: self.window,
            query: self.query,
            sampler: self.sampler,
            budget: self.budget,
            durability: self.durability,
            executor: QueryExecutor::new(handle).with_sketch_params(self.sketch),
            _service: service,
        }
    }
}

/// A runnable StreamApprox pipeline.
pub struct Pipeline {
    config: EngineConfig,
    window: WindowConfig,
    query: Query,
    sampler: SamplerKind,
    budget: QueryBudget,
    durability: DurabilityOptions,
    executor: QueryExecutor,
    /// Owned compute service (None when sharing a handle).
    _service: Option<ComputeService>,
}

impl std::fmt::Debug for Pipeline {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pipeline")
            .field("config", &self.config)
            .field("window", &self.window)
            .field("query", &self.query)
            .field("sampler", &self.sampler)
            .field("budget", &self.budget)
            .field("durability", &self.durability)
            .finish_non_exhaustive()
    }
}

/// Convenience alias for the run outcome.
pub type PipelineReport = RunReport;

impl Pipeline {
    /// Run over a pre-generated, event-time-sorted trace.
    ///
    /// Errors when the query/budget combination is invalid (sketch-backed
    /// query under a `TargetRelativeError` budget — the engines validate
    /// this, so direct engine users get the same rejection).
    pub fn run_items(&self, items: &[Item]) -> Result<RunReport> {
        let mut cost = CostFunction::new(self.budget.clone());
        let ckpt = self.durability.checkpoint.as_ref();
        if self.durability.restore_on_start && ckpt.is_none() {
            return Err(Error::Config(
                "restore_on_start requires a checkpoint directory (set checkpoint_to)".into(),
            ));
        }
        match self.config.kind {
            EngineKind::Batched => {
                let engine = BatchedEngine::new(
                    &self.config,
                    self.window,
                    self.query.clone(),
                    &self.executor,
                );
                match ckpt {
                    Some(spec) if self.durability.restore_on_start => {
                        engine.recover(items, self.sampler, &mut cost, spec)
                    }
                    Some(spec) => engine.run_checkpointed(items, self.sampler, &mut cost, spec),
                    None => engine.run(items, self.sampler, &mut cost),
                }
            }
            EngineKind::Pipelined => {
                let engine = PipelinedEngine::new(
                    &self.config,
                    self.window,
                    self.query.clone(),
                    &self.executor,
                );
                match ckpt {
                    Some(spec) if self.durability.restore_on_start => {
                        engine.recover(items, self.sampler, &mut cost, spec)
                    }
                    Some(spec) => engine.run_checkpointed(items, self.sampler, &mut cost, spec),
                    None => engine.run(items, self.sampler, &mut cost),
                }
            }
        }
    }

    /// Generate `duration_ms` of a synthetic stream and run over it.
    pub fn run_stream(&self, stream: &StreamConfig, duration_ms: u64) -> Result<RunReport> {
        let items = StreamGenerator::new(stream).take_until(duration_ms);
        self.run_items(&items)
    }

    pub fn sampler(&self) -> SamplerKind {
        self.sampler
    }

    pub fn engine_kind(&self) -> EngineKind {
        self.config.kind
    }

    pub fn window_config(&self) -> WindowConfig {
        self.window
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_run() {
        let p = PipelineBuilder::new()
            .window(WindowConfig::new(2_000, 1_000))
            .build_native();
        let r = p
            .run_stream(&StreamConfig::gaussian_micro(100.0, 3), 6_000)
            .unwrap();
        assert!(!r.windows.is_empty());
        assert!(r.throughput() > 0.0);
    }

    #[test]
    fn batched_and_pipelined_same_accuracy_class() {
        let mk = |kind| {
            PipelineBuilder::new()
                .engine(kind)
                .sampler(SamplerKind::Oasrs)
                .budget(QueryBudget::SamplingFraction(0.6))
                .window(WindowConfig::new(2_000, 1_000))
                .build_native()
        };
        let stream = StreamConfig::gaussian_micro(100.0, 5);
        let rb = mk(EngineKind::Batched).run_stream(&stream, 10_000).unwrap();
        let rp = mk(EngineKind::Pipelined).run_stream(&stream, 10_000).unwrap();
        assert!(rb.mean_accuracy_loss() < 0.05);
        assert!(rp.mean_accuracy_loss() < 0.05);
    }

    #[test]
    fn shared_handle_pipelines() {
        let svc = ComputeService::native();
        let a = PipelineBuilder::new()
            .window(WindowConfig::tumbling(1_000))
            .build_with_handle(svc.handle());
        let b = PipelineBuilder::new()
            .sampler(SamplerKind::Srs)
            .window(WindowConfig::tumbling(1_000))
            .build_with_handle(svc.handle());
        let stream = StreamConfig::gaussian_micro(100.0, 6);
        assert!(!a.run_stream(&stream, 4_000).unwrap().windows.is_empty());
        assert!(!b.run_stream(&stream, 4_000).unwrap().windows.is_empty());
    }

    #[test]
    fn accuracy_budget_rejected_for_sketch_queries() {
        let p = PipelineBuilder::new()
            .budget(QueryBudget::TargetRelativeError { target: 0.01, initial_fraction: 0.1 })
            .query(Query::TopK(3))
            .window(WindowConfig::tumbling(1_000))
            .build_native();
        let err = p.run_stream(&StreamConfig::gaussian_micro(100.0, 4), 2_000);
        assert!(err.is_err(), "sketch query + accuracy budget must be rejected");
        let msg = err.err().unwrap().to_string();
        assert!(msg.contains("top-k"), "unhelpful error: {msg}");
    }

    #[test]
    fn sketch_queries_end_to_end() {
        let stream = StreamConfig::gaussian_micro(200.0, 8);
        for query in [Query::Quantile(0.5), Query::Distinct, Query::TopK(3)] {
            let p = PipelineBuilder::new()
                .query(query.clone())
                .window(WindowConfig::new(2_000, 1_000))
                .sketch_params(crate::sketch::SketchParams {
                    quantile_clusters: 128,
                    ..Default::default()
                })
                .build_native();
            let r = p.run_stream(&stream, 6_000).unwrap();
            assert!(!r.windows.is_empty(), "{query:?} produced no windows");
            for w in &r.windows {
                assert!(w.result.value().is_finite(), "{query:?} non-finite value");
            }
        }
    }

    #[test]
    fn sketch_pane_and_per_window_paths_agree() {
        // Same stream, same seeds: the pane-incremental path (default) and
        // the seed's per-window rebuild must agree — exactly for Distinct
        // (HLL register max is partition-invariant over the same item
        // multiset), and within weighting-granularity slack for the
        // weighted sketches (panes weight by interval counters, the
        // per-window path by span counters).
        let stream = StreamConfig::gaussian_micro(200.0, 12);
        let mk = |query: Query, panes: bool| {
            PipelineBuilder::new()
                .query(query)
                .window(WindowConfig::new(4_000, 1_000))
                .sketch_pane_windows(panes)
                .build_native()
        };
        for query in [Query::Quantile(0.95), Query::Distinct, Query::TopK(2)] {
            let rp = mk(query.clone(), true).run_stream(&stream, 10_000).unwrap();
            let rw = mk(query.clone(), false).run_stream(&stream, 10_000).unwrap();
            assert_eq!(rp.windows.len(), rw.windows.len());
            for (a, b) in rp.windows.iter().zip(rw.windows.iter()) {
                assert_eq!(a.end_ms, b.end_ms);
                let (va, vb) = (a.result.value(), b.result.value());
                match &query {
                    Query::Distinct => assert_eq!(va, vb, "distinct diverged"),
                    Query::TopK(_) => {
                        let ka: Vec<u64> =
                            a.result.top_k.as_ref().unwrap().iter().map(|&(k, _)| k).collect();
                        let kb: Vec<u64> =
                            b.result.top_k.as_ref().unwrap().iter().map(|&(k, _)| k).collect();
                        assert_eq!(ka, kb, "top-k ranking diverged");
                        assert!((va - vb).abs() <= 0.2 * vb.abs().max(1.0), "{va} vs {vb}");
                    }
                    _ => {
                        assert!(va.is_finite() && vb.is_finite());
                        assert!((va - vb).abs() <= 0.25 * vb.abs().max(1.0), "{va} vs {vb}");
                    }
                }
            }
        }
    }

    #[test]
    fn accessors() {
        let p = PipelineBuilder::new().sampler(SamplerKind::Sts).build_native();
        assert_eq!(p.sampler(), SamplerKind::Sts);
        assert_eq!(p.engine_kind(), EngineKind::Pipelined);
        assert_eq!(p.window_config(), WindowConfig::paper_default());
    }
}
