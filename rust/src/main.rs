//! StreamApprox CLI — leader entrypoint (hand-rolled arg parsing; the
//! offline build environment has no clap).
//!
//! ```text
//! streamapprox info
//! streamapprox run   [--engine batched|pipelined]
//!                    [--sampler oasrs|srs|sts|weighted|none]
//!                    [--fraction 0.6] [--workers N] [--duration-ms 30000]
//!                    [--query sum|mean|count|per-stratum-sum|per-stratum-mean|
//!                             quantile:<q>|distinct|topk:<k>]
//!                    [--window <size_ms>:<slide_ms> | <size_ms>]
//!                    [--dataset micro|caida|taxi] [--backend xla|native]
//!                    [--watermark-skew <ms>] [--lateness <ms>]
//!                    [--disorder <max_skew_ms>[:<straggler_frac>:<straggler_delay_ms>]]
//!                    [--checkpoint-dir <dir>] [--checkpoint-every <n>] [--restore]
//!                    [--metrics <out.prom>] [--trace <out.json>]
//! streamapprox bench --figure fig5a|fig5b|fig5c|fig6a|fig6bc|fig7a|fig7b|
//!                             fig7c|fig8|fig9|fig10|fig11|sketch|window|all
//!                    [--full]
//! ```
//!
//! `--window 60000:1000` runs a 60 s window sliding every second — the
//! long-window/small-slide family the pane-store assembler makes viable.
//!
//! `--watermark-skew`/`--lateness` turn on event-time windowing (panes
//! assigned from item `ts` under a bounded-skew low-watermark; `--lateness`
//! defaults to the skew).  `--disorder 400` shuffles the trace with seeded
//! uniform arrival delays up to 400 virtual ms (optionally
//! `400:0.05:900` adds a 5% straggler burst of +900 ms) before the run —
//! the pairing the disorder-equivalence suite pins.
//!
//! `--checkpoint-dir ckpt/` persists an epoch-stamped pipeline snapshot
//! every `--checkpoint-every` interval boundaries (default 1); `--restore`
//! resumes from the newest valid snapshot in that directory with restored
//! sampler/window state — a seeded run interrupted at a boundary continues
//! bit-identically to the uninterrupted run.
//!
//! `--metrics out.prom` writes the run's registry delta as a Prometheus
//! text export and prints the per-stage latency table; `--trace out.json`
//! enables span tracing for the run and writes a Chrome `trace_event` file
//! (load via chrome://tracing or Perfetto).

// BTreeMap, not HashMap: flag maps feed result-facing config echoes (RunReport
// headers, manifest dumps) — keep iteration deterministic (lint rule D1).
use std::collections::BTreeMap;

use streamapprox::datasets::{CaidaConfig, TaxiConfig};
use streamapprox::harness::{figures, Ctx, Scale};
use streamapprox::prelude::*;
use streamapprox::runtime::default_artifacts_dir;
use streamapprox::stream::StreamGenerator;

fn parse_flags(args: &[String]) -> (Vec<String>, BTreeMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn cmd_info() {
    let dir = default_artifacts_dir();
    println!("artifacts dir: {}", dir.display());
    match streamapprox::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("  strata: {}, pad id: {}", m.num_strata, m.pad_id);
            for v in m.sorted_variants() {
                println!("  variant: {} (N={})", v.file, v.n_items);
            }
            match ComputeService::start(Backend::Xla, Some(dir)) {
                Ok(_) => println!("  XLA backend: OK (PJRT CPU)"),
                Err(e) => println!("  XLA backend: FAILED ({e})"),
            }
        }
        Err(e) => println!("  not available ({e}); run `make artifacts`"),
    }
}

fn cmd_run(flags: &BTreeMap<String, String>) -> Result<(), Box<dyn std::error::Error>> {
    let get = |k: &str, d: &str| flags.get(k).cloned().unwrap_or_else(|| d.to_string());
    let engine = match get("engine", "pipelined").as_str() {
        "batched" => EngineKind::Batched,
        _ => EngineKind::Pipelined,
    };
    let sampler = match get("sampler", "oasrs").as_str() {
        "srs" => SamplerKind::Srs,
        "sts" => SamplerKind::Sts,
        "weighted" => SamplerKind::WeightedRes,
        "none" => SamplerKind::None,
        _ => SamplerKind::Oasrs,
    };
    // `quantile:<q>` and `topk:<k>` carry a parameter after the colon; a
    // malformed parameter is an error, not a silent fallback.
    let query_arg = get("query", "sum");
    let (query_name, query_param) = match query_arg.split_once(':') {
        Some((name, param)) => (name, Some(param)),
        None => (query_arg.as_str(), None),
    };
    const PLAIN_QUERIES: [&str; 6] =
        ["sum", "mean", "count", "per-stratum-sum", "per-stratum-mean", "distinct"];
    let query = match (query_name, query_param) {
        ("sum", None) => Query::Sum,
        ("mean", None) => Query::Mean,
        ("count", None) => Query::Count,
        ("per-stratum-sum", None) => Query::PerStratumSum,
        ("per-stratum-mean", None) => Query::PerStratumMean,
        ("distinct", None) => Query::Distinct,
        ("quantile", Some(p)) => Query::Quantile(
            p.parse()
                .map_err(|e| format!("--query quantile:<q>: bad q {p:?} ({e})"))?,
        ),
        ("topk", Some(p)) => Query::TopK(
            p.parse()
                .map_err(|e| format!("--query topk:<k>: bad k {p:?} ({e})"))?,
        ),
        ("quantile", None) => {
            return Err("--query quantile requires a parameter, e.g. quantile:0.95".into())
        }
        ("topk", None) => {
            return Err("--query topk requires a parameter, e.g. topk:10".into())
        }
        (name, Some(p)) if PLAIN_QUERIES.contains(&name) => {
            return Err(format!("--query {name} takes no parameter (got {p:?})").into())
        }
        (name, _) => return Err(format!("unknown --query {name:?} (see --help in source)").into()),
    };
    let fraction: f64 = get("fraction", "0.6").parse()?;
    let workers: usize = get("workers", "1").parse()?;
    let duration: u64 = get("duration-ms", "30000").parse()?;
    // `--window <size_ms>:<slide_ms>` (or just `<size_ms>` for tumbling);
    // default is the paper's w=10s δ=5s.
    let window = match flags.get("window") {
        None => WindowConfig::paper_default(),
        Some(spec) => {
            let (size, slide) = match spec.split_once(':') {
                Some((size, slide)) => (
                    size.parse()
                        .map_err(|e| format!("--window: bad size {size:?} ({e})"))?,
                    slide
                        .parse()
                        .map_err(|e| format!("--window: bad slide {slide:?} ({e})"))?,
                ),
                None => {
                    let size: u64 = spec
                        .parse()
                        .map_err(|e| format!("--window: bad size {spec:?} ({e})"))?;
                    (size, size)
                }
            };
            if size == 0 || slide == 0 || size % slide != 0 {
                return Err(format!(
                    "--window: size must be a positive multiple of slide (got {size}:{slide})"
                )
                .into());
            }
            WindowConfig::new(size, slide)
        }
    };
    let mut builder = PipelineBuilder::new()
        .engine(engine)
        .sampler(sampler)
        .budget(QueryBudget::SamplingFraction(fraction))
        .query(query)
        .window(window)
        .workers(workers);
    // Event-time mode: either flag enables it; lateness defaults to the
    // skew (a symmetric budget that absorbs `--disorder` up to 2x skew).
    if flags.contains_key("watermark-skew") || flags.contains_key("lateness") {
        let skew: u64 = match flags.get("watermark-skew") {
            Some(s) => s.parse().map_err(|e| format!("--watermark-skew: bad ms {s:?} ({e})"))?,
            None => 0,
        };
        let lateness: u64 = match flags.get("lateness") {
            Some(s) => s.parse().map_err(|e| format!("--lateness: bad ms {s:?} ({e})"))?,
            None => skew,
        };
        builder = builder.event_time(skew, lateness);
    }
    // Durability: periodic snapshots and restore-on-start.
    if let Some(dir) = flags.get("checkpoint-dir") {
        let every: u64 = match flags.get("checkpoint-every") {
            Some(s) => s.parse().map_err(|e| format!("--checkpoint-every: bad n {s:?} ({e})"))?,
            None => 1,
        };
        builder = builder.checkpoint_to(dir, every);
        if flags.contains_key("restore") {
            builder = builder.restore_on_start(true);
        }
    } else if flags.contains_key("restore") || flags.contains_key("checkpoint-every") {
        return Err("--restore/--checkpoint-every require --checkpoint-dir <dir>".into());
    }
    let pipeline = match get("backend", "xla").as_str() {
        "native" => builder.build_native(),
        _ => match builder.clone().build_xla() {
            Ok(p) => p,
            Err(e) => {
                eprintln!("xla backend unavailable ({e}); using native");
                builder.build_native()
            }
        },
    };
    let mut items = match get("dataset", "micro").as_str() {
        "caida" => CaidaConfig::default().generate(duration),
        "taxi" => TaxiConfig::default().generate(duration),
        _ => StreamGenerator::new(&StreamConfig::gaussian_micro(1000.0, 7)).take_until(duration),
    };
    if let Some(spec) = flags.get("disorder") {
        let parts: Vec<&str> = spec.split(':').collect();
        let bad = |e: &dyn std::fmt::Display| {
            format!("--disorder <max_skew_ms>[:<frac>:<delay_ms>]: bad spec {spec:?} ({e})")
        };
        let mut cfg = streamapprox::stream::DisorderConfig::bounded_skew(
            parts[0].parse().map_err(|e| bad(&e))?,
            7,
        );
        match parts.len() {
            1 => {}
            3 => {
                cfg = cfg.with_stragglers(
                    parts[1].parse().map_err(|e| bad(&e))?,
                    parts[2].parse().map_err(|e| bad(&e))?,
                );
            }
            _ => return Err(bad(&"expected 1 or 3 colon-separated fields").into()),
        }
        items = cfg.apply(&items);
    }
    if flags.contains_key("trace") {
        streamapprox::obs::trace::set_tracing_enabled(true);
    }
    let r = pipeline.run_items(&items)?;
    println!(
        "{} items in {:.1} ms -> {:.0} items/s; {} windows; mean loss {:.4}%",
        r.items_processed,
        r.wall_ns as f64 / 1e6,
        r.throughput(),
        r.windows.len(),
        r.mean_accuracy_loss() * 100.0
    );
    for w in r.windows.iter().rev().take(3).collect::<Vec<_>>().into_iter().rev() {
        if let Some(ci) = w.result.scalar {
            println!(
                "  window {:>4}-{:<4}s: {} (exact {:.1})",
                w.start_ms / 1000,
                w.end_ms / 1000,
                ci,
                w.exact_scalar.unwrap_or(f64::NAN)
            );
        }
    }
    let late: u64 = r.windows.iter().map(|w| w.late_dropped).sum();
    if late > 0 {
        println!("  beyond-lateness drops charged to windows: {late}");
    }
    if let Some(path) = flags.get("metrics") {
        let snap = r
            .metrics
            .clone()
            .unwrap_or_else(|| streamapprox::obs::global().snapshot());
        std::fs::write(path, snap.to_prometheus())
            .map_err(|e| format!("--metrics {path}: {e}"))?;
        streamapprox::harness::stage_latency_table(&snap).print();
        println!("metrics (prometheus text) -> {path}");
    }
    if let Some(path) = flags.get("trace") {
        let json = streamapprox::obs::trace::chrome_trace().to_string();
        std::fs::write(path, json).map_err(|e| format!("--trace {path}: {e}"))?;
        println!("chrome trace -> {path} (load via chrome://tracing)");
    }
    Ok(())
}

fn cmd_bench(flags: &BTreeMap<String, String>) {
    let scale = if flags.contains_key("full") { Scale::full() } else { Scale::quick() };
    let ctx = Ctx::auto(scale);
    eprintln!("backend: {:?}, scale: {:?}", ctx.backend(), ctx.scale);
    let fig = flags.get("figure").map(|s| s.as_str()).unwrap_or("all");
    let run = |name: &str| fig == "all" || fig == name;
    if run("fig5a") {
        figures::fig5a(&ctx).print();
    }
    if run("fig5b") {
        figures::fig5b(&ctx).print();
    }
    if run("fig5c") {
        figures::fig5c(&ctx).print();
    }
    if run("fig6a") {
        figures::fig6a(&ctx).print();
    }
    if run("fig6bc") {
        let (b, c) = figures::fig6bc(&ctx);
        b.print();
        c.print();
    }
    if run("fig7a") {
        figures::fig7a(&ctx).print();
    }
    if run("fig7b") {
        figures::fig7b(&ctx).print();
    }
    if run("fig7c") {
        figures::fig7c(&ctx).print();
    }
    if run("fig8") {
        figures::fig8(&ctx).print();
    }
    if run("fig9") {
        let (a, b, c) = figures::fig9(&ctx);
        a.print();
        b.print();
        c.print();
    }
    if run("fig10") {
        let (a, b, c) = figures::fig10(&ctx);
        a.print();
        b.print();
        c.print();
    }
    if run("fig11") {
        figures::fig11(&ctx).print();
    }
    if run("sketch") {
        figures::sketch_workloads(&ctx).print();
    }
    if run("window") {
        let (a, b) = figures::window_scaling(&ctx);
        a.print();
        b.print();
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_flags(&args);
    match pos.first().map(|s| s.as_str()) {
        Some("info") => cmd_info(),
        Some("run") => {
            if let Err(e) = cmd_run(&flags) {
                eprintln!("error: {e}");
                std::process::exit(1);
            }
        }
        Some("bench") => cmd_bench(&flags),
        _ => {
            eprintln!("usage: streamapprox <info|run|bench> [flags]  (see --help in source)");
            std::process::exit(2);
        }
    }
}
