//! Log-linear (HDR-style) latency histogram over atomics.
//!
//! Values (nanoseconds, `u64`) land in power-of-two octaves, each octave
//! split into [`SUB`] linear sub-buckets, so relative error is bounded by
//! `1/SUB` (6.25%) at every magnitude while the whole 64-bit range needs
//! only [`BUCKETS`] cells.  Recording is three relaxed `fetch_add`s and one
//! `fetch_max` — no locks, safe from any thread — which is what lets the
//! ingest hot path keep a live latency distribution instead of a mean.
//!
//! Layout (the classic HdrHistogram scheme):
//!
//! * values `0..SUB` get one bucket each (width 1);
//! * for `v >= SUB`, octave `o = floor(log2 v)` covers `[2^o, 2^(o+1))`
//!   with `SUB` sub-buckets of width `2^(o-SUB_BITS)`.
//!
//! Quantiles walk the cumulative counts and answer with the matched
//! bucket's midpoint (clamped to the observed max), so `p50 <= p95 <= p99
//! <= max` holds by construction — pinned by the property tests in
//! `tests/obs_plane.rs`.

use std::sync::atomic::{AtomicU64, Ordering};

/// log2 of the sub-buckets per octave.
pub const SUB_BITS: u32 = 4;
/// Linear sub-buckets per power-of-two octave (16 → ≤ 6.25% bucket error).
pub const SUB: usize = 1 << SUB_BITS;
/// Octaves above the unit-width range (covers the full `u64` domain).
pub const OCTAVES: usize = 64 - SUB_BITS as usize;
/// Total bucket count.
pub const BUCKETS: usize = SUB + OCTAVES * SUB;

/// Bucket index for a recorded value (total order, contiguous coverage).
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < SUB as u64 {
        return v as usize;
    }
    let o = 63 - v.leading_zeros(); // o >= SUB_BITS
    let sub = ((v >> (o - SUB_BITS)) & (SUB as u64 - 1)) as usize;
    SUB + (o - SUB_BITS) as usize * SUB + sub
}

/// Half-open value range `[low, high)` covered by bucket `i`; the final
/// bucket saturates at `u64::MAX`.
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    assert!(i < BUCKETS, "bucket index {i} out of range");
    if i < SUB {
        return (i as u64, i as u64 + 1);
    }
    let o = SUB_BITS as usize + (i - SUB) / SUB;
    let sub = ((i - SUB) % SUB) as u64;
    let width = 1u64 << (o - SUB_BITS as usize);
    let low = (1u64 << o) + sub * width;
    (low, low.saturating_add(width))
}

/// Shared histogram cells: bucket counts plus count/sum/max, all relaxed
/// atomics.  Handles ([`crate::obs::Histogram`]) wrap a `&'static` one.
#[derive(Debug)]
pub struct HistCore {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for HistCore {
    fn default() -> Self {
        Self::new()
    }
}

impl HistCore {
    pub fn new() -> Self {
        Self {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one value: 3 relaxed `fetch_add` + 1 relaxed `fetch_max`.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Copy the cells out (relaxed loads; exact once recorders have
    /// synchronized with the reader, e.g. via `join`).
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
        }
    }
}

/// Plain-data copy of a histogram, carried in
/// [`crate::obs::MetricsSnapshot`] (and therefore in `RunReport`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    /// Largest value observed.  In a delta snapshot this is the *end* max
    /// (an upper bound for the run — maxima are not subtractable).
    pub max: u64,
    pub buckets: Vec<u64>,
}

impl HistSnapshot {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Quantile estimate: midpoint of the bucket holding the q-th ranked
    /// value, clamped to the observed max.  Monotone in `q`.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum = cum.saturating_add(c);
            if cum >= target {
                let (lo, hi) = bucket_bounds(i);
                return (lo + (hi - lo) / 2).min(self.max.max(lo));
            }
        }
        self.max
    }

    /// Per-run attribution: `self` (end-of-run) minus `start`.  Counts and
    /// sums subtract bucket-wise; `max` keeps the end value.
    pub fn delta(&self, start: &HistSnapshot) -> HistSnapshot {
        let buckets = self
            .buckets
            .iter()
            .enumerate()
            .map(|(i, &b)| b.saturating_sub(start.buckets.get(i).copied().unwrap_or(0)))
            .collect();
        HistSnapshot {
            count: self.count.saturating_sub(start.count),
            sum: self.sum.saturating_sub(start.sum),
            max: self.max,
            buckets,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_tile_the_domain() {
        // Contiguous, non-overlapping: each bucket starts where the
        // previous one ended.
        let mut expect_low = 0u64;
        for i in 0..BUCKETS {
            let (lo, hi) = bucket_bounds(i);
            assert_eq!(lo, expect_low, "gap before bucket {i}");
            assert!(hi > lo);
            expect_low = hi;
        }
        assert_eq!(expect_low, u64::MAX, "last bucket saturates the domain");
    }

    #[test]
    fn index_and_bounds_agree_on_edges() {
        for v in [0u64, 1, 15, 16, 17, 31, 32, 255, 256, 257, 1 << 20, (1 << 20) + 1, u64::MAX] {
            let i = bucket_index(v);
            let (lo, hi) = bucket_bounds(i);
            assert!(lo <= v && v < hi || (v == u64::MAX && i == BUCKETS - 1), "{v} not in [{lo},{hi})");
        }
    }

    #[test]
    fn record_and_quantile_roundtrip() {
        let h = HistCore::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 1000);
        assert_eq!(s.sum, 500_500);
        assert_eq!(s.max, 1000);
        let p50 = s.quantile(0.5);
        let p99 = s.quantile(0.99);
        // bucket relative error is <= 1/SUB
        assert!((p50 as f64 - 500.0).abs() <= 500.0 / SUB as f64 + 1.0, "p50={p50}");
        assert!(p50 <= p99 && p99 <= s.max);
    }

    #[test]
    fn delta_subtracts() {
        let h = HistCore::new();
        h.record(10);
        let start = h.snapshot();
        h.record(20);
        h.record(30);
        let d = h.snapshot().delta(&start);
        assert_eq!(d.count, 2);
        assert_eq!(d.sum, 50);
        assert_eq!(d.max, 30);
        assert_eq!(d.buckets.iter().sum::<u64>(), 2);
    }

    #[test]
    fn empty_quantiles_are_zero() {
        let s = HistCore::new().snapshot();
        assert_eq!(s.quantile(0.5), 0);
        assert_eq!(s.mean(), 0.0);
    }
}
