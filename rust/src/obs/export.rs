//! Metric snapshots and exporters: Prometheus text exposition, JSON, and
//! the per-run delta embedded in `RunReport`.
//!
//! The registry is process-global (many pipelines, one address space), so
//! raw totals cannot attribute cost to a single run.  The engines take a
//! [`MetricsSnapshot`] at run start and end and store `end.delta(&start)`
//! in the report: counters and histogram cells subtract, gauges keep their
//! end-of-run value (they are last-write-wins levels, not accumulations).
//!
//! Histograms export as Prometheus *summaries* (`quantile` label +
//! `_sum`/`_count`) rather than native `_bucket{le=}` series — the
//! log-linear store has 976 cells and the quantiles are what the per-stage
//! latency tables read anyway.  CI diffs the `# TYPE` lines of this export
//! against a committed golden name-set so metric renames break loudly.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use super::hist::HistSnapshot;
use crate::util::json::{obj, Value};

/// Plain-data copy of every registered series, either absolute (a registry
/// snapshot) or a per-run delta.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub hists: BTreeMap<String, HistSnapshot>,
    /// Family name → help text (family = series id up to the label brace).
    pub help: BTreeMap<String, String>,
}

impl MetricsSnapshot {
    /// Per-run attribution: `self` is the end-of-run snapshot, `start` the
    /// one taken before the run.  Series missing from `start` (registered
    /// mid-run) keep their end value.
    pub fn delta(&self, start: &MetricsSnapshot) -> MetricsSnapshot {
        let counters = self
            .counters
            .iter()
            .map(|(k, &v)| (k.clone(), v.saturating_sub(start.counters.get(k).copied().unwrap_or(0))))
            .collect();
        let hists = self
            .hists
            .iter()
            .map(|(k, v)| match start.hists.get(k) {
                Some(s) => (k.clone(), v.delta(s)),
                None => (k.clone(), v.clone()),
            })
            .collect();
        MetricsSnapshot {
            counters,
            gauges: self.gauges.clone(),
            hists,
            help: self.help.clone(),
        }
    }

    /// Sum of counter series whose family name equals `family`.
    pub fn counter(&self, family: &str) -> u64 {
        self.counters
            .iter()
            .filter(|(k, _)| family_of(k) == family)
            .map(|(_, &v)| v)
            .sum()
    }

    /// Gauge value by exact series id.
    pub fn gauge(&self, series: &str) -> Option<f64> {
        self.gauges.get(series).copied()
    }

    /// Histogram by exact series id.
    pub fn hist(&self, series: &str) -> Option<&HistSnapshot> {
        self.hists.get(series)
    }

    /// Render the Prometheus text exposition format.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut emitted: Vec<String> = Vec::new(); // families with headers written
        let header = |out: &mut String, emitted: &mut Vec<String>, family: &str, kind: &str| {
            if emitted.iter().any(|f| f == family) {
                return;
            }
            if let Some(h) = self.help.get(family) {
                let _ = writeln!(out, "# HELP {family} {h}");
            }
            let _ = writeln!(out, "# TYPE {family} {kind}");
            emitted.push(family.to_string());
        };
        for (id, v) in &self.counters {
            header(&mut out, &mut emitted, family_of(id), "counter");
            let _ = writeln!(out, "{id} {v}");
        }
        for (id, v) in &self.gauges {
            header(&mut out, &mut emitted, family_of(id), "gauge");
            let _ = writeln!(out, "{id} {v}");
        }
        for (id, h) in &self.hists {
            header(&mut out, &mut emitted, family_of(id), "summary");
            for q in [0.5, 0.95, 0.99] {
                let _ =
                    writeln!(out, "{} {}", series_with(id, &format!("quantile=\"{q}\"")), h.quantile(q));
            }
            let _ = writeln!(out, "{}_sum {}", splice_suffix(id, "_sum"), h.sum);
            let _ = writeln!(out, "{}_count {}", splice_suffix(id, "_count"), h.count);
        }
        out
    }

    /// Machine-readable snapshot (counters, gauges, histogram summaries).
    pub fn to_json(&self) -> Value {
        let counters = Value::Obj(
            self.counters.iter().map(|(k, &v)| (k.clone(), Value::Num(v as f64))).collect(),
        );
        let gauges = Value::Obj(
            self.gauges
                .iter()
                .map(|(k, &v)| (k.clone(), if v.is_finite() { Value::Num(v) } else { Value::Null }))
                .collect(),
        );
        let hists = Value::Obj(
            self.hists
                .iter()
                .map(|(k, h)| {
                    (
                        k.clone(),
                        obj(vec![
                            ("count", Value::Num(h.count as f64)),
                            ("sum", Value::Num(h.sum as f64)),
                            ("max", Value::Num(h.max as f64)),
                            ("mean", Value::Num(h.mean())),
                            ("p50", Value::Num(h.quantile(0.5) as f64)),
                            ("p95", Value::Num(h.quantile(0.95) as f64)),
                            ("p99", Value::Num(h.quantile(0.99) as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        obj(vec![("counters", counters), ("gauges", gauges), ("histograms", hists)])
    }
}

/// Family name of a rendered series id (strip the label block).
pub fn family_of(series: &str) -> &str {
    match series.find('{') {
        Some(i) => &series[..i],
        None => series,
    }
}

/// Append one more label to a rendered series id.
fn series_with(series: &str, label: &str) -> String {
    match series.strip_suffix('}') {
        Some(head) => format!("{head},{label}}}"),
        None => format!("{series}{{{label}}}"),
    }
}

/// `name{l=v}` → `name_sum{l=v}`; `name` → `name_sum`.
fn splice_suffix(series: &str, suffix: &str) -> String {
    match series.find('{') {
        Some(i) => format!("{}{suffix}{}", &series[..i], &series[i..]),
        None => format!("{series}{suffix}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Registry;

    fn sample_registry() -> Registry {
        let r = Registry::new();
        r.counter("reqs_total", "requests").add(3);
        r.gauge("ratio", "a ratio").set(0.5);
        let h = r.histogram("lat_ns", "latency");
        h.record(100);
        h.record(200);
        r
    }

    #[test]
    fn prometheus_format_basics() {
        let text = sample_registry().snapshot().to_prometheus();
        assert!(text.contains("# TYPE reqs_total counter"), "{text}");
        assert!(text.contains("reqs_total 3"));
        assert!(text.contains("# TYPE ratio gauge"));
        assert!(text.contains("# TYPE lat_ns summary"));
        assert!(text.contains("lat_ns{quantile=\"0.5\"}"));
        assert!(text.contains("lat_ns_count 2"));
        // one TYPE line per family
        assert_eq!(text.matches("# TYPE lat_ns ").count(), 1);
    }

    #[test]
    fn labeled_summary_series() {
        let r = Registry::new();
        r.histogram_with("lat_ns", &[("stage", "close")], "h").record(50);
        let text = r.snapshot().to_prometheus();
        assert!(text.contains("lat_ns{stage=\"close\",quantile=\"0.5\"}"), "{text}");
        assert!(text.contains("lat_ns_sum{stage=\"close\"} 50"), "{text}");
    }

    #[test]
    fn json_snapshot_parses_back() {
        let v = sample_registry().snapshot().to_json();
        let parsed = crate::util::json::parse(&v.to_string()).unwrap();
        assert_eq!(parsed.get("counters").unwrap().get("reqs_total").unwrap().as_i64(), Some(3));
        let lat = parsed.get("histograms").unwrap().get("lat_ns").unwrap();
        assert_eq!(lat.get("count").unwrap().as_i64(), Some(2));
    }

    #[test]
    fn family_helpers() {
        assert_eq!(family_of("a{b=\"c\"}"), "a");
        assert_eq!(family_of("a"), "a");
        assert_eq!(series_with("a", "q=\"1\""), "a{q=\"1\"}");
        assert_eq!(series_with("a{b=\"c\"}", "q=\"1\""), "a{b=\"c\",q=\"1\"}");
        assert_eq!(splice_suffix("a{b=\"c\"}", "_sum"), "a_sum{b=\"c\"}");
    }

    #[test]
    fn counter_family_sums_labeled_series() {
        let r = Registry::new();
        r.counter_with("n", &[("w", "0")], "h").add(2);
        r.counter_with("n", &[("w", "1")], "h").add(5);
        assert_eq!(r.snapshot().counter("n"), 7);
    }
}
