//! Pipeline-wide observability plane: a zero-dependency metrics registry,
//! HDR-style latency histograms, and a lock-free span tracer — from ingest
//! to window emit.
//!
//! The paper's whole argument is a measured throughput/accuracy trade-off,
//! and means are not enough to defend it: per-stage latency *distributions*
//! (p50/p95/p99) are what separate "the sampler is slow" from "one worker's
//! ring is backing up".  This module replaces the previous scatter of
//! ad-hoc globals and struct-local counters with one process-wide registry:
//!
//! * [`Counter`] / [`Gauge`] / [`Histogram`] handles over `&'static`
//!   atomics — recording is a few relaxed atomic ops, no locks;
//! * [`hist`]: log-linear (power-of-two octave × 16 linear sub-buckets)
//!   histograms giving cheap p50/p95/p99/max at ≤ 6.25% bucket error;
//! * [`trace`]: per-thread fixed-capacity span rings exportable as Chrome
//!   `trace_event` JSON (off by default, enabled per run);
//! * [`export`]: Prometheus text + JSON snapshot exporters, and
//!   [`MetricsSnapshot`] deltas embedded in `RunReport` so per-run
//!   attribution works even though the registry is process-global.
//!
//! Instrumentation cost discipline: hot sites record per *chunk* (512
//! items), per slice, or per interval — never per item — and cache their
//! handles in `OnceLock`s via the [`obs_counter!`] / [`obs_gauge!`] /
//! [`obs_histogram!`] macros.  Histograms and gauges honor a global enable
//! flag ([`set_metrics_enabled`]) so the benchmark can measure an
//! uninstrumented baseline; counters always count, because drop accounting
//! (`metrics::dropped_items`) is semantically load-bearing.
//!
//! # Metrics reference
//!
//! | name | type | stage | meaning |
//! |------|------|-------|---------|
//! | `ingest_items_total` | counter | ingest | items offered to the sampling plane (ticked at interval close) |
//! | `ingest_accepts_total` | counter | ingest | sampled items surviving admission (interval sample size) |
//! | `ingest_rng_draws_total` | counter | ingest | sampler RNG draws (= items offered for the per-item-rate samplers; derived at close) |
//! | `ingest_dropped_items_total` | counter | ingest | admission-control drops (shimmed from `metrics::record_dropped_item`) |
//! | `estimator_zero_weight_strata_total` | counter | estimate | strata skipped for zero weight (shimmed from `metrics::record_zero_weight_stratum`) |
//! | `transport_chunks_sent_total` | counter | transport | 512-item chunks shipped over the SPSC rings |
//! | `transport_buffers_recycled_total` | counter | transport | chunk buffers reused from the return rings |
//! | `transport_buffers_allocated_total` | counter | transport | chunk buffers freshly allocated (pool misses) |
//! | `ingest_backoff_naps_total` | counter | transport | worker idle-loop naps (sleep-tier backoff rounds) |
//! | `late_items_dropped_total` | counter | window | beyond-lateness items dropped by the event-time router |
//! | `window_pane_reopens_total` | counter | window | late arrivals routed into an already-open older event-time pane |
//! | `window_pane_merges_total` | counter | window | structural pane merges (assembler folds + pane-store merges) |
//! | `window_spill_events_total` | counter | window | sample-deque spills to compressed pane summaries |
//! | `query_sketch_builds_total` | counter | query | sketches built at query time (rebuild path; prebuilt panes keep this flat) |
//! | `ingest_columnar_chunks_total` | counter | ingest | columnar (SoA) chunks offered to the sampling kernels |
//! | `ingest_mask_survivors_total` | counter | ingest | items surviving the batched acceptance kernels (OASRS columnar path) |
//! | `snapshots_written_total` | counter | checkpoint | epoch snapshots persisted (tmp-then-rename publishes) |
//! | `recovery_restores_total` | counter | checkpoint | successful `Engine::recover` restores |
//! | `recovery_fallbacks_total` | counter | checkpoint | invalid snapshot epochs skipped during recovery (exactly one tick per bad file) |
//! | `recovery_replayed_items_total` | counter | checkpoint | items re-read from the broker offset during event-time recovery replay |
//! | `transport_recycle_hit_rate` | gauge | transport | recycled / (recycled + allocated), 0.0 on an idle pool |
//! | `ingest_ring_occupancy` | gauge | transport | chunks queued on the most recently shipped worker ring |
//! | `feedback_ci_width_ewma` | gauge | feedback | EWMA of observed CI relative width (the controller's input) |
//! | `feedback_fraction` | gauge | feedback | current sampling fraction chosen by the controller |
//! | `broker_lag` | gauge | source | produced − consumed on the polled broker topic |
//! | `event_time_watermark_lag_ms` | gauge | window | virtual ms the low-watermark trails the newest observed event time |
//! | `snapshot_epoch` | gauge | checkpoint | most recently persisted checkpoint epoch |
//! | `ingest_offer_ns` | histogram | ingest | wall time of one `offer_slice` call (per slice, not per item) |
//! | `control_ack_ns` | histogram | control | rendezvous ack latency for `set_fraction` / `register_sketches` |
//! | `close_sts_sort_ns` | histogram | close | STS full random sort at interval close |
//! | `close_sketch_build_ns` | histogram | close | sketch-partial build from the interval sample |
//! | `interval_close_ns` | histogram | close | whole interval close (drain + merge + partials) |
//! | `window_merge_ns` | histogram | window | assembling one window view from its panes |
//! | `query_execute_ns` | histogram | query | estimate/aggregate execution per window |
//! | `window_emit_ns` | histogram | emit | query + report assembly per emitted window |
//! | `columnar_compact_ns` | histogram | ingest | one OASRS columnar kernel pass over a chunk (partition + batched acceptance) |
//! | `snapshot_bytes` | histogram | checkpoint | size of one persisted snapshot frame (bytes) |
//! | `snapshot_write_ns` | histogram | checkpoint | wall time to frame + persist one snapshot |

pub mod export;
pub mod hist;
pub mod trace;

pub use export::MetricsSnapshot;
pub use hist::{HistCore, HistSnapshot};

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Gates [`Histogram::record`] and [`Gauge::set`] (counters always count —
/// see the module doc).  Default on; the sampling-hotpath bench flips it
/// off to measure the uninstrumented baseline.  Process-global: tests must
/// not toggle it (they run in parallel); the bench is its own process.
static METRICS_ENABLED: AtomicBool = AtomicBool::new(true);

/// Enable/disable histogram+gauge recording process-wide.
pub fn set_metrics_enabled(on: bool) {
    METRICS_ENABLED.store(on, Ordering::Relaxed);
}

#[inline]
pub fn metrics_enabled() -> bool {
    METRICS_ENABLED.load(Ordering::Relaxed)
}

/// Monotone counter handle (`Copy` — cache freely).
#[derive(Debug, Clone, Copy)]
pub struct Counter {
    cell: &'static AtomicU64,
}

impl Counter {
    #[inline]
    pub fn inc(&self) {
        self.cell.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.cell.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.cell.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge holding an `f64` (bits in an `AtomicU64`).
#[derive(Debug, Clone, Copy)]
pub struct Gauge {
    cell: &'static AtomicU64,
}

impl Gauge {
    #[inline]
    pub fn set(&self, v: f64) {
        if metrics_enabled() {
            self.cell.store(v.to_bits(), Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.cell.load(Ordering::Relaxed))
    }
}

/// Latency histogram handle (values in nanoseconds by convention).
#[derive(Debug, Clone, Copy)]
pub struct Histogram {
    core: &'static HistCore,
}

impl Histogram {
    #[inline]
    pub fn record(&self, v: u64) {
        if metrics_enabled() {
            self.core.record(v);
        }
    }

    /// Record the elapsed time since `t0` in nanoseconds.
    #[inline]
    pub fn record_elapsed(&self, t0: Instant) {
        if metrics_enabled() {
            self.core.record(t0.elapsed().as_nanos() as u64);
        }
    }

    pub fn snapshot(&self) -> HistSnapshot {
        self.core.snapshot()
    }
}

enum Slot {
    Counter(&'static AtomicU64),
    Gauge(&'static AtomicU64),
    Hist(&'static HistCore),
}

impl Slot {
    fn kind(&self) -> &'static str {
        match self {
            Slot::Counter(_) => "counter",
            Slot::Gauge(_) => "gauge",
            Slot::Hist(_) => "histogram",
        }
    }
}

struct Entry {
    name: String,
    labels: Vec<(String, String)>,
    help: String,
    slot: Slot,
}

impl Entry {
    /// Rendered series id, `name` or `name{k="v",...}`.
    fn series_id(&self) -> String {
        if self.labels.is_empty() {
            return self.name.clone();
        }
        let labels = self
            .labels
            .iter()
            .map(|(k, v)| format!("{k}=\"{v}\""))
            .collect::<Vec<_>>()
            .join(",");
        format!("{}{{{labels}}}", self.name)
    }
}

/// A registry of named, labeled metrics.  Registration is idempotent (same
/// name+labels returns the same handle) and cold-path locked; recording is
/// lock-free through the returned handles.  Handle cells are `Box::leak`ed
/// so instance registries (used by tests for race-free exact-delta
/// assertions) leak a few atomics each — fine for their lifetime.
#[derive(Default)]
pub struct Registry {
    entries: Mutex<Vec<Entry>>,
}

impl std::fmt::Debug for Registry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let n = self.entries.lock().map(|e| e.len()).unwrap_or(0);
        f.debug_struct("Registry").field("entries", &n).finish()
    }
}

impl Registry {
    pub const fn new() -> Self {
        Self { entries: Mutex::new(Vec::new()) }
    }

    fn register(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        help: &str,
        make: impl FnOnce() -> Slot,
    ) -> usize {
        let mut entries = self.entries.lock().unwrap();
        if let Some(i) = entries
            .iter()
            .position(|e| e.name == name && e.labels.len() == labels.len()
                && e.labels.iter().zip(labels).all(|(a, b)| a.0 == b.0 && a.1 == b.1))
        {
            return i;
        }
        entries.push(Entry {
            name: name.to_string(),
            labels: labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect(),
            help: help.to_string(),
            slot: make(),
        });
        entries.len() - 1
    }

    pub fn counter(&self, name: &str, help: &str) -> Counter {
        self.counter_with(name, &[], help)
    }

    pub fn counter_with(&self, name: &str, labels: &[(&str, &str)], help: &str) -> Counter {
        let i = self.register(name, labels, help, || {
            Slot::Counter(Box::leak(Box::new(AtomicU64::new(0))))
        });
        let entries = self.entries.lock().unwrap();
        match entries[i].slot {
            Slot::Counter(c) => Counter { cell: c },
            ref other => panic!("metric {name} already registered as a {}", other.kind()),
        }
    }

    pub fn gauge(&self, name: &str, help: &str) -> Gauge {
        self.gauge_with(name, &[], help)
    }

    pub fn gauge_with(&self, name: &str, labels: &[(&str, &str)], help: &str) -> Gauge {
        let i = self.register(name, labels, help, || {
            Slot::Gauge(Box::leak(Box::new(AtomicU64::new(0))))
        });
        let entries = self.entries.lock().unwrap();
        match entries[i].slot {
            Slot::Gauge(g) => Gauge { cell: g },
            ref other => panic!("metric {name} already registered as a {}", other.kind()),
        }
    }

    pub fn histogram(&self, name: &str, help: &str) -> Histogram {
        self.histogram_with(name, &[], help)
    }

    pub fn histogram_with(&self, name: &str, labels: &[(&str, &str)], help: &str) -> Histogram {
        let i = self.register(name, labels, help, || {
            Slot::Hist(Box::leak(Box::new(HistCore::new())))
        });
        let entries = self.entries.lock().unwrap();
        match entries[i].slot {
            Slot::Hist(h) => Histogram { core: h },
            ref other => panic!("metric {name} already registered as a {}", other.kind()),
        }
    }

    /// Copy every registered series out as plain data.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let entries = self.entries.lock().unwrap();
        let mut s = MetricsSnapshot::default();
        for e in entries.iter() {
            let id = e.series_id();
            s.help.insert(e.name.clone(), e.help.clone());
            match e.slot {
                Slot::Counter(c) => {
                    s.counters.insert(id, c.load(Ordering::Relaxed));
                }
                Slot::Gauge(g) => {
                    s.gauges.insert(id, f64::from_bits(g.load(Ordering::Relaxed)));
                }
                Slot::Hist(h) => {
                    s.hists.insert(id, h.snapshot());
                }
            }
        }
        s
    }
}

/// The process-wide registry every pipeline stage records into.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

/// Register-once-then-record counter handle for a hot site: the `OnceLock`
/// fast path is one atomic load, the record one relaxed `fetch_add`.
#[macro_export]
macro_rules! obs_counter {
    ($name:expr, $help:expr) => {{
        static HANDLE: std::sync::OnceLock<$crate::obs::Counter> = std::sync::OnceLock::new();
        *HANDLE.get_or_init(|| $crate::obs::global().counter($name, $help))
    }};
}

/// Cached gauge handle (see [`obs_counter!`]).
#[macro_export]
macro_rules! obs_gauge {
    ($name:expr, $help:expr) => {{
        static HANDLE: std::sync::OnceLock<$crate::obs::Gauge> = std::sync::OnceLock::new();
        *HANDLE.get_or_init(|| $crate::obs::global().gauge($name, $help))
    }};
}

/// Cached histogram handle (see [`obs_counter!`]).
#[macro_export]
macro_rules! obs_histogram {
    ($name:expr, $help:expr) => {{
        static HANDLE: std::sync::OnceLock<$crate::obs::Histogram> = std::sync::OnceLock::new();
        *HANDLE.get_or_init(|| $crate::obs::global().histogram($name, $help))
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registration_is_idempotent() {
        let r = Registry::new();
        let a = r.counter("c", "help");
        let b = r.counter("c", "help");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        assert_eq!(b.get(), 3);
        assert_eq!(r.snapshot().counters.len(), 1);
    }

    #[test]
    fn labels_split_series() {
        let r = Registry::new();
        let a = r.counter_with("reqs", &[("stage", "ingest")], "h");
        let b = r.counter_with("reqs", &[("stage", "close")], "h");
        a.inc();
        b.add(5);
        let s = r.snapshot();
        assert_eq!(s.counters["reqs{stage=\"ingest\"}"], 1);
        assert_eq!(s.counters["reqs{stage=\"close\"}"], 5);
    }

    #[test]
    #[should_panic]
    fn kind_mismatch_panics() {
        let r = Registry::new();
        let _ = r.counter("m", "h");
        let _ = r.gauge("m", "h");
    }

    #[test]
    fn gauge_roundtrips_f64() {
        let r = Registry::new();
        let g = r.gauge("ratio", "h");
        g.set(0.375);
        assert_eq!(g.get(), 0.375);
        assert_eq!(r.snapshot().gauges["ratio"], 0.375);
    }

    #[test]
    fn snapshot_delta_is_per_run() {
        let r = Registry::new();
        let c = r.counter("items", "h");
        let h = r.histogram("lat", "h");
        c.add(10);
        h.record(100);
        let start = r.snapshot();
        c.add(7);
        h.record(200);
        h.record(300);
        let d = r.snapshot().delta(&start);
        assert_eq!(d.counters["items"], 7);
        assert_eq!(d.hists["lat"].count, 2);
        assert_eq!(d.hists["lat"].sum, 500);
    }
}
