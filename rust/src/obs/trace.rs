//! Lock-free span tracer: per-thread fixed-capacity rings of completed
//! spans, exported as Chrome `trace_event` JSON.
//!
//! Tracing is **off by default** ([`set_tracing_enabled`]); the disabled
//! fast path is one relaxed load and a branch.  When enabled, a
//! [`SpanGuard`] (from [`span`]) captures a start timestamp and, on drop,
//! writes `(name, start_ns, end_ns)` into the calling thread's ring — the
//! RAII drop order *is* the per-thread span stack, so spans on one thread
//! are well-nested by construction (pinned in `tests/obs_plane.rs`).
//!
//! Each ring is single-writer (its owning thread) with atomic slots, so the
//! exporter can read concurrently without locks; events overwritten while
//! being read are detected by re-checking the head and dropped.  Rings hold
//! the most recent [`RING_CAP`] spans per thread — span sites are interval/
//! window granularity, so a run's tail comfortably fits.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::{obj, Value};

/// Spans retained per thread (newest win).
pub const RING_CAP: usize = 4096;

static TRACING: AtomicBool = AtomicBool::new(false);

/// Turn span capture on/off process-wide (off by default; the CLI enables
/// it for `run --trace`).
pub fn set_tracing_enabled(on: bool) {
    TRACING.store(on, Ordering::Relaxed);
}

#[inline]
pub fn tracing_enabled() -> bool {
    TRACING.load(Ordering::Relaxed)
}

/// Nanoseconds since the process trace epoch (first use).
#[inline]
pub fn now_ns() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Intern a span name, returning its id (cold path — takes a lock; span
/// sites are interval-granularity so this is fine, and ids repeat).
pub fn intern(name: &'static str) -> u32 {
    let mut names = names().lock().unwrap();
    if let Some(i) = names.iter().position(|&n| n == name) {
        return i as u32;
    }
    names.push(name);
    (names.len() - 1) as u32
}

fn name_of(id: u32) -> &'static str {
    names().lock().unwrap().get(id as usize).copied().unwrap_or("?")
}

fn names() -> &'static Mutex<Vec<&'static str>> {
    static NAMES: Mutex<Vec<&'static str>> = Mutex::new(Vec::new());
    &NAMES
}

struct TraceSlot {
    name: AtomicU32,
    start: AtomicU64,
    end: AtomicU64,
}

struct ThreadRing {
    tid: u64,
    thread_name: String,
    slots: Box<[TraceSlot]>,
    /// Next write index (monotone; owned by the ring's thread).
    head: AtomicUsize,
}

impl ThreadRing {
    /// Single-writer append: fill the slot, then publish via `head`.
    fn record(&self, name: u32, start: u64, end: u64) {
        let h = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[h % RING_CAP];
        slot.name.store(name, Ordering::Relaxed);
        slot.start.store(start, Ordering::Relaxed);
        slot.end.store(end, Ordering::Relaxed);
        self.head.store(h + 1, Ordering::Release);
    }
}

fn rings() -> &'static Mutex<Vec<Arc<ThreadRing>>> {
    static RINGS: Mutex<Vec<Arc<ThreadRing>>> = Mutex::new(Vec::new());
    &RINGS
}

thread_local! {
    static LOCAL_RING: Arc<ThreadRing> = {
        let mut all = rings().lock().unwrap();
        let ring = Arc::new(ThreadRing {
            tid: all.len() as u64 + 1,
            thread_name: std::thread::current().name().unwrap_or("thread").to_string(),
            slots: (0..RING_CAP)
                .map(|_| TraceSlot {
                    name: AtomicU32::new(0),
                    start: AtomicU64::new(0),
                    end: AtomicU64::new(0),
                })
                .collect(),
            head: AtomicUsize::new(0),
        });
        all.push(ring.clone());
        ring
    };
}

/// RAII span: records on drop (LIFO drop order keeps per-thread spans
/// well-nested).  Inert when tracing is disabled at creation.
#[must_use = "a span measures the scope it lives in"]
#[derive(Debug)]
pub struct SpanGuard {
    id: u32,
    start: u64,
    active: bool,
}

impl SpanGuard {
    const INERT: SpanGuard = SpanGuard { id: 0, start: 0, active: false };
}

/// Open a span covering the enclosing scope.
#[inline]
pub fn span(name: &'static str) -> SpanGuard {
    if !tracing_enabled() {
        return SpanGuard::INERT;
    }
    SpanGuard { id: intern(name), start: now_ns(), active: true }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let end = now_ns();
        let (id, start) = (self.id, self.start);
        LOCAL_RING.with(|r| r.record(id, start, end));
    }
}

/// Reset all rings (per-run traces).  Call only while span recorders are
/// quiescent — a concurrent writer may leave one stale event behind.
pub fn reset() {
    for ring in rings().lock().unwrap().iter() {
        ring.head.store(0, Ordering::Release);
    }
}

/// Export everything recorded as a Chrome `trace_event` document
/// (`chrome://tracing` / Perfetto): complete events (`ph:"X"`, µs
/// timestamps) plus a `thread_name` metadata record per thread.
pub fn chrome_trace() -> Value {
    let rings = rings().lock().unwrap();
    let mut events = Vec::new();
    for ring in rings.iter() {
        events.push(obj(vec![
            ("name", Value::Str("thread_name".into())),
            ("ph", Value::Str("M".into())),
            ("pid", Value::Num(1.0)),
            ("tid", Value::Num(ring.tid as f64)),
            ("args", obj(vec![("name", Value::Str(ring.thread_name.clone()))])),
        ]));
        let head = ring.head.load(Ordering::Acquire);
        for i in head.saturating_sub(RING_CAP)..head {
            let slot = &ring.slots[i % RING_CAP];
            let name = slot.name.load(Ordering::Relaxed);
            let start = slot.start.load(Ordering::Relaxed);
            let end = slot.end.load(Ordering::Relaxed);
            // Drop events the writer may have overwritten mid-read.
            if i + RING_CAP < ring.head.load(Ordering::Acquire) || end < start {
                continue;
            }
            events.push(obj(vec![
                ("name", Value::Str(name_of(name).into())),
                ("cat", Value::Str("streamapprox".into())),
                ("ph", Value::Str("X".into())),
                ("ts", Value::Num(start as f64 / 1000.0)),
                ("dur", Value::Num((end - start) as f64 / 1000.0)),
                ("pid", Value::Num(1.0)),
                ("tid", Value::Num(ring.tid as f64)),
            ]));
        }
    }
    obj(vec![
        ("traceEvents", Value::Arr(events)),
        ("displayTimeUnit", Value::Str("ms".into())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: tests here avoid toggling the global TRACING flag — the
    // threaded end-to-end trace test lives in `tests/obs_plane.rs`, which
    // owns the flag for its process.  Unit tests exercise the pieces.

    #[test]
    fn inert_span_records_nothing() {
        assert!(!tracing_enabled());
        let before = rings().lock().unwrap().iter().map(|r| r.head.load(Ordering::Relaxed)).sum::<usize>();
        {
            let _s = span("unit_inert");
        }
        let after = rings().lock().unwrap().iter().map(|r| r.head.load(Ordering::Relaxed)).sum::<usize>();
        assert_eq!(before, after);
    }

    #[test]
    fn interning_is_stable() {
        let a = intern("unit_a");
        let b = intern("unit_b");
        let a2 = intern("unit_a");
        assert_eq!(a, a2);
        assert_ne!(a, b);
        assert_eq!(name_of(a), "unit_a");
    }

    #[test]
    fn now_ns_is_monotone() {
        let a = now_ns();
        let b = now_ns();
        assert!(b >= a);
    }

    #[test]
    fn chrome_trace_is_valid_json() {
        let doc = chrome_trace().to_string();
        let v = crate::util::json::parse(&doc).unwrap();
        assert!(v.get("traceEvents").unwrap().as_arr().is_some());
    }
}
