//! Measurement utilities (paper §6.1): throughput, latency, accuracy loss,
//! multi-run aggregation (the paper reports the average over 10 runs), the
//! fixed-accuracy throughput search used by Figs. 7b / 9c / 10c, and the
//! process-wide drop counter that surfaces items silently rejected at
//! ingest (out-of-range strata).

use crate::engine::RunReport;
use crate::obs::Counter;

/// Items rejected at ingest because their stratum id exceeds
/// [`crate::core::MAX_STRATA`].  Samplers used to discard these invisibly;
/// they now tick the `ingest_dropped_items_total` registry counter (the
/// free functions below are thin shims) so operators can alert on a
/// misconfigured stratifier instead of chasing an unexplained undercount —
/// and so `RunReport::metrics` can attribute drops to one run as a
/// snapshot delta instead of a racing process-global total.
fn dropped_counter() -> Counter {
    crate::obs_counter!(
        "ingest_dropped_items_total",
        "items rejected at ingest (stratum id out of range)"
    )
}

/// Record one dropped (out-of-range-stratum) item.
#[inline]
pub fn record_dropped_item() {
    dropped_counter().inc();
}

/// Total items dropped at ingest since process start (monotone; shared by
/// every sampler instance in the process — read
/// `RunReport::metrics` for per-run deltas).
pub fn dropped_items() -> u64 {
    dropped_counter().get()
}

/// Observations of an arrived-but-unsampled stratum: every weight
/// computation (`estimator::weights_for`) that meets a stratum with
/// `C_i > 0` but `N_i = 0` pins its weight to 0 and ticks the
/// `estimator_zero_weight_strata_total` registry counter.  One underlying
/// undercount event is therefore observed several times — once per sketch
/// build, estimate, or window query that touches the interval — so treat
/// this as a *signal* (zero vs growing), not an event count; any steady
/// growth means a sampler is sizing some stratum's reservoir to zero, an
/// undercount that used to be silent.
fn zero_weight_counter() -> Counter {
    crate::obs_counter!(
        "estimator_zero_weight_strata_total",
        "arrived-but-unsampled stratum observations in weight computation"
    )
}

/// Record one arrived-but-unsampled stratum observation.
#[inline]
pub fn record_zero_weight_stratum() {
    zero_weight_counter().inc();
}

/// Total arrived-but-unsampled stratum observations since process start
/// (monotone; process-wide).
pub fn zero_weight_strata() -> u64 {
    zero_weight_counter().get()
}

/// Summary statistics over repeated runs of the same configuration.
#[derive(Debug, Clone, Default)]
pub struct RunSummary {
    pub runs: usize,
    /// Mean throughput (items/s).
    pub throughput: f64,
    /// Std-dev of throughput across runs.
    pub throughput_sd: f64,
    /// Mean of mean-accuracy-loss across runs.
    pub accuracy_loss: f64,
    /// Mean per-window processing latency (ns).
    pub window_latency_ns: f64,
    /// Mean total items per run.
    pub items: f64,
    /// Mean wall time per run (ns).
    pub wall_ns: f64,
}

/// Aggregate several runs into a summary.
pub fn summarize(reports: &[RunReport]) -> RunSummary {
    if reports.is_empty() {
        return RunSummary::default();
    }
    let n = reports.len() as f64;
    let thr: Vec<f64> = reports.iter().map(|r| r.throughput()).collect();
    let thr_mean = thr.iter().sum::<f64>() / n;
    let thr_var = thr.iter().map(|t| (t - thr_mean) * (t - thr_mean)).sum::<f64>() / n;
    let losses: Vec<f64> = reports
        .iter()
        .map(|r| r.mean_accuracy_loss())
        .filter(|l| l.is_finite())
        .collect();
    let loss = if losses.is_empty() {
        f64::NAN
    } else {
        losses.iter().sum::<f64>() / losses.len() as f64
    };
    RunSummary {
        runs: reports.len(),
        throughput: thr_mean,
        throughput_sd: thr_var.sqrt(),
        accuracy_loss: loss,
        window_latency_ns: reports.iter().map(|r| r.mean_window_latency_ns()).sum::<f64>() / n,
        items: reports.iter().map(|r| r.items_processed as f64).sum::<f64>() / n,
        wall_ns: reports.iter().map(|r| r.wall_ns as f64).sum::<f64>() / n,
    }
}

/// Binary-search the sampling fraction that achieves a target accuracy loss
/// (paper's "fix the accuracy loss, compare throughputs" methodology):
/// returns the smallest tested fraction whose measured loss ≤ target.
///
/// `measure(fraction) -> loss` runs the system at the fraction and returns
/// the observed mean accuracy loss (assumed monotone non-increasing in the
/// fraction, which holds in expectation).
pub fn fraction_for_accuracy(
    mut measure: impl FnMut(f64) -> f64,
    target_loss: f64,
    iters: usize,
) -> f64 {
    let mut lo = 0.01;
    let mut hi = 1.0;
    // If even full sampling misses the target (shouldn't happen), return 1.
    let mut best = 1.0;
    for _ in 0..iters {
        let mid = 0.5 * (lo + hi);
        let loss = measure(mid);
        if loss <= target_loss {
            best = mid;
            hi = mid;
        } else {
            lo = mid;
        }
        if hi - lo < 0.02 {
            break;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summarize_empty() {
        let s = summarize(&[]);
        assert_eq!(s.runs, 0);
    }

    #[test]
    fn summarize_multiple() {
        let mk = |items: u64, wall: u64| RunReport {
            windows: vec![],
            items_processed: items,
            wall_ns: wall,
            sketch_ingest: None,
            metrics: None,
        };
        let s = summarize(&[mk(1000, 1_000_000_000), mk(2000, 1_000_000_000)]);
        assert_eq!(s.runs, 2);
        assert!((s.throughput - 1500.0).abs() < 1e-9);
        assert!(s.throughput_sd > 0.0);
        assert!(s.accuracy_loss.is_nan()); // no windows
    }

    #[test]
    fn fraction_search_monotone_plant() {
        // loss(f) = 0.05 / sqrt(f) -> target 0.1 needs f >= 0.25
        let f = fraction_for_accuracy(|f| 0.05 / f.sqrt(), 0.1, 12);
        assert!((f - 0.25).abs() < 0.1, "f {f}");
    }

    #[test]
    fn fraction_search_easy_target() {
        let f = fraction_for_accuracy(|_| 0.0, 0.5, 8);
        assert!(f < 0.1, "f {f}");
    }

    #[test]
    fn fraction_search_impossible_target() {
        let f = fraction_for_accuracy(|_| 1.0, 0.001, 8);
        assert_eq!(f, 1.0);
    }

    #[test]
    fn drop_counter_exact_delta_on_isolated_registry() {
        // The registry makes drop accounting testable exactly: an isolated
        // Registry instance sees no other test's traffic, so the snapshot
        // delta is == (the old process-global test could only assert a
        // floor because parallel tests race on one static).
        let r = crate::obs::Registry::new();
        let c = r.counter("ingest_dropped_items_total", "h");
        let start = r.snapshot();
        c.inc();
        c.inc();
        let d = r.snapshot().delta(&start);
        assert_eq!(d.counters["ingest_dropped_items_total"], 2);
    }

    #[test]
    fn drop_shims_route_to_global_registry() {
        let before = dropped_items();
        record_dropped_item();
        record_dropped_item();
        // shims tick the registry counter; other tests may add drops
        // concurrently (process-global), so only monotonicity is asserted
        // here — exact per-run attribution is the snapshot delta above.
        assert!(dropped_items() >= before + 2);
        let snap = crate::obs::global().snapshot();
        assert!(snap.counters.contains_key("ingest_dropped_items_total"));
    }

    #[test]
    fn zero_weight_shims_route_to_global_registry() {
        let before = zero_weight_strata();
        record_zero_weight_stratum();
        assert!(zero_weight_strata() >= before + 1);
    }
}
