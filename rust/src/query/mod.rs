//! Approximate queries over window samples.
//!
//! Two families share one executor:
//!
//! * **Linear queries** (paper §3.2) — sum, mean, count, histogram and
//!   per-stratum aggregates, executed through the compute service (XLA
//!   artifacts or the native executor) and annotated with CLT error bounds
//!   (§3.3).
//! * **Sketch-backed queries** (the [`crate::sketch`] subsystem) —
//!   quantiles, distinct counts, and top-k heavy hitters.  Per window, the
//!   sample is split into shards, one mergeable sketch is built per shard,
//!   and the shards merge at the window boundary — the same no-barrier
//!   associative combine the OASRS workers use.  Each result carries the
//!   sketch's *native* guarantee (rank ε, HLL RSE, Count-Min over-bound) as
//!   its [`ConfidenceInterval`].
//!
//! ```
//! use streamapprox::prelude::*;
//!
//! // 95th-percentile of item values per window, with a rank-ε value band.
//! let pipeline = PipelineBuilder::new()
//!     .sampler(SamplerKind::Oasrs)
//!     .query(Query::Quantile(0.95))
//!     .window(WindowConfig::tumbling(1_000))
//!     .build_native();
//! let report = pipeline
//!     .run_stream(&StreamConfig::gaussian_micro(200.0, 7), 4_000)
//!     .unwrap();
//! for w in &report.windows {
//!     assert!(w.result.value().is_finite());
//! }
//! ```

use std::sync::atomic::{AtomicU64, Ordering};

use crate::core::{Error, Result, MAX_STRATA};
use crate::error::bounds::{ConfidenceInterval, ConfidenceLevel};
use crate::error::estimator::{estimate, StrataPartials, StrataState, K};
use crate::runtime::{ComputeHandle, WindowInput, WindowOutput};
use crate::sampling::SampleResult;
use crate::sketch::{
    HeavyHitters, HyperLogLog, PaneSketch, QuantileSketch, SketchParams, SketchSpec,
};
use crate::window::{PaneStore, WindowView};

/// Shared Count-Min row-hash seed: every per-shard and per-pane
/// heavy-hitters sketch must use the same seed to stay merge-compatible.
const HH_SEED: u64 = 0x70_4B;

/// A streaming query over the item values.
#[derive(Debug, Clone, PartialEq)]
pub enum Query {
    /// Total of all item values (Eq. 3).
    Sum,
    /// Mean of all item values (Eq. 4).
    Mean,
    /// Number of items (estimated from weights when sampled).
    Count,
    /// Per-stratum totals — e.g. TCP/UDP/ICMP traffic sizes (§6.2).
    PerStratumSum,
    /// Per-stratum means — e.g. average trip distance per borough (§6.3).
    PerStratumMean,
    /// Histogram of values over fixed buckets in [lo, hi).
    Histogram { lo: f64, hi: f64, buckets: usize },
    /// Value at quantile q ∈ [0, 1] of the window's (weighted) value
    /// distribution, with a rank-error-ε band (sketch-backed).
    Quantile(f64),
    /// Distinct values observed in the window sample (HyperLogLog-backed;
    /// under sampling this is a lower bound on the stream's distinct count —
    /// see `sketch::hll`).
    Distinct,
    /// The k heaviest sub-streams by estimated item count (Count-Min +
    /// space-saving), with the Count-Min over-estimate bound.
    TopK(usize),
}

impl Query {
    pub fn sum() -> Self {
        Query::Sum
    }

    pub fn mean() -> Self {
        Query::Mean
    }

    /// Quantile query, e.g. `Query::quantile(0.99)` for the p99.
    ///
    /// ```
    /// use streamapprox::query::Query;
    /// assert_eq!(Query::quantile(0.5).label(), "quantile");
    /// ```
    pub fn quantile(q: f64) -> Self {
        Query::Quantile(q)
    }

    /// Top-k heavy-hitter query.
    ///
    /// ```
    /// use streamapprox::prelude::*;
    ///
    /// let pipeline = PipelineBuilder::new()
    ///     .query(Query::top_k(3))
    ///     .window(WindowConfig::tumbling(1_000))
    ///     .build_native();
    /// let report = pipeline
    ///     .run_stream(&StreamConfig::gaussian_micro(200.0, 9), 3_000)
    ///     .unwrap();
    /// let top = report.windows[0].result.top_k.as_ref().unwrap();
    /// assert!(top.len() <= 3);
    /// ```
    pub fn top_k(k: usize) -> Self {
        Query::TopK(k)
    }

    pub fn label(&self) -> &'static str {
        match self {
            Query::Sum => "sum",
            Query::Mean => "mean",
            Query::Count => "count",
            Query::PerStratumSum => "per-stratum-sum",
            Query::PerStratumMean => "per-stratum-mean",
            Query::Histogram { .. } => "histogram",
            Query::Quantile(_) => "quantile",
            Query::Distinct => "distinct",
            Query::TopK(_) => "top-k",
        }
    }

    /// True for the sketch-backed (non-linear) queries.
    pub fn is_sketch_backed(&self) -> bool {
        matches!(self, Query::Quantile(_) | Query::Distinct | Query::TopK(_))
    }
}

/// Result of a query over one window: `output ± error bound`.
#[derive(Debug, Clone)]
pub struct QueryResult {
    /// Scalar result with CI (Sum/Mean/Count/Quantile/Distinct; for TopK,
    /// the summed top-k mass with the Count-Min over-bound).
    pub scalar: Option<ConfidenceInterval>,
    /// Per-stratum values (PerStratum*, Histogram, and TopK queries).
    pub per_stratum: Option<Vec<f64>>,
    /// Ranked `(key, estimated weight)` pairs — TopK queries only.
    pub top_k: Option<Vec<(u64, f64)>>,
    /// The raw estimate backing the result.
    pub output: WindowOutput,
}

impl QueryResult {
    /// Point value of the scalar result.
    pub fn value(&self) -> f64 {
        self.scalar.map(|ci| ci.value).unwrap_or(f64::NAN)
    }

    /// Relative error bound of the scalar result.
    pub fn relative_bound(&self) -> f64 {
        self.scalar.map(|ci| ci.relative()).unwrap_or(f64::NAN)
    }
}

/// Executes queries over window samples via a compute handle.
pub struct QueryExecutor {
    compute: ComputeHandle,
    level: ConfidenceLevel,
    sketch: SketchParams,
    /// Query-time sketch constructions (the per-window rebuild path).  The
    /// streaming ingest path keeps this at zero — pane sketches arrive
    /// pre-built from the workers — and the engines report the per-run
    /// delta as the acceptance witness ([`crate::engine::SketchIngestStats`]).
    sketch_builds: AtomicU64,
}

impl std::fmt::Debug for QueryExecutor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("QueryExecutor")
            .field("compute", &self.compute)
            .field("level", &self.level)
            .field("sketch", &self.sketch)
            // ordering: monotonic stats counter, diagnostics only.
            .field("sketch_builds", &self.sketch_builds.load(Ordering::Relaxed))
            .finish()
    }
}

impl QueryExecutor {
    pub fn new(compute: ComputeHandle) -> Self {
        Self {
            compute,
            level: ConfidenceLevel::P95,
            sketch: SketchParams::default(),
            sketch_builds: AtomicU64::new(0),
        }
    }

    /// Sketches built at query time by this executor so far (monotone).
    pub fn query_time_sketch_builds(&self) -> u64 {
        // ordering: monotonic stats counter read for reporting only.
        self.sketch_builds.load(Ordering::Relaxed)
    }

    pub fn with_level(mut self, level: ConfidenceLevel) -> Self {
        self.level = level;
        self
    }

    /// Tune the sketches built for Quantile/Distinct/TopK queries.
    pub fn with_sketch_params(mut self, params: SketchParams) -> Self {
        self.sketch = params;
        self
    }

    pub fn sketch_params(&self) -> SketchParams {
        self.sketch
    }

    /// Run `query` over a window's merged sample (single-slice adapter for
    /// [`Self::execute_view`]).
    pub fn execute(&self, query: &Query, window: &SampleResult) -> Result<QueryResult> {
        self.execute_view(query, &WindowView::from_result(window))
    }

    /// Run `query` over a completed window without materializing the
    /// sample: the view's pane-ordered slices stream straight into the
    /// compute input and sketch builders, so the per-slide cost of a query
    /// does not include a span re-merge or clone.
    pub fn execute_view(&self, query: &Query, view: &WindowView<'_>) -> Result<QueryResult> {
        let t0 = crate::obs::metrics_enabled().then(std::time::Instant::now); // lint: wall-clock latency metric only, never feeds results
        let result = {
            let _sp = crate::obs::trace::span("query_execute");
            self.execute_view_impl(query, view)
        };
        if let Some(t0) = t0 {
            query_execute_hist().record_elapsed(t0);
        }
        result
    }

    fn execute_view_impl(&self, query: &Query, view: &WindowView<'_>) -> Result<QueryResult> {
        // Distinct reads only the raw sample values — none of the aggregate
        // output — so skip the compute-service round trip (f32 conversion +
        // cross-thread rendezvous / XLA execution) and finish the estimate
        // locally with the same arithmetic the native executor uses.
        if matches!(query, Query::Distinct) {
            let partials = StrataPartials::from_sample(view.iter());
            let est = estimate(&partials, &view.state);
            let output = WindowOutput { partials, estimate: est, executions: 0 };
            return self.interpret_view(query, view, output);
        }
        let input = WindowInput::from_parts(&view.parts(), &view.state);
        let output = self.compute.aggregate(input)?;
        self.interpret_view(query, view, output)
    }

    /// Run a sketch-backed `query` over pane-level sketches instead of the
    /// window sample: the [`SketchWindow`]'s two-stacks store hands back
    /// the merged span sketch in O(1) merges, so long-window/small-slide
    /// sketch queries cost O(pane) per slide, not O(window) — and with the
    /// streaming ingest path the panes themselves arrive pre-built from
    /// the workers, so this method performs **zero sketch construction**.
    /// `state` is the window's merged counters (for the output's
    /// weights/totals).
    pub fn execute_sketch(
        &self,
        query: &Query,
        sketches: &SketchWindow,
        state: &StrataState,
    ) -> Result<QueryResult> {
        let t0 = crate::obs::metrics_enabled().then(std::time::Instant::now); // lint: wall-clock latency metric only, never feeds results
        let result = {
            let _sp = crate::obs::trace::span("query_execute");
            self.execute_sketch_impl(query, sketches, state)
        };
        if let Some(t0) = t0 {
            query_execute_hist().record_elapsed(t0);
        }
        result
    }

    fn execute_sketch_impl(
        &self,
        query: &Query,
        sketches: &SketchWindow,
        state: &StrataState,
    ) -> Result<QueryResult> {
        // Fail fast on bad arguments or a query/pane kind mismatch before
        // paying the span-sketch aggregate (a clone + merge).
        match (query, &sketches.spec) {
            (Query::Quantile(q), SketchSpec::Quantile { .. }) => {
                if !(0.0..=1.0).contains(q) {
                    return Err(Error::Query(format!("quantile {q} outside [0, 1]")));
                }
            }
            (Query::Distinct, SketchSpec::Distinct { .. }) => {}
            (Query::TopK(k), SketchSpec::TopK { .. }) => {
                if *k == 0 {
                    return Err(Error::Query("top-k with k = 0".into()));
                }
            }
            _ => {
                return Err(Error::Query(format!(
                    "sketch panes do not match the {} query",
                    query.label()
                )))
            }
        }
        let est = estimate(&StrataPartials::default(), state);
        let output =
            WindowOutput { partials: StrataPartials::default(), estimate: est, executions: 0 };
        match (query, &sketches.aggregate()) {
            (Query::Quantile(q), PaneSketch::Quantile(sk)) => {
                Ok(self.quantile_result(*q, sk, output))
            }
            (Query::Distinct, PaneSketch::Distinct(hll)) => {
                Ok(self.distinct_result(hll, output))
            }
            (Query::TopK(k), PaneSketch::TopK(hh)) => Ok(self.topk_result(*k, hh, output)),
            _ => unreachable!("query/spec agreement checked above"),
        }
    }

    /// Interpret a compute output under a query (separated for tests).
    pub fn interpret(
        &self,
        query: &Query,
        window: &SampleResult,
        output: WindowOutput,
    ) -> Result<QueryResult> {
        self.interpret_view(query, &WindowView::from_result(window), output)
    }

    /// Interpret a compute output under a query over a window view.
    pub fn interpret_view(
        &self,
        query: &Query,
        view: &WindowView<'_>,
        output: WindowOutput,
    ) -> Result<QueryResult> {
        let est = &output.estimate;
        let result = match query {
            Query::Sum => QueryResult {
                scalar: Some(ConfidenceInterval::for_sum(est, self.level)),
                per_stratum: None,
                top_k: None,
                output: output.clone(),
            },
            Query::Mean => QueryResult {
                scalar: Some(ConfidenceInterval::for_mean(est, self.level)),
                per_stratum: None,
                top_k: None,
                output: output.clone(),
            },
            Query::Count => {
                // Arrival counters are exact (maintained outside the sample),
                // so COUNT carries a zero-width bound.
                let ci = ConfidenceInterval { value: est.total_c, bound: 0.0, level: self.level };
                QueryResult { scalar: Some(ci), per_stratum: None, top_k: None, output: output.clone() }
            }
            Query::PerStratumSum => QueryResult {
                scalar: Some(ConfidenceInterval::for_sum(est, self.level)),
                per_stratum: Some(est.strata_sums.to_vec()),
                top_k: None,
                output: output.clone(),
            },
            Query::PerStratumMean => {
                let mut means = vec![0.0; MAX_STRATA];
                for s in 0..K {
                    let c = view.state.c[s];
                    if c > 0.0 {
                        means[s] = est.strata_sums[s] / c;
                    }
                }
                QueryResult {
                    scalar: Some(ConfidenceInterval::for_mean(est, self.level)),
                    per_stratum: Some(means),
                    top_k: None,
                    output: output.clone(),
                }
            }
            Query::Histogram { lo, hi, buckets } => {
                if *buckets == 0 || hi <= lo {
                    return Err(Error::Query("bad histogram spec".into()));
                }
                // Weighted histogram over the sample: each selected item of
                // stratum i represents W_i originals.
                let mut hist = vec![0.0; *buckets];
                let width = (hi - lo) / *buckets as f64;
                for &(s, v) in view.iter() {
                    let w = est.weights[s as usize];
                    if v >= *lo && v < *hi {
                        let b = ((v - lo) / width) as usize;
                        hist[b.min(buckets - 1)] += w;
                    }
                }
                QueryResult {
                    scalar: Some(ConfidenceInterval::for_sum(est, self.level)),
                    per_stratum: Some(hist),
                    top_k: None,
                    output: output.clone(),
                }
            }
            Query::Quantile(q) => {
                if !(0.0..=1.0).contains(q) {
                    return Err(Error::Query(format!("quantile {q} outside [0, 1]")));
                }
                let sketch = self.build_quantile(view, &output);
                self.quantile_result(*q, &sketch, output)
            }
            Query::Distinct => {
                let hll = self.build_hll(view);
                self.distinct_result(&hll, output)
            }
            Query::TopK(k) => {
                if *k == 0 {
                    return Err(Error::Query("top-k with k = 0".into()));
                }
                let hh = self.build_heavy_hitters(view, &output);
                self.topk_result(*k, &hh, output)
            }
        };
        Ok(result)
    }

    /// Quantile result with its rank-ε value band (shared by the
    /// window-sample and pane-sketch paths).
    fn quantile_result(
        &self,
        q: f64,
        sketch: &QuantileSketch,
        output: WindowOutput,
    ) -> QueryResult {
        let value = sketch.quantile(q);
        let eps = sketch.eps();
        let lo = sketch.quantile((q - eps).max(0.0));
        let hi = sketch.quantile((q + eps).min(1.0));
        QueryResult {
            scalar: Some(ConfidenceInterval::for_quantile(value, lo, hi, self.level)),
            per_stratum: None,
            top_k: None,
            output,
        }
    }

    /// Distinct-count result.  The interval bounds HLL sketch error only;
    /// under sampling the value is a lower bound on the stream's distinct
    /// count (unselected values are invisible — see
    /// `ConfidenceInterval::for_distinct` and `sketch::hll` docs).
    fn distinct_result(&self, hll: &HyperLogLog, output: WindowOutput) -> QueryResult {
        let ci = ConfidenceInterval::for_distinct(
            hll.estimate(),
            hll.relative_std_error(),
            self.level,
        );
        QueryResult { scalar: Some(ci), per_stratum: None, top_k: None, output }
    }

    /// Top-k result: summed top-k mass as the scalar (each addend
    /// over-counts by at most the Count-Min bound, so the sum carries k of
    /// them) plus the per-stratum count view.
    fn topk_result(&self, k: usize, hh: &HeavyHitters, output: WindowOutput) -> QueryResult {
        let top = hh.top_k(k);
        let mass: f64 = top.iter().map(|&(_, c)| c).sum();
        let ci = ConfidenceInterval::for_count_overestimate(
            mass,
            k as f64 * hh.over_estimate_bound(),
            self.level,
        );
        let mut per_stratum = vec![0.0; MAX_STRATA];
        for &(key, count) in &top {
            if (key as usize) < MAX_STRATA {
                per_stratum[key as usize] = count;
            }
        }
        QueryResult {
            scalar: Some(ci),
            per_stratum: Some(per_stratum),
            top_k: Some(top),
            output,
        }
    }

    /// Sharded sketch construction skeleton: the window sample is split
    /// round-robin into `shards` shards, one sketch is built per shard, and
    /// the shards merge — the same associative, barrier-free combine the
    /// per-worker OASRS results use, exercised on every window.  This is
    /// the *query-time rebuild* path (each call ticks the build-count
    /// witness); the streaming ingest path never reaches it.
    fn build_sharded<S>(
        &self,
        view: &WindowView<'_>,
        mk: impl Fn() -> S,
        mut feed: impl FnMut(&mut S, (u16, f64)),
        merge: impl Fn(&mut S, &S),
    ) -> S {
        // ordering: monotonic stats counter; nothing orders against it.
        self.sketch_builds.fetch_add(1, Ordering::Relaxed);
        crate::obs_counter!(
            "query_sketch_builds_total",
            "sketches constructed at query time (per-window rebuild path)"
        )
        .inc();
        let shards = self.sketch.shards.max(1);
        let mut parts: Vec<S> = (0..shards).map(|_| mk()).collect();
        for (i, &item) in view.iter().enumerate() {
            feed(&mut parts[i % shards], item);
        }
        let mut merged = parts.remove(0);
        for p in &parts {
            merge(&mut merged, p);
        }
        merged
    }

    fn build_quantile(&self, view: &WindowView<'_>, output: &WindowOutput) -> QuantileSketch {
        let est = &output.estimate;
        self.build_sharded(
            view,
            || QuantileSketch::new(self.sketch.quantile_clusters),
            |sk, (s, v)| sk.offer(v, est.weight_for(s)),
            |a, b| a.merge(b),
        )
    }

    fn build_hll(&self, view: &WindowView<'_>) -> HyperLogLog {
        self.build_sharded(
            view,
            || HyperLogLog::new(self.sketch.hll_precision),
            |sk, (_, v)| sk.offer(v),
            |a, b| a.merge(b),
        )
    }

    fn build_heavy_hitters(&self, view: &WindowView<'_>, output: &WindowOutput) -> HeavyHitters {
        let est = &output.estimate;
        self.build_sharded(
            view,
            // Shared seed so per-shard Count-Mins are merge-compatible.
            || {
                HeavyHitters::new(
                    self.sketch.topk_capacity,
                    self.sketch.cm_width,
                    self.sketch.cm_depth,
                    HH_SEED,
                )
            },
            // Key = sub-stream id; mass = HT weight, so the count estimates
            // the stratum's arrivals in the full stream.
            |sk, (s, _)| sk.offer(s as u64, est.weight_for(s)),
            |a, b| a.merge(b),
        )
    }
}

/// Shared handle for pane-store structural merge counting (same family as
/// the window assembler's emission folds).
fn pane_merge_counter() -> crate::obs::Counter {
    crate::obs_counter!(
        "window_pane_merges_total",
        "pane summaries folded into emitted windows (assembler + pane store)"
    )
}

/// Shared handle for the executor's per-query timing (both the view and
/// the pane-sketch paths record into it).
fn query_execute_hist() -> crate::obs::Histogram {
    crate::obs_histogram!(
        "query_execute_ns",
        "one query execution over a completed window (view or pane-sketch path)"
    )
}

/// The [`SketchSpec`] a query registers on the ingest pool, with the
/// process-wide Count-Min seed filled in; `None` for linear queries.  The
/// single source of truth for query → sketch-shape mapping (shared by
/// [`SketchWindow::for_query`] and the engines' pool registration).
pub fn sketch_spec_for(query: &Query, params: SketchParams) -> Option<SketchSpec> {
    match query {
        Query::Quantile(_) => Some(SketchSpec::Quantile { clusters: params.quantile_clusters }),
        Query::Distinct => Some(SketchSpec::Distinct { precision: params.hll_precision }),
        Query::TopK(_) => Some(SketchSpec::TopK {
            capacity: params.topk_capacity,
            cm_width: params.cm_width,
            cm_depth: params.cm_depth,
            seed: HH_SEED,
        }),
        _ => None,
    }
}

/// Pane-level sketch windowing: one mergeable sketch per sampling interval,
/// held in a two-stacks [`PaneStore`] so the merged span sketch costs
/// O(panes evicted + 1) merges per slide — constant-size aggregates, flat
/// across window/slide ratios.  This is what makes sliding windows over
/// sketch queries sustainable in the long-window/small-slide regime
/// (network monitoring, taxi case study) where rebuilding a sketch from
/// the whole window sample per slide would cost O(window).
///
/// Panes arrive on one of two paths, counted separately as the acceptance
/// witness of the streaming ingest tentpole:
///
/// * **[`SketchWindow::push_prebuilt`]** — the production path: the ingest
///   pool's workers built the pane sketch at interval close (spec
///   registered via [`crate::engine::IngestPool::register_sketches`]) and
///   it lands here with zero query-side construction;
/// * **[`SketchWindow::push_pane`]** — the rebuild fallback: fold the
///   interval's sample into a fresh sketch here.  Same fold, same weights
///   ([`SketchSpec::build`]), so single-worker runs produce byte-identical
///   panes on either path.
///
/// Each pane's items are weighted by that interval's own Horvitz–Thompson
/// weights (Eq. 1 from the interval's counters): an interval's selected
/// items represent that interval's arrivals, so the merged sketch estimates
/// the full span.  (The per-window path, `QueryExecutor::execute_view`,
/// weights by the merged span counters instead; both are consistent
/// estimators and the engines choose via `EngineConfig::sketch_panes`.)
#[derive(Debug, Clone)]
pub struct SketchWindow {
    spec: SketchSpec,
    panes: PaneStore<PaneSketch>,
    prebuilt: u64,
    rebuilt: u64,
}

impl SketchWindow {
    /// Pane store for a sketch-backed query spanning `panes_per_window`
    /// sampling intervals; `None` for linear queries.
    pub fn for_query(query: &Query, params: SketchParams, panes_per_window: usize) -> Option<Self> {
        let spec = sketch_spec_for(query, params)?;
        Some(Self {
            spec,
            panes: PaneStore::new(panes_per_window.max(1)),
            prebuilt: 0,
            rebuilt: 0,
        })
    }

    /// The spec to register on the ingest pool so panes arrive pre-built.
    pub fn spec(&self) -> SketchSpec {
        self.spec
    }

    /// Push a worker-built pane sketch into the ring (evicting the expired
    /// pane).  O(1) sketch constructions — the pane was built at ingest.
    /// Panics when the sketch kind does not match the registered query (a
    /// control-plane protocol bug, not a data error).
    pub fn push_prebuilt(&mut self, pane: PaneSketch) {
        assert!(
            pane.matches(&self.spec),
            "pre-built pane sketch does not match the registered query spec"
        );
        self.prebuilt += 1;
        let ops_before = self.panes.merge_ops();
        self.panes.push(pane);
        pane_merge_counter().add(self.panes.merge_ops() - ops_before);
    }

    /// Build this interval's pane sketch from its sample result and push it
    /// into the ring (evicting the expired pane).  O(interval sample) work
    /// on the query side — the fallback when the pool has no registration.
    pub fn push_pane(&mut self, interval: &SampleResult) {
        self.rebuilt += 1;
        let ops_before = self.panes.merge_ops();
        self.panes.push(self.spec.build(interval));
        pane_merge_counter().add(self.panes.merge_ops() - ops_before);
    }

    /// Merged sketch over every pane currently held (the spec's empty
    /// sketch for a pane-less window), at most one sketch merge and zero
    /// sketch builds.
    pub fn aggregate(&self) -> PaneSketch {
        self.panes.aggregate().unwrap_or_else(|| self.spec.empty())
    }

    /// Panes pushed pre-built from the ingest workers.
    pub fn prebuilt_panes(&self) -> u64 {
        self.prebuilt
    }

    /// Panes rebuilt from interval samples on the query side.
    pub fn rebuilt_panes(&self) -> u64 {
        self.rebuilt
    }

    /// Panes currently held.
    pub fn len(&self) -> usize {
        self.panes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Structural pane merges performed so far — the deterministic
    /// flatness instrument: amortized ≤ 2 per slide at any window/slide
    /// ratio (the unit tests pin this; `benches/window_hotpath.rs` asserts
    /// the same property on the underlying [`PaneStore`]).
    pub fn merge_ops(&self) -> u64 {
        self.panes.merge_ops()
    }
}

use crate::runtime::checkpoint::{Snapshot, SnapshotReader, SnapshotWriter};

/// Spec + the pane ring + both provenance counters travel, so a restored
/// window keeps answering from the same per-pane sketches (bit-identical
/// merges) and the prebuilt/rebuilt acceptance counters stay honest across
/// a crash.
impl Snapshot for SketchWindow {
    fn encode(&self, w: &mut SnapshotWriter) {
        self.spec.encode(w);
        self.panes.encode(w);
        w.put_u64(self.prebuilt);
        w.put_u64(self.rebuilt);
    }
    fn decode(r: &mut SnapshotReader) -> crate::core::Result<Self> {
        Ok(Self {
            spec: SketchSpec::decode(r)?,
            panes: PaneStore::<PaneSketch>::decode(r)?,
            prebuilt: r.get_u64()?,
            rebuilt: r.get_u64()?,
        })
    }
}

/// Summed count of the `k` largest entries — the top-k ground-truth scalar
/// shared by [`exact_eval`] and the engines' `exact_values`.
pub fn top_k_mass(counts: &[f64], k: usize) -> f64 {
    let mut ranked: Vec<f64> = counts.to_vec();
    ranked.sort_by(|a, b| b.partial_cmp(a).expect("finite counts"));
    ranked.iter().take(k).sum()
}

/// Indices of the `k` largest counts, largest first (index order breaks
/// ties) — the exact top-k ranking shared by the harness, the integration
/// tests, and the examples when grading recovery.
pub fn top_k_strata(counts: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..counts.len()).collect();
    idx.sort_by(|&a, &b| {
        counts[b].partial_cmp(&counts[a]).expect("finite counts").then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

/// Exact (no-sampling) evaluation of a query over raw items — the ground
/// truth for accuracy-loss measurements (§6.1: |approx − exact| / exact).
pub fn exact_eval(query: &Query, items: &[(u16, f64)]) -> (f64, Vec<f64>) {
    let mut count = [0.0f64; MAX_STRATA];
    let mut sum = [0.0f64; MAX_STRATA];
    for &(s, v) in items {
        if (s as usize) < MAX_STRATA {
            count[s as usize] += 1.0;
            sum[s as usize] += v;
        }
    }
    let total_c: f64 = count.iter().sum();
    let total_sum: f64 = sum.iter().sum();
    match query {
        Query::Sum => (total_sum, vec![]),
        Query::Mean => (if total_c > 0.0 { total_sum / total_c } else { 0.0 }, vec![]),
        Query::Count => (total_c, vec![]),
        Query::PerStratumSum => (total_sum, sum.to_vec()),
        Query::PerStratumMean => {
            let means = (0..MAX_STRATA)
                .map(|s| if count[s] > 0.0 { sum[s] / count[s] } else { 0.0 })
                .collect();
            (if total_c > 0.0 { total_sum / total_c } else { 0.0 }, means)
        }
        Query::Histogram { lo, hi, buckets } => {
            let mut hist = vec![0.0; *buckets];
            let width = (hi - lo) / *buckets as f64;
            for &(_, v) in items {
                if v >= *lo && v < *hi {
                    let b = ((v - lo) / width) as usize;
                    hist[b.min(buckets - 1)] += 1.0;
                }
            }
            (total_sum, hist)
        }
        Query::Quantile(q) => {
            let mut vals: Vec<f64> = items
                .iter()
                .filter(|&&(s, _)| (s as usize) < MAX_STRATA)
                .map(|&(_, v)| v)
                .collect();
            if vals.is_empty() {
                return (f64::NAN, vec![]);
            }
            vals.sort_by(|a, b| a.partial_cmp(b).expect("finite values"));
            let q = q.clamp(0.0, 1.0);
            let idx = ((vals.len() - 1) as f64 * q).round() as usize;
            (vals[idx.min(vals.len() - 1)], vec![])
        }
        Query::Distinct => {
            // BTreeSet over bit patterns (lint rule D1): count is order-
            // free today, but the ground-truth path must stay deterministic
            // if anyone ever iterates it (e.g. to list distinct values).
            let mut seen = std::collections::BTreeSet::new();
            for &(s, v) in items {
                if (s as usize) < MAX_STRATA {
                    let v = if v == 0.0 { 0.0 } else { v };
                    seen.insert(v.to_bits());
                }
            }
            (seen.len() as f64, vec![])
        }
        Query::TopK(k) => {
            // per-stratum item counts; scalar = summed count of the true
            // top-k strata (mirrors the approximate scalar).
            (top_k_mass(&count, *k), count.to_vec())
        }
    }
}

/// Accuracy loss |approx − exact| / |exact| (0 when exact == 0 == approx).
pub fn accuracy_loss(approx: f64, exact: f64) -> f64 {
    if exact == 0.0 {
        if approx == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        (approx - exact).abs() / exact.abs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Item;
    use crate::runtime::ComputeService;
    use crate::sampling::{NoopSampler, Sampler};

    fn window_from_items(items: &[(u16, f64)]) -> SampleResult {
        let mut s = NoopSampler::new();
        for (i, &(st, v)) in items.iter().enumerate() {
            s.offer(&Item::new(st, v, i as u64));
        }
        s.finish_interval()
    }

    fn items() -> Vec<(u16, f64)> {
        let mut v = Vec::new();
        for i in 0..100 {
            v.push((0, 10.0 + (i % 5) as f64));
        }
        for i in 0..50 {
            v.push((1, 100.0 + (i % 3) as f64));
        }
        v
    }

    #[test]
    fn sum_query_exact_on_full_sample() {
        let svc = ComputeService::native();
        let exec = QueryExecutor::new(svc.handle());
        let w = window_from_items(&items());
        let r = exec.execute(&Query::Sum, &w).unwrap();
        let (exact, _) = exact_eval(&Query::Sum, &items());
        assert!((r.value() - exact).abs() < 1e-9);
        assert_eq!(r.scalar.unwrap().bound, 0.0); // fully sampled
    }

    #[test]
    fn mean_and_count() {
        let svc = ComputeService::native();
        let exec = QueryExecutor::new(svc.handle());
        let w = window_from_items(&items());
        let rm = exec.execute(&Query::Mean, &w).unwrap();
        let (exact_mean, _) = exact_eval(&Query::Mean, &items());
        assert!((rm.value() - exact_mean).abs() < 1e-9);
        let rc = exec.execute(&Query::Count, &w).unwrap();
        assert_eq!(rc.value(), 150.0);
    }

    #[test]
    fn per_stratum_queries() {
        let svc = ComputeService::native();
        let exec = QueryExecutor::new(svc.handle());
        let w = window_from_items(&items());
        let r = exec.execute(&Query::PerStratumSum, &w).unwrap();
        let (_, exact) = exact_eval(&Query::PerStratumSum, &items());
        let got = r.per_stratum.unwrap();
        for s in 0..2 {
            assert!((got[s] - exact[s]).abs() < 1e-9, "stratum {s}");
        }
        let r = exec.execute(&Query::PerStratumMean, &w).unwrap();
        let (_, exact) = exact_eval(&Query::PerStratumMean, &items());
        let got = r.per_stratum.unwrap();
        for s in 0..2 {
            assert!((got[s] - exact[s]).abs() < 1e-9, "stratum {s}");
        }
    }

    #[test]
    fn histogram_weighted() {
        let svc = ComputeService::native();
        let exec = QueryExecutor::new(svc.handle());
        let w = window_from_items(&items());
        let q = Query::Histogram { lo: 0.0, hi: 200.0, buckets: 4 };
        let r = exec.execute(&q, &w).unwrap();
        let hist = r.per_stratum.unwrap();
        // stratum 0 values are 10..14 -> bucket 0; stratum 1 ~ 100..102 -> bucket 2
        assert_eq!(hist[0], 100.0);
        assert_eq!(hist[2], 50.0);
        assert_eq!(hist[1] + hist[3], 0.0);
    }

    #[test]
    fn bad_histogram_rejected() {
        let svc = ComputeService::native();
        let exec = QueryExecutor::new(svc.handle());
        let w = window_from_items(&items());
        assert!(exec
            .execute(&Query::Histogram { lo: 1.0, hi: 1.0, buckets: 4 }, &w)
            .is_err());
        assert!(exec
            .execute(&Query::Histogram { lo: 0.0, hi: 1.0, buckets: 0 }, &w)
            .is_err());
    }

    #[test]
    fn accuracy_loss_metric() {
        assert_eq!(accuracy_loss(101.0, 100.0), 0.01);
        assert_eq!(accuracy_loss(0.0, 0.0), 0.0);
        assert!(accuracy_loss(1.0, 0.0).is_infinite());
        assert_eq!(accuracy_loss(99.0, 100.0), 0.01);
    }

    #[test]
    fn quantile_query_on_full_sample() {
        let svc = ComputeService::native();
        let exec = QueryExecutor::new(svc.handle());
        let w = window_from_items(&items());
        let r = exec.execute(&Query::Quantile(0.5), &w).unwrap();
        let (exact, _) = exact_eval(&Query::Quantile(0.5), &items());
        // full sample, coarse distribution (values 10..14 and 100..102);
        // the median must land in the low cluster like the exact one
        assert!((r.value() - exact).abs() < 5.0, "approx {} exact {exact}", r.value());
        // high quantile lands in the stratum-1 cluster
        let r99 = exec.execute(&Query::Quantile(0.99), &w).unwrap();
        assert!(r99.value() > 90.0, "p99 {}", r99.value());
        // band endpoints bracket the value
        let ci = r.scalar.unwrap();
        assert!(ci.bound >= 0.0);
    }

    #[test]
    fn quantile_rejects_out_of_range() {
        let svc = ComputeService::native();
        let exec = QueryExecutor::new(svc.handle());
        let w = window_from_items(&items());
        assert!(exec.execute(&Query::Quantile(-0.1), &w).is_err());
        assert!(exec.execute(&Query::Quantile(1.5), &w).is_err());
    }

    #[test]
    fn distinct_query_counts_unique_values() {
        let svc = ComputeService::native();
        let exec = QueryExecutor::new(svc.handle());
        let w = window_from_items(&items());
        let r = exec.execute(&Query::Distinct, &w).unwrap();
        let (exact, _) = exact_eval(&Query::Distinct, &items());
        assert_eq!(exact, 8.0); // 5 values in stratum 0, 3 in stratum 1
        assert!((r.value() - exact).abs() < 1.0, "distinct {} vs {exact}", r.value());
    }

    #[test]
    fn top_k_query_ranks_strata() {
        let svc = ComputeService::native();
        let exec = QueryExecutor::new(svc.handle());
        let w = window_from_items(&items()); // 100 items stratum 0, 50 stratum 1
        let r = exec.execute(&Query::TopK(2), &w).unwrap();
        let top = r.top_k.as_ref().unwrap();
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].0, 0);
        assert_eq!(top[1].0, 1);
        assert!((top[0].1 - 100.0).abs() < 1.0, "top count {}", top[0].1);
        let (exact_mass, _) = exact_eval(&Query::TopK(2), &items());
        assert!((r.value() - exact_mass).abs() / exact_mass < 0.05);
        assert!(exec.execute(&Query::TopK(0), &w).is_err());
    }

    #[test]
    fn sketch_query_labels_and_predicates() {
        assert_eq!(Query::quantile(0.9).label(), "quantile");
        assert_eq!(Query::Distinct.label(), "distinct");
        assert_eq!(Query::top_k(5).label(), "top-k");
        assert!(Query::Quantile(0.5).is_sketch_backed());
        assert!(Query::Distinct.is_sketch_backed());
        assert!(Query::TopK(1).is_sketch_backed());
        assert!(!Query::Sum.is_sketch_backed());
    }

    #[test]
    fn exact_eval_sketch_variants() {
        let items = vec![(0u16, 1.0), (0, 2.0), (0, 2.0), (1, 5.0), (99, 9.0)];
        let (d, _) = exact_eval(&Query::Distinct, &items);
        assert_eq!(d, 3.0); // 1, 2, 5 (out-of-range stratum ignored)
        let (q, _) = exact_eval(&Query::Quantile(0.5), &items);
        assert_eq!(q, 2.0);
        let (mass, counts) = exact_eval(&Query::TopK(1), &items);
        assert_eq!(mass, 3.0); // stratum 0 has 3 items
        assert_eq!(counts[0], 3.0);
        assert_eq!(counts[1], 1.0);
        // empty input
        let (q, _) = exact_eval(&Query::Quantile(0.5), &[]);
        assert!(q.is_nan());
    }

    #[test]
    fn sketch_window_panes_slide_and_stay_flat() {
        // Pane-level sketch windowing over a 4-pane ring: per-slide
        // structural merges stay ≤ 2 amortized regardless of how many
        // panes have flowed through, and execute_sketch answers from the
        // merged span.
        let svc = ComputeService::native();
        let exec = QueryExecutor::new(svc.handle());
        let query = Query::TopK(2);
        let mut sw = SketchWindow::for_query(&query, SketchParams::default(), 4)
            .expect("sketch-backed query");
        assert!(sw.is_empty());
        assert!(SketchWindow::for_query(&Query::Sum, SketchParams::default(), 4).is_none());

        let mut pushes = 0u64;
        for round in 0..20 {
            // stratum 0 twice as heavy as stratum 1
            let pane = window_from_items(&[
                (0, 1.0),
                (0, 2.0),
                (1, 3.0),
                (0, 4.0 + round as f64),
            ]);
            sw.push_pane(&pane);
            pushes += 1;
            assert!(sw.len() <= 4);
            let window_state = pane.state; // counters of one pane suffice here
            let qr = exec.execute_sketch(&query, &sw, &window_state).unwrap();
            let top = qr.top_k.expect("top-k list");
            assert_eq!(top[0].0, 0, "heaviest stratum must lead");
        }
        assert_eq!(sw.len(), 4);
        assert!(
            sw.merge_ops() <= 2 * pushes,
            "{} structural merges for {pushes} pushes",
            sw.merge_ops()
        );
        // mismatched query/panes is an error, not a panic
        assert!(exec
            .execute_sketch(&Query::Distinct, &sw, &crate::error::estimator::StrataState::default())
            .is_err());
    }

    #[test]
    fn prebuilt_and_rebuilt_panes_agree_and_are_counted() {
        // The two pane paths — worker-built (push_prebuilt) and query-side
        // rebuild (push_pane) — must produce identical stores for the same
        // interval stream, and the provenance counters must tell them apart.
        let svc = ComputeService::native();
        let exec = QueryExecutor::new(svc.handle());
        let query = Query::Quantile(0.5);
        let params = SketchParams::default();
        let mut via_prebuilt = SketchWindow::for_query(&query, params, 3).unwrap();
        let mut via_rebuild = SketchWindow::for_query(&query, params, 3).unwrap();
        let spec = via_prebuilt.spec();
        let mut last_state = crate::error::estimator::StrataState::default();
        for round in 0..8 {
            let pane = window_from_items(&[
                (0, round as f64),
                (0, 10.0 + round as f64),
                (1, 100.0),
            ]);
            via_prebuilt.push_prebuilt(spec.build(&pane)); // "from the worker"
            via_rebuild.push_pane(&pane);
            last_state = pane.state;
        }
        assert_eq!(via_prebuilt.prebuilt_panes(), 8);
        assert_eq!(via_prebuilt.rebuilt_panes(), 0);
        assert_eq!(via_rebuild.prebuilt_panes(), 0);
        assert_eq!(via_rebuild.rebuilt_panes(), 8);
        assert_eq!(via_prebuilt.aggregate(), via_rebuild.aggregate());
        let qa = exec.execute_sketch(&query, &via_prebuilt, &last_state).unwrap();
        let qb = exec.execute_sketch(&query, &via_rebuild, &last_state).unwrap();
        assert_eq!(qa.value().to_bits(), qb.value().to_bits());
    }

    #[test]
    fn execute_sketch_performs_zero_query_time_builds() {
        // The build-count witness at the executor level: pane-store queries
        // never construct a sketch, the per-window rebuild path does.
        let svc = ComputeService::native();
        let exec = QueryExecutor::new(svc.handle());
        let query = Query::Quantile(0.9);
        let mut sw = SketchWindow::for_query(&query, SketchParams::default(), 4).unwrap();
        let spec = sw.spec();
        let mut state = crate::error::estimator::StrataState::default();
        for i in 0..6 {
            let pane = window_from_items(&[(0, i as f64), (0, 2.0 * i as f64)]);
            sw.push_prebuilt(spec.build(&pane));
            state = pane.state;
        }
        let before = exec.query_time_sketch_builds();
        for _ in 0..10 {
            exec.execute_sketch(&query, &sw, &state).unwrap();
        }
        assert_eq!(
            exec.query_time_sketch_builds(),
            before,
            "execute_sketch built a sketch at query time"
        );
        // contrast: the per-window path ticks the witness once per window
        let w = window_from_items(&[(0, 1.0), (0, 2.0), (1, 3.0)]);
        exec.execute(&query, &w).unwrap();
        assert_eq!(exec.query_time_sketch_builds(), before + 1);
    }

    #[test]
    #[should_panic(expected = "does not match the registered query")]
    fn prebuilt_kind_mismatch_panics() {
        let mut sw =
            SketchWindow::for_query(&Query::Quantile(0.5), SketchParams::default(), 2).unwrap();
        let wrong = crate::sketch::SketchSpec::Distinct { precision: 8 }
            .build(&SampleResult::default());
        sw.push_prebuilt(wrong);
    }

    #[test]
    fn sketch_spec_for_maps_queries() {
        let p = SketchParams::default();
        assert!(matches!(
            sketch_spec_for(&Query::Quantile(0.5), p),
            Some(SketchSpec::Quantile { clusters }) if clusters == p.quantile_clusters
        ));
        assert!(matches!(
            sketch_spec_for(&Query::Distinct, p),
            Some(SketchSpec::Distinct { precision }) if precision == p.hll_precision
        ));
        assert!(matches!(
            sketch_spec_for(&Query::TopK(3), p),
            Some(SketchSpec::TopK { seed, .. }) if seed == HH_SEED
        ));
        assert!(sketch_spec_for(&Query::Sum, p).is_none());
        assert!(sketch_spec_for(&Query::Mean, p).is_none());
    }
}
