//! Approximate linear queries (paper §3.2): sum, mean, count, histogram and
//! per-stratum aggregates, executed over a window sample through the
//! compute service (XLA artifacts or the native executor) and annotated with
//! error bounds (§3.3).

use crate::core::{Error, Result, MAX_STRATA};
use crate::error::bounds::{ConfidenceInterval, ConfidenceLevel};
use crate::error::estimator::K;
use crate::runtime::{ComputeHandle, WindowInput, WindowOutput};
use crate::sampling::SampleResult;

/// A streaming query over the item values.
#[derive(Debug, Clone, PartialEq)]
pub enum Query {
    /// Total of all item values (Eq. 3).
    Sum,
    /// Mean of all item values (Eq. 4).
    Mean,
    /// Number of items (estimated from weights when sampled).
    Count,
    /// Per-stratum totals — e.g. TCP/UDP/ICMP traffic sizes (§6.2).
    PerStratumSum,
    /// Per-stratum means — e.g. average trip distance per borough (§6.3).
    PerStratumMean,
    /// Histogram of values over fixed buckets in [lo, hi).
    Histogram { lo: f64, hi: f64, buckets: usize },
}

impl Query {
    pub fn sum() -> Self {
        Query::Sum
    }

    pub fn mean() -> Self {
        Query::Mean
    }

    pub fn label(&self) -> &'static str {
        match self {
            Query::Sum => "sum",
            Query::Mean => "mean",
            Query::Count => "count",
            Query::PerStratumSum => "per-stratum-sum",
            Query::PerStratumMean => "per-stratum-mean",
            Query::Histogram { .. } => "histogram",
        }
    }
}

/// Result of a query over one window: `output ± error bound`.
#[derive(Debug, Clone)]
pub struct QueryResult {
    /// Scalar result with CI (Sum/Mean/Count), if applicable.
    pub scalar: Option<ConfidenceInterval>,
    /// Per-stratum values (PerStratum* and Histogram queries).
    pub per_stratum: Option<Vec<f64>>,
    /// The raw estimate backing the result.
    pub output: WindowOutput,
}

impl QueryResult {
    /// Point value of the scalar result.
    pub fn value(&self) -> f64 {
        self.scalar.map(|ci| ci.value).unwrap_or(f64::NAN)
    }

    /// Relative error bound of the scalar result.
    pub fn relative_bound(&self) -> f64 {
        self.scalar.map(|ci| ci.relative()).unwrap_or(f64::NAN)
    }
}

/// Executes queries over window samples via a compute handle.
pub struct QueryExecutor {
    compute: ComputeHandle,
    level: ConfidenceLevel,
}

impl QueryExecutor {
    pub fn new(compute: ComputeHandle) -> Self {
        Self { compute, level: ConfidenceLevel::P95 }
    }

    pub fn with_level(mut self, level: ConfidenceLevel) -> Self {
        self.level = level;
        self
    }

    /// Run `query` over a window's merged sample.
    pub fn execute(&self, query: &Query, window: &SampleResult) -> Result<QueryResult> {
        let input = WindowInput::from_sample(&window.sample, &window.state);
        let output = self.compute.aggregate(input)?;
        self.interpret(query, window, output)
    }

    /// Interpret a compute output under a query (separated for tests).
    pub fn interpret(
        &self,
        query: &Query,
        window: &SampleResult,
        output: WindowOutput,
    ) -> Result<QueryResult> {
        let est = &output.estimate;
        let result = match query {
            Query::Sum => QueryResult {
                scalar: Some(ConfidenceInterval::for_sum(est, self.level)),
                per_stratum: None,
                output: output.clone(),
            },
            Query::Mean => QueryResult {
                scalar: Some(ConfidenceInterval::for_mean(est, self.level)),
                per_stratum: None,
                output: output.clone(),
            },
            Query::Count => {
                // Arrival counters are exact (maintained outside the sample),
                // so COUNT carries a zero-width bound.
                let ci = ConfidenceInterval { value: est.total_c, bound: 0.0, level: self.level };
                QueryResult { scalar: Some(ci), per_stratum: None, output: output.clone() }
            }
            Query::PerStratumSum => QueryResult {
                scalar: Some(ConfidenceInterval::for_sum(est, self.level)),
                per_stratum: Some(est.strata_sums.to_vec()),
                output: output.clone(),
            },
            Query::PerStratumMean => {
                let mut means = vec![0.0; MAX_STRATA];
                for s in 0..K {
                    let c = window.state.c[s];
                    if c > 0.0 {
                        means[s] = est.strata_sums[s] / c;
                    }
                }
                QueryResult {
                    scalar: Some(ConfidenceInterval::for_mean(est, self.level)),
                    per_stratum: Some(means),
                    output: output.clone(),
                }
            }
            Query::Histogram { lo, hi, buckets } => {
                if *buckets == 0 || hi <= lo {
                    return Err(Error::Query("bad histogram spec".into()));
                }
                // Weighted histogram over the sample: each selected item of
                // stratum i represents W_i originals.
                let mut hist = vec![0.0; *buckets];
                let width = (hi - lo) / *buckets as f64;
                for &(s, v) in &window.sample {
                    let w = est.weights[s as usize];
                    if v >= *lo && v < *hi {
                        let b = ((v - lo) / width) as usize;
                        hist[b.min(buckets - 1)] += w;
                    }
                }
                QueryResult {
                    scalar: Some(ConfidenceInterval::for_sum(est, self.level)),
                    per_stratum: Some(hist),
                    output: output.clone(),
                }
            }
        };
        Ok(result)
    }
}

/// Exact (no-sampling) evaluation of a query over raw items — the ground
/// truth for accuracy-loss measurements (§6.1: |approx − exact| / exact).
pub fn exact_eval(query: &Query, items: &[(u16, f64)]) -> (f64, Vec<f64>) {
    let mut count = [0.0f64; MAX_STRATA];
    let mut sum = [0.0f64; MAX_STRATA];
    for &(s, v) in items {
        if (s as usize) < MAX_STRATA {
            count[s as usize] += 1.0;
            sum[s as usize] += v;
        }
    }
    let total_c: f64 = count.iter().sum();
    let total_sum: f64 = sum.iter().sum();
    match query {
        Query::Sum => (total_sum, vec![]),
        Query::Mean => (if total_c > 0.0 { total_sum / total_c } else { 0.0 }, vec![]),
        Query::Count => (total_c, vec![]),
        Query::PerStratumSum => (total_sum, sum.to_vec()),
        Query::PerStratumMean => {
            let means = (0..MAX_STRATA)
                .map(|s| if count[s] > 0.0 { sum[s] / count[s] } else { 0.0 })
                .collect();
            (if total_c > 0.0 { total_sum / total_c } else { 0.0 }, means)
        }
        Query::Histogram { lo, hi, buckets } => {
            let mut hist = vec![0.0; *buckets];
            let width = (hi - lo) / *buckets as f64;
            for &(_, v) in items {
                if v >= *lo && v < *hi {
                    let b = ((v - lo) / width) as usize;
                    hist[b.min(buckets - 1)] += 1.0;
                }
            }
            (total_sum, hist)
        }
    }
}

/// Accuracy loss |approx − exact| / |exact| (0 when exact == 0 == approx).
pub fn accuracy_loss(approx: f64, exact: f64) -> f64 {
    if exact == 0.0 {
        if approx == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        (approx - exact).abs() / exact.abs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Item;
    use crate::runtime::ComputeService;
    use crate::sampling::{NoopSampler, Sampler};

    fn window_from_items(items: &[(u16, f64)]) -> SampleResult {
        let mut s = NoopSampler::new();
        for (i, &(st, v)) in items.iter().enumerate() {
            s.offer(&Item::new(st, v, i as u64));
        }
        s.finish_interval()
    }

    fn items() -> Vec<(u16, f64)> {
        let mut v = Vec::new();
        for i in 0..100 {
            v.push((0, 10.0 + (i % 5) as f64));
        }
        for i in 0..50 {
            v.push((1, 100.0 + (i % 3) as f64));
        }
        v
    }

    #[test]
    fn sum_query_exact_on_full_sample() {
        let svc = ComputeService::native();
        let exec = QueryExecutor::new(svc.handle());
        let w = window_from_items(&items());
        let r = exec.execute(&Query::Sum, &w).unwrap();
        let (exact, _) = exact_eval(&Query::Sum, &items());
        assert!((r.value() - exact).abs() < 1e-9);
        assert_eq!(r.scalar.unwrap().bound, 0.0); // fully sampled
    }

    #[test]
    fn mean_and_count() {
        let svc = ComputeService::native();
        let exec = QueryExecutor::new(svc.handle());
        let w = window_from_items(&items());
        let rm = exec.execute(&Query::Mean, &w).unwrap();
        let (exact_mean, _) = exact_eval(&Query::Mean, &items());
        assert!((rm.value() - exact_mean).abs() < 1e-9);
        let rc = exec.execute(&Query::Count, &w).unwrap();
        assert_eq!(rc.value(), 150.0);
    }

    #[test]
    fn per_stratum_queries() {
        let svc = ComputeService::native();
        let exec = QueryExecutor::new(svc.handle());
        let w = window_from_items(&items());
        let r = exec.execute(&Query::PerStratumSum, &w).unwrap();
        let (_, exact) = exact_eval(&Query::PerStratumSum, &items());
        let got = r.per_stratum.unwrap();
        for s in 0..2 {
            assert!((got[s] - exact[s]).abs() < 1e-9, "stratum {s}");
        }
        let r = exec.execute(&Query::PerStratumMean, &w).unwrap();
        let (_, exact) = exact_eval(&Query::PerStratumMean, &items());
        let got = r.per_stratum.unwrap();
        for s in 0..2 {
            assert!((got[s] - exact[s]).abs() < 1e-9, "stratum {s}");
        }
    }

    #[test]
    fn histogram_weighted() {
        let svc = ComputeService::native();
        let exec = QueryExecutor::new(svc.handle());
        let w = window_from_items(&items());
        let q = Query::Histogram { lo: 0.0, hi: 200.0, buckets: 4 };
        let r = exec.execute(&q, &w).unwrap();
        let hist = r.per_stratum.unwrap();
        // stratum 0 values are 10..14 -> bucket 0; stratum 1 ~ 100..102 -> bucket 2
        assert_eq!(hist[0], 100.0);
        assert_eq!(hist[2], 50.0);
        assert_eq!(hist[1] + hist[3], 0.0);
    }

    #[test]
    fn bad_histogram_rejected() {
        let svc = ComputeService::native();
        let exec = QueryExecutor::new(svc.handle());
        let w = window_from_items(&items());
        assert!(exec
            .execute(&Query::Histogram { lo: 1.0, hi: 1.0, buckets: 4 }, &w)
            .is_err());
        assert!(exec
            .execute(&Query::Histogram { lo: 0.0, hi: 1.0, buckets: 0 }, &w)
            .is_err());
    }

    #[test]
    fn accuracy_loss_metric() {
        assert_eq!(accuracy_loss(101.0, 100.0), 0.01);
        assert_eq!(accuracy_loss(0.0, 0.0), 0.0);
        assert!(accuracy_loss(1.0, 0.0).is_infinite());
        assert_eq!(accuracy_loss(99.0, 100.0), 0.01);
    }
}
