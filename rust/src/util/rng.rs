//! Seedable statistical RNG: xoshiro256++ core with the distributions the
//! workload generators need (uniform, Gaussian, Poisson, Bernoulli,
//! exponential, log-normal, Zipf-ish categorical).
//!
//! Implemented in-tree (offline build — see Cargo.toml note). xoshiro256++
//! is the reference generator of Blackman & Vigna; SplitMix64 seeds it so
//! any u64 seed yields a well-mixed state.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller variate.
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Rng {
    /// Create from a 64-bit seed (SplitMix64-expanded).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s, gauss_spare: None }
    }

    /// Raw generator state for snapshotting: the four xoshiro words plus
    /// the cached Box-Muller spare.  Restoring via [`Rng::from_state`]
    /// continues the stream exactly where it left off — bit-identical to a
    /// generator that was never serialized, including a pending Gaussian
    /// half-pair.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.gauss_spare)
    }

    /// Rebuild a generator from [`Rng::state`] output.
    pub fn from_state(s: [u64; 4], gauss_spare: Option<f64>) -> Self {
        Self { s, gauss_spare }
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 top bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fill `out` with uniforms in [0, 1), eight per unrolled round, from
    /// the **same** xoshiro stream as repeated [`Rng::f64`] calls — the
    /// output is byte-identical to `out.iter_mut().for_each(|x| *x =
    /// rng.f64())`, so batched kernels built on this stay seed-compatible
    /// with the scalar path.  The state recurrence is serial, but hoisting
    /// the shift/convert/scale tail out of the per-call path lets it
    /// vectorize and amortizes loop control 8-wide.
    pub fn fill_f64(&mut self, out: &mut [f64]) {
        const SCALE: f64 = 1.0 / (1u64 << 53) as f64;
        let mut chunks = out.chunks_exact_mut(8);
        for c in &mut chunks {
            let r0 = self.next_u64();
            let r1 = self.next_u64();
            let r2 = self.next_u64();
            let r3 = self.next_u64();
            let r4 = self.next_u64();
            let r5 = self.next_u64();
            let r6 = self.next_u64();
            let r7 = self.next_u64();
            c[0] = (r0 >> 11) as f64 * SCALE;
            c[1] = (r1 >> 11) as f64 * SCALE;
            c[2] = (r2 >> 11) as f64 * SCALE;
            c[3] = (r3 >> 11) as f64 * SCALE;
            c[4] = (r4 >> 11) as f64 * SCALE;
            c[5] = (r5 >> 11) as f64 * SCALE;
            c[6] = (r6 >> 11) as f64 * SCALE;
            c[7] = (r7 >> 11) as f64 * SCALE;
        }
        for slot in chunks.into_remainder() {
            *slot = self.f64();
        }
    }

    /// Batched Bernoulli mask: `out[i] = (u_i < p)` with uniforms drawn by
    /// [`Rng::fill_f64`] — same stream order as repeated
    /// [`Rng::bernoulli`] calls.  Works through a fixed stack buffer, so it
    /// never allocates.
    pub fn fill_bernoulli(&mut self, p: f64, out: &mut [bool]) {
        let mut buf = [0.0f64; 64];
        let mut rest: &mut [bool] = out;
        while !rest.is_empty() {
            let n = rest.len().min(64);
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(n);
            self.fill_f64(&mut buf[..n]);
            for (slot, &u) in head.iter_mut().zip(&buf[..n]) {
                *slot = u < p;
            }
            rest = tail;
        }
    }

    /// Uniform integer in [lo, hi) — panics if lo >= hi.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range");
        let span = hi - lo;
        // Lemire's multiply-shift with rejection for unbiasedness.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (span as u128);
        let mut l = m as u64;
        if l < span {
            let t = span.wrapping_neg() % span;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (span as u128);
                l = m as u64;
            }
        }
        lo + (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (polar form), cached spare.
    pub fn standard_normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * f);
                return u * f;
            }
        }
    }

    /// Normal(mu, sigma).
    #[inline]
    pub fn normal(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.standard_normal()
    }

    /// Log-normal with parameters of the underlying normal.
    #[inline]
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Exponential with rate lambda.
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(f64::MIN_POSITIVE).ln() / lambda
    }

    /// Poisson(lambda). Knuth's product method for small lambda, normal
    /// approximation (rounded, clamped at 0) for large lambda — the paper's
    /// Poisson workloads go up to lambda = 1e8 where the approximation error
    /// is negligible (relative sd ~ 1e-4).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        }
        let z = self.standard_normal();
        let v = lambda + lambda.sqrt() * z;
        if v < 0.0 {
            0
        } else {
            v.round() as u64
        }
    }

    /// Gamma(shape, 1) for `shape >= 1` — Marsaglia & Tsang's squeeze
    /// method (ACM TOMS '00): `d (1 + c·z)³` with a fast acceptance test,
    /// ~1.05 normal draws per variate.  Used to seed the skip-reservoir's
    /// threshold (via [`Rng::beta`]); panics on `shape < 1` (no boost
    /// transform needed by current callers).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape >= 1.0, "gamma: shape must be >= 1");
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.standard_normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.f64().max(f64::MIN_POSITIVE);
            if u < 1.0 - 0.0331 * x * x * x * x {
                return d * v;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Beta(a, b) for `a, b >= 1` via two Gamma draws, clamped strictly
    /// inside (0, 1) so downstream logarithms stay finite.
    pub fn beta(&mut self, a: f64, b: f64) -> f64 {
        let x = self.gamma(a);
        let y = self.gamma(b);
        (x / (x + y)).clamp(f64::MIN_POSITIVE, 1.0 - f64::EPSILON / 2.0)
    }

    /// Sample an index from (unnormalized) weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn fill_f64_matches_sequential_f64() {
        // The batched fill must consume the stream in exactly the scalar
        // order — this is what keeps columnar kernels byte-identical to
        // the per-item path.  Cover the unrolled body, the remainder tail,
        // and degenerate lengths.
        for len in [0usize, 1, 7, 8, 9, 16, 63, 64, 1000] {
            let mut a = Rng::seed_from_u64(99);
            let mut b = Rng::seed_from_u64(99);
            let mut got = vec![0.0f64; len];
            a.fill_f64(&mut got);
            let want: Vec<f64> = (0..len).map(|_| b.f64()).collect();
            assert_eq!(got, want, "len {len}");
            // and the streams stay in lockstep afterwards
            assert_eq!(a.next_u64(), b.next_u64(), "len {len}: stream diverged");
        }
    }

    #[test]
    fn fill_bernoulli_matches_sequential_bernoulli() {
        for len in [0usize, 1, 63, 64, 65, 300] {
            let mut a = Rng::seed_from_u64(123);
            let mut b = Rng::seed_from_u64(123);
            let mut got = vec![false; len];
            a.fill_bernoulli(0.3, &mut got);
            let want: Vec<bool> = (0..len).map(|_| b.bernoulli(0.3)).collect();
            assert_eq!(got, want, "len {len}");
        }
    }

    #[test]
    fn fill_bernoulli_rate_is_close() {
        let mut r = Rng::seed_from_u64(17);
        let mut mask = vec![false; 200_000];
        r.fill_bernoulli(0.1, &mut mask);
        let hits = mask.iter().filter(|&&b| b).count() as f64;
        let expect = 0.1 * mask.len() as f64;
        // 5 sigma of Binomial(n, 0.1)
        let sd = (mask.len() as f64 * 0.1 * 0.9).sqrt();
        assert!((hits - expect).abs() < 5.0 * sd, "hits {hits} vs {expect}");
    }

    #[test]
    fn fill_f64_lanes_are_uniform_chi_square() {
        // Chi-square uniformity per unrolled lane: bucket each lane's
        // output into 16 cells and test against the uniform expectation —
        // guards against a transposed/unbalanced unroll.
        let mut r = Rng::seed_from_u64(21);
        let rounds = 8_000usize;
        let mut buf = [0.0f64; 8];
        let mut cells = [[0usize; 16]; 8];
        for _ in 0..rounds {
            r.fill_f64(&mut buf);
            for (lane, &u) in buf.iter().enumerate() {
                cells[lane][((u * 16.0) as usize).min(15)] += 1;
            }
        }
        for (lane, counts) in cells.iter().enumerate() {
            let expect = rounds as f64 / 16.0;
            let chi2: f64 = counts
                .iter()
                .map(|&c| {
                    let d = c as f64 - expect;
                    d * d / expect
                })
                .sum();
            // df = 15: mean 15, sd ~5.5; 50 is far beyond any plausible
            // noise while catching real non-uniformity.
            assert!(chi2 < 50.0, "lane {lane}: chi2 {chi2}");
        }
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng::seed_from_u64(4);
        for _ in 0..10_000 {
            let x = r.range_u64(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn range_is_roughly_uniform() {
        let mut r = Rng::seed_from_u64(5);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.range_usize(0, 10)] += 1;
        }
        for &c in &counts {
            let expect = n as f64 / 10.0;
            assert!((c as f64 - expect).abs() < 5.0 * expect.sqrt(), "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(6);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(10.0, 5.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.1, "mean {mean}");
        assert!((var - 25.0).abs() < 0.5, "var {var}");
    }

    #[test]
    fn poisson_small_lambda_moments() {
        let mut r = Rng::seed_from_u64(7);
        let n = 100_000;
        let lambda = 4.0;
        let xs: Vec<f64> = (0..n).map(|_| r.poisson(lambda) as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - lambda).abs() < 0.05, "mean {mean}");
        assert!((var - lambda).abs() < 0.2, "var {var}");
    }

    #[test]
    fn poisson_large_lambda_mean() {
        let mut r = Rng::seed_from_u64(8);
        let n = 10_000;
        let lambda = 1e6;
        let mean = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
        assert!((mean - lambda).abs() / lambda < 1e-3, "mean {mean}");
    }

    #[test]
    fn poisson_zero_and_negative() {
        let mut r = Rng::seed_from_u64(9);
        assert_eq!(r.poisson(0.0), 0);
        assert_eq!(r.poisson(-5.0), 0);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::seed_from_u64(10);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        let n = 40_000;
        for _ in 0..n {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(11);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::seed_from_u64(12);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gamma_moments() {
        let mut r = Rng::seed_from_u64(13);
        for shape in [1.0, 2.5, 10.0, 500.0] {
            let n = 50_000;
            let xs: Vec<f64> = (0..n).map(|_| r.gamma(shape)).collect();
            let mean = xs.iter().sum::<f64>() / n as f64;
            let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
            // Gamma(k, 1): mean k, variance k.
            assert!((mean - shape).abs() < 0.05 * shape, "shape {shape}: mean {mean}");
            assert!((var - shape).abs() < 0.15 * shape, "shape {shape}: var {var}");
        }
    }

    #[test]
    fn beta_moments_and_range() {
        let mut r = Rng::seed_from_u64(14);
        for (a, b) in [(1.0, 1.0), (6.0, 2.0), (64.0, 937.0)] {
            let n = 50_000;
            let xs: Vec<f64> = (0..n).map(|_| r.beta(a, b)).collect();
            let mean = xs.iter().sum::<f64>() / n as f64;
            let expect = a / (a + b);
            assert!(
                (mean - expect).abs() < 0.03 * expect.max(0.05),
                "Beta({a},{b}): mean {mean} != {expect}"
            );
            assert!(xs.iter().all(|&x| x > 0.0 && x < 1.0));
        }
    }
}
