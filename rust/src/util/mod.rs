//! In-tree substrate utilities.
//!
//! The build environment is offline, so everything beyond `xla`/`anyhow`/
//! `thiserror` is implemented here from scratch: a seedable statistical RNG
//! ([`rng`]), a minimal JSON parser/writer ([`json`]), a bounded MPMC
//! channel with blocking backpressure ([`channel`]), a lock-free SPSC ring
//! for the ingest data plane ([`spsc`]), and ASCII table rendering for the
//! benchmark harness ([`table`]).

pub mod channel;
pub mod json;
pub mod rng;
pub mod spsc;
pub mod table;
