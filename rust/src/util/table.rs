//! ASCII table rendering for the benchmark harness — prints the same
//! rows/series the paper's figures plot.

/// A simple column-aligned table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Self {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<w$} ", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a throughput value as "123.4K items/s".
pub fn fmt_throughput(items_per_sec: f64) -> String {
    if items_per_sec >= 1e6 {
        format!("{:.2}M", items_per_sec / 1e6)
    } else if items_per_sec >= 1e3 {
        format!("{:.1}K", items_per_sec / 1e3)
    } else {
        format!("{items_per_sec:.0}")
    }
}

/// Format a fraction as a percentage.
pub fn fmt_pct(frac: f64) -> String {
    format!("{:.2}%", frac * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["sys", "thr"]);
        t.row(vec!["oasrs".into(), "12".into()]);
        t.row(vec!["sts".into(), "345678".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        let lines: Vec<&str> = s.lines().collect();
        // all data lines same width
        assert_eq!(lines[1].len(), lines[3].len());
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn throughput_formatting() {
        assert_eq!(fmt_throughput(500.0), "500");
        assert_eq!(fmt_throughput(12_300.0), "12.3K");
        assert_eq!(fmt_throughput(2_500_000.0), "2.50M");
    }

    #[test]
    fn pct_formatting() {
        assert_eq!(fmt_pct(0.0123), "1.23%");
    }
}
